//! Recycled datagram frame buffers — the allocation-free receive path.
//!
//! Every datagram that crossed a [`super::channel::Datagram`] endpoint
//! used to cost at least one fresh `Vec<u8>`; at the paper's pacing
//! rates (§5.2.2 argues the coding kernels must outrun the wire) the
//! allocator, not the GF(256) kernels, became the receiver's bottleneck.
//! A [`FramePool`] keeps a freelist of `MAX_DATAGRAM`-sized buffers; a
//! [`Frame`] is one leased buffer that returns itself to the pool on
//! drop, so a warmed-up data path recycles the same handful of
//! allocations forever (the steady-state zero-allocation invariant,
//! asserted by `rust/tests/alloc_datapath.rs`).

use crate::coordinator::packet::MAX_DATAGRAM;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shared freelist of fixed-size datagram buffers.
///
/// Lease with [`FramePool::lease`]; buffers come back automatically when
/// the [`Frame`] drops. The pool never shrinks and never blocks: an
/// empty freelist just means one fresh allocation (counted, so tests can
/// assert the steady state stops allocating).
pub struct FramePool {
    free: Mutex<Vec<Vec<u8>>>,
    fresh: AtomicU64,
    recycled: AtomicU64,
}

impl FramePool {
    /// New empty pool (buffers are allocated on first lease, then
    /// recycled).
    pub fn new() -> Arc<FramePool> {
        Arc::new(FramePool {
            free: Mutex::new(Vec::new()),
            fresh: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
        })
    }

    /// Pool pre-warmed with `frames` ready buffers.
    pub fn with_frames(frames: usize) -> Arc<FramePool> {
        let pool = FramePool::new();
        {
            let mut free = pool.free.lock().unwrap();
            for _ in 0..frames {
                free.push(vec![0u8; MAX_DATAGRAM]);
            }
        }
        pool
    }

    /// Lease a frame: recycled when available, freshly allocated
    /// otherwise.
    pub fn lease(self: &Arc<Self>) -> Frame {
        let recycled = self.free.lock().unwrap().pop();
        let buf = match recycled {
            Some(b) => {
                self.recycled.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.fresh.fetch_add(1, Ordering::Relaxed);
                vec![0u8; MAX_DATAGRAM]
            }
        };
        Frame { buf, len: 0, pool: Arc::clone(self) }
    }

    /// (fresh allocations, recycled leases) so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.fresh.load(Ordering::Relaxed), self.recycled.load(Ordering::Relaxed))
    }

    /// Buffers currently parked in the freelist.
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

/// One leased datagram buffer; dereferences to the datagram bytes and
/// returns to its pool when dropped.
///
/// The backing buffer is always `MAX_DATAGRAM` bytes; `len` tracks how
/// much of it is actual datagram content.
pub struct Frame {
    buf: Vec<u8>,
    len: usize,
    pool: Arc<FramePool>,
}

// lint: datapath — the warmed-up frame path (fill, read, recycle-on-drop)
// must not allocate; only the cold `lease` miss above may.

impl Frame {
    /// Copy a datagram into the frame. Oversized payloads are truncated
    /// at `MAX_DATAGRAM`, like a UDP socket buffer would.
    pub fn copy_from(&mut self, src: &[u8]) {
        let n = src.len().min(self.buf.len());
        self.buf[..n].copy_from_slice(&src[..n]);
        self.len = n;
    }

    /// The whole backing buffer, for `recv_into`-style fills; pair with
    /// [`Frame::set_len`].
    pub fn buf_mut(&mut self) -> &mut [u8] {
        &mut self.buf
    }

    /// Record how many bytes of the backing buffer are datagram content.
    pub fn set_len(&mut self, n: usize) {
        assert!(n <= self.buf.len(), "frame content exceeds MAX_DATAGRAM");
        self.len = n;
    }

    /// Datagram length.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl std::ops::Deref for Frame {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.buf[..self.len]
    }
}

impl std::fmt::Debug for Frame {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Frame({} bytes)", self.len)
    }
}

impl Drop for Frame {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        // Pool invariant: only full-size buffers park in the freelist.
        if buf.len() == MAX_DATAGRAM {
            self.pool.free.lock().unwrap().push(buf);
        }
    }
}

// lint: end-datapath

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_recycles_buffers() {
        let pool = FramePool::new();
        let f = pool.lease();
        assert_eq!(pool.stats(), (1, 0));
        drop(f);
        assert_eq!(pool.idle(), 1);
        let f = pool.lease();
        assert_eq!(pool.stats(), (1, 1), "second lease must recycle");
        drop(f);
    }

    #[test]
    fn with_frames_prewarms() {
        let pool = FramePool::with_frames(4);
        assert_eq!(pool.idle(), 4);
        let a = pool.lease();
        let b = pool.lease();
        assert_eq!(pool.stats(), (0, 2), "no fresh allocations needed");
        drop(a);
        drop(b);
        assert_eq!(pool.idle(), 4);
    }

    #[test]
    fn copy_from_sets_content_and_truncates() {
        let pool = FramePool::new();
        let mut f = pool.lease();
        assert!(f.is_empty());
        f.copy_from(b"hello");
        assert_eq!(&*f, b"hello");
        let huge = vec![0xAB; MAX_DATAGRAM + 100];
        f.copy_from(&huge);
        assert_eq!(f.len(), MAX_DATAGRAM, "oversized datagrams truncate");
    }

    #[test]
    fn buf_mut_set_len_roundtrip() {
        let pool = FramePool::new();
        let mut f = pool.lease();
        f.buf_mut()[..3].copy_from_slice(b"abc");
        f.set_len(3);
        assert_eq!(&*f, b"abc");
    }
}
