//! Datagram transports: the in-memory test channel, loss/reorder
//! injectors (the controlled-WAN substitute), real UDP sockets, and the
//! recycled frame buffers behind the allocation-free receive path.

pub mod channel;
pub mod frame;
// The Linux socket-buffer `setsockopt` call is one of the crate's four
// audited unsafe modules (lint rule `unsafe-audit`, DESIGN.md §13).
#[allow(unsafe_code)]
pub mod udp;

pub use channel::{mem_pair, Datagram, LossKnob, LossyChannel, MemChannel, ReorderChannel};
pub use frame::{Frame, FramePool};
pub use udp::{udp_pair, UdpChannel};
