//! Datagram transports: the in-memory test channel, loss/reorder
//! injectors (the controlled-WAN substitute), and real UDP sockets.

pub mod channel;
pub mod udp;

pub use channel::{mem_pair, Datagram, LossyChannel, MemChannel, ReorderChannel};
pub use udp::{udp_pair, UdpChannel};
