//! Real UDP transport over `std::net` (the Boost.Asio substitute for the
//! paper's §5.3 prototype; tokio is not in the offline crate set, and the
//! sender/receiver engines are thread-per-role anyway).

use super::channel::Datagram;
use std::net::{SocketAddr, ToSocketAddrs, UdpSocket};
use std::time::Duration;

/// UDP endpoint connected to a fixed peer. Receives go straight into the
/// caller's buffer ([`Datagram::recv_into`]) — no per-datagram staging
/// copy or allocation.
pub struct UdpChannel {
    sock: UdpSocket,
}

/// Grow kernel socket buffers: Janus bursts 4 KiB datagrams at the full
/// pacing rate, and the default SO_RCVBUF (~200 KiB) silently drops whole
/// FTG runs on loopback whenever the receiver thread lags — losses the
/// protocol would misattribute to the network.
///
/// The `libc` crate is not in the offline vendored set, so the syscall is
/// declared directly against the C library std already links (Linux-only;
/// a no-op elsewhere — correctness never depends on it, only loopback
/// throughput headroom).
#[cfg(target_os = "linux")]
fn grow_buffers(sock: &UdpSocket) {
    use std::os::fd::AsRawFd;
    extern "C" {
        fn setsockopt(
            fd: i32,
            level: i32,
            optname: i32,
            optval: *const std::ffi::c_void,
            optlen: u32,
        ) -> i32;
    }
    const SOL_SOCKET: i32 = 1;
    const SO_RCVBUF: i32 = 8;
    const SO_SNDBUF: i32 = 7;
    let fd = sock.as_raw_fd();
    let size: i32 = 16 * 1024 * 1024;
    // SAFETY: `fd` is a live socket owned by `sock`; `optval` points at a
    // stack i32 whose size is passed as `optlen`. Best-effort — the
    // kernel clamps to rmem_max/wmem_max and errors are ignored.
    unsafe {
        setsockopt(
            fd,
            SOL_SOCKET,
            SO_RCVBUF,
            &size as *const i32 as *const std::ffi::c_void,
            std::mem::size_of::<i32>() as u32,
        );
        setsockopt(
            fd,
            SOL_SOCKET,
            SO_SNDBUF,
            &size as *const i32 as *const std::ffi::c_void,
            std::mem::size_of::<i32>() as u32,
        );
    }
}

#[cfg(not(target_os = "linux"))]
fn grow_buffers(_sock: &UdpSocket) {}

impl UdpChannel {
    /// Bind to `local` and direct all traffic to `peer`.
    pub fn bind_connect<A: ToSocketAddrs, B: ToSocketAddrs>(
        local: A,
        peer: B,
    ) -> std::io::Result<UdpChannel> {
        let sock = UdpSocket::bind(local)?;
        grow_buffers(&sock);
        sock.connect(peer)?;
        Ok(UdpChannel { sock })
    }

    /// Bind to an ephemeral localhost port (peer set later via `connect`).
    pub fn bind_ephemeral() -> std::io::Result<UdpChannel> {
        let sock = UdpSocket::bind(("127.0.0.1", 0))?;
        grow_buffers(&sock);
        Ok(UdpChannel { sock })
    }

    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.sock.local_addr()
    }

    pub fn connect<A: ToSocketAddrs>(&mut self, peer: A) -> std::io::Result<()> {
        self.sock.connect(peer)
    }

    /// Wrap an already-configured socket (must be connected to a peer).
    pub fn from_socket(sock: UdpSocket) -> UdpChannel {
        grow_buffers(&sock);
        UdpChannel { sock }
    }
}

impl Datagram for UdpChannel {
    fn send(&mut self, buf: &[u8]) {
        // UDP may fail transiently (e.g. ECONNREFUSED on loopback before
        // the peer binds); fire-and-forget semantics swallow it.
        let _ = self.sock.send(buf);
    }

    fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize> {
        self.sock.set_read_timeout(Some(timeout)).ok()?;
        self.sock.recv(buf).ok()
    }

    fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize> {
        self.sock.set_nonblocking(true).ok()?;
        let res = self.sock.recv(buf).ok();
        let _ = self.sock.set_nonblocking(false);
        res
    }
}

/// Create a connected localhost UDP pair on ephemeral ports.
pub fn udp_pair() -> std::io::Result<(UdpChannel, UdpChannel)> {
    let mut a = UdpChannel::bind_ephemeral()?;
    let mut b = UdpChannel::bind_ephemeral()?;
    let addr_a = a.local_addr()?;
    let addr_b = b.local_addr()?;
    a.connect(addr_b)?;
    b.connect(addr_a)?;
    Ok((a, b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_roundtrip() {
        let (mut a, mut b) = udp_pair().unwrap();
        a.send(b"ping");
        let got = b.recv_timeout(Duration::from_millis(500)).unwrap();
        assert_eq!(got, b"ping");
        b.send(b"pong");
        let got = a.recv_timeout(Duration::from_millis(500)).unwrap();
        assert_eq!(got, b"pong");
    }

    #[test]
    fn recv_timeout_elapses() {
        let (mut a, _b) = udp_pair().unwrap();
        assert!(a.recv_timeout(Duration::from_millis(30)).is_none());
    }

    #[test]
    fn large_datagram_roundtrip() {
        let (mut a, mut b) = udp_pair().unwrap();
        let payload = vec![0x5Au8; 8192];
        a.send(&payload);
        let got = b.recv_timeout(Duration::from_millis(500)).unwrap();
        assert_eq!(got, payload);
    }

    #[test]
    fn try_recv_nonblocking() {
        let (mut a, mut b) = udp_pair().unwrap();
        assert!(b.try_recv().is_none());
        a.send(b"x");
        // Give the kernel a moment on loopback.
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(b.try_recv().unwrap(), b"x");
    }
}
