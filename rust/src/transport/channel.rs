//! Datagram channel abstraction.
//!
//! The coordinator's sender/receiver engines are transport-agnostic: they
//! speak [`Datagram`], implemented by real UDP sockets ([`super::udp`]),
//! an in-memory pair (tests), and a loss-injecting wrapper (the WAN
//! substitute for the paper's real-network experiments, DESIGN.md §3).
//!
//! The hot-path receive primitive is [`Datagram::recv_into`]: the caller
//! owns the buffer, so a steady-state receiver never allocates per
//! datagram (DESIGN.md §6). The legacy `Vec`-returning methods survive
//! as default shims over the `*_into` primitives and allocate only when
//! a datagram is actually delivered.

use super::frame::{Frame, FramePool};
use crate::coordinator::packet::MAX_DATAGRAM;
use crate::util::Pcg64;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Unreliable, unordered datagram endpoint (UDP semantics).
///
/// Implementors provide `send` plus the buffer-filling `recv_into` /
/// `try_recv_into` primitives (wrappers usually just forward to their
/// inner channel). The legacy `Vec`-returning methods are default
/// shims over those.
pub trait Datagram: Send {
    /// Fire-and-forget send. May silently drop (that is the point).
    fn send(&mut self, buf: &[u8]);

    /// Blocking receive into a caller-provided buffer; returns the
    /// datagram length, `None` on timeout. Datagrams longer than `buf`
    /// are truncated, like a UDP socket read.
    fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize>;

    /// Non-blocking receive into a caller-provided buffer.
    fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize>;

    /// Blocking receive with timeout, allocating. `None` on timeout.
    /// The shim stages through a stack buffer so an *empty* poll costs
    /// no heap allocation — only a delivered datagram does.
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
        let mut buf = [0u8; MAX_DATAGRAM];
        let n = self.recv_into(&mut buf, timeout)?;
        Some(buf[..n].to_vec())
    }

    /// Non-blocking receive, allocating (empty polls allocate nothing).
    fn try_recv(&mut self) -> Option<Vec<u8>> {
        let mut buf = [0u8; MAX_DATAGRAM];
        let n = self.try_recv_into(&mut buf)?;
        Some(buf[..n].to_vec())
    }
}

/// Boxed channels are channels — what lets [`crate::api::Transport`]
/// hand `Box<dyn Datagram>` to the engines' generic entry points.
impl<C: Datagram + ?Sized> Datagram for Box<C> {
    fn send(&mut self, buf: &[u8]) {
        (**self).send(buf)
    }
    fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize> {
        (**self).recv_into(buf, timeout)
    }
    fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize> {
        (**self).try_recv_into(buf)
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
        (**self).recv_timeout(timeout)
    }
    fn try_recv(&mut self) -> Option<Vec<u8>> {
        (**self).try_recv()
    }
}

/// Unbounded FIFO of pooled frames with a condvar for blocking receives
/// — the crate's allocation-free frame hand-off (also the pool
/// receiver's demux fan-in). `closed` mirrors mpsc disconnection:
/// either endpoint of the pair going away marks both queues, so sends
/// to a dead peer drop instead of accumulating and receives from a dead
/// peer return promptly once drained.
pub(crate) struct FrameQueue {
    q: Mutex<VecDeque<Frame>>,
    cv: Condvar,
    closed: AtomicBool,
}

impl FrameQueue {
    pub(crate) fn new() -> Arc<FrameQueue> {
        Arc::new(FrameQueue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
        })
    }

    // lint: datapath — queue operations move pooled frames only; every
    // allocation stays in `new()` above.

    fn close(&self) {
        self.closed.store(true, Ordering::Relaxed);
        self.cv.notify_all();
    }

    pub(crate) fn push(&self, frame: Frame) {
        self.q.lock().unwrap().push_back(frame);
        self.cv.notify_one();
    }

    fn pop(&self) -> Option<Frame> {
        self.q.lock().unwrap().pop_front()
    }

    pub(crate) fn pop_timeout(&self, timeout: Duration) -> Option<Frame> {
        // Clamp so `now + timeout` cannot overflow Instant arithmetic.
        let timeout = timeout.min(Duration::from_secs(3600));
        let deadline = Instant::now() + timeout;
        let mut g = self.q.lock().unwrap();
        loop {
            // Drain queued frames even after the producer went away
            // (mpsc delivers the backlog before reporting Disconnected).
            if let Some(f) = g.pop_front() {
                return Some(f);
            }
            if self.closed.load(Ordering::Relaxed) {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            g = self.cv.wait_timeout(g, deadline - now).unwrap().0;
        }
    }
}

// lint: end-datapath

/// In-memory datagram endpoint (lossless, ordered — loss is layered on
/// with [`LossyChannel`]). Datagrams travel as [`Frame`]s leased from a
/// [`FramePool`] shared by the pair, so a warmed-up channel moves
/// traffic with zero allocations per datagram.
pub struct MemChannel {
    tx: Arc<FrameQueue>,
    rx: Arc<FrameQueue>,
    pool: Arc<FramePool>,
}

/// Connected pair of in-memory endpoints.
pub fn mem_pair() -> (MemChannel, MemChannel) {
    let pool = FramePool::new();
    let ab = FrameQueue::new();
    let ba = FrameQueue::new();
    (
        MemChannel { tx: Arc::clone(&ab), rx: Arc::clone(&ba), pool: Arc::clone(&pool) },
        MemChannel { tx: ba, rx: ab, pool },
    )
}

impl MemChannel {
    /// The pair's shared frame pool (benchmarks and the allocation tests
    /// inspect its recycle statistics).
    pub fn frame_pool(&self) -> &Arc<FramePool> {
        &self.pool
    }

    /// Receive the raw pooled frame (zero-copy; `MemChannel`-specific).
    pub fn recv_frame(&mut self, timeout: Duration) -> Option<Frame> {
        self.rx.pop_timeout(timeout)
    }
}

impl Drop for MemChannel {
    fn drop(&mut self) {
        // Either endpoint going away "disconnects" the pair: the peer's
        // sends start dropping (no consumer) and its blocked receives
        // wake promptly (no producer) — the mpsc semantics the engines'
        // error paths rely on.
        self.tx.close();
        self.rx.close();
    }
}

// lint: datapath — the `*_into` primitives are the engines' per-datagram
// path: lease-copy-push on send, copy-out on receive, zero heap traffic
// once the pool is warm. The allocating `recv_timeout`/`try_recv` shims
// below the end marker are deliberately outside.

impl Datagram for MemChannel {
    fn send(&mut self, buf: &[u8]) {
        if self.tx.closed.load(Ordering::Relaxed) {
            return; // peer gone ⇒ drop, like UDP
        }
        let mut frame = self.pool.lease();
        frame.copy_from(buf);
        self.tx.push(frame);
    }
    fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize> {
        let frame = self.rx.pop_timeout(timeout)?;
        let n = frame.len().min(buf.len());
        buf[..n].copy_from_slice(&frame[..n]);
        Some(n)
    }
    fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize> {
        let frame = self.rx.pop()?;
        let n = frame.len().min(buf.len());
        buf[..n].copy_from_slice(&frame[..n]);
        Some(n)
    }
    // lint: end-datapath
    /// Zero-extra-copy override of the allocating receive: hand the
    /// pooled frame's bytes out as an exact-size `Vec`.
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
        self.rx.pop_timeout(timeout).map(|f| f.to_vec())
    }
    fn try_recv(&mut self) -> Option<Vec<u8>> {
        self.rx.pop().map(|f| f.to_vec())
    }
}

/// Handle for adjusting a [`LossyChannel`]'s loss fraction while the
/// transfer runs (time-varying-loss loopback experiments).
#[derive(Clone)]
pub struct LossKnob(Arc<AtomicU64>);

impl LossKnob {
    pub fn set(&self, fraction: f64) {
        self.0.store(fraction.to_bits(), Ordering::Relaxed);
    }
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Loss/latency-injecting wrapper: drops outgoing datagrams with
/// probability `loss_fraction` — the controlled-WAN substitute used by the
/// loopback experiments (Fig. 6 / Table 2).
///
/// Only *fragment-bearing* packets should be subjected to loss in Janus
/// experiments; the caller decides by wrapping the data path's channel but
/// not the control path's.
///
/// The fraction is stored as `AtomicU64` f64-bits (no mutex on the send
/// path) and a zero fraction skips the RNG draw entirely.
pub struct LossyChannel<C: Datagram> {
    pub inner: C,
    loss_bits: Arc<AtomicU64>,
    rng: Pcg64,
    dropped: u64,
    sent: u64,
}

impl<C: Datagram> LossyChannel<C> {
    pub fn new(inner: C, loss_fraction: f64, seed: u64) -> Self {
        LossyChannel {
            inner,
            loss_bits: Arc::new(AtomicU64::new(loss_fraction.to_bits())),
            rng: Pcg64::seeded(seed),
            dropped: 0,
            sent: 0,
        }
    }

    /// Handle to adjust the loss fraction while the transfer runs
    /// (time-varying-loss loopback experiments).
    pub fn loss_knob(&self) -> LossKnob {
        LossKnob(Arc::clone(&self.loss_bits))
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.sent, self.dropped)
    }
}

impl<C: Datagram> Datagram for LossyChannel<C> {
    fn send(&mut self, buf: &[u8]) {
        self.sent += 1;
        let p = f64::from_bits(self.loss_bits.load(Ordering::Relaxed));
        if p > 0.0 && self.rng.bool_with(p) {
            self.dropped += 1;
            return;
        }
        self.inner.send(buf);
    }
    fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize> {
        self.inner.recv_into(buf, timeout)
    }
    fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize> {
        self.inner.try_recv_into(buf)
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
        self.inner.recv_timeout(timeout)
    }
    fn try_recv(&mut self) -> Option<Vec<u8>> {
        self.inner.try_recv()
    }
}

/// Reordering wrapper: buffers sends and flushes them slightly out of
/// order — for robustness tests (UDP does not guarantee ordering).
/// Anything still buffered is flushed on `Drop`, so a sender that
/// finishes (or aborts) early cannot strand its last `window` datagrams.
pub struct ReorderChannel<C: Datagram> {
    pub inner: C,
    window: usize,
    rng: Pcg64,
    queue: VecDeque<Vec<u8>>,
}

impl<C: Datagram> ReorderChannel<C> {
    pub fn new(inner: C, window: usize, seed: u64) -> Self {
        ReorderChannel { inner, window: window.max(1), rng: Pcg64::seeded(seed), queue: VecDeque::new() }
    }
    fn flush_one(&mut self) {
        if self.queue.is_empty() {
            return;
        }
        let idx = self.rng.range(0, self.queue.len());
        let buf = self.queue.remove(idx).unwrap();
        self.inner.send(&buf);
    }
    /// Flush everything still buffered (call at end of stream).
    pub fn flush(&mut self) {
        while !self.queue.is_empty() {
            self.flush_one();
        }
    }
}

impl<C: Datagram> Datagram for ReorderChannel<C> {
    fn send(&mut self, buf: &[u8]) {
        self.queue.push_back(buf.to_vec());
        while self.queue.len() > self.window {
            self.flush_one();
        }
    }
    fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize> {
        self.flush();
        self.inner.recv_into(buf, timeout)
    }
    fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize> {
        self.inner.try_recv_into(buf)
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
        self.flush();
        self.inner.recv_timeout(timeout)
    }
    fn try_recv(&mut self) -> Option<Vec<u8>> {
        self.inner.try_recv()
    }
}

impl<C: Datagram> Drop for ReorderChannel<C> {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_pair_delivers_both_ways() {
        let (mut a, mut b) = mem_pair();
        a.send(b"hello");
        b.send(b"world");
        assert_eq!(b.recv_timeout(Duration::from_millis(50)).unwrap(), b"hello");
        assert_eq!(a.recv_timeout(Duration::from_millis(50)).unwrap(), b"world");
        assert!(a.try_recv().is_none());
    }

    #[test]
    fn recv_times_out() {
        let (mut a, _b) = mem_pair();
        let start = std::time::Instant::now();
        assert!(a.recv_timeout(Duration::from_millis(20)).is_none());
        assert!(start.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn recv_into_reuses_caller_buffer() {
        let (mut a, mut b) = mem_pair();
        a.send(b"first");
        a.send(b"second!");
        let mut buf = [0u8; MAX_DATAGRAM];
        let n = b.recv_into(&mut buf, Duration::from_millis(50)).unwrap();
        assert_eq!(&buf[..n], b"first");
        let n = b.try_recv_into(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"second!");
        assert!(b.try_recv_into(&mut buf).is_none());
    }

    #[test]
    fn mem_channel_recycles_frames() {
        let (mut a, mut b) = mem_pair();
        let mut buf = [0u8; MAX_DATAGRAM];
        // Warm-up: the first send allocates one frame...
        a.send(b"x");
        b.recv_into(&mut buf, Duration::from_millis(50)).unwrap();
        let (fresh, _) = a.frame_pool().stats();
        // ...which every later ping-pong recycles.
        for _ in 0..100 {
            a.send(b"y");
            b.recv_into(&mut buf, Duration::from_millis(50)).unwrap();
        }
        assert_eq!(a.frame_pool().stats().0, fresh, "steady state must not allocate frames");
    }

    #[test]
    fn recv_frame_is_zero_copy() {
        let (mut a, mut b) = mem_pair();
        a.send(b"payload");
        let frame = b.recv_frame(Duration::from_millis(50)).unwrap();
        assert_eq!(&*frame, b"payload");
        drop(frame);
        assert_eq!(b.frame_pool().idle(), 1, "dropped frame parks in the pool");
    }

    #[test]
    fn dropped_peer_disconnects_the_pair() {
        // Sends to a dead receiver must drop (no unbounded frame
        // build-up), and receives from a dead sender must return
        // promptly after the backlog drains — mpsc semantics.
        let (mut a, mut b) = mem_pair();
        a.send(b"backlog");
        let (fresh_before, _) = a.frame_pool().stats();
        drop(b);
        for _ in 0..100 {
            a.send(b"into the void");
        }
        assert_eq!(
            a.frame_pool().stats().0,
            fresh_before,
            "sends to a dropped peer must not lease frames"
        );
        let (mut c, mut d) = mem_pair();
        d.send(b"last words");
        drop(d);
        assert_eq!(
            c.recv_timeout(Duration::from_secs(30)).unwrap(),
            b"last words",
            "backlog delivers after the sender dropped"
        );
        let start = std::time::Instant::now();
        assert!(c.recv_timeout(Duration::from_secs(30)).is_none());
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "disconnected receive must not wait out the timeout"
        );
    }

    #[test]
    fn lossy_drops_expected_fraction() {
        let (a, mut b) = mem_pair();
        let mut lossy = LossyChannel::new(a, 0.3, 42);
        let n = 10_000;
        for _ in 0..n {
            lossy.send(b"x");
        }
        let mut got = 0;
        while b.try_recv().is_some() {
            got += 1;
        }
        let frac = 1.0 - got as f64 / n as f64;
        assert!((frac - 0.3).abs() < 0.03, "dropped frac {frac}");
        let (sent, dropped) = lossy.stats();
        assert_eq!(sent, n as u64);
        assert_eq!(dropped as usize, n - got);
    }

    #[test]
    fn loss_knob_changes_rate_live() {
        let (a, mut b) = mem_pair();
        let mut lossy = LossyChannel::new(a, 0.0, 1);
        let knob = lossy.loss_knob();
        assert_eq!(knob.get(), 0.0);
        for _ in 0..100 {
            lossy.send(b"x");
        }
        knob.set(1.0);
        for _ in 0..100 {
            lossy.send(b"x");
        }
        let mut got = 0;
        while b.try_recv().is_some() {
            got += 1;
        }
        assert_eq!(got, 100);
    }

    #[test]
    fn reorder_flushes_buffered_datagrams_on_drop() {
        // Regression: a sender that finished early used to strand up to
        // `window` datagrams in the reorder buffer forever.
        let (a, mut b) = mem_pair();
        let mut ch = ReorderChannel::new(a, 8, 5);
        for i in 0..5u32 {
            ch.send(&i.to_le_bytes()); // all 5 stay buffered (window 8)
        }
        drop(ch); // no explicit flush()
        let mut got: Vec<u32> = Vec::new();
        while let Some(buf) = b.try_recv() {
            got.push(u32::from_le_bytes(buf.try_into().unwrap()));
        }
        got.sort_unstable();
        assert_eq!(got, (0..5).collect::<Vec<_>>(), "drop must flush the tail");
    }

    #[test]
    fn boxed_channels_are_channels() {
        let (a, b) = mem_pair();
        let mut a: Box<dyn Datagram> = Box::new(a);
        let mut b: Box<dyn Datagram> = Box::new(b);
        a.send(b"via box");
        assert_eq!(b.recv_timeout(Duration::from_millis(50)).unwrap(), b"via box");
        assert!(a.try_recv().is_none());
    }

    #[test]
    fn reorder_preserves_contents() {
        let (a, mut b) = mem_pair();
        let mut ch = ReorderChannel::new(a, 8, 3);
        for i in 0..100u32 {
            ch.send(&i.to_le_bytes());
        }
        ch.flush();
        let mut got: Vec<u32> = Vec::new();
        while let Some(buf) = b.try_recv() {
            got.push(u32::from_le_bytes(buf.try_into().unwrap()));
        }
        assert_eq!(got.len(), 100);
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(got, sorted, "window 8 should reorder something");
    }
}
