//! Datagram channel abstraction.
//!
//! The coordinator's sender/receiver engines are transport-agnostic: they
//! speak [`Datagram`], implemented by real UDP sockets ([`super::udp`]),
//! an in-memory pair (tests), and a loss-injecting wrapper (the WAN
//! substitute for the paper's real-network experiments, DESIGN.md §3).

use crate::util::Pcg64;
use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Unreliable, unordered datagram endpoint (UDP semantics).
pub trait Datagram: Send {
    /// Fire-and-forget send. May silently drop (that is the point).
    fn send(&mut self, buf: &[u8]);
    /// Blocking receive with timeout. `None` on timeout.
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>>;
    /// Non-blocking receive.
    fn try_recv(&mut self) -> Option<Vec<u8>>;
}

/// Boxed channels are channels — what lets [`crate::api::Transport`]
/// hand `Box<dyn Datagram>` to the engines' generic entry points.
impl<C: Datagram + ?Sized> Datagram for Box<C> {
    fn send(&mut self, buf: &[u8]) {
        (**self).send(buf)
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
        (**self).recv_timeout(timeout)
    }
    fn try_recv(&mut self) -> Option<Vec<u8>> {
        (**self).try_recv()
    }
}

/// In-memory datagram endpoint over std mpsc (lossless, ordered — loss is
/// layered on with [`LossyChannel`]).
pub struct MemChannel {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

/// Connected pair of in-memory endpoints.
pub fn mem_pair() -> (MemChannel, MemChannel) {
    let (tx_a, rx_b) = std::sync::mpsc::channel();
    let (tx_b, rx_a) = std::sync::mpsc::channel();
    (MemChannel { tx: tx_a, rx: rx_a }, MemChannel { tx: tx_b, rx: rx_b })
}

impl Datagram for MemChannel {
    fn send(&mut self, buf: &[u8]) {
        // Peer gone ⇒ drop, like UDP.
        let _ = self.tx.send(buf.to_vec());
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
        match self.rx.recv_timeout(timeout) {
            Ok(b) => Some(b),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }
    fn try_recv(&mut self) -> Option<Vec<u8>> {
        match self.rx.try_recv() {
            Ok(b) => Some(b),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }
}

/// Loss/latency-injecting wrapper: drops outgoing datagrams with
/// probability `loss_fraction` — the controlled-WAN substitute used by the
/// loopback experiments (Fig. 6 / Table 2).
///
/// Only *fragment-bearing* packets should be subjected to loss in Janus
/// experiments; the caller decides by wrapping the data path's channel but
/// not the control path's.
pub struct LossyChannel<C: Datagram> {
    pub inner: C,
    loss_fraction: Arc<Mutex<f64>>,
    rng: Pcg64,
    dropped: u64,
    sent: u64,
}

impl<C: Datagram> LossyChannel<C> {
    pub fn new(inner: C, loss_fraction: f64, seed: u64) -> Self {
        LossyChannel {
            inner,
            loss_fraction: Arc::new(Mutex::new(loss_fraction)),
            rng: Pcg64::seeded(seed),
            dropped: 0,
            sent: 0,
        }
    }

    /// Handle to adjust the loss fraction while the transfer runs
    /// (time-varying-loss loopback experiments).
    pub fn loss_knob(&self) -> Arc<Mutex<f64>> {
        Arc::clone(&self.loss_fraction)
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.sent, self.dropped)
    }
}

impl<C: Datagram> Datagram for LossyChannel<C> {
    fn send(&mut self, buf: &[u8]) {
        self.sent += 1;
        let p = *self.loss_fraction.lock().unwrap();
        if self.rng.bool_with(p) {
            self.dropped += 1;
            return;
        }
        self.inner.send(buf);
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
        self.inner.recv_timeout(timeout)
    }
    fn try_recv(&mut self) -> Option<Vec<u8>> {
        self.inner.try_recv()
    }
}

/// Reordering wrapper: buffers sends and flushes them slightly out of
/// order — for robustness tests (UDP does not guarantee ordering).
/// Anything still buffered is flushed on `Drop`, so a sender that
/// finishes (or aborts) early cannot strand its last `window` datagrams.
pub struct ReorderChannel<C: Datagram> {
    pub inner: C,
    window: usize,
    rng: Pcg64,
    queue: VecDeque<Vec<u8>>,
}

impl<C: Datagram> ReorderChannel<C> {
    pub fn new(inner: C, window: usize, seed: u64) -> Self {
        ReorderChannel { inner, window: window.max(1), rng: Pcg64::seeded(seed), queue: VecDeque::new() }
    }
    fn flush_one(&mut self) {
        if self.queue.is_empty() {
            return;
        }
        let idx = self.rng.range(0, self.queue.len());
        let buf = self.queue.remove(idx).unwrap();
        self.inner.send(&buf);
    }
    /// Flush everything still buffered (call at end of stream).
    pub fn flush(&mut self) {
        while !self.queue.is_empty() {
            self.flush_one();
        }
    }
}

impl<C: Datagram> Datagram for ReorderChannel<C> {
    fn send(&mut self, buf: &[u8]) {
        self.queue.push_back(buf.to_vec());
        while self.queue.len() > self.window {
            self.flush_one();
        }
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
        self.flush();
        self.inner.recv_timeout(timeout)
    }
    fn try_recv(&mut self) -> Option<Vec<u8>> {
        self.inner.try_recv()
    }
}

impl<C: Datagram> Drop for ReorderChannel<C> {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_pair_delivers_both_ways() {
        let (mut a, mut b) = mem_pair();
        a.send(b"hello");
        b.send(b"world");
        assert_eq!(b.recv_timeout(Duration::from_millis(50)).unwrap(), b"hello");
        assert_eq!(a.recv_timeout(Duration::from_millis(50)).unwrap(), b"world");
        assert!(a.try_recv().is_none());
    }

    #[test]
    fn recv_times_out() {
        let (mut a, _b) = mem_pair();
        let start = std::time::Instant::now();
        assert!(a.recv_timeout(Duration::from_millis(20)).is_none());
        assert!(start.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn lossy_drops_expected_fraction() {
        let (a, mut b) = mem_pair();
        let mut lossy = LossyChannel::new(a, 0.3, 42);
        let n = 10_000;
        for _ in 0..n {
            lossy.send(b"x");
        }
        let mut got = 0;
        while b.try_recv().is_some() {
            got += 1;
        }
        let frac = 1.0 - got as f64 / n as f64;
        assert!((frac - 0.3).abs() < 0.03, "dropped frac {frac}");
        let (sent, dropped) = lossy.stats();
        assert_eq!(sent, n as u64);
        assert_eq!(dropped as usize, n - got);
    }

    #[test]
    fn loss_knob_changes_rate_live() {
        let (a, mut b) = mem_pair();
        let mut lossy = LossyChannel::new(a, 0.0, 1);
        let knob = lossy.loss_knob();
        for _ in 0..100 {
            lossy.send(b"x");
        }
        *knob.lock().unwrap() = 1.0;
        for _ in 0..100 {
            lossy.send(b"x");
        }
        let mut got = 0;
        while b.try_recv().is_some() {
            got += 1;
        }
        assert_eq!(got, 100);
    }

    #[test]
    fn reorder_flushes_buffered_datagrams_on_drop() {
        // Regression: a sender that finished early used to strand up to
        // `window` datagrams in the reorder buffer forever.
        let (a, mut b) = mem_pair();
        let mut ch = ReorderChannel::new(a, 8, 5);
        for i in 0..5u32 {
            ch.send(&i.to_le_bytes()); // all 5 stay buffered (window 8)
        }
        drop(ch); // no explicit flush()
        let mut got: Vec<u32> = Vec::new();
        while let Some(buf) = b.try_recv() {
            got.push(u32::from_le_bytes(buf.try_into().unwrap()));
        }
        got.sort_unstable();
        assert_eq!(got, (0..5).collect::<Vec<_>>(), "drop must flush the tail");
    }

    #[test]
    fn boxed_channels_are_channels() {
        let (a, b) = mem_pair();
        let mut a: Box<dyn Datagram> = Box::new(a);
        let mut b: Box<dyn Datagram> = Box::new(b);
        a.send(b"via box");
        assert_eq!(b.recv_timeout(Duration::from_millis(50)).unwrap(), b"via box");
        assert!(a.try_recv().is_none());
    }

    #[test]
    fn reorder_preserves_contents() {
        let (a, mut b) = mem_pair();
        let mut ch = ReorderChannel::new(a, 8, 3);
        for i in 0..100u32 {
            ch.send(&i.to_le_bytes());
        }
        ch.flush();
        let mut got: Vec<u32> = Vec::new();
        while let Some(buf) = b.try_recv() {
            got.push(u32::from_le_bytes(buf.try_into().unwrap()));
        }
        assert_eq!(got.len(), 100);
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(got, sorted, "window 8 should reorder something");
    }
}
