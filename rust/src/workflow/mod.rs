//! Cross-facility workflow orchestration — multiple concurrent Janus
//! transfers sharing one WAN uplink.
//!
//! The paper's motivation (§1) is *workflows*: facilities continuously
//! exchanging many datasets with different urgency. This module is the
//! streaming orchestrator above the per-transfer protocols: a
//! deficit-round-robin scheduler partitions the link rate across active
//! jobs by weight, each job runs its own contract (guaranteed-ε with
//! passive retransmission, or guaranteed-time), λ feedback is shared
//! (one network ⇒ one loss process), and per-job admission/backpressure
//! keeps the aggregate rate at `r_link`.

pub mod scheduler;

pub use crate::api::Contract;
pub use scheduler::{run_campaign, CampaignResult, Job, JobOutcome, SchedulerConfig};
