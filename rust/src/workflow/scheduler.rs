//! Weighted deficit-round-robin transfer scheduler (simulated).
//!
//! Jobs arrive over time; active jobs share the link in proportion to
//! their weights at FTG granularity (one FTG ≈ n fragments is the
//! scheduling quantum, matching the protocol's natural unit). Each job
//! carries a [`Contract`] — the same unified type the `janus::api`
//! facade uses (the scheduler's private `JobContract` twin is gone):
//!
//! * [`Contract::Fidelity`] — all levels needed for ε must arrive;
//!   unrecoverable FTGs are re-queued (passive retransmission), and the
//!   job's parity adapts to the shared λ̂ via Eq. 8.
//! * [`Contract::Deadline`] — per-level parity from Eq. 12 against the
//!   job's *own* remaining deadline (measured from *arrival*); FTGs are
//!   never re-queued; levels with unrecoverable groups are lost.
//! * [`Contract::BestEffort`] — deliver every level reliably (the
//!   Fidelity machinery at the schedule's finest ε).

use crate::api::{Contract, TransferSpec};
use crate::model::error_model::optimize_deadline_paper;
use crate::model::params::{LevelSchedule, NetParams};
use crate::model::time_model::optimize_parity;
use crate::sim::loss::LossProcess;
use std::collections::VecDeque;

/// One dataset transfer request.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: usize,
    pub sched: LevelSchedule,
    pub contract: Contract,
    /// Relative share of the link while active (≥ 1).
    pub weight: u32,
    /// Arrival time, seconds.
    pub arrival: f64,
}

impl Job {
    /// Schedule a transfer described by an API [`TransferSpec`]: the
    /// job inherits the spec's contract; link-level parameters stay in
    /// [`SchedulerConfig`] (one shared uplink for the whole campaign).
    pub fn from_spec(
        id: usize,
        sched: LevelSchedule,
        spec: &TransferSpec,
        weight: u32,
        arrival: f64,
    ) -> Job {
        Job { id, sched, contract: spec.contract(), weight, arrival }
    }
}

/// Orchestrator parameters.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Per-stream link parameters (`net.r` = one stream's pacing rate).
    pub net: NetParams,
    /// λ measurement window (shared across jobs), seconds.
    pub t_w: f64,
    /// Initial λ estimate for the first solves.
    pub initial_lambda: f64,
    /// Parallel uplink streams (the [`crate::coordinator::pool`]
    /// deployment model): jobs fan their FTGs out over `streams`
    /// concurrent paced senders, so the aggregate wire rate is
    /// `streams · net.r`. 1 = the paper's single-stream link.
    pub streams: usize,
}

/// Per-job result.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub id: usize,
    pub start: f64,
    pub finish: f64,
    /// Leading fully-recovered levels.
    pub levels_recovered: usize,
    pub levels_sent: usize,
    pub achieved_eps: f64,
    pub met_contract: bool,
    pub fragments_sent: u64,
    pub fragments_lost: u64,
    pub retransmitted_ftgs: u64,
}

/// Whole-campaign result.
#[derive(Debug, Clone)]
pub struct CampaignResult {
    pub jobs: Vec<JobOutcome>,
    /// Time the last job finished.
    pub makespan: f64,
    /// Fraction of wall time the link carried fragments.
    pub link_utilization: f64,
    /// λ̂ reports over time.
    pub lambda_trace: Vec<(f64, f64)>,
}

/// Scheduling quantum state for one active job.
struct ActiveJob {
    job: Job,
    /// (level, k, m, is_retransmission) FTGs still to send this pass.
    queue: VecDeque<(usize, usize, usize, bool)>,
    /// Unrecoverable FTGs awaiting the next retransmission pass
    /// (error-bound contract only).
    lost: Vec<(usize, usize, usize)>,
    level_ok: Vec<bool>,
    levels_sent: usize,
    deficit: i64,
    started_at: f64,
    fragments_sent: u64,
    fragments_lost: u64,
    retransmitted: u64,
    /// Current Eq. 8 m (error-bound jobs).
    current_m: usize,
    done: bool,
}

impl ActiveJob {
    /// Build the initial FTG queue for a job given λ̂ and `now`.
    fn plan(job: Job, cfg: &SchedulerConfig, lambda: f64, now: f64) -> ActiveJob {
        let p = NetParams { lambda, ..cfg.net };
        let n = cfg.net.n;
        let s = cfg.net.s as u64;
        let mut queue = VecDeque::new();
        let (levels_sent, per_level_m, current_m) = match &job.contract {
            Contract::Fidelity(bound) => {
                let l = job.sched.levels_for_error_bound(*bound).unwrap_or(job.sched.num_levels());
                let m = optimize_parity(&p, job.sched.total_bytes(l)).m;
                (l, vec![m; l], m)
            }
            Contract::BestEffort => {
                // Deliver everything: the Fidelity machinery at ε_L.
                let l = job.sched.num_levels();
                let m = optimize_parity(&p, job.sched.total_bytes(l)).m;
                (l, vec![m; l], m)
            }
            Contract::Deadline(tau) => {
                let remaining = (job.arrival + tau - now).max(0.0);
                match optimize_deadline_paper(&p, &job.sched, remaining) {
                    Some(opt) => {
                        let l = opt.levels;
                        (l, opt.m, 0)
                    }
                    None => (0, vec![], 0), // infeasible: deliver nothing
                }
            }
        };
        for (li, &m) in per_level_m.iter().enumerate() {
            let mut bytes = job.sched.sizes[li];
            while bytes > 0 {
                let k = (n - m).min(bytes.div_ceil(s).max(1) as usize);
                bytes = bytes.saturating_sub(k as u64 * s);
                queue.push_back((li, k, m, false));
            }
        }
        let level_ok = vec![true; levels_sent];
        ActiveJob {
            job,
            queue,
            lost: Vec::new(),
            level_ok,
            levels_sent,
            deficit: 0,
            started_at: now,
            fragments_sent: 0,
            fragments_lost: 0,
            retransmitted: 0,
            current_m,
            done: false,
        }
    }
}

/// Run a campaign of jobs over one shared (simulated) link.
pub fn run_campaign(
    cfg: &SchedulerConfig,
    mut jobs: Vec<Job>,
    loss: &mut dyn LossProcess,
) -> CampaignResult {
    jobs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    assert!(cfg.streams >= 1, "streams must be >= 1");
    // Pool fan-out: N streams pace concurrently, so in aggregate one
    // fragment departs every 1/(N·r) seconds. Modelling the aggregate
    // keeps the (single) loss process's time queries monotone.
    let step = 1.0 / (cfg.net.r * cfg.streams as f64);
    let quantum_frags = cfg.net.n as i64; // one FTG per quantum per weight
    let mut clock = 0.0f64;
    let mut busy_time = 0.0f64;
    let mut outcomes: Vec<Option<JobOutcome>> = vec![None; jobs.len()];
    let mut pending: VecDeque<Job> = jobs.into_iter().collect();
    let mut active: Vec<ActiveJob> = Vec::new();

    // Shared λ measurement.
    let mut lambda_hat = cfg.initial_lambda;
    let mut window_start = 0.0f64;
    let mut window_losses = 0u64;
    let mut lambda_trace = Vec::new();

    let mut rr_index = 0usize;
    loop {
        // Admit arrivals.
        while pending.front().map_or(false, |j| j.arrival <= clock) {
            let job = pending.pop_front().unwrap();
            active.push(ActiveJob::plan(job, cfg, lambda_hat, clock));
        }
        if active.is_empty() {
            match pending.front() {
                Some(j) => {
                    clock = j.arrival;
                    continue;
                }
                None => break,
            }
        }

        // Deficit round robin over active jobs.
        if rr_index >= active.len() {
            rr_index = 0;
        }
        let aj = &mut active[rr_index];
        aj.deficit += quantum_frags * aj.job.weight as i64;

        // Transmit whole FTGs while deficit allows.
        while aj.deficit > 0 {
            let (level, k, m, is_retx) = match aj.queue.pop_front() {
                Some(f) => f,
                None => break,
            };
            let total = k + m;
            let mut lost_in_group = 0usize;
            for _ in 0..total {
                let depart = clock;
                clock += step;
                busy_time += step;
                aj.fragments_sent += 1;
                if loss.is_lost(depart) {
                    aj.fragments_lost += 1;
                    lost_in_group += 1;
                    window_losses += 1;
                }
                if clock - window_start >= cfg.t_w {
                    lambda_hat = window_losses as f64 / cfg.t_w;
                    lambda_trace.push((clock, lambda_hat));
                    window_start = clock;
                    window_losses = 0;
                }
            }
            aj.deficit -= total as i64;
            if lost_in_group > m {
                if aj.job.contract.retransmits() {
                    aj.lost.push((level, k, m));
                } else {
                    aj.level_ok[level] = false;
                }
            }
            if is_retx {
                aj.retransmitted += 1;
            }
        }
        if aj.queue.is_empty() {
            // Pass over: error-bound jobs re-queue their lost FTGs (with
            // parity re-solved for the *current* λ̂ — adaptive behaviour).
            if !aj.lost.is_empty() {
                let p = NetParams { lambda: lambda_hat, ..cfg.net };
                let bytes: u64 = aj
                    .lost
                    .iter()
                    .map(|&(_, k, _)| k as u64 * cfg.net.s as u64)
                    .sum();
                let m_new = optimize_parity(&p, bytes.max(1)).m;
                aj.current_m = m_new;
                for (level, k, _) in aj.lost.drain(..) {
                    // Re-encode with the adapted parity (k stays: the data
                    // fragments are fixed; parity count changes).
                    aj.queue.push_back((level, k, m_new, true));
                }
            } else {
                aj.done = true;
            }
        }

        // Retire finished jobs.
        if active[rr_index].done {
            let aj = active.remove(rr_index);
            let prefix = aj.level_ok.iter().take_while(|&&ok| ok).count();
            let achieved = aj.job.sched.eps_with_levels(prefix);
            let met = match aj.job.contract {
                Contract::Fidelity(bound) => prefix == aj.levels_sent && achieved <= bound,
                Contract::BestEffort => prefix == aj.levels_sent,
                Contract::Deadline(tau) => clock <= aj.job.arrival + tau * 1.001,
            };
            outcomes[aj.job.id] = Some(JobOutcome {
                id: aj.job.id,
                start: aj.started_at,
                finish: clock,
                levels_recovered: prefix,
                levels_sent: aj.levels_sent,
                achieved_eps: achieved,
                met_contract: met,
                fragments_sent: aj.fragments_sent,
                fragments_lost: aj.fragments_lost,
                retransmitted_ftgs: aj.retransmitted,
            });
        } else {
            rr_index += 1;
        }
    }

    let makespan = clock;
    CampaignResult {
        jobs: outcomes.into_iter().map(|o| o.expect("all jobs retired")).collect(),
        makespan,
        link_utilization: if makespan > 0.0 { busy_time / makespan } else { 0.0 },
        lambda_trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::loss::{NoLoss, StaticLoss};

    fn cfg(lambda: f64) -> SchedulerConfig {
        SchedulerConfig {
            net: NetParams::paper_default(lambda),
            t_w: 0.2,
            initial_lambda: lambda,
            streams: 1,
        }
    }

    fn small_sched(scale: u64) -> LevelSchedule {
        LevelSchedule::paper_nyx_scaled(scale)
    }

    fn eb_job(id: usize, arrival: f64, weight: u32) -> Job {
        Job {
            id,
            sched: small_sched(2000),
            contract: Contract::Fidelity(1e-7),
            weight,
            arrival,
        }
    }

    #[test]
    fn single_job_completes_like_plain_transfer() {
        let res = run_campaign(&cfg(0.0), vec![eb_job(0, 0.0, 1)], &mut NoLoss);
        assert_eq!(res.jobs.len(), 1);
        let j = &res.jobs[0];
        assert!(j.met_contract);
        assert_eq!(j.levels_recovered, 4);
        assert_eq!(j.fragments_lost, 0);
        // Wire time ≈ fragments/r.
        let expect = j.fragments_sent as f64 / 19_144.0;
        assert!((res.makespan - expect).abs() / expect < 0.01);
        assert!(res.link_utilization > 0.99);
    }

    #[test]
    fn weights_partition_the_link() {
        // Two identical jobs, weights 3:1 — the heavy one finishes well
        // before the light one.
        let jobs = vec![eb_job(0, 0.0, 3), eb_job(1, 0.0, 1)];
        let res = run_campaign(&cfg(0.0), jobs, &mut NoLoss);
        let (a, b) = (&res.jobs[0], &res.jobs[1]);
        assert!(
            a.finish < b.finish * 0.75,
            "weight-3 job should finish much earlier: {} vs {}",
            a.finish,
            b.finish
        );
        assert!(a.met_contract && b.met_contract);
    }

    #[test]
    fn arrivals_are_respected() {
        let mut late = eb_job(1, 5.0, 1);
        late.arrival = 5.0;
        let res = run_campaign(&cfg(0.0), vec![eb_job(0, 0.0, 1), late], &mut NoLoss);
        assert!(res.jobs[1].start >= 5.0);
        assert!(res.jobs[0].finish <= res.jobs[1].finish);
    }

    #[test]
    fn best_effort_job_delivers_everything() {
        let mut loss = StaticLoss::with_ttl(383.0, 17, 1.0 / 19_144.0);
        let job = Job {
            id: 0,
            sched: small_sched(2000),
            contract: Contract::BestEffort,
            weight: 1,
            arrival: 0.0,
        };
        let res = run_campaign(&cfg(383.0), vec![job], &mut loss);
        let j = &res.jobs[0];
        assert!(j.met_contract, "best effort must deliver all levels");
        assert_eq!(j.levels_recovered, 4);
        assert_eq!(j.levels_sent, 4);
    }

    #[test]
    fn jobs_can_be_built_from_transfer_specs() {
        let spec = TransferSpec::builder()
            .contract(Contract::Fidelity(1e-7))
            .build()
            .unwrap();
        let job = Job::from_spec(3, small_sched(2000), &spec, 2, 1.5);
        assert_eq!(job.id, 3);
        assert_eq!(job.contract, Contract::Fidelity(1e-7));
        assert_eq!(job.weight, 2);
        let res = run_campaign(&cfg(0.0), vec![Job { id: 0, ..job }], &mut NoLoss);
        assert!(res.jobs[0].met_contract);
    }

    #[test]
    fn error_bound_jobs_survive_loss() {
        let mut loss = StaticLoss::with_ttl(383.0, 7, 1.0 / 19_144.0);
        let jobs = vec![eb_job(0, 0.0, 1), eb_job(1, 0.0, 1)];
        let res = run_campaign(&cfg(383.0), jobs, &mut loss);
        for j in &res.jobs {
            assert!(j.met_contract, "job {} failed contract", j.id);
            assert_eq!(j.levels_recovered, 4);
        }
        assert!(res.jobs.iter().any(|j| j.fragments_lost > 0));
    }

    #[test]
    fn deadline_job_meets_its_deadline_under_load() {
        // A deadline job shares the link with a bulk job; its deadline is
        // counted from its own arrival and must hold despite contention.
        let sched = small_sched(2000);
        let bulk = eb_job(0, 0.0, 1);
        let tau = 2.0;
        let dl = Job {
            id: 1,
            sched: sched.clone(),
            contract: Contract::Deadline(tau),
            weight: 4,
            arrival: 0.2,
        };
        let mut loss = StaticLoss::with_ttl(383.0, 9, 1.0 / 19_144.0);
        let res = run_campaign(&cfg(383.0), vec![bulk, dl], &mut loss);
        let j = &res.jobs[1];
        assert!(j.met_contract, "deadline missed: finish {} τ {}", j.finish, 0.2 + tau);
        assert!(j.levels_recovered >= 1);
    }

    #[test]
    fn shared_lambda_estimate_tracks_network() {
        let mut loss = StaticLoss::with_ttl(383.0, 11, 1.0 / 19_144.0);
        let res = run_campaign(
            &cfg(383.0),
            vec![eb_job(0, 0.0, 1), eb_job(1, 0.0, 2)],
            &mut loss,
        );
        assert!(!res.lambda_trace.is_empty());
        let mean: f64 = res.lambda_trace.iter().map(|&(_, l)| l).sum::<f64>()
            / res.lambda_trace.len() as f64;
        assert!(
            (mean - 383.0).abs() / 383.0 < 0.3,
            "shared λ̂ mean {mean} far from 383"
        );
    }

    #[test]
    fn pool_streams_cut_makespan_proportionally() {
        // Same campaign over 1 vs 4 uplink streams: the fan-out should
        // shrink the makespan ~4× (lossless, so no retransmission noise).
        let jobs = || vec![eb_job(0, 0.0, 1), eb_job(1, 0.0, 2)];
        let t1 = run_campaign(&cfg(0.0), jobs(), &mut NoLoss).makespan;
        let mut c4 = cfg(0.0);
        c4.streams = 4;
        let t4 = run_campaign(&c4, jobs(), &mut NoLoss).makespan;
        let ratio = t1 / t4;
        assert!(
            (3.8..=4.2).contains(&ratio),
            "expected ~4x speedup, got {ratio:.2} ({t1:.3}s vs {t4:.3}s)"
        );
    }

    #[test]
    fn pool_streams_still_meet_contracts_under_loss() {
        let mut c = cfg(383.0);
        c.streams = 4;
        let mut loss = StaticLoss::with_ttl(383.0, 5, 1.0 / (4.0 * 19_144.0));
        let res = run_campaign(&c, vec![eb_job(0, 0.0, 1), eb_job(1, 0.0, 1)], &mut loss);
        for j in &res.jobs {
            assert!(j.met_contract, "job {} failed under pooled streams", j.id);
            assert_eq!(j.levels_recovered, 4);
        }
    }

    #[test]
    fn utilization_accounts_for_idle_gaps() {
        // One tiny job at t=0, another at t=10: the link idles between.
        let mut early = eb_job(0, 0.0, 1);
        early.sched = small_sched(20_000);
        let mut late = eb_job(1, 10.0, 1);
        late.sched = small_sched(20_000);
        let res = run_campaign(&cfg(0.0), vec![early, late], &mut NoLoss);
        assert!(res.makespan > 10.0);
        assert!(res.link_utilization < 0.2, "util {}", res.link_utilization);
    }
}
