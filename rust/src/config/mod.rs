//! Lightweight CLI argument parsing and experiment configuration.
//!
//! `clap` is not in the offline vendored crate set, so this module
//! provides the small, predictable subset Janus needs: subcommands,
//! `--key value` / `--key=value` options with typed getters, and `--help`
//! text assembled from declared options.

use std::collections::HashMap;

/// Parsed command line: a subcommand plus `--key value` options.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut it = args.into_iter().peekable();
        let command = match it.peek() {
            Some(a) if !a.starts_with('-') => Some(it.next().unwrap()),
            _ => None,
        };
        let mut opts = HashMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    opts.insert(body.to_string(), it.next().unwrap());
                } else {
                    flags.push(body.to_string());
                }
            } else {
                positional.push(arg);
            }
        }
        Args { command, opts, flags, positional }
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer, got {v:?}")))
            .unwrap_or(default)
    }

    /// Integer option constrained to an inclusive range (e.g. `--streams`
    /// for the transfer pool, which the wire format caps at 255).
    pub fn get_usize_in(&self, name: &str, default: usize, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = self.get_usize(name, default);
        if !(lo..=hi).contains(&v) {
            panic!("--{name} must be in {lo}..={hi}, got {v}");
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        // Note: `--key value` is greedy, so bare flags go last (or use
        // `--key=value` forms before positionals).
        let a = parse("simulate input.bin --lambda 383 --m=4 --adaptive");
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.get_f64("lambda", 0.0), 383.0);
        assert_eq!(a.get_usize("m", 0), 4);
        assert!(a.flag("adaptive"));
        assert_eq!(a.positional, vec!["input.bin"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("optimize");
        assert_eq!(a.get_f64("lambda", 19.0), 19.0);
        assert_eq!(a.get_or("mode", "error-bound"), "error-bound");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.command, None);
        assert!(a.flag("help"));
    }

    #[test]
    fn equals_form() {
        let a = parse("x --tau=401.11");
        assert_eq!(a.get_f64("tau", 0.0), 401.11);
    }

    #[test]
    #[should_panic(expected = "must be a number")]
    fn bad_number_panics() {
        parse("x --lambda abc").get_f64("lambda", 0.0);
    }

    #[test]
    fn ranged_getter_accepts_in_range() {
        let a = parse("pool --streams 8");
        assert_eq!(a.get_usize_in("streams", 4, 1, 255), 8);
        assert_eq!(a.get_usize_in("missing", 4, 1, 255), 4);
    }

    #[test]
    #[should_panic(expected = "must be in 1..=255")]
    fn ranged_getter_rejects_out_of_range() {
        parse("pool --streams 0").get_usize_in("streams", 4, 1, 255);
    }
}
