//! Lightweight CLI argument parsing and experiment configuration.
//!
//! `clap` is not in the offline vendored crate set, so this module
//! provides the small, predictable subset Janus needs: subcommands,
//! `--key value` / `--key=value` options with typed getters, and `--help`
//! text assembled from declared options.

use std::collections::HashMap;

/// Parsed command line: a subcommand plus `--key value` options.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut it = args.into_iter().peekable();
        let command = match it.peek() {
            Some(a) if !a.starts_with('-') => Some(it.next().unwrap()),
            _ => None,
        };
        let mut opts = HashMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    opts.insert(body.to_string(), it.next().unwrap());
                } else {
                    flags.push(body.to_string());
                }
            } else {
                positional.push(arg);
            }
        }
        Args { command, opts, flags, positional }
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer, got {v:?}")))
            .unwrap_or(default)
    }

    /// Integer option constrained to an inclusive range (e.g. `--streams`
    /// for the transfer pool, which the wire format caps at 255).
    pub fn get_usize_in(&self, name: &str, default: usize, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = self.get_usize(name, default);
        if !(lo..=hi).contains(&v) {
            panic!("--{name} must be in {lo}..={hi}, got {v}");
        }
        v
    }

    /// Names of every `--option` present (valued options and bare flags),
    /// for validation against a [`CommandSpec`].
    pub fn option_names(&self) -> impl Iterator<Item = &str> {
        self.opts.keys().map(|k| k.as_str()).chain(self.flags.iter().map(|f| f.as_str()))
    }
}

/// One `--option` a subcommand accepts.
#[derive(Debug, Clone, Copy)]
pub struct OptSpec {
    /// Option name without the leading `--`.
    pub name: &'static str,
    /// Value placeholder shown in help (`None` = boolean flag).
    pub value: Option<&'static str>,
    pub help: &'static str,
}

/// Declarative description of one CLI subcommand: drives the generated
/// `--help` text and the unknown-option rejection (typos used to be
/// silently ignored).
#[derive(Debug, Clone, Copy)]
pub struct CommandSpec {
    pub name: &'static str,
    pub summary: &'static str,
    /// Positional argument placeholders, e.g. `["input.bin"]`.
    pub positional: &'static [&'static str],
    pub opts: &'static [OptSpec],
}

impl CommandSpec {
    /// Generated `--help` text for this subcommand.
    pub fn help_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("janus {} — {}\n\nusage: janus {}", self.name, self.summary, self.name));
        for p in self.positional {
            out.push_str(&format!(" <{p}>"));
        }
        if !self.opts.is_empty() {
            out.push_str(" [options]\n\noptions:\n");
            for o in self.opts {
                let lhs = match o.value {
                    Some(v) => format!("--{} <{v}>", o.name),
                    None => format!("--{}", o.name),
                };
                out.push_str(&format!("  {lhs:<24} {}\n", o.help));
            }
        } else {
            out.push('\n');
        }
        out.push_str("  --help                   show this help\n");
        out
    }

    /// Reject options this subcommand does not declare, valued options
    /// missing their value, and boolean flags given one. The error names
    /// the offender and (for unknown names) lists every valid option.
    pub fn validate(&self, args: &Args) -> Result<(), String> {
        for name in args.option_names() {
            if name == "help" || self.opts.iter().any(|o| o.name == name) {
                continue;
            }
            let mut valid: Vec<&str> = self.opts.iter().map(|o| o.name).collect();
            valid.sort_unstable();
            let valid = valid
                .iter()
                .map(|v| format!("--{v}"))
                .collect::<Vec<_>>()
                .join(", ");
            return Err(format!(
                "janus {}: unknown option --{name}\nvalid options: {}",
                self.name,
                if valid.is_empty() { "(none, only --help)".to_string() } else { valid }
            ));
        }
        // Arity: a declared valued option parsed as a bare flag means its
        // value is missing (it would otherwise be silently defaulted —
        // the failure mode this validation exists to kill), and a boolean
        // flag that swallowed a value means the command line is off by a
        // token.
        for o in self.opts {
            match o.value {
                Some(placeholder) if args.flag(o.name) => {
                    return Err(format!(
                        "janus {}: --{} requires a value <{placeholder}>",
                        self.name, o.name
                    ));
                }
                None => {
                    if let Some(v) = args.get(o.name) {
                        return Err(format!(
                            "janus {}: --{} is a flag and takes no value (got {v:?})",
                            self.name, o.name
                        ));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        // Note: `--key value` is greedy, so bare flags go last (or use
        // `--key=value` forms before positionals).
        let a = parse("simulate input.bin --lambda 383 --m=4 --adaptive");
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.get_f64("lambda", 0.0), 383.0);
        assert_eq!(a.get_usize("m", 0), 4);
        assert!(a.flag("adaptive"));
        assert_eq!(a.positional, vec!["input.bin"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("optimize");
        assert_eq!(a.get_f64("lambda", 19.0), 19.0);
        assert_eq!(a.get_or("mode", "error-bound"), "error-bound");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.command, None);
        assert!(a.flag("help"));
    }

    #[test]
    fn equals_form() {
        let a = parse("x --tau=401.11");
        assert_eq!(a.get_f64("tau", 0.0), 401.11);
    }

    #[test]
    #[should_panic(expected = "must be a number")]
    fn bad_number_panics() {
        parse("x --lambda abc").get_f64("lambda", 0.0);
    }

    #[test]
    fn ranged_getter_accepts_in_range() {
        let a = parse("pool --streams 8");
        assert_eq!(a.get_usize_in("streams", 4, 1, 255), 8);
        assert_eq!(a.get_usize_in("missing", 4, 1, 255), 4);
    }

    #[test]
    #[should_panic(expected = "must be in 1..=255")]
    fn ranged_getter_rejects_out_of_range() {
        parse("pool --streams 0").get_usize_in("streams", 4, 1, 255);
    }

    #[test]
    fn ranged_getter_accepts_boundaries() {
        assert_eq!(parse("pool --streams 1").get_usize_in("streams", 4, 1, 255), 1);
        assert_eq!(parse("pool --streams 255").get_usize_in("streams", 4, 1, 255), 255);
    }

    #[test]
    #[should_panic(expected = "must be in 1..=255")]
    fn ranged_getter_rejects_above_hi() {
        parse("pool --streams 256").get_usize_in("streams", 4, 1, 255);
    }

    #[test]
    fn empty_equals_value_is_kept_as_empty_string() {
        let a = parse("x --mode=");
        assert_eq!(a.get("mode"), Some(""));
        // Empty is not a number: the typed getter must say so, not
        // silently fall back to the default.
        let r = std::panic::catch_unwind(|| a.get_f64("mode", 1.0));
        assert!(r.is_err(), "empty value must not parse as a number");
    }

    #[test]
    fn repeated_option_last_one_wins() {
        let a = parse("x --m 2 --m 7");
        assert_eq!(a.get_usize("m", 0), 7);
    }

    #[test]
    fn repeated_flags_are_deduplicated_by_flag_query() {
        let a = parse("x --verbose --verbose");
        assert!(a.flag("verbose"));
        assert_eq!(a.option_names().filter(|&n| n == "verbose").count(), 2);
    }

    #[test]
    fn option_names_cover_opts_and_flags() {
        let a = parse("x --m=2 --adaptive");
        let mut names: Vec<&str> = a.option_names().collect();
        names.sort_unstable();
        assert_eq!(names, vec!["adaptive", "m"]);
    }

    const TEST_SPEC: CommandSpec = CommandSpec {
        name: "simulate",
        summary: "run a simulated transfer",
        positional: &[],
        opts: &[
            OptSpec { name: "lambda", value: Some("l/s"), help: "loss rate" },
            OptSpec { name: "adaptive", value: None, help: "adaptive parity" },
        ],
    };

    #[test]
    fn command_spec_accepts_declared_options() {
        let a = parse("simulate --lambda 19 --adaptive");
        assert!(TEST_SPEC.validate(&a).is_ok());
        // --help is always accepted.
        assert!(TEST_SPEC.validate(&parse("simulate --help")).is_ok());
    }

    #[test]
    fn command_spec_rejects_valued_option_without_value() {
        // `--lambda` at end of line parses as a bare flag; defaulting it
        // silently would reintroduce the typo-swallowing this fixes.
        let a = parse("simulate --adaptive --lambda");
        let err = TEST_SPEC.validate(&a).unwrap_err();
        assert!(err.contains("--lambda requires a value"), "{err}");
        // Same when the valued option precedes another option.
        let a = parse("simulate --lambda --adaptive");
        assert!(TEST_SPEC.validate(&a).is_err());
    }

    #[test]
    fn command_spec_rejects_flag_with_value() {
        // Greedy parsing makes `--adaptive 19` swallow the next token.
        let a = parse("simulate --adaptive 19");
        let err = TEST_SPEC.validate(&a).unwrap_err();
        assert!(err.contains("takes no value"), "{err}");
    }

    #[test]
    fn command_spec_rejects_unknown_option_listing_valid_ones() {
        let a = parse("simulate --lambada 19");
        let err = TEST_SPEC.validate(&a).unwrap_err();
        assert!(err.contains("--lambada"), "{err}");
        assert!(err.contains("--lambda"), "must list valid options: {err}");
        assert!(err.contains("--adaptive"), "must list valid options: {err}");
    }

    #[test]
    fn command_spec_help_text_mentions_every_option() {
        let h = TEST_SPEC.help_text();
        assert!(h.contains("janus simulate"));
        assert!(h.contains("--lambda <l/s>"));
        assert!(h.contains("--adaptive"));
        assert!(h.contains("--help"));
    }
}
