//! Dialing a daemon from a plain endpoint: the tagged-datagram dialect
//! as a [`Datagram`] wrapper and a single-stream [`Transport`].
//!
//! A [`crate::serve::Daemon`] only speaks transfer-tagged datagrams on
//! its shared sockets. [`TaggedChannel`] makes any ordinary channel
//! speak that dialect for exactly one transfer id: sends are wrapped in
//! the [`packet::encode_tagged`] envelope, receives peel it and drop
//! anything tagged for a different transfer (other tenants' traffic on
//! the same shared socket). [`ServeTransport`] packages one such
//! channel as a [`Transport`], so an unmodified [`crate::api::Endpoint`]
//! can run a transfer against a daemon.

use crate::api::transport::Transport;
use crate::coordinator::packet::{self, MAX_DATAGRAM};
use crate::transport::channel::Datagram;
use crate::util::err::Result;
use crate::{anyhow, bail};
use std::time::{Duration, Instant};

/// One transfer's view of a shared tagged socket.
pub struct TaggedChannel<C: Datagram> {
    inner: C,
    id: u32,
    sbuf: Vec<u8>,
    rbuf: Vec<u8>,
}

impl<C: Datagram> TaggedChannel<C> {
    pub fn new(inner: C, id: u32) -> TaggedChannel<C> {
        TaggedChannel {
            inner,
            id,
            sbuf: Vec::with_capacity(MAX_DATAGRAM),
            rbuf: vec![0u8; MAX_DATAGRAM],
        }
    }

    /// Copy a peeled inner packet out if the tag matches our id.
    /// Foreign and untagged datagrams vanish, like a kernel dropping
    /// someone else's port traffic.
    fn accept(&self, n: usize, buf: &mut [u8]) -> Option<usize> {
        let (id, inner) = packet::peel_tag(&self.rbuf[..n])?;
        if id != self.id {
            return None;
        }
        let m = inner.len().min(buf.len());
        buf[..m].copy_from_slice(&inner[..m]);
        Some(m)
    }
}

impl<C: Datagram> Datagram for TaggedChannel<C> {
    fn send(&mut self, buf: &[u8]) {
        packet::encode_tagged(self.id, buf, &mut self.sbuf);
        self.inner.send(&self.sbuf);
    }

    fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize> {
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            let n = self.inner.recv_into(&mut self.rbuf, left)?;
            if let Some(m) = self.accept(n, buf) {
                return Some(m);
            }
            if deadline.saturating_duration_since(Instant::now()).is_zero() {
                return None;
            }
        }
    }

    fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize> {
        loop {
            let n = self.inner.try_recv_into(&mut self.rbuf)?;
            if let Some(m) = self.accept(n, buf) {
                return Some(m);
            }
        }
    }
}

/// Single-stream [`Transport`] for one transfer against a daemon
/// socket — [`crate::api::transport::ChannelTransport`] with the tag
/// envelope applied.
pub struct ServeTransport {
    control: Option<Box<dyn Datagram>>,
}

impl ServeTransport {
    /// `chan` is (one end of) the daemon's shared socket; `id` must
    /// match the id the transfer was registered under.
    pub fn new(chan: impl Datagram + 'static, id: u32) -> ServeTransport {
        ServeTransport { control: Some(Box::new(TaggedChannel::new(chan, id))) }
    }
}

impl Transport for ServeTransport {
    fn open_control(&mut self) -> Result<Box<dyn Datagram>> {
        self.control
            .take()
            .ok_or_else(|| anyhow!("serve transport: control already opened"))
    }

    fn open_data(&mut self, stream: usize) -> Result<Box<dyn Datagram>> {
        bail!("serve transport is single-stream; no data channel {stream}")
    }
}
