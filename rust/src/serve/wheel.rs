//! Hashed timer wheel ordering every machine's `poll_timeout()`.
//!
//! The daemon multiplexes thousands of transfers, each with one armed
//! deadline (pacing gate, barrier retry, idle/max-duration expiry). A
//! wheel keeps arming O(1): deadlines hash into `granularity`-wide
//! buckets; `advance` walks the cursor to `now` and fires everything
//! due. Entries keep their *exact* `Instant` — a bucket holds a range
//! of deadlines, and `advance` re-files entries whose exact time has
//! not arrived yet — so [`TimerWheel::next_deadline`] can answer the
//! virtual-clock question ("what is the next instant anything becomes
//! due?") exactly, which is what lets [`crate::serve::Daemon`] jump
//! virtual time without ever sleeping.
//!
//! Cancellation is lazy: the daemon never removes entries. A fired key
//! whose slot re-armed (or died) since is a spurious wake-up, and
//! machines tolerate spurious `handle_timeout` calls by design.

use std::time::{Duration, Instant};

/// One-deadline-per-key hashed wheel. Keys are caller-defined (the
/// daemon uses slot indices).
pub struct TimerWheel {
    origin: Instant,
    granularity: Duration,
    buckets: Vec<Vec<(u64, Instant)>>,
    /// Deadlines beyond the wheel horizon, re-filed as the cursor wraps.
    overflow: Vec<(u64, Instant)>,
    /// Tick index of the next bucket `advance` will drain.
    cursor: u64,
    /// Live entries (buckets + overflow) — cheap emptiness probe.
    len: usize,
}

impl TimerWheel {
    /// `slots × granularity` is the horizon; later deadlines go to the
    /// overflow list and are re-filed as the cursor approaches them.
    pub fn new(origin: Instant, granularity: Duration, slots: usize) -> TimerWheel {
        assert!(slots > 0 && granularity > Duration::ZERO);
        TimerWheel {
            origin,
            granularity,
            buckets: vec![Vec::new(); slots],
            overflow: Vec::new(),
            cursor: 0,
            len: 0,
        }
    }

    fn tick_of(&self, at: Instant) -> u64 {
        let dt = at.saturating_duration_since(self.origin);
        (dt.as_nanos() / self.granularity.as_nanos().max(1)) as u64
    }

    /// Round `at` up to the end of its bucket — the effective firing
    /// resolution. The daemon's virtual clock jumps to bucket ends so
    /// one jump drains one whole bucket (the wheel's batching unit).
    pub fn bucket_end(&self, at: Instant) -> Instant {
        self.origin + self.granularity * (self.tick_of(at) as u32 + 1)
    }

    /// Arm `key` at `at`. Deadlines already in the past land in the
    /// cursor's bucket and fire on the next `advance`.
    pub fn schedule(&mut self, key: u64, at: Instant) {
        let tick = self.tick_of(at).max(self.cursor);
        if tick >= self.cursor + self.buckets.len() as u64 {
            self.overflow.push((key, at));
        } else {
            let idx = (tick % self.buckets.len() as u64) as usize;
            self.buckets[idx].push((key, at));
        }
        self.len += 1;
    }

    /// Walk the cursor to `now`, appending every key whose exact
    /// deadline has passed to `fired`. Same-bucket entries with later
    /// exact times are re-filed, never fired early.
    pub fn advance(&mut self, now: Instant, fired: &mut Vec<u64>) {
        let now_tick = self.tick_of(now);
        while self.cursor <= now_tick {
            let idx = (self.cursor % self.buckets.len() as u64) as usize;
            let entries = std::mem::take(&mut self.buckets[idx]);
            self.cursor += 1;
            self.len -= entries.len();
            for (key, at) in entries {
                if at <= now {
                    fired.push(key);
                } else {
                    self.schedule(key, at);
                }
            }
            // The cursor moved: overflow entries may now be inside the
            // horizon.
            let horizon = self.cursor + self.buckets.len() as u64;
            let mut i = 0;
            while i < self.overflow.len() {
                let (key, at) = self.overflow[i];
                if self.tick_of(at).max(self.cursor) < horizon {
                    self.overflow.swap_remove(i);
                    self.len -= 1;
                    self.schedule(key, at);
                } else {
                    i += 1;
                }
            }
        }
    }

    /// Exact minimum armed `Instant` (buckets and overflow), or `None`
    /// when nothing is armed. Scans from the cursor to the first
    /// non-empty bucket — O(gap), cheap in steady state because the
    /// nearest deadline is almost always near the cursor.
    pub fn next_deadline(&self) -> Option<Instant> {
        let mut best: Option<Instant> = None;
        if self.len > self.overflow.len() {
            for off in 0..self.buckets.len() as u64 {
                let idx = ((self.cursor + off) % self.buckets.len() as u64) as usize;
                let b = &self.buckets[idx];
                if b.is_empty() {
                    continue;
                }
                best = b.iter().map(|&(_, at)| at).min();
                break;
            }
        }
        for &(_, at) in &self.overflow {
            best = Some(best.map_or(at, |x| x.min(at)));
        }
        best
    }

    /// Live entries (including stale ones not yet lazily discarded).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wheel() -> (TimerWheel, Instant) {
        let origin = Instant::now();
        (TimerWheel::new(origin, Duration::from_millis(1), 16), origin)
    }

    #[test]
    fn fires_in_deadline_order() {
        let (mut w, t0) = wheel();
        w.schedule(1, t0 + Duration::from_millis(5));
        w.schedule(2, t0 + Duration::from_millis(2));
        w.schedule(3, t0 + Duration::from_millis(9));
        assert_eq!(w.len(), 3);
        let mut fired = Vec::new();
        w.advance(t0 + Duration::from_millis(3), &mut fired);
        assert_eq!(fired, vec![2]);
        w.advance(t0 + Duration::from_millis(20), &mut fired);
        assert_eq!(fired, vec![2, 1, 3]);
        assert!(w.is_empty());
    }

    #[test]
    fn same_bucket_later_instant_not_fired_early() {
        let (mut w, t0) = wheel();
        // Two deadlines in the same 1 ms bucket, 400 µs apart.
        w.schedule(1, t0 + Duration::from_micros(4200));
        w.schedule(2, t0 + Duration::from_micros(4600));
        let mut fired = Vec::new();
        w.advance(t0 + Duration::from_micros(4300), &mut fired);
        assert_eq!(fired, vec![1], "later same-bucket entry must be re-filed, not fired");
        assert_eq!(w.len(), 1);
        w.advance(t0 + Duration::from_micros(5100), &mut fired);
        assert_eq!(fired, vec![1, 2]);
    }

    #[test]
    fn overflow_refiles_into_horizon() {
        let (mut w, t0) = wheel();
        // Horizon is 16 ms: a 40 ms deadline starts in overflow.
        w.schedule(7, t0 + Duration::from_millis(40));
        assert_eq!(w.next_deadline(), Some(t0 + Duration::from_millis(40)));
        let mut fired = Vec::new();
        w.advance(t0 + Duration::from_millis(30), &mut fired);
        assert!(fired.is_empty());
        w.advance(t0 + Duration::from_millis(41), &mut fired);
        assert_eq!(fired, vec![7]);
        assert!(w.is_empty());
    }

    #[test]
    fn next_deadline_is_exact_min() {
        let (mut w, t0) = wheel();
        assert_eq!(w.next_deadline(), None);
        w.schedule(1, t0 + Duration::from_millis(12));
        w.schedule(2, t0 + Duration::from_micros(3700));
        w.schedule(3, t0 + Duration::from_millis(100)); // overflow
        assert_eq!(w.next_deadline(), Some(t0 + Duration::from_micros(3700)));
        let mut fired = Vec::new();
        w.advance(t0 + Duration::from_millis(4), &mut fired);
        assert_eq!(fired, vec![2]);
        assert_eq!(w.next_deadline(), Some(t0 + Duration::from_millis(12)));
    }

    #[test]
    fn past_deadlines_fire_on_next_advance() {
        let (mut w, t0) = wheel();
        let mut fired = Vec::new();
        w.advance(t0 + Duration::from_millis(8), &mut fired);
        w.schedule(1, t0 + Duration::from_millis(2)); // already past
        assert!(w.next_deadline().is_some());
        w.advance(t0 + Duration::from_millis(8), &mut fired);
        assert_eq!(fired, vec![1]);
    }
}
