//! `janus serve` — a multi-tenant transfer daemon multiplexing many
//! concurrent transfers over shared sockets on one event loop
//! (DESIGN.md §10).
//!
//! The blocking engines bind one transfer to one channel and one
//! thread; a facility-edge data mover wants thousands of concurrent
//! transfers through a handful of sockets. The daemon gets there with
//! the [`crate::engine`] machines:
//!
//! * **Transfer-id routing** — every datagram on a daemon socket wears
//!   the [`crate::coordinator::packet::encode_tagged`] envelope. The
//!   loop peels the tag and routes the inner packet to the owning
//!   machine through sharded `(socket, id) → slot` tables; untagged or
//!   unknown datagrams are counted and dropped.
//! * **One event loop** — no per-transfer threads. Sockets are drained
//!   non-blockingly; touched slots go on a ready queue; each serviced
//!   slot pumps `poll_transmit` until its pacing gate closes.
//! * **A timer wheel** ([`wheel::TimerWheel`]) orders every machine's
//!   `poll_timeout()`. In [`TimeMode::Virtual`] the loop never sleeps:
//!   when nothing is ready it jumps the clock to the end of the next
//!   armed wheel bucket, so a whole bucket of pacing deadlines fires
//!   per jump and each paced sender batches ~granularity/pace
//!   fragments per wake-up. [`TimeMode::Real`] sleeps the same wait
//!   out on the OS clock instead.
//! * **Tenant budgets** — each transfer is registered under a tenant
//!   with an in-flight byte budget. Over-budget submissions are
//!   rejected or queued per [`AdmissionPolicy`]; finishing transfers
//!   release budget and admit queued work FIFO.
//!
//! Remote peers that are not themselves a daemon dial in with
//! [`transport::ServeTransport`], which wraps any [`Datagram`] channel
//! so an ordinary [`crate::api::Endpoint`] speaks the tagged dialect.

pub mod transport;
pub mod wheel;

pub use transport::{ServeTransport, TaggedChannel};
pub use wheel::TimerWheel;

use crate::coordinator::packet::{self, MAX_DATAGRAM, MAX_FRAGMENT_PAYLOAD, TAG_BYTES};
use crate::coordinator::receiver::{ReceiverConfig, ReceiverReport};
use crate::coordinator::sender::{SenderConfig, SenderReport};
use crate::engine::{DecodeJob, EncodeJob, ReceiverMachine, SenderMachine};
use crate::erasure::CodingPool;
use crate::transport::channel::Datagram;
use crate::util::err::Result;
use crate::{anyhow, bail};
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Real-mode poll cadence: how long the loop sleeps when idle with no
/// machine deadline nearer than this.
const REAL_POLL: Duration = Duration::from_micros(200);

/// How the daemon's clock advances.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimeMode {
    /// `Instant::now()`; idle waits sleep on the OS clock. Use with
    /// real sockets and live peers.
    Real,
    /// Virtual clock: idle waits *jump* to the next armed deadline.
    /// Deterministic and sleep-free — in-process benchmarks and tests.
    Virtual,
}

/// What happens to a submission that does not fit its tenant's budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Fail the registration call.
    Reject,
    /// Park it; admit FIFO as running transfers release budget.
    Queue,
}

/// Daemon construction knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub mode: TimeMode,
    /// Routing-table shards (keyed by `id % shards`).
    pub shards: usize,
    /// Timer-wheel bucket width — the effective timer resolution and
    /// the virtual-clock batching quantum.
    pub wheel_granularity: Duration,
    /// Timer-wheel bucket count (horizon = slots × granularity).
    pub wheel_slots: usize,
    /// Coding worker threads for off-loop parity/decode compute. Zero
    /// (the default) keeps all coding inline on the event loop. Only
    /// honoured in [`TimeMode::Real`]: virtual-clock runs stay inline
    /// and synchronous so traces are deterministic.
    pub coding_workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            mode: TimeMode::Real,
            shards: 16,
            wheel_granularity: Duration::from_millis(1),
            wheel_slots: 1024,
            coding_workers: 0,
        }
    }
}

/// One unit of off-loop coding compute: a sender's parity encode or a
/// receiver's final reconstruction, moved out of the machine whole.
enum CodingJob {
    Encode(EncodeJob),
    Decode(DecodeJob),
}

impl CodingJob {
    fn run(&mut self) {
        match self {
            CodingJob::Encode(j) => j.run(),
            CodingJob::Decode(j) => j.run(),
        }
    }
}

/// A coding job on its way back from the pool. `gen` fences slot reuse:
/// a completion whose generation no longer matches the slot's is from a
/// transfer that already died (failure deadline, reap) and is dropped.
struct Completion {
    idx: usize,
    gen: u64,
    job: CodingJob,
}

/// Either half of a transfer, as a machine.
enum MachineKind {
    Sender(Box<SenderMachine>),
    Receiver(Box<ReceiverMachine>),
}

impl MachineKind {
    fn handle_datagram(&mut self, buf: &[u8], now: Instant) {
        match self {
            MachineKind::Sender(m) => m.handle_datagram(buf, now),
            MachineKind::Receiver(m) => m.handle_datagram(buf, now),
        }
    }
    fn poll_transmit(&mut self, out: &mut Vec<u8>, now: Instant) -> bool {
        match self {
            MachineKind::Sender(m) => m.poll_transmit(out, now),
            MachineKind::Receiver(m) => m.poll_transmit(out, now),
        }
    }
    fn poll_timeout(&self) -> Option<Instant> {
        match self {
            MachineKind::Sender(m) => m.poll_timeout(),
            MachineKind::Receiver(m) => m.poll_timeout(),
        }
    }
    fn handle_timeout(&mut self, now: Instant) {
        match self {
            MachineKind::Sender(m) => m.handle_timeout(now),
            MachineKind::Receiver(m) => m.handle_timeout(now),
        }
    }
    fn is_finished(&self) -> bool {
        match self {
            MachineKind::Sender(m) => m.is_finished(),
            MachineKind::Receiver(m) => m.is_finished(),
        }
    }
    fn set_coding_offload(&mut self, on: bool) {
        match self {
            MachineKind::Sender(m) => m.set_coding_offload(on),
            MachineKind::Receiver(m) => m.set_coding_offload(on),
        }
    }
    fn take_coding_job(&mut self) -> Option<CodingJob> {
        match self {
            MachineKind::Sender(m) => m.take_encode_job().map(CodingJob::Encode),
            MachineKind::Receiver(m) => m.take_decode_job().map(CodingJob::Decode),
        }
    }
    fn complete_coding_job(&mut self, job: CodingJob) {
        match (self, job) {
            (MachineKind::Sender(m), CodingJob::Encode(j)) => m.complete_encode_job(j),
            (MachineKind::Receiver(m), CodingJob::Decode(j)) => m.complete_decode_job(j),
            // A kind mismatch can only follow a routing bug; the job is
            // dropped rather than poisoning an unrelated transfer.
            _ => {}
        }
    }
}

/// One live transfer.
struct Slot {
    tenant: usize,
    socket: usize,
    id: u32,
    /// Bytes charged against the tenant budget while in flight.
    cost: u64,
    /// Deadline currently armed in the wheel (lazy-cancel: stale wheel
    /// entries for this key fire spuriously and are ignored).
    armed: Option<Instant>,
    /// Admission generation (fences stale coding completions after this
    /// slot index is reused).
    gen: u64,
    /// Coding jobs this transfer sent through the pool.
    coding_jobs: u64,
    machine: MachineKind,
}

/// A not-yet-admitted transfer. Machines are built at *admission* so
/// deadline clocks (τ, max-duration) start when the transfer actually
/// starts, not while it sits queued.
enum PendingKind {
    Sender { cfg: SenderConfig, levels: Vec<Vec<u8>>, eps: Vec<f64> },
    Receiver { cfg: ReceiverConfig },
}

struct Pending {
    socket: usize,
    id: u32,
    cost: u64,
    kind: PendingKind,
}

struct Tenant {
    name: String,
    budget_bytes: u64,
    used: u64,
    policy: AdmissionPolicy,
    queued: VecDeque<Pending>,
}

/// Terminal record for one transfer, collected via
/// [`Daemon::take_finished`].
#[derive(Debug)]
pub struct FinishedTransfer {
    pub tenant: usize,
    pub socket: usize,
    pub id: u32,
    /// Coding jobs this transfer ran on the daemon's coding pool
    /// (zero when offload is disabled or inline coding was used).
    pub coding_jobs: u64,
    pub outcome: TransferOutcome,
}

#[derive(Debug)]
pub enum TransferOutcome {
    Sent(SenderReport),
    Received(ReceiverReport),
    Failed(String),
}

impl TransferOutcome {
    pub fn is_ok(&self) -> bool {
        !matches!(self, TransferOutcome::Failed(_))
    }
}

/// The multi-tenant transfer daemon. Single-threaded: construct,
/// register sockets/tenants/transfers, then [`Daemon::run_to_completion`].
pub struct Daemon {
    cfg: ServeConfig,
    origin: Instant,
    /// Virtual clock = `origin + now_off` (ignored in real mode).
    now_off: Duration,
    sockets: Vec<Box<dyn Datagram>>,
    slots: Vec<Option<Slot>>,
    free: Vec<usize>,
    /// `(socket, id) → slot`, sharded by `id % shards`.
    shards: Vec<HashMap<(usize, u32), usize>>,
    tenants: Vec<Tenant>,
    wheel: TimerWheel,
    ready: VecDeque<usize>,
    in_ready: Vec<bool>,
    finished: Vec<FinishedTransfer>,
    active: usize,
    queued_total: usize,
    dropped_untagged: u64,
    dropped_unknown: u64,
    /// Coding offload (None: all coding runs inline on the loop).
    coding: Option<CodingPool>,
    /// Jobs on their way back from the pool, drained each `poll_once`.
    completions: Arc<Mutex<Vec<Completion>>>,
    /// Admission generation counter (see [`Slot::gen`]).
    gen_counter: u64,
    coding_jobs_queued: u64,
    coding_jobs_completed: u64,
    /// Longest single `service` call observed — the event-loop stall
    /// bound that offload exists to keep small.
    max_service_stall: Duration,
    rbuf: Vec<u8>,
    out: Vec<u8>,
    tag_buf: Vec<u8>,
    fired: Vec<u64>,
}

impl Daemon {
    pub fn new(cfg: ServeConfig) -> Daemon {
        // lint: allow(sans-io-clock): the construction-time origin both
        // modes measure offsets from; Virtual never reads the clock again.
        let origin = Instant::now();
        let wheel = TimerWheel::new(origin, cfg.wheel_granularity, cfg.wheel_slots.max(1));
        let shards = vec![HashMap::new(); cfg.shards.max(1)];
        // Virtual mode keeps coding inline: a worker thread finishing a
        // job on the OS clock would race the virtual clock and break
        // trace determinism.
        let coding = match cfg.mode {
            TimeMode::Real if cfg.coding_workers > 0 => Some(CodingPool::new(cfg.coding_workers)),
            _ => None,
        };
        Daemon {
            cfg,
            origin,
            now_off: Duration::ZERO,
            sockets: Vec::new(),
            slots: Vec::new(),
            free: Vec::new(),
            shards,
            tenants: Vec::new(),
            wheel,
            ready: VecDeque::new(),
            in_ready: Vec::new(),
            finished: Vec::new(),
            active: 0,
            queued_total: 0,
            dropped_untagged: 0,
            dropped_unknown: 0,
            coding,
            completions: Arc::new(Mutex::new(Vec::new())),
            gen_counter: 0,
            coding_jobs_queued: 0,
            coding_jobs_completed: 0,
            max_service_stall: Duration::ZERO,
            rbuf: vec![0u8; MAX_DATAGRAM],
            out: Vec::with_capacity(MAX_DATAGRAM),
            tag_buf: Vec::with_capacity(MAX_DATAGRAM),
            fired: Vec::new(),
        }
    }

    /// Adopt a (nonblocking-capable) channel; returns its socket index.
    pub fn add_socket(&mut self, sock: Box<dyn Datagram>) -> usize {
        self.sockets.push(sock);
        self.sockets.len() - 1
    }

    /// Create a tenant with an in-flight byte budget; returns its index.
    pub fn add_tenant(&mut self, name: &str, budget_bytes: u64, policy: AdmissionPolicy) -> usize {
        self.tenants.push(Tenant {
            name: name.to_string(),
            budget_bytes,
            used: 0,
            policy,
            queued: VecDeque::new(),
        });
        self.tenants.len() - 1
    }

    /// Register the sending half of transfer `id` on `socket`. The
    /// tenant is charged the dataset size while the transfer runs.
    pub fn register_sender(
        &mut self,
        tenant: usize,
        socket: usize,
        id: u32,
        cfg: SenderConfig,
        levels: Vec<Vec<u8>>,
        eps: Vec<f64>,
    ) -> Result<()> {
        self.check_registration(tenant, socket, id)?;
        if cfg.net.s > MAX_FRAGMENT_PAYLOAD - TAG_BYTES {
            bail!(
                "serve: fragment size {} exceeds the tagged-datagram payload limit {}",
                cfg.net.s,
                MAX_FRAGMENT_PAYLOAD - TAG_BYTES
            );
        }
        let cost: u64 = levels.iter().map(|l| l.len() as u64).sum();
        let kind = PendingKind::Sender { cfg, levels, eps };
        self.submit(tenant, Pending { socket, id, cost, kind })
    }

    /// Register the receiving half of transfer `id` on `socket`.
    /// `cost` is the expected dataset size charged against the tenant
    /// budget (the receiver only learns the true size at manifest time).
    pub fn register_receiver(
        &mut self,
        tenant: usize,
        socket: usize,
        id: u32,
        cfg: ReceiverConfig,
        cost: u64,
    ) -> Result<()> {
        self.check_registration(tenant, socket, id)?;
        let kind = PendingKind::Receiver { cfg };
        self.submit(tenant, Pending { socket, id, cost, kind })
    }

    fn check_registration(&self, tenant: usize, socket: usize, id: u32) -> Result<()> {
        if tenant >= self.tenants.len() {
            bail!("serve: unknown tenant index {tenant}");
        }
        if socket >= self.sockets.len() {
            bail!("serve: unknown socket index {socket}");
        }
        if self.shards[self.shard_of(id)].contains_key(&(socket, id)) {
            bail!("serve: transfer id {id} already active on socket {socket}");
        }
        for t in &self.tenants {
            if t.queued.iter().any(|p| p.socket == socket && p.id == id) {
                bail!("serve: transfer id {id} already queued on socket {socket}");
            }
        }
        Ok(())
    }

    fn shard_of(&self, id: u32) -> usize {
        id as usize % self.shards.len()
    }

    fn submit(&mut self, tenant: usize, p: Pending) -> Result<()> {
        let t = &self.tenants[tenant];
        if t.used + p.cost <= t.budget_bytes {
            return self.admit(tenant, p);
        }
        match t.policy {
            AdmissionPolicy::Reject => bail!(
                "serve: tenant '{}' over budget ({} in flight + {} requested > {} bytes)",
                t.name,
                t.used,
                p.cost,
                t.budget_bytes
            ),
            AdmissionPolicy::Queue => {
                self.tenants[tenant].queued.push_back(p);
                self.queued_total += 1;
                Ok(())
            }
        }
    }

    /// Build the machine, charge the budget, activate the slot.
    fn admit(&mut self, tenant: usize, p: Pending) -> Result<()> {
        let now = self.now();
        let mut machine = match p.kind {
            PendingKind::Sender { cfg, levels, eps } => {
                MachineKind::Sender(Box::new(SenderMachine::new(&cfg, &levels, &eps, now)?))
            }
            PendingKind::Receiver { cfg } => {
                MachineKind::Receiver(Box::new(ReceiverMachine::new(&cfg, now)))
            }
        };
        if self.coding.is_some() {
            machine.set_coding_offload(true);
        }
        self.tenants[tenant].used += p.cost;
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                self.in_ready.push(false);
                self.slots.len() - 1
            }
        };
        self.shards[self.shard_of(p.id)].insert((p.socket, p.id), idx);
        self.gen_counter += 1;
        self.slots[idx] = Some(Slot {
            tenant,
            socket: p.socket,
            id: p.id,
            cost: p.cost,
            armed: None,
            gen: self.gen_counter,
            coding_jobs: 0,
            machine,
        });
        self.active += 1;
        self.push_ready(idx);
        Ok(())
    }

    fn push_ready(&mut self, idx: usize) {
        if !self.in_ready[idx] {
            self.in_ready[idx] = true;
            self.ready.push_back(idx);
        }
    }

    fn now(&self) -> Instant {
        match self.cfg.mode {
            // lint: allow(sans-io-clock): the single Real-mode clock read
            // every other `now()` caller funnels through.
            TimeMode::Real => Instant::now(),
            TimeMode::Virtual => self.origin + self.now_off,
        }
    }

    /// Run the event loop until every registered transfer (including
    /// queued ones) has finished or failed.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while self.active > 0 || self.queued_total > 0 {
            if self.poll_once() {
                continue;
            }
            if self.active == 0 {
                bail!(
                    "serve: {} queued transfers can never be admitted \
                     (their cost exceeds the whole tenant budget)",
                    self.queued_total
                );
            }
            self.idle_step()?;
        }
        Ok(())
    }

    /// One pass: drain sockets, service the ready queue. Returns
    /// whether anything moved.
    fn poll_once(&mut self) -> bool {
        let mut progressed = false;
        progressed |= self.drain_completions();
        let now = self.now();
        for si in 0..self.sockets.len() {
            while let Some(n) = self.sockets[si].try_recv_into(&mut self.rbuf) {
                progressed = true;
                match packet::peel_tag(&self.rbuf[..n]) {
                    Some((id, inner)) => {
                        let shard = id as usize % self.shards.len();
                        match self.shards[shard].get(&(si, id)).copied() {
                            Some(idx) => {
                                if let Some(slot) = self.slots[idx].as_mut() {
                                    slot.machine.handle_datagram(inner, now);
                                }
                                if !self.in_ready[idx] {
                                    self.in_ready[idx] = true;
                                    self.ready.push_back(idx);
                                }
                            }
                            None => self.dropped_unknown += 1,
                        }
                    }
                    None => self.dropped_untagged += 1,
                }
            }
        }
        while let Some(idx) = self.ready.pop_front() {
            self.in_ready[idx] = false;
            // lint: allow(sans-io-clock): stall telemetry only — measures
            // host service latency, never feeds protocol decisions.
            let t0 = Instant::now();
            progressed |= self.service(idx);
            self.max_service_stall = self.max_service_stall.max(t0.elapsed());
        }
        progressed
    }

    /// Hand completed coding jobs back to their machines. Generation
    /// mismatches (the slot died or was reused while the job ran) drop
    /// the job on the floor — the new occupant never sees it.
    fn drain_completions(&mut self) -> bool {
        let done: Vec<Completion> = {
            let mut q = self.completions.lock().unwrap();
            if q.is_empty() {
                return false;
            }
            std::mem::take(&mut *q)
        };
        let mut progressed = false;
        for c in done {
            self.coding_jobs_completed += 1;
            if let Some(slot) = self.slots.get_mut(c.idx).and_then(|s| s.as_mut()) {
                if slot.gen == c.gen {
                    slot.machine.complete_coding_job(c.job);
                    progressed = true;
                    self.push_ready(c.idx);
                }
            }
        }
        progressed
    }

    /// Pump one slot: transmit until its pacing gate closes, reap it if
    /// finished, re-arm its wheel deadline otherwise.
    fn service(&mut self, idx: usize) -> bool {
        let mut progressed = false;
        let now = self.now();
        loop {
            let slot = match self.slots[idx].as_mut() {
                Some(s) => s,
                None => return progressed,
            };
            if !slot.machine.poll_transmit(&mut self.out, now) {
                break;
            }
            let (id, si) = (slot.id, slot.socket);
            packet::encode_tagged(id, &self.out, &mut self.tag_buf);
            self.sockets[si].send(&self.tag_buf);
            progressed = true;
        }
        // Ship any parked coding job to the pool; the machine emits
        // nothing for that work until the completion comes back, so the
        // loop never blocks on a large group's parity or decode.
        if self.coding.is_some() {
            if let Some(slot) = self.slots[idx].as_mut() {
                if let Some(mut job) = slot.machine.take_coding_job() {
                    slot.coding_jobs += 1;
                    let gen = slot.gen;
                    self.coding_jobs_queued += 1;
                    let completions = Arc::clone(&self.completions);
                    self.coding.as_ref().expect("coding pool").spawn(move || {
                        job.run();
                        completions.lock().unwrap().push(Completion { idx, gen, job });
                    });
                    progressed = true;
                }
            }
        }
        let done = self.slots[idx].as_ref().map_or(false, |s| s.machine.is_finished());
        if done {
            self.reap(idx);
            return true;
        }
        if let Some(slot) = self.slots[idx].as_mut() {
            let want = slot.machine.poll_timeout();
            if want != slot.armed {
                if let Some(at) = want {
                    self.wheel.schedule(idx as u64, at);
                }
                slot.armed = want;
            }
        }
        progressed
    }

    /// Retire a finished slot: record the outcome, release the budget,
    /// admit queued transfers that now fit (FIFO).
    fn reap(&mut self, idx: usize) {
        let slot = match self.slots[idx].take() {
            Some(s) => s,
            None => return,
        };
        self.shards[self.shard_of(slot.id)].remove(&(slot.socket, slot.id));
        self.free.push(idx);
        self.active -= 1;
        let outcome = match slot.machine {
            MachineKind::Sender(m) => match (*m).into_report() {
                Ok(r) => TransferOutcome::Sent(r),
                Err(e) => TransferOutcome::Failed(e.to_string()),
            },
            MachineKind::Receiver(m) => match (*m).into_report() {
                Ok(r) => TransferOutcome::Received(r),
                Err(e) => TransferOutcome::Failed(e.to_string()),
            },
        };
        self.finished.push(FinishedTransfer {
            tenant: slot.tenant,
            socket: slot.socket,
            id: slot.id,
            coding_jobs: slot.coding_jobs,
            outcome,
        });
        let t = &mut self.tenants[slot.tenant];
        t.used = t.used.saturating_sub(slot.cost);
        let mut admit = Vec::new();
        let mut reserved = 0u64;
        while let Some(p) = t.queued.front() {
            if t.used + reserved + p.cost > t.budget_bytes {
                break;
            }
            reserved += p.cost;
            admit.push(t.queued.pop_front().unwrap());
        }
        self.queued_total -= admit.len();
        for p in admit {
            let (psock, pid) = (p.socket, p.id);
            if let Err(e) = self.admit(slot.tenant, p) {
                self.finished.push(FinishedTransfer {
                    tenant: slot.tenant,
                    socket: psock,
                    id: pid,
                    coding_jobs: 0,
                    outcome: TransferOutcome::Failed(e.to_string()),
                });
            }
        }
    }

    /// Nothing is ready: advance time to the next armed deadline. In
    /// virtual mode this jumps the clock to the end of the deadline's
    /// wheel bucket (draining a whole bucket per jump); in real mode it
    /// sleeps the wait out, capped at [`REAL_POLL`] so fresh socket
    /// arrivals are noticed promptly.
    fn idle_step(&mut self) -> Result<()> {
        match self.cfg.mode {
            TimeMode::Virtual => {
                let dl = self.wheel.next_deadline().ok_or_else(|| {
                    anyhow!(
                        "serve: stalled — {} transfers active but no timer armed",
                        self.active
                    )
                })?;
                let now = self.now().max(self.wheel.bucket_end(dl));
                self.now_off = now.saturating_duration_since(self.origin);
                self.fire_timers(now);
            }
            TimeMode::Real => {
                // lint: allow(sans-io-clock): Real-mode idle wait — this
                // arm IS the driver; Virtual mode never reaches it.
                let now = Instant::now();
                let wait = match self.wheel.next_deadline() {
                    Some(at) => at.saturating_duration_since(now).min(REAL_POLL),
                    None => REAL_POLL,
                };
                if !wait.is_zero() {
                    // lint: allow(sans-io-clock): Real-mode idle sleep.
                    std::thread::sleep(wait);
                }
                // lint: allow(sans-io-clock): Real-mode timer pump.
                self.fire_timers(Instant::now());
            }
        }
        Ok(())
    }

    fn fire_timers(&mut self, now: Instant) {
        let mut fired = std::mem::take(&mut self.fired);
        fired.clear();
        self.wheel.advance(now, &mut fired);
        for &key in &fired {
            let idx = key as usize;
            // Clear `armed` so `service` re-arms even an unchanged
            // deadline; a key whose slot died or re-armed since is a
            // stale lazy-cancel entry — the spurious `handle_timeout`
            // is harmless by the machine contract.
            let live = match self.slots.get_mut(idx).and_then(|s| s.as_mut()) {
                Some(slot) => {
                    slot.armed = None;
                    slot.machine.handle_timeout(now);
                    true
                }
                None => false,
            };
            if live {
                self.push_ready(idx);
            }
        }
        self.fired = fired;
    }

    /// Drain the finished-transfer records collected so far.
    pub fn take_finished(&mut self) -> Vec<FinishedTransfer> {
        std::mem::take(&mut self.finished)
    }

    /// Transfers currently holding a slot (admitted, not yet reaped).
    pub fn active_transfers(&self) -> usize {
        self.active
    }

    /// Transfers parked in tenant admission queues.
    pub fn queued_transfers(&self) -> usize {
        self.queued_total
    }

    /// Bytes of `tenant`'s budget currently held by in-flight transfers.
    pub fn tenant_used(&self, tenant: usize) -> u64 {
        self.tenants[tenant].used
    }

    /// Datagrams dropped for missing the transfer-tag envelope.
    pub fn dropped_untagged(&self) -> u64 {
        self.dropped_untagged
    }

    /// Tagged datagrams dropped for an unknown `(socket, id)`.
    pub fn dropped_unknown(&self) -> u64 {
        self.dropped_unknown
    }

    /// Coding-offload counters: `(jobs queued to the pool, completions
    /// handed back)`. Both zero when offload is disabled.
    pub fn coding_stats(&self) -> (u64, u64) {
        (self.coding_jobs_queued, self.coding_jobs_completed)
    }

    /// Longest single slot-service call observed so far — the bound on
    /// how long any one transfer stalled the shared event loop.
    pub fn max_service_stall(&self) -> Duration {
        self.max_service_stall
    }
}
