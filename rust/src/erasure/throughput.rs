//! Parity-generation throughput measurement (`r_ec`).
//!
//! Reproduces the paper's §5.2.2 measurement: with n = 32 fragments of
//! 4 096 B per FTG, liberasurecode's parity generation rate fell from
//! 319 531 frag/s (m = 1) to 41 561 frag/s (m = 16). The sender's
//! effective transmission rate is `r = min(r_ec, r_link)`, so this module
//! is what feeds the optimization models with a *measured* `r_ec`.

use super::rs::RsCode;
use crate::util::Pcg64;
use std::time::Instant;

/// One (m, rate) measurement point.
#[derive(Debug, Clone, Copy)]
pub struct EcRate {
    pub m: usize,
    /// Fragments (data + parity) produced per second.
    pub fragments_per_sec: f64,
    /// Payload bytes encoded per second (data only).
    pub data_bytes_per_sec: f64,
}

/// Measure parity generation rate for a single (n, m) configuration.
///
/// Encodes random FTGs for at least `min_duration` seconds and reports the
/// rate in fragments/s, matching the paper's metric (total fragments of
/// completed FTGs over elapsed time).
pub fn measure_ec_rate(
    n: usize,
    m: usize,
    fragment_size: usize,
    min_duration_secs: f64,
    seed: u64,
) -> EcRate {
    assert!(m < n, "need at least one data fragment");
    let k = n - m;
    let code = RsCode::new(k, m).expect("valid code");
    let mut rng = Pcg64::seeded(seed);
    // One FTG worth of random data, re-encoded repeatedly (matches how
    // liberasurecode benchmarks are usually run; data content does not
    // affect GF math throughput).
    let data: Vec<Vec<u8>> = (0..k)
        .map(|_| {
            let mut f = vec![0u8; fragment_size];
            rng.fill_bytes(&mut f);
            f
        })
        .collect();
    let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
    let mut parity: Vec<Vec<u8>> = vec![vec![0u8; fragment_size]; m];

    // Warm-up.
    code.encode_into(&refs, &mut parity).unwrap();

    let start = Instant::now();
    let mut groups = 0u64;
    while start.elapsed().as_secs_f64() < min_duration_secs {
        for _ in 0..8 {
            code.encode_into(&refs, &mut parity).unwrap();
            groups += 1;
        }
    }
    let secs = start.elapsed().as_secs_f64();
    let fragments = groups * n as u64;
    EcRate {
        m,
        fragments_per_sec: fragments as f64 / secs,
        data_bytes_per_sec: (groups * k as u64 * fragment_size as u64) as f64 / secs,
    }
}

/// Aggregate parity-generation rate with `workers` independent encoders
/// (the TransferPool's per-stream worker-pool encoding): each worker owns
/// its own [`RsCode`] and data, so the measurement captures true
/// multi-core scaling of `r_ec` rather than lock contention.
pub fn measure_parallel_ec_rate(
    n: usize,
    m: usize,
    fragment_size: usize,
    min_duration_secs: f64,
    seed: u64,
    workers: usize,
) -> EcRate {
    assert!(m < n && workers >= 1);
    let per_worker: Vec<EcRate> = std::thread::scope(|scope| {
        (0..workers)
            .map(|w| {
                scope.spawn(move || {
                    measure_ec_rate(n, m, fragment_size, min_duration_secs, seed ^ (w as u64 + 1))
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().expect("encode worker panicked"))
            .collect()
    });
    EcRate {
        m,
        fragments_per_sec: per_worker.iter().map(|r| r.fragments_per_sec).sum(),
        data_bytes_per_sec: per_worker.iter().map(|r| r.data_bytes_per_sec).sum(),
    }
}

/// Sweep m = 1..=max_m at fixed n, like the paper's table.
pub fn sweep_ec_rates(
    n: usize,
    max_m: usize,
    fragment_size: usize,
    min_duration_secs: f64,
) -> Vec<EcRate> {
    (1..=max_m)
        .map(|m| measure_ec_rate(n, m, fragment_size, min_duration_secs, 0xEC0DE + m as u64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_is_positive_and_m_monotonicity_roughly_holds() {
        // Short measurements; only sanity, the bench does the real sweep.
        let fast = measure_ec_rate(32, 1, 4096, 0.05, 1);
        let slow = measure_ec_rate(32, 16, 4096, 0.05, 2);
        assert!(fast.fragments_per_sec > 0.0);
        assert!(slow.fragments_per_sec > 0.0);
        // More parity per group => fewer fragments/s (with slack for noise).
        assert!(
            fast.fragments_per_sec > slow.fragments_per_sec * 1.2,
            "m=1: {:.0}, m=16: {:.0}",
            fast.fragments_per_sec,
            slow.fragments_per_sec
        );
    }

    #[test]
    fn sweep_returns_all_points() {
        let rates = sweep_ec_rates(8, 4, 1024, 0.01);
        assert_eq!(rates.len(), 4);
        assert!(rates.iter().enumerate().all(|(i, r)| r.m == i + 1));
    }

    #[test]
    fn parallel_rate_aggregates_workers() {
        // Not a strict scaling assertion (a single-core machine sums two
        // half-speed workers back to ~1×): the aggregate must simply be
        // positive, well-formed, and not collapse below a lone worker.
        let single = measure_ec_rate(16, 4, 2048, 0.05, 3);
        let multi = measure_parallel_ec_rate(16, 4, 2048, 0.05, 3, 2);
        assert_eq!(multi.m, 4);
        assert!(multi.fragments_per_sec > 0.0 && multi.data_bytes_per_sec > 0.0);
        assert!(
            multi.fragments_per_sec > 0.6 * single.fragments_per_sec,
            "2 workers {:.0} collapsed vs 1 worker {:.0}",
            multi.fragments_per_sec,
            single.fragments_per_sec
        );
    }
}
