//! Systematic Reed–Solomon erasure codes over GF(2^8).
//!
//! This is the fault-tolerance substrate of the paper (§2.1, §3.1): every
//! `k` data fragments produce `m` parity fragments, forming a
//! fault-tolerant group (FTG) of `n = k + m` fragments; **any** `k`
//! surviving fragments reconstruct the originals.
//!
//! Stands in for liberasurecode in the paper's prototype. The encoder
//! hot loop uses per-constant split-nibble tables ([`gf256::MulTable`])
//! and reuses precomputed tables across FTGs via [`RsCode`], since the
//! paper's sender encodes thousands of FTGs with the same (k, m).
//!
//! Encode (and dense decode) go through the fused multi-row kernels in
//! [`crate::erasure::kernel`]: each source fragment is streamed once per
//! band of up to four output rows instead of once per row, write-once
//! (no parity pre-zeroing). [`RsCode::encode_batch`] /
//! [`RsCode::reconstruct_batch`] fan whole-FTG jobs across a
//! [`CodingPool`] with byte-identical results for any worker count.

use super::gf256::MulTable;
use super::kernel::{self, KernelTier};
use super::matrix::{systematic_generator, Matrix};
use super::par::CodingPool;
use crate::coordinator::arena::FtgArena;

/// Errors from Reed–Solomon operations.
#[derive(Debug, PartialEq, Eq)]
pub enum RsError {
    BadParams { k: usize, m: usize },
    LengthMismatch { expected: usize, got: usize },
    NotEnough { have: usize, need: usize },
    BadIndex { idx: usize, n: usize },
}

impl std::fmt::Display for RsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RsError::BadParams { k, m } => {
                write!(f, "invalid code parameters: k={k}, m={m} (need k>=1, m>=0, k+m<=256)")
            }
            RsError::LengthMismatch { expected, got } => {
                write!(f, "fragment length mismatch: expected {expected}, got {got}")
            }
            RsError::NotEnough { have, need } => {
                write!(f, "not enough fragments to reconstruct: have {have}, need {need}")
            }
            RsError::BadIndex { idx, n } => {
                write!(f, "fragment index {idx} out of range for n={n}")
            }
        }
    }
}

impl std::error::Error for RsError {}

/// One cached inverted decode submatrix for a survivor-index pattern.
struct DecodeEntry {
    /// Survivor fragment indices (the first k shards' indices, in order).
    rows: Vec<u8>,
    /// Inverted k×k submatrix of the generator for those rows.
    inv: Matrix,
    /// `inv` as precomputed split-nibble tables: `tables[j][i]` applies
    /// coefficient `inv[(j, i)]` (built for every cell, zeros included,
    /// so the fused kernel can consume the matrix directly).
    tables: Vec<Vec<MulTable>>,
    /// Nonzero cells of `inv` — picks between the fused kernel (dense
    /// inverses) and the skip-zero row loop (near-identity inverses).
    nnz: usize,
    /// LRU stamp (last lookup that touched this entry).
    stamp: u64,
}

/// Small LRU of inverted decode submatrices keyed by survivor pattern.
///
/// A steady loss regime repeats the same few patterns across thousands
/// of FTGs; without the cache every [`RsCode::reconstruct`] re-inverts
/// the submatrix and rebuilds a [`MulTable`] per nonzero cell.
struct DecodeCache {
    entries: Vec<DecodeEntry>,
    clock: u64,
    hits: u64,
    misses: u64,
}

const DECODE_CACHE_CAP: usize = 32;

impl DecodeCache {
    fn new() -> DecodeCache {
        DecodeCache { entries: Vec::new(), clock: 0, hits: 0, misses: 0 }
    }

    /// Index of the entry for `chosen`'s survivor pattern, building (and
    /// possibly evicting the LRU entry) on a miss. Allocation-free on a
    /// hit: the comparison walks the shard indices directly.
    fn lookup_or_build(
        &mut self,
        generator: &Matrix,
        k: usize,
        chosen: &[(usize, &[u8])],
    ) -> usize {
        self.clock += 1;
        let clock = self.clock;
        if let Some(i) = self.entries.iter().position(|e| {
            e.rows.len() == k
                && e.rows.iter().zip(chosen).all(|(&r, &(idx, _))| r as usize == idx)
        }) {
            self.hits += 1;
            self.entries[i].stamp = clock;
            return i;
        }
        self.misses += 1;
        let rows: Vec<usize> = chosen.iter().map(|&(idx, _)| idx).collect();
        let sub = generator.select_rows(&rows);
        let inv = sub
            .inverse()
            .expect("MDS property: any k rows of the generator are invertible");
        let tables: Vec<Vec<MulTable>> = (0..k)
            .map(|j| (0..k).map(|i| MulTable::new(inv[(j, i)])).collect())
            .collect();
        let nnz = (0..k)
            .map(|j| (0..k).filter(|&i| inv[(j, i)] != 0).count())
            .sum();
        let entry = DecodeEntry {
            rows: rows.iter().map(|&r| r as u8).collect(),
            inv,
            tables,
            nnz,
            stamp: clock,
        };
        if self.entries.len() < DECODE_CACHE_CAP {
            self.entries.push(entry);
            self.entries.len() - 1
        } else {
            let evict = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i)
                .unwrap();
            self.entries[evict] = entry;
            evict
        }
    }
}

/// A (k, m) systematic Reed–Solomon code with cached encode tables.
pub struct RsCode {
    pub k: usize,
    pub m: usize,
    /// n×k systematic generator (top k rows = identity).
    generator: Matrix,
    /// Parity rows as precomputed split-nibble tables: `parity_tables[p][j]`
    /// multiplies data fragment `j` into parity fragment `p`.
    parity_tables: Vec<Vec<MulTable>>,
    /// LRU of inverted decode submatrices (see [`DecodeCache`]).
    decode_cache: DecodeCache,
}

impl RsCode {
    /// Build a code with `k` data and `m` parity fragments per group.
    pub fn new(k: usize, m: usize) -> Result<RsCode, RsError> {
        if k < 1 || k + m > 256 {
            return Err(RsError::BadParams { k, m });
        }
        let n = k + m;
        let generator = systematic_generator(n, k);
        let parity_tables = (0..m)
            .map(|p| {
                (0..k)
                    .map(|j| MulTable::new(generator[(k + p, j)]))
                    .collect()
            })
            .collect();
        Ok(RsCode { k, m, generator, parity_tables, decode_cache: DecodeCache::new() })
    }

    /// Total fragments per group.
    #[inline]
    pub fn n(&self) -> usize {
        self.k + self.m
    }

    /// Encode: given `k` equal-length data fragments, produce `m` parity
    /// fragments.
    pub fn encode(&self, data: &[&[u8]]) -> Result<Vec<Vec<u8>>, RsError> {
        if data.len() != self.k {
            return Err(RsError::NotEnough { have: data.len(), need: self.k });
        }
        let len = data[0].len();
        for d in data {
            if d.len() != len {
                return Err(RsError::LengthMismatch { expected: len, got: d.len() });
            }
        }
        let mut parity = vec![vec![0u8; len]; self.m];
        kernel::mul_matrix_into_vecs_tier(&self.parity_tables, data, &mut parity, kernel::active());
        Ok(parity)
    }

    /// Encode into caller-provided parity buffers (no allocation).
    ///
    /// Used by the throughput benchmark and the sender hot path. The
    /// fused kernel is write-once: parity buffers are resized for
    /// geometry but never pre-zeroed.
    pub fn encode_into(&self, data: &[&[u8]], parity: &mut [Vec<u8>]) -> Result<(), RsError> {
        if data.len() != self.k {
            return Err(RsError::NotEnough { have: data.len(), need: self.k });
        }
        let len = data[0].len();
        for d in data {
            if d.len() != len {
                return Err(RsError::LengthMismatch { expected: len, got: d.len() });
            }
        }
        assert_eq!(parity.len(), self.m);
        for out in parity.iter_mut() {
            out.resize(len, 0);
        }
        kernel::mul_matrix_into_vecs_tier(&self.parity_tables, data, parity, kernel::active());
        Ok(())
    }

    /// Encode within a strided group buffer (the
    /// [`crate::coordinator::arena::FtgArena`] layout): `buf` holds the
    /// `k` data fragments followed by the `m` parity slots, each
    /// `stride` bytes. Parity is computed in place via the fused
    /// multi-row kernel — the sender's zero-allocation path.
    pub fn encode_strided(&self, buf: &mut [u8], stride: usize) -> Result<(), RsError> {
        self.encode_strided_tier(buf, stride, kernel::active())
    }

    /// [`RsCode::encode_strided`] on a forced kernel tier (clamped to
    /// CPU support) — the tier-sweeping entry point for tests/benches.
    pub fn encode_strided_tier(
        &self,
        buf: &mut [u8],
        stride: usize,
        tier: KernelTier,
    ) -> Result<(), RsError> {
        if stride == 0 || buf.len() != self.n() * stride {
            return Err(RsError::LengthMismatch {
                expected: self.n() * stride,
                got: buf.len(),
            });
        }
        kernel::mul_matrix_strided_tier(&self.parity_tables, buf, self.k, stride, tier);
        Ok(())
    }

    /// Row-at-a-time strided encode on a forced tier: the reference
    /// implementation the fused kernel is validated against (property
    /// tests) and benchmarked against (the fused-speedup gate in
    /// `benches/rs_throughput.rs`). Write-once like the fused path —
    /// the first source term overwrites, the rest accumulate.
    pub fn encode_strided_rowwise(
        &self,
        buf: &mut [u8],
        stride: usize,
        tier: KernelTier,
    ) -> Result<(), RsError> {
        if stride == 0 || buf.len() != self.n() * stride {
            return Err(RsError::LengthMismatch {
                expected: self.n() * stride,
                got: buf.len(),
            });
        }
        let (data, parity) = buf.split_at_mut(self.k * stride);
        for p in 0..self.m {
            let out = &mut parity[p * stride..(p + 1) * stride];
            for j in 0..self.k {
                let x = &data[j * stride..(j + 1) * stride];
                if j == 0 {
                    self.parity_tables[p][j].mul_slice_tier(x, out, tier);
                } else {
                    self.parity_tables[p][j].mul_slice_add_tier(x, out, tier);
                }
            }
        }
        Ok(())
    }

    /// Reconstruct the `k` data fragments into one contiguous strided
    /// output buffer (`out.len()` must equal `k · fragment_len`),
    /// reusing a cached inverted decode matrix when the survivor-index
    /// pattern repeats (`&mut self`: the LRU cache lives in the code).
    ///
    /// Byte-for-byte equivalent to [`RsCode::reconstruct`] (asserted by
    /// `rust/tests/erasure_props.rs`), minus its per-call allocations.
    pub fn reconstruct_into(
        &mut self,
        shards: &[(usize, &[u8])],
        out: &mut [u8],
    ) -> Result<(), RsError> {
        let n = self.n();
        reconstruct_into_cached(&self.generator, self.k, n, &mut self.decode_cache, shards, out)
    }

    /// (hits, misses) of the decode-matrix cache.
    pub fn decode_cache_stats(&self) -> (u64, u64) {
        (self.decode_cache.hits, self.decode_cache.misses)
    }

    /// Reconstruct the original `k` data fragments from any `k` surviving
    /// fragments.
    ///
    /// `shards` maps fragment index (0..n; 0..k data, k..n parity) to the
    /// fragment bytes. Returns the `k` data fragments in order.
    pub fn reconstruct(
        &self,
        shards: &[(usize, &[u8])],
    ) -> Result<Vec<Vec<u8>>, RsError> {
        if shards.len() < self.k {
            return Err(RsError::NotEnough { have: shards.len(), need: self.k });
        }
        let len = shards[0].1.len();
        for &(idx, frag) in shards {
            if idx >= self.n() {
                return Err(RsError::BadIndex { idx, n: self.n() });
            }
            if frag.len() != len {
                return Err(RsError::LengthMismatch { expected: len, got: frag.len() });
            }
        }
        // Fast path: all data fragments present.
        let mut have_data = vec![None; self.k];
        for &(idx, frag) in shards {
            if idx < self.k {
                have_data[idx] = Some(frag);
            }
        }
        if have_data.iter().all(|f| f.is_some()) {
            return Ok(have_data.into_iter().map(|f| f.unwrap().to_vec()).collect());
        }
        // General path: invert the k×k submatrix of the generator picked
        // by the first k surviving fragment indices.
        let chosen: Vec<&(usize, &[u8])> = shards.iter().take(self.k).collect();
        let rows: Vec<usize> = chosen.iter().map(|(i, _)| *i).collect();
        let sub = self.generator.select_rows(&rows);
        let inv = sub
            .inverse()
            .expect("MDS property: any k rows of the generator are invertible");
        // data[j] = sum_i inv[j][i] * chosen[i]
        let mut out = vec![vec![0u8; len]; self.k];
        for (j, out_frag) in out.iter_mut().enumerate() {
            for (i, &&(_, frag)) in chosen.iter().enumerate() {
                let c = inv[(j, i)];
                if c != 0 {
                    MulTable::new(c).mul_slice_add(frag, out_frag);
                }
            }
        }
        Ok(out)
    }

    /// Convenience: encode a contiguous buffer into an FTG.
    ///
    /// Pads the tail with zeros to a multiple of `fragment_size` and
    /// returns all n fragments (data first, then parity).
    pub fn encode_buffer(
        &self,
        buf: &[u8],
        fragment_size: usize,
    ) -> Result<Vec<Vec<u8>>, RsError> {
        assert!(fragment_size > 0);
        let mut frags: Vec<Vec<u8>> = Vec::with_capacity(self.n());
        for i in 0..self.k {
            let lo = (i * fragment_size).min(buf.len());
            let hi = ((i + 1) * fragment_size).min(buf.len());
            let mut f = buf[lo..hi].to_vec();
            f.resize(fragment_size, 0);
            frags.push(f);
        }
        let refs: Vec<&[u8]> = frags.iter().map(|f| f.as_slice()).collect();
        let parity = self.encode(&refs)?;
        frags.extend(parity);
        Ok(frags)
    }

    /// Encode the parity of a batch of FTG arenas across a worker pool.
    ///
    /// Byte-identical to calling [`FtgArena::encode_parity`] on each
    /// arena in order, for any pool size — the jobs are pure compute on
    /// disjoint arenas (see the determinism contract in
    /// [`crate::erasure::par`]). Geometry is validated up front so the
    /// parallel phase cannot fail.
    pub fn encode_batch(&self, pool: &CodingPool, arenas: &mut [FtgArena]) -> Result<(), RsError> {
        for arena in arenas.iter() {
            let stride = arena.stride();
            if stride == 0 || arena.as_slice().len() != self.n() * stride {
                return Err(RsError::LengthMismatch {
                    expected: self.n() * stride,
                    got: arena.as_slice().len(),
                });
            }
        }
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = arenas
            .iter_mut()
            .map(|arena| {
                Box::new(move || {
                    arena.encode_parity(self).expect("geometry validated above");
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_batch(jobs);
        Ok(())
    }

    /// Reconstruct a batch of groups across a worker pool: each item
    /// pairs an arena (its present fragments are the survivors) with a
    /// `k·stride` output buffer. Returns one result per item, in order.
    ///
    /// Byte-identical to sequential [`RsCode::reconstruct_into`] for any
    /// worker count: chunks use thread-local decode caches, and cache
    /// state never changes decoded bytes (only inversion reuse). The
    /// shared `&self` cache is deliberately untouched.
    pub fn reconstruct_batch(
        &self,
        pool: &CodingPool,
        items: &mut [(&FtgArena, &mut [u8])],
    ) -> Vec<Result<(), RsError>> {
        if items.is_empty() {
            return Vec::new();
        }
        let mut results: Vec<Result<(), RsError>> = Vec::with_capacity(items.len());
        results.resize_with(items.len(), || Ok(()));
        let chunk = items.len().div_ceil(pool.workers().max(1) + 1).max(1);
        let generator = &self.generator;
        let (k, n) = (self.k, self.n());
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = items
            .chunks_mut(chunk)
            .zip(results.chunks_mut(chunk))
            .map(|(item_chunk, result_chunk)| {
                Box::new(move || {
                    let mut cache = DecodeCache::new();
                    for (item, result) in item_chunk.iter_mut().zip(result_chunk.iter_mut()) {
                        let shards: Vec<(usize, &[u8])> = item.0.iter_present().collect();
                        *result =
                            reconstruct_into_cached(generator, k, n, &mut cache, &shards, item.1);
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_batch(jobs);
        results
    }
}

/// Core of [`RsCode::reconstruct_into`] with an explicit decode cache,
/// shared between the `&mut self` entry point and the batch path (which
/// runs chunks with thread-local caches — decoded bytes never depend on
/// cache state).
fn reconstruct_into_cached(
    generator: &Matrix,
    k: usize,
    n: usize,
    cache: &mut DecodeCache,
    shards: &[(usize, &[u8])],
    out: &mut [u8],
) -> Result<(), RsError> {
    if shards.len() < k {
        return Err(RsError::NotEnough { have: shards.len(), need: k });
    }
    let len = shards[0].1.len();
    for &(idx, frag) in shards {
        if idx >= n {
            return Err(RsError::BadIndex { idx, n });
        }
        if frag.len() != len {
            return Err(RsError::LengthMismatch { expected: len, got: frag.len() });
        }
    }
    if out.len() != k * len {
        return Err(RsError::LengthMismatch { expected: k * len, got: out.len() });
    }
    // Fast path: all data fragments present — pure copies.
    let mut seen = [0u64; 4];
    let mut have_data = 0usize;
    for &(idx, _) in shards {
        if idx < k {
            let (w, b) = (idx / 64, 1u64 << (idx % 64));
            if seen[w] & b == 0 {
                seen[w] |= b;
                have_data += 1;
            }
        }
    }
    if have_data == k {
        for &(idx, frag) in shards {
            if idx < k {
                out[idx * len..(idx + 1) * len].copy_from_slice(frag);
            }
        }
        return Ok(());
    }
    // General path: cached inverse of the k×k submatrix picked by the
    // first k surviving fragment indices.
    let chosen = &shards[..k];
    let e = cache.lookup_or_build(generator, k, chosen);
    let entry = &cache.entries[e];
    if entry.nnz * 2 >= k * k {
        // Dense inverse (deep-loss pattern): fused multi-row kernel over
        // the full matrix. Zero cells multiply to zero, so this is
        // byte-identical to the skip-zero accumulation below.
        let mut srcs: [&[u8]; 256] = [&[]; 256];
        for (i, &(_, frag)) in chosen.iter().enumerate() {
            srcs[i] = frag;
        }
        kernel::mul_matrix_into_strided_tier(&entry.tables, &srcs[..k], out, len, kernel::active());
        return Ok(());
    }
    // Near-identity inverse (few losses): most cells are zero — skip
    // them row by row. The first nonzero term overwrites (write-once
    // `mul_slice`), the rest accumulate — `out` needs no pre-zeroing
    // and is touched exactly once per term.
    for j in 0..k {
        let out_frag = &mut out[j * len..(j + 1) * len];
        let mut written = false;
        for (i, &(_, frag)) in chosen.iter().enumerate() {
            if entry.inv[(j, i)] != 0 {
                if written {
                    entry.tables[j][i].mul_slice_add(frag, out_frag);
                } else {
                    entry.tables[j][i].mul_slice(frag, out_frag);
                    written = true;
                }
            }
        }
        if !written {
            // Unreachable for an MDS inverse (no zero rows), but stay
            // well-defined on arbitrary matrices.
            out_frag.fill(0);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_fragments(rng: &mut Pcg64, k: usize, len: usize) -> Vec<Vec<u8>> {
        (0..k)
            .map(|_| {
                let mut f = vec![0u8; len];
                rng.fill_bytes(&mut f);
                f
            })
            .collect()
    }

    #[test]
    fn roundtrip_no_loss() {
        let mut rng = Pcg64::seeded(1);
        let code = RsCode::new(4, 2).unwrap();
        let data = random_fragments(&mut rng, 4, 64);
        let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
        let parity = code.encode(&refs).unwrap();
        assert_eq!(parity.len(), 2);
        let shards: Vec<(usize, &[u8])> =
            data.iter().enumerate().map(|(i, f)| (i, f.as_slice())).collect();
        let got = code.reconstruct(&shards).unwrap();
        assert_eq!(got, data);
        let _ = parity;
    }

    #[test]
    fn recovers_from_any_m_losses() {
        let mut rng = Pcg64::seeded(2);
        for (k, m) in [(4, 2), (7, 1), (16, 16), (28, 4), (31, 1)] {
            let code = RsCode::new(k, m).unwrap();
            let data = random_fragments(&mut rng, k, 128);
            let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
            let parity = code.encode(&refs).unwrap();
            let all: Vec<Vec<u8>> = data.iter().chain(parity.iter()).cloned().collect();
            for _trial in 0..20 {
                // Drop exactly m random fragments.
                let lost = rng.sample_indices(k + m, m);
                let shards: Vec<(usize, &[u8])> = (0..k + m)
                    .filter(|i| !lost.contains(i))
                    .map(|i| (i, all[i].as_slice()))
                    .collect();
                let got = code.reconstruct(&shards).unwrap();
                assert_eq!(got, data, "k={k} m={m} lost={lost:?}");
            }
        }
    }

    #[test]
    fn fails_with_fewer_than_k() {
        let code = RsCode::new(4, 2).unwrap();
        let mut rng = Pcg64::seeded(3);
        let data = random_fragments(&mut rng, 4, 32);
        let shards: Vec<(usize, &[u8])> = data
            .iter()
            .take(3)
            .enumerate()
            .map(|(i, f)| (i, f.as_slice()))
            .collect();
        assert_eq!(
            code.reconstruct(&shards),
            Err(RsError::NotEnough { have: 3, need: 4 })
        );
    }

    #[test]
    fn zero_parity_code_is_passthrough() {
        // m = 0 is legal in the paper's sweeps (no fault tolerance).
        let code = RsCode::new(5, 0).unwrap();
        let mut rng = Pcg64::seeded(4);
        let data = random_fragments(&mut rng, 5, 16);
        let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
        assert!(code.encode(&refs).unwrap().is_empty());
    }

    #[test]
    fn bad_params_rejected() {
        assert!(RsCode::new(0, 3).is_err());
        assert!(RsCode::new(200, 100).is_err());
        assert!(RsCode::new(1, 0).is_ok());
        assert!(RsCode::new(128, 128).is_ok());
    }

    #[test]
    fn length_mismatch_rejected() {
        let code = RsCode::new(2, 1).unwrap();
        let a = vec![0u8; 16];
        let b = vec![0u8; 17];
        let refs: Vec<&[u8]> = vec![&a, &b];
        assert!(matches!(
            code.encode(&refs),
            Err(RsError::LengthMismatch { .. })
        ));
    }

    #[test]
    fn encode_buffer_pads_and_splits() {
        let code = RsCode::new(4, 4).unwrap();
        let buf: Vec<u8> = (0..1000u32).map(|i| i as u8).collect();
        let frags = code.encode_buffer(&buf, 4096).unwrap();
        assert_eq!(frags.len(), 8);
        assert!(frags.iter().all(|f| f.len() == 4096));
        assert_eq!(&frags[0][..1000], &buf[..]);
        assert!(frags[0][1000..].iter().all(|&b| b == 0));
        // Recover data from parity only + 0 data? Need any 4 of 8:
        let shards: Vec<(usize, &[u8])> =
            (4..8).map(|i| (i, frags[i].as_slice())).collect();
        let got = code.reconstruct(&shards).unwrap();
        assert_eq!(got[0], frags[0]);
    }

    #[test]
    fn encode_into_matches_encode() {
        let mut rng = Pcg64::seeded(5);
        let code = RsCode::new(6, 3).unwrap();
        let data = random_fragments(&mut rng, 6, 256);
        let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
        let fresh = code.encode(&refs).unwrap();
        let mut reused = vec![vec![0xAAu8; 7]; 3]; // wrong size, pre-dirtied
        code.encode_into(&refs, &mut reused).unwrap();
        assert_eq!(fresh, reused);
    }

    #[test]
    fn encode_strided_matches_encode() {
        let mut rng = Pcg64::seeded(6);
        for (k, m, s) in [(4usize, 2usize, 64usize), (8, 3, 100), (1, 0, 16), (5, 5, 33)] {
            let code = RsCode::new(k, m).unwrap();
            let data = random_fragments(&mut rng, k, s);
            let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
            let parity = code.encode(&refs).unwrap();
            let mut buf = vec![0u8; (k + m) * s];
            for (i, d) in data.iter().enumerate() {
                buf[i * s..(i + 1) * s].copy_from_slice(d);
            }
            // Pre-dirty the parity region: encode_strided must overwrite.
            buf[k * s..].fill(0xEE);
            code.encode_strided(&mut buf, s).unwrap();
            for (p, want) in parity.iter().enumerate() {
                assert_eq!(&buf[(k + p) * s..(k + p + 1) * s], &want[..], "k={k} m={m} p={p}");
            }
            // Data region untouched.
            for (i, d) in data.iter().enumerate() {
                assert_eq!(&buf[i * s..(i + 1) * s], &d[..]);
            }
        }
    }

    #[test]
    fn encode_strided_rejects_bad_geometry() {
        let code = RsCode::new(4, 2).unwrap();
        let mut buf = vec![0u8; 5 * 16];
        assert!(matches!(
            code.encode_strided(&mut buf, 16),
            Err(RsError::LengthMismatch { .. })
        ));
        assert!(matches!(
            code.encode_strided(&mut [], 0),
            Err(RsError::LengthMismatch { .. })
        ));
    }

    #[test]
    fn reconstruct_into_matches_reconstruct_and_caches() {
        let mut rng = Pcg64::seeded(7);
        let (k, m, s) = (6usize, 3usize, 128usize);
        let mut code = RsCode::new(k, m).unwrap();
        let data = random_fragments(&mut rng, k, s);
        let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
        let parity = code.encode(&refs).unwrap();
        let all: Vec<Vec<u8>> = data.iter().chain(parity.iter()).cloned().collect();
        // Same loss pattern thrice: one miss, then hits, identical bytes.
        let lost = [1usize, 4];
        let shards: Vec<(usize, &[u8])> = (0..k + m)
            .filter(|i| !lost.contains(i))
            .map(|i| (i, all[i].as_slice()))
            .collect();
        let want = code.reconstruct(&shards).unwrap();
        let flat_want: Vec<u8> = want.concat();
        let mut out = vec![0xAAu8; k * s];
        for round in 0..3 {
            out.fill(0xAA);
            code.reconstruct_into(&shards, &mut out).unwrap();
            assert_eq!(out, flat_want, "round {round}");
        }
        let (hits, misses) = code.decode_cache_stats();
        assert_eq!(misses, 1, "one inversion for a repeated pattern");
        assert_eq!(hits, 2);
        // All-data fast path never touches the cache.
        let shards_all: Vec<(usize, &[u8])> =
            (0..k).map(|i| (i, all[i].as_slice())).collect();
        out.fill(0);
        code.reconstruct_into(&shards_all, &mut out).unwrap();
        assert_eq!(out, flat_want);
        assert_eq!(code.decode_cache_stats(), (hits, misses));
    }

    #[test]
    fn reconstruct_into_validates_output_length() {
        let mut code = RsCode::new(2, 1).unwrap();
        let a = [1u8; 8];
        let b = [2u8; 8];
        let shards: Vec<(usize, &[u8])> = vec![(0, &a[..]), (1, &b[..])];
        let mut short = vec![0u8; 15];
        assert!(matches!(
            code.reconstruct_into(&shards, &mut short),
            Err(RsError::LengthMismatch { .. })
        ));
    }

    #[test]
    fn prop_any_k_subset_reconstructs() {
        // Property-style test over random (k, m, subset) draws.
        use crate::util::prop::{check, no_shrink, PropConfig};
        check(
            &PropConfig { cases: 60, ..Default::default() },
            |rng| {
                let k = rng.range(1, 12);
                let m = rng.range(0, 8);
                let seed = rng.next_u64();
                (k, m, seed)
            },
            no_shrink,
            |&(k, m, seed)| {
                let mut rng = Pcg64::seeded(seed);
                let code = RsCode::new(k, m).map_err(|e| e.to_string())?;
                let data = random_fragments(&mut rng, k, 32);
                let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
                let parity = code.encode(&refs).map_err(|e| e.to_string())?;
                let all: Vec<Vec<u8>> = data.iter().chain(parity.iter()).cloned().collect();
                let mut idx: Vec<usize> = (0..k + m).collect();
                rng.shuffle(&mut idx);
                let shards: Vec<(usize, &[u8])> =
                    idx[..k].iter().map(|&i| (i, all[i].as_slice())).collect();
                let got = code.reconstruct(&shards).map_err(|e| e.to_string())?;
                if got == data {
                    Ok(())
                } else {
                    Err(format!("mismatch k={k} m={m} subset={:?}", &idx[..k]))
                }
            },
        );
    }
}
