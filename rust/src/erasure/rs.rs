//! Systematic Reed–Solomon erasure codes over GF(2^8).
//!
//! This is the fault-tolerance substrate of the paper (§2.1, §3.1): every
//! `k` data fragments produce `m` parity fragments, forming a
//! fault-tolerant group (FTG) of `n = k + m` fragments; **any** `k`
//! surviving fragments reconstruct the originals.
//!
//! Stands in for liberasurecode in the paper's prototype. The encoder
//! hot loop uses per-constant split-nibble tables ([`gf256::MulTable`])
//! and reuses precomputed tables across FTGs via [`RsCode`], since the
//! paper's sender encodes thousands of FTGs with the same (k, m).

use super::gf256::MulTable;
use super::matrix::{systematic_generator, Matrix};

/// Errors from Reed–Solomon operations.
#[derive(Debug, PartialEq, Eq)]
pub enum RsError {
    BadParams { k: usize, m: usize },
    LengthMismatch { expected: usize, got: usize },
    NotEnough { have: usize, need: usize },
    BadIndex { idx: usize, n: usize },
}

impl std::fmt::Display for RsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RsError::BadParams { k, m } => {
                write!(f, "invalid code parameters: k={k}, m={m} (need k>=1, m>=0, k+m<=256)")
            }
            RsError::LengthMismatch { expected, got } => {
                write!(f, "fragment length mismatch: expected {expected}, got {got}")
            }
            RsError::NotEnough { have, need } => {
                write!(f, "not enough fragments to reconstruct: have {have}, need {need}")
            }
            RsError::BadIndex { idx, n } => {
                write!(f, "fragment index {idx} out of range for n={n}")
            }
        }
    }
}

impl std::error::Error for RsError {}

/// A (k, m) systematic Reed–Solomon code with cached encode tables.
pub struct RsCode {
    pub k: usize,
    pub m: usize,
    /// n×k systematic generator (top k rows = identity).
    generator: Matrix,
    /// Parity rows as precomputed split-nibble tables: `parity_tables[p][j]`
    /// multiplies data fragment `j` into parity fragment `p`.
    parity_tables: Vec<Vec<MulTable>>,
}

impl RsCode {
    /// Build a code with `k` data and `m` parity fragments per group.
    pub fn new(k: usize, m: usize) -> Result<RsCode, RsError> {
        if k < 1 || k + m > 256 {
            return Err(RsError::BadParams { k, m });
        }
        let n = k + m;
        let generator = systematic_generator(n, k);
        let parity_tables = (0..m)
            .map(|p| {
                (0..k)
                    .map(|j| MulTable::new(generator[(k + p, j)]))
                    .collect()
            })
            .collect();
        Ok(RsCode { k, m, generator, parity_tables })
    }

    /// Total fragments per group.
    #[inline]
    pub fn n(&self) -> usize {
        self.k + self.m
    }

    /// Encode: given `k` equal-length data fragments, produce `m` parity
    /// fragments.
    pub fn encode(&self, data: &[&[u8]]) -> Result<Vec<Vec<u8>>, RsError> {
        if data.len() != self.k {
            return Err(RsError::NotEnough { have: data.len(), need: self.k });
        }
        let len = data[0].len();
        for d in data {
            if d.len() != len {
                return Err(RsError::LengthMismatch { expected: len, got: d.len() });
            }
        }
        let mut parity = vec![vec![0u8; len]; self.m];
        for (p, out) in parity.iter_mut().enumerate() {
            for (j, frag) in data.iter().enumerate() {
                self.parity_tables[p][j].mul_slice_add(frag, out);
            }
        }
        Ok(parity)
    }

    /// Encode into caller-provided parity buffers (no allocation).
    ///
    /// Used by the throughput benchmark and the sender hot path.
    pub fn encode_into(&self, data: &[&[u8]], parity: &mut [Vec<u8>]) -> Result<(), RsError> {
        if data.len() != self.k {
            return Err(RsError::NotEnough { have: data.len(), need: self.k });
        }
        let len = data[0].len();
        assert_eq!(parity.len(), self.m);
        for (p, out) in parity.iter_mut().enumerate() {
            out.resize(len, 0);
            out.fill(0);
            for (j, frag) in data.iter().enumerate() {
                self.parity_tables[p][j].mul_slice_add(frag, out);
            }
        }
        Ok(())
    }

    /// Reconstruct the original `k` data fragments from any `k` surviving
    /// fragments.
    ///
    /// `shards` maps fragment index (0..n; 0..k data, k..n parity) to the
    /// fragment bytes. Returns the `k` data fragments in order.
    pub fn reconstruct(
        &self,
        shards: &[(usize, &[u8])],
    ) -> Result<Vec<Vec<u8>>, RsError> {
        if shards.len() < self.k {
            return Err(RsError::NotEnough { have: shards.len(), need: self.k });
        }
        let len = shards[0].1.len();
        for &(idx, frag) in shards {
            if idx >= self.n() {
                return Err(RsError::BadIndex { idx, n: self.n() });
            }
            if frag.len() != len {
                return Err(RsError::LengthMismatch { expected: len, got: frag.len() });
            }
        }
        // Fast path: all data fragments present.
        let mut have_data = vec![None; self.k];
        for &(idx, frag) in shards {
            if idx < self.k {
                have_data[idx] = Some(frag);
            }
        }
        if have_data.iter().all(|f| f.is_some()) {
            return Ok(have_data.into_iter().map(|f| f.unwrap().to_vec()).collect());
        }
        // General path: invert the k×k submatrix of the generator picked
        // by the first k surviving fragment indices.
        let chosen: Vec<&(usize, &[u8])> = shards.iter().take(self.k).collect();
        let rows: Vec<usize> = chosen.iter().map(|(i, _)| *i).collect();
        let sub = self.generator.select_rows(&rows);
        let inv = sub
            .inverse()
            .expect("MDS property: any k rows of the generator are invertible");
        // data[j] = sum_i inv[j][i] * chosen[i]
        let mut out = vec![vec![0u8; len]; self.k];
        for (j, out_frag) in out.iter_mut().enumerate() {
            for (i, &&(_, frag)) in chosen.iter().enumerate() {
                let c = inv[(j, i)];
                if c != 0 {
                    MulTable::new(c).mul_slice_add(frag, out_frag);
                }
            }
        }
        Ok(out)
    }

    /// Convenience: encode a contiguous buffer into an FTG.
    ///
    /// Pads the tail with zeros to a multiple of `fragment_size` and
    /// returns all n fragments (data first, then parity).
    pub fn encode_buffer(
        &self,
        buf: &[u8],
        fragment_size: usize,
    ) -> Result<Vec<Vec<u8>>, RsError> {
        assert!(fragment_size > 0);
        let mut frags: Vec<Vec<u8>> = Vec::with_capacity(self.n());
        for i in 0..self.k {
            let lo = (i * fragment_size).min(buf.len());
            let hi = ((i + 1) * fragment_size).min(buf.len());
            let mut f = buf[lo..hi].to_vec();
            f.resize(fragment_size, 0);
            frags.push(f);
        }
        let refs: Vec<&[u8]> = frags.iter().map(|f| f.as_slice()).collect();
        let parity = self.encode(&refs)?;
        frags.extend(parity);
        Ok(frags)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_fragments(rng: &mut Pcg64, k: usize, len: usize) -> Vec<Vec<u8>> {
        (0..k)
            .map(|_| {
                let mut f = vec![0u8; len];
                rng.fill_bytes(&mut f);
                f
            })
            .collect()
    }

    #[test]
    fn roundtrip_no_loss() {
        let mut rng = Pcg64::seeded(1);
        let code = RsCode::new(4, 2).unwrap();
        let data = random_fragments(&mut rng, 4, 64);
        let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
        let parity = code.encode(&refs).unwrap();
        assert_eq!(parity.len(), 2);
        let shards: Vec<(usize, &[u8])> =
            data.iter().enumerate().map(|(i, f)| (i, f.as_slice())).collect();
        let got = code.reconstruct(&shards).unwrap();
        assert_eq!(got, data);
        let _ = parity;
    }

    #[test]
    fn recovers_from_any_m_losses() {
        let mut rng = Pcg64::seeded(2);
        for (k, m) in [(4, 2), (7, 1), (16, 16), (28, 4), (31, 1)] {
            let code = RsCode::new(k, m).unwrap();
            let data = random_fragments(&mut rng, k, 128);
            let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
            let parity = code.encode(&refs).unwrap();
            let all: Vec<Vec<u8>> = data.iter().chain(parity.iter()).cloned().collect();
            for _trial in 0..20 {
                // Drop exactly m random fragments.
                let lost = rng.sample_indices(k + m, m);
                let shards: Vec<(usize, &[u8])> = (0..k + m)
                    .filter(|i| !lost.contains(i))
                    .map(|i| (i, all[i].as_slice()))
                    .collect();
                let got = code.reconstruct(&shards).unwrap();
                assert_eq!(got, data, "k={k} m={m} lost={lost:?}");
            }
        }
    }

    #[test]
    fn fails_with_fewer_than_k() {
        let code = RsCode::new(4, 2).unwrap();
        let mut rng = Pcg64::seeded(3);
        let data = random_fragments(&mut rng, 4, 32);
        let shards: Vec<(usize, &[u8])> = data
            .iter()
            .take(3)
            .enumerate()
            .map(|(i, f)| (i, f.as_slice()))
            .collect();
        assert_eq!(
            code.reconstruct(&shards),
            Err(RsError::NotEnough { have: 3, need: 4 })
        );
    }

    #[test]
    fn zero_parity_code_is_passthrough() {
        // m = 0 is legal in the paper's sweeps (no fault tolerance).
        let code = RsCode::new(5, 0).unwrap();
        let mut rng = Pcg64::seeded(4);
        let data = random_fragments(&mut rng, 5, 16);
        let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
        assert!(code.encode(&refs).unwrap().is_empty());
    }

    #[test]
    fn bad_params_rejected() {
        assert!(RsCode::new(0, 3).is_err());
        assert!(RsCode::new(200, 100).is_err());
        assert!(RsCode::new(1, 0).is_ok());
        assert!(RsCode::new(128, 128).is_ok());
    }

    #[test]
    fn length_mismatch_rejected() {
        let code = RsCode::new(2, 1).unwrap();
        let a = vec![0u8; 16];
        let b = vec![0u8; 17];
        let refs: Vec<&[u8]> = vec![&a, &b];
        assert!(matches!(
            code.encode(&refs),
            Err(RsError::LengthMismatch { .. })
        ));
    }

    #[test]
    fn encode_buffer_pads_and_splits() {
        let code = RsCode::new(4, 4).unwrap();
        let buf: Vec<u8> = (0..1000u32).map(|i| i as u8).collect();
        let frags = code.encode_buffer(&buf, 4096).unwrap();
        assert_eq!(frags.len(), 8);
        assert!(frags.iter().all(|f| f.len() == 4096));
        assert_eq!(&frags[0][..1000], &buf[..]);
        assert!(frags[0][1000..].iter().all(|&b| b == 0));
        // Recover data from parity only + 0 data? Need any 4 of 8:
        let shards: Vec<(usize, &[u8])> =
            (4..8).map(|i| (i, frags[i].as_slice())).collect();
        let got = code.reconstruct(&shards).unwrap();
        assert_eq!(got[0], frags[0]);
    }

    #[test]
    fn encode_into_matches_encode() {
        let mut rng = Pcg64::seeded(5);
        let code = RsCode::new(6, 3).unwrap();
        let data = random_fragments(&mut rng, 6, 256);
        let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
        let fresh = code.encode(&refs).unwrap();
        let mut reused = vec![vec![0xAAu8; 7]; 3]; // wrong size, pre-dirtied
        code.encode_into(&refs, &mut reused).unwrap();
        assert_eq!(fresh, reused);
    }

    #[test]
    fn prop_any_k_subset_reconstructs() {
        // Property-style test over random (k, m, subset) draws.
        use crate::util::prop::{check, no_shrink, PropConfig};
        check(
            &PropConfig { cases: 60, ..Default::default() },
            |rng| {
                let k = rng.range(1, 12);
                let m = rng.range(0, 8);
                let seed = rng.next_u64();
                (k, m, seed)
            },
            no_shrink,
            |&(k, m, seed)| {
                let mut rng = Pcg64::seeded(seed);
                let code = RsCode::new(k, m).map_err(|e| e.to_string())?;
                let data = random_fragments(&mut rng, k, 32);
                let refs: Vec<&[u8]> = data.iter().map(|f| f.as_slice()).collect();
                let parity = code.encode(&refs).map_err(|e| e.to_string())?;
                let all: Vec<Vec<u8>> = data.iter().chain(parity.iter()).cloned().collect();
                let mut idx: Vec<usize> = (0..k + m).collect();
                rng.shuffle(&mut idx);
                let shards: Vec<(usize, &[u8])> =
                    idx[..k].iter().map(|&i| (i, all[i].as_slice())).collect();
                let got = code.reconstruct(&shards).map_err(|e| e.to_string())?;
                if got == data {
                    Ok(())
                } else {
                    Err(format!("mismatch k={k} m={m} subset={:?}", &idx[..k]))
                }
            },
        );
    }
}
