//! LT-style rateless fountain code — the barrier-free erasure backend
//! (DESIGN.md §12).
//!
//! Reed–Solomon plans `m` parity fragments per group up front and
//! repairs residual loss through pass-barrier LostList exchanges, paying
//! one RTT per repair round. An LT code is *rateless*: the sender can
//! generate an unbounded stream of encoding symbols, each a seeded XOR
//! of a robust-soliton-sized subset of the group's `k` source fragments,
//! and the receiver decodes as soon as *any* `k(1+ε)` symbols arrive —
//! no rounds, no lost lists (exemplar: the `raptorq` sender/receiver
//! split; SNIPPETS.md Snippet 2).
//!
//! * [`RobustSoliton`] — the degree distribution μ(d): the ideal soliton
//!   ρ(d) plus Luby's τ(d) spike, normalized into a sampling CDF.
//! * [`LtCode`] — symbol generation: `esi < k` emits the systematic
//!   source fragment unchanged; `esi ≥ k` XORs a seeded neighbor set on
//!   the GF(256) kernel fast paths (XOR is GF(256) addition, so the
//!   dispatch-once SIMD `MulTable(1)` slice kernels apply unchanged).
//! * [`FountainDecoder`] — incremental peeling with a bounded pending
//!   buffer and a Gaussian-elimination fallback for the stalls peeling
//!   alone cannot clear (both produce identical bytes; asserted by
//!   `tests/fountain_props.rs`).
//!
//! Determinism contract: a symbol's neighbor set is a pure function of
//! `(seed, group, esi, k)` — both endpoints derive it independently, so
//! the wire carries only those integers, never the neighbor list.

use super::backend::ErasureBackend;
use super::gf256::MulTable;
use super::par::CodingPool;
use super::rs::RsError;
use crate::coordinator::arena::FtgArena;
use crate::util::Pcg64;

/// Robust-soliton degree distribution over `1..=k`, precomputed as a
/// CDF for O(log k) sampling.
#[derive(Debug, Clone)]
pub struct RobustSoliton {
    k: usize,
    cdf: Vec<f64>,
}

impl RobustSoliton {
    /// Default spike-width constant `c` (Luby's tuning parameter).
    pub const C: f64 = 0.1;
    /// Default decode-failure bound `δ`.
    pub const DELTA: f64 = 0.5;

    /// Distribution for `k` source symbols with the default `(c, δ)`.
    pub fn new(k: usize) -> RobustSoliton {
        Self::with_params(k, Self::C, Self::DELTA)
    }

    /// Distribution with explicit Luby parameters. `R = c·ln(k/δ)·√k`
    /// sizes the spike; the spike position `k/R` is clamped into
    /// `1..=k` so tiny `k` stay well-formed.
    pub fn with_params(k: usize, c: f64, delta: f64) -> RobustSoliton {
        assert!(k >= 1, "degree distribution needs k >= 1");
        if k == 1 {
            return RobustSoliton { k, cdf: vec![1.0] };
        }
        let kf = k as f64;
        let r = (c * (kf / delta).ln() * kf.sqrt()).max(1.0);
        let spike = ((kf / r).round() as usize).clamp(1, k);
        let mut pdf = vec![0.0f64; k];
        // Ideal soliton ρ: ρ(1) = 1/k, ρ(d) = 1/(d(d−1)).
        pdf[0] = 1.0 / kf;
        for d in 2..=k {
            pdf[d - 1] = 1.0 / (d as f64 * (d as f64 - 1.0));
        }
        // Luby's τ: R/(dk) below the spike, R·ln(R/δ)/k at it.
        for d in 1..spike {
            pdf[d - 1] += r / (d as f64 * kf);
        }
        pdf[spike - 1] += r * (r / delta).ln().max(0.0) / kf;
        let beta: f64 = pdf.iter().sum();
        let mut cdf = Vec::with_capacity(k);
        let mut acc = 0.0;
        for p in &pdf {
            acc += p / beta;
            cdf.push(acc);
        }
        // Guard the tail against float drift so sample() never misses.
        *cdf.last_mut().unwrap() = 1.0;
        RobustSoliton { k, cdf }
    }

    /// Source symbols the distribution was built for.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Map a uniform draw `u ∈ [0, 1)` to a degree in `1..=k`.
    pub fn sample(&self, u: f64) -> usize {
        let idx = self.cdf.partition_point(|&p| p <= u);
        idx.min(self.k - 1) + 1
    }

    /// Mean degree (the expected XOR width; tests pin statistics here).
    pub fn mean_degree(&self) -> f64 {
        let mut prev = 0.0;
        let mut mean = 0.0;
        for (i, &p) in self.cdf.iter().enumerate() {
            mean += (i + 1) as f64 * (p - prev);
            prev = p;
        }
        mean
    }
}

/// Mix `(seed, group, esi)` into one 64-bit symbol seed (splitmix64
/// finalizer — both endpoints must agree on this exactly).
fn symbol_seed(seed: u64, group: u32, esi: u32) -> u64 {
    let mut z = seed
        ^ ((group as u64) << 32)
        ^ (esi as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// LT encoder/geometry for one group size `k`: seeded robust-soliton
/// degree sampling + XOR symbol generation on the kernel fast paths.
///
/// One `LtCode` serves every group with the same `k` (the per-symbol
/// neighbor set mixes the group id in, so groups stay decorrelated).
#[derive(Debug, Clone)]
pub struct LtCode {
    k: usize,
    seed: u64,
    dist: RobustSoliton,
    /// `MulTable::new(1)`: GF(256) add is XOR, so the SIMD slice kernels
    /// double as the fountain's XOR engine.
    one: MulTable,
}

impl LtCode {
    /// Protocol-default transfer seed. Every repair symbol carries its
    /// seed on the wire ([`crate::coordinator::packet::RepairHeader`]),
    /// so senders *may* randomize; the default keeps both endpoints
    /// aligned even for groups whose first arrivals are systematic
    /// fragments (which carry no seed).
    pub const DEFAULT_SEED: u64 = 0x4A41_4E55_535F_4C54; // "JANUS_LT"

    /// Code for `k` source fragments under transfer seed `seed`.
    pub fn new(k: usize, seed: u64) -> Result<LtCode, RsError> {
        if k < 1 || k > 256 {
            return Err(RsError::BadParams { k, m: 0 });
        }
        Ok(LtCode { k, seed, dist: RobustSoliton::new(k), one: MulTable::new(1) })
    }

    /// Source fragments per group.
    pub fn k(&self) -> usize {
        self.k
    }

    /// The transfer seed symbols derive from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The degree distribution (tests sample it directly).
    pub fn distribution(&self) -> &RobustSoliton {
        &self.dist
    }

    /// XOR `src` into `dst` through the dispatch-once kernel tiers.
    #[inline]
    pub fn xor_into(&self, src: &[u8], dst: &mut [u8]) {
        self.one.mul_slice_add(src, dst);
    }

    /// Compute the neighbor set of symbol `esi` for `group` into `out`.
    /// `esi < k` is systematic (neighbors = `[esi]`); `esi ≥ k` draws a
    /// robust-soliton degree and that many distinct source indices from
    /// the seeded per-symbol stream.
    pub fn neighbors_into(&self, group: u32, esi: u32, out: &mut Vec<usize>) {
        out.clear();
        let e = esi as usize;
        if e < self.k {
            out.push(e);
            return;
        }
        let mut rng = Pcg64::seeded(symbol_seed(self.seed, group, esi));
        let d = self.dist.sample(rng.next_f64());
        out.extend(rng.sample_indices(self.k, d));
    }

    /// Generate symbol `esi` of `group` into `out` (`stride` bytes) from
    /// the group's source data (`≥ k·stride` bytes, slot `i` at
    /// `[i·stride, (i+1)·stride)`). `scratch` avoids a per-symbol
    /// neighbor allocation on the sender hot path.
    pub fn symbol_into(
        &self,
        data: &[u8],
        stride: usize,
        group: u32,
        esi: u32,
        scratch: &mut Vec<usize>,
        out: &mut [u8],
    ) {
        debug_assert!(data.len() >= self.k * stride);
        debug_assert_eq!(out.len(), stride);
        self.neighbors_into(group, esi, scratch);
        let mut first = true;
        for &nb in scratch.iter() {
            let src = &data[nb * stride..(nb + 1) * stride];
            if first {
                self.one.mul_slice(src, out);
                first = false;
            } else {
                self.one.mul_slice_add(src, out);
            }
        }
    }
}

impl ErasureBackend for LtCode {
    fn data_fragments(&self) -> usize {
        self.k
    }

    /// Rateless: no planned parity slots — repair symbols are generated
    /// on demand, so group arenas carry exactly `k` slots.
    fn parity_fragments(&self) -> usize {
        0
    }

    fn encode_strided(&self, buf: &mut [u8], stride: usize) -> Result<(), RsError> {
        if stride == 0 || buf.len() != self.k * stride {
            return Err(RsError::LengthMismatch { expected: self.k * stride, got: buf.len() });
        }
        // Systematic source only: nothing to compute in the arena. The
        // repair stream flows through [`LtCode::symbol_into`] instead.
        Ok(())
    }

    /// The trait path only handles the systematic-complete case (all `k`
    /// source fragments present); lossy groups decode through
    /// [`FountainDecoder`], which owns the rateless symbol state.
    fn reconstruct_group(
        &mut self,
        shards: &[(usize, &[u8])],
        out: &mut [u8],
    ) -> Result<(), RsError> {
        let mut found = 0usize;
        let len = match shards.first() {
            Some(&(_, f)) => f.len(),
            None => return Err(RsError::NotEnough { have: 0, need: self.k }),
        };
        if out.len() != self.k * len {
            return Err(RsError::LengthMismatch { expected: self.k * len, got: out.len() });
        }
        for &(idx, frag) in shards {
            if idx >= self.k {
                return Err(RsError::BadIndex { idx, n: self.k });
            }
            if frag.len() != len {
                return Err(RsError::LengthMismatch { expected: len, got: frag.len() });
            }
            out[idx * len..(idx + 1) * len].copy_from_slice(frag);
            found += 1;
        }
        if found < self.k {
            return Err(RsError::NotEnough { have: found, need: self.k });
        }
        Ok(())
    }

    fn reconstruct_batch(
        &self,
        _pool: &CodingPool,
        items: &mut [(&FtgArena, &mut [u8])],
    ) -> Vec<Result<(), RsError>> {
        items
            .iter_mut()
            .map(|(arena, out)| {
                let shards: Vec<(usize, &[u8])> = arena.iter_present().collect();
                // Clone is cheap: LtCode is a CDF + one table; decode
                // state, unlike RS, lives in FountainDecoder.
                self.clone().reconstruct_group(&shards, out)
            })
            .collect()
    }
}

/// One buffered not-yet-resolved symbol: its still-unknown neighbor set
/// and its payload reduced by every already-decoded source.
#[derive(Debug)]
struct Pending {
    neighbors: Vec<usize>,
    buf: Vec<u8>,
}

/// Incremental per-group LT decoder: peeling first, bounded pending
/// memory, Gaussian elimination when peeling stalls.
///
/// Memory bound: the decoded output (`k·s` bytes) plus at most
/// `2k + 16` pending symbols of `s` bytes each — symbols beyond the cap
/// are counted in [`FountainDecoder::dropped`] and simply re-requested
/// by the rateless stream's nature (more symbols always come).
#[derive(Debug)]
pub struct FountainDecoder {
    code: LtCode,
    group: u32,
    s: usize,
    data: Vec<u8>,
    have: Vec<bool>,
    decoded: usize,
    pending: Vec<Pending>,
    scratch: Vec<usize>,
    received: u64,
    dropped: u64,
    /// Gaussian-elimination throttle: attempts are spaced this many
    /// symbols apart once the rank condition is plausible.
    ge_cooldown: usize,
}

impl FountainDecoder {
    /// Decoder for group `group` with `k` source fragments of `s` bytes
    /// under transfer seed `seed`.
    pub fn new(k: usize, s: usize, seed: u64, group: u32) -> Result<FountainDecoder, RsError> {
        let code = LtCode::new(k, seed)?;
        Ok(FountainDecoder {
            code,
            group,
            s,
            data: vec![0u8; k * s],
            have: vec![false; k],
            decoded: 0,
            pending: Vec::new(),
            scratch: Vec::new(),
            received: 0,
            dropped: 0,
            ge_cooldown: 0,
        })
    }

    /// Source fragments this group decodes to.
    pub fn k(&self) -> usize {
        self.code.k()
    }

    /// Symbols fed in so far (including redundant ones).
    pub fn received(&self) -> u64 {
        self.received
    }

    /// Symbols discarded at the pending-buffer cap (bounded memory).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Currently buffered unresolved symbols.
    pub fn buffered(&self) -> usize {
        self.pending.len()
    }

    /// Have all `k` source fragments been recovered?
    pub fn is_complete(&self) -> bool {
        self.decoded == self.code.k()
    }

    /// The recovered group data (`k·s` bytes). Only meaningful once
    /// [`FountainDecoder::is_complete`] returns true.
    pub fn data(&self) -> &[u8] {
        &self.data
    }

    fn cap(&self) -> usize {
        2 * self.code.k() + 16
    }

    /// Feed one symbol; returns `true` the moment the group completes.
    /// Wrong-length payloads are ignored (a corrupted-but-CRC-valid
    /// datagram cannot reach here; this guards logic bugs upstream).
    pub fn add_symbol(&mut self, esi: u32, payload: &[u8]) -> bool {
        if self.is_complete() || payload.len() != self.s {
            return false;
        }
        self.received += 1;
        self.code.neighbors_into(self.group, esi, &mut self.scratch);
        // Reduce against everything already decoded.
        let mut buf = payload.to_vec();
        let mut unknown: Vec<usize> = Vec::with_capacity(self.scratch.len());
        for i in 0..self.scratch.len() {
            let nb = self.scratch[i];
            if self.have[nb] {
                self.code.xor_into(&self.data[nb * self.s..(nb + 1) * self.s], &mut buf);
            } else {
                unknown.push(nb);
            }
        }
        match unknown.len() {
            0 => {} // redundant: every neighbor already known
            1 => {
                let idx = unknown[0];
                self.learn(idx, &buf);
                self.peel();
            }
            _ => {
                if self.pending.len() >= self.cap() {
                    self.dropped += 1;
                } else {
                    self.pending.push(Pending { neighbors: unknown, buf });
                }
            }
        }
        if !self.is_complete() {
            self.maybe_gaussian();
        }
        if self.is_complete() {
            self.pending.clear();
            self.pending.shrink_to_fit();
            true
        } else {
            false
        }
    }

    fn learn(&mut self, idx: usize, bytes: &[u8]) {
        debug_assert!(!self.have[idx]);
        self.data[idx * self.s..(idx + 1) * self.s].copy_from_slice(bytes);
        self.have[idx] = true;
        self.decoded += 1;
    }

    /// Peeling cascade: reduce every pending symbol by the known
    /// sources, release the degree-1 remainders, repeat to fixpoint.
    fn peel(&mut self) {
        let s = self.s;
        loop {
            // Reduce all pending entries against the current known set.
            for p in self.pending.iter_mut() {
                let mut j = 0;
                while j < p.neighbors.len() {
                    let nb = p.neighbors[j];
                    if self.have[nb] {
                        self.code.xor_into(&self.data[nb * s..(nb + 1) * s], &mut p.buf);
                        p.neighbors.swap_remove(j);
                    } else {
                        j += 1;
                    }
                }
            }
            // Release resolved entries; learning any re-runs the loop.
            let mut progressed = false;
            let mut i = 0;
            while i < self.pending.len() {
                match self.pending[i].neighbors.len() {
                    0 => {
                        self.pending.swap_remove(i);
                    }
                    1 => {
                        let p = self.pending.swap_remove(i);
                        let idx = p.neighbors[0];
                        if !self.have[idx] {
                            self.learn(idx, &p.buf);
                            progressed = true;
                        }
                    }
                    _ => i += 1,
                }
            }
            if !progressed || self.is_complete() {
                break;
            }
        }
    }

    /// Gaussian-elimination fallback: peeling can stall even when the
    /// buffered symbols jointly have full rank over the missing sources
    /// (no degree-1 symbol exposed). Attempt a solve over GF(2) when the
    /// count condition allows one, throttled so the O(pending·k) work
    /// isn't paid on every symbol.
    fn maybe_gaussian(&mut self) {
        let k = self.code.k();
        if self.decoded + self.pending.len() < k {
            return;
        }
        if self.ge_cooldown > 0 {
            self.ge_cooldown -= 1;
            return;
        }
        self.ge_cooldown = 4;
        self.gaussian();
    }

    fn gaussian(&mut self) {
        let k = self.code.k();
        let missing: Vec<usize> = (0..k).filter(|&i| !self.have[i]).collect();
        let ncols = missing.len();
        if ncols == 0 || self.pending.len() < ncols {
            return;
        }
        let mut col_of = vec![usize::MAX; k];
        for (c, &idx) in missing.iter().enumerate() {
            col_of[idx] = c;
        }
        let words = ncols.div_ceil(64);
        // Work on copies: a failed (rank-deficient) solve must leave the
        // pending set intact for future peeling.
        let mut rows: Vec<(Vec<u64>, Vec<u8>)> = self
            .pending
            .iter()
            .map(|p| {
                let mut bits = vec![0u64; words];
                for &nb in &p.neighbors {
                    let c = col_of[nb];
                    bits[c / 64] |= 1u64 << (c % 64);
                }
                (bits, p.buf.clone())
            })
            .collect();
        let bit = |bits: &[u64], c: usize| bits[c / 64] >> (c % 64) & 1 == 1;
        // Gauss-Jordan over GF(2): after the sweep each pivot row holds
        // exactly its own column bit.
        let mut pivot_of_col = vec![usize::MAX; ncols];
        let mut next_row = 0usize;
        for c in 0..ncols {
            let Some(pr) = (next_row..rows.len()).find(|&i| bit(&rows[i].0, c)) else {
                continue;
            };
            rows.swap(next_row, pr);
            let (pbits, pbuf) = (rows[next_row].0.clone(), rows[next_row].1.clone());
            for (i, row) in rows.iter_mut().enumerate() {
                if i != next_row && bit(&row.0, c) {
                    for (w, pw) in row.0.iter_mut().zip(&pbits) {
                        *w ^= pw;
                    }
                    self.code.xor_into(&pbuf, &mut row.1);
                }
            }
            pivot_of_col[c] = next_row;
            next_row += 1;
        }
        if pivot_of_col.iter().any(|&p| p == usize::MAX) {
            return; // rank-deficient: wait for more symbols
        }
        for c in 0..ncols {
            let r = pivot_of_col[c];
            debug_assert!(bit(&rows[r].0, c));
            let idx = missing[c];
            let buf = std::mem::take(&mut rows[r].1);
            self.learn(idx, &buf);
        }
        self.pending.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn group_data(k: usize, s: usize, seed: u64) -> Vec<u8> {
        let mut data = vec![0u8; k * s];
        Pcg64::seeded(seed).fill_bytes(&mut data);
        data
    }

    #[test]
    fn soliton_cdf_is_monotone_and_complete() {
        for k in [1usize, 2, 3, 8, 31, 64, 255] {
            let d = RobustSoliton::new(k);
            let mut prev = 0.0;
            for (i, &p) in d.cdf.iter().enumerate() {
                assert!(p >= prev, "k={k}: cdf dips at degree {}", i + 1);
                prev = p;
            }
            assert_eq!(*d.cdf.last().unwrap(), 1.0);
            assert_eq!(d.sample(0.0), 1, "k={k}: u=0 must map to degree 1");
            assert!(d.sample(0.9999999) <= k);
        }
    }

    #[test]
    fn systematic_symbols_are_source_fragments() {
        let (k, s) = (8usize, 64usize);
        let code = LtCode::new(k, 0xABCD).unwrap();
        let data = group_data(k, s, 1);
        let mut scratch = Vec::new();
        let mut out = vec![0u8; s];
        for esi in 0..k as u32 {
            code.symbol_into(&data, s, 0, esi, &mut scratch, &mut out);
            assert_eq!(&out[..], &data[esi as usize * s..(esi as usize + 1) * s]);
        }
    }

    #[test]
    fn decoder_completes_from_source_symbols_alone() {
        let (k, s) = (6usize, 32usize);
        let data = group_data(k, s, 2);
        let mut dec = FountainDecoder::new(k, s, 7, 3).unwrap();
        for esi in 0..k as u32 {
            let done = dec.add_symbol(esi, &data[esi as usize * s..(esi as usize + 1) * s]);
            assert_eq!(done, esi as usize == k - 1);
        }
        assert!(dec.is_complete());
        assert_eq!(dec.data(), &data[..]);
    }

    #[test]
    fn decoder_recovers_lost_sources_from_repair_symbols() {
        let (k, s) = (12usize, 48usize);
        let seed = 0xFEED;
        let code = LtCode::new(k, seed).unwrap();
        let data = group_data(k, s, 3);
        let mut dec = FountainDecoder::new(k, s, seed, 9).unwrap();
        let mut scratch = Vec::new();
        let mut sym = vec![0u8; s];
        // Lose a third of the source symbols.
        for esi in 0..k as u32 {
            if esi % 3 == 0 {
                continue;
            }
            code.symbol_into(&data, s, 9, esi, &mut scratch, &mut sym);
            dec.add_symbol(esi, &sym);
        }
        assert!(!dec.is_complete());
        // Stream repair symbols until it closes (generous bound).
        let mut esi = k as u32;
        while !dec.is_complete() {
            assert!(esi < 20 * k as u32, "decoder failed to converge");
            code.symbol_into(&data, s, 9, esi, &mut scratch, &mut sym);
            dec.add_symbol(esi, &sym);
            esi += 1;
        }
        assert_eq!(dec.data(), &data[..]);
    }

    #[test]
    fn pending_buffer_is_bounded() {
        let (k, s) = (8usize, 16usize);
        let seed = 0x11;
        let code = LtCode::new(k, seed).unwrap();
        let data = group_data(k, s, 4);
        let mut dec = FountainDecoder::new(k, s, seed, 0).unwrap();
        let mut scratch = Vec::new();
        let mut sym = vec![0u8; s];
        // Feed only high-degree repair symbols; the pending buffer must
        // never exceed the documented cap whatever happens.
        for esi in k as u32..(k as u32 + 500) {
            code.symbol_into(&data, s, 0, esi, &mut scratch, &mut sym);
            dec.add_symbol(esi, &sym);
            assert!(dec.buffered() <= 2 * k + 16);
            if dec.is_complete() {
                break;
            }
        }
        assert!(dec.is_complete(), "500 symbols must decode k=8");
        assert_eq!(dec.data(), &data[..]);
    }

    #[test]
    fn backend_trait_geometry_for_lt() {
        let code = LtCode::new(24, 1).unwrap();
        let b: &dyn ErasureBackend = &code;
        assert_eq!(b.data_fragments(), 24);
        assert_eq!(b.parity_fragments(), 0);
        assert_eq!(b.group_slots(), 24);
    }

    #[test]
    fn backend_encode_strided_validates_geometry() {
        let code = LtCode::new(4, 1).unwrap();
        let mut buf = vec![0u8; 4 * 8];
        assert!(ErasureBackend::encode_strided(&code, &mut buf, 8).is_ok());
        assert!(ErasureBackend::encode_strided(&code, &mut buf, 7).is_err());
    }
}
