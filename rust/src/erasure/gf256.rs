//! GF(2^8) arithmetic for Reed–Solomon coding.
//!
//! Field: GF(256) with the AES/Rijndael-compatible primitive polynomial
//! x^8 + x^4 + x^3 + x^2 + 1 (0x11D), generator 2 — the same construction
//! used by liberasurecode/ISA-L, which the paper benchmarks (§5.2.2).
//!
//! Two multiplication strategies:
//!  * `mul` — log/exp table lookups, used for matrix algebra.
//!  * `MulTable::apply` / [`mul_slice_add`] — a 2×16-entry split-nibble
//!    table per constant, applied over byte slices. This is the encode/
//!    decode inner loop; it avoids the log/exp double lookup and the
//!    branch on zero, and vectorizes well.
//!
//! Kernel tiers (scalar / SSSE3 `pshufb` / AVX2 `vpshufb`) are resolved
//! exactly once per process by [`crate::erasure::kernel::active`]; the
//! slice kernels here dispatch on that cached tier — no per-call feature
//! detection. The `*_tier` variants force a tier (clamped to CPU
//! support) for tests and benches.

use super::kernel;

/// Primitive polynomial x^8+x^4+x^3+x^2+1 (0x11D) reduced to 8 bits.
const POLY: u32 = 0x11D;

/// Exponentiation table: EXP[i] = g^i for g = 2, length 512 to avoid
/// a modulo in `mul`.
static EXP: [u8; 512] = build_exp();
/// Log table: LOG[x] = i such that g^i = x (LOG[0] unused).
static LOG: [u8; 256] = build_log();

const fn build_exp() -> [u8; 512] {
    let mut exp = [0u8; 512];
    let mut x: u32 = 1;
    let mut i = 0;
    while i < 255 {
        exp[i] = x as u8;
        x <<= 1;
        if x & 0x100 != 0 {
            x ^= POLY;
        }
        i += 1;
    }
    // Duplicate so exp[a + b] works without (a + b) % 255.
    let mut j = 0;
    while j < 257 {
        exp[255 + j] = exp[j % 255];
        j += 1;
    }
    exp
}

const fn build_log() -> [u8; 256] {
    let exp = build_exp();
    let mut log = [0u8; 256];
    let mut i = 0;
    while i < 255 {
        log[exp[i] as usize] = i as u8;
        i += 1;
    }
    log
}

/// Field addition (= subtraction): XOR.
#[inline(always)]
pub fn add(a: u8, b: u8) -> u8 {
    a ^ b
}

/// Field multiplication via log/exp tables.
#[inline(always)]
pub fn mul(a: u8, b: u8) -> u8 {
    if a == 0 || b == 0 {
        0
    } else {
        EXP[LOG[a as usize] as usize + LOG[b as usize] as usize]
    }
}

/// Multiplicative inverse. Panics on zero.
#[inline]
pub fn inv(a: u8) -> u8 {
    assert!(a != 0, "gf256: inverse of zero");
    EXP[255 - LOG[a as usize] as usize]
}

/// Field division a / b. Panics when b == 0.
#[inline]
pub fn div(a: u8, b: u8) -> u8 {
    assert!(b != 0, "gf256: division by zero");
    if a == 0 {
        0
    } else {
        EXP[LOG[a as usize] as usize + 255 - LOG[b as usize] as usize]
    }
}

/// a^n by repeated squaring (exponent over the integers).
pub fn pow(a: u8, n: u64) -> u8 {
    if n == 0 {
        return 1;
    }
    if a == 0 {
        return 0;
    }
    let e = (LOG[a as usize] as u64 * (n % 255)) % 255;
    EXP[e as usize]
}

/// Precomputed split-nibble multiplication table for one constant.
///
/// `mul(c, x)` = `lo[x & 15] ^ hi[x >> 4]` — two loads and one XOR per
/// byte, no branches, friendly to auto-vectorization.
#[derive(Clone)]
pub struct MulTable {
    pub(crate) lo: [u8; 16],
    pub(crate) hi: [u8; 16],
}

impl MulTable {
    pub fn new(c: u8) -> Self {
        let mut lo = [0u8; 16];
        let mut hi = [0u8; 16];
        for i in 0..16u8 {
            lo[i as usize] = mul(c, i);
            hi[i as usize] = mul(c, i << 4);
        }
        MulTable { lo, hi }
    }

    /// y[i] ^= c * x[i] over slices.
    ///
    /// Hot loop of Reed–Solomon encode/decode. On x86-64 the split-nibble
    /// tables map directly onto `pshufb`/`vpshufb` (16/32 parallel table
    /// lookups per instruction — the ISA-L/liberasurecode technique the
    /// paper's `r_ec` numbers come from); elsewhere a scalar loop. The
    /// tier comes from the process-wide dispatch cache
    /// ([`kernel::active`]) — resolved once, branched on here.
    #[inline]
    pub fn mul_slice_add(&self, x: &[u8], y: &mut [u8]) {
        self.mul_slice_add_tier(x, y, kernel::active());
    }

    /// [`MulTable::mul_slice_add`] on a forced kernel tier (clamped to
    /// what the CPU supports) — lets tests and benches sweep every tier
    /// in one process.
    #[inline]
    pub fn mul_slice_add_tier(&self, x: &[u8], y: &mut [u8], tier: kernel::KernelTier) {
        debug_assert_eq!(x.len(), y.len());
        match tier.clamp() {
            // SAFETY: `clamp()` only returns Avx2 when the CPU reports
            // AVX2, satisfying the kernel's target-feature contract.
            #[cfg(target_arch = "x86_64")]
            kernel::KernelTier::Avx2 => unsafe { self.mul_slice_add_avx2(x, y) },
            // SAFETY: `clamp()` only returns Ssse3 when the CPU reports
            // SSSE3, satisfying the kernel's target-feature contract.
            #[cfg(target_arch = "x86_64")]
            kernel::KernelTier::Ssse3 => unsafe { self.mul_slice_add_ssse3(x, y) },
            _ => self.mul_slice_add_scalar(x, y),
        }
    }

    #[inline]
    fn mul_slice_add_scalar(&self, x: &[u8], y: &mut [u8]) {
        for (yi, &xi) in y.iter_mut().zip(x.iter()) {
            *yi ^= self.lo[(xi & 0x0F) as usize] ^ self.hi[(xi >> 4) as usize];
        }
    }

    /// # Safety
    /// The CPU must support SSSE3 (the `#[target_feature]` calling
    /// contract) and `x.len() == y.len()`.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "ssse3")]
    unsafe fn mul_slice_add_ssse3(&self, x: &[u8], y: &mut [u8]) {
        use std::arch::x86_64::*;
        let chunks = x.len() / 16;
        let done = chunks * 16;
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        // SAFETY: the caller guarantees SSSE3; unaligned loads/stores
        // stay in bounds because every offset is < chunks*16 <= len,
        // and the table loads read exactly the 16-byte nibble arrays.
        unsafe {
            let lo_tbl = _mm_loadu_si128(self.lo.as_ptr() as *const __m128i);
            let hi_tbl = _mm_loadu_si128(self.hi.as_ptr() as *const __m128i);
            let mask = _mm_set1_epi8(0x0F);
            for i in 0..chunks {
                let xv = _mm_loadu_si128(xp.add(i * 16) as *const __m128i);
                let lo_idx = _mm_and_si128(xv, mask);
                let hi_idx = _mm_and_si128(_mm_srli_epi64(xv, 4), mask);
                let prod = _mm_xor_si128(
                    _mm_shuffle_epi8(lo_tbl, lo_idx),
                    _mm_shuffle_epi8(hi_tbl, hi_idx),
                );
                let yv = _mm_loadu_si128(yp.add(i * 16) as *const __m128i);
                _mm_storeu_si128(yp.add(i * 16) as *mut __m128i, _mm_xor_si128(yv, prod));
            }
        }
        self.mul_slice_add_scalar(&x[done..], &mut y[done..]);
    }

    /// 32-byte AVX2 accumulate kernel: the two 16-entry nibble tables are
    /// broadcast to both 128-bit lanes (`vpshufb` shuffles per lane, so
    /// the broadcast is exactly the duplicated lookup table it needs);
    /// the sub-32-byte tail reuses the SSSE3 kernel (AVX2 implies SSSE3).
    ///
    /// # Safety
    /// The CPU must support AVX2 (the `#[target_feature]` calling
    /// contract; AVX2 implies SSSE3 for the tail) and
    /// `x.len() == y.len()`.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn mul_slice_add_avx2(&self, x: &[u8], y: &mut [u8]) {
        use std::arch::x86_64::*;
        let chunks = x.len() / 32;
        let done = chunks * 32;
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        // SAFETY: the caller guarantees AVX2 (hence SSSE3 for the tail
        // call); unaligned loads/stores stay in bounds because every
        // offset is < chunks*32 <= len.
        unsafe {
            let lo_tbl =
                _mm256_broadcastsi128_si256(_mm_loadu_si128(self.lo.as_ptr() as *const __m128i));
            let hi_tbl =
                _mm256_broadcastsi128_si256(_mm_loadu_si128(self.hi.as_ptr() as *const __m128i));
            let mask = _mm256_set1_epi8(0x0F);
            for i in 0..chunks {
                let xv = _mm256_loadu_si256(xp.add(i * 32) as *const __m256i);
                let lo_idx = _mm256_and_si256(xv, mask);
                let hi_idx = _mm256_and_si256(_mm256_srli_epi64(xv, 4), mask);
                let prod = _mm256_xor_si256(
                    _mm256_shuffle_epi8(lo_tbl, lo_idx),
                    _mm256_shuffle_epi8(hi_tbl, hi_idx),
                );
                let yv = _mm256_loadu_si256(yp.add(i * 32) as *const __m256i);
                _mm256_storeu_si256(yp.add(i * 32) as *mut __m256i, _mm256_xor_si256(yv, prod));
            }
            self.mul_slice_add_ssse3(&x[done..], &mut y[done..]);
        }
    }

    /// y[i] = c * x[i] over slices — overwrites `y`, no pre-zeroing
    /// needed (write-once kernel; pairs with [`MulTable::mul_slice_add`]
    /// so decode accumulation never double-touches the output).
    #[inline]
    pub fn mul_slice(&self, x: &[u8], y: &mut [u8]) {
        self.mul_slice_tier(x, y, kernel::active());
    }

    /// [`MulTable::mul_slice`] on a forced kernel tier (clamped to what
    /// the CPU supports).
    #[inline]
    pub fn mul_slice_tier(&self, x: &[u8], y: &mut [u8], tier: kernel::KernelTier) {
        debug_assert_eq!(x.len(), y.len());
        match tier.clamp() {
            // SAFETY: `clamp()` only returns Avx2 when the CPU reports
            // AVX2, satisfying the kernel's target-feature contract.
            #[cfg(target_arch = "x86_64")]
            kernel::KernelTier::Avx2 => unsafe { self.mul_slice_set_avx2(x, y) },
            // SAFETY: `clamp()` only returns Ssse3 when the CPU reports
            // SSSE3, satisfying the kernel's target-feature contract.
            #[cfg(target_arch = "x86_64")]
            kernel::KernelTier::Ssse3 => unsafe { self.mul_slice_set_ssse3(x, y) },
            _ => self.mul_slice_set_scalar(x, y),
        }
    }

    #[inline]
    fn mul_slice_set_scalar(&self, x: &[u8], y: &mut [u8]) {
        for (yi, &xi) in y.iter_mut().zip(x.iter()) {
            *yi = self.lo[(xi & 0x0F) as usize] ^ self.hi[(xi >> 4) as usize];
        }
    }

    /// # Safety
    /// The CPU must support SSSE3 (the `#[target_feature]` calling
    /// contract) and `x.len() == y.len()`.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "ssse3")]
    unsafe fn mul_slice_set_ssse3(&self, x: &[u8], y: &mut [u8]) {
        use std::arch::x86_64::*;
        let chunks = x.len() / 16;
        let done = chunks * 16;
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        // SAFETY: the caller guarantees SSSE3; unaligned loads/stores
        // stay in bounds because every offset is < chunks*16 <= len,
        // and the table loads read exactly the 16-byte nibble arrays.
        unsafe {
            let lo_tbl = _mm_loadu_si128(self.lo.as_ptr() as *const __m128i);
            let hi_tbl = _mm_loadu_si128(self.hi.as_ptr() as *const __m128i);
            let mask = _mm_set1_epi8(0x0F);
            for i in 0..chunks {
                let xv = _mm_loadu_si128(xp.add(i * 16) as *const __m128i);
                let lo_idx = _mm_and_si128(xv, mask);
                let hi_idx = _mm_and_si128(_mm_srli_epi64(xv, 4), mask);
                let prod = _mm_xor_si128(
                    _mm_shuffle_epi8(lo_tbl, lo_idx),
                    _mm_shuffle_epi8(hi_tbl, hi_idx),
                );
                _mm_storeu_si128(yp.add(i * 16) as *mut __m128i, prod);
            }
        }
        self.mul_slice_set_scalar(&x[done..], &mut y[done..]);
    }

    /// 32-byte AVX2 write-once kernel (same shape as the accumulate
    /// variant above, minus the output load/xor).
    ///
    /// # Safety
    /// The CPU must support AVX2 (the `#[target_feature]` calling
    /// contract; AVX2 implies SSSE3 for the tail) and
    /// `x.len() == y.len()`.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn mul_slice_set_avx2(&self, x: &[u8], y: &mut [u8]) {
        use std::arch::x86_64::*;
        let chunks = x.len() / 32;
        let done = chunks * 32;
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        // SAFETY: the caller guarantees AVX2 (hence SSSE3 for the tail
        // call); unaligned loads/stores stay in bounds because every
        // offset is < chunks*32 <= len.
        unsafe {
            let lo_tbl =
                _mm256_broadcastsi128_si256(_mm_loadu_si128(self.lo.as_ptr() as *const __m128i));
            let hi_tbl =
                _mm256_broadcastsi128_si256(_mm_loadu_si128(self.hi.as_ptr() as *const __m128i));
            let mask = _mm256_set1_epi8(0x0F);
            for i in 0..chunks {
                let xv = _mm256_loadu_si256(xp.add(i * 32) as *const __m256i);
                let lo_idx = _mm256_and_si256(xv, mask);
                let hi_idx = _mm256_and_si256(_mm256_srli_epi64(xv, 4), mask);
                let prod = _mm256_xor_si256(
                    _mm256_shuffle_epi8(lo_tbl, lo_idx),
                    _mm256_shuffle_epi8(hi_tbl, hi_idx),
                );
                _mm256_storeu_si256(yp.add(i * 32) as *mut __m256i, prod);
            }
            self.mul_slice_set_ssse3(&x[done..], &mut y[done..]);
        }
    }
}

/// y ^= c * x without a precomputed table (used on cold paths).
pub fn mul_slice_add(c: u8, x: &[u8], y: &mut [u8]) {
    if c == 0 {
        return;
    }
    if c == 1 {
        for (yi, &xi) in y.iter_mut().zip(x.iter()) {
            *yi ^= xi;
        }
        return;
    }
    MulTable::new(c).mul_slice_add(x, y);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Slow bit-by-bit ("Russian peasant") reference multiply.
    fn mul_ref(mut a: u8, mut b: u8) -> u8 {
        let mut p = 0u8;
        while b != 0 {
            if b & 1 != 0 {
                p ^= a;
            }
            let hi = a & 0x80 != 0;
            a <<= 1;
            if hi {
                a ^= (POLY & 0xFF) as u8;
            }
            b >>= 1;
        }
        p
    }

    #[test]
    fn mul_matches_reference_everywhere() {
        for a in 0..=255u8 {
            for b in 0..=255u8 {
                assert_eq!(mul(a, b), mul_ref(a, b), "a={a} b={b}");
            }
        }
    }

    #[test]
    fn field_axioms_hold() {
        for a in 1..=255u8 {
            assert_eq!(mul(a, inv(a)), 1, "a={a}");
            assert_eq!(mul(a, 1), a);
            assert_eq!(mul(a, 0), 0);
            assert_eq!(div(a, a), 1);
        }
        // Associativity + distributivity over all triples on a stride
        // (the full randomized sweep lives in tests/erasure_props.rs).
        for a in (0..=255u8).step_by(7) {
            for b in (0..=255u8).step_by(11) {
                for c in (0..=255u8).step_by(13) {
                    assert_eq!(mul(a, add(b, c)), add(mul(a, b), mul(a, c)));
                    assert_eq!(mul(mul(a, b), c), mul(a, mul(b, c)));
                    assert_eq!(add(add(a, b), c), add(a, add(b, c)));
                }
            }
        }
    }

    #[test]
    fn pow_consistent_with_mul() {
        for a in 1..=255u8 {
            let mut acc = 1u8;
            for n in 0..=8u64 {
                assert_eq!(pow(a, n), acc, "a={a} n={n}");
                acc = mul(acc, a);
            }
            assert_eq!(pow(a, 255), 1, "Fermat: a^255 = 1");
        }
    }

    #[test]
    fn generator_has_full_order() {
        let mut seen = [false; 256];
        let mut x = 1u8;
        for _ in 0..255 {
            assert!(!seen[x as usize], "generator order < 255");
            seen[x as usize] = true;
            x = mul(x, 2);
        }
        assert_eq!(x, 1);
    }

    #[test]
    fn mul_table_matches_mul() {
        for c in [0u8, 1, 2, 3, 0x53, 0xCA, 0xFF] {
            let t = MulTable::new(c);
            let x: Vec<u8> = (0..=255).collect();
            let mut y = vec![0u8; 256];
            t.mul_slice(&x, &mut y);
            for (i, &yi) in y.iter().enumerate() {
                assert_eq!(yi, mul(c, i as u8), "c={c} x={i}");
            }
            // mul_slice_add accumulates.
            let mut z = y.clone();
            t.mul_slice_add(&x, &mut z);
            assert!(z.iter().all(|&b| b == 0), "y ^ y must be zero");
        }
    }

    #[test]
    fn mul_slice_add_special_cases() {
        let x = [1u8, 2, 3, 4];
        let mut y = [0u8; 4];
        mul_slice_add(0, &x, &mut y);
        assert_eq!(y, [0, 0, 0, 0]);
        mul_slice_add(1, &x, &mut y);
        assert_eq!(y, x);
    }

    #[test]
    fn slice_kernels_agree_across_tiers() {
        use crate::erasure::kernel::{supported_tiers, KernelTier};
        for c in [0u8, 1, 0x8E, 0xFF] {
            let t = MulTable::new(c);
            for len in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 100] {
                let x: Vec<u8> = (0..len).map(|i| (i * 37 + 11) as u8).collect();
                let mut want = vec![0u8; len];
                t.mul_slice_tier(&x, &mut want, KernelTier::Scalar);
                let mut acc_want = x.clone();
                t.mul_slice_add_tier(&x, &mut acc_want, KernelTier::Scalar);
                for tier in supported_tiers() {
                    let mut got = vec![0xEEu8; len];
                    t.mul_slice_tier(&x, &mut got, tier);
                    assert_eq!(got, want, "set c={c} len={len} tier={tier}");
                    let mut acc_got = x.clone();
                    t.mul_slice_add_tier(&x, &mut acc_got, tier);
                    assert_eq!(acc_got, acc_want, "add c={c} len={len} tier={tier}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "inverse of zero")]
    fn inv_zero_panics() {
        inv(0);
    }
}
