//! Pluggable erasure backends — the trait seam between the transfer
//! engines and the coding math.
//!
//! PR 1–8 hard-wired [`RsCode`] into every engine: the arenas, the
//! coding pool, and the batch entry points all named the concrete type.
//! [`ErasureBackend`] extracts the surface those layers actually use —
//! group geometry, strided-arena encode, group reconstruct, and the
//! deterministic batch entry points — so [`FtgArena`]/[`CodingPool`]
//! plumbing stays backend-agnostic while backends differ in *how*
//! redundancy is produced:
//!
//! * [`RsCode`] — fixed-rate systematic Reed–Solomon: `m` parity
//!   fragments planned per pass, repaired through the pass-barrier
//!   LostList exchange.
//! * [`crate::erasure::fountain::LtCode`] — rateless LT: zero planned
//!   parity, an unbounded stream of seeded XOR symbols repaired with
//!   compact cumulative acks and no barriers (DESIGN.md §12).
//!
//! The enum [`Backend`] is the user-facing selector
//! (`TransferSpecBuilder::backend`); `Backend::Rs` is the default and
//! keeps every legacy trace byte-identical.

use super::par::CodingPool;
use super::rs::{RsCode, RsError};
use crate::coordinator::arena::FtgArena;

/// User-facing backend selector (see
/// [`crate::api::TransferSpecBuilder::backend`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// Systematic Reed–Solomon with pass-barrier repair (the paper's
    /// design; the default — legacy traces stay byte-identical).
    #[default]
    Rs,
    /// LT-style rateless fountain: barrier-free repair streaming.
    Fountain,
}

/// The coding surface the transfer engines consume.
///
/// `encode_*` methods take `&self` (pure math, safe to share across the
/// pool's workers); `reconstruct_group` takes `&mut self` because
/// backends may keep per-code decode state (the RS inverted-matrix LRU).
pub trait ErasureBackend {
    /// Data fragments per group (`k`).
    fn data_fragments(&self) -> usize;

    /// Planned parity fragments per group (`m`; 0 for rateless backends,
    /// whose repair symbols are generated on demand instead).
    fn parity_fragments(&self) -> usize;

    /// Slots a group arena carries (`k + m`).
    fn group_slots(&self) -> usize {
        self.data_fragments() + self.parity_fragments()
    }

    /// Fill the parity slots of a strided group buffer (`k` data slots
    /// then `m` parity slots, each `stride` bytes) in place.
    fn encode_strided(&self, buf: &mut [u8], stride: usize) -> Result<(), RsError>;

    /// Encode a batch of arenas, optionally fanned out over `pool`.
    /// Contract (inherited from [`RsCode::encode_batch`]): byte-identical
    /// output for any worker count, including zero.
    fn encode_batch(&self, pool: &CodingPool, arenas: &mut [FtgArena]) -> Result<(), RsError>
    where
        Self: Sized,
    {
        let _ = pool;
        for arena in arenas.iter_mut() {
            arena.encode_parity(self)?;
        }
        Ok(())
    }

    /// Reconstruct a group's `k` data fragments from any decodable shard
    /// set into one contiguous output buffer.
    fn reconstruct_group(
        &mut self,
        shards: &[(usize, &[u8])],
        out: &mut [u8],
    ) -> Result<(), RsError>;

    /// Reconstruct a batch of groups, optionally fanned out over `pool`,
    /// returning one result per item. Same any-worker-count determinism
    /// contract as [`ErasureBackend::encode_batch`].
    fn reconstruct_batch(
        &self,
        pool: &CodingPool,
        items: &mut [(&FtgArena, &mut [u8])],
    ) -> Vec<Result<(), RsError>>;
}

impl ErasureBackend for RsCode {
    fn data_fragments(&self) -> usize {
        self.k
    }

    fn parity_fragments(&self) -> usize {
        self.m
    }

    fn encode_strided(&self, buf: &mut [u8], stride: usize) -> Result<(), RsError> {
        RsCode::encode_strided(self, buf, stride)
    }

    fn encode_batch(&self, pool: &CodingPool, arenas: &mut [FtgArena]) -> Result<(), RsError> {
        RsCode::encode_batch(self, pool, arenas)
    }

    fn reconstruct_group(
        &mut self,
        shards: &[(usize, &[u8])],
        out: &mut [u8],
    ) -> Result<(), RsError> {
        self.reconstruct_into(shards, out)
    }

    fn reconstruct_batch(
        &self,
        pool: &CodingPool,
        items: &mut [(&FtgArena, &mut [u8])],
    ) -> Vec<Result<(), RsError>> {
        RsCode::reconstruct_batch(self, pool, items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive an arena through the trait object-agnostic surface and
    /// check it matches the concrete RS path bit for bit.
    fn encode_both_ways(k: u8, m: u8, s: usize) -> (Vec<u8>, Vec<u8>) {
        let code = RsCode::new(k as usize, m as usize).unwrap();
        let data: Vec<u8> = (0..k as usize * s).map(|i| (i * 31 % 251) as u8).collect();

        let mut direct = FtgArena::new(k, m, s);
        direct.fill_data(&data, 0);
        direct.encode_parity(&code).unwrap();

        let mut via_trait = FtgArena::new(k, m, s);
        via_trait.fill_data(&data, 0);
        let backend: &dyn ErasureBackend = &code;
        assert_eq!(backend.data_fragments(), k as usize);
        assert_eq!(backend.parity_fragments(), m as usize);
        assert_eq!(backend.group_slots(), (k + m) as usize);
        let stride = via_trait.stride();
        backend.encode_strided(via_trait.as_mut_slice(), stride).unwrap();

        (direct.as_slice().to_vec(), via_trait.as_slice().to_vec())
    }

    #[test]
    fn trait_encode_matches_concrete_rs() {
        for (k, m) in [(4u8, 2u8), (24, 8), (31, 1)] {
            let (a, b) = encode_both_ways(k, m, 64);
            assert_eq!(a, b, "k={k} m={m}");
        }
    }

    #[test]
    fn trait_reconstruct_matches_concrete_rs() {
        let (k, m, s) = (6usize, 3usize, 48usize);
        let mut code = RsCode::new(k, m).unwrap();
        let data: Vec<u8> = (0..k * s).map(|i| (i * 17 % 239) as u8).collect();
        let mut arena = FtgArena::new(k as u8, m as u8, s);
        arena.fill_data(&data, 0);
        arena.encode_parity(&code).unwrap();

        // Drop three data fragments, keep parity.
        let shards: Vec<(usize, &[u8])> =
            arena.iter_present().filter(|&(i, _)| !(1..=3).contains(&i)).collect();
        let mut out = vec![0u8; k * s];
        let backend: &mut dyn ErasureBackend = &mut code;
        backend.reconstruct_group(&shards, &mut out).unwrap();
        assert_eq!(out, data);
    }
}
