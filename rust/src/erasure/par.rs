//! Fixed-size std-thread worker pool for erasure-coding compute.
//!
//! Zero new dependencies: plain `std::thread` workers draining a
//! `Mutex<VecDeque>` of boxed jobs behind a condvar. Two submission
//! modes:
//!
//! * [`CodingPool::spawn`] — fire-and-forget `'static` jobs. Used by the
//!   `janus serve` daemon, which moves a machine's coding job into the
//!   closure and gets it back through its own completion queue.
//! * [`CodingPool::run_batch`] — scoped borrowed jobs. Blocks until every
//!   job in the batch has executed; while waiting, the *caller* also
//!   drains the pool queue, so a batch always completes even on a pool
//!   with zero workers (the caller is the worker). This is what
//!   `RsCode::encode_batch` / `reconstruct_batch` ride on.
//!
//! Determinism contract: jobs are pure compute on disjoint buffers —
//! which thread runs a job affects only timing, never bytes. Encoding a
//! batch of FTG arenas through the pool is byte-identical to encoding
//! them sequentially, for any worker count (asserted for 0/1/2/8 workers
//! by `rust/tests/erasure_props.rs`).
//!
//! A panicking job poisons its batch: the panic is caught on the worker
//! (keeping the thread alive for other tenants), recorded on the batch
//! latch, and re-raised on the submitting thread when the batch drains.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

/// Count-down latch completing one batch of [`CodingPool::run_batch`].
struct Latch {
    /// (jobs still outstanding, some job panicked).
    state: Mutex<(usize, bool)>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { state: Mutex::new((n, false)), cv: Condvar::new() }
    }

    fn complete(&self, ok: bool) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        if !ok {
            st.1 = true;
        }
        if st.0 == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every job has executed; true when one panicked.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.1
    }
}

/// Fixed worker pool for encode/decode compute (see module docs).
pub struct CodingPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl CodingPool {
    /// Spawn `workers` threads. Zero is legal: [`CodingPool::spawn`] jobs
    /// then only run when a [`CodingPool::run_batch`] caller drains the
    /// queue, so pools that might receive fire-and-forget jobs should
    /// have at least one worker.
    pub fn new(workers: usize) -> CodingPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState { jobs: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("janus-coding-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn coding worker")
            })
            .collect();
        CodingPool { shared, workers: handles }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue one fire-and-forget job.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.jobs.push_back(Box::new(job));
        }
        self.shared.work_cv.notify_one();
    }

    /// Run a batch of borrowed jobs to completion (see module docs for
    /// the caller-drains + determinism contract). Panics if any job
    /// panicked.
    #[allow(clippy::type_complexity)]
    pub fn run_batch<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if jobs.is_empty() {
            return;
        }
        let latch = Arc::new(Latch::new(jobs.len()));
        {
            let mut st = self.shared.state.lock().unwrap();
            for job in jobs {
                // SAFETY: the latch is only released once the wrapped
                // closure has run (or panicked), and `run_batch` does not
                // return until the latch drains — every borrow captured
                // by `job` strictly outlives its execution.
                let job: Job = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'scope>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(job)
                };
                let l = Arc::clone(&latch);
                st.jobs.push_back(Box::new(move || {
                    let ok = catch_unwind(AssertUnwindSafe(job)).is_ok();
                    l.complete(ok);
                }));
            }
        }
        self.shared.work_cv.notify_all();
        // Help drain: the submitting thread works the queue until it is
        // empty, then waits for in-flight jobs. Correct at 0 workers.
        loop {
            let job = { self.shared.state.lock().unwrap().jobs.pop_front() };
            match job {
                Some(j) => j(),
                None => break,
            }
        }
        if latch.wait() {
            panic!("coding pool: a batch job panicked");
        }
    }
}

impl Drop for CodingPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    break Some(j);
                }
                if st.shutdown {
                    break None;
                }
                st = sh.work_cv.wait(st).unwrap();
            }
        };
        match job {
            // Batch jobs catch panics themselves; spawn() jobs are pure
            // compute closures built by this crate and must not panic —
            // a stray panic here only kills this worker, not the pool.
            Some(j) => j(),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_batch_executes_every_job_even_with_zero_workers() {
        for workers in [0usize, 1, 3] {
            let pool = CodingPool::new(workers);
            let hits = AtomicUsize::new(0);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..17)
                .map(|_| {
                    Box::new(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_batch(jobs);
            assert_eq!(hits.load(Ordering::SeqCst), 17, "workers={workers}");
        }
    }

    #[test]
    fn spawn_jobs_complete_before_drop_joins() {
        let pool = CodingPool::new(2);
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            let h = Arc::clone(&hits);
            pool.spawn(move || {
                h.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // Drop drains the queue, then joins.
        assert_eq!(hits.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn batch_jobs_can_mutate_borrowed_disjoint_buffers() {
        let pool = CodingPool::new(2);
        let mut bufs = vec![vec![0u8; 64]; 9];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = bufs
            .iter_mut()
            .enumerate()
            .map(|(i, b)| {
                Box::new(move || b.fill(i as u8 + 1)) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_batch(jobs);
        for (i, b) in bufs.iter().enumerate() {
            assert!(b.iter().all(|&x| x == i as u8 + 1), "buffer {i}");
        }
    }

    #[test]
    #[should_panic(expected = "a batch job panicked")]
    fn panicking_batch_job_propagates_to_submitter() {
        let pool = CodingPool::new(1);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| {}),
            Box::new(|| panic!("boom")),
            Box::new(|| {}),
        ];
        pool.run_batch(jobs);
    }
}
