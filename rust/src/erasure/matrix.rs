//! Dense matrices over GF(2^8) and the systematic RS generator matrix.
//!
//! The code is MDS: the generator is built from an extended Vandermonde
//! matrix reduced so its top k×k block is the identity (systematic form),
//! guaranteeing any k rows of the n×k generator are invertible.

use super::gf256 as gf;

/// Row-major dense matrix over GF(256).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    data: Vec<u8>,
}

impl Matrix {
    pub fn zero(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zero(n, n);
        for i in 0..n {
            m[(i, i)] = 1;
        }
        m
    }

    pub fn from_rows(rows: &[&[u8]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut m = Matrix::zero(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.row_mut(i).copy_from_slice(row);
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[u8] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [u8] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix product over GF(256).
    pub fn mul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "dimension mismatch");
        let mut out = Matrix::zero(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] ^= gf::mul(a, other[(k, j)]);
                }
            }
        }
        out
    }

    /// Select a subset of rows.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zero(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Invert via Gauss–Jordan elimination. Returns None when singular.
    pub fn inverse(&self) -> Option<Matrix> {
        assert_eq!(self.rows, self.cols, "inverse of non-square matrix");
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = Matrix::identity(n);
        for col in 0..n {
            // Find pivot.
            let pivot = (col..n).find(|&r| a[(r, col)] != 0)?;
            if pivot != col {
                a.swap_rows(pivot, col);
                inv.swap_rows(pivot, col);
            }
            // Normalize pivot row.
            let p = a[(col, col)];
            let pinv = gf::inv(p);
            for j in 0..n {
                a[(col, j)] = gf::mul(a[(col, j)], pinv);
                inv[(col, j)] = gf::mul(inv[(col, j)], pinv);
            }
            // Eliminate all other rows.
            for r in 0..n {
                if r == col || a[(r, col)] == 0 {
                    continue;
                }
                let f = a[(r, col)];
                for j in 0..n {
                    let av = gf::mul(f, a[(col, j)]);
                    let iv = gf::mul(f, inv[(col, j)]);
                    a[(r, j)] ^= av;
                    inv[(r, j)] ^= iv;
                }
            }
        }
        Some(inv)
    }

    fn swap_rows(&mut self, i: usize, j: usize) {
        if i == j {
            return;
        }
        for c in 0..self.cols {
            self.data.swap(i * self.cols + c, j * self.cols + c);
        }
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = u8;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &u8 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut u8 {
        &mut self.data[r * self.cols + c]
    }
}

/// Extended Vandermonde matrix: n rows, k cols, entry (i, j) = i^j
/// (with 0^0 = 1).
pub fn vandermonde(n: usize, k: usize) -> Matrix {
    assert!(n <= 256, "GF(256) supports at most 256 distinct rows");
    let mut m = Matrix::zero(n, k);
    for i in 0..n {
        for j in 0..k {
            m[(i, j)] = gf::pow(i as u8, j as u64);
        }
    }
    m
}

/// Systematic n×k generator matrix: top k×k block is the identity, the
/// remaining m = n−k rows are the parity rows. Any k rows are linearly
/// independent (MDS property), proven by construction from Vandermonde.
pub fn systematic_generator(n: usize, k: usize) -> Matrix {
    assert!(k >= 1 && n >= k, "need n >= k >= 1");
    let v = vandermonde(n, k);
    let top = v.select_rows(&(0..k).collect::<Vec<_>>());
    let top_inv = top
        .inverse()
        .expect("Vandermonde top block is always invertible");
    // G = V * top^{-1} has identity in the first k rows.
    v.mul(&top_inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn identity_is_self_inverse() {
        let i = Matrix::identity(8);
        assert_eq!(i.inverse().unwrap(), i);
    }

    #[test]
    fn inverse_roundtrip_random() {
        let mut rng = Pcg64::seeded(21);
        for _ in 0..50 {
            let n = rng.range(1, 12);
            let mut m = Matrix::zero(n, n);
            loop {
                for r in 0..n {
                    for c in 0..n {
                        m[(r, c)] = rng.next_below(256) as u8;
                    }
                }
                if m.inverse().is_some() {
                    break;
                }
            }
            let inv = m.inverse().unwrap();
            assert_eq!(m.mul(&inv), Matrix::identity(n));
            assert_eq!(inv.mul(&m), Matrix::identity(n));
        }
    }

    #[test]
    fn singular_matrix_returns_none() {
        let m = Matrix::from_rows(&[&[1, 2], &[1, 2]]);
        assert!(m.inverse().is_none());
        let z = Matrix::zero(3, 3);
        assert!(z.inverse().is_none());
    }

    #[test]
    fn systematic_generator_top_is_identity() {
        for (n, k) in [(6, 4), (32, 16), (32, 31), (4, 1)] {
            let g = systematic_generator(n, k);
            let top = g.select_rows(&(0..k).collect::<Vec<_>>());
            assert_eq!(top, Matrix::identity(k), "n={n} k={k}");
        }
    }

    #[test]
    fn any_k_rows_invertible_mds() {
        // Exhaustive over a small code, randomized over the paper's n=32.
        let g = systematic_generator(8, 4);
        let idx: Vec<usize> = (0..8).collect();
        // All C(8,4)=70 subsets.
        fn combos(n: usize, k: usize) -> Vec<Vec<usize>> {
            if k == 0 {
                return vec![vec![]];
            }
            if n < k {
                return vec![];
            }
            let mut out = combos(n - 1, k);
            for mut c in combos(n - 1, k - 1) {
                c.push(n - 1);
                out.push(c);
            }
            out
        }
        for subset in combos(idx.len(), 4) {
            let sub = g.select_rows(&subset);
            assert!(sub.inverse().is_some(), "rows {subset:?} singular");
        }
        let g32 = systematic_generator(32, 16);
        let mut rng = Pcg64::seeded(33);
        for _ in 0..200 {
            let rows = rng.sample_indices(32, 16);
            assert!(g32.select_rows(&rows).inverse().is_some(), "rows {rows:?}");
        }
    }

    #[test]
    fn vandermonde_values() {
        let v = vandermonde(4, 3);
        assert_eq!(v[(0, 0)], 1); // 0^0
        assert_eq!(v[(0, 1)], 0);
        assert_eq!(v[(2, 1)], 2);
        assert_eq!(v[(3, 2)], gf::mul(3, 3));
    }

    #[test]
    fn mul_dimensions_and_identity() {
        let mut rng = Pcg64::seeded(4);
        let mut m = Matrix::zero(3, 5);
        for r in 0..3 {
            for c in 0..5 {
                m[(r, c)] = rng.next_below(256) as u8;
            }
        }
        assert_eq!(Matrix::identity(3).mul(&m), m);
        assert_eq!(m.mul(&Matrix::identity(5)), m);
    }
}
