//! Crate-wide GF(256) kernel dispatch and fused multi-row coding kernels.
//!
//! Two jobs (DESIGN.md §11):
//!
//! 1. **Dispatch-once tier selection.** CPU features are resolved exactly
//!    once per process into a [`KernelTier`] ([`active`], a `OnceLock`) —
//!    the per-call `is_x86_feature_detected!` that used to sit inside
//!    every `mul_slice*` is gone from the hot path. The env override
//!    `JANUS_GF_KERNEL=scalar|ssse3|avx2|auto` forces a tier for tests
//!    and CI lanes; a request the CPU cannot honor is clamped down to the
//!    best supported tier, never up.
//!
//! 2. **Fused multi-row kernels.** [`mul_matrix_strided`] / [`mul_matrix`]
//!    apply *all* output rows of a coefficient matrix to each source
//!    fragment while the source chunk is hot in registers (the ISA-L
//!    `gf_vect_mad` shape): per 16/32-byte chunk the two nibble indices
//!    are computed once and reused across a band of up to [`BAND`] output
//!    rows, so every source byte is loaded (and its nibbles extracted)
//!    once per band instead of once per parity row. Outputs are
//!    write-once: the first source term overwrites, later terms
//!    accumulate — callers never pre-zero.
//!
//! Safety argument for the `unsafe` blocks: the SIMD paths are only
//! reachable after `is_x86_feature_detected!` has confirmed the feature
//! (clamping), every pointer handed to [`mul_matrix_raw`] is derived from
//! a live slice of at least `len` bytes, sources and outputs come from
//! disjoint borrows (`&[u8]` vs `&mut [u8]`, or `split_at_mut` halves),
//! and the vector loops stop at `len / width` with a scalar tail — no
//! read or write ever crosses `len`. All tiers compute the identical
//! bytes (exact field arithmetic), asserted tier-against-tier by
//! `rust/tests/erasure_props.rs`.

use super::gf256::MulTable;
use std::sync::OnceLock;

/// A GF(256) kernel implementation tier, in increasing order of width.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum KernelTier {
    /// Portable split-nibble table loop (any CPU).
    Scalar,
    /// 16-byte `pshufb` nibble lookups (x86-64 SSSE3).
    Ssse3,
    /// 32-byte `vpshufb` nibble lookups (x86-64 AVX2).
    Avx2,
}

impl KernelTier {
    /// Stable lower-case name (matches the `JANUS_GF_KERNEL` values).
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Ssse3 => "ssse3",
            KernelTier::Avx2 => "avx2",
        }
    }

    /// This tier, lowered to the best the CPU actually supports.
    #[inline]
    pub fn clamp(self) -> KernelTier {
        self.min(best_supported())
    }
}

impl std::fmt::Display for KernelTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Best tier this CPU supports (`is_x86_feature_detected!` caches the
/// CPUID result internally; this is cheap but not free — hot paths go
/// through [`active`] instead).
pub fn best_supported() -> KernelTier {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return KernelTier::Avx2;
        }
        if is_x86_feature_detected!("ssse3") {
            return KernelTier::Ssse3;
        }
    }
    KernelTier::Scalar
}

/// Every tier this CPU can run, ascending (always starts with Scalar).
pub fn supported_tiers() -> Vec<KernelTier> {
    let mut tiers = vec![KernelTier::Scalar];
    if best_supported() >= KernelTier::Ssse3 {
        tiers.push(KernelTier::Ssse3);
    }
    if best_supported() >= KernelTier::Avx2 {
        tiers.push(KernelTier::Avx2);
    }
    tiers
}

static ACTIVE: OnceLock<KernelTier> = OnceLock::new();

/// The process-wide kernel tier, resolved exactly once: CPU detection,
/// overridden by `JANUS_GF_KERNEL=scalar|ssse3|avx2` (an unsupported or
/// unknown value, or `auto`, falls back to CPU-best). All dispatching
/// call sites branch on this cached value — no feature detection in any
/// per-call path.
pub fn active() -> KernelTier {
    *ACTIVE.get_or_init(|| {
        let req = match std::env::var("JANUS_GF_KERNEL") {
            Ok(v) => match v.to_ascii_lowercase().as_str() {
                "scalar" => Some(KernelTier::Scalar),
                "ssse3" => Some(KernelTier::Ssse3),
                "avx2" => Some(KernelTier::Avx2),
                _ => None,
            },
            Err(_) => None,
        };
        req.unwrap_or_else(best_supported).clamp()
    })
}

/// Output rows fused per band: four accumulator/table-pair sets fit the
/// 16 architectural vector registers alongside the source chunk and the
/// nibble mask without spilling.
pub const BAND: usize = 4;

// lint: datapath — the fused coding kernels run per fragment on the
// hot path; everything below until the end marker must stay free of
// heap allocation (rule `datapath-no-alloc`, DESIGN.md §13).

/// Fused matrix-vector product over equal-length byte fragments:
/// `outs[p] = Σ_j tables[p][j] · srcs[j]` (write-once — no pre-zeroing
/// of `outs` required; with no sources the outputs are zeroed).
///
/// Uses the process-wide tier ([`active`]).
pub fn mul_matrix(tables: &[Vec<MulTable>], srcs: &[&[u8]], outs: &mut [&mut [u8]]) {
    mul_matrix_tier(tables, srcs, outs, active());
}

/// [`mul_matrix`] on a forced tier (clamped to CPU support) — the
/// tier-sweeping entry point for tests and benches.
pub fn mul_matrix_tier(
    tables: &[Vec<MulTable>],
    srcs: &[&[u8]],
    outs: &mut [&mut [u8]],
    tier: KernelTier,
) {
    let m = outs.len();
    assert_eq!(tables.len(), m, "one table row per output");
    if m == 0 {
        return;
    }
    if srcs.is_empty() {
        for out in outs.iter_mut() {
            out.fill(0);
        }
        return;
    }
    let len = outs[0].len();
    for src in srcs {
        assert_eq!(src.len(), len, "source length mismatch");
    }
    for out in outs.iter() {
        assert_eq!(out.len(), len, "output length mismatch");
    }
    for row in tables {
        assert!(row.len() >= srcs.len(), "table row shorter than sources");
    }
    assert!(srcs.len() <= 256 && m <= 256, "GF(256) codes have n <= 256");
    let mut sp = [std::ptr::null::<u8>(); 256];
    let mut op = [std::ptr::null_mut::<u8>(); 256];
    for (j, src) in srcs.iter().enumerate() {
        sp[j] = src.as_ptr();
    }
    for (p, out) in outs.iter_mut().enumerate() {
        op[p] = out.as_mut_ptr();
    }
    // SAFETY: every pointer covers `len` bytes of a live slice; `srcs`
    // and `outs` are disjoint by borrow rules; tier is clamped.
    unsafe { mul_matrix_raw(tables, &sp[..srcs.len()], &op[..m], len, tier.clamp()) }
}

/// Fused product from referenced sources into one contiguous strided
/// output: `out[p·len..(p+1)·len] = Σ_j tables[p][j] · srcs[j]` for
/// `p < tables.len()`, write-once. The decode shape: survivors live in
/// scattered fragments, the reconstruction lands in one strided buffer.
/// Allocation-free.
pub fn mul_matrix_into_strided_tier(
    tables: &[Vec<MulTable>],
    srcs: &[&[u8]],
    out: &mut [u8],
    len: usize,
    tier: KernelTier,
) {
    let m = tables.len();
    assert_eq!(out.len(), m * len, "output must hold tables.len() rows of len bytes");
    if m == 0 {
        return;
    }
    if srcs.is_empty() {
        out.fill(0);
        return;
    }
    for src in srcs {
        assert_eq!(src.len(), len, "source length mismatch");
    }
    for row in tables {
        assert!(row.len() >= srcs.len(), "table row shorter than sources");
    }
    assert!(srcs.len() <= 256 && m <= 256, "GF(256) codes have n <= 256");
    if len == 0 {
        return;
    }
    let mut sp = [std::ptr::null::<u8>(); 256];
    let mut op = [std::ptr::null_mut::<u8>(); 256];
    for (j, src) in srcs.iter().enumerate() {
        sp[j] = src.as_ptr();
    }
    let out_base = out.as_mut_ptr();
    for (p, slot) in op.iter_mut().enumerate().take(m) {
        *slot = out_base.wrapping_add(p * len);
    }
    // SAFETY: `out` holds m·len bytes (asserted), so the row windows are
    // disjoint and in-bounds; `srcs` are live shared borrows disjoint
    // from the `out` mutable borrow; tier is clamped.
    unsafe { mul_matrix_raw(tables, &sp[..srcs.len()], &op[..m], len, tier.clamp()) }
}

/// Variant of [`mul_matrix`] writing into owned `Vec<u8>` outputs (the
/// `encode_into` shape) without collecting a slice of references —
/// keeps that path allocation-free. Every output must already have the
/// sources' length.
pub fn mul_matrix_into_vecs_tier(
    tables: &[Vec<MulTable>],
    srcs: &[&[u8]],
    outs: &mut [Vec<u8>],
    tier: KernelTier,
) {
    let m = outs.len();
    assert_eq!(tables.len(), m, "one table row per output");
    if m == 0 {
        return;
    }
    if srcs.is_empty() {
        for out in outs.iter_mut() {
            out.fill(0);
        }
        return;
    }
    let len = srcs[0].len();
    for src in srcs {
        assert_eq!(src.len(), len, "source length mismatch");
    }
    for out in outs.iter() {
        assert_eq!(out.len(), len, "output length mismatch");
    }
    for row in tables {
        assert!(row.len() >= srcs.len(), "table row shorter than sources");
    }
    assert!(srcs.len() <= 256 && m <= 256, "GF(256) codes have n <= 256");
    let mut sp = [std::ptr::null::<u8>(); 256];
    let mut op = [std::ptr::null_mut::<u8>(); 256];
    for (j, src) in srcs.iter().enumerate() {
        sp[j] = src.as_ptr();
    }
    for (p, out) in outs.iter_mut().enumerate() {
        op[p] = out.as_mut_ptr();
    }
    // SAFETY: each output Vec holds `len` bytes (asserted); distinct
    // Vecs never alias, nor do they alias the shared `srcs` borrows;
    // tier is clamped.
    unsafe { mul_matrix_raw(tables, &sp[..srcs.len()], &op[..m], len, tier.clamp()) }
}

/// Fused strided encode over an arena-layout buffer: `buf` holds `k`
/// source fragments followed by `tables.len()` output fragments, each
/// `stride` bytes. Computes `out[p] = Σ_j tables[p][j] · data[j]`
/// write-once (the output region is never pre-zeroed, and is fully
/// overwritten). Allocation-free — the pointer gather lives on the
/// stack, which is what keeps `encode_strided` on the sender's
/// zero-allocation datapath (`rust/tests/alloc_datapath.rs`).
pub fn mul_matrix_strided(tables: &[Vec<MulTable>], buf: &mut [u8], k: usize, stride: usize) {
    mul_matrix_strided_tier(tables, buf, k, stride, active());
}

/// [`mul_matrix_strided`] on a forced tier (clamped to CPU support).
pub fn mul_matrix_strided_tier(
    tables: &[Vec<MulTable>],
    buf: &mut [u8],
    k: usize,
    stride: usize,
    tier: KernelTier,
) {
    let m = tables.len();
    assert!(buf.len() >= (k + m) * stride, "buffer shorter than (k+m)·stride");
    assert!(k <= 256 && m <= 256, "GF(256) codes have n <= 256");
    if m == 0 || stride == 0 {
        return;
    }
    let (data, parity) = buf.split_at_mut(k * stride);
    if k == 0 {
        parity[..m * stride].fill(0);
        return;
    }
    for row in tables {
        assert!(row.len() >= k, "table row shorter than sources");
    }
    let mut sp = [std::ptr::null::<u8>(); 256];
    let mut op = [std::ptr::null_mut::<u8>(); 256];
    let data_base = data.as_ptr();
    let parity_base = parity.as_mut_ptr();
    for (j, slot) in sp.iter_mut().enumerate().take(k) {
        *slot = data_base.wrapping_add(j * stride);
    }
    for (p, slot) in op.iter_mut().enumerate().take(m) {
        *slot = parity_base.wrapping_add(p * stride);
    }
    // SAFETY: `data` holds k·stride bytes and `parity` at least m·stride
    // (asserted above), so every row pointer covers `stride` bytes; the
    // two `split_at_mut` halves cannot alias; rows within each half are
    // disjoint `stride`-sized windows; tier is clamped.
    unsafe { mul_matrix_raw(tables, &sp[..k], &op[..m], stride, tier.clamp()) }
}

/// Fused core over raw fragment pointers.
///
/// # Safety
/// Every pointer in `srcs`/`outs` must be valid for `len` bytes; the
/// `outs` regions must not overlap each other or any `srcs` region;
/// `tier` must be supported by the CPU; `tables[p][j]` must exist for
/// every `p < outs.len()`, `j < srcs.len()`.
unsafe fn mul_matrix_raw(
    tables: &[Vec<MulTable>],
    srcs: &[*const u8],
    outs: &[*mut u8],
    len: usize,
    tier: KernelTier,
) {
    debug_assert_eq!(tables.len(), outs.len());
    let mut band_start = 0;
    while band_start < outs.len() {
        let band_end = (band_start + BAND).min(outs.len());
        // SAFETY: forwarding the caller's contract verbatim; the band
        // kernels touch only rows b0..b1 and bytes 0..len of each.
        unsafe {
            match tier {
                #[cfg(target_arch = "x86_64")]
                KernelTier::Avx2 => band_avx2(tables, srcs, outs, len, band_start, band_end),
                #[cfg(target_arch = "x86_64")]
                KernelTier::Ssse3 => band_ssse3(tables, srcs, outs, len, band_start, band_end),
                _ => band_scalar(tables, srcs, outs, len, band_start, band_end),
            }
        }
        band_start = band_end;
    }
}

/// Scalar fused band: nibbles of each source byte are extracted once and
/// applied to every row in the band.
///
/// # Safety
/// See [`mul_matrix_raw`].
unsafe fn band_scalar(
    tables: &[Vec<MulTable>],
    srcs: &[*const u8],
    outs: &[*mut u8],
    len: usize,
    b0: usize,
    b1: usize,
) {
    let nb = b1 - b0;
    for (j, &x) in srcs.iter().enumerate() {
        let first = j == 0;
        let mut tabs: [&MulTable; BAND] = [&tables[b0][j]; BAND];
        let mut ys: [*mut u8; BAND] = [outs[b0]; BAND];
        for (bi, p) in (b0..b1).enumerate() {
            tabs[bi] = &tables[p][j];
            ys[bi] = outs[p];
        }
        // SAFETY: `x` and every `ys[bi]` cover `len` bytes and the
        // output rows are disjoint (caller contract), so each `add(i)`
        // with i < len is in bounds and writes never alias reads.
        unsafe {
            for i in 0..len {
                let xi = *x.add(i);
                let lo = (xi & 0x0F) as usize;
                let hi = (xi >> 4) as usize;
                for bi in 0..nb {
                    let prod = tabs[bi].lo[lo] ^ tabs[bi].hi[hi];
                    if first {
                        *ys[bi].add(i) = prod;
                    } else {
                        *ys[bi].add(i) ^= prod;
                    }
                }
            }
        }
    }
}

/// SSSE3 fused band: the band's split-nibble tables stay in xmm
/// registers across the whole stride; each 16-byte source chunk is
/// loaded and nibble-split once, then `pshufb`-multiplied into every
/// row of the band.
///
/// # Safety
/// See [`mul_matrix_raw`]; additionally the CPU must support SSSE3.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "ssse3")]
unsafe fn band_ssse3(
    tables: &[Vec<MulTable>],
    srcs: &[*const u8],
    outs: &[*mut u8],
    len: usize,
    b0: usize,
    b1: usize,
) {
    use std::arch::x86_64::*;
    let nb = b1 - b0;
    let chunks = len / 16;
    // SAFETY: caller guarantees SSSE3 and `len` readable/writable bytes
    // per pointer; all `loadu`/`storeu` stay below `chunks * 16 <= len`
    // and are unaligned-tolerant; `tail_scalar` gets the same contract
    // with `ys[bi] == outs[b0 + bi]` as gathered above.
    unsafe {
        let mask = _mm_set1_epi8(0x0F);
        for (j, &x) in srcs.iter().enumerate() {
            let first = j == 0;
            let mut lo_tbl = [_mm_setzero_si128(); BAND];
            let mut hi_tbl = [_mm_setzero_si128(); BAND];
            let mut ys: [*mut u8; BAND] = [outs[b0]; BAND];
            for (bi, p) in (b0..b1).enumerate() {
                let t = &tables[p][j];
                lo_tbl[bi] = _mm_loadu_si128(t.lo.as_ptr() as *const __m128i);
                hi_tbl[bi] = _mm_loadu_si128(t.hi.as_ptr() as *const __m128i);
                ys[bi] = outs[p];
            }
            for c in 0..chunks {
                let xv = _mm_loadu_si128(x.add(c * 16) as *const __m128i);
                let lo_idx = _mm_and_si128(xv, mask);
                let hi_idx = _mm_and_si128(_mm_srli_epi64(xv, 4), mask);
                for bi in 0..nb {
                    let prod = _mm_xor_si128(
                        _mm_shuffle_epi8(lo_tbl[bi], lo_idx),
                        _mm_shuffle_epi8(hi_tbl[bi], hi_idx),
                    );
                    let yp = ys[bi].add(c * 16) as *mut __m128i;
                    if first {
                        _mm_storeu_si128(yp, prod);
                    } else {
                        let acc = _mm_xor_si128(_mm_loadu_si128(yp as *const __m128i), prod);
                        _mm_storeu_si128(yp, acc);
                    }
                }
            }
            let done = chunks * 16;
            if done < len {
                tail_scalar(tables, x, &ys, j, done, len, first, b0, b1);
            }
        }
    }
}

/// AVX2 fused band: as [`band_ssse3`] but 32 bytes per `vpshufb`, with
/// the 16-byte nibble tables broadcast to both 128-bit lanes (per-lane
/// shuffle semantics make the broadcast exactly the table duplication
/// the lookup needs).
///
/// # Safety
/// See [`mul_matrix_raw`]; additionally the CPU must support AVX2.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn band_avx2(
    tables: &[Vec<MulTable>],
    srcs: &[*const u8],
    outs: &[*mut u8],
    len: usize,
    b0: usize,
    b1: usize,
) {
    use std::arch::x86_64::*;
    let nb = b1 - b0;
    let chunks = len / 32;
    // SAFETY: caller guarantees AVX2 and `len` readable/writable bytes
    // per pointer; all `loadu`/`storeu` stay below `chunks * 32 <= len`
    // and are unaligned-tolerant; `tail_scalar` gets the same contract
    // with `ys[bi] == outs[b0 + bi]` as gathered above.
    unsafe {
        let mask = _mm256_set1_epi8(0x0F);
        for (j, &x) in srcs.iter().enumerate() {
            let first = j == 0;
            let mut lo_tbl = [_mm256_setzero_si256(); BAND];
            let mut hi_tbl = [_mm256_setzero_si256(); BAND];
            let mut ys: [*mut u8; BAND] = [outs[b0]; BAND];
            for (bi, p) in (b0..b1).enumerate() {
                let t = &tables[p][j];
                lo_tbl[bi] =
                    _mm256_broadcastsi128_si256(_mm_loadu_si128(t.lo.as_ptr() as *const __m128i));
                hi_tbl[bi] =
                    _mm256_broadcastsi128_si256(_mm_loadu_si128(t.hi.as_ptr() as *const __m128i));
                ys[bi] = outs[p];
            }
            for c in 0..chunks {
                let xv = _mm256_loadu_si256(x.add(c * 32) as *const __m256i);
                let lo_idx = _mm256_and_si256(xv, mask);
                let hi_idx = _mm256_and_si256(_mm256_srli_epi64(xv, 4), mask);
                for bi in 0..nb {
                    let prod = _mm256_xor_si256(
                        _mm256_shuffle_epi8(lo_tbl[bi], lo_idx),
                        _mm256_shuffle_epi8(hi_tbl[bi], hi_idx),
                    );
                    let yp = ys[bi].add(c * 32) as *mut __m256i;
                    if first {
                        _mm256_storeu_si256(yp, prod);
                    } else {
                        _mm256_storeu_si256(
                            yp,
                            _mm256_xor_si256(_mm256_loadu_si256(yp as *const __m256i), prod),
                        );
                    }
                }
            }
            let done = chunks * 32;
            if done < len {
                tail_scalar(tables, x, &ys, j, done, len, first, b0, b1);
            }
        }
    }
}

/// Scalar tail for the SIMD bands: bytes `done..len` of source `j`
/// (pointer `x`) applied to the band rows already gathered in `ys`.
///
/// # Safety
/// See [`mul_matrix_raw`]; `ys[bi]` must be `outs[b0 + bi]`.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[inline]
unsafe fn tail_scalar(
    tables: &[Vec<MulTable>],
    x: *const u8,
    ys: &[*mut u8; BAND],
    j: usize,
    done: usize,
    len: usize,
    first: bool,
    b0: usize,
    b1: usize,
) {
    let nb = b1 - b0;
    // SAFETY: `x` and each `ys[bi]` cover `len` bytes (caller contract),
    // so every `add(i)` with done <= i < len stays in bounds.
    unsafe {
        for i in done..len {
            let xi = *x.add(i);
            let lo = (xi & 0x0F) as usize;
            let hi = (xi >> 4) as usize;
            for bi in 0..nb {
                let t = &tables[b0 + bi][j];
                let prod = t.lo[lo] ^ t.hi[hi];
                if first {
                    *ys[bi].add(i) = prod;
                } else {
                    *ys[bi].add(i) ^= prod;
                }
            }
        }
    }
}

// lint: end-datapath

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn clamp_never_raises_tier() {
        assert_eq!(KernelTier::Scalar.clamp(), KernelTier::Scalar);
        assert!(KernelTier::Avx2.clamp() <= best_supported());
        assert!(supported_tiers().contains(&active()));
    }

    #[test]
    fn tier_names_roundtrip() {
        for t in [KernelTier::Scalar, KernelTier::Ssse3, KernelTier::Avx2] {
            assert_eq!(t.to_string(), t.name());
        }
    }

    #[test]
    fn fused_matches_rowwise_reference_on_every_tier() {
        let mut rng = Pcg64::seeded(0xF00D);
        for (k, m, len) in [(1usize, 1usize, 17usize), (5, 3, 64), (8, 4, 100), (3, 9, 31)] {
            let tables: Vec<Vec<MulTable>> = (0..m)
                .map(|_| (0..k).map(|_| MulTable::new(rng.next_u64() as u8)).collect())
                .collect();
            let srcs_data: Vec<Vec<u8>> = (0..k)
                .map(|_| {
                    let mut v = vec![0u8; len];
                    rng.fill_bytes(&mut v);
                    v
                })
                .collect();
            let srcs: Vec<&[u8]> = srcs_data.iter().map(|v| v.as_slice()).collect();
            // Reference: scalar row-at-a-time accumulation.
            let mut want = vec![vec![0u8; len]; m];
            for (p, out) in want.iter_mut().enumerate() {
                for (j, src) in srcs.iter().enumerate() {
                    tables[p][j].mul_slice_add(src, out);
                }
            }
            for tier in supported_tiers() {
                let mut got = vec![vec![0xABu8; len]; m]; // pre-dirtied
                let mut refs: Vec<&mut [u8]> = got.iter_mut().map(|v| v.as_mut_slice()).collect();
                mul_matrix_tier(&tables, &srcs, &mut refs, tier);
                assert_eq!(got, want, "k={k} m={m} len={len} tier={tier}");
            }
        }
    }

    #[test]
    fn strided_matches_refs_variant() {
        let mut rng = Pcg64::seeded(0xBEEF);
        let (k, m, s) = (6usize, 5usize, 77usize);
        let tables: Vec<Vec<MulTable>> = (0..m)
            .map(|_| (0..k).map(|_| MulTable::new(rng.next_u64() as u8)).collect())
            .collect();
        let mut buf = vec![0u8; (k + m) * s];
        rng.fill_bytes(&mut buf);
        let data: Vec<Vec<u8>> = (0..k).map(|j| buf[j * s..(j + 1) * s].to_vec()).collect();
        let srcs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let mut want = vec![vec![0u8; s]; m];
        let mut refs: Vec<&mut [u8]> = want.iter_mut().map(|v| v.as_mut_slice()).collect();
        mul_matrix(&tables, &srcs, &mut refs);
        for tier in supported_tiers() {
            let mut b = buf.clone();
            mul_matrix_strided_tier(&tables, &mut b, k, s, tier);
            for (p, w) in want.iter().enumerate() {
                assert_eq!(&b[(k + p) * s..(k + p + 1) * s], &w[..], "p={p} tier={tier}");
            }
            assert_eq!(&b[..k * s], &buf[..k * s], "data region untouched");
        }
    }

    #[test]
    fn empty_sources_zero_the_outputs() {
        let tables: Vec<Vec<MulTable>> = vec![Vec::new(); 2];
        let mut outs = vec![vec![0x55u8; 9]; 2];
        let mut refs: Vec<&mut [u8]> = outs.iter_mut().map(|v| v.as_mut_slice()).collect();
        mul_matrix(&tables, &[], &mut refs);
        assert!(outs.iter().all(|o| o.iter().all(|&b| b == 0)));
    }
}
