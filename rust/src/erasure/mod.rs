//! Reed–Solomon erasure coding over GF(2^8) — the fault-tolerance
//! substrate of Janus (paper §2.1, §3.1; substitute for liberasurecode).
//!
//! * [`gf256`] — field arithmetic with split-nibble slice kernels.
//! * [`kernel`] — dispatch-once SIMD tier selection (scalar/SSSE3/AVX2,
//!   `JANUS_GF_KERNEL` override) + fused multi-row coding kernels.
//! * [`matrix`] — GF(256) linear algebra + systematic MDS generator.
//! * [`rs`] — `(k, m)` encode / reconstruct, the FTG primitive.
//! * [`backend`] — the [`ErasureBackend`] trait seam + the user-facing
//!   [`Backend`] selector (DESIGN.md §12).
//! * [`fountain`] — LT-style rateless code: robust-soliton degree
//!   sampling, seeded XOR symbols on the kernel fast paths, peeling +
//!   Gaussian-elimination decoding.
//! * [`par`] — fixed std-thread coding pool (deterministic batch
//!   encode/decode across cores).
//! * [`throughput`] — measured parity-generation rate `r_ec` (§5.2.2).

pub mod backend;
pub mod fountain;
// The SIMD kernels and the coding pool's scoped-job transmute are the
// crate's audited unsafe surface (with `transport::udp`): counts pinned
// in `analysis/unsafe_budget.txt`, every block `// SAFETY:`-commented
// (lint rule `unsafe-audit`, DESIGN.md §13).
#[allow(unsafe_code)]
pub mod gf256;
#[allow(unsafe_code)]
pub mod kernel;
pub mod matrix;
#[allow(unsafe_code)]
pub mod par;
pub mod rs;
pub mod throughput;

pub use backend::{Backend, ErasureBackend};
pub use fountain::{FountainDecoder, LtCode, RobustSoliton};
pub use kernel::KernelTier;
pub use par::CodingPool;
pub use rs::{RsCode, RsError};
pub use throughput::{measure_ec_rate, measure_parallel_ec_rate, sweep_ec_rates, EcRate};
