//! Reed–Solomon erasure coding over GF(2^8) — the fault-tolerance
//! substrate of Janus (paper §2.1, §3.1; substitute for liberasurecode).
//!
//! * [`gf256`] — field arithmetic with split-nibble slice kernels.
//! * [`matrix`] — GF(256) linear algebra + systematic MDS generator.
//! * [`rs`] — `(k, m)` encode / reconstruct, the FTG primitive.
//! * [`throughput`] — measured parity-generation rate `r_ec` (§5.2.2).

pub mod gf256;
pub mod matrix;
pub mod rs;
pub mod throughput;

pub use rs::{RsCode, RsError};
pub use throughput::{measure_ec_rate, measure_parallel_ec_rate, sweep_ec_rates, EcRate};
