//! # Janus
//!
//! A reproduction of *"JANUS: Resilient and Adaptive Data Transmission for
//! Enabling Timely and Efficient Cross-Facility Scientific Workflows"*
//! (CS.DC 2025) as a three-layer Rust + JAX + Pallas system.
//!
//! Janus transfers progressively-refactored scientific data over UDP,
//! protecting each level's fragments with Reed-Solomon parity
//! (fault-tolerant groups), choosing redundancy by solving the paper's
//! optimization models, and adapting to measured packet-loss rates.
//!
//! The public entry point is the [`api`] facade: build a
//! [`api::TransferSpec`], hand an [`api::Endpoint`] a transport, and run
//! `send`/`receive` (or [`api::run_pair`] in-process). Raw f32 volumes
//! enter through the [`codec`] progressive encoder
//! ([`api::Dataset::from_volume`]), which maps a requested ε ladder to
//! bitplane-truncated precision rungs and lets receivers report the
//! achieved error bound. See `DESIGN.md` for the module inventory and
//! `EXPERIMENTS.md` for the reproduced tables/figures.

// Unsafe is confined to four audited modules (the SIMD GF(256) kernels,
// the coding-pool scoped-job transmute, and the UDP setsockopt call),
// each carrying `#[allow(unsafe_code)]` on its `mod` declaration. Every
// unsafe block needs a `// SAFETY:` comment and a matching entry in
// `analysis/unsafe_budget.txt` — `janus lint` (rule `unsafe-audit`,
// DESIGN.md §13) and `tests/lint_gate.rs` enforce both.
#![deny(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod api;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod erasure;
pub mod metrics;
pub mod model;
pub mod refactor;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod testkit;
pub mod transport;
pub mod util;
pub mod workflow;
