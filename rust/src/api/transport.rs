//! Transport factories — how an [`crate::api::Endpoint`] obtains its
//! datagram channels.
//!
//! The engines are generic over [`Datagram`], but threading those
//! generics through every public signature made each new channel type a
//! breaking change. A [`Transport`] instead hands the facade boxed
//! channels at construction time: real UDP sockets, in-memory pairs, the
//! testkit's deterministic loss channels, or any custom wrapper are
//! interchangeable without touching a single engine signature.
//!
//! Channel layout convention:
//! * **control** — the handshake/feedback channel. Single-stream runs
//!   (`streams == 1`) carry *everything* (fragments included) on it,
//!   matching the single-socket deployment of the paper's prototype.
//! * **data `w`** — pooled runs additionally open one paced channel per
//!   stream `w ∈ 0..streams`.
//!
//! The engines drain every channel through the allocation-free
//! [`Datagram::recv_into`] primitive (DESIGN.md §6); boxed channels
//! forward it, so custom `Transport` impls inherit the zero-copy path
//! for free when their channels implement it.

use crate::transport::channel::Datagram;
use crate::transport::udp::UdpChannel;
use crate::util::err::Result;
use crate::{anyhow, bail};
use std::net::{SocketAddr, ToSocketAddrs};

/// Factory for the channels one endpoint of a transfer uses.
pub trait Transport: Send {
    /// Open the control channel. Called once per transfer.
    fn open_control(&mut self) -> Result<Box<dyn Datagram>>;
    /// Open the data channel for `stream` (pooled runs only).
    fn open_data(&mut self, stream: usize) -> Result<Box<dyn Datagram>>;
}

/// Adapt one prebuilt channel (of any [`Datagram`] impl — a connected
/// UDP socket, a loss-injecting wrapper, …) into a single-stream
/// [`Transport`].
pub struct ChannelTransport {
    chan: Option<Box<dyn Datagram>>,
}

impl ChannelTransport {
    pub fn new(chan: impl Datagram + 'static) -> ChannelTransport {
        ChannelTransport { chan: Some(Box::new(chan)) }
    }
}

impl Transport for ChannelTransport {
    fn open_control(&mut self) -> Result<Box<dyn Datagram>> {
        self.chan
            .take()
            .ok_or_else(|| anyhow!("channel transport: control already opened"))
    }

    fn open_data(&mut self, stream: usize) -> Result<Box<dyn Datagram>> {
        bail!("channel transport is single-stream; no data channel {stream}")
    }
}

/// A [`Transport`] over pre-staged channels — the construction used by
/// in-process pairs (memory channels, testkit loss channels).
pub struct StagedTransport {
    control: Option<Box<dyn Datagram>>,
    data: Vec<Option<Box<dyn Datagram>>>,
}

impl StagedTransport {
    pub fn new(
        control: impl Datagram + 'static,
        data: Vec<Box<dyn Datagram>>,
    ) -> StagedTransport {
        StagedTransport {
            control: Some(Box::new(control)),
            data: data.into_iter().map(Some).collect(),
        }
    }
}

impl Transport for StagedTransport {
    fn open_control(&mut self) -> Result<Box<dyn Datagram>> {
        self.control
            .take()
            .ok_or_else(|| anyhow!("staged transport: control already opened"))
    }

    fn open_data(&mut self, stream: usize) -> Result<Box<dyn Datagram>> {
        match self.data.get_mut(stream) {
            Some(slot) => slot
                .take()
                .ok_or_else(|| anyhow!("staged transport: data channel {stream} already opened")),
            None => bail!(
                "staged transport has {} data channels, stream {stream} requested",
                self.data.len()
            ),
        }
    }
}

/// Connected pair of in-memory transports: lossless control plus
/// `streams` lossless data channels each way. The loss-injecting sibling
/// lives in [`crate::testkit::loss_transport_pair`].
pub fn mem_transport_pair(streams: usize) -> (StagedTransport, StagedTransport) {
    use crate::transport::channel::mem_pair;
    let (ac, bc) = mem_pair();
    let mut ad: Vec<Box<dyn Datagram>> = Vec::with_capacity(streams);
    let mut bd: Vec<Box<dyn Datagram>> = Vec::with_capacity(streams);
    for _ in 0..streams {
        let (a, b) = mem_pair();
        ad.push(Box::new(a));
        bd.push(Box::new(b));
    }
    (StagedTransport::new(ac, ad), StagedTransport::new(bc, bd))
}

/// Real-UDP transport addressed by a (local, peer) socket-address pair.
///
/// Port convention: the control channel binds/connects the given ports;
/// data stream `w` uses `port + 1 + w` on both sides. Both endpoints must
/// therefore be constructed from the same spec so the port maps agree.
pub struct UdpTransport {
    local: SocketAddr,
    peer: SocketAddr,
}

impl UdpTransport {
    pub fn new(local: impl ToSocketAddrs, peer: impl ToSocketAddrs) -> Result<UdpTransport> {
        let local = local
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow!("udp transport: local address resolved to nothing"))?;
        let peer = peer
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow!("udp transport: peer address resolved to nothing"))?;
        Ok(UdpTransport { local, peer })
    }

    fn offset(addr: SocketAddr, by: u16) -> Result<SocketAddr> {
        let mut out = addr;
        // An ephemeral local port (0) stays ephemeral on every channel.
        if addr.port() != 0 {
            let port = addr
                .port()
                .checked_add(by)
                .ok_or_else(|| anyhow!("udp transport: port {} + {by} overflows", addr.port()))?;
            out.set_port(port);
        }
        Ok(out)
    }
}

impl Transport for UdpTransport {
    fn open_control(&mut self) -> Result<Box<dyn Datagram>> {
        Ok(Box::new(UdpChannel::bind_connect(self.local, self.peer)?))
    }

    fn open_data(&mut self, stream: usize) -> Result<Box<dyn Datagram>> {
        if self.peer.port() == 0 {
            bail!("udp transport: pooled data channels need a fixed peer port");
        }
        let by = 1 + u16::try_from(stream)
            .map_err(|_| anyhow!("udp transport: stream index {stream} out of range"))?;
        let local = Self::offset(self.local, by)?;
        let peer = Self::offset(self.peer, by)?;
        Ok(Box::new(UdpChannel::bind_connect(local, peer)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::channel::mem_pair;
    use std::time::Duration;

    #[test]
    fn channel_transport_opens_once() {
        let (a, _b) = mem_pair();
        let mut t = ChannelTransport::new(a);
        assert!(t.open_control().is_ok());
        assert!(t.open_control().is_err(), "second open must fail");
        assert!(t.open_data(0).is_err(), "single-stream: no data channels");
    }

    #[test]
    fn mem_transport_pair_is_wired_both_ways() {
        let (mut s, mut r) = mem_transport_pair(2);
        let mut sc = s.open_control().unwrap();
        let mut rc = r.open_control().unwrap();
        sc.send(b"ctl");
        assert_eq!(rc.recv_timeout(Duration::from_millis(100)).unwrap(), b"ctl");
        rc.send(b"ack");
        assert_eq!(sc.recv_timeout(Duration::from_millis(100)).unwrap(), b"ack");
        for w in 0..2 {
            let mut sd = s.open_data(w).unwrap();
            let mut rd = r.open_data(w).unwrap();
            sd.send(&[w as u8]);
            assert_eq!(
                rd.recv_timeout(Duration::from_millis(100)).unwrap(),
                vec![w as u8]
            );
        }
        assert!(s.open_data(2).is_err(), "only 2 staged data channels");
        assert!(s.open_data(0).is_err(), "channel 0 already taken");
    }

    #[test]
    fn udp_transport_port_convention() {
        let t = UdpTransport::new("127.0.0.1:9000", "127.0.0.1:9100").unwrap();
        assert_eq!(t.local.port(), 9000);
        assert_eq!(t.peer.port(), 9100);
        // Data stream w lives at port + 1 + w; ephemeral (0) stays 0.
        assert_eq!(UdpTransport::offset(t.local, 3).unwrap().port(), 9003);
        let eph = UdpTransport::new("127.0.0.1:0", "127.0.0.1:9100").unwrap();
        assert_eq!(UdpTransport::offset(eph.local, 3).unwrap().port(), 0);
        // Overflowing port maps are an error, not a wrap.
        let hi = UdpTransport::new("127.0.0.1:65535", "127.0.0.1:9100").unwrap();
        assert!(UdpTransport::offset(hi.local, 1).is_err());
    }

    #[test]
    fn udp_transport_rejects_pooled_ephemeral_peer() {
        let mut t = UdpTransport::new("127.0.0.1:0", "127.0.0.1:0").unwrap();
        assert!(t.open_data(0).is_err());
    }
}
