//! One report shape for every transfer, single-stream or pooled.
//!
//! The common questions — how many fragments, how many passes, what got
//! delivered, at what fidelity — live in [`SendSummary`] /
//! [`ReceiveSummary`] regardless of which engine ran. Engine-specific
//! depth (per-pass traces, λ̂ feedback logs, adaptation history) stays
//! available through the `detail` enums.

use crate::codec::{CodecError, DecodeOutput, Decoder};
use crate::coordinator::pool::{
    DeadlineOutcome, PassRecord, PoolReceiverReport, PoolSenderReport, RecvPassRecord,
};
use crate::coordinator::receiver::ReceiverReport;
use crate::coordinator::sender::SenderReport;

/// Engine-specific sender detail.
#[derive(Debug, Clone)]
pub enum SendDetail {
    SingleStream(SenderReport),
    Pooled(PoolSenderReport),
}

/// Sender-side outcome of a transfer, engine-agnostic.
#[derive(Debug, Clone)]
pub struct SendSummary {
    /// Fragments put on the wire (data + parity, all passes).
    pub fragments_sent: u64,
    /// Data fragments among them.
    pub data_fragments: u64,
    /// Retransmission passes (0 = everything accepted first pass).
    pub passes: u32,
    /// Wall-clock seconds.
    pub duration: f64,
    /// λ̂ values observed over the transfer, in order.
    pub lambda_history: Vec<f64>,
    /// Pacing rate settled at each pass barrier (per-stream,
    /// fragments/s). Constant at the configured rate under
    /// `AdaptConfig::fixed()`; tracks the congestion controller when
    /// rate control is on. Empty for zero-barrier transfers.
    pub rate_history: Vec<f64>,
    /// Full engine report.
    pub detail: SendDetail,
}

impl SendSummary {
    /// Per-pass trace (pooled runs only).
    pub fn trace(&self) -> Option<&[PassRecord]> {
        match &self.detail {
            SendDetail::Pooled(r) => Some(&r.trace),
            SendDetail::SingleStream(_) => None,
        }
    }

    /// τ accounting of a pooled Deadline transfer: virtual time spent
    /// against the contracted deadline and the ε the final (post-shed)
    /// advertisement promises. `None` for other contracts and for the
    /// single-stream route (whose Deadline plan is fixed up front — see
    /// `plan_history` in [`SenderReport`]).
    pub fn deadline(&self) -> Option<&DeadlineOutcome> {
        match &self.detail {
            SendDetail::Pooled(r) => r.deadline.as_ref(),
            SendDetail::SingleStream(_) => None,
        }
    }

    pub fn pooled(&self) -> Option<&PoolSenderReport> {
        match &self.detail {
            SendDetail::Pooled(r) => Some(r),
            SendDetail::SingleStream(_) => None,
        }
    }

    pub fn single_stream(&self) -> Option<&SenderReport> {
        match &self.detail {
            SendDetail::SingleStream(r) => Some(r),
            SendDetail::Pooled(_) => None,
        }
    }
}

impl From<SenderReport> for SendSummary {
    fn from(r: SenderReport) -> SendSummary {
        SendSummary {
            fragments_sent: r.fragments_sent,
            data_fragments: r.data_fragments,
            passes: r.passes,
            duration: r.duration,
            lambda_history: r.lambda_updates.clone(),
            rate_history: r.rate_history.clone(),
            detail: SendDetail::SingleStream(r),
        }
    }
}

impl From<PoolSenderReport> for SendSummary {
    fn from(r: PoolSenderReport) -> SendSummary {
        SendSummary {
            fragments_sent: r.fragments_sent,
            data_fragments: r.data_fragments,
            passes: r.passes,
            duration: r.duration,
            lambda_history: r.lambda_history.clone(),
            rate_history: r.rate_history.clone(),
            detail: SendDetail::Pooled(r),
        }
    }
}

/// Engine-specific receiver detail. The recovered level buffers are moved
/// into [`ReceiveSummary::levels`]; the `levels` field inside these
/// reports is left empty to avoid double-buffering large transfers.
#[derive(Debug, Clone)]
pub enum ReceiveDetail {
    SingleStream(ReceiverReport),
    Pooled(PoolReceiverReport),
}

/// Receiver-side view of a delivered codec stream: what the progressive
/// decoder certified about the recovered prefix. Present only when the
/// dataset came through [`crate::api::Dataset::from_volume`] (the
/// facade sniffs the codec magic in level 0 and replays the rungs).
#[derive(Debug, Clone, PartialEq)]
pub struct CodecSummary {
    /// Rungs the progressive decoder applied (delivered prefix).
    pub rungs_decoded: usize,
    /// Recorded (measured-at-encode) ε of the applied prefix.
    pub achieved_eps: f64,
    /// Contiguous mantissa-plane prefix applied per lifting level.
    pub planes_used: Vec<u8>,
    /// Volume dimension from the stream header.
    pub d: usize,
    /// Lifting levels from the stream header.
    pub lifting_levels: usize,
    /// Total CRC-valid segments applied.
    pub segments_applied: usize,
}

/// Receiver-side outcome of a transfer, engine-agnostic.
#[derive(Debug, Clone)]
pub struct ReceiveSummary {
    /// Recovered level buffers (exact original bytes); `None` where a
    /// level had unrecoverable groups (possible only under `Deadline`).
    pub levels: Vec<Option<Vec<u8>>>,
    /// Leading fully-recovered levels.
    pub levels_recovered: usize,
    /// ε of the recovered prefix (1.0 when nothing usable arrived).
    pub achieved_eps: f64,
    pub fragments_received: u64,
    /// Groups that needed Reed–Solomon recovery (vs. arriving complete).
    pub groups_recovered: u64,
    /// Wall-clock seconds.
    pub duration: f64,
    /// Progressive-decode certificate for codec datasets (None for raw).
    pub codec: Option<CodecSummary>,
    /// Full engine report (with `levels` drained — see [`ReceiveDetail`]).
    pub detail: ReceiveDetail,
}

impl ReceiveSummary {
    /// Per-pass trace (pooled runs only).
    pub fn trace(&self) -> Option<&[RecvPassRecord]> {
        match &self.detail {
            ReceiveDetail::Pooled(r) => Some(&r.trace),
            ReceiveDetail::SingleStream(_) => None,
        }
    }

    pub fn pooled(&self) -> Option<&PoolReceiverReport> {
        match &self.detail {
            ReceiveDetail::Pooled(r) => Some(r),
            ReceiveDetail::SingleStream(_) => None,
        }
    }

    pub fn single_stream(&self) -> Option<&ReceiverReport> {
        match &self.detail {
            ReceiveDetail::SingleStream(r) => Some(r),
            ReceiveDetail::Pooled(_) => None,
        }
    }

    /// The recovered prefix as byte slices (levels beyond the prefix are
    /// excluded even if present, matching the ε accounting).
    pub fn recovered_prefix(&self) -> Vec<&[u8]> {
        self.levels[..self.levels_recovered]
            .iter()
            .map(|l| l.as_ref().expect("prefix levels are present").as_slice())
            .collect()
    }

    /// Whether the delivered bytes look like a codec stream (level 0
    /// opens with the container magic).
    pub fn is_codec_stream(&self) -> bool {
        matches!(
            self.levels.first(),
            Some(Some(l0)) if l0.starts_with(&crate::codec::container::STREAM_MAGIC)
        )
    }

    /// Reconstruct the volume from the delivered codec prefix. `None`
    /// when the payload is not a codec stream (raw datasets, or level 0
    /// undelivered); otherwise the progressive decode result, including
    /// the recorded achieved ε and the reconstructed volume itself.
    ///
    /// This replays the container from `levels` each call rather than
    /// caching the receive-time decoder: keeping that state would hold
    /// a second copy of every plane in memory for the (common) callers
    /// who never reconstruct. Decode the volume once and keep the
    /// [`DecodeOutput`] if you need it repeatedly.
    pub fn decode_volume(&self) -> Option<Result<DecodeOutput, CodecError>> {
        if !self.is_codec_stream() {
            return None;
        }
        let mut dec = Decoder::new();
        for rung in self.recovered_prefix() {
            if let Err(e) = dec.push_rung(rung) {
                return Some(Err(e));
            }
        }
        Some(dec.reconstruct())
    }
}

impl From<ReceiverReport> for ReceiveSummary {
    fn from(mut r: ReceiverReport) -> ReceiveSummary {
        let levels = std::mem::take(&mut r.levels);
        ReceiveSummary {
            levels,
            levels_recovered: r.levels_recovered,
            achieved_eps: r.achieved_eps,
            fragments_received: r.fragments_received,
            groups_recovered: r.groups_recovered,
            duration: r.duration,
            codec: None,
            detail: ReceiveDetail::SingleStream(r),
        }
    }
}

impl From<PoolReceiverReport> for ReceiveSummary {
    fn from(mut r: PoolReceiverReport) -> ReceiveSummary {
        let levels = std::mem::take(&mut r.levels);
        ReceiveSummary {
            levels,
            levels_recovered: r.levels_recovered,
            achieved_eps: r.achieved_eps,
            fragments_received: r.fragments_received,
            groups_recovered: r.groups_recovered,
            duration: r.duration,
            codec: None,
            detail: ReceiveDetail::Pooled(r),
        }
    }
}

/// Both sides of an in-process transfer (see [`crate::api::run_pair`]).
#[derive(Debug, Clone)]
pub struct TransferReport {
    pub sent: SendSummary,
    pub received: ReceiveSummary,
}
