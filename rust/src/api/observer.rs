//! Typed transfer events and the observer interface.
//!
//! The engines used to report what happened only after the fact, through
//! ad-hoc per-engine report structs. [`TransferObserver`] replaces that
//! with a push interface: the facade delivers [`TransferEvent`]s while the
//! transfer runs, so callers can log, plot λ̂ live, or assert protocol
//! ordering in tests without reaching into engine internals.
//!
//! Ordering guarantees (per endpoint):
//! * `PassStarted { pass }` precedes every other event of that pass.
//! * `ParityAdapted { pass, .. }` follows its `PassStarted` and precedes
//!   the pass's `StreamFinished` events.
//! * All `StreamFinished { pass, .. }` of a pass precede the
//!   `LambdaUpdated` derived from that pass's statistics (pooled runs).
//! * `StreamFinished` events of *different* streams in the same pass may
//!   interleave in any order (they come from concurrent workers).
//! * `LevelShed { pass, .. }` events (pooled Deadline) follow the
//!   pass's `LambdaUpdated` and precede the next `PassStarted`.
//! * `RateAdapted { pass, .. }` follows the pass's `LambdaUpdated` and
//!   precedes the next `PassStarted` — an observer that drives a live
//!   channel model (the congestion testkit) therefore applies the new
//!   rate deterministically at the pass boundary.
//! * `GroupRecovered` events are receiver-side and are emitted in
//!   (level, group) reconstruction order.
//! * `LevelDecoded` events are receiver-side, follow every
//!   `GroupRecovered`, and arrive in level (rung) order — one per
//!   delivered codec rung, carrying the recorded achieved ε of the
//!   prefix up to that rung. Raw (non-codec) datasets emit none.

/// One protocol-level occurrence inside a running transfer.
#[derive(Debug, Clone, PartialEq)]
pub enum TransferEvent {
    /// A transmission pass began (pass 0 = initial, >0 = retransmission).
    PassStarted { pass: u32 },
    /// The shared loss estimate λ̂ changed (receiver feedback on the
    /// single-stream path, pass-barrier statistics on the pooled path).
    LambdaUpdated { lambda: f64 },
    /// Eq. 8 / Eq. 12 (re-)solved the redundancy for a pass.
    ParityAdapted { pass: u32, m: usize },
    /// A fault-tolerant group needed Reed–Solomon recovery and succeeded.
    GroupRecovered { level: u8, ftg: u32 },
    /// One stream finished its share of a pass.
    StreamFinished { stream: u8, pass: u32, fragments: u64 },
    /// Receiver-side progressive reconstruction applied one codec rung:
    /// the delivered prefix now decodes at the recorded `achieved_eps`
    /// (measured at encode time). Emitted in level order after the
    /// transfer's `GroupRecovered` events; codec datasets only.
    LevelDecoded { level: u8, achieved_eps: f64 },
    /// A pooled Deadline pass barrier shed work: level `level`'s
    /// advertised prefix shrank to `kept_bytes` (0 = the level was
    /// abandoned) because the residual τ budget could not afford its
    /// retransmission. `eps` is the relative L∞ error the transfer
    /// prefix achieves after the shed (the plane cut's measured ε for a
    /// partial shed). Emitted after the pass's `LambdaUpdated`, before
    /// the next `PassStarted`.
    LevelShed { pass: u32, level: u8, kept_bytes: u64, eps: f64 },
    /// The congestion controller settled the pacing rate for the *next*
    /// pass: `rate` is the new per-stream rate (fragments/s), `backoff`
    /// whether it sits below the configured maximum. Under
    /// `AdaptConfig::fixed()` the rate never moves (the pooled engine
    /// still reports it each barrier; the single-stream engine emits
    /// only on change). Emitted after the pass's `LambdaUpdated`,
    /// before the next `PassStarted`.
    RateAdapted { pass: u32, rate: f64, backoff: bool },
}

/// Receives [`TransferEvent`]s while a transfer runs.
///
/// Implementations must be `Send`: events can originate from engine
/// worker threads (delivery is serialized — `on_event` is never called
/// concurrently for one observer).
pub trait TransferObserver: Send {
    fn on_event(&mut self, event: &TransferEvent);
}

/// Adapter turning any `FnMut(&TransferEvent) + Send` closure into an
/// observer: `FnObserver(|e| println!("{e:?}"))`.
pub struct FnObserver<F: FnMut(&TransferEvent) + Send>(pub F);

impl<F: FnMut(&TransferEvent) + Send> TransferObserver for FnObserver<F> {
    fn on_event(&mut self, event: &TransferEvent) {
        (self.0)(event)
    }
}

/// Observer that records every event — the assertion workhorse for
/// integration tests and a convenient building block for callers.
#[derive(Debug, Default)]
pub struct EventLog {
    pub events: Vec<TransferEvent>,
}

impl EventLog {
    pub fn new() -> EventLog {
        EventLog::default()
    }

    /// Events matching a predicate, in delivery order.
    pub fn filtered(&self, pred: impl Fn(&TransferEvent) -> bool) -> Vec<&TransferEvent> {
        self.events.iter().filter(|e| pred(e)).collect()
    }
}

impl TransferObserver for EventLog {
    fn on_event(&mut self, event: &TransferEvent) {
        self.events.push(event.clone());
    }
}

/// Internal fan-in point the engines emit into: a shared, thread-safe
/// callback (the facade wraps the caller's observer in a mutex). `None`
/// compiles the emission down to a no-op.
pub(crate) type EventSink<'a> = Option<&'a (dyn Fn(TransferEvent) + Sync)>;

/// Emit `event` into `sink` if one is installed.
#[inline]
pub(crate) fn emit(sink: EventSink<'_>, event: TransferEvent) {
    if let Some(f) = sink {
        f(event);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_log_records_in_order() {
        let mut log = EventLog::new();
        log.on_event(&TransferEvent::PassStarted { pass: 0 });
        log.on_event(&TransferEvent::LambdaUpdated { lambda: 42.0 });
        assert_eq!(
            log.events,
            vec![
                TransferEvent::PassStarted { pass: 0 },
                TransferEvent::LambdaUpdated { lambda: 42.0 },
            ]
        );
        assert_eq!(
            log.filtered(|e| matches!(e, TransferEvent::PassStarted { .. })).len(),
            1
        );
    }

    #[test]
    fn closures_are_observers_via_fn_observer() {
        let mut count = 0usize;
        {
            let mut obs = FnObserver(|_: &TransferEvent| count += 1);
            obs.on_event(&TransferEvent::PassStarted { pass: 0 });
            obs.on_event(&TransferEvent::PassStarted { pass: 1 });
        }
        assert_eq!(count, 2);
    }

    #[test]
    fn emit_into_none_is_a_noop() {
        emit(None, TransferEvent::PassStarted { pass: 0 });
    }
}
