//! # `janus::api` — the transfer facade
//!
//! The one public way to run a Janus transfer. Callers declare an intent
//! — *deliver this dataset under this contract* — and the facade picks
//! the engine, the streams, and the redundancy (PAPER.md §3, Eq. 8):
//!
//! ```text
//! TransferSpec::builder() ──build()──▶ TransferSpec (validated, immutable)
//!                                           │
//!                      Endpoint::new(spec) ─┤─ Transport (UDP / mem / testkit)
//!                                           ▼
//!                 Endpoint::send(…) / Endpoint::receive(…)
//!                   │ streams == 1 → single-stream engine (all contracts)
//!                   │ streams  > 1 → TransferPool        (retransmitting)
//!                   ▼
//!        TransferObserver ◀─ typed events (PassStarted, LambdaUpdated,
//!                             ParityAdapted, GroupRecovered, StreamFinished)
//!                   ▼
//!        SendSummary / ReceiveSummary / TransferReport
//! ```
//!
//! ## Example
//!
//! ```no_run
//! use janus::api::{mem_transport_pair, run_pair, Contract, Dataset, TransferSpec};
//!
//! let dataset = Dataset::new(
//!     vec![vec![1u8; 40_000], vec![2u8; 160_000]],
//!     vec![0.004, 0.0000001],
//! )?;
//! let spec = TransferSpec::builder()
//!     .contract(Contract::Fidelity(1e-7))
//!     .streams(4)
//!     .pacing_rate(100_000.0)
//!     .build()?;
//! let (sender_t, receiver_t) = mem_transport_pair(spec.streams());
//! let report = run_pair(&spec, sender_t, receiver_t, &dataset, None, None)?;
//! assert_eq!(report.received.levels_recovered, 2);
//! # Ok::<(), janus::util::err::Error>(())
//! ```
//!
//! Raw f32 volumes enter through [`Dataset::from_volume`] — the
//! `janus::codec` progressive encoder — so a transfer can start from a
//! scientific array instead of opaque bytes: levels become measured ε
//! rungs, the receiver emits [`TransferEvent::LevelDecoded`] as the
//! delivered prefix decodes, and
//! [`ReceiveSummary::decode_volume`] reconstructs the volume together
//! with its certified achieved ε. [`Dataset::raw`] keeps today's
//! byte-level path.
//!
//! The pre-facade free functions (`coordinator::run_sender`,
//! `run_receiver`, `run_session`, `TransferPool::run_*`) survive only as
//! `#[deprecated]` shims; CI builds the examples with `-D deprecated` so
//! migrated call sites cannot regress onto them.

pub mod endpoint;
pub mod observer;
pub mod report;
pub mod spec;
pub mod transport;

pub use endpoint::Endpoint;
pub use observer::{EventLog, FnObserver, TransferEvent, TransferObserver};
pub use report::{
    CodecSummary, ReceiveDetail, ReceiveSummary, SendDetail, SendSummary, TransferReport,
};
// Pooled Deadline τ accounting, reachable from `SendSummary::deadline`
// and the pooled pass trace.
pub use crate::coordinator::pool::{DeadlineOutcome, ShedDecision};
// Congestion/burst adaptation knobs for `TransferSpecBuilder::adaptation`.
pub use crate::coordinator::rate::AdaptConfig;
pub use crate::erasure::Backend;
pub use spec::{Contract, Dataset, SpecError, TransferSpec, TransferSpecBuilder};

// The codec types a facade caller needs for `Dataset::from_volume` and
// `ReceiveSummary::decode_volume`.
pub use crate::codec::{CodecConfig, CodecError, DecodeOutput};
pub use transport::{
    mem_transport_pair, ChannelTransport, StagedTransport, Transport, UdpTransport,
};

use crate::anyhow;
use crate::util::err::Result;

/// Run a full transfer in-process: the receiver on a spawned thread, the
/// sender on the caller's, both built from the same `spec`. This is the
/// harness behind the examples, the loopback benches, and the e2e tests.
///
/// Observers are per-side (events from the two endpoints would otherwise
/// interleave nondeterministically).
pub fn run_pair<TS, TR>(
    spec: &TransferSpec,
    mut sender_transport: TS,
    mut receiver_transport: TR,
    dataset: &Dataset,
    sender_observer: Option<&mut dyn TransferObserver>,
    receiver_observer: Option<&mut dyn TransferObserver>,
) -> Result<TransferReport>
where
    TS: Transport,
    TR: Transport,
{
    let sender_ep = Endpoint::new(spec.clone());
    let receiver_ep = Endpoint::new(spec.clone());
    std::thread::scope(|scope| {
        let recv = scope.spawn(move || {
            receiver_ep.receive(&mut receiver_transport, receiver_observer)
        });
        let sent = sender_ep.send(&mut sender_transport, dataset, sender_observer)?;
        let received = recv
            .join()
            .map_err(|_| anyhow!("receiver thread panicked"))??;
        Ok(TransferReport { sent, received })
    })
}
