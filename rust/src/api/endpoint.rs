//! [`Endpoint`] — the one entry pair (`send` / `receive`) every Janus
//! transfer goes through.

use super::observer::{emit, EventSink, TransferEvent, TransferObserver};
use super::report::{CodecSummary, ReceiveSummary, SendSummary};
use super::spec::{Dataset, SpecError, TransferSpec};
use super::transport::Transport;
use crate::codec::Decoder;
use crate::coordinator::pool::{PoolConfig, TransferPool};
use crate::coordinator::receiver::{transfer_receiver, ReceiverConfig};
use crate::coordinator::sender::{transfer_sender, SenderConfig};
use crate::engine::{drive_receiver, drive_sender_backend};
use crate::erasure::Backend;
use crate::transport::channel::Datagram;
use crate::util::err::Result;
use std::sync::Mutex;

/// One side of a transfer, bound to a validated [`TransferSpec`].
///
/// `send` and `receive` route internally: `streams == 1` runs the
/// single-stream engine over the transport's control channel;
/// `streams > 1` runs the multi-stream [`TransferPool`] over control +
/// per-stream data channels. All three contracts run on either route —
/// pooled `Deadline` debits a virtual τ budget at pass barriers and
/// sheds work that no longer fits (see [`SendSummary::deadline`]).
#[derive(Debug, Clone)]
pub struct Endpoint {
    spec: TransferSpec,
}

impl Endpoint {
    pub fn new(spec: TransferSpec) -> Endpoint {
        Endpoint { spec }
    }

    pub fn spec(&self) -> &TransferSpec {
        &self.spec
    }

    /// Run this endpoint as the sender of `dataset`. Blocks until the
    /// contract is fulfilled (or fails). `observer`, when given, receives
    /// typed [`TransferEvent`]s as the transfer progresses.
    pub fn send(
        &self,
        transport: &mut dyn Transport,
        dataset: &Dataset,
        observer: Option<&mut dyn TransferObserver>,
    ) -> Result<SendSummary> {
        with_sink(observer, |sink| self.send_inner(transport, dataset, sink))
    }

    /// Run this endpoint as the receiver. Blocks until the sender closes
    /// the transfer (or a timeout in the spec fires).
    pub fn receive(
        &self,
        transport: &mut dyn Transport,
        observer: Option<&mut dyn TransferObserver>,
    ) -> Result<ReceiveSummary> {
        with_sink(observer, |sink| self.receive_inner(transport, sink))
    }

    fn send_inner(
        &self,
        transport: &mut dyn Transport,
        dataset: &Dataset,
        sink: EventSink<'_>,
    ) -> Result<SendSummary> {
        let spec = &self.spec;
        // `Dataset`'s constructors validate all of this, but `levels`
        // and `eps` are public fields: re-check here so a mutated
        // dataset surfaces as a typed error instead of a panic in the
        // engines' schedule asserts.
        if dataset.levels.is_empty() {
            return Err(SpecError::EmptyDataset.into());
        }
        if dataset.levels.len() != dataset.eps.len()
            || dataset.eps.iter().any(|e| e.is_nan() || *e <= 0.0 || *e > 1.0)
            || dataset.eps.windows(2).any(|w| w[0] <= w[1])
        {
            return Err(SpecError::BadEpsilonLadder.into());
        }
        // Codec plane cuts must still describe these exact levels; a
        // mutation that invalidated them costs the Deadline contract its
        // bitplane shed granularity, not a panic.
        let plane_cuts =
            if cuts_describe(dataset) { dataset.cuts.clone() } else { Vec::new() };
        let mut control = transport.open_control()?;
        if spec.streams() == 1 {
            let cfg = SenderConfig {
                net: spec.net(),
                contract: spec.contract(),
                initial_lambda: spec.initial_lambda(),
                max_duration: spec.max_duration(),
                plane_cuts,
                adapt: spec.adaptation(),
            };
            if spec.backend() == Backend::Fountain {
                // Barrier-free rateless mode runs on the sans-IO machine
                // (the blocking engine's loop is organized around pass
                // barriers, which fountain transfers do not have).
                let rep = drive_sender_backend(
                    control.as_mut(),
                    &cfg,
                    &dataset.levels,
                    &dataset.eps,
                    Backend::Fountain,
                )?;
                return Ok(rep.into());
            }
            let rep = transfer_sender(control.as_mut(), &cfg, &dataset.levels, &dataset.eps, sink)?;
            Ok(rep.into())
        } else {
            // All three contracts route to the pool; Deadline runs the
            // pass-barrier τ accounting (Fidelity narrows the level set
            // inside the engine, BestEffort ships the full ladder).
            let pool = TransferPool::new(PoolConfig {
                net: spec.net(),
                streams: spec.streams(),
                contract: spec.contract(),
                initial_lambda: spec.initial_lambda(),
                max_duration: spec.max_duration(),
                plane_cuts,
                adapt: spec.adaptation(),
            })?;
            let mut data = open_data_channels(transport, spec.streams())?;
            let rep =
                pool.pooled_sender(&mut control, &mut data, &dataset.levels, &dataset.eps, sink)?;
            Ok(rep.into())
        }
    }

    fn receive_inner(
        &self,
        transport: &mut dyn Transport,
        sink: EventSink<'_>,
    ) -> Result<ReceiveSummary> {
        let spec = &self.spec;
        let rcfg = ReceiverConfig {
            t_w: spec.lambda_window(),
            idle_timeout: spec.idle_timeout(),
            max_duration: spec.max_duration(),
        };
        let mut control = transport.open_control()?;
        let mut summary: ReceiveSummary = if spec.streams() == 1 {
            if spec.backend() == Backend::Fountain {
                // The machine receiver auto-detects the fountain flag in
                // the manifest; routing by spec keeps the two sides
                // symmetric (and the blocking engine barrier-only).
                drive_receiver(control.as_mut(), &rcfg)?.into()
            } else {
                transfer_receiver(control.as_mut(), &rcfg, sink)?.into()
            }
        } else {
            let data = open_data_channels(transport, spec.streams())?;
            TransferPool::pooled_receiver(&mut control, data, &rcfg, sink)?.into()
        };
        attach_codec_summary(&mut summary, sink);
        Ok(summary)
    }
}

/// Receiver-side progressive reconstruction: when the delivered bytes
/// are a codec stream, replay the recovered rung prefix through the
/// progressive decoder, emitting one [`TransferEvent::LevelDecoded`]
/// per rung (in level order, after the engine's events) and recording
/// the decode certificate in [`ReceiveSummary::codec`].
///
/// Certification is all-or-nothing over the recovered prefix: if *any*
/// recovered rung fails to parse (corruption, or a raw dataset whose
/// first bytes merely collide with the codec magic), no events are
/// emitted and no certificate is attached — exactly the prefixes this
/// function certifies are the ones [`ReceiveSummary::decode_volume`]
/// can reconstruct.
fn attach_codec_summary(summary: &mut ReceiveSummary, sink: EventSink<'_>) {
    if !summary.is_codec_stream() {
        return;
    }
    // Headers-only replay: every structural/CRC check runs, nothing is
    // copied — reconstruction stays on-demand via `decode_volume`.
    let mut dec = Decoder::headers_only();
    let mut events = Vec::new();
    for (idx, rung) in summary.recovered_prefix().into_iter().enumerate() {
        match dec.push_rung(rung) {
            Ok(achieved_eps) => {
                events.push(TransferEvent::LevelDecoded { level: idx as u8, achieved_eps });
            }
            // Not (entirely) a codec stream after all: certify nothing.
            Err(_) => return,
        }
    }
    if events.is_empty() {
        return;
    }
    let rungs_decoded = events.len();
    for event in events {
        emit(sink, event);
    }
    let header = dec.header().expect("rung 0 applied");
    summary.codec = Some(CodecSummary {
        rungs_decoded,
        achieved_eps: dec.achieved_eps(),
        planes_used: dec.planes_used(),
        d: header.d,
        lifting_levels: header.levels,
        segments_applied: dec.segments_applied(),
    });
}

/// Do the dataset's plane cuts still describe its (publicly mutable)
/// levels and ε ladder? Mirrors `LevelSchedule::with_cuts`'s asserts —
/// the codec encoder guarantees all of this at construction, but a
/// caller who truncated `levels` or edited `eps` afterwards would
/// otherwise trip those asserts deep inside an engine.
fn cuts_describe(dataset: &Dataset) -> bool {
    let cuts = dataset.cuts();
    if cuts.len() != dataset.levels.len() {
        return false;
    }
    for (li, (list, level)) in cuts.iter().zip(&dataset.levels).enumerate() {
        let mut last_bytes = 0u64;
        let mut last_eps = if li == 0 { 1.0 } else { dataset.eps[li - 1] };
        for cut in list {
            if cut.bytes <= last_bytes
                || cut.bytes >= level.len() as u64
                || cut.eps >= last_eps
                || cut.eps <= dataset.eps[li]
            {
                return false;
            }
            last_bytes = cut.bytes;
            last_eps = cut.eps;
        }
    }
    true
}

fn open_data_channels(
    transport: &mut dyn Transport,
    streams: usize,
) -> Result<Vec<Box<dyn Datagram>>> {
    (0..streams).map(|w| transport.open_data(w)).collect()
}

/// Bridge the caller's `&mut` observer into the engines' `Fn + Sync`
/// sink: worker threads serialize delivery through a mutex, so
/// `on_event` is never entered concurrently.
fn with_sink<R>(
    observer: Option<&mut dyn TransferObserver>,
    f: impl FnOnce(EventSink<'_>) -> Result<R>,
) -> Result<R> {
    match observer {
        None => f(None),
        Some(obs) => {
            let cell = Mutex::new(obs);
            let sink = move |event: TransferEvent| {
                if let Ok(mut o) = cell.lock() {
                    o.on_event(&event);
                }
            };
            f(Some(&sink))
        }
    }
}
