//! The transfer contract, dataset description, and the validated
//! [`TransferSpec`] every Janus transfer is built from.

use crate::codec::{self, CodecConfig, CodecError, Encoded};
use crate::coordinator::rate::AdaptConfig;
use crate::erasure::Backend;
use crate::model::params::{LevelSchedule, NetParams, PlaneCut};
use crate::refactor::Volume;
use std::fmt;
use std::time::Duration;

/// What the user asks Janus to guarantee (PAPER.md §3.2) — the single
/// contract type shared by the facade, the engines, and the workflow
/// scheduler (it replaces the old `sender::Contract` /
/// `scheduler::JobContract` pair, which had silently drifted apart).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Contract {
    /// Guaranteed fidelity (Alg. 1): deliver every level needed for this
    /// relative L∞ error bound, retransmitting until recovered.
    Fidelity(f64),
    /// Guaranteed time (Alg. 2): deliver the best level prefix possible
    /// within this many seconds. Single-stream: one pass, no
    /// retransmission. Pooled (`streams > 1`): retransmission passes run
    /// while a virtual τ budget lasts, shedding late levels (and plane-
    /// cut tails) at pass barriers when it no longer does.
    Deadline(f64),
    /// No constraint declared: deliver the full dataset reliably (every
    /// level, retransmitting as needed), with parity still adapted to the
    /// measured loss rate.
    BestEffort,
}

impl Contract {
    /// Whether this contract retransmits until everything is recovered
    /// (everything except `Deadline`, whose retransmission — pooled
    /// engine only — is bounded by the τ budget instead).
    pub fn retransmits(&self) -> bool {
        !matches!(self, Contract::Deadline(_))
    }
}

/// A validated-at-construction transfer specification error.
#[derive(Debug, Clone, PartialEq)]
pub enum SpecError {
    /// `streams` must be ≥ 1.
    ZeroStreams,
    /// `streams` must fit the wire format's u8 stream id.
    TooManyStreams(usize),
    /// Group size `n = k + m` must be ≥ 2 (one data + one slot).
    GroupTooSmall(usize),
    /// Group size `n = k + m` must fit the wire format's u8 index
    /// (≤ 255; ≤ 128 for pooled runs).
    GroupTooLarge(usize),
    /// Fragment payload size must be positive.
    ZeroFragmentSize,
    /// Fragment payload size must fit one wire datagram
    /// ([`crate::coordinator::packet::MAX_FRAGMENT_PAYLOAD`]).
    FragmentTooLarge(usize),
    /// Pacing rate (fragments/s) must be positive and finite.
    BadPacingRate(f64),
    /// A `Deadline` contract needs a positive number of seconds.
    ZeroDeadline,
    /// A `Fidelity` bound is a relative error and must lie in (0, 1).
    FidelityOutOfRange(f64),
    /// The initial λ estimate cannot be negative.
    NegativeLambda(f64),
    /// The λ measurement window must be positive.
    ZeroWindow,
    /// A dataset needs at least one level.
    EmptyDataset,
    /// One ε per level, strictly decreasing, each in (0, 1].
    BadEpsilonLadder,
    /// An [`AdaptConfig`] knob is out of range (message from
    /// [`AdaptConfig::validate`]).
    BadAdaptation(String),
    /// The fountain backend streams one seeded symbol sequence per
    /// group; it runs single-stream only (`streams == 1`).
    FountainNeedsSingleStream(usize),
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::ZeroStreams => write!(f, "spec: streams must be >= 1"),
            SpecError::TooManyStreams(n) => {
                write!(f, "spec: streams must be <= 255 (wire u8 stream id), got {n}")
            }
            SpecError::GroupTooSmall(n) => {
                write!(f, "spec: group size k+m must be >= 2, got {n}")
            }
            SpecError::GroupTooLarge(n) => write!(
                f,
                "spec: group size k+m must be <= 255 (<= 128 pooled), got {n}"
            ),
            SpecError::ZeroFragmentSize => write!(f, "spec: fragment size must be positive"),
            SpecError::FragmentTooLarge(s) => write!(
                f,
                "spec: fragment size {s} exceeds the {}-byte datagram payload limit",
                crate::coordinator::packet::MAX_FRAGMENT_PAYLOAD
            ),
            SpecError::BadPacingRate(r) => {
                write!(f, "spec: pacing rate must be positive and finite, got {r}")
            }
            SpecError::ZeroDeadline => {
                write!(f, "spec: deadline contract needs a positive number of seconds")
            }
            SpecError::FidelityOutOfRange(b) => {
                write!(f, "spec: fidelity bound must be in (0, 1), got {b}")
            }
            SpecError::NegativeLambda(l) => {
                write!(f, "spec: initial lambda cannot be negative, got {l}")
            }
            SpecError::ZeroWindow => write!(f, "spec: lambda window must be positive"),
            SpecError::EmptyDataset => write!(f, "dataset: at least one level required"),
            SpecError::BadEpsilonLadder => write!(
                f,
                "dataset: need one epsilon per level, strictly decreasing, each in (0, 1]"
            ),
            SpecError::BadAdaptation(msg) => write!(f, "spec: {msg}"),
            SpecError::FountainNeedsSingleStream(n) => write!(
                f,
                "spec: the fountain backend is single-stream (barrier-free repair \
                 streaming), got streams = {n}"
            ),
        }
    }
}

impl std::error::Error for SpecError {}

/// The refactored payload: level byte buffers (largest-error-reduction
/// first) plus the error ladder `eps[i]` = relative L∞ error after
/// receiving levels `0..=i`.
///
/// Two front doors:
/// * [`Dataset::from_volume`] — the codec path: a raw f32 volume is
///   progressively encoded against a requested ε ladder; levels become
///   precision rungs with *measured* ε and sub-level [`PlaneCut`]s.
/// * [`Dataset::raw`] — the byte-level escape hatch (today's path):
///   caller-supplied opaque buffers and ε ladder, no codec semantics.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub levels: Vec<Vec<u8>>,
    pub eps: Vec<f64>,
    /// Sub-level shed points per level (codec datasets; empty for raw).
    /// Crate-private: only the codec encoder can establish the cut
    /// invariants (`LevelSchedule::with_cuts` asserts them), so callers
    /// read via [`Dataset::cuts`] instead of mutating.
    pub(crate) cuts: Vec<Vec<PlaneCut>>,
}

impl Dataset {
    pub fn new(levels: Vec<Vec<u8>>, eps: Vec<f64>) -> Result<Dataset, SpecError> {
        if levels.is_empty() {
            return Err(SpecError::EmptyDataset);
        }
        if levels.len() != eps.len()
            || eps.iter().any(|&e| e.is_nan() || e <= 0.0 || e > 1.0)
            || eps.windows(2).any(|w| w[0] <= w[1])
        {
            return Err(SpecError::BadEpsilonLadder);
        }
        let cuts = vec![Vec::new(); levels.len()];
        Ok(Dataset { levels, eps, cuts })
    }

    /// Byte-level escape hatch: identical to [`Dataset::new`], named so
    /// call sites read as the deliberate non-codec path.
    pub fn raw(levels: Vec<Vec<u8>>, eps: Vec<f64>) -> Result<Dataset, SpecError> {
        Dataset::new(levels, eps)
    }

    /// Run `vol` through the `janus::codec` progressive encoder: each ε
    /// rung of `cfg.ladder` becomes one transfer level whose recorded ε
    /// is **measured** against the original volume, and every interior
    /// bitplane-segment boundary becomes a [`PlaneCut`] the Deadline
    /// contract can shed to.
    pub fn from_volume(vol: &Volume, cfg: &CodecConfig) -> Result<Dataset, CodecError> {
        Ok(Dataset::from_encoded(codec::encode(vol, cfg)?))
    }

    /// Wrap an already-encoded codec container.
    pub fn from_encoded(enc: Encoded) -> Dataset {
        let Encoded { rungs, eps, cuts, .. } = enc;
        let mut dataset =
            Dataset::new(rungs, eps).expect("codec encoder guarantees a valid ε ladder");
        dataset.cuts = cuts;
        dataset
    }

    pub fn total_bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.len() as u64).sum()
    }

    /// Sub-level plane cuts per level (codec datasets; empty lists for
    /// [`Dataset::raw`]).
    pub fn cuts(&self) -> &[Vec<PlaneCut>] {
        &self.cuts
    }

    /// The model-layer view of this dataset (plane cuts included).
    pub fn schedule(&self) -> LevelSchedule {
        LevelSchedule::new(
            self.levels.iter().map(|l| l.len() as u64).collect(),
            self.eps.clone(),
        )
        .with_cuts(self.cuts.clone())
    }

    /// Tightest error bound this dataset can achieve (ε of the full
    /// ladder) — what [`Contract::BestEffort`] delivers.
    pub fn finest_eps(&self) -> f64 {
        *self.eps.last().expect("validated non-empty")
    }
}

/// An immutable, validated transfer plan: contract + streams + network
/// and coding parameters + timeouts. Built via [`TransferSpec::builder`];
/// construction is the only place validation happens, so every
/// [`TransferSpec`] in flight is known-good.
#[derive(Debug, Clone)]
pub struct TransferSpec {
    contract: Contract,
    streams: usize,
    net: NetParams,
    initial_lambda: f64,
    t_w: f64,
    idle_timeout: Duration,
    max_duration: Duration,
    adapt: AdaptConfig,
    backend: Backend,
}

impl TransferSpec {
    pub fn builder() -> TransferSpecBuilder {
        TransferSpecBuilder::default()
    }

    pub fn contract(&self) -> Contract {
        self.contract
    }

    /// Concurrent streams (1 = the single-stream engine; >1 = pooled).
    pub fn streams(&self) -> usize {
        self.streams
    }

    /// Network/coding parameters; `net.r` is the **per-stream** pacing
    /// rate and `net.lambda` mirrors the initial λ estimate.
    pub fn net(&self) -> NetParams {
        self.net
    }

    pub fn initial_lambda(&self) -> f64 {
        self.initial_lambda
    }

    /// λ measurement window `T_W`, seconds.
    pub fn lambda_window(&self) -> f64 {
        self.t_w
    }

    pub fn idle_timeout(&self) -> Duration {
        self.idle_timeout
    }

    pub fn max_duration(&self) -> Duration {
        self.max_duration
    }

    /// Congestion/burst adaptation knobs (default: legacy fixed pacing).
    pub fn adaptation(&self) -> AdaptConfig {
        self.adapt
    }

    /// Erasure backend (default [`Backend::Rs`] — pass-barrier RS repair,
    /// byte-identical to every pre-backend release).
    pub fn backend(&self) -> Backend {
        self.backend
    }
}

/// Builder for [`TransferSpec`]. Defaults: `BestEffort`, 1 stream, the
/// paper's measured testbed parameters ([`NetParams::paper_default`]),
/// λ₀ = 0, T_W = 3 s, 10 s idle timeout, 600 s overall cap, legacy
/// fixed pacing ([`AdaptConfig::fixed`] — opt into the congestion
/// controller with [`TransferSpecBuilder::adaptation`]).
#[derive(Debug, Clone)]
pub struct TransferSpecBuilder {
    contract: Contract,
    streams: usize,
    net: NetParams,
    initial_lambda: f64,
    t_w: f64,
    idle_timeout: Duration,
    max_duration: Duration,
    adapt: AdaptConfig,
    backend: Backend,
}

impl Default for TransferSpecBuilder {
    fn default() -> Self {
        TransferSpecBuilder {
            contract: Contract::BestEffort,
            streams: 1,
            net: NetParams::paper_default(0.0),
            initial_lambda: 0.0,
            t_w: 3.0,
            idle_timeout: Duration::from_secs(10),
            max_duration: Duration::from_secs(600),
            adapt: AdaptConfig::fixed(),
            backend: Backend::Rs,
        }
    }
}

impl TransferSpecBuilder {
    pub fn contract(mut self, contract: Contract) -> Self {
        self.contract = contract;
        self
    }

    pub fn streams(mut self, streams: usize) -> Self {
        self.streams = streams;
        self
    }

    /// Replace all network/coding parameters at once.
    pub fn net(mut self, net: NetParams) -> Self {
        self.net = net;
        self
    }

    /// Per-stream pacing rate `r_link`, fragments/s.
    pub fn pacing_rate(mut self, r: f64) -> Self {
        self.net.r = r;
        self
    }

    /// Fragment payload size `s`, bytes.
    pub fn fragment_bytes(mut self, s: usize) -> Self {
        self.net.s = s;
        self
    }

    /// Fault-tolerant group size `n = k + m` (the EC bound).
    pub fn group_fragments(mut self, n: usize) -> Self {
        self.net.n = n;
        self
    }

    /// One-way fragment latency `t`, seconds.
    pub fn latency(mut self, t: f64) -> Self {
        self.net.t = t;
        self
    }

    /// Initial λ estimate feeding the first Eq. 8 / Eq. 12 solve.
    pub fn initial_lambda(mut self, lambda: f64) -> Self {
        self.initial_lambda = lambda;
        self
    }

    /// λ measurement window `T_W`, seconds (paper: 3 s).
    pub fn lambda_window(mut self, t_w: f64) -> Self {
        self.t_w = t_w;
        self
    }

    pub fn idle_timeout(mut self, d: Duration) -> Self {
        self.idle_timeout = d;
        self
    }

    pub fn max_duration(mut self, d: Duration) -> Self {
        self.max_duration = d;
        self
    }

    /// Congestion/burst adaptation knobs. `AdaptConfig::default()`
    /// enables the CUBIC pacer and the burst-aware λ̂ split;
    /// [`AdaptConfig::fixed`] (the spec default) keeps the legacy fixed
    /// `1/r` pacing and i.i.d. λ̂.
    pub fn adaptation(mut self, adapt: AdaptConfig) -> Self {
        self.adapt = adapt;
        self
    }

    /// Erasure backend selector. [`Backend::Rs`] (the default) keeps the
    /// classic pass-barrier engines and byte-identical wire traces;
    /// [`Backend::Fountain`] streams rateless repair symbols with compact
    /// group acks and no EndOfPass/LostList barriers (DESIGN.md §12).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Validate into an immutable [`TransferSpec`].
    pub fn build(self) -> Result<TransferSpec, SpecError> {
        if self.streams == 0 {
            return Err(SpecError::ZeroStreams);
        }
        if self.streams > 255 {
            return Err(SpecError::TooManyStreams(self.streams));
        }
        if self.net.n < 2 {
            return Err(SpecError::GroupTooSmall(self.net.n));
        }
        // k + m is carried in u8 wire fields; the pooled engine further
        // caps n at 128.
        if self.net.n > 255 || (self.streams > 1 && self.net.n > 128) {
            return Err(SpecError::GroupTooLarge(self.net.n));
        }
        if self.net.s == 0 {
            return Err(SpecError::ZeroFragmentSize);
        }
        // Channels truncate datagrams at MAX_DATAGRAM (UDP semantics);
        // an oversized s would corrupt every fragment on the wire.
        if self.net.s > crate::coordinator::packet::MAX_FRAGMENT_PAYLOAD {
            return Err(SpecError::FragmentTooLarge(self.net.s));
        }
        if !self.net.r.is_finite() || self.net.r <= 0.0 {
            return Err(SpecError::BadPacingRate(self.net.r));
        }
        if self.initial_lambda.is_nan() || self.initial_lambda < 0.0 {
            return Err(SpecError::NegativeLambda(self.initial_lambda));
        }
        if self.t_w.is_nan() || self.t_w <= 0.0 {
            return Err(SpecError::ZeroWindow);
        }
        match self.contract {
            Contract::Deadline(tau) => {
                // Finite too: the pooled engine's τ budget arithmetic
                // rejects ∞, so catch it here as a typed error.
                if !tau.is_finite() || tau <= 0.0 {
                    return Err(SpecError::ZeroDeadline);
                }
            }
            Contract::Fidelity(bound) => {
                if bound.is_nan() || bound <= 0.0 || bound >= 1.0 {
                    return Err(SpecError::FidelityOutOfRange(bound));
                }
            }
            Contract::BestEffort => {}
        }
        if let Err(e) = self.adapt.validate() {
            return Err(SpecError::BadAdaptation(e.to_string()));
        }
        if self.backend == Backend::Fountain && self.streams != 1 {
            return Err(SpecError::FountainNeedsSingleStream(self.streams));
        }
        let mut net = self.net;
        net.lambda = self.initial_lambda;
        Ok(TransferSpec {
            contract: self.contract,
            streams: self.streams,
            net,
            initial_lambda: self.initial_lambda,
            t_w: self.t_w,
            idle_timeout: self.idle_timeout,
            max_duration: self.max_duration,
            adapt: self.adapt,
            backend: self.backend,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_build() {
        let spec = TransferSpec::builder().build().unwrap();
        assert_eq!(spec.contract(), Contract::BestEffort);
        assert_eq!(spec.streams(), 1);
        assert_eq!(spec.net().n, 32);
        assert!((spec.lambda_window() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_streams_rejected() {
        let err = TransferSpec::builder().streams(0).build().unwrap_err();
        assert_eq!(err, SpecError::ZeroStreams);
    }

    #[test]
    fn too_many_streams_rejected() {
        let err = TransferSpec::builder().streams(256).build().unwrap_err();
        assert_eq!(err, SpecError::TooManyStreams(256));
    }

    #[test]
    fn group_over_255_rejected() {
        // k + m > 255 cannot be carried in the wire format's u8 fields.
        let err = TransferSpec::builder().group_fragments(256).build().unwrap_err();
        assert_eq!(err, SpecError::GroupTooLarge(256));
    }

    #[test]
    fn pooled_group_over_128_rejected() {
        let err = TransferSpec::builder()
            .streams(4)
            .contract(Contract::Fidelity(1e-7))
            .group_fragments(200)
            .build()
            .unwrap_err();
        assert_eq!(err, SpecError::GroupTooLarge(200));
        // The same n is fine single-stream.
        assert!(TransferSpec::builder().group_fragments(200).build().is_ok());
    }

    #[test]
    fn zero_deadline_rejected() {
        let err = TransferSpec::builder()
            .contract(Contract::Deadline(0.0))
            .build()
            .unwrap_err();
        assert_eq!(err, SpecError::ZeroDeadline);
        // NaN and infinite deadlines are equally meaningless (the pool's
        // τ budget arithmetic needs a finite number).
        for bad in [f64::NAN, f64::INFINITY] {
            let err = TransferSpec::builder()
                .contract(Contract::Deadline(bad))
                .build()
                .unwrap_err();
            assert_eq!(err, SpecError::ZeroDeadline);
        }
    }

    #[test]
    fn deadline_builds_pooled() {
        // The single-stream restriction is gone: Deadline contracts run
        // on the multi-stream pool with pass-barrier tau accounting.
        let spec = TransferSpec::builder()
            .contract(Contract::Deadline(10.0))
            .streams(4)
            .build()
            .unwrap();
        assert_eq!(spec.streams(), 4);
        assert_eq!(spec.contract(), Contract::Deadline(10.0));
    }

    #[test]
    fn fidelity_bound_must_be_a_relative_error() {
        for bad in [0.0, 1.0, 1.5, -0.1] {
            let err = TransferSpec::builder()
                .contract(Contract::Fidelity(bad))
                .build()
                .unwrap_err();
            assert_eq!(err, SpecError::FidelityOutOfRange(bad));
        }
        assert!(TransferSpec::builder()
            .contract(Contract::Fidelity(1e-7))
            .build()
            .is_ok());
    }

    #[test]
    fn bad_rates_and_sizes_rejected() {
        assert_eq!(
            TransferSpec::builder().fragment_bytes(0).build().unwrap_err(),
            SpecError::ZeroFragmentSize
        );
        assert_eq!(
            TransferSpec::builder().fragment_bytes(16384).build().unwrap_err(),
            SpecError::FragmentTooLarge(16384),
            "fragments must fit one MAX_DATAGRAM datagram"
        );
        assert!(TransferSpec::builder()
            .fragment_bytes(crate::coordinator::packet::MAX_FRAGMENT_PAYLOAD)
            .build()
            .is_ok());
        assert_eq!(
            TransferSpec::builder().pacing_rate(0.0).build().unwrap_err(),
            SpecError::BadPacingRate(0.0)
        );
        assert_eq!(
            TransferSpec::builder().group_fragments(1).build().unwrap_err(),
            SpecError::GroupTooSmall(1)
        );
        assert_eq!(
            TransferSpec::builder().initial_lambda(-1.0).build().unwrap_err(),
            SpecError::NegativeLambda(-1.0)
        );
        assert_eq!(
            TransferSpec::builder().lambda_window(0.0).build().unwrap_err(),
            SpecError::ZeroWindow
        );
    }

    #[test]
    fn adaptation_defaults_fixed_and_validates() {
        let spec = TransferSpec::builder().build().unwrap();
        assert_eq!(spec.adaptation(), AdaptConfig::fixed());
        assert!(!spec.adaptation().rate_control);
        let spec = TransferSpec::builder().adaptation(AdaptConfig::default()).build().unwrap();
        assert!(spec.adaptation().rate_control && spec.adaptation().burst_aware);
        let err = TransferSpec::builder()
            .adaptation(AdaptConfig { beta: 1.5, ..AdaptConfig::default() })
            .build()
            .unwrap_err();
        assert!(matches!(err, SpecError::BadAdaptation(_)), "{err}");
    }

    #[test]
    fn spec_mirrors_lambda_into_net() {
        let spec = TransferSpec::builder().initial_lambda(383.0).build().unwrap();
        assert!((spec.net().lambda - 383.0).abs() < 1e-12);
    }

    #[test]
    fn dataset_validation() {
        assert_eq!(Dataset::new(vec![], vec![]).unwrap_err(), SpecError::EmptyDataset);
        // Mismatched lengths.
        assert_eq!(
            Dataset::new(vec![vec![0u8; 4]], vec![0.1, 0.01]).unwrap_err(),
            SpecError::BadEpsilonLadder
        );
        // Non-decreasing ladder.
        assert_eq!(
            Dataset::new(vec![vec![0u8; 4], vec![0u8; 4]], vec![0.1, 0.1]).unwrap_err(),
            SpecError::BadEpsilonLadder
        );
        let d = Dataset::new(vec![vec![1u8; 4], vec![2u8; 8]], vec![0.1, 0.01]).unwrap();
        assert_eq!(d.total_bytes(), 12);
        assert!((d.finest_eps() - 0.01).abs() < 1e-15);
        assert_eq!(d.schedule().num_levels(), 2);
    }

    #[test]
    fn dataset_from_volume_measures_its_ladder() {
        use crate::refactor::{generate, GrfConfig};
        let vol = generate(16, &GrfConfig::default(), 5);
        let cfg = CodecConfig { levels: 3, ladder: vec![8e-3, 4e-4], max_planes: 22 };
        let d = Dataset::from_volume(&vol, &cfg).unwrap();
        assert_eq!(d.levels.len(), 2, "one transfer level per ε rung");
        for (rec, req) in d.eps.iter().zip(&cfg.ladder) {
            assert!(rec <= req, "recorded {rec} vs requested {req}");
        }
        // The schedule view carries the plane cuts along.
        let sched = d.schedule();
        assert_eq!(sched.cuts, d.cuts);
        // The raw escape hatch has no codec semantics.
        let r = Dataset::raw(vec![vec![0u8; 8]], vec![0.1]).unwrap();
        assert!(r.cuts.iter().all(|c| c.is_empty()));
        assert!(Dataset::from_volume(&Volume::zeros(16), &cfg).is_err());
    }

    #[test]
    fn backend_defaults_rs_and_fountain_is_single_stream() {
        let spec = TransferSpec::builder().build().unwrap();
        assert_eq!(spec.backend(), Backend::Rs);
        let spec = TransferSpec::builder().backend(Backend::Fountain).build().unwrap();
        assert_eq!(spec.backend(), Backend::Fountain);
        let err = TransferSpec::builder()
            .backend(Backend::Fountain)
            .streams(4)
            .build()
            .unwrap_err();
        assert_eq!(err, SpecError::FountainNeedsSingleStream(4));
    }

    #[test]
    fn contract_retransmits() {
        assert!(Contract::Fidelity(1e-7).retransmits());
        assert!(Contract::BestEffort.retransmits());
        assert!(!Contract::Deadline(5.0).retransmits());
    }
}
