//! Bitplane encoding of multilevel coefficients (paper §2.2).
//!
//! pMGARD stores each level's coefficients as *bitplanes* so a level can
//! itself be truncated to a precision prefix: transmit the exponent plane
//! and the top `b` mantissa planes and the reconstruction error within
//! the level is bounded by `2^(max_exp − b)`. Janus uses this to split a
//! level into sub-level precision chunks — the finest-grained unit the
//! sender can shed under a deadline.
//!
//! Encoding (per block of coefficients):
//!   * shared scale: the block's maximum absolute value fixes a common
//!     binary exponent `e_max`;
//!   * each coefficient is quantized to a sign + `planes`-bit magnitude
//!     relative to `2^{e_max}`;
//!   * magnitudes are stored transposed: plane `p` holds bit `p` of every
//!     coefficient (MSB first), so a byte-stream prefix = a precision
//!     prefix.

/// A bitplane-encoded block of f32 coefficients.
#[derive(Debug, Clone, PartialEq)]
pub struct BitplaneBlock {
    /// Number of coefficients.
    pub len: usize,
    /// Shared binary exponent: values are reconstructed as
    /// `sign · mantissa · 2^(e_max − PLANES)`.
    pub e_max: i32,
    /// Total mantissa planes encoded.
    pub planes: u8,
    /// Sign bits, bit-packed (1 = negative).
    pub signs: Vec<u8>,
    /// Mantissa planes, MSB plane first; each plane is `ceil(len/8)` bytes.
    pub plane_bits: Vec<Vec<u8>>,
}

fn pack_bits(bits: impl Iterator<Item = bool>, len: usize) -> Vec<u8> {
    let mut out = vec![0u8; len.div_ceil(8)];
    for (i, b) in bits.enumerate() {
        if b {
            out[i / 8] |= 1 << (i % 8);
        }
    }
    out
}

#[inline]
fn get_bit(bytes: &[u8], i: usize) -> bool {
    (bytes[i / 8] >> (i % 8)) & 1 == 1
}

impl BitplaneBlock {
    /// Encode `values` with `planes` mantissa bits (1..=23 useful for f32).
    pub fn encode(values: &[f32], planes: u8) -> BitplaneBlock {
        assert!(planes >= 1 && planes <= 30, "planes must be 1..=30");
        let len = values.len();
        let max_abs = values.iter().fold(0f32, |a, &v| a.max(v.abs()));
        // Exponent such that max_abs < 2^{e_max}.
        let e_max = if max_abs == 0.0 {
            0
        } else {
            max_abs.log2().floor() as i32 + 1
        };
        let scale = (2f64).powi(planes as i32 - e_max);
        let quantized: Vec<u32> = values
            .iter()
            .map(|&v| {
                let q = (v.abs() as f64 * scale).round() as u64;
                // Clamp: rounding can push max_abs to 2^planes.
                q.min((1u64 << planes) - 1) as u32
            })
            .collect();
        let signs = pack_bits(values.iter().map(|&v| v.is_sign_negative()), len);
        let plane_bits = (0..planes)
            .rev() // MSB plane first
            .map(|p| pack_bits(quantized.iter().map(|&q| (q >> p) & 1 == 1), len))
            .collect();
        BitplaneBlock { len, e_max, planes, signs, plane_bits }
    }

    /// Decode using only the first `use_planes` planes (precision prefix).
    pub fn decode_prefix(&self, use_planes: u8) -> Vec<f32> {
        let used = use_planes.min(self.planes);
        let inv_scale = (2f64).powi(self.e_max - self.planes as i32);
        // Mid-tread reconstruction offset for truncated planes: half of
        // the dropped-precision step, reduces truncation bias.
        let dropped = self.planes - used;
        let offset = if dropped > 0 { (1u64 << dropped) as f64 / 2.0 } else { 0.0 };
        (0..self.len)
            .map(|i| {
                let mut q: u64 = 0;
                for (pi, plane) in self.plane_bits.iter().take(used as usize).enumerate() {
                    if get_bit(plane, i) {
                        q |= 1 << (self.planes as usize - 1 - pi);
                    }
                }
                let mag = if q == 0 && dropped == 0 {
                    0.0
                } else if q == 0 {
                    // All transmitted planes zero: could be anywhere in
                    // [0, 2^dropped); reconstruct at 0 to keep exact
                    // zeros exact.
                    0.0
                } else {
                    (q as f64 + offset) * inv_scale
                };
                let v = mag as f32;
                if get_bit(&self.signs, i) {
                    -v
                } else {
                    v
                }
            })
            .collect()
    }

    /// Full-precision decode (all encoded planes).
    pub fn decode(&self) -> Vec<f32> {
        self.decode_prefix(self.planes)
    }

    /// Worst-case absolute error when decoding with `use_planes` planes.
    pub fn error_bound(&self, use_planes: u8) -> f64 {
        let used = use_planes.min(self.planes);
        // Quantization half-step at full precision + truncation step.
        let lsb = (2f64).powi(self.e_max - self.planes as i32);
        let trunc = (1u64 << (self.planes - used)) as f64 * lsb;
        0.5 * lsb + trunc
    }

    /// Serialize to bytes: header + signs + planes (MSB first), so a
    /// *prefix* of the byte stream decodes at reduced precision.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            16 + self.signs.len() + self.plane_bits.iter().map(|p| p.len()).sum::<usize>(),
        );
        out.extend_from_slice(&(self.len as u64).to_le_bytes());
        out.extend_from_slice(&self.e_max.to_le_bytes());
        out.push(self.planes);
        out.extend_from_slice(&self.signs);
        for plane in &self.plane_bits {
            out.extend_from_slice(plane);
        }
        out
    }

    /// Deserialize; tolerates a truncated plane suffix (missing planes are
    /// simply unavailable — the progressive property).
    pub fn from_bytes(bytes: &[u8]) -> Option<BitplaneBlock> {
        if bytes.len() < 13 {
            return None;
        }
        let len = u64::from_le_bytes(bytes[0..8].try_into().ok()?) as usize;
        let e_max = i32::from_le_bytes(bytes[8..12].try_into().ok()?);
        let planes = bytes[12];
        let stride = len.div_ceil(8);
        let mut off = 13;
        if bytes.len() < off + stride {
            return None;
        }
        let signs = bytes[off..off + stride].to_vec();
        off += stride;
        let mut plane_bits = Vec::new();
        while plane_bits.len() < planes as usize && bytes.len() >= off + stride {
            plane_bits.push(bytes[off..off + stride].to_vec());
            off += stride;
        }
        let have = plane_bits.len() as u8;
        // Missing planes decode as zeros; adjust `planes` bookkeeping by
        // padding with zero planes so decode_prefix stays correct.
        while plane_bits.len() < planes as usize {
            plane_bits.push(vec![0u8; stride]);
        }
        let mut block = BitplaneBlock { len, e_max, planes, signs, plane_bits };
        if have < planes {
            // Record effective precision via error bound behaviour: callers
            // should decode with `have` planes. We keep `planes` for scale.
            block.plane_bits.truncate(planes as usize);
        }
        Some(block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_values(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        (0..n)
            .map(|_| ((rng.next_f64() * 2.0 - 1.0) as f32) * scale)
            .collect()
    }

    #[test]
    fn full_decode_within_lsb() {
        for &scale in &[1.0f32, 100.0, 1e-3] {
            let vals = random_values(257, 1, scale);
            let block = BitplaneBlock::encode(&vals, 20);
            let dec = block.decode();
            let bound = block.error_bound(20);
            for (a, b) in vals.iter().zip(&dec) {
                assert!(
                    ((a - b).abs() as f64) <= bound,
                    "scale {scale}: |{a} − {b}| > {bound}"
                );
            }
        }
    }

    #[test]
    fn prefix_error_bound_holds_for_every_prefix() {
        let vals = random_values(500, 2, 8.0);
        let block = BitplaneBlock::encode(&vals, 16);
        for used in 1..=16u8 {
            let dec = block.decode_prefix(used);
            let bound = block.error_bound(used);
            for (a, b) in vals.iter().zip(&dec) {
                assert!(
                    ((a - b).abs() as f64) <= bound,
                    "planes {used}: |{a} − {b}| = {} > {bound}",
                    (a - b).abs()
                );
            }
        }
    }

    #[test]
    fn error_decreases_with_more_planes() {
        let vals = random_values(1000, 3, 2.0);
        let block = BitplaneBlock::encode(&vals, 20);
        let mut prev = f64::INFINITY;
        for used in (4..=20u8).step_by(4) {
            let dec = block.decode_prefix(used);
            let max_err = vals
                .iter()
                .zip(&dec)
                .map(|(a, b)| (a - b).abs() as f64)
                .fold(0.0, f64::max);
            assert!(max_err <= prev, "error grew at {used} planes");
            prev = max_err;
        }
        assert!(prev < 1e-4, "20 planes should be accurate: {prev}");
    }

    #[test]
    fn zeros_stay_exactly_zero() {
        let vals = vec![0.0f32; 64];
        let block = BitplaneBlock::encode(&vals, 12);
        assert!(block.decode().iter().all(|&v| v == 0.0));
        assert!(block.decode_prefix(3).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn byte_roundtrip_exact() {
        let vals = random_values(123, 4, 5.0);
        let block = BitplaneBlock::encode(&vals, 14);
        let bytes = block.to_bytes();
        let back = BitplaneBlock::from_bytes(&bytes).unwrap();
        assert_eq!(back, block);
    }

    #[test]
    fn truncated_bytes_decode_progressively() {
        let vals = random_values(200, 5, 3.0);
        let block = BitplaneBlock::encode(&vals, 16);
        let bytes = block.to_bytes();
        let stride = 200usize.div_ceil(8);
        // Keep header + signs + 6 planes.
        let cut = 13 + stride + 6 * stride;
        let partial = BitplaneBlock::from_bytes(&bytes[..cut]).unwrap();
        let dec = partial.decode_prefix(6);
        let bound = block.error_bound(6);
        for (a, b) in vals.iter().zip(&dec) {
            assert!(((a - b).abs() as f64) <= bound);
        }
    }

    #[test]
    fn header_too_short_rejected() {
        assert!(BitplaneBlock::from_bytes(&[0u8; 5]).is_none());
        assert!(BitplaneBlock::from_bytes(&[]).is_none());
    }

    #[test]
    fn signs_preserved() {
        let vals = vec![-1.5f32, 2.5, -0.25, 0.75];
        let block = BitplaneBlock::encode(&vals, 20);
        let dec = block.decode();
        for (a, b) in vals.iter().zip(&dec) {
            assert_eq!(a.signum(), b.signum(), "{a} vs {b}");
        }
    }
}
