//! Native Rust mirror of the L2/L1 multilevel refactorer.
//!
//! Bit-for-bit the same CDF(2,2)-style lifting scheme as
//! `python/compile/kernels/lift.py` (verified against the PJRT artifacts
//! in `rust/tests/runtime_artifacts.rs`). Used where the PJRT runtime is
//! unnecessary (tests, pure-simulation experiments) and as the oracle for
//! artifact validation.

use std::fmt;

/// Why a volume shape cannot go through the multilevel lifting pipeline.
///
/// `decompose`/`reconstruct` used to `assert!` on bad shapes, which turns
/// a malformed user input (CLI `--dim`, a foreign dataset) into a panic
/// deep inside the transform. The checked entry points
/// ([`try_decompose`], [`try_reconstruct`]) reject instead; the panicking
/// wrappers remain for trusted in-tree callers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShapeError {
    /// At least one lifting level is required.
    ZeroLevels,
    /// The volume dimension must be positive.
    ZeroDim,
    /// Lifting halves the dimension per level, so `d` must be divisible
    /// by `2^(levels−1)`; odd or non-divisible dimensions (e.g. d = 15,
    /// or d = 24 with 4 levels) have no well-defined coarse octant.
    NotDivisible { d: usize, levels: usize },
    /// Each lifting step needs rows of width ≥ 2: `d / 2^(levels−1)`
    /// must stay ≥ 1 (too many levels for this dimension).
    TooManyLevels { d: usize, levels: usize },
    /// A coefficient buffer's length does not match the `(d, levels)`
    /// geometry it claims.
    BadBufferLen { level: usize, expected: usize, got: usize },
    /// `levels_used` must satisfy `1 ≤ levels_used ≤ total_levels`.
    LevelRange { levels_used: usize, total_levels: usize },
    /// Fewer coefficient buffers supplied than `levels_used` requires.
    MissingBuffers { have: usize, need: usize },
}

impl fmt::Display for ShapeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShapeError::ZeroLevels => write!(f, "lifting: at least one level required"),
            ShapeError::ZeroDim => write!(f, "lifting: volume dimension must be positive"),
            ShapeError::NotDivisible { d, levels } => write!(
                f,
                "lifting: dimension {d} not divisible by 2^(levels-1) = {} for {levels} levels",
                1usize << (levels - 1)
            ),
            ShapeError::TooManyLevels { d, levels } => {
                write!(f, "lifting: {levels} levels leave no coarse octant for dimension {d}")
            }
            ShapeError::BadBufferLen { level, expected, got } => write!(
                f,
                "lifting: level {level} buffer has {got} coefficients, geometry needs {expected}"
            ),
            ShapeError::LevelRange { levels_used, total_levels } => write!(
                f,
                "lifting: levels_used {levels_used} outside 1..={total_levels}"
            ),
            ShapeError::MissingBuffers { have, need } => {
                write!(f, "lifting: {have} coefficient buffers supplied, {need} required")
            }
        }
    }
}

impl std::error::Error for ShapeError {}

/// Check that a `(d, d, d)` volume supports `levels` lifting levels.
pub fn validate_shape(d: usize, levels: usize) -> Result<(), ShapeError> {
    if levels == 0 {
        return Err(ShapeError::ZeroLevels);
    }
    if d == 0 {
        return Err(ShapeError::ZeroDim);
    }
    if levels > 1 {
        let div = 1usize
            .checked_shl(levels as u32 - 1)
            .ok_or(ShapeError::TooManyLevels { d, levels })?;
        if d / div == 0 {
            return Err(ShapeError::TooManyLevels { d, levels });
        }
        if d % div != 0 {
            return Err(ShapeError::NotDivisible { d, levels });
        }
    }
    Ok(())
}

/// Coefficient count of each level buffer for a `(d, levels)` geometry:
/// `[base³, 7·base³, 7·(2·base)³, …]` with `base = d / 2^(levels−1)`.
pub fn level_coeff_counts(d: usize, levels: usize) -> Result<Vec<usize>, ShapeError> {
    validate_shape(d, levels)?;
    let base = d >> (levels - 1);
    let mut counts = vec![base * base * base];
    let mut h = base;
    for _ in 1..levels {
        counts.push(7 * h * h * h);
        h *= 2;
    }
    Ok(counts)
}

/// Forward lifting along contiguous rows of width `w` (even).
///
/// `x` is a `(rows, w)` row-major view; outputs are `(rows, w/2)` coarse
/// and detail planes.
pub fn lift_forward(x: &[f32], rows: usize, w: usize) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(x.len(), rows * w);
    assert!(w % 2 == 0 && w >= 2);
    let half = w / 2;
    let mut coarse = vec![0f32; rows * half];
    let mut detail = vec![0f32; rows * half];
    for r in 0..rows {
        let row = &x[r * w..(r + 1) * w];
        let c = &mut coarse[r * half..(r + 1) * half];
        let d = &mut detail[r * half..(r + 1) * half];
        // Predict: detail_j = odd_j − (even_j + even_{j+1})/2 (clamped).
        for j in 0..half {
            let even = row[2 * j];
            let right = row[2 * (j + 1).min(half - 1)];
            d[j] = row[2 * j + 1] - 0.5 * (even + right);
        }
        // Update: coarse_j = even_j + (d_{j−1} + d_j)/4 (clamped).
        for j in 0..half {
            let dl = d[j.saturating_sub(1)];
            c[j] = row[2 * j] + 0.25 * (dl + d[j]);
        }
    }
    (coarse, detail)
}

/// Inverse lifting: `(rows, w/2)` coarse+detail → `(rows, w)` rows.
pub fn lift_inverse(coarse: &[f32], detail: &[f32], rows: usize, half: usize) -> Vec<f32> {
    assert_eq!(coarse.len(), rows * half);
    assert_eq!(detail.len(), rows * half);
    let w = half * 2;
    let mut out = vec![0f32; rows * w];
    let mut even = vec![0f32; half];
    for r in 0..rows {
        let c = &coarse[r * half..(r + 1) * half];
        let d = &detail[r * half..(r + 1) * half];
        for j in 0..half {
            let dl = d[j.saturating_sub(1)];
            even[j] = c[j] - 0.25 * (dl + d[j]);
        }
        let row = &mut out[r * w..(r + 1) * w];
        for j in 0..half {
            let right = even[(j + 1).min(half - 1)];
            row[2 * j] = even[j];
            row[2 * j + 1] = d[j] + 0.5 * (even[j] + right);
        }
    }
    out
}

/// A (D, D, D) f32 volume, row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct Volume {
    pub d: usize,
    pub data: Vec<f32>,
}

impl Volume {
    pub fn new(d: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), d * d * d);
        Volume { d, data }
    }

    pub fn zeros(d: usize) -> Self {
        Volume { d, data: vec![0.0; d * d * d] }
    }

    #[inline]
    fn at(&self, i: usize, j: usize, k: usize) -> f32 {
        self.data[(i * self.d + j) * self.d + k]
    }

    #[inline]
    fn set(&mut self, i: usize, j: usize, k: usize, v: f32) {
        self.data[(i * self.d + j) * self.d + k] = v;
    }

    /// Relative L∞ error vs another volume (paper Eq. 1).
    pub fn linf_rel_error(&self, other: &Volume) -> f64 {
        assert_eq!(self.d, other.d);
        let mut num = 0f32;
        let mut den = 0f32;
        for (a, b) in self.data.iter().zip(&other.data) {
            num = num.max((a - b).abs());
            den = den.max(a.abs());
        }
        num as f64 / den as f64
    }

    /// Transpose so the given axis becomes the contiguous (last) axis.
    fn to_last_axis(&self, axis: usize) -> Vec<f32> {
        let d = self.d;
        let mut out = vec![0f32; d * d * d];
        let mut idx = 0;
        match axis {
            2 => out.copy_from_slice(&self.data),
            1 => {
                for i in 0..d {
                    for k in 0..d {
                        for j in 0..d {
                            out[idx] = self.at(i, j, k);
                            idx += 1;
                        }
                    }
                }
            }
            0 => {
                for k in 0..d {
                    for j in 0..d {
                        for i in 0..d {
                            out[idx] = self.at(i, j, k);
                            idx += 1;
                        }
                    }
                }
            }
            _ => panic!("axis {axis}"),
        }
        out
    }

    fn from_last_axis(buf: &[f32], d: usize, axis: usize) -> Volume {
        let mut v = Volume::zeros(d);
        let mut idx = 0;
        match axis {
            2 => v.data.copy_from_slice(buf),
            1 => {
                for i in 0..d {
                    for k in 0..d {
                        for j in 0..d {
                            v.set(i, j, k, buf[idx]);
                            idx += 1;
                        }
                    }
                }
            }
            0 => {
                for k in 0..d {
                    for j in 0..d {
                        for i in 0..d {
                            v.set(i, j, k, buf[idx]);
                            idx += 1;
                        }
                    }
                }
            }
            _ => panic!("axis {axis}"),
        }
        v
    }
}

/// One separable 3-D lift step; returns the same-shape array whose
/// `[:h,:h,:h]` octant is coarse (h = d/2), matching the Python layout.
pub fn lift3d_forward(x: &Volume) -> Volume {
    let d = x.d;
    assert!(d % 2 == 0);
    let mut cur = x.clone();
    for axis in [2usize, 1, 0] {
        let rows = d * d;
        let flat = cur.to_last_axis(axis);
        let (c, det) = lift_forward(&flat, rows, d);
        let mut merged = vec![0f32; d * d * d];
        let half = d / 2;
        for r in 0..rows {
            merged[r * d..r * d + half].copy_from_slice(&c[r * half..(r + 1) * half]);
            merged[r * d + half..(r + 1) * d].copy_from_slice(&det[r * half..(r + 1) * half]);
        }
        cur = Volume::from_last_axis(&merged, d, axis);
    }
    cur
}

/// Inverse of [`lift3d_forward`].
pub fn lift3d_inverse(y: &Volume) -> Volume {
    let d = y.d;
    let half = d / 2;
    let mut cur = y.clone();
    for axis in [0usize, 1, 2] {
        let rows = d * d;
        let flat = cur.to_last_axis(axis);
        let mut c = vec![0f32; rows * half];
        let mut det = vec![0f32; rows * half];
        for r in 0..rows {
            c[r * half..(r + 1) * half].copy_from_slice(&flat[r * d..r * d + half]);
            det[r * half..(r + 1) * half].copy_from_slice(&flat[r * d + half..(r + 1) * d]);
        }
        let inv = lift_inverse(&c, &det, rows, half);
        cur = Volume::from_last_axis(&inv, d, axis);
    }
    cur
}

/// Extract the 7 detail octants in the Python layout order.
fn detail_octants(y: &Volume) -> Vec<f32> {
    let h = y.d / 2;
    let mut out = Vec::with_capacity(7 * h * h * h);
    for oi in 0..2 {
        for oj in 0..2 {
            for ok in 0..2 {
                if (oi, oj, ok) == (0, 0, 0) {
                    continue;
                }
                for i in 0..h {
                    for j in 0..h {
                        for k in 0..h {
                            out.push(y.at(oi * h + i, oj * h + j, ok * h + k));
                        }
                    }
                }
            }
        }
    }
    out
}

fn coarse_octant(y: &Volume) -> Volume {
    let h = y.d / 2;
    let mut out = Volume::zeros(h);
    for i in 0..h {
        for j in 0..h {
            for k in 0..h {
                out.set(i, j, k, y.at(i, j, k));
            }
        }
    }
    out
}

fn unflatten_octants(coarse: &Volume, det: &[f32]) -> Volume {
    let h = coarse.d;
    let d = 2 * h;
    let csize = h * h * h;
    assert_eq!(det.len(), 7 * csize);
    let mut y = Volume::zeros(d);
    for i in 0..h {
        for j in 0..h {
            for k in 0..h {
                y.set(i, j, k, coarse.at(i, j, k));
            }
        }
    }
    let mut idx = 0;
    for oi in 0..2 {
        for oj in 0..2 {
            for ok in 0..2 {
                if (oi, oj, ok) == (0, 0, 0) {
                    continue;
                }
                for i in 0..h {
                    for j in 0..h {
                        for k in 0..h {
                            y.set(oi * h + i, oj * h + j, ok * h + k, det[idx]);
                            idx += 1;
                        }
                    }
                }
            }
        }
    }
    y
}

/// Multilevel decomposition into `levels` flat f32 buffers (level 1 =
/// coarsest approximation; identical layout to the Python model).
/// Rejects shapes the lifting scheme cannot halve (odd / non-divisible
/// dimensions) with a typed [`ShapeError`].
pub fn try_decompose(x: &Volume, levels: usize) -> Result<Vec<Vec<f32>>, ShapeError> {
    validate_shape(x.d, levels)?;
    let mut details = Vec::new();
    let mut cur = x.clone();
    for _ in 0..levels - 1 {
        let y = lift3d_forward(&cur);
        details.push(detail_octants(&y));
        cur = coarse_octant(&y);
    }
    let mut out = vec![cur.data];
    details.reverse();
    out.extend(details);
    Ok(out)
}

/// Panicking wrapper over [`try_decompose`] for trusted in-tree shapes.
pub fn decompose(x: &Volume, levels: usize) -> Vec<Vec<f32>> {
    try_decompose(x, levels).expect("decompose: unsupported shape")
}

/// Progressive reconstruction from the first `levels_used` buffers;
/// missing details are zero-filled. Rejects bad geometry and
/// buffer-length mismatches with a typed [`ShapeError`].
pub fn try_reconstruct(
    buffers: &[&[f32]],
    levels_used: usize,
    total_levels: usize,
    d: usize,
) -> Result<Volume, ShapeError> {
    let counts = level_coeff_counts(d, total_levels)?;
    if levels_used < 1 || levels_used > total_levels {
        return Err(ShapeError::LevelRange { levels_used, total_levels });
    }
    if buffers.len() < levels_used {
        return Err(ShapeError::MissingBuffers { have: buffers.len(), need: levels_used });
    }
    for (li, (buf, &want)) in buffers.iter().zip(&counts).enumerate().take(levels_used) {
        if buf.len() != want {
            return Err(ShapeError::BadBufferLen { level: li, expected: want, got: buf.len() });
        }
    }
    let base = d >> (total_levels - 1);
    let mut cur = Volume::new(base, buffers[0].to_vec());
    for i in 1..total_levels {
        let h = cur.d;
        let zero;
        let det: &[f32] = if i < levels_used {
            buffers[i]
        } else {
            zero = vec![0f32; 7 * h * h * h];
            &zero
        };
        cur = lift3d_inverse(&unflatten_octants(&cur, det));
    }
    Ok(cur)
}

/// Panicking wrapper over [`try_reconstruct`] for trusted in-tree shapes.
pub fn reconstruct(buffers: &[&[f32]], levels_used: usize, total_levels: usize, d: usize) -> Volume {
    try_reconstruct(buffers, levels_used, total_levels, d)
        .expect("reconstruct: unsupported shape")
}

/// Level byte sizes for a (D, D, D) f32 volume (matches the Python model).
pub fn level_sizes(d: usize, levels: usize) -> Vec<u64> {
    let base = d >> (levels - 1);
    let mut sizes = vec![(base * base * base * 4) as u64];
    let mut h = base;
    for _ in 1..levels {
        sizes.push((7 * h * h * h * 4) as u64);
        h *= 2;
    }
    sizes
}

/// Serialize level buffers to byte vectors (little-endian f32) for the
/// transfer path, and back.
pub fn levels_to_bytes(levels: &[Vec<f32>]) -> Vec<Vec<u8>> {
    levels
        .iter()
        .map(|l| l.iter().flat_map(|v| v.to_le_bytes()).collect())
        .collect()
}

pub fn bytes_to_level(bytes: &[u8]) -> Vec<f32> {
    assert!(bytes.len() % 4 == 0);
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_volume(d: usize, seed: u64) -> Volume {
        let mut rng = Pcg64::seeded(seed);
        let data = (0..d * d * d)
            .map(|_| (rng.next_f64() * 2.0 - 1.0) as f32)
            .collect();
        Volume::new(d, data)
    }

    /// Smooth low-frequency field (decomposition error ladder needs
    /// scale structure).
    fn smooth_volume(d: usize, seed: u64) -> Volume {
        let mut rng = Pcg64::seeded(seed);
        let mut v = Volume::zeros(d);
        let tau = 2.0 * std::f64::consts::PI / d as f64;
        let modes: Vec<(f64, f64, f64, f64, f64)> = (0..10)
            .map(|_| {
                (
                    (rng.range(1, 3)) as f64,
                    (rng.range(1, 3)) as f64,
                    (rng.range(1, 3)) as f64,
                    rng.next_f64() * std::f64::consts::TAU,
                    rng.next_f64() + 0.2,
                )
            })
            .collect();
        for i in 0..d {
            for j in 0..d {
                for k in 0..d {
                    let mut val = 3.0;
                    for &(ki, kj, kk, ph, amp) in &modes {
                        val += amp
                            * (ki * i as f64 * tau + ph).cos()
                            * (kj * j as f64 * tau).cos()
                            * (kk * k as f64 * tau).cos();
                    }
                    v.set(i, j, k, val as f32);
                }
            }
        }
        v
    }

    #[test]
    fn lift_1d_roundtrip() {
        let mut rng = Pcg64::seeded(1);
        for (rows, w) in [(1, 2), (4, 8), (16, 64), (3, 256)] {
            let x: Vec<f32> = (0..rows * w).map(|_| rng.next_f64() as f32).collect();
            let (c, d) = lift_forward(&x, rows, w);
            let xi = lift_inverse(&c, &d, rows, w / 2);
            for (a, b) in x.iter().zip(&xi) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn constant_signal_zero_detail() {
        let x = vec![5.0f32; 4 * 16];
        let (c, d) = lift_forward(&x, 4, 16);
        assert!(d.iter().all(|&v| v.abs() < 1e-6));
        assert!(c.iter().all(|&v| (v - 5.0).abs() < 1e-6));
    }

    #[test]
    fn lift3d_roundtrip() {
        let x = random_volume(16, 2);
        let y = lift3d_forward(&x);
        let xi = lift3d_inverse(&y);
        assert!(x.linf_rel_error(&xi) < 1e-5);
    }

    #[test]
    fn decompose_reconstruct_exact() {
        for (d, levels) in [(16, 2), (16, 3), (32, 4)] {
            let x = random_volume(d, 3);
            let bufs = decompose(&x, levels);
            assert_eq!(bufs.len(), levels);
            let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
            let xi = reconstruct(&refs, levels, levels, d);
            assert!(
                x.linf_rel_error(&xi) < 1e-4,
                "d={d} L={levels}: {}",
                x.linf_rel_error(&xi)
            );
        }
    }

    #[test]
    fn progressive_error_decreases_on_smooth_field() {
        let d = 32;
        let x = smooth_volume(d, 4);
        let bufs = decompose(&x, 4);
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let errs: Vec<f64> = (1..=4)
            .map(|u| x.linf_rel_error(&reconstruct(&refs, u, 4, d)))
            .collect();
        for w in errs.windows(2) {
            assert!(w[0] > w[1], "ε must decrease: {errs:?}");
        }
        assert!(errs[3] < 1e-5);
    }

    #[test]
    fn level_sizes_match_buffers() {
        let x = random_volume(32, 5);
        let bufs = decompose(&x, 4);
        let sizes = level_sizes(32, 4);
        for (b, &s) in bufs.iter().zip(&sizes) {
            assert_eq!(b.len() as u64 * 4, s);
        }
        assert!(sizes.windows(2).all(|w| w[0] < w[1]), "S_i must grow");
    }

    #[test]
    fn byte_serialization_roundtrip() {
        let x = random_volume(16, 6);
        let bufs = decompose(&x, 3);
        let bytes = levels_to_bytes(&bufs);
        for (orig, by) in bufs.iter().zip(&bytes) {
            assert_eq!(&bytes_to_level(by), orig);
        }
    }

    #[test]
    fn unsupported_shapes_rejected_with_typed_error() {
        // Odd dimension: no first halving.
        let odd = Volume::zeros(15);
        assert_eq!(
            try_decompose(&odd, 2).unwrap_err(),
            ShapeError::NotDivisible { d: 15, levels: 2 }
        );
        // Even but not divisible deep enough: 24 = 8·3 supports 4 levels
        // (24 % 8 == 0) but not 5 (24 % 16 != 0).
        let v24 = Volume::zeros(24);
        assert!(try_decompose(&v24, 4).is_ok());
        assert_eq!(
            try_decompose(&v24, 5).unwrap_err(),
            ShapeError::NotDivisible { d: 24, levels: 5 }
        );
        // Degenerate requests.
        assert_eq!(try_decompose(&v24, 0).unwrap_err(), ShapeError::ZeroLevels);
        assert_eq!(validate_shape(0, 1).unwrap_err(), ShapeError::ZeroDim);
        // More levels than halvings: 8 / 2^4 == 0.
        assert_eq!(
            validate_shape(8, 5).unwrap_err(),
            ShapeError::TooManyLevels { d: 8, levels: 5 }
        );
        // Buffer-length mismatch is a typed error, not a panic.
        let bufs = decompose(&Volume::zeros(16), 2);
        let mut short = bufs[1].clone();
        short.pop();
        let refs: Vec<&[f32]> = vec![&bufs[0], &short];
        assert!(matches!(
            try_reconstruct(&refs, 2, 2, 16).unwrap_err(),
            ShapeError::BadBufferLen { level: 1, .. }
        ));
    }

    #[test]
    fn non_power_of_two_dimensions_roundtrip() {
        // 24 = 2³·3 and 12 = 2²·3 exercise the boundary clamps on rows
        // whose width is not a power of two.
        for (d, levels) in [(24usize, 3usize), (12, 2), (24, 4), (6, 2)] {
            let x = random_volume(d, 11 + d as u64);
            let bufs = try_decompose(&x, levels).unwrap();
            let counts = level_coeff_counts(d, levels).unwrap();
            for (b, &c) in bufs.iter().zip(&counts) {
                assert_eq!(b.len(), c, "d={d} L={levels}");
            }
            let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
            let xi = try_reconstruct(&refs, levels, levels, d).unwrap();
            assert!(
                x.linf_rel_error(&xi) < 1e-4,
                "d={d} L={levels}: {}",
                x.linf_rel_error(&xi)
            );
        }
    }

    #[test]
    fn boundary_clamp_rows_roundtrip_at_minimal_width() {
        // w = 2 makes half = 1, so the right-neighbour clamp
        // `(j+1).min(half-1)` and the left clamp `saturating_sub` are
        // active on every sample — the worst case for the mirrored
        // boundary handling.
        let mut rng = Pcg64::seeded(21);
        for rows in [1usize, 3, 16] {
            let x: Vec<f32> = (0..rows * 2).map(|_| rng.next_f64() as f32).collect();
            let (c, d) = lift_forward(&x, rows, 2);
            let xi = lift_inverse(&c, &d, rows, 1);
            for (a, b) in x.iter().zip(&xi) {
                assert!((a - b).abs() < 1e-5, "w=2 rows={rows}: {a} vs {b}");
            }
        }
        // The clamps must also be exact where they engage mid-row: the
        // last even sample of every row uses its own value as the
        // "right" neighbour. A linear ramp makes any asymmetry visible.
        let w = 6;
        let ramp: Vec<f32> = (0..w).map(|i| i as f32).collect();
        let (c, d) = lift_forward(&ramp, 1, w);
        let back = lift_inverse(&c, &d, 1, w / 2);
        for (a, b) in ramp.iter().zip(&back) {
            assert!((a - b).abs() < 1e-5, "ramp: {a} vs {b}");
        }
    }

    #[test]
    fn transpose_roundtrip_all_axes() {
        let x = random_volume(8, 7);
        for axis in 0..3 {
            let flat = x.to_last_axis(axis);
            let back = Volume::from_last_axis(&flat, 8, axis);
            assert_eq!(back, x, "axis {axis}");
        }
    }
}
