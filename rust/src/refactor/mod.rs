//! Multilevel data refactoring — the pMGARD substitute (paper §2.2):
//! native lifting transform mirroring the L2/L1 JAX+Pallas pipeline,
//! plus the synthetic Nyx-like field generator.

pub mod bitplane;
pub mod grf;
pub mod lifting;

pub use bitplane::BitplaneBlock;
pub use grf::{generate, GrfConfig};
pub use lifting::{
    bytes_to_level, decompose, level_coeff_counts, level_sizes, levels_to_bytes, reconstruct,
    try_decompose, try_reconstruct, validate_shape, ShapeError, Volume,
};
