//! Synthetic scientific-data generator — the Nyx-snapshot substitute
//! (DESIGN.md §3).
//!
//! Produces a smooth 3-D field with power-law spectral decay (cosine-mode
//! synthesis, amplitude ∝ |k|^−γ) over a positive baseline, mimicking the
//! large-scale-structure smoothness of cosmology fields — what gives the
//! multilevel hierarchy its decreasing-ε ladder.

use super::lifting::Volume;
use crate::util::Pcg64;

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct GrfConfig {
    /// Number of random cosine modes.
    pub modes: usize,
    /// Maximum wavenumber per axis (inclusive).
    pub kmax: usize,
    /// Spectral decay exponent γ (amplitude ∝ (ki+kj+kk)^−γ).
    pub gamma: f64,
    /// Constant baseline (keeps max|d| well away from zero).
    pub baseline: f64,
    /// Small white-noise floor as a fraction of the baseline.
    pub noise: f64,
}

impl Default for GrfConfig {
    fn default() -> Self {
        GrfConfig { modes: 24, kmax: 3, gamma: 2.5, baseline: 3.0, noise: 1e-4 }
    }
}

/// Generate a (d, d, d) synthetic field.
pub fn generate(d: usize, cfg: &GrfConfig, seed: u64) -> Volume {
    let mut rng = Pcg64::seeded(seed);
    let tau = std::f64::consts::TAU / d as f64;
    // Draw modes.
    struct Mode {
        k: [f64; 3],
        phase: [f64; 3],
        amp: f64,
    }
    let modes: Vec<Mode> = (0..cfg.modes)
        .map(|_| {
            let k = [
                rng.range(1, cfg.kmax + 1) as f64,
                rng.range(1, cfg.kmax + 1) as f64,
                rng.range(1, cfg.kmax + 1) as f64,
            ];
            let ksum = k[0] + k[1] + k[2];
            Mode {
                k,
                phase: [
                    rng.next_f64() * std::f64::consts::TAU,
                    rng.next_f64() * std::f64::consts::TAU,
                    rng.next_f64() * std::f64::consts::TAU,
                ],
                amp: (0.5 + rng.next_f64()) * ksum.powf(-cfg.gamma),
            }
        })
        .collect();
    // Precompute per-axis cosine tables: modes × d.
    let mut tables = vec![vec![0f64; 3 * d]; cfg.modes];
    for (mi, m) in modes.iter().enumerate() {
        for ax in 0..3 {
            for i in 0..d {
                tables[mi][ax * d + i] = (m.k[ax] * i as f64 * tau + m.phase[ax]).cos();
            }
        }
    }
    let mut v = Volume::zeros(d);
    let mut idx = 0;
    for i in 0..d {
        for j in 0..d {
            // Partial product over the first two axes for speed.
            let partial: Vec<f64> = modes
                .iter()
                .enumerate()
                .map(|(mi, m)| m.amp * tables[mi][i] * tables[mi][d + j])
                .collect();
            for k in 0..d {
                let mut val = cfg.baseline;
                for (mi, p) in partial.iter().enumerate() {
                    val += p * tables[mi][2 * d + k];
                }
                val += cfg.noise * cfg.baseline * (rng.next_f64() * 2.0 - 1.0);
                v.data[idx] = val as f32;
                idx += 1;
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::refactor::lifting::{decompose, reconstruct};

    #[test]
    fn deterministic_per_seed() {
        let a = generate(16, &GrfConfig::default(), 9);
        let b = generate(16, &GrfConfig::default(), 9);
        assert_eq!(a, b);
        let c = generate(16, &GrfConfig::default(), 10);
        assert_ne!(a, c);
    }

    #[test]
    fn field_is_positive_and_bounded() {
        let v = generate(32, &GrfConfig::default(), 1);
        assert!(v.data.iter().all(|&x| x.is_finite()));
        let max = v.data.iter().cloned().fold(f32::MIN, f32::max);
        let min = v.data.iter().cloned().fold(f32::MAX, f32::min);
        assert!(min > 0.0, "baseline keeps the field positive (min={min})");
        assert!(max < 10.0);
    }

    #[test]
    fn refactoring_ladder_decreases_on_generated_field() {
        // The key property the substitute must preserve: a usable
        // ε-per-level ladder like the paper's Nyx data.
        let d = 32;
        let x = generate(d, &GrfConfig::default(), 7);
        let bufs = decompose(&x, 4);
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let errs: Vec<f64> = (1..=4)
            .map(|u| x.linf_rel_error(&reconstruct(&refs, u, 4, d)))
            .collect();
        for w in errs.windows(2) {
            assert!(w[0] > w[1], "ε ladder broken: {errs:?}");
        }
        assert!(errs[0] < 0.5, "coarse level too lossy: {errs:?}");
        assert!(errs[3] < 1e-4, "full reconstruction: {errs:?}");
    }
}
