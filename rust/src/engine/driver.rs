//! Blocking drivers: the legacy one-transfer-one-channel call shape,
//! rebuilt as a thin loop over a sans-IO machine.
//!
//! A driver owns the I/O the machine refuses to do: it drains the
//! channel into `handle_datagram`, pumps `poll_transmit` onto the wire,
//! sleeps (inside `recv_into`) until the machine's `poll_timeout`, and
//! fires `handle_timeout` when it passes. This is the migration path
//! for callers that want machine-backed transfers without running a
//! [`crate::serve`] daemon; the original blocking engines remain the
//! trace-stable reference (`tests/engine_sm.rs` asserts equivalence).

use crate::coordinator::packet::MAX_DATAGRAM;
use crate::coordinator::receiver::{ReceiverConfig, ReceiverReport};
use crate::coordinator::sender::{SenderConfig, SenderReport};
use crate::engine::{ReceiverMachine, SenderMachine};
use crate::erasure::Backend;
use crate::transport::channel::Datagram;
use crate::util::err::Result;
use std::time::{Duration, Instant};

/// Poll cadence cap: even with a far-off machine deadline the driver
/// wakes this often to notice newly arrived datagrams' side effects.
const MAX_WAIT: Duration = Duration::from_millis(50);

/// The machine surface the drivers pump. Private: the public types are
/// the machines themselves.
trait Machine {
    fn handle_datagram(&mut self, buf: &[u8], now: Instant);
    fn poll_transmit(&mut self, out: &mut Vec<u8>, now: Instant) -> bool;
    fn poll_timeout(&self) -> Option<Instant>;
    fn handle_timeout(&mut self, now: Instant);
    fn is_finished(&self) -> bool;
}

impl Machine for SenderMachine {
    fn handle_datagram(&mut self, buf: &[u8], now: Instant) {
        SenderMachine::handle_datagram(self, buf, now)
    }
    fn poll_transmit(&mut self, out: &mut Vec<u8>, now: Instant) -> bool {
        SenderMachine::poll_transmit(self, out, now)
    }
    fn poll_timeout(&self) -> Option<Instant> {
        SenderMachine::poll_timeout(self)
    }
    fn handle_timeout(&mut self, now: Instant) {
        SenderMachine::handle_timeout(self, now)
    }
    fn is_finished(&self) -> bool {
        SenderMachine::is_finished(self)
    }
}

impl Machine for ReceiverMachine {
    fn handle_datagram(&mut self, buf: &[u8], now: Instant) {
        ReceiverMachine::handle_datagram(self, buf, now)
    }
    fn poll_transmit(&mut self, out: &mut Vec<u8>, now: Instant) -> bool {
        ReceiverMachine::poll_transmit(self, out, now)
    }
    fn poll_timeout(&self) -> Option<Instant> {
        ReceiverMachine::poll_timeout(self)
    }
    fn handle_timeout(&mut self, now: Instant) {
        ReceiverMachine::handle_timeout(self, now)
    }
    fn is_finished(&self) -> bool {
        ReceiverMachine::is_finished(self)
    }
}

/// Pump one machine over one channel until it finishes (real clock).
fn drive<M: Machine>(m: &mut M, chan: &mut dyn Datagram) {
    let mut rbuf = vec![0u8; MAX_DATAGRAM];
    let mut out = Vec::with_capacity(MAX_DATAGRAM);
    while !m.is_finished() {
        let mut progressed = false;
        while let Some(n) = chan.try_recv_into(&mut rbuf) {
            m.handle_datagram(&rbuf[..n], Instant::now());
            progressed = true;
        }
        while m.poll_transmit(&mut out, Instant::now()) {
            chan.send(&out);
            progressed = true;
        }
        if m.is_finished() {
            break;
        }
        if progressed {
            continue;
        }
        // Idle: block on the channel until the machine's next deadline
        // (capped so freshly queued peer datagrams are never starved).
        let now = Instant::now();
        let wait = match m.poll_timeout() {
            Some(at) => at.saturating_duration_since(now).min(MAX_WAIT),
            None => MAX_WAIT,
        };
        if wait.is_zero() {
            m.handle_timeout(now);
            continue;
        }
        if let Some(n) = chan.recv_into(&mut rbuf, wait) {
            m.handle_datagram(&rbuf[..n], Instant::now());
        } else if let Some(at) = m.poll_timeout() {
            let now = Instant::now();
            if now >= at {
                m.handle_timeout(now);
            }
        }
    }
    // Flush queued control datagrams (e.g. the receiver's final Done).
    while m.poll_transmit(&mut out, Instant::now()) {
        chan.send(&out);
    }
}

/// Run a transfer as the sender: machine-backed equivalent of
/// [`crate::coordinator::sender::transfer_sender`]'s blocking loop.
pub fn drive_sender(
    chan: &mut dyn Datagram,
    cfg: &SenderConfig,
    levels: &[Vec<u8>],
    eps: &[f64],
) -> Result<SenderReport> {
    drive_sender_backend(chan, cfg, levels, eps, Backend::Rs)
}

/// [`drive_sender`] with an explicit erasure backend
/// ([`Backend::Fountain`] = barrier-free rateless repair streaming; the
/// receive side needs no flag — it follows the manifest).
pub fn drive_sender_backend(
    chan: &mut dyn Datagram,
    cfg: &SenderConfig,
    levels: &[Vec<u8>],
    eps: &[f64],
    backend: Backend,
) -> Result<SenderReport> {
    let mut m = SenderMachine::with_backend(cfg, levels, eps, backend, Instant::now())?;
    drive(&mut m, chan);
    m.into_report()
}

/// Run a transfer as the receiver: machine-backed equivalent of
/// [`crate::coordinator::receiver::transfer_receiver`]'s blocking loop.
pub fn drive_receiver(chan: &mut dyn Datagram, cfg: &ReceiverConfig) -> Result<ReceiverReport> {
    let mut m = ReceiverMachine::new(cfg, Instant::now());
    drive(&mut m, chan);
    m.into_report()
}
