//! Sans-IO transfer engine — the single-stream protocol re-stated as
//! poll-driven state machines (DESIGN.md §10).
//!
//! The blocking engines in [`crate::coordinator`] own their sockets and
//! their clock: concurrency means threads, and testing means real time.
//! This module factors the *protocol* out of the *I/O*: a
//! [`SenderMachine`] / [`ReceiverMachine`] never touches a channel or
//! calls `Instant::now()` — every state transition is driven through
//! four calls, clocked by explicit `Instant`s the caller supplies:
//!
//! * `handle_datagram(bytes, now)` — feed one received datagram in;
//! * `poll_transmit(out, now)` — ask for the next datagram to send
//!   (pacing, handshake retries and barrier retries are all expressed
//!   as "nothing to send yet" until their timer is due);
//! * `poll_timeout()` — the next `Instant` at which the machine wants
//!   `handle_timeout` or another `poll_transmit`;
//! * `handle_timeout(now)` — let the machine act on elapsed time
//!   (failure deadlines: manifest/idle/max-duration expiry).
//!
//! One machine = one transfer = no threads, which is what lets
//! [`crate::serve`] multiplex thousands of transfers on a single event
//! loop, and what lets `tests/engine_sm.rs` script loss, reordering,
//! duplication and RTT steps against a virtual clock with no sleeps.
//!
//! The protocol logic mirrors the blocking engines statement-for-
//! statement (manifest handshake cadence, frozen FTG geometry, pass
//! barriers on the RFC 6298 RTO, pass-barrier rate verdicts); the
//! receiver side shares `collect_lost` / `reconstruct_levels` /
//! `usable_prefix` with [`crate::coordinator::receiver`] outright.
//! Two deliberate divergences, both invisible to byte-exact delivery:
//! machines emit no [`crate::api::TransferEvent`]s, and the sender
//! applies λ̂ updates at the next group-encode boundary instead of the
//! blocking engine's ≤ 64-fragment feedback-poll lag.
//!
//! [`driver`] rebuilds the blocking call shape as a thin loop over a
//! machine — the migration path for code that wants one transfer on one
//! channel without running a daemon.

pub mod driver;
pub mod receiver;
pub mod sender;

pub use driver::{drive_receiver, drive_sender, drive_sender_backend};
pub use receiver::{DecodeJob, ReceiverMachine};
pub use sender::{EncodeJob, SenderMachine};
