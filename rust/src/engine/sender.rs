//! Sans-IO sender: the Alg. 1 / Alg. 2 protocol of
//! [`crate::coordinator::sender`] as a poll-driven state machine.
//!
//! The blocking engine splits the work across a parity thread and a
//! transmission thread with a bounded pipeline between them; here the
//! same plan/geometry/pacing/barrier logic runs inline, encoding one
//! FTG lazily whenever transmission catches up with generation. The
//! wire behaviour is identical (asserted by `tests/engine_sm.rs`); only
//! the thread structure and event emission differ.

use crate::api::Contract;
use crate::coordinator::arena::FtgArena;
use crate::coordinator::packet::{
    encode_fragment_into, encode_repair_into, validate_fragment_size, FragmentHeader, Manifest,
    ManifestLevel, Packet, RepairHeader, CONTRACT_FOUNTAIN,
};
use crate::coordinator::rate::{RateController, RttEstimator};
use crate::coordinator::sender::{SenderConfig, SenderReport};
use crate::erasure::{Backend, LtCode, RsCode};
use crate::model::error_model::optimize_deadline_bitplane;
use crate::model::params::{LevelSchedule, NetParams};
use crate::model::time_model::{fountain_feasible_levels, optimize_parity};
use crate::util::err::Result;
use crate::{anyhow, bail};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Manifest handshake cadence (blocking engine: 50 tries × 100 ms).
const MANIFEST_TRIES: u32 = 50;
const MANIFEST_INTERVAL: Duration = Duration::from_millis(100);
/// End-of-pass barrier retries (blocking engine: 100 tries × RTO).
const EOP_TRIES: u32 = 100;

/// One encoded FTG: all `k + m` fragments in one strided arena.
struct StoredFtg {
    level: u8,
    ftg: u32,
    k: u8,
    m: u8,
    arena: FtgArena,
}

/// Parity work split out of the machine so a host can run it
/// off-thread: take it with [`SenderMachine::take_encode_job`], call
/// [`EncodeJob::run`] anywhere (it owns all its data), and hand it back
/// via [`SenderMachine::complete_encode_job`]. The machine emits no
/// fragments for the group until the job returns, so wire bytes are
/// identical to the inline path regardless of where `run` executes.
pub struct EncodeJob {
    ftg: StoredFtg,
    code: Arc<RsCode>,
}

impl EncodeJob {
    /// Compute the group's parity slots (the CPU-heavy part).
    pub fn run(&mut self) {
        self.ftg.arena.encode_parity(&*self.code).expect("encode");
    }
}

#[derive(Clone, Copy, Debug)]
enum State {
    /// Resending the manifest until the ack arrives.
    SendManifest { tries: u32, next_at: Instant },
    /// Streaming pass-0 fragments (paced).
    Sending,
    /// Sent `EndOfPass`, awaiting the lost list (retries on the RTO).
    Barrier { tries: u32, eop_sent_at: Instant, next_at: Instant },
    /// Streaming a retransmission pass (paced).
    Retransmit,
    /// Fountain backend only: streaming rateless repair symbols
    /// round-robin over unacked groups (paced). No barriers — groups
    /// retire on compact [`Packet::GroupAck`]s instead.
    Repair,
    Finished,
    Failed,
}

/// Rateless transmission state ([`Backend::Fountain`]): the global
/// group table in build order (both endpoints enumerate the manifest
/// identically, so group ids never ride the wire beyond a `u32`),
/// per-group ack/ESI cursors, and one [`LtCode`] per distinct `k`.
struct FountainTx {
    seed: u64,
    groups: Vec<FountainGroup>,
    acked: usize,
    cursor: usize,
    lt: HashMap<usize, LtCode>,
    neigh: Vec<usize>,
    sym: Vec<u8>,
}

pub(crate) struct FountainGroup {
    pub(crate) level: u8,
    pub(crate) ftg: u32,
    pub(crate) k: usize,
    /// Next repair ESI (starts at `k`; `0..k` were pass-0 fragments).
    pub(crate) next_esi: u32,
    pub(crate) acked: bool,
}

/// The fountain group table for level byte-sizes `sizes`: both
/// endpoints run this exact enumeration (sender over its send plan,
/// receiver over the manifest), so a group's global id, geometry and
/// data placement agree without any extra wire state. Mirrors
/// [`SenderMachine::build_group`]'s cursor arithmetic at `m0 = 0`.
pub(crate) fn fountain_table(n: usize, s: usize, sizes: &[usize]) -> Vec<FountainGroup> {
    let mut groups = Vec::new();
    for (li, &size) in sizes.iter().enumerate() {
        let mut remaining = size;
        let mut ftg = 0u32;
        while remaining > 0 {
            let k = n.max(1).min(remaining.div_ceil(s).max(1));
            groups.push(FountainGroup {
                level: li as u8,
                ftg,
                k,
                next_esi: k as u32,
                acked: false,
            });
            remaining = remaining.saturating_sub(k * s);
            ftg += 1;
        }
    }
    groups
}

/// Poll-driven single-stream sender. See the [`crate::engine`] module
/// docs for the calling convention.
pub struct SenderMachine {
    cfg: SenderConfig,
    levels: Vec<Vec<u8>>,
    start: Instant,
    state: State,
    manifest: Vec<u8>,
    // Plan (frozen at construction, like the blocking engine).
    send_levels: usize,
    limits: Vec<usize>,
    deadline_tau: Option<f64>,
    plan_m: Option<Vec<usize>>,
    manifest_m0: Vec<u8>,
    sched_sizes: Vec<u64>,
    // Pass-0 encode cursor (lazy per-group parity generation).
    li: usize,
    offset: usize,
    remaining: usize,
    ftg_id: u32,
    frag_counter: u64,
    current: Option<StoredFtg>,
    slot: usize,
    codes: HashMap<(usize, usize), Arc<RsCode>>,
    // Coding offload (serve daemon): when enabled, pass-0 parity runs
    // off-machine as `EncodeJob`s instead of inline in `next_group`.
    coding_offload: bool,
    pending_encode: Option<EncodeJob>,
    encode_inflight: bool,
    current_m: usize,
    lambda: f64,
    lambda_dirty: bool,
    // Pacing + barrier timing.
    controller: RateController,
    pace: Duration,
    rtt: RttEstimator,
    /// RFC 6298 §5.5 exponential backoff exponent. Bumped on every
    /// barrier retry, held across barriers until a clean (unretried)
    /// RTT sample arrives — without this, an RTT step upward would turn
    /// every later barrier into a spurious-retry storm that Karn's rule
    /// never lets the estimator recover from.
    backoff: u32,
    next_send: Instant,
    seq: u64,
    pass: u32,
    pass_groups: u64,
    eop_sends: u64,
    // Retransmission state.
    retain: bool,
    buf_store: HashMap<(u8, u32), StoredFtg>,
    rq: Vec<(u8, u32)>,
    rq_idx: usize,
    // Rateless repair state (None = classic RS pass barriers).
    fountain: Option<FountainTx>,
    report: SenderReport,
    error: Option<String>,
}

impl SenderMachine {
    /// Build the machine: solves the contract's plan exactly like
    /// [`crate::coordinator::sender::transfer_sender`] and queues the
    /// manifest for transmission. `now` is the transfer's start instant
    /// (all later deadlines are relative to it).
    pub fn new(
        cfg: &SenderConfig,
        levels: &[Vec<u8>],
        eps: &[f64],
        now: Instant,
    ) -> Result<SenderMachine> {
        Self::with_backend(cfg, levels, eps, Backend::Rs, now)
    }

    /// [`SenderMachine::new`] with an explicit erasure backend.
    /// [`Backend::Rs`] is the classic pass-barrier machine (every wire
    /// byte identical to [`SenderMachine::new`]); [`Backend::Fountain`]
    /// plans zero parity, flags the manifest, and follows pass 0 with
    /// the barrier-free rateless repair stream (DESIGN.md §12).
    pub fn with_backend(
        cfg: &SenderConfig,
        levels: &[Vec<u8>],
        eps: &[f64],
        backend: Backend,
        now: Instant,
    ) -> Result<SenderMachine> {
        assert_eq!(levels.len(), eps.len());
        let n = cfg.net.n;
        let s = cfg.net.s;
        validate_fragment_size(s)?;
        let rateless = backend == Backend::Fountain;
        let sched =
            LevelSchedule::new(levels.iter().map(|l| l.len() as u64).collect(), eps.to_vec())
                .with_cuts(cfg.plane_cuts.clone());

        let mut limits: Vec<usize> = levels.iter().map(|l| l.len()).collect();
        let mut manifest_eps = eps.to_vec();
        let mut cut_flags = vec![false; levels.len()];
        let (send_levels, deadline) = match cfg.contract {
            Contract::Fidelity(bound) => {
                let l = sched.levels_for_error_bound(bound).ok_or_else(|| {
                    anyhow!("error bound {bound} unachievable: ε_L = {}", eps[eps.len() - 1])
                })?;
                (l, None)
            }
            Contract::BestEffort => (levels.len(), None),
            Contract::Deadline(tau) if rateless => {
                // Barrier-free τ accounting: no repair rounds to price,
                // so the Eq. 12 search collapses to the largest level
                // prefix whose expected overhead-symbol stream fits τ.
                // No mid-pass hard stop either — the prefix was sized so
                // the whole stream (overhead included) completes in time.
                let p = NetParams { lambda: cfg.initial_lambda, ..cfg.net };
                let l = fountain_feasible_levels(&p, &sched, tau);
                if l == 0 {
                    bail!("deadline {tau}s infeasible for this schedule (fountain)");
                }
                (l, None)
            }
            Contract::Deadline(tau) => {
                let p = NetParams { lambda: cfg.initial_lambda, ..cfg.net };
                let plan = optimize_deadline_bitplane(&p, &sched, tau)
                    .ok_or_else(|| anyhow!("deadline {tau}s infeasible for this schedule"))?;
                let mut m = plan.base.m.clone();
                let mut send = plan.base.levels;
                if let Some((li, cut)) = plan.partial {
                    limits[li] = cut.bytes as usize;
                    manifest_eps[li] = cut.eps;
                    cut_flags[li] = true;
                    m.push(0); // partial level ships unprotected (§5.2.3)
                    send = li + 1;
                }
                (send, Some((tau, m)))
            }
        };
        let manifest_m0: Vec<u8> = if rateless {
            vec![0; send_levels] // rateless: repair is generated on demand
        } else {
            match &deadline {
                Some((_, m)) => m.iter().map(|&mi| mi as u8).collect(),
                None => {
                    let p = NetParams { lambda: cfg.initial_lambda, ..cfg.net };
                    let m = optimize_parity(&p, sched.total_bytes(send_levels).max(1)).m;
                    vec![m as u8; send_levels]
                }
            }
        };
        let contract_byte = u8::from(!cfg.contract.retransmits())
            | if rateless { CONTRACT_FOUNTAIN } else { 0 };
        let manifest = Packet::Manifest(Manifest {
            n: n as u8,
            s: s as u32,
            streams: 1,
            levels: (0..send_levels)
                .map(|i| ManifestLevel {
                    size: limits[i] as u64,
                    eps: manifest_eps[i],
                    m0: manifest_m0[i],
                    cut: cut_flags[i],
                })
                .collect(),
            contract: contract_byte,
        })
        .encode();

        // Fountain groups are retained whatever the contract: repair
        // symbols are generated from the stored data until acked.
        let retain = rateless || cfg.contract.retransmits();
        let current_m = if retain && !rateless {
            let p = NetParams { lambda: cfg.initial_lambda, ..cfg.net };
            optimize_parity(&p, sched.total_bytes(send_levels)).m
        } else {
            0
        };
        let fountain = rateless.then(|| {
            let sizes: Vec<usize> =
                (0..send_levels).map(|i| limits[i].min(levels[i].len())).collect();
            FountainTx {
                seed: LtCode::DEFAULT_SEED,
                groups: fountain_table(n, s, &sizes),
                acked: 0,
                cursor: 0,
                lt: HashMap::new(),
                neigh: Vec::new(),
                sym: vec![0u8; s],
            }
        });
        let mut report = SenderReport {
            fragments_sent: 0,
            data_fragments: 0,
            passes: 0,
            duration: 0.0,
            m_history: vec![(0, current_m)],
            plan_history: Vec::new(),
            encode_rate: 0.0,
            lambda_updates: Vec::new(),
            rate_history: Vec::new(),
        };
        if let Some((_, plan)) = &deadline {
            report.plan_history.push(plan.clone());
        }

        let controller = RateController::new(cfg.net.r, cfg.adapt);
        let pace = Duration::from_secs_f64(1.0 / controller.rate());
        let remaining0 = if send_levels > 0 { limits[0].min(levels[0].len()) } else { 0 };
        Ok(SenderMachine {
            cfg: cfg.clone(),
            levels: levels.to_vec(),
            start: now,
            state: State::SendManifest { tries: 0, next_at: now },
            manifest,
            send_levels,
            limits,
            deadline_tau: deadline.as_ref().map(|(tau, _)| *tau),
            plan_m: deadline.map(|(_, m)| m),
            manifest_m0,
            sched_sizes: sched.sizes.clone(),
            li: 0,
            offset: 0,
            remaining: remaining0,
            ftg_id: 0,
            frag_counter: 0,
            current: None,
            slot: 0,
            codes: HashMap::new(),
            coding_offload: false,
            pending_encode: None,
            encode_inflight: false,
            current_m,
            lambda: cfg.initial_lambda,
            lambda_dirty: false,
            controller,
            pace,
            rtt: RttEstimator::new(0.02, 0.2),
            backoff: 0,
            next_send: now,
            seq: 0,
            pass: 0,
            pass_groups: 0,
            eop_sends: 0,
            retain,
            buf_store: HashMap::new(),
            rq: Vec::new(),
            rq_idx: 0,
            fountain,
            report,
            error: None,
        })
    }

    /// Feed one received datagram (already un-tagged by the caller).
    /// Undecodable datagrams are dropped, like the blocking engine.
    pub fn handle_datagram(&mut self, buf: &[u8], now: Instant) {
        let pkt = match Packet::decode(buf) {
            Ok(p) => p,
            Err(_) => return,
        };
        match pkt {
            Packet::ManifestAck => {
                if matches!(self.state, State::SendManifest { .. }) {
                    self.state = State::Sending;
                    self.next_send = now;
                }
            }
            Packet::LambdaUpdate { lambda } => {
                self.report.lambda_updates.push(lambda);
                self.lambda = lambda;
                self.lambda_dirty = true;
            }
            Packet::LostList { pass: p, total, ftgs } => {
                if let State::Barrier { tries, eop_sent_at, .. } = self.state {
                    if p == self.pass {
                        // Karn's algorithm: only an unretried barrier
                        // yields an unambiguous RTT sample; a retried one
                        // keeps its backed-off RTO for the next barrier.
                        if tries == 1 {
                            self.rtt.observe(
                                now.saturating_duration_since(eop_sent_at).as_secs_f64(),
                            );
                            self.backoff = 0;
                        }
                        self.on_lost_list(total, ftgs, now);
                    }
                }
            }
            Packet::GroupAck { upto, bitmap } => {
                if let Some(ft) = self.fountain.as_mut() {
                    // Cumulative + bitmap, monotone and idempotent: acks
                    // may arrive duplicated, reordered or stale.
                    let len = ft.groups.len();
                    let upto = (upto as usize).min(len);
                    let mut newly = 0usize;
                    for g in ft.groups.iter_mut().take(upto) {
                        if !g.acked {
                            g.acked = true;
                            newly += 1;
                        }
                    }
                    for b in 0..64usize {
                        if bitmap >> b & 1 == 1 {
                            if let Some(g) = ft.groups.get_mut(upto + b) {
                                if !g.acked {
                                    g.acked = true;
                                    newly += 1;
                                }
                            }
                        }
                    }
                    ft.acked += newly;
                    if ft.acked == len
                        && matches!(self.state, State::Sending | State::Repair)
                    {
                        self.finish(now);
                    }
                }
            }
            Packet::Done => {
                if matches!(
                    self.state,
                    State::Sending | State::Barrier { .. } | State::Retransmit | State::Repair
                ) {
                    self.finish(now);
                }
            }
            _ => {}
        }
    }

    /// Fill `out` with the next datagram due at `now`, if any. Pacing,
    /// manifest retries and barrier retries all surface here: `false`
    /// means "nothing due yet" — [`Self::poll_timeout`] says when to ask
    /// again.
    pub fn poll_transmit(&mut self, out: &mut Vec<u8>, now: Instant) -> bool {
        match self.state {
            State::SendManifest { tries, next_at } => {
                if now < next_at {
                    return false;
                }
                if tries >= MANIFEST_TRIES {
                    self.fail("receiver did not acknowledge manifest");
                    return false;
                }
                out.clear();
                out.extend_from_slice(&self.manifest);
                self.state =
                    State::SendManifest { tries: tries + 1, next_at: now + MANIFEST_INTERVAL };
                true
            }
            State::Sending => {
                if now < self.next_send {
                    return false;
                }
                if self.current.is_none() {
                    if self.coding_offload {
                        if self.pending_encode.is_none() && !self.encode_inflight {
                            self.prepare_encode_job(now);
                            if !matches!(self.state, State::Sending) {
                                // Pass 0 exhausted → the barrier's
                                // EndOfPass is due immediately.
                                return self.poll_transmit(out, now);
                            }
                        }
                        // Parity is computing off-machine: nothing to
                        // send until `complete_encode_job`.
                        return false;
                    }
                    self.next_group(now);
                    if !matches!(self.state, State::Sending) {
                        // Pass 0 exhausted → the barrier's EndOfPass is
                        // due immediately.
                        return self.poll_transmit(out, now);
                    }
                }
                let g = self.current.as_ref().expect("current group");
                let hdr = FragmentHeader {
                    level: g.level,
                    stream: 0,
                    ftg: g.ftg,
                    index: self.slot as u8,
                    k: g.k,
                    m: g.m,
                    seq: self.seq,
                    pass: 0,
                };
                self.seq += 1;
                encode_fragment_into(&hdr, g.arena.slot(self.slot), out);
                self.next_send = now.max(self.next_send) + self.pace;
                self.report.fragments_sent += 1;
                if self.slot < g.k as usize {
                    self.report.data_fragments += 1;
                }
                self.slot += 1;
                if self.slot >= self.current.as_ref().expect("current group").arena.slots() {
                    self.finish_group(now);
                }
                true
            }
            State::Barrier { tries, next_at, .. } => {
                if now < next_at {
                    return false;
                }
                if tries >= EOP_TRIES {
                    // Blocking engine: retries exhausted means failure
                    // under a retransmission contract, success otherwise
                    // (the Deadline peer may simply be done already).
                    if self.retain {
                        self.fail("no response to EndOfPass");
                    } else {
                        self.finish(now);
                    }
                    return false;
                }
                if tries > 0 {
                    // RFC 6298 §5.5: back the timer off on every retry.
                    self.backoff = (self.backoff + 1).min(6);
                }
                Packet::EndOfPass { pass: self.pass }.encode_into(out);
                self.eop_sends += 1;
                let rto =
                    Duration::from_secs_f64(self.rtt.rto() * f64::from(1u32 << self.backoff));
                self.state =
                    State::Barrier { tries: tries + 1, eop_sent_at: now, next_at: now + rto };
                true
            }
            State::Retransmit => {
                if now < self.next_send {
                    return false;
                }
                // Advance past finished / unknown lost-list entries.
                loop {
                    if self.rq_idx >= self.rq.len() {
                        self.enter_barrier(now);
                        return self.poll_transmit(out, now);
                    }
                    match self.buf_store.get(&self.rq[self.rq_idx]) {
                        Some(g) if self.slot < g.arena.slots() => break,
                        _ => {
                            self.rq_idx += 1;
                            self.slot = 0;
                        }
                    }
                }
                let g = self.buf_store.get(&self.rq[self.rq_idx]).expect("retained group");
                let hdr = FragmentHeader {
                    level: g.level,
                    stream: 0,
                    ftg: g.ftg,
                    index: self.slot as u8,
                    k: g.k,
                    m: g.m,
                    seq: self.seq,
                    pass: self.pass,
                };
                self.seq += 1;
                encode_fragment_into(&hdr, g.arena.slot(self.slot), out);
                self.next_send = now.max(self.next_send) + self.pace;
                self.report.fragments_sent += 1;
                self.slot += 1;
                true
            }
            State::Repair => {
                if now < self.next_send {
                    return false;
                }
                let all_acked = match &self.fountain {
                    Some(ft) => ft.acked >= ft.groups.len(),
                    None => true,
                };
                if all_acked {
                    self.finish(now);
                    return false;
                }
                let s = self.cfg.net.s;
                let ft = self.fountain.as_mut().expect("repair state implies fountain");
                let total = ft.groups.len();
                let mut idx = ft.cursor % total;
                for _ in 0..total {
                    if !ft.groups[idx].acked {
                        break;
                    }
                    idx = (idx + 1) % total;
                }
                let g = &mut ft.groups[idx];
                let stored = self
                    .buf_store
                    .get(&(g.level, g.ftg))
                    .expect("fountain retains every group");
                let esi = g.next_esi;
                g.next_esi += 1;
                let k = g.k;
                let data = &stored.arena.as_slice()[..k * s];
                let lt = ft
                    .lt
                    .entry(k)
                    .or_insert_with(|| LtCode::new(k, LtCode::DEFAULT_SEED).expect("valid k"));
                lt.symbol_into(data, s, idx as u32, esi, &mut ft.neigh, &mut ft.sym);
                let hdr = RepairHeader { group: idx as u32, esi, seed: ft.seed, seq: self.seq };
                self.seq += 1;
                encode_repair_into(&hdr, &ft.sym, out);
                ft.cursor = (idx + 1) % total;
                self.next_send = now.max(self.next_send) + self.pace;
                self.report.fragments_sent += 1;
                true
            }
            State::Finished | State::Failed => false,
        }
    }

    /// The next instant at which the machine has time-gated work: a
    /// manifest/barrier retry, the pacing gate, or the max-duration
    /// failure deadline. `None` once finished or failed.
    pub fn poll_timeout(&self) -> Option<Instant> {
        let hard = self.start + self.cfg.max_duration;
        let at = match self.state {
            State::SendManifest { next_at, .. } | State::Barrier { next_at, .. } => next_at,
            State::Sending | State::Retransmit | State::Repair => {
                if self.awaiting_coding() {
                    // Nothing is due until the host returns the parity
                    // job — only the hard deadline gates time (keeps
                    // the event loop from spinning on a stale pace).
                    return Some(hard);
                }
                self.next_send
            }
            State::Finished | State::Failed => return None,
        };
        Some(at.min(hard))
    }

    /// Route pass-0 parity through the caller: when enabled,
    /// [`Self::poll_transmit`] stops encoding inline and instead parks
    /// an [`EncodeJob`] for [`Self::take_encode_job`]; transmission
    /// resumes once [`Self::complete_encode_job`] hands it back.
    pub fn set_coding_offload(&mut self, on: bool) {
        self.coding_offload = on;
    }

    /// Take the parked parity job, if any (marks it in flight).
    pub fn take_encode_job(&mut self) -> Option<EncodeJob> {
        let job = self.pending_encode.take();
        if job.is_some() {
            self.encode_inflight = true;
        }
        job
    }

    /// Return a completed parity job. The group is dropped (not an
    /// error) if the transfer left pass 0 while the job was in flight —
    /// a racing `Done` wins.
    pub fn complete_encode_job(&mut self, job: EncodeJob) {
        self.encode_inflight = false;
        if matches!(self.state, State::Sending) {
            self.current = Some(job.ftg);
            self.slot = 0;
        }
    }

    /// Is transmission blocked on an off-machine parity job?
    fn awaiting_coding(&self) -> bool {
        matches!(self.state, State::Sending)
            && self.coding_offload
            && self.current.is_none()
            && (self.pending_encode.is_some() || self.encode_inflight)
    }

    /// Act on elapsed time: enforces the max-duration failure deadline.
    /// Spurious calls (timer fired early or late) are harmless.
    pub fn handle_timeout(&mut self, now: Instant) {
        if matches!(self.state, State::Finished | State::Failed) {
            return;
        }
        if now.saturating_duration_since(self.start) > self.cfg.max_duration {
            let msg = match self.state {
                State::Barrier { .. } => "sender timed out waiting for lost list",
                State::Retransmit => "sender exceeded max duration during retransmission",
                _ => "sender exceeded max duration",
            };
            self.fail(msg);
        }
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, State::Finished | State::Failed)
    }

    pub fn is_failed(&self) -> bool {
        matches!(self.state, State::Failed)
    }

    /// Current barrier retry timeout (RFC 6298 RTO), seconds — the
    /// RTT-step scenario test asserts re-convergence through this.
    pub fn rto(&self) -> f64 {
        self.rtt.rto()
    }

    /// `EndOfPass` datagrams sent so far (spurious-retry accounting).
    pub fn eop_sends(&self) -> u64 {
        self.eop_sends
    }

    /// Current pass number (0 = initial transmission).
    pub fn pass(&self) -> u32 {
        self.pass
    }

    /// Consume the machine into its report. Errors if the transfer
    /// failed or is still in flight.
    pub fn into_report(self) -> Result<SenderReport> {
        match self.state {
            State::Finished => Ok(self.report),
            State::Failed => {
                bail!("{}", self.error.unwrap_or_else(|| "sender failed".into()))
            }
            _ => bail!("sender machine still running"),
        }
    }

    fn fail(&mut self, msg: &str) {
        self.error = Some(msg.to_string());
        self.state = State::Failed;
    }

    fn finish(&mut self, now: Instant) {
        let elapsed = now.saturating_duration_since(self.start).as_secs_f64();
        self.report.duration = elapsed;
        self.report.encode_rate = self.frag_counter as f64 / elapsed.max(1e-9);
        self.state = State::Finished;
    }

    fn enter_barrier(&mut self, now: Instant) {
        self.state = State::Barrier { tries: 0, eop_sent_at: now, next_at: now };
    }

    /// A group's last fragment went out: retain it for retransmission,
    /// count it toward the pass, and apply the Deadline hard stop.
    fn finish_group(&mut self, now: Instant) {
        let g = self.current.take().expect("current group");
        self.pass_groups += 1;
        if self.retain {
            self.buf_store.insert((g.level, g.ftg), g);
        }
        self.slot = 0;
        if let Some(tau) = self.deadline_tau {
            if now.saturating_duration_since(self.start).as_secs_f64() >= tau {
                // Deadline contract: hard stop at τ (skip the rest of
                // pass 0, like the blocking engine's loop break).
                self.enter_barrier(now);
            }
        }
    }

    /// Advance the pass-0 cursor and slice the next FTG's data slots
    /// into a fresh arena (parity slots still zero). `None` means the
    /// plan is exhausted and the machine entered the barrier. Mirrors
    /// the blocking parity thread: λ̂ re-solves happen at group
    /// boundaries, geometry stays frozen at the manifest's m0.
    fn build_group(&mut self, now: Instant) -> Option<(StoredFtg, Arc<RsCode>)> {
        while self.li < self.send_levels && self.remaining == 0 {
            self.li += 1;
            if self.li < self.send_levels {
                self.offset = 0;
                self.remaining = self.limits[self.li].min(self.levels[self.li].len());
                self.ftg_id = 0;
            }
        }
        if self.li >= self.send_levels {
            if self.fountain.is_some() {
                // Barrier-free: source symbols are out; stream rateless
                // repair until the group acks drain. No EndOfPass, ever.
                self.state = State::Repair;
            } else {
                self.enter_barrier(now);
            }
            return None;
        }
        if self.lambda_dirty {
            self.lambda_dirty = false;
            // Rateless groups have no parity geometry to re-solve; λ̂
            // still lands in the report via `handle_datagram`.
            if self.retain && self.fountain.is_none() {
                let p = NetParams { lambda: self.lambda, ..self.cfg.net };
                let left = self.remaining as u64
                    + self.sched_sizes[self.li + 1..self.send_levels].iter().sum::<u64>();
                let m_new = optimize_parity(&p, left.max(1)).m;
                if m_new != self.current_m {
                    self.current_m = m_new;
                    self.report.m_history.push((self.frag_counter, m_new));
                }
            }
        }
        let s = self.cfg.net.s;
        let n = self.cfg.net.n;
        let m = match &self.plan_m {
            Some(p) => p[self.li],
            None => self.current_m,
        };
        let k = n
            .saturating_sub(self.manifest_m0[self.li] as usize)
            .max(1)
            .min(self.remaining.div_ceil(s).max(1));
        let code = self
            .codes
            .entry((k, m))
            .or_insert_with(|| Arc::new(RsCode::new(k, m).expect("valid k,m")))
            .clone();
        let mut arena = FtgArena::new(k as u8, m as u8, s);
        let limit = self.limits[self.li].min(self.levels[self.li].len());
        arena.fill_data(&self.levels[self.li][..limit], self.offset);
        self.offset += k * s;
        self.remaining = self.remaining.saturating_sub(k * s);
        self.frag_counter += arena.slots() as u64;
        let ftg = StoredFtg {
            level: self.li as u8,
            ftg: self.ftg_id,
            k: k as u8,
            m: m as u8,
            arena,
        };
        self.ftg_id += 1;
        Some((ftg, code))
    }

    /// Encode the next FTG of pass 0 inline (lazy parity generation) or
    /// enter the barrier when the plan is exhausted.
    fn next_group(&mut self, now: Instant) {
        let Some((mut ftg, code)) = self.build_group(now) else {
            return;
        };
        ftg.arena.encode_parity(&*code).expect("encode");
        self.current = Some(ftg);
        self.slot = 0;
    }

    /// Offload variant of [`Self::next_group`]: park the data-filled
    /// group as an [`EncodeJob`] instead of encoding inline.
    fn prepare_encode_job(&mut self, now: Instant) {
        let Some((ftg, code)) = self.build_group(now) else {
            return;
        };
        self.pending_encode = Some(EncodeJob { ftg, code });
    }

    /// Barrier resolved with a lost list: finish if it is empty, else
    /// run the pass-barrier rate verdict and start the retransmission
    /// pass (Alg. 1).
    fn on_lost_list(&mut self, total: u32, ftgs: Vec<(u8, u32)>, now: Instant) {
        if ftgs.is_empty() || !self.retain {
            self.finish(now);
            return;
        }
        let loss_frac = (total as f64 / self.pass_groups.max(1) as f64).min(1.0);
        self.controller.on_pass(
            now.saturating_duration_since(self.start).as_secs_f64(),
            loss_frac,
            1.0,
        );
        self.report.rate_history.push(self.controller.rate());
        self.pace = Duration::from_secs_f64(1.0 / self.controller.rate());
        self.pass += 1;
        self.pass_groups = ftgs.len() as u64;
        self.report.passes = self.pass;
        self.rq = ftgs;
        self.rq_idx = 0;
        self.slot = 0;
        self.state = State::Retransmit;
    }
}
