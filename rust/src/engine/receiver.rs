//! Sans-IO receiver: the reassembly/recovery/feedback protocol of
//! [`crate::coordinator::receiver`] as a poll-driven state machine.
//!
//! Reconstruction, lost-FTG enumeration and the usable-prefix walk are
//! literally shared with the blocking engine (`reconstruct_levels`,
//! `collect_lost`, `usable_prefix`), so the two cannot drift. Outgoing
//! control datagrams (ManifestAck, λ̂ updates, lost lists, Done) queue
//! internally and drain through `poll_transmit` — the receiver has no
//! pacing, so the queue empties as fast as the caller pumps it.

use crate::bail;
use crate::coordinator::arena::FtgArena;
use crate::coordinator::packet::{
    validate_fragment_size, Manifest, Packet, PacketView, MAX_LOST_PER_MSG,
};
use crate::coordinator::receiver::{
    collect_lost, reconstruct_levels, usable_prefix, ReceiverConfig, ReceiverReport,
};
use crate::engine::sender::fountain_table;
use crate::erasure::{FountainDecoder, LtCode, RsCode};
use crate::util::err::Result;
use std::collections::{HashMap, VecDeque};
use std::time::Instant;

/// Rateless receive state ([`crate::erasure::Backend::Fountain`]),
/// entered when the manifest carries the fountain contract flag — the
/// receive side needs no configuration, it follows the wire.
struct FountainRx {
    /// `(level, byte-offset-in-level, k)` per global group id, in the
    /// shared manifest enumeration order (see
    /// [`crate::engine::sender::fountain_table`]).
    groups: Vec<(u8, usize, usize)>,
    /// `(level, ftg) → global group id` for systematic fragments.
    map: HashMap<(u8, u32), u32>,
    /// Lazily created per-group decoders, dropped on completion.
    decoders: HashMap<u32, FountainDecoder>,
    done: Vec<bool>,
    /// Groups that received at least one repair (non-systematic) symbol.
    saw_repair: Vec<bool>,
    completed: usize,
    /// Completed groups that needed repair symbols (report statistic —
    /// the fountain analogue of `groups_recovered`).
    repaired: u64,
    /// Assembled level payloads (written group by group).
    levels: Vec<Vec<u8>>,
    /// Symbols since the last ack went out (periodic re-ack cadence).
    since_ack: u32,
}

/// Re-ack cadence: a fresh [`Packet::GroupAck`] also goes out every
/// this many received symbols, so a lost ack only costs a short burst
/// of redundant repair symbols, never a stall.
const ACK_EVERY: u32 = 32;

/// Compress the done-set into the compact ack: `upto` = longest fully
/// complete prefix, `bitmap` = the 64 groups after it.
fn ack_of(done: &[bool]) -> (u32, u64) {
    let upto = done.iter().take_while(|&&d| d).count();
    let mut bitmap = 0u64;
    for (b, &d) in done[upto..].iter().take(64).enumerate() {
        if d {
            bitmap |= 1u64 << b;
        }
    }
    (upto as u32, bitmap)
}

#[derive(Clone, Copy, Debug)]
enum State {
    AwaitManifest,
    Receiving,
    /// Transfer is over on the wire; reconstruction is running
    /// off-machine as a [`DecodeJob`] (coding offload only).
    Decoding,
    Finished,
    Failed,
}

/// Reconstruction work split out of the machine so a host can run it
/// off-thread: take it with [`ReceiverMachine::take_decode_job`], call
/// [`DecodeJob::run`] anywhere (it owns the manifest, group arenas and
/// decode caches), and hand it back via
/// [`ReceiverMachine::complete_decode_job`] to finalize the report.
pub struct DecodeJob {
    manifest: Manifest,
    groups: HashMap<(u8, u32), FtgArena>,
    codes: HashMap<(u8, u8), RsCode>,
    s: usize,
    finished_at: Instant,
    out: Option<(Vec<Option<Vec<u8>>>, u64)>,
}

impl DecodeJob {
    /// Reconstruct every level (the CPU-heavy part).
    pub fn run(&mut self) {
        self.out =
            Some(reconstruct_levels(&self.manifest, &self.groups, self.s, &mut self.codes, None));
    }
}

/// Poll-driven single-stream receiver. See the [`crate::engine`] module
/// docs for the calling convention. Note that queued control datagrams
/// (the final `Done` in particular) may still be pending after
/// [`Self::is_finished`] turns true — drain `poll_transmit` before
/// retiring the machine.
pub struct ReceiverMachine {
    cfg: ReceiverConfig,
    start: Instant,
    state: State,
    manifest: Option<Manifest>,
    retransmitting: bool,
    s: usize,
    groups: HashMap<(u8, u32), FtgArena>,
    codes: HashMap<(u8, u8), RsCode>,
    pending: VecDeque<Vec<u8>>,
    window_start: Instant,
    window_received: u64,
    window_first_seq: Option<u64>,
    window_max_seq: u64,
    last_packet: Instant,
    // Coding offload (serve daemon): when enabled, final reconstruction
    // runs off-machine as a `DecodeJob` instead of inline in `finish`.
    coding_offload: bool,
    pending_decode: Option<DecodeJob>,
    decode_inflight: bool,
    // Rateless decode state (None = classic RS pass barriers).
    fountain: Option<FountainRx>,
    report: ReceiverReport,
    error: Option<String>,
}

impl ReceiverMachine {
    /// `now` is the transfer's start instant; the manifest/idle/
    /// max-duration deadlines are relative to it.
    pub fn new(cfg: &ReceiverConfig, now: Instant) -> ReceiverMachine {
        ReceiverMachine {
            cfg: cfg.clone(),
            start: now,
            state: State::AwaitManifest,
            manifest: None,
            retransmitting: false,
            s: 0,
            groups: HashMap::new(),
            codes: HashMap::new(),
            pending: VecDeque::new(),
            window_start: now,
            window_received: 0,
            window_first_seq: None,
            window_max_seq: 0,
            last_packet: now,
            coding_offload: false,
            pending_decode: None,
            decode_inflight: false,
            fountain: None,
            report: ReceiverReport {
                levels: Vec::new(),
                achieved_eps: 1.0,
                levels_recovered: 0,
                fragments_received: 0,
                groups_recovered: 0,
                lambda_reports: Vec::new(),
                duration: 0.0,
            },
            error: None,
        }
    }

    /// Feed one received datagram (already un-tagged by the caller).
    pub fn handle_datagram(&mut self, buf: &[u8], now: Instant) {
        match self.state {
            State::AwaitManifest => {
                if let Ok(Packet::Manifest(m)) = Packet::decode(buf) {
                    let s = m.s as usize;
                    if validate_fragment_size(s).is_err() {
                        self.fail("receiver: manifest fragment size exceeds datagram limit");
                        return;
                    }
                    self.pending.push_back(Packet::ManifestAck.encode());
                    self.report.levels = vec![None; m.levels.len()];
                    self.retransmitting = m.contract_mode() == 0;
                    if m.is_fountain() {
                        // Enumerate the shared group table from the
                        // manifest — identical to the sender's, so
                        // global group ids agree without negotiation.
                        let sizes: Vec<usize> =
                            m.levels.iter().map(|l| l.size as usize).collect();
                        let table = fountain_table(m.n as usize, s, &sizes);
                        let mut offsets = vec![0usize; m.levels.len()];
                        let mut groups = Vec::with_capacity(table.len());
                        let mut map = HashMap::with_capacity(table.len());
                        for (gi, g) in table.iter().enumerate() {
                            let off = offsets[g.level as usize];
                            offsets[g.level as usize] += g.k * s;
                            groups.push((g.level, off, g.k));
                            map.insert((g.level, g.ftg), gi as u32);
                        }
                        let count = groups.len();
                        self.fountain = Some(FountainRx {
                            groups,
                            map,
                            decoders: HashMap::new(),
                            done: vec![false; count],
                            saw_repair: vec![false; count],
                            completed: 0,
                            repaired: 0,
                            levels: sizes.into_iter().map(|sz| vec![0u8; sz]).collect(),
                            since_ack: 0,
                        });
                    }
                    self.s = s;
                    self.manifest = Some(m);
                    self.state = State::Receiving;
                    self.last_packet = now;
                    self.window_start = now;
                    // An empty fountain dataset is complete on arrival.
                    if self.fountain.as_ref().is_some_and(|f| f.groups.is_empty()) {
                        self.pending.push_back(Packet::Done.encode());
                        self.finish_fountain(now);
                    }
                }
            }
            State::Receiving => {
                self.last_packet = now;
                match PacketView::decode(buf) {
                    Ok(PacketView::Fragment(view)) => {
                        let h = view.header;
                        self.report.fragments_received += 1;
                        self.lambda_tick(h.seq, now);
                        if self.fountain.is_some() {
                            // Systematic fountain symbol: ESI = slot index.
                            if let Some(gi) = self
                                .fountain
                                .as_ref()
                                .and_then(|f| f.map.get(&(h.level, h.ftg)).copied())
                            {
                                self.fountain_symbol(
                                    gi,
                                    h.index as u32,
                                    LtCode::DEFAULT_SEED,
                                    view.payload,
                                    now,
                                );
                            }
                            return;
                        }
                        // Copy the payload exactly once: datagram → arena.
                        // An index beyond the group's geometry is a stray
                        // datagram — dropped, never grown into a phantom
                        // shard.
                        let s = self.s;
                        let g = self
                            .groups
                            .entry((h.level, h.ftg))
                            .or_insert_with(|| FtgArena::new(h.k, h.m, s));
                        if (h.index as usize) < g.slots() {
                            g.insert(h.index as usize, view.payload);
                        }
                    }
                    Ok(PacketView::Repair(view)) => {
                        let h = view.header;
                        self.report.fragments_received += 1;
                        self.lambda_tick(h.seq, now);
                        if self.fountain.is_some() {
                            self.fountain_symbol(h.group, h.esi, h.seed, view.payload, now);
                        }
                    }
                    Ok(PacketView::Control(Packet::EndOfPass { pass })) => {
                        if self.fountain.is_some() {
                            // Barrier-free mode has no pass barriers; a
                            // stray EndOfPass gets no LostList back.
                            return;
                        }
                        let manifest = self.manifest.as_ref().expect("manifest set");
                        let lost = collect_lost(manifest, &self.groups, self.s);
                        if self.retransmitting {
                            let total = lost.len() as u32;
                            let wire: Vec<(u8, u32)> =
                                lost.iter().take(MAX_LOST_PER_MSG).copied().collect();
                            self.pending
                                .push_back(Packet::LostList { pass, total, ftgs: wire }.encode());
                            if lost.is_empty() {
                                self.pending.push_back(Packet::Done.encode());
                                self.finish(now);
                            }
                        } else {
                            // Deadline contract: take what we have.
                            self.pending.push_back(Packet::Done.encode());
                            self.finish(now);
                        }
                    }
                    Ok(PacketView::Control(Packet::Manifest(_))) => {
                        // Our ack may have been lost: re-ack so the
                        // sender stops retrying the handshake. (The
                        // blocking engine relies on a lossless control
                        // path here; the machine is also driven over
                        // lossy shared sockets.)
                        self.pending.push_back(Packet::ManifestAck.encode());
                    }
                    _ => {}
                }
            }
            State::Decoding | State::Finished | State::Failed => {}
        }
    }

    /// Pop the next queued control datagram into `out`. Unpaced: keeps
    /// returning `true` until the queue is empty.
    pub fn poll_transmit(&mut self, out: &mut Vec<u8>, _now: Instant) -> bool {
        match self.pending.pop_front() {
            Some(buf) => {
                out.clear();
                out.extend_from_slice(&buf);
                true
            }
            None => false,
        }
    }

    /// Next failure deadline: idle timeout or max duration, whichever
    /// is earlier. `None` once finished or failed.
    pub fn poll_timeout(&self) -> Option<Instant> {
        match self.state {
            State::AwaitManifest | State::Receiving => Some(
                (self.last_packet + self.cfg.idle_timeout)
                    .min(self.start + self.cfg.max_duration),
            ),
            // Awaiting the off-machine decode: the wire is quiet, so the
            // idle timer no longer applies — only the hard deadline.
            State::Decoding => Some(self.start + self.cfg.max_duration),
            State::Finished | State::Failed => None,
        }
    }

    /// Route final reconstruction through the caller: when enabled,
    /// end-of-transfer decode parks a [`DecodeJob`] for
    /// [`Self::take_decode_job`] instead of running inline; the report
    /// finalizes once [`Self::complete_decode_job`] hands it back.
    pub fn set_coding_offload(&mut self, on: bool) {
        self.coding_offload = on;
    }

    /// Take the parked decode job, if any (marks it in flight).
    pub fn take_decode_job(&mut self) -> Option<DecodeJob> {
        let job = self.pending_decode.take();
        if job.is_some() {
            self.decode_inflight = true;
        }
        job
    }

    /// Return a completed decode job and finalize the report. The
    /// transfer's duration anchors at the instant the wire went quiet
    /// (not at job completion), matching the inline path. Dropped if a
    /// racing failure deadline already killed the machine.
    pub fn complete_decode_job(&mut self, job: DecodeJob) {
        self.decode_inflight = false;
        if !matches!(self.state, State::Decoding) {
            return;
        }
        let DecodeJob { manifest, finished_at, out, .. } = job;
        let (levels, recovered) = out.expect("decode job was run");
        self.report.levels = levels;
        self.report.groups_recovered = recovered;
        let prefix = usable_prefix(&manifest, &self.report.levels);
        self.report.levels_recovered = prefix;
        self.report.achieved_eps = if prefix == 0 { 1.0 } else { manifest.levels[prefix - 1].eps };
        self.report.duration = finished_at.saturating_duration_since(self.start).as_secs_f64();
        self.manifest = Some(manifest);
        self.state = State::Finished;
    }

    /// Enforce the idle/max-duration failure deadlines. Spurious calls
    /// are harmless.
    pub fn handle_timeout(&mut self, now: Instant) {
        let over_max = now.saturating_duration_since(self.start) > self.cfg.max_duration;
        let idle = now.saturating_duration_since(self.last_packet) > self.cfg.idle_timeout;
        match self.state {
            State::AwaitManifest => {
                if over_max {
                    self.fail("receiver: no manifest");
                } else if idle {
                    self.fail("receiver: timed out waiting for manifest");
                }
            }
            State::Receiving => {
                if over_max {
                    self.fail("receiver exceeded max duration");
                } else if idle {
                    self.fail("receiver: sender went silent");
                }
            }
            State::Decoding => {
                if over_max {
                    self.fail("receiver exceeded max duration during decode");
                }
            }
            State::Finished | State::Failed => {}
        }
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, State::Finished | State::Failed)
    }

    pub fn is_failed(&self) -> bool {
        matches!(self.state, State::Failed)
    }

    /// Consume the machine into its report. Errors if the transfer
    /// failed or is still in flight.
    pub fn into_report(self) -> Result<ReceiverReport> {
        match self.state {
            State::Finished => Ok(self.report),
            State::Failed => {
                bail!("{}", self.error.unwrap_or_else(|| "receiver failed".into()))
            }
            _ => bail!("receiver machine still running"),
        }
    }

    /// λ window bookkeeping (sequence-gap based) — shared by the classic
    /// fragment path and the fountain symbol path, so λ̂ cadence and
    /// values are identical across backends at equal `(seq, arrival)`
    /// streams.
    fn lambda_tick(&mut self, seq: u64, now: Instant) {
        self.window_received += 1;
        if self.window_first_seq.is_none() {
            self.window_first_seq = Some(seq);
        }
        self.window_max_seq = self.window_max_seq.max(seq);
        let elapsed = now.saturating_duration_since(self.window_start).as_secs_f64();
        if elapsed >= self.cfg.t_w {
            let first = self.window_first_seq.unwrap_or(self.window_max_seq);
            let expected = self.window_max_seq.saturating_sub(first) + 1;
            let lost = expected.saturating_sub(self.window_received);
            let lambda_hat = lost as f64 / elapsed;
            self.report.lambda_reports.push(lambda_hat);
            self.pending.push_back(Packet::LambdaUpdate { lambda: lambda_hat }.encode());
            self.window_start = now;
            self.window_received = 0;
            self.window_first_seq = None;
        }
    }

    /// Feed one fountain symbol (systematic fragment or repair) into its
    /// group's decoder; on completion place the data, retire the
    /// decoder, and push the compact ack. Symbols for unknown or
    /// already-done groups only refresh the ack cadence.
    fn fountain_symbol(&mut self, gi: u32, esi: u32, seed: u64, payload: &[u8], now: Instant) {
        let s = self.s;
        let gid = gi as usize;
        let f = self.fountain.as_mut().expect("fountain state");
        let Some(&(level, offset, k)) = f.groups.get(gid) else {
            return; // stray group id: drop, like out-of-geometry fragments
        };
        let mut completed_now = false;
        if !f.done[gid] {
            if esi as usize >= k {
                f.saw_repair[gid] = true;
            }
            let dec = f.decoders.entry(gi).or_insert_with(|| {
                FountainDecoder::new(k, s, seed, gi).expect("group table geometry is valid")
            });
            if dec.add_symbol(esi, payload) {
                let lvl = &mut f.levels[level as usize];
                let len = (k * s).min(lvl.len().saturating_sub(offset));
                lvl[offset..offset + len].copy_from_slice(&dec.data()[..len]);
                f.decoders.remove(&gi);
                f.done[gid] = true;
                f.completed += 1;
                if f.saw_repair[gid] {
                    f.repaired += 1;
                }
                completed_now = true;
            }
        }
        f.since_ack += 1;
        let all = f.completed == f.groups.len();
        if completed_now || f.since_ack >= ACK_EVERY {
            f.since_ack = 0;
            let (upto, bitmap) = ack_of(&f.done);
            self.pending.push_back(Packet::GroupAck { upto, bitmap }.encode());
        }
        if all {
            self.pending.push_back(Packet::Done.encode());
            self.finish_fountain(now);
        }
    }

    /// Fountain counterpart of [`ReceiverMachine::finish`]: levels were
    /// assembled incrementally as groups completed, so there is no
    /// decode step left — just the report.
    fn finish_fountain(&mut self, now: Instant) {
        let manifest = self.manifest.take().expect("manifest set");
        let f = self.fountain.take().expect("fountain state");
        self.report.levels = f.levels.into_iter().map(Some).collect();
        self.report.groups_recovered = f.repaired;
        let prefix = usable_prefix(&manifest, &self.report.levels);
        self.report.levels_recovered = prefix;
        self.report.achieved_eps = if prefix == 0 { 1.0 } else { manifest.levels[prefix - 1].eps };
        self.report.duration = now.saturating_duration_since(self.start).as_secs_f64();
        self.manifest = Some(manifest);
        self.state = State::Finished;
    }

    fn fail(&mut self, msg: &str) {
        self.error = Some(msg.to_string());
        self.state = State::Failed;
    }

    fn finish(&mut self, now: Instant) {
        let manifest = self.manifest.take().expect("manifest set");
        if self.coding_offload {
            // Park reconstruction for the host; the queued Done/LostList
            // control datagrams still drain through `poll_transmit`.
            self.pending_decode = Some(DecodeJob {
                manifest,
                groups: std::mem::take(&mut self.groups),
                codes: std::mem::take(&mut self.codes),
                s: self.s,
                finished_at: now,
                out: None,
            });
            self.state = State::Decoding;
            return;
        }
        let (levels, recovered) =
            reconstruct_levels(&manifest, &self.groups, self.s, &mut self.codes, None);
        self.report.levels = levels;
        self.report.groups_recovered = recovered;
        let prefix = usable_prefix(&manifest, &self.report.levels);
        self.report.levels_recovered = prefix;
        self.report.achieved_eps = if prefix == 0 { 1.0 } else { manifest.levels[prefix - 1].eps };
        self.report.duration = now.saturating_duration_since(self.start).as_secs_f64();
        self.manifest = Some(manifest);
        self.state = State::Finished;
    }
}
