//! Bounded-interleaving concurrency model checker (DESIGN.md §13).
//!
//! A CHESS-style *stateless* explorer: the scenario's threads run as
//! real OS threads, but a central scheduler serializes them so exactly
//! one is ever executing, and every visible operation on the
//! instrumented shims ([`Mutex`], [`Condvar`], [`AtomicUsize`],
//! [`AtomicBool`]) is a *decision point* where the scheduler may switch
//! threads. [`explore`] enumerates schedules depth-first, branching at
//! every decision point whose alternative stays within the configured
//! *preemption bound* (switching away from a thread that could have
//! continued costs one preemption; switching off a blocked thread is
//! free). Empirically, almost all real concurrency bugs manifest within
//! two preemptions, so a small bound buys near-exhaustive coverage at a
//! tractable schedule count.
//!
//! The checker finds four kinds of [`Finding`]:
//! * [`Finding::Panic`] — a scenario thread panicked (assertion failed).
//! * [`Finding::Deadlock`] — no thread is runnable but some are blocked.
//! * [`Finding::Check`] — a [`Env::finally`] post-condition failed.
//! * [`Finding::StepLimit`] — a schedule exceeded `max_steps` (livelock
//!   guard).
//!
//! Modeled semantics, chosen to match how this crate uses `std::sync`:
//! mutexes are non-reentrant and unfair; condvars have FIFO wake order
//! and **no spurious wakeups** (every `std` wait in this crate is
//! wrapped in a predicate loop anyway, and removing spurious wakes
//! keeps the schedule space finite); atomics are sequentially
//! consistent regardless of the `Ordering` argument (the crate only
//! relies on SeqCst-or-stronger reasoning; weak-memory exploration is
//! out of scope). Lock poisoning is not modeled: a panic aborts the
//! schedule and is reported directly.
//!
//! Used by `rust/tests/sched_model.rs` to check faithful mirrors of the
//! three hand-rolled concurrent structures in this crate — the
//! `erasure::par::CodingPool` latch, the serve generation-fenced coding
//! completion queue, and the `transport::frame::FrameQueue` drop
//! semantics — with zero findings on the real logic and a caught
//! finding on each deliberately injected bug.
//!
//! Outside a model thread (e.g. inside [`Env::finally`] checks, which
//! run on the controller), the shims degrade to their plain `std`
//! behavior, so post-conditions can read final state directly.

use std::collections::VecDeque;
use std::panic::{catch_unwind, panic_any, AssertUnwindSafe};
use std::sync::atomic::Ordering as AtomicOrdering;
use std::sync::atomic::{AtomicBool as StdAtomicBool, AtomicUsize as StdAtomicUsize};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard};
use std::thread;

// ---------------------------------------------------------------------------
// Configuration and results
// ---------------------------------------------------------------------------

/// Exploration limits.
#[derive(Debug, Clone)]
pub struct Config {
    /// Maximum number of preemptive context switches per schedule.
    pub preemption_bound: usize,
    /// Hard cap on the number of schedules explored; hitting it clears
    /// [`Report::exhausted`].
    pub max_schedules: usize,
    /// Per-schedule decision cap (livelock guard).
    pub max_steps: usize,
}

impl Default for Config {
    fn default() -> Config {
        Config { preemption_bound: 2, max_schedules: 50_000, max_steps: 20_000 }
    }
}

impl Config {
    /// Default limits with a specific preemption bound.
    pub fn with_bound(preemption_bound: usize) -> Config {
        Config { preemption_bound, ..Config::default() }
    }
}

/// What went wrong in one schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Finding {
    /// A scenario thread panicked (message captured).
    Panic { thread: usize, message: String },
    /// No thread runnable, some not finished: the listed threads are
    /// blocked forever.
    Deadlock { blocked: Vec<usize> },
    /// A [`Env::finally`] post-condition panicked after a clean finish.
    Check { message: String },
    /// The schedule exceeded [`Config::max_steps`] decisions.
    StepLimit,
}

/// A finding plus the schedule that produced it (replayable: the
/// decision sequence is the thread id chosen at each decision point).
#[derive(Debug, Clone)]
pub struct Failure {
    pub finding: Finding,
    pub schedule: Vec<usize>,
    /// 0-based index of the failing schedule in exploration order.
    pub schedule_index: usize,
}

/// Result of [`explore`].
#[derive(Debug, Clone)]
pub struct Report {
    /// Schedules actually executed.
    pub schedules: usize,
    /// True when the bounded schedule space was fully enumerated
    /// (false on failure or when `max_schedules` was hit).
    pub exhausted: bool,
    /// First failure encountered, if any (exploration stops there).
    pub failure: Option<Failure>,
    /// FNV-1a hash over every decision of every schedule, in order —
    /// two deterministic explorations of the same scenario must agree.
    pub trace_hash: u64,
}

impl Report {
    /// Panic with the failing schedule if the check found anything.
    #[track_caller]
    pub fn assert_ok(&self) {
        if let Some(f) = &self.failure {
            panic!(
                "model check failed after {} schedule(s): {:?} (schedule {:?})",
                self.schedules, f.finding, f.schedule
            );
        }
    }

    /// Panic unless the check found something; returns the failure.
    #[track_caller]
    pub fn assert_finding(&self) -> &Failure {
        match &self.failure {
            Some(f) => f,
            None => panic!(
                "model check found nothing in {} schedule(s) (exhausted: {})",
                self.schedules, self.exhausted
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// Central scheduler state
// ---------------------------------------------------------------------------

/// Per-thread scheduler state.
#[derive(Debug, Clone, PartialEq, Eq)]
enum TState {
    /// Spawned but not yet checked in at its first decision point.
    New,
    /// Parked at a decision point, eligible to be scheduled.
    Runnable,
    /// Currently the one executing thread.
    Running,
    /// Waiting for the mutex with this registration id.
    BlockedMutex(usize),
    /// Waiting on the condvar with this registration id.
    BlockedCv(usize),
    /// Body returned (or unwound).
    Finished,
}

/// Everything the controller and the shims share.
#[derive(Debug)]
struct St {
    threads: Vec<TState>,
    /// The one thread allowed to execute, if any.
    active: Option<usize>,
    /// Set at teardown: parked threads unwind with [`AbortSignal`].
    abort: bool,
    /// Ownership per registered mutex.
    mutex_owner: Vec<Option<usize>>,
    /// FIFO wait queue per registered condvar.
    cv_queue: Vec<VecDeque<usize>>,
    /// First real (non-abort) panic: (thread, message).
    panic_msg: Option<(usize, String)>,
}

struct Ctl {
    st: StdMutex<St>,
    cv: StdCondvar,
}

impl Ctl {
    fn new() -> Ctl {
        Ctl {
            st: StdMutex::new(St {
                threads: Vec::new(),
                active: None,
                abort: false,
                mutex_owner: Vec::new(),
                cv_queue: Vec::new(),
                panic_msg: None,
            }),
            cv: StdCondvar::new(),
        }
    }
}

/// Panic payload used to unwind parked threads at teardown. Never
/// reported as a [`Finding`].
struct AbortSignal;

thread_local! {
    /// Set on model threads: which checker run this thread belongs to,
    /// and its thread id within it.
    static CURRENT: std::cell::RefCell<Option<(Arc<Ctl>, usize)>> =
        const { std::cell::RefCell::new(None) };
}

/// The calling thread's model id, if it belongs to `ctl`'s run.
fn current_for(ctl: &Arc<Ctl>) -> Option<usize> {
    CURRENT.with(|c| {
        c.borrow().as_ref().and_then(|(c2, id)| Arc::ptr_eq(c2, ctl).then_some(*id))
    })
}

/// Park the calling thread: apply `set` (its new state plus any other
/// bookkeeping) under the lock, hand control back, and block until the
/// controller schedules this thread again. Unwinds with [`AbortSignal`]
/// at teardown.
fn block_until_scheduled(ctl: &Ctl, me: usize, set: impl FnOnce(&mut St)) {
    let mut st = ctl.st.lock().unwrap();
    set(&mut st);
    if st.active == Some(me) {
        st.active = None;
    }
    ctl.cv.notify_all();
    loop {
        if st.abort {
            drop(st);
            panic_any(AbortSignal);
        }
        if st.active == Some(me) {
            break;
        }
        st = ctl.cv.wait(st).unwrap();
    }
    st.threads[me] = TState::Running;
}

/// A plain yield: park as Runnable, continue when rescheduled. The
/// decision point preceding every shim operation.
fn yield_point(ctl: &Ctl, me: usize) {
    block_until_scheduled(ctl, me, |st| st.threads[me] = TState::Runnable);
}

// ---------------------------------------------------------------------------
// Instrumented shims
// ---------------------------------------------------------------------------

struct MutexInner<T> {
    ctl: Arc<Ctl>,
    id: usize,
    cell: StdMutex<T>,
}

/// Instrumented mutex. Created via [`Env::mutex`]; clones share the
/// cell. No poisoning: [`Mutex::lock`] returns the guard directly.
pub struct Mutex<T> {
    inner: Arc<MutexInner<T>>,
}

impl<T> Clone for Mutex<T> {
    fn clone(&self) -> Self {
        Mutex { inner: Arc::clone(&self.inner) }
    }
}

/// Guard for [`Mutex`]; releasing it is a decision point.
pub struct MutexGuard<'a, T> {
    m: &'a Mutex<T>,
    inner: Option<StdMutexGuard<'a, T>>,
}

impl<T> Mutex<T> {
    /// Lock, blocking (in model time) while another thread owns it.
    pub fn lock(&self) -> MutexGuard<'_, T> {
        match current_for(&self.inner.ctl) {
            Some(me) => {
                yield_point(&self.inner.ctl, me);
                let g = acquire(&self.inner, me);
                MutexGuard { m: self, inner: Some(g) }
            }
            None => MutexGuard { m: self, inner: Some(self.inner.cell.lock().unwrap()) },
        }
    }
}

/// Claim ownership of `m` for `me`, parking as `BlockedMutex` while it
/// is owned. Returns the real guard (uncontended by construction: only
/// the registered owner ever locks the cell).
fn acquire<'a, T>(m: &'a MutexInner<T>, me: usize) -> StdMutexGuard<'a, T> {
    loop {
        let mut st = m.ctl.st.lock().unwrap();
        if st.abort {
            drop(st);
            panic_any(AbortSignal);
        }
        if st.mutex_owner[m.id].is_none() {
            st.mutex_owner[m.id] = Some(me);
            drop(st);
            return m.cell.lock().unwrap();
        }
        // Owned elsewhere: park until the owner's release wakes us.
        st.threads[me] = TState::BlockedMutex(m.id);
        if st.active == Some(me) {
            st.active = None;
        }
        m.ctl.cv.notify_all();
        loop {
            if st.abort {
                drop(st);
                panic_any(AbortSignal);
            }
            if st.active == Some(me) {
                break;
            }
            st = m.ctl.cv.wait(st).unwrap();
        }
        st.threads[me] = TState::Running;
        // Retry: another scheduled thread may have claimed it first.
    }
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard consumed")
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard consumed")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Already consumed (by Condvar::wait): nothing to release.
        let Some(real) = self.inner.take() else { return };
        drop(real);
        let ctl = &self.m.inner.ctl;
        let Some(me) = current_for(ctl) else { return };
        let mid = self.m.inner.id;
        let abort = {
            let mut st = ctl.st.lock().unwrap();
            if st.mutex_owner[mid] == Some(me) {
                st.mutex_owner[mid] = None;
            }
            wake_mutex_waiters(&mut st, mid);
            ctl.cv.notify_all();
            st.abort
        };
        // The release itself is a decision point — unless this thread
        // is unwinding (parking inside Drop during a panic would turn
        // teardown into a double panic).
        if !abort && !thread::panicking() {
            yield_point(ctl, me);
        }
    }
}

/// Move every `BlockedMutex(mid)` thread back to `Runnable`.
fn wake_mutex_waiters(st: &mut St, mid: usize) {
    for t in st.threads.iter_mut() {
        if *t == TState::BlockedMutex(mid) {
            *t = TState::Runnable;
        }
    }
}

struct CvInner {
    ctl: Arc<Ctl>,
    id: usize,
}

/// Instrumented condvar: FIFO wake order, no spurious wakeups. Only
/// usable from model threads.
pub struct Condvar {
    inner: Arc<CvInner>,
}

impl Clone for Condvar {
    fn clone(&self) -> Self {
        Condvar { inner: Arc::clone(&self.inner) }
    }
}

impl Condvar {
    /// Atomically release the guard's mutex and wait to be notified;
    /// reacquires the mutex before returning.
    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        let ctl = &self.inner.ctl;
        let me = current_for(ctl).expect("sched::Condvar::wait outside a model thread");
        let m = guard.m;
        drop(guard.inner.take().expect("guard consumed"));
        let (cvid, mid) = (self.inner.id, m.inner.id);
        block_until_scheduled(ctl, me, |st| {
            if st.mutex_owner[mid] == Some(me) {
                st.mutex_owner[mid] = None;
            }
            wake_mutex_waiters(st, mid);
            st.cv_queue[cvid].push_back(me);
            st.threads[me] = TState::BlockedCv(cvid);
        });
        // Notified and scheduled: take the mutex back.
        let real = acquire(&m.inner, me);
        MutexGuard { m, inner: Some(real) }
    }

    /// Wake the longest-waiting thread, if any.
    pub fn notify_one(&self) {
        self.notify(false)
    }

    /// Wake every waiting thread.
    pub fn notify_all(&self) {
        self.notify(true)
    }

    fn notify(&self, all: bool) {
        let ctl = &self.inner.ctl;
        let Some(me) = current_for(ctl) else { return };
        yield_point(ctl, me);
        let mut st = ctl.st.lock().unwrap();
        let cvid = self.inner.id;
        loop {
            match st.cv_queue[cvid].pop_front() {
                Some(t) => st.threads[t] = TState::Runnable,
                None => break,
            }
            if !all {
                break;
            }
        }
        ctl.cv.notify_all();
    }
}

struct AtomicInnerUsize {
    ctl: Arc<Ctl>,
    cell: StdAtomicUsize,
}

/// Instrumented atomic counter. Every operation is a decision point;
/// the `Ordering` argument is accepted for mirror fidelity but the
/// model is always sequentially consistent.
pub struct AtomicUsize {
    inner: Arc<AtomicInnerUsize>,
}

impl Clone for AtomicUsize {
    fn clone(&self) -> Self {
        AtomicUsize { inner: Arc::clone(&self.inner) }
    }
}

impl AtomicUsize {
    fn step(&self) {
        if let Some(me) = current_for(&self.inner.ctl) {
            yield_point(&self.inner.ctl, me);
        }
    }

    pub fn load(&self, _order: AtomicOrdering) -> usize {
        self.step();
        self.inner.cell.load(AtomicOrdering::SeqCst)
    }

    pub fn store(&self, value: usize, _order: AtomicOrdering) {
        self.step();
        self.inner.cell.store(value, AtomicOrdering::SeqCst)
    }

    pub fn fetch_add(&self, value: usize, _order: AtomicOrdering) -> usize {
        self.step();
        self.inner.cell.fetch_add(value, AtomicOrdering::SeqCst)
    }
}

struct AtomicInnerBool {
    ctl: Arc<Ctl>,
    cell: StdAtomicBool,
}

/// Instrumented atomic flag (see [`AtomicUsize`]).
pub struct AtomicBool {
    inner: Arc<AtomicInnerBool>,
}

impl Clone for AtomicBool {
    fn clone(&self) -> Self {
        AtomicBool { inner: Arc::clone(&self.inner) }
    }
}

impl AtomicBool {
    fn step(&self) {
        if let Some(me) = current_for(&self.inner.ctl) {
            yield_point(&self.inner.ctl, me);
        }
    }

    pub fn load(&self, _order: AtomicOrdering) -> bool {
        self.step();
        self.inner.cell.load(AtomicOrdering::SeqCst)
    }

    pub fn store(&self, value: bool, _order: AtomicOrdering) {
        self.step();
        self.inner.cell.store(value, AtomicOrdering::SeqCst)
    }
}

// ---------------------------------------------------------------------------
// Scenario environment
// ---------------------------------------------------------------------------

/// Handed to the scenario closure each schedule: registers shims,
/// thread bodies, and post-conditions. A fresh `Env` (and fresh shims)
/// is built for every schedule, so scenarios must create all state
/// through it.
pub struct Env {
    ctl: Arc<Ctl>,
    bodies: Vec<Box<dyn FnOnce() + Send + 'static>>,
    finals: Vec<Box<dyn FnOnce() + 'static>>,
}

impl Env {
    /// Register a model thread. Ids are assigned in registration order
    /// starting at 0.
    pub fn spawn(&mut self, body: impl FnOnce() + Send + 'static) {
        self.bodies.push(Box::new(body));
    }

    /// Register a post-condition, run on the controller after every
    /// cleanly finished schedule; a panic becomes [`Finding::Check`].
    pub fn finally(&mut self, check: impl FnOnce() + 'static) {
        self.finals.push(Box::new(check));
    }

    /// Create an instrumented mutex.
    pub fn mutex<T>(&mut self, value: T) -> Mutex<T> {
        let mut st = self.ctl.st.lock().unwrap();
        let id = st.mutex_owner.len();
        st.mutex_owner.push(None);
        drop(st);
        Mutex {
            inner: Arc::new(MutexInner {
                ctl: Arc::clone(&self.ctl),
                id,
                cell: StdMutex::new(value),
            }),
        }
    }

    /// Create an instrumented condvar.
    pub fn condvar(&mut self) -> Condvar {
        let mut st = self.ctl.st.lock().unwrap();
        let id = st.cv_queue.len();
        st.cv_queue.push(VecDeque::new());
        drop(st);
        Condvar { inner: Arc::new(CvInner { ctl: Arc::clone(&self.ctl), id }) }
    }

    /// Create an instrumented atomic counter.
    pub fn atomic_usize(&mut self, value: usize) -> AtomicUsize {
        AtomicUsize {
            inner: Arc::new(AtomicInnerUsize {
                ctl: Arc::clone(&self.ctl),
                cell: StdAtomicUsize::new(value),
            }),
        }
    }

    /// Create an instrumented atomic flag.
    pub fn atomic_bool(&mut self, value: bool) -> AtomicBool {
        AtomicBool {
            inner: Arc::new(AtomicInnerBool {
                ctl: Arc::clone(&self.ctl),
                cell: StdAtomicBool::new(value),
            }),
        }
    }
}

// ---------------------------------------------------------------------------
// Execution of one schedule
// ---------------------------------------------------------------------------

/// One scheduling decision: who was eligible, who ran, and whether the
/// choice preempted a thread that could have continued.
#[derive(Debug, Clone)]
struct Decision {
    runnable: Vec<usize>,
    chosen: usize,
    preemptive: bool,
}

struct Execution {
    decisions: Vec<Decision>,
    finding: Option<Finding>,
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Run the scenario once, following `prefix` at the first
/// `prefix.len()` decision points and the deterministic default
/// afterwards (keep the previous thread while it is runnable, else the
/// lowest-id runnable thread — zero preemptions).
fn run_one(scenario: &dyn Fn(&mut Env), cfg: &Config, prefix: &[usize]) -> Execution {
    let ctl = Arc::new(Ctl::new());
    let mut env = Env { ctl: Arc::clone(&ctl), bodies: Vec::new(), finals: Vec::new() };
    scenario(&mut env);
    let bodies = std::mem::take(&mut env.bodies);
    ctl.st.lock().unwrap().threads = vec![TState::New; bodies.len()];

    let handles: Vec<_> = bodies
        .into_iter()
        .enumerate()
        .map(|(i, body)| {
            let ctl = Arc::clone(&ctl);
            thread::Builder::new()
                .name(format!("sched-model-{i}"))
                .spawn(move || {
                    CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&ctl), i)));
                    let result = catch_unwind(AssertUnwindSafe(|| {
                        // Check in: the first decision point.
                        block_until_scheduled(&ctl, i, |st| st.threads[i] = TState::Runnable);
                        body();
                    }));
                    let mut st = ctl.st.lock().unwrap();
                    st.threads[i] = TState::Finished;
                    if st.active == Some(i) {
                        st.active = None;
                    }
                    if let Err(payload) = result {
                        if !payload.is::<AbortSignal>() && st.panic_msg.is_none() {
                            st.panic_msg = Some((i, panic_message(payload)));
                            st.abort = true;
                        }
                    }
                    drop(st);
                    ctl.cv.notify_all();
                })
                .expect("spawn model thread")
        })
        .collect();

    let mut decisions: Vec<Decision> = Vec::new();
    let mut finding = None;
    let mut prev: Option<usize> = None;
    loop {
        let mut st = ctl.st.lock().unwrap();
        // Wait for quiescence: nobody executing, everybody checked in.
        loop {
            if st.panic_msg.is_some() {
                break;
            }
            let quiet =
                st.active.is_none() && st.threads.iter().all(|t| !matches!(t, TState::New));
            if quiet {
                break;
            }
            st = ctl.cv.wait(st).unwrap();
        }
        if let Some((thread, message)) = st.panic_msg.clone() {
            finding = Some(Finding::Panic { thread, message });
            break;
        }
        let runnable: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t, TState::Runnable))
            .map(|(i, _)| i)
            .collect();
        if runnable.is_empty() {
            if st.threads.iter().any(|t| !matches!(t, TState::Finished)) {
                let blocked: Vec<usize> = st
                    .threads
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| !matches!(t, TState::Finished))
                    .map(|(i, _)| i)
                    .collect();
                finding = Some(Finding::Deadlock { blocked });
            }
            break;
        }
        if decisions.len() >= cfg.max_steps {
            finding = Some(Finding::StepLimit);
            break;
        }
        let chosen = match prefix.get(decisions.len()) {
            // Replay is deterministic, so the prefix thread is always
            // runnable; fall back defensively if a scenario is not.
            Some(&want) if runnable.contains(&want) => want,
            _ => match prev {
                Some(p) if runnable.contains(&p) => p,
                _ => runnable[0],
            },
        };
        let preemptive = prev.map_or(false, |p| chosen != p && runnable.contains(&p));
        decisions.push(Decision { runnable, chosen, preemptive });
        prev = Some(chosen);
        st.active = Some(chosen);
        drop(st);
        ctl.cv.notify_all();
    }

    // Teardown: unwind every parked thread and join.
    {
        let mut st = ctl.st.lock().unwrap();
        st.abort = true;
        drop(st);
        ctl.cv.notify_all();
    }
    for h in handles {
        let _ = h.join();
    }
    if finding.is_none() {
        for check in std::mem::take(&mut env.finals) {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(check)) {
                finding = Some(Finding::Check { message: panic_message(payload) });
                break;
            }
        }
    }
    Execution { decisions, finding }
}

// ---------------------------------------------------------------------------
// Exploration
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0100_0000_01b3;

fn fnv(hash: u64, value: u64) -> u64 {
    let mut h = hash;
    for byte in value.to_le_bytes() {
        h ^= byte as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Depth-first enumeration of schedules up to the preemption bound.
/// Stops at the first failure. Deterministic: two calls on the same
/// scenario produce identical reports (including [`Report::trace_hash`]).
pub fn explore(cfg: &Config, scenario: impl Fn(&mut Env)) -> Report {
    let mut stack: Vec<Vec<usize>> = vec![Vec::new()];
    let mut schedules = 0usize;
    let mut trace_hash = FNV_OFFSET;
    let mut exhausted = true;
    while let Some(prefix) = stack.pop() {
        if schedules >= cfg.max_schedules {
            exhausted = false;
            break;
        }
        let exec = run_one(&scenario, cfg, &prefix);
        schedules += 1;
        for d in &exec.decisions {
            trace_hash = fnv(trace_hash, d.chosen as u64 + 1);
        }
        trace_hash = fnv(trace_hash, 0);
        if let Some(finding) = exec.finding {
            return Report {
                schedules,
                exhausted: false,
                failure: Some(Failure {
                    finding,
                    schedule: exec.decisions.iter().map(|d| d.chosen).collect(),
                    schedule_index: schedules - 1,
                }),
                trace_hash,
            };
        }
        // Branch at every decision at or past the prefix depth whose
        // alternative keeps the schedule within the preemption bound.
        let mut preemptions = 0usize;
        let mut alts: Vec<Vec<usize>> = Vec::new();
        for (i, d) in exec.decisions.iter().enumerate() {
            if i >= prefix.len() {
                let prev = i.checked_sub(1).map(|j| exec.decisions[j].chosen);
                for &alt in &d.runnable {
                    if alt == d.chosen {
                        continue;
                    }
                    let alt_preemptive =
                        prev.map_or(false, |p| alt != p && d.runnable.contains(&p));
                    if preemptions + usize::from(alt_preemptive) <= cfg.preemption_bound {
                        let mut next: Vec<usize> =
                            exec.decisions[..i].iter().map(|x| x.chosen).collect();
                        next.push(alt);
                        alts.push(next);
                    }
                }
            }
            preemptions += usize::from(d.preemptive);
        }
        // Reverse so the stack pops shallowest-first, in thread order.
        for p in alts.into_iter().rev() {
            stack.push(p);
        }
    }
    Report { schedules, exhausted, failure: None, trace_hash }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering::SeqCst;

    /// Two threads doing a read-modify-write through separate load and
    /// store: the classic lost update. Needs one preemption.
    fn racy_counter(env: &mut Env) {
        let counter = env.atomic_usize(0);
        for _ in 0..2 {
            let c = counter.clone();
            env.spawn(move || {
                let v = c.load(SeqCst);
                c.store(v + 1, SeqCst);
            });
        }
        let c = counter;
        env.finally(move || assert_eq!(c.load(SeqCst), 2, "lost update"));
    }

    #[test]
    fn racy_counter_not_found_at_bound_zero() {
        let report = explore(&Config::with_bound(0), racy_counter);
        report.assert_ok();
        assert!(report.exhausted);
        assert!(report.schedules >= 2, "both first-thread choices explored");
    }

    #[test]
    fn racy_counter_found_at_bound_one() {
        let report = explore(&Config::with_bound(1), racy_counter);
        let failure = report.assert_finding();
        assert!(
            matches!(&failure.finding, Finding::Check { message } if message.contains("lost update")),
            "unexpected finding: {:?}",
            failure.finding
        );
    }

    #[test]
    fn ab_ba_deadlock_detected() {
        let report = explore(&Config::with_bound(2), |env| {
            let a = env.mutex(());
            let b = env.mutex(());
            let (a1, b1) = (a.clone(), b.clone());
            env.spawn(move || {
                let _ga = a1.lock();
                let _gb = b1.lock();
            });
            env.spawn(move || {
                let _gb = b.lock();
                let _ga = a.lock();
            });
        });
        let failure = report.assert_finding();
        assert!(
            matches!(&failure.finding, Finding::Deadlock { blocked } if blocked.len() == 2),
            "unexpected finding: {:?}",
            failure.finding
        );
    }

    #[test]
    fn condvar_handoff_has_no_lost_wakeup() {
        // Predicate-loop wait never hangs: the checker proves it over
        // every schedule within the bound.
        let report = explore(&Config::with_bound(2), |env| {
            let slot = env.mutex(0usize);
            let cv = env.condvar();
            let (s1, c1) = (slot.clone(), cv.clone());
            env.spawn(move || {
                let mut g = s1.lock();
                *g = 1;
                drop(g);
                c1.notify_one();
            });
            env.spawn(move || {
                let mut g = slot.lock();
                while *g == 0 {
                    g = cv.wait(g);
                }
                assert_eq!(*g, 1);
            });
        });
        report.assert_ok();
        assert!(report.exhausted);
    }

    #[test]
    fn naked_condvar_wait_misses_the_wakeup() {
        // Bug under test: the ready check happens outside the mutex, so
        // the notify can fire between the check and the wait — the
        // checker must expose the lost wakeup as a deadlock.
        let report = explore(&Config::with_bound(2), |env| {
            let ready = env.atomic_bool(false);
            let m = env.mutex(());
            let cv = env.condvar();
            let (r1, c1) = (ready.clone(), cv.clone());
            env.spawn(move || {
                r1.store(true, SeqCst);
                c1.notify_one();
            });
            env.spawn(move || {
                if !ready.load(SeqCst) {
                    let g = m.lock();
                    let _g = cv.wait(g);
                }
            });
        });
        let failure = report.assert_finding();
        assert!(
            matches!(&failure.finding, Finding::Deadlock { blocked } if blocked == &vec![1]),
            "unexpected finding: {:?}",
            failure.finding
        );
    }

    #[test]
    fn exploration_is_deterministic() {
        let run = || explore(&Config::with_bound(2), racy_counter);
        let (a, b) = (run(), run());
        assert_eq!(a.schedules, b.schedules);
        assert_eq!(a.trace_hash, b.trace_hash);
        let (fa, fb) = (a.assert_finding(), b.assert_finding());
        assert_eq!(fa.schedule, fb.schedule);
        assert_eq!(fa.schedule_index, fb.schedule_index);
        assert_eq!(fa.finding, fb.finding);
    }

    #[test]
    fn mutex_exclusion_holds_in_every_schedule() {
        let report = explore(&Config::with_bound(2), |env| {
            let m = env.mutex(0usize);
            for _ in 0..2 {
                let m = m.clone();
                env.spawn(move || {
                    for _ in 0..2 {
                        let mut g = m.lock();
                        let v = *g;
                        *g = v + 1;
                    }
                });
            }
            let m = m;
            env.finally(move || assert_eq!(*m.lock(), 4));
        });
        report.assert_ok();
        assert!(report.exhausted);
    }
}
