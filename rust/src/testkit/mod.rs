//! Deterministic in-process test harness for the transfer engines.
//!
//! Wall-clock loss injection (drop with probability p whenever `send` is
//! called) makes end-to-end traces depend on thread scheduling. This
//! module removes that: loss decisions are driven by a **virtual clock**
//! that advances one tick per transmitted fragment, so which fragments
//! die is a pure function of (loss trace, per-channel seed, fragment
//! ordinal) — never of pacing, scheduler jitter, or host load. Control
//! packets model a reliable side channel and are never dropped (the
//! convention the loopback experiments already follow, see
//! [`crate::transport::channel::LossyChannel`] docs).
//!
//! Building blocks:
//! * [`LossTrace`] — scripted per-fragment drop decisions: seeded
//!   Bernoulli, explicit scripts, or phased (time-varying) schedules.
//! * [`VirtualClock`] — fragment-count time base shared by a channel.
//! * [`FragmentLossChannel`] — a [`Datagram`] wrapper dropping only
//!   fragment datagrams according to its trace.
//! * [`pool_fixture`] — one-call construction of the control + N-stream
//!   channel sets a [`crate::coordinator::pool::TransferPool`] needs.
//! * [`loss_transport_pair`] — the same wiring packaged as a pair of
//!   [`crate::api::Transport`]s for the `janus::api` facade.

pub mod sched;

use crate::api::transport::StagedTransport;
use crate::coordinator::packet::is_fragment;
use crate::sim::hmm::{HmmConfig, HmmLoss};
use crate::sim::loss::LossProcess;
use crate::sim::tcp::RenoCwnd;
use crate::transport::channel::{mem_pair, Datagram, MemChannel};
use crate::util::Pcg64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Virtual time base: one tick per fragment pushed through the channel.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    ticks: u64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { ticks: 0 }
    }

    /// Advance by one fragment and return the new tick count.
    pub fn tick(&mut self) -> u64 {
        self.ticks += 1;
        self.ticks
    }

    /// Fragments seen so far.
    pub fn now(&self) -> u64 {
        self.ticks
    }

    /// Virtual seconds at a nominal pacing rate (fragments/s).
    pub fn now_secs(&self, rate: f64) -> f64 {
        self.ticks as f64 / rate
    }
}

/// Scripted per-fragment loss decisions.
#[derive(Debug, Clone)]
pub enum LossTrace {
    /// Never drop.
    None,
    /// Independent Bernoulli(fraction) per fragment, from a seeded PRNG.
    Seeded { fraction: f64, rng: Pcg64 },
    /// Explicit decision list (true = drop); beyond the end, deliver.
    Script(Vec<bool>),
    /// Piecewise Bernoulli: `(fragments, fraction)` phases in virtual
    /// time, cycling on exhaustion — models regime changes (the HMM's
    /// low/medium/high states) deterministically.
    Phased { phases: Vec<(u64, f64)>, rng: Pcg64 },
    /// Burst loss from a [`crate::sim::hmm`] Gilbert-Elliott chain,
    /// sampled on the virtual clock: fragment ordinal `tick` maps to
    /// chain time `tick / rate`, so the drop sequence is a pure function
    /// of (config, seed) — bit-identical across runs regardless of how
    /// the sender paces.
    Gilbert { loss: HmmLoss, rate: f64 },
}

impl LossTrace {
    /// Seeded Bernoulli trace.
    pub fn seeded(fraction: f64, seed: u64) -> LossTrace {
        assert!((0.0..=1.0).contains(&fraction), "fraction {fraction}");
        LossTrace::Seeded { fraction, rng: Pcg64::seeded(seed) }
    }

    /// Phased (time-varying) trace.
    pub fn phased(phases: Vec<(u64, f64)>, seed: u64) -> LossTrace {
        assert!(!phases.is_empty());
        assert!(phases.iter().all(|&(n, f)| n > 0 && (0.0..=1.0).contains(&f)));
        LossTrace::Phased { phases, rng: Pcg64::seeded(seed) }
    }

    /// Gilbert-Elliott burst trace: stationary loss fraction `mean_loss`
    /// arriving in runs of mean length `burst_len` fragments, observed at
    /// `rate` fragments/s on the virtual clock. Same mean λ as
    /// [`LossTrace::seeded`]`(mean_loss, _)` but a very different shape —
    /// the pair the adaptive controller must tell apart.
    pub fn gilbert_elliott(mean_loss: f64, burst_len: f64, rate: f64, seed: u64) -> LossTrace {
        let cfg = HmmConfig::gilbert_elliott(mean_loss, burst_len, rate);
        // One-packet-service-time TTL: a loss event marks exactly the
        // fragment whose slot it fell in (see `sim::loss::StaticLoss`).
        LossTrace::Gilbert { loss: HmmLoss::with_ttl(cfg, seed, 1.0 / rate), rate }
    }

    /// Decide the fate of the fragment at virtual time `tick` (0-based
    /// ordinal of this fragment on its channel).
    pub fn drop_at(&mut self, tick: u64) -> bool {
        match self {
            LossTrace::None => false,
            LossTrace::Seeded { fraction, rng } => rng.bool_with(*fraction),
            LossTrace::Script(script) => {
                script.get(tick as usize).copied().unwrap_or(false)
            }
            LossTrace::Phased { phases, rng } => {
                let cycle: u64 = phases.iter().map(|&(n, _)| n).sum();
                let mut pos = tick % cycle;
                let mut fraction = phases[phases.len() - 1].1;
                for &(n, f) in phases.iter() {
                    if pos < n {
                        fraction = f;
                        break;
                    }
                    pos -= n;
                }
                rng.bool_with(fraction)
            }
            LossTrace::Gilbert { loss, rate } => loss.is_lost(tick as f64 / *rate),
        }
    }
}

/// Shared, atomically-updated pacing rate (fragments/s) — the hook a test
/// uses to make a [`CongestionChannel`]'s loss respond to the sender's
/// adaptive rate: an observer sink stores each `RateAdapted` event here,
/// and the channel reads it per fragment.
#[derive(Debug, Clone)]
pub struct RateHandle(Arc<AtomicU64>);

impl RateHandle {
    pub fn new(rate: f64) -> RateHandle {
        assert!(rate > 0.0);
        RateHandle(Arc::new(AtomicU64::new(rate.to_bits())))
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    pub fn set(&self, rate: f64) {
        assert!(rate > 0.0);
        self.0.store(rate.to_bits(), Ordering::Relaxed);
    }
}

/// Deterministic congestion model: a token-bucket policer of `capacity`
/// fragments/s that drops the overflow whenever the sender's current rate
/// (read from a [`RateHandle`]) exceeds capacity. Credit accrues in
/// *virtual* time — `capacity / rate` tokens per offered fragment — so
/// which fragments die is a pure function of (capacity, rate history),
/// independent of wall-clock pacing: loss fraction ≈ `1 − capacity/rate`
/// while over capacity, and exactly zero once the controller backs off to
/// `rate ≤ capacity`. This is the loss *shape* that should trigger rate
/// back-off, in contrast to [`LossTrace::Gilbert`] which should not.
pub struct CongestionChannel<C: Datagram> {
    pub inner: C,
    capacity: f64,
    rate: RateHandle,
    credit: f64,
    fragments_sent: u64,
    fragments_dropped: u64,
}

impl<C: Datagram> CongestionChannel<C> {
    /// `capacity` in fragments/s on this channel; `rate` is the handle
    /// tracking the sender's current per-channel pacing rate.
    pub fn new(inner: C, capacity: f64, rate: RateHandle) -> Self {
        assert!(capacity > 0.0);
        CongestionChannel {
            inner,
            capacity,
            rate,
            credit: 1.0,
            fragments_sent: 0,
            fragments_dropped: 0,
        }
    }

    /// (fragments offered, fragments dropped).
    pub fn stats(&self) -> (u64, u64) {
        (self.fragments_sent, self.fragments_dropped)
    }
}

impl<C: Datagram> Datagram for CongestionChannel<C> {
    fn send(&mut self, buf: &[u8]) {
        if is_fragment(buf) {
            self.fragments_sent += 1;
            // Bucket depth 2: enough slack to absorb rounding, small
            // enough that sustained over-rate sending drops immediately.
            self.credit = (self.credit + self.capacity / self.rate.get()).min(2.0);
            if self.credit < 1.0 {
                self.fragments_dropped += 1;
                return;
            }
            self.credit -= 1.0;
        }
        self.inner.send(buf);
    }
    fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize> {
        self.inner.recv_into(buf, timeout)
    }
    fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize> {
        self.inner.try_recv_into(buf)
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
        self.inner.recv_timeout(timeout)
    }
    fn try_recv(&mut self) -> Option<Vec<u8>> {
        self.inner.try_recv()
    }
}

/// [`Datagram`] wrapper that drops only fragment datagrams, per a
/// deterministic [`LossTrace`] over its own [`VirtualClock`].
pub struct FragmentLossChannel<C: Datagram> {
    pub inner: C,
    trace: LossTrace,
    clock: VirtualClock,
    fragments_sent: u64,
    fragments_dropped: u64,
}

impl<C: Datagram> FragmentLossChannel<C> {
    pub fn new(inner: C, trace: LossTrace) -> Self {
        FragmentLossChannel {
            inner,
            trace,
            clock: VirtualClock::new(),
            fragments_sent: 0,
            fragments_dropped: 0,
        }
    }

    /// (fragments offered, fragments dropped).
    pub fn stats(&self) -> (u64, u64) {
        (self.fragments_sent, self.fragments_dropped)
    }

    /// The channel's virtual clock (fragments offered so far).
    pub fn clock(&self) -> &VirtualClock {
        &self.clock
    }
}

impl<C: Datagram> Datagram for FragmentLossChannel<C> {
    fn send(&mut self, buf: &[u8]) {
        if is_fragment(buf) {
            let tick = self.clock.now();
            self.clock.tick();
            self.fragments_sent += 1;
            if self.trace.drop_at(tick) {
                self.fragments_dropped += 1;
                return;
            }
        }
        self.inner.send(buf);
    }
    fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize> {
        self.inner.recv_into(buf, timeout)
    }
    fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize> {
        self.inner.try_recv_into(buf)
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
        self.inner.recv_timeout(timeout)
    }
    fn try_recv(&mut self) -> Option<Vec<u8>> {
        self.inner.try_recv()
    }
}

/// Everything a pool transfer needs, with per-stream deterministic loss on
/// the sender→receiver data paths: `(sender_control, sender_data,
/// receiver_control, receiver_data)`.
///
/// `make_trace(stream)` builds each data stream's loss trace; control is
/// lossless both ways.
#[allow(clippy::type_complexity)]
pub fn pool_fixture(
    streams: usize,
    mut make_trace: impl FnMut(usize) -> LossTrace,
) -> (
    MemChannel,
    Vec<FragmentLossChannel<MemChannel>>,
    MemChannel,
    Vec<MemChannel>,
) {
    let (sender_control, receiver_control) = mem_pair();
    let mut sender_data = Vec::with_capacity(streams);
    let mut receiver_data = Vec::with_capacity(streams);
    for w in 0..streams {
        let (a, b) = mem_pair();
        sender_data.push(FragmentLossChannel::new(a, make_trace(w)));
        receiver_data.push(b);
    }
    (sender_control, sender_data, receiver_control, receiver_data)
}

/// The deterministic-loss wiring packaged for the [`crate::api`] facade:
/// `(sender_transport, receiver_transport)` built from the same spec
/// shape the facade expects.
///
/// * `streams == 1` (single-stream route): the transfer runs entirely on
///   the control channel, so the sender's control end is wrapped in a
///   [`FragmentLossChannel`] driven by `make_trace(0)` — control packets
///   still never drop, only fragments.
/// * `streams > 1` (pooled route): control is lossless both ways; data
///   stream `w` drops per `make_trace(w)` on the sender→receiver path.
pub fn loss_transport_pair(
    streams: usize,
    mut make_trace: impl FnMut(usize) -> LossTrace,
) -> (StagedTransport, StagedTransport) {
    assert!(streams >= 1, "at least one stream");
    let (sc, rc) = mem_pair();
    if streams == 1 {
        let lossy = FragmentLossChannel::new(sc, make_trace(0));
        return (
            StagedTransport::new(lossy, Vec::new()),
            StagedTransport::new(rc, Vec::new()),
        );
    }
    let mut sender_data: Vec<Box<dyn Datagram>> = Vec::with_capacity(streams);
    let mut receiver_data: Vec<Box<dyn Datagram>> = Vec::with_capacity(streams);
    for w in 0..streams {
        let (a, b) = mem_pair();
        sender_data.push(Box::new(FragmentLossChannel::new(a, make_trace(w))));
        receiver_data.push(Box::new(b));
    }
    (
        StagedTransport::new(sc, sender_data),
        StagedTransport::new(rc, receiver_data),
    )
}

/// Congestion wiring for the [`crate::api`] facade: every data stream is
/// policed by a [`CongestionChannel`] of `capacity` fragments/s reading
/// the sender's current per-stream rate from the returned [`RateHandle`]
/// (initialised to `nominal_rate`). Control is lossless both ways.
pub fn congestion_transport_pair(
    streams: usize,
    capacity: f64,
    nominal_rate: f64,
) -> (StagedTransport, StagedTransport, RateHandle) {
    assert!(streams >= 2, "congestion fixture targets the pooled route");
    let handle = RateHandle::new(nominal_rate);
    let (sc, rc) = mem_pair();
    let mut sender_data: Vec<Box<dyn Datagram>> = Vec::with_capacity(streams);
    let mut receiver_data: Vec<Box<dyn Datagram>> = Vec::with_capacity(streams);
    for _ in 0..streams {
        let (a, b) = mem_pair();
        sender_data.push(Box::new(CongestionChannel::new(a, capacity, handle.clone())));
        receiver_data.push(Box::new(b));
    }
    (
        StagedTransport::new(sc, sender_data),
        StagedTransport::new(rc, receiver_data),
        handle,
    )
}

/// Aggregate counters for the simulated TCP flows competing with the
/// janus sender inside [`TcpCompetitorChannel`]s. Cloneable handle; all
/// streams of a fixture feed one instance.
#[derive(Debug, Clone, Default)]
pub struct TcpCompetitorStats {
    inner: Arc<TcpStatsInner>,
}

#[derive(Debug, Default)]
struct TcpStatsInner {
    tcp_sent: AtomicU64,
    tcp_dropped: AtomicU64,
    janus_offered: AtomicU64,
    janus_dropped: AtomicU64,
}

impl TcpCompetitorStats {
    pub fn new() -> TcpCompetitorStats {
        TcpCompetitorStats::default()
    }

    /// TCP segments the shared link admitted.
    pub fn tcp_sent(&self) -> u64 {
        self.inner.tcp_sent.load(Ordering::Relaxed)
    }

    /// TCP segments the shared link shed (Reno loss events).
    pub fn tcp_dropped(&self) -> u64 {
        self.inner.tcp_dropped.load(Ordering::Relaxed)
    }

    /// Janus fragments offered to the shared link.
    pub fn janus_offered(&self) -> u64 {
        self.inner.janus_offered.load(Ordering::Relaxed)
    }

    /// Janus fragments the shared link shed.
    pub fn janus_dropped(&self) -> u64 {
        self.inner.janus_dropped.load(Ordering::Relaxed)
    }
}

/// Deterministic *competing-flow* congestion model: the janus stream and
/// a simulated Reno TCP flow ([`RenoCwnd`]) share one token-bucket link
/// of `capacity` fragments/s. Time is virtual — each offered janus
/// fragment advances the clock by `1 / rate` seconds (rate read from the
/// [`RateHandle`]), during which the link accrues credit and the TCP
/// flow generates `cwnd / rtt · dt` segments of demand. TCP's backlog
/// drains first each tick (an ACK-clocked kernel flow reacts at RTT
/// granularity, far faster than the pass-barrier controller, so giving
/// it priority is the conservative fairness test); whatever credit
/// remains admits the janus fragment or sheds it. Admitted TCP segments
/// ACK the window up, shed ones halve it — the classic sawtooth — so
/// both flows adapt to each other and the division of `capacity` is a
/// pure function of (capacity, rtt, rate history), independent of
/// wall-clock pacing.
pub struct TcpCompetitorChannel<C: Datagram> {
    pub inner: C,
    capacity: f64,
    rate: RateHandle,
    rtt: f64,
    reno: RenoCwnd,
    credit: f64,
    tcp_backlog: f64,
    stats: TcpCompetitorStats,
}

impl<C: Datagram> TcpCompetitorChannel<C> {
    /// `capacity` in fragments/s on this link; `rate` tracks the janus
    /// sender's current per-channel pacing rate; `rtt` is the competing
    /// TCP flow's round-trip time in seconds.
    pub fn new(
        inner: C,
        capacity: f64,
        rate: RateHandle,
        rtt: f64,
        stats: TcpCompetitorStats,
    ) -> Self {
        assert!(capacity > 0.0);
        assert!(rtt > 0.0);
        TcpCompetitorChannel {
            inner,
            capacity,
            rate,
            rtt,
            reno: RenoCwnd::new(),
            credit: 1.0,
            tcp_backlog: 0.0,
            stats,
        }
    }

    /// The competitor's current congestion window, segments.
    pub fn tcp_cwnd(&self) -> f64 {
        self.reno.cwnd()
    }
}

impl<C: Datagram> Datagram for TcpCompetitorChannel<C> {
    fn send(&mut self, buf: &[u8]) {
        if is_fragment(buf) {
            let dt = 1.0 / self.rate.get().max(1.0);
            // Bucket depth 4: two flows share it, so give each the same
            // slack the single-flow CongestionChannel's depth-2 bucket
            // allows.
            self.credit = (self.credit + self.capacity * dt).min(4.0);
            self.tcp_backlog += self.reno.rate(self.rtt) * dt;
            // Drop-tail: TCP's burst goes first, then the janus fragment
            // contends for whatever credit is left. One halving per tick
            // no matter how many of the burst died (one loss *event*).
            let mut tcp_lost = false;
            while self.tcp_backlog >= 1.0 {
                self.tcp_backlog -= 1.0;
                if self.credit >= 1.0 {
                    self.credit -= 1.0;
                    self.stats.inner.tcp_sent.fetch_add(1, Ordering::Relaxed);
                    self.reno.on_ack();
                } else {
                    self.stats.inner.tcp_dropped.fetch_add(1, Ordering::Relaxed);
                    tcp_lost = true;
                }
            }
            if tcp_lost {
                self.reno.on_loss();
            }
            self.stats.inner.janus_offered.fetch_add(1, Ordering::Relaxed);
            if self.credit < 1.0 {
                self.stats.inner.janus_dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
            self.credit -= 1.0;
        }
        self.inner.send(buf);
    }
    fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize> {
        self.inner.recv_into(buf, timeout)
    }
    fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize> {
        self.inner.try_recv_into(buf)
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Option<Vec<u8>> {
        self.inner.recv_timeout(timeout)
    }
    fn try_recv(&mut self) -> Option<Vec<u8>> {
        self.inner.try_recv()
    }
}

/// TCP-competition wiring for the [`crate::api`] facade: every data
/// stream shares its `capacity`-fragments/s link with an independent Reno
/// TCP flow of round-trip time `rtt` seconds. Control is lossless both
/// ways. The returned [`RateHandle`] (initialised to `nominal_rate`)
/// must track the sender's adaptive per-stream rate; the returned
/// [`TcpCompetitorStats`] aggregates both flows' admitted/shed counts
/// across all streams.
pub fn tcp_competitor_transport_pair(
    streams: usize,
    capacity: f64,
    nominal_rate: f64,
    rtt: f64,
) -> (StagedTransport, StagedTransport, RateHandle, TcpCompetitorStats) {
    assert!(streams >= 2, "competitor fixture targets the pooled route");
    let handle = RateHandle::new(nominal_rate);
    let stats = TcpCompetitorStats::new();
    let (sc, rc) = mem_pair();
    let mut sender_data: Vec<Box<dyn Datagram>> = Vec::with_capacity(streams);
    let mut receiver_data: Vec<Box<dyn Datagram>> = Vec::with_capacity(streams);
    for _ in 0..streams {
        let (a, b) = mem_pair();
        sender_data.push(Box::new(TcpCompetitorChannel::new(
            a,
            capacity,
            handle.clone(),
            rtt,
            stats.clone(),
        )));
        receiver_data.push(Box::new(b));
    }
    (
        StagedTransport::new(sc, sender_data),
        StagedTransport::new(rc, receiver_data),
        handle,
        stats,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::packet::{encode_fragment_into, FragmentHeader, Packet};

    fn fragment_buf(seq: u64) -> Vec<u8> {
        let mut out = Vec::new();
        let hdr = FragmentHeader {
            level: 0,
            stream: 0,
            ftg: 0,
            index: 0,
            k: 1,
            m: 0,
            seq,
            pass: 0,
        };
        encode_fragment_into(&hdr, &[0xAA; 32], &mut out);
        out
    }

    #[test]
    fn control_packets_never_dropped() {
        let (a, mut b) = mem_pair();
        let mut ch = FragmentLossChannel::new(a, LossTrace::seeded(1.0, 1));
        for _ in 0..50 {
            ch.send(&Packet::Done.encode());
            ch.send(&fragment_buf(0));
        }
        let mut control = 0;
        let mut frags = 0;
        while let Some(buf) = b.try_recv() {
            if is_fragment(&buf) {
                frags += 1;
            } else {
                control += 1;
            }
        }
        assert_eq!(control, 50, "control must always survive");
        assert_eq!(frags, 0, "fraction 1.0 must kill every fragment");
        assert_eq!(ch.stats(), (50, 50));
    }

    #[test]
    fn seeded_trace_is_deterministic() {
        let run = || {
            let (a, mut b) = mem_pair();
            let mut ch = FragmentLossChannel::new(a, LossTrace::seeded(0.3, 99));
            for i in 0..1000 {
                ch.send(&fragment_buf(i));
            }
            let mut got = Vec::new();
            while let Some(buf) = b.try_recv() {
                if let Ok(Packet::Fragment(h, _)) = Packet::decode(&buf) {
                    got.push(h.seq);
                }
            }
            got
        };
        let first = run();
        assert_eq!(first, run(), "identical seeds must survive identically");
        assert!(first.len() > 500 && first.len() < 900, "≈70% survive");
    }

    #[test]
    fn script_trace_follows_script_exactly() {
        let (a, mut b) = mem_pair();
        let script = vec![true, false, false, true, false];
        let mut ch = FragmentLossChannel::new(a, LossTrace::Script(script));
        for i in 0..7 {
            ch.send(&fragment_buf(i));
        }
        let mut got = Vec::new();
        while let Some(buf) = b.try_recv() {
            if let Ok(Packet::Fragment(h, _)) = Packet::decode(&buf) {
                got.push(h.seq);
            }
        }
        // Dropped: ordinals 0 and 3; beyond the script everything lives.
        assert_eq!(got, vec![1, 2, 4, 5, 6]);
    }

    #[test]
    fn phased_trace_switches_regimes() {
        // 500 lossless fragments then 500 at 100% loss, cycling.
        let mut trace = LossTrace::phased(vec![(500, 0.0), (500, 1.0)], 7);
        let first: Vec<bool> = (0..500).map(|t| trace.drop_at(t)).collect();
        let second: Vec<bool> = (500..1000).map(|t| trace.drop_at(t)).collect();
        let third: Vec<bool> = (1000..1500).map(|t| trace.drop_at(t)).collect();
        assert!(first.iter().all(|&d| !d));
        assert!(second.iter().all(|&d| d));
        assert!(third.iter().all(|&d| !d), "phases must cycle");
    }

    #[test]
    fn virtual_clock_counts_fragments_only() {
        let (a, _b) = mem_pair();
        let mut ch = FragmentLossChannel::new(a, LossTrace::None);
        ch.send(&Packet::Done.encode());
        ch.send(&fragment_buf(0));
        ch.send(&Packet::Done.encode());
        ch.send(&fragment_buf(1));
        assert_eq!(ch.clock().now(), 2);
        assert!((ch.clock().now_secs(1000.0) - 0.002).abs() < 1e-12);
    }

    #[test]
    fn loss_transport_pair_wraps_control_when_single_stream() {
        use crate::api::transport::Transport;
        let (mut s, mut r) = loss_transport_pair(1, |_| LossTrace::seeded(1.0, 3));
        let mut sc = s.open_control().unwrap();
        let mut rc = r.open_control().unwrap();
        sc.send(&Packet::Done.encode());
        sc.send(&fragment_buf(0));
        assert!(!is_fragment(
            &rc.recv_timeout(Duration::from_millis(50)).unwrap()
        ));
        assert!(
            rc.recv_timeout(Duration::from_millis(50)).is_none(),
            "fraction 1.0 must kill the fragment"
        );
        assert!(s.open_data(0).is_err(), "single-stream: no data channels");
    }

    #[test]
    fn loss_transport_pair_spares_control_when_pooled() {
        use crate::api::transport::Transport;
        let (mut s, mut r) = loss_transport_pair(2, |_| LossTrace::seeded(1.0, 9));
        let mut sc = s.open_control().unwrap();
        let mut rc = r.open_control().unwrap();
        sc.send(&fragment_buf(7));
        assert!(
            rc.recv_timeout(Duration::from_millis(50)).is_some(),
            "pooled control is lossless"
        );
        let mut sd = s.open_data(1).unwrap();
        let mut rd = r.open_data(1).unwrap();
        sd.send(&fragment_buf(8));
        assert!(rd.recv_timeout(Duration::from_millis(50)).is_none());
        sd.send(&Packet::Done.encode());
        assert!(rd.recv_timeout(Duration::from_millis(50)).is_some());
    }

    #[test]
    fn gilbert_trace_is_bursty_at_the_requested_mean() {
        // 20% mean loss in bursts of ~8 at 1000 frag/s.
        let mut trace = LossTrace::gilbert_elliott(0.2, 8.0, 1000.0, 42);
        let n = 200_000u64;
        let drops: Vec<bool> = (0..n).map(|t| trace.drop_at(t)).collect();
        let lost = drops.iter().filter(|&&d| d).count() as f64;
        let frac = lost / n as f64;
        assert!((frac - 0.2).abs() < 0.05, "mean loss {frac} !≈ 0.2");
        // Run-length structure: mean run well above i.i.d.'s ~1.25.
        let mut runs = 0u64;
        let mut prev = false;
        for &d in &drops {
            if d && !prev {
                runs += 1;
            }
            prev = d;
        }
        let mean_run = lost / runs as f64;
        assert!(mean_run > 3.0, "mean run {mean_run} not bursty");
        // Determinism: same seed, same drop sequence.
        let mut again = LossTrace::gilbert_elliott(0.2, 8.0, 1000.0, 42);
        let replay: Vec<bool> = (0..n).map(|t| again.drop_at(t)).collect();
        assert_eq!(drops, replay);
    }

    #[test]
    fn congestion_channel_polices_to_capacity() {
        let handle = RateHandle::new(2000.0);
        let (a, mut b) = mem_pair();
        let mut ch = CongestionChannel::new(a, 1000.0, handle.clone());
        for i in 0..1000 {
            ch.send(&fragment_buf(i));
        }
        let (sent, dropped) = ch.stats();
        assert_eq!(sent, 1000);
        let frac = dropped as f64 / sent as f64;
        assert!(
            (frac - 0.5).abs() < 0.01,
            "rate 2×capacity must shed ≈half, got {frac}"
        );
        // Back off to capacity: no further drops.
        handle.set(1000.0);
        for i in 0..1000 {
            ch.send(&fragment_buf(i));
        }
        let (_, dropped_after) = ch.stats();
        assert_eq!(dropped_after, dropped, "at-capacity sending is lossless");
        // Control packets bypass the policer entirely.
        handle.set(1e9);
        ch.send(&Packet::Done.encode());
        let mut survived = 0;
        while b.try_recv().is_some() {
            survived += 1;
        }
        assert_eq!(survived as u64, 2000 - dropped + 1);
    }

    #[test]
    fn tcp_competitor_contends_then_yields() {
        let run = |rate2: f64| {
            let handle = RateHandle::new(1000.0);
            let stats = TcpCompetitorStats::new();
            let (a, _b) = mem_pair();
            let mut ch =
                TcpCompetitorChannel::new(a, 1000.0, handle.clone(), 0.05, stats.clone());
            for i in 0..20_000 {
                ch.send(&fragment_buf(i));
            }
            let shed1 = stats.janus_dropped() as f64 / stats.janus_offered() as f64;
            // TCP carved out a real share and saw its sawtooth losses.
            assert!(stats.tcp_sent() > 1_000, "tcp sent {}", stats.tcp_sent());
            assert!(stats.tcp_dropped() > 0, "no Reno loss events");
            assert!(shed1 > 0.02, "competition must pressure janus: {shed1}");
            // Janus backs off; its loss fraction must drop.
            handle.set(rate2);
            let (off0, drop0) = (stats.janus_offered(), stats.janus_dropped());
            for i in 0..20_000 {
                ch.send(&fragment_buf(i));
            }
            let shed2 = (stats.janus_dropped() - drop0) as f64
                / (stats.janus_offered() - off0) as f64;
            assert!(shed2 < shed1, "backing off must shed less: {shed2} vs {shed1}");
            (stats.tcp_sent(), stats.tcp_dropped(), stats.janus_dropped())
        };
        // Deterministic: identical inputs, identical division of the link.
        assert_eq!(run(400.0), run(400.0));
    }

    #[test]
    fn pool_fixture_wires_streams_both_ways() {
        let (mut sc, mut sd, mut rc, mut rd) = pool_fixture(3, |_| LossTrace::None);
        assert_eq!(sd.len(), 3);
        assert_eq!(rd.len(), 3);
        sc.send(b"ctl");
        assert_eq!(rc.recv_timeout(Duration::from_millis(50)).unwrap(), b"ctl");
        rc.send(b"ack");
        assert_eq!(sc.recv_timeout(Duration::from_millis(50)).unwrap(), b"ack");
        for (i, ch) in sd.iter_mut().enumerate() {
            ch.send(&fragment_buf(i as u64));
        }
        for (i, ch) in rd.iter_mut().enumerate() {
            let buf = ch.recv_timeout(Duration::from_millis(50)).unwrap();
            match Packet::decode(&buf).unwrap() {
                Packet::Fragment(h, _) => assert_eq!(h.seq, i as u64),
                other => panic!("unexpected {other:?}"),
            }
        }
    }
}
