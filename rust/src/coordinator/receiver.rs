//! Receiver engine — FTG reassembly, recovery, λ measurement, feedback.
//!
//! Mirrors the paper's §4 receiver: processes incoming fragments, extracts
//! the per-FTG redundancy metadata, recovers lost data fragments when no
//! more than `m` are missing, tracks the packet-loss rate over a window
//! `T_W` via sequence gaps and notifies the sender, and answers
//! end-of-transmission notifications with the lost-FTG list (Alg. 1) or
//! finalizes immediately (Alg. 2).

use super::arena::FtgArena;
use super::packet::{
    validate_fragment_size, Manifest, Packet, PacketView, MAX_DATAGRAM, MAX_LOST_PER_MSG,
};
use crate::api::observer::{emit, EventSink};
use crate::api::TransferEvent;
use crate::bail;
use crate::erasure::RsCode;
use crate::transport::channel::Datagram;
use crate::util::err::Result;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Receiver configuration.
#[derive(Debug, Clone)]
pub struct ReceiverConfig {
    /// λ measurement window `T_W`, seconds (paper: 3 s).
    pub t_w: f64,
    /// Give up if nothing at all arrives for this long.
    pub idle_timeout: Duration,
    /// Overall wall-clock cap.
    pub max_duration: Duration,
}

impl Default for ReceiverConfig {
    fn default() -> Self {
        ReceiverConfig {
            t_w: 3.0,
            idle_timeout: Duration::from_secs(10),
            max_duration: Duration::from_secs(600),
        }
    }
}

/// Transfer outcome at the receiver.
#[derive(Debug, Clone)]
pub struct ReceiverReport {
    /// Recovered level buffers (exact original bytes) — `None` when the
    /// level had unrecoverable FTGs (possible only under Alg. 2).
    pub levels: Vec<Option<Vec<u8>>>,
    /// Achieved error bound: ε of the longest fully-recovered prefix.
    pub achieved_eps: f64,
    /// Levels in the usable prefix.
    pub levels_recovered: usize,
    pub fragments_received: u64,
    /// FTGs that needed Reed–Solomon recovery (vs. arriving complete).
    pub groups_recovered: u64,
    /// λ̂ values reported to the sender.
    pub lambda_reports: Vec<f64>,
    /// Wall-clock duration from manifest to completion.
    pub duration: f64,
}

/// Run a transfer as the receiver.
#[deprecated(note = "use janus::api::Endpoint::receive")]
pub fn run_receiver(chan: &mut dyn Datagram, cfg: &ReceiverConfig) -> Result<ReceiverReport> {
    transfer_receiver(chan, cfg, None)
}

/// Single-stream receiver engine. Blocks until the transfer completes
/// (Alg. 1: all FTGs of all levels recovered; Alg. 2: sender signalled the
/// end and everything received was processed). Public entry:
/// [`crate::api::Endpoint::receive`].
pub(crate) fn transfer_receiver(
    chan: &mut dyn Datagram,
    cfg: &ReceiverConfig,
    events: EventSink<'_>,
) -> Result<ReceiverReport> {
    // === Handshake ===
    let start = Instant::now();
    let manifest: Manifest = loop {
        if start.elapsed() > cfg.max_duration {
            bail!("receiver: no manifest");
        }
        if let Some(buf) = chan.recv_timeout(cfg.idle_timeout) {
            match Packet::decode(&buf) {
                Ok(Packet::Manifest(m)) => {
                    chan.send(&Packet::ManifestAck.encode());
                    break m;
                }
                _ => continue,
            }
        } else {
            bail!("receiver: timed out waiting for manifest");
        }
    };
    let retransmitting = manifest.contract == 0;
    let s = manifest.s as usize;
    validate_fragment_size(s)?;
    let num_levels = manifest.levels.len();

    let mut groups: HashMap<(u8, u32), FtgArena> = HashMap::new();
    let mut codes: HashMap<(u8, u8), RsCode> = HashMap::new();
    let mut report = ReceiverReport {
        levels: vec![None; num_levels],
        achieved_eps: 1.0,
        levels_recovered: 0,
        fragments_received: 0,
        groups_recovered: 0,
        lambda_reports: Vec::new(),
        duration: 0.0,
    };

    // λ window state (sequence-gap based, per pass).
    let mut window_start = Instant::now();
    let mut window_received = 0u64;
    let mut window_first_seq: Option<u64> = None;
    let mut window_max_seq = 0u64;

    let mut last_packet = Instant::now();
    // One receive buffer for the whole transfer: the steady-state loop
    // (recv_into → PacketView → arena insert) allocates nothing per
    // fragment (asserted by rust/tests/alloc_datapath.rs).
    let mut rbuf = vec![0u8; MAX_DATAGRAM];

    loop {
        if start.elapsed() > cfg.max_duration {
            bail!("receiver exceeded max duration");
        }
        let n = match chan.recv_into(&mut rbuf, Duration::from_millis(50)) {
            Some(n) => n,
            None => {
                if last_packet.elapsed() > cfg.idle_timeout {
                    bail!("receiver: sender went silent");
                }
                continue;
            }
        };
        last_packet = Instant::now();
        match PacketView::decode(&rbuf[..n]) {
            Ok(PacketView::Fragment(view)) => {
                let h = view.header;
                report.fragments_received += 1;
                // λ window bookkeeping.
                window_received += 1;
                if window_first_seq.is_none() {
                    window_first_seq = Some(h.seq);
                }
                window_max_seq = window_max_seq.max(h.seq);
                let elapsed = window_start.elapsed().as_secs_f64();
                if elapsed >= cfg.t_w {
                    let first = window_first_seq.unwrap_or(window_max_seq);
                    let expected = window_max_seq.saturating_sub(first) + 1;
                    let lost = expected.saturating_sub(window_received);
                    let lambda_hat = lost as f64 / elapsed;
                    report.lambda_reports.push(lambda_hat);
                    chan.send(&Packet::LambdaUpdate { lambda: lambda_hat }.encode());
                    emit(events, TransferEvent::LambdaUpdated { lambda: lambda_hat });
                    window_start = Instant::now();
                    window_received = 0;
                    window_first_seq = None;
                }
                // Copy the payload exactly once: receive buffer → arena.
                // Single-stream m is fixed per group (retransmissions
                // resend identical fragments), so an index beyond the
                // group's geometry is a stray datagram — dropped, never
                // grown into a phantom shard.
                let g = groups
                    .entry((h.level, h.ftg))
                    .or_insert_with(|| FtgArena::new(h.k, h.m, s));
                if (h.index as usize) < g.slots() {
                    g.insert(h.index as usize, view.payload);
                }
            }
            Ok(PacketView::Control(Packet::EndOfPass { pass })) => {
                // Evaluate recoverability of every group seen; also detect
                // levels with missing tails (groups never seen at all are
                // only knowable via byte accounting below).
                let lost = collect_lost(&manifest, &groups, s);
                if retransmitting {
                    // Cap the wire list so it always fits one datagram;
                    // the tail is re-reported on the next pass. `total`
                    // carries the true count so the sender can price the
                    // unreported tail when re-planning.
                    let total = lost.len() as u32;
                    let wire: Vec<(u8, u32)> =
                        lost.iter().take(MAX_LOST_PER_MSG).copied().collect();
                    chan.send(&Packet::LostList { pass, total, ftgs: wire }.encode());
                    if lost.is_empty() {
                        chan.send(&Packet::Done.encode());
                        break;
                    }
                } else {
                    // Deadline contract: take what we have.
                    chan.send(&Packet::Done.encode());
                    break;
                }
            }
            _ => {}
        }
    }

    // === Reconstruct levels ===
    let (levels, recovered) = reconstruct_levels(&manifest, &groups, s, &mut codes, events);
    report.levels = levels;
    report.groups_recovered = recovered;

    let prefix = usable_prefix(&manifest, &report.levels);
    report.levels_recovered = prefix;
    report.achieved_eps = if prefix == 0 {
        1.0
    } else {
        manifest.levels[prefix - 1].eps
    };
    report.duration = start.elapsed().as_secs_f64();
    Ok(report)
}

/// Reconstruct every level's byte buffer from the FTG arenas (cached RS
/// decode matrices across groups). Returns the per-level buffers
/// (`None` where an FTG was unrecoverable) and the count of groups that
/// needed Reed–Solomon recovery. Shared by the blocking receiver and
/// the sans-IO [`crate::engine::ReceiverMachine`].
pub(crate) fn reconstruct_levels(
    manifest: &Manifest,
    groups: &HashMap<(u8, u32), FtgArena>,
    s: usize,
    codes: &mut HashMap<(u8, u8), RsCode>,
    events: EventSink<'_>,
) -> (Vec<Option<Vec<u8>>>, u64) {
    let mut levels: Vec<Option<Vec<u8>>> = vec![None; manifest.levels.len()];
    let mut groups_recovered = 0u64;
    for (li, entry) in manifest.levels.iter().enumerate() {
        let size = entry.size;
        let mut out = Vec::with_capacity(size as usize);
        let mut ok = true;
        let mut ftg = 0u32;
        while (out.len() as u64) < size {
            match groups.get(&(li as u8, ftg)) {
                Some(g) if g.data_complete() => {
                    for i in 0..g.k() as usize {
                        out.extend_from_slice(g.slot(i));
                    }
                }
                Some(g) if g.decodable() => {
                    // Reed–Solomon recovery, straight into the level
                    // buffer (cached decode matrices across groups).
                    let k = g.k();
                    let m_seen = (g.slots() - k as usize) as u8;
                    let code = codes
                        .entry((k, m_seen))
                        .or_insert_with(|| RsCode::new(k as usize, m_seen as usize).unwrap());
                    let shards: Vec<(usize, &[u8])> = g.iter_present().collect();
                    let start_len = out.len();
                    out.resize(start_len + k as usize * s, 0);
                    match code.reconstruct_into(&shards, &mut out[start_len..]) {
                        Ok(()) => {
                            groups_recovered += 1;
                            emit(
                                events,
                                TransferEvent::GroupRecovered { level: li as u8, ftg },
                            );
                        }
                        Err(_) => {
                            out.truncate(start_len);
                            ok = false;
                            break;
                        }
                    }
                }
                _ => {
                    ok = false;
                    break;
                }
            }
            ftg += 1;
        }
        if ok {
            out.truncate(size as usize);
            levels[li] = Some(out);
        }
    }
    (levels, groups_recovered)
}

/// Usable prefix length. The prefix ends at the first missing level or
/// the first plane-cut level: a cut level's missing bitplanes gate
/// every later rung (for the single-stream engine the cut is always the
/// last advertised level, so this is belt-and-braces consistency with
/// the pooled walk).
pub(crate) fn usable_prefix(manifest: &Manifest, levels: &[Option<Vec<u8>>]) -> usize {
    let mut prefix = 0;
    for (li, l) in levels.iter().enumerate() {
        if l.is_none() {
            break;
        }
        prefix += 1;
        if manifest.levels[li].cut {
            break;
        }
    }
    prefix
}

/// FTGs (per manifest byte accounting) that cannot currently be decoded.
/// Shared by the blocking receiver and the sans-IO
/// [`crate::engine::ReceiverMachine`].
pub(crate) fn collect_lost(
    manifest: &Manifest,
    groups: &HashMap<(u8, u32), FtgArena>,
    s: usize,
) -> Vec<(u8, u32)> {
    let n = manifest.n as usize;
    let mut lost = Vec::new();
    for (li, entry) in manifest.levels.iter().enumerate() {
        let size = entry.size;
        // Walk the level's groups by byte accounting. Group *geometry*
        // (k per group) is frozen at pass 0 from the manifest's m0 — the
        // sender adapts only the parity count m on λ updates, never k —
        // so never-seen groups stride by exactly k0·s. (Before the
        // freeze this fell back to a worst-case k = n stride, which
        // under-enumerated after whole-pass loss: a single lost FTG id
        // per n/k0 real groups, costing an extra feedback round per
        // group to discover each next id.)
        let k0 = n.saturating_sub(entry.m0 as usize).max(1);
        let mut covered = 0u64;
        let mut ftg = 0u32;
        while covered < size {
            match groups.get(&(li as u8, ftg)) {
                Some(g) => {
                    if !g.decodable() {
                        lost.push((li as u8, ftg));
                    }
                    covered += g.k() as u64 * s as u64;
                }
                None => {
                    lost.push((li as u8, ftg));
                    covered += k0 as u64 * s as u64;
                }
            }
            ftg += 1;
        }
    }
    lost
}
