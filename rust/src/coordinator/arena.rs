//! Strided fault-tolerant-group arenas — one allocation per FTG.
//!
//! The group tables used to hold every FTG as `Vec<Option<Vec<u8>>>`
//! (k+m separate heap fragments plus `have_*` counters), and the
//! sender's parity thread built k+m fresh `Vec`s per group. An
//! [`FtgArena`] packs all `k + m` fragments of one group into a single
//! strided buffer — slot `i` lives at bytes `[i·s, (i+1)·s)` — with a
//! presence bitmap instead of `Option`s. One allocation per group,
//! recyclable in place via [`FtgArena::reset`], and laid out exactly how
//! [`crate::erasure::RsCode::encode_strided`] and
//! [`crate::erasure::RsCode::reconstruct_into`] want their operands
//! (DESIGN.md §6).

use crate::erasure::backend::ErasureBackend;
use crate::erasure::RsError;

/// Presence bitmap width: wire fragment indices are `u8`, so 256 bits
/// cover every legal slot.
const BITMAP_WORDS: usize = 4;

/// All fragments of one fault-tolerant group in a single strided buffer
/// plus a presence bitmap.
#[derive(Debug, Clone)]
pub struct FtgArena {
    k: u8,
    s: usize,
    buf: Vec<u8>,
    present: [u64; BITMAP_WORDS],
}

impl FtgArena {
    /// Arena for a `(k, m)` group with fragment payloads of `s` bytes.
    pub fn new(k: u8, m: u8, s: usize) -> FtgArena {
        let slots = k as usize + m as usize;
        FtgArena { k, s, buf: vec![0u8; slots * s], present: [0; BITMAP_WORDS] }
    }

    /// Re-geometry the arena in place, keeping the allocation: presence
    /// bits clear, slot contents stale (callers fully overwrite a slot
    /// before marking it present).
    pub fn reset(&mut self, k: u8, m: u8, s: usize) {
        self.k = k;
        self.s = s;
        self.present = [0; BITMAP_WORDS];
        let want = (k as usize + m as usize) * s;
        if self.buf.len() < want {
            self.buf.resize(want, 0);
        } else {
            self.buf.truncate(want);
        }
    }

    /// Data fragments in the group.
    #[inline]
    pub fn k(&self) -> u8 {
        self.k
    }

    /// Fragment payload size in bytes.
    #[inline]
    pub fn stride(&self) -> usize {
        self.s
    }

    /// Fragment slots this arena currently holds (k + m, grown when a
    /// later pass raised m).
    #[inline]
    pub fn slots(&self) -> usize {
        if self.s == 0 {
            0
        } else {
            self.buf.len() / self.s
        }
    }

    #[inline]
    fn bit(idx: usize) -> (usize, u64) {
        (idx / 64, 1u64 << (idx % 64))
    }

    /// Is fragment `idx` present?
    #[inline]
    pub fn contains(&self, idx: usize) -> bool {
        if idx >= 64 * BITMAP_WORDS {
            return false;
        }
        let (w, b) = Self::bit(idx);
        self.present[w] & b != 0
    }

    /// Grow the buffer to cover `slots` fragments (a later pass raised
    /// m; parity rows nest, so existing fragments stay valid).
    pub fn ensure_slots(&mut self, slots: usize) {
        let want = slots * self.s;
        if self.buf.len() < want {
            self.buf.resize(want, 0);
        }
    }

    // lint: datapath — per-fragment receive path: one copy into the
    // strided slot, no heap traffic (grow via `ensure_slots` is the
    // amortized cold path and uses `resize`, never a fresh Vec).

    /// Copy `payload` into slot `idx` (zero-padding the tail) and mark
    /// it present. Returns `false` — and copies nothing — for
    /// duplicates, out-of-range indices, or over-long payloads.
    pub fn insert(&mut self, idx: usize, payload: &[u8]) -> bool {
        if idx >= 64 * BITMAP_WORDS || payload.len() > self.s || self.contains(idx) {
            return false;
        }
        self.ensure_slots(idx + 1);
        let slot = &mut self.buf[idx * self.s..(idx + 1) * self.s];
        slot[..payload.len()].copy_from_slice(payload);
        slot[payload.len()..].fill(0);
        let (w, b) = Self::bit(idx);
        self.present[w] |= b;
        true
    }

    // lint: end-datapath

    /// Fragments present, any index.
    pub fn have_total(&self) -> usize {
        self.present.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Data fragments (index < k) present.
    pub fn have_data(&self) -> usize {
        let k = self.k as usize;
        let mut count = 0;
        for (w, word) in self.present.iter().enumerate() {
            let lo = w * 64;
            if k <= lo {
                break;
            }
            let mask = if k >= lo + 64 { u64::MAX } else { (1u64 << (k - lo)) - 1 };
            count += (word & mask).count_ones() as usize;
        }
        count
    }

    /// All data fragments present (pure-copy reassembly)?
    #[inline]
    pub fn data_complete(&self) -> bool {
        self.have_data() == self.k as usize
    }

    /// Enough fragments (any mix of data/parity) to decode?
    #[inline]
    pub fn decodable(&self) -> bool {
        self.have_total() >= self.k as usize
    }

    /// Slot `idx` bytes regardless of presence (sender-side access to
    /// fully-populated arenas).
    #[inline]
    pub fn slot(&self, idx: usize) -> &[u8] {
        &self.buf[idx * self.s..(idx + 1) * self.s]
    }

    /// Mutable slot `idx` (fill, then [`FtgArena::mark_present`]).
    #[inline]
    pub fn slot_mut(&mut self, idx: usize) -> &mut [u8] {
        let s = self.s;
        &mut self.buf[idx * s..(idx + 1) * s]
    }

    /// Mark slot `idx` present without copying (for slots filled in
    /// place via [`FtgArena::slot_mut`] / `encode_strided`).
    pub fn mark_present(&mut self, idx: usize) {
        assert!(idx < 64 * BITMAP_WORDS, "fragment index {idx} out of bitmap range");
        assert!((idx + 1) * self.s <= self.buf.len(), "slot {idx} beyond arena");
        let (w, b) = Self::bit(idx);
        self.present[w] |= b;
    }

    /// Fragment `idx`, when present.
    pub fn fragment(&self, idx: usize) -> Option<&[u8]> {
        if self.contains(idx) && (idx + 1) * self.s <= self.buf.len() {
            Some(self.slot(idx))
        } else {
            None
        }
    }

    /// Present fragments in index order, as `reconstruct`-shaped shards.
    pub fn iter_present(&self) -> impl Iterator<Item = (usize, &[u8])> + '_ {
        (0..self.slots()).filter_map(move |i| self.fragment(i).map(|f| (i, f)))
    }

    /// Raw strided buffer — `k` data slots then parity slots — for
    /// [`crate::erasure::RsCode::encode_strided`].
    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        &mut self.buf
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    // lint: datapath — per-group sender path: slice + pad + encode in
    // place inside the arena's single allocation.

    /// Slice the `k` data slots out of `src` starting at byte `offset`,
    /// zero-padding slot tails that run past the end of `src`. The
    /// explicit tail fill makes this correct on *reused* arenas (stale
    /// bytes from the previous group must not leak into short final
    /// groups). Shared by the pooled per-stream workers and the sans-IO
    /// sender — the slicing arithmetic used to be duplicated at each
    /// call site.
    pub fn fill_data(&mut self, src: &[u8], offset: usize) {
        let s = self.s;
        for i in 0..self.k as usize {
            let lo = (offset + i * s).min(src.len());
            let hi = (offset + (i + 1) * s).min(src.len());
            let slot = self.slot_mut(i);
            slot[..hi - lo].copy_from_slice(&src[lo..hi]);
            slot[hi - lo..].fill(0);
        }
    }

    /// Encode the parity slots from the data slots in place and mark
    /// every slot present (the sender's one-allocation path). Generic
    /// over [`ErasureBackend`] so the arena works unchanged for any
    /// coding backend — rateless backends simply have zero parity slots.
    pub fn encode_parity<B: ErasureBackend + ?Sized>(&mut self, code: &B) -> Result<(), RsError> {
        let s = self.s;
        code.encode_strided(&mut self.buf, s)?;
        let n = self.slots();
        for idx in 0..n {
            self.mark_present(idx);
        }
        Ok(())
    }
}

// lint: end-datapath

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_tracks_presence_and_pads() {
        let mut a = FtgArena::new(3, 2, 8);
        assert_eq!(a.slots(), 5);
        assert_eq!(a.have_total(), 0);
        assert!(a.insert(1, b"hello"));
        assert!(!a.insert(1, b"again"), "duplicates rejected");
        assert!(a.insert(4, &[9u8; 8]));
        assert!(!a.insert(300, b"x"), "out-of-range index rejected");
        assert!(!a.insert(2, &[0u8; 9]), "over-long payload rejected");
        assert_eq!(a.have_total(), 2);
        assert_eq!(a.have_data(), 1);
        assert!(!a.data_complete());
        assert_eq!(a.fragment(1).unwrap(), b"hello\0\0\0");
        assert!(a.fragment(0).is_none());
        let shards: Vec<usize> = a.iter_present().map(|(i, _)| i).collect();
        assert_eq!(shards, vec![1, 4]);
    }

    #[test]
    fn grows_when_later_pass_raises_m() {
        let mut a = FtgArena::new(2, 1, 4);
        assert_eq!(a.slots(), 3);
        assert!(a.insert(5, &[7u8; 4]), "index beyond slots grows the arena");
        assert_eq!(a.slots(), 6);
        assert_eq!(a.fragment(5).unwrap(), &[7u8; 4]);
        assert!(a.insert(0, &[1u8; 4]));
        assert_eq!(a.have_data(), 1);
        assert_eq!(a.have_total(), 2);
    }

    #[test]
    fn reset_reuses_the_allocation() {
        let mut a = FtgArena::new(4, 4, 16);
        a.insert(0, &[1u8; 16]);
        let cap = a.as_slice().len();
        a.reset(2, 2, 16);
        assert_eq!(a.slots(), 4);
        assert_eq!(a.have_total(), 0, "reset clears presence");
        assert!(cap >= a.as_slice().len());
        a.reset(4, 4, 16);
        assert_eq!(a.slots(), 8);
    }

    #[test]
    fn have_data_counts_only_below_k() {
        let mut a = FtgArena::new(65, 10, 2);
        for i in 0..65usize {
            assert!(a.insert(i, &[i as u8; 2]));
        }
        assert!(a.data_complete(), "k spanning a bitmap word boundary");
        assert_eq!(a.have_data(), 65);
        a.insert(70, &[0u8; 2]);
        assert_eq!(a.have_data(), 65);
        assert_eq!(a.have_total(), 66);
    }

    #[test]
    fn fill_data_slices_pads_and_overwrites_stale_bytes() {
        let src: Vec<u8> = (0..22u8).collect();
        let mut a = FtgArena::new(3, 1, 8);
        // Dirty every slot, as a reused arena would be.
        a.as_mut_slice().fill(0xEE);
        a.fill_data(&src, 0);
        assert_eq!(a.slot(0), &src[0..8]);
        assert_eq!(a.slot(1), &src[8..16]);
        assert_eq!(&a.slot(2)[..6], &src[16..22]);
        assert_eq!(&a.slot(2)[6..], &[0u8; 2], "tail zero-padded, not stale");
        // Offset past the end: fully zeroed slots.
        a.as_mut_slice().fill(0xEE);
        a.fill_data(&src, 100);
        for i in 0..3 {
            assert_eq!(a.slot(i), &[0u8; 8], "slot {i}");
        }
    }

    #[test]
    fn encode_parity_fills_and_marks_all_slots() {
        let code = crate::erasure::RsCode::new(4, 2).unwrap();
        let mut a = FtgArena::new(4, 2, 32);
        for i in 0..4usize {
            a.slot_mut(i).fill(i as u8 + 1);
        }
        a.encode_parity(&code).unwrap();
        assert_eq!(a.have_total(), 6);
        assert!(a.data_complete());
        // Parity must match the Vec-based encoder.
        let data: Vec<&[u8]> = (0..4).map(|i| a.slot(i)).collect();
        let parity = code.encode(&data).unwrap();
        assert_eq!(a.slot(4), &parity[0][..]);
        assert_eq!(a.slot(5), &parity[1][..]);
    }
}
