//! Session helpers: run a sender/receiver pair over a channel pair in
//! threads and collect both reports — the harness used by examples,
//! integration tests, and the loopback (Fig. 6 / Table 2) benches.

use super::receiver::{run_receiver, ReceiverConfig, ReceiverReport};
use super::sender::{run_sender, SenderConfig, SenderReport};
use crate::transport::channel::Datagram;
use crate::util::err::Result;

/// Run a full transfer across two already-connected channels.
///
/// `sender_chan` and `receiver_chan` are the two ends (wrap the sender end
/// in [`crate::transport::channel::LossyChannel`] to inject loss).
pub fn run_session<CS, CR>(
    mut sender_chan: CS,
    mut receiver_chan: CR,
    sender_cfg: SenderConfig,
    receiver_cfg: ReceiverConfig,
    levels: Vec<Vec<u8>>,
    eps: Vec<f64>,
) -> Result<(SenderReport, ReceiverReport)>
where
    CS: Datagram + 'static,
    CR: Datagram + 'static,
{
    let recv_handle =
        std::thread::spawn(move || run_receiver(&mut receiver_chan, &receiver_cfg));
    let send_report = run_sender(&mut sender_chan, &sender_cfg, &levels, &eps)?;
    let recv_report = recv_handle
        .join()
        .map_err(|_| crate::anyhow!("receiver thread panicked"))??;
    Ok((send_report, recv_report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sender::Contract;
    use crate::model::params::NetParams;
    use crate::transport::channel::{mem_pair, LossyChannel};
    use crate::util::Pcg64;
    use std::time::Duration;

    fn test_levels(seed: u64) -> (Vec<Vec<u8>>, Vec<f64>) {
        let mut rng = Pcg64::seeded(seed);
        let sizes = [40_000usize, 160_000, 320_000, 1_000_000];
        let eps = vec![0.004, 0.0005, 0.00006, 0.0000001];
        let levels = sizes
            .iter()
            .map(|&sz| {
                let mut v = vec![0u8; sz];
                rng.fill_bytes(&mut v);
                v
            })
            .collect();
        (levels, eps)
    }

    fn fast_net(lambda: f64) -> NetParams {
        // High pacing rate so tests finish quickly; small fragments keep
        // group counts realistic.
        NetParams { t: 0.0005, r: 200_000.0, lambda, n: 32, s: 1024 }
    }

    fn sender_cfg(contract: Contract) -> SenderConfig {
        SenderConfig {
            net: fast_net(0.0),
            contract,
            initial_lambda: 0.0,
            max_duration: Duration::from_secs(60),
        }
    }

    fn receiver_cfg() -> ReceiverConfig {
        ReceiverConfig {
            t_w: 0.25,
            idle_timeout: Duration::from_secs(5),
            max_duration: Duration::from_secs(60),
        }
    }

    #[test]
    fn lossless_error_bound_transfer_delivers_exact_bytes() {
        let (levels, eps) = test_levels(1);
        let (a, b) = mem_pair();
        let (s_rep, r_rep) = run_session(
            a,
            b,
            sender_cfg(Contract::ErrorBound(1e-7)),
            receiver_cfg(),
            levels.clone(),
            eps,
        )
        .unwrap();
        assert_eq!(r_rep.levels_recovered, 4);
        for (got, want) in r_rep.levels.iter().zip(&levels) {
            assert_eq!(got.as_ref().unwrap(), want, "level bytes must match");
        }
        assert_eq!(s_rep.passes, 0);
        assert!((r_rep.achieved_eps - 1e-7).abs() < 1e-15);
    }

    #[test]
    fn error_bound_contract_sends_only_needed_levels() {
        let (levels, eps) = test_levels(2);
        let (a, b) = mem_pair();
        let (_s, r) = run_session(
            a,
            b,
            sender_cfg(Contract::ErrorBound(0.004)), // level 1 suffices
            receiver_cfg(),
            levels.clone(),
            eps,
        )
        .unwrap();
        assert_eq!(r.levels.len(), 1, "only level 1 in manifest");
        assert_eq!(r.levels[0].as_ref().unwrap(), &levels[0]);
    }

    #[test]
    fn lossy_error_bound_transfer_recovers_exactly() {
        let (levels, eps) = test_levels(3);
        let (a, b) = mem_pair();
        // 2% fragment loss on the sender's outgoing data path.
        let lossy = LossyChannel::new(a, 0.02, 99);
        let mut cfg = sender_cfg(Contract::ErrorBound(1e-7));
        cfg.initial_lambda = 0.02 * cfg.net.r; // honest initial estimate
        let (s_rep, r_rep) =
            run_session(lossy, b, cfg, receiver_cfg(), levels.clone(), eps).unwrap();
        assert_eq!(r_rep.levels_recovered, 4, "all levels must be recovered");
        for (got, want) in r_rep.levels.iter().zip(&levels) {
            assert_eq!(got.as_ref().unwrap(), want);
        }
        // With 2% loss some groups needed RS recovery or retransmission.
        assert!(r_rep.groups_recovered > 0 || s_rep.passes > 0);
    }

    #[test]
    fn deadline_contract_returns_prefix_under_heavy_loss() {
        let (levels, eps) = test_levels(4);
        let (a, b) = mem_pair();
        let lossy = LossyChannel::new(a, 0.05, 7);
        let mut cfg = sender_cfg(Contract::Deadline(60.0));
        cfg.initial_lambda = 0.05 * cfg.net.r;
        let (s_rep, r_rep) =
            run_session(lossy, b, cfg, receiver_cfg(), levels.clone(), eps).unwrap();
        assert_eq!(s_rep.passes, 0, "no retransmission under deadline contract");
        // Whatever prefix was recovered must be byte-exact.
        for i in 0..r_rep.levels_recovered {
            assert_eq!(r_rep.levels[i].as_ref().unwrap(), &levels[i]);
        }
        // The plan protects early levels: level 1 should essentially
        // always survive 5% loss.
        assert!(r_rep.levels_recovered >= 1, "level 1 must survive");
    }

    #[test]
    fn receiver_reports_lambda_estimates() {
        let (levels, eps) = test_levels(5);
        let (a, b) = mem_pair();
        let lossy = LossyChannel::new(a, 0.03, 13);
        let mut cfg = sender_cfg(Contract::ErrorBound(1e-7));
        cfg.initial_lambda = 0.03 * cfg.net.r;
        // Tiny window: the whole scaled transfer lasts ~10 ms of wall time.
        let rcfg = ReceiverConfig { t_w: 0.002, ..receiver_cfg() };
        let (s_rep, r_rep) = run_session(lossy, b, cfg, rcfg, levels, eps).unwrap();
        assert!(!r_rep.lambda_reports.is_empty(), "λ̂ must be reported");
        assert!(!s_rep.lambda_updates.is_empty(), "sender must see λ̂");
        // λ̂ should track the loss fraction times the *achieved* wire rate
        // (sleep-granularity pacing undershoots the nominal r).
        let achieved_rate = s_rep.fragments_sent as f64 / s_rep.duration;
        let expect = 0.03 * achieved_rate;
        let mean = crate::util::stats::mean(&r_rep.lambda_reports);
        assert!(
            mean > 0.2 * expect && mean < 3.0 * expect,
            "λ̂ mean {mean} vs expected ≈{expect}"
        );
    }
}
