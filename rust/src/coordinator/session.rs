//! Session helper: run a sender/receiver pair over a channel pair in
//! threads and collect both reports.
//!
//! Superseded by the [`crate::api`] facade ([`crate::api::run_pair`]);
//! kept as the engine behind the deprecated [`run_session`] shim.

use super::receiver::{transfer_receiver, ReceiverConfig, ReceiverReport};
use super::sender::{transfer_sender, SenderConfig, SenderReport};
use crate::transport::channel::Datagram;
use crate::util::err::Result;

/// Run a full transfer across two already-connected channels.
#[deprecated(note = "use janus::api::run_pair")]
pub fn run_session<CS, CR>(
    sender_chan: CS,
    receiver_chan: CR,
    sender_cfg: SenderConfig,
    receiver_cfg: ReceiverConfig,
    levels: Vec<Vec<u8>>,
    eps: Vec<f64>,
) -> Result<(SenderReport, ReceiverReport)>
where
    CS: Datagram + 'static,
    CR: Datagram + 'static,
{
    transfer_session(sender_chan, receiver_chan, sender_cfg, receiver_cfg, levels, eps)
}

/// Session engine: receiver on a spawned thread, sender on the caller's.
/// `sender_chan` and `receiver_chan` are the two ends (wrap the sender
/// end in [`crate::transport::channel::LossyChannel`] to inject loss).
pub(crate) fn transfer_session<CS, CR>(
    mut sender_chan: CS,
    mut receiver_chan: CR,
    sender_cfg: SenderConfig,
    receiver_cfg: ReceiverConfig,
    levels: Vec<Vec<u8>>,
    eps: Vec<f64>,
) -> Result<(SenderReport, ReceiverReport)>
where
    CS: Datagram + 'static,
    CR: Datagram + 'static,
{
    let recv_handle =
        std::thread::spawn(move || transfer_receiver(&mut receiver_chan, &receiver_cfg, None));
    let send_report = transfer_sender(&mut sender_chan, &sender_cfg, &levels, &eps, None)?;
    let recv_report = recv_handle
        .join()
        .map_err(|_| crate::anyhow!("receiver thread panicked"))??;
    Ok((send_report, recv_report))
}
