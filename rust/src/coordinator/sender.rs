//! Sender engine — Alg. 1 / Alg. 2 over a real datagram transport.
//!
//! Mirrors the paper's §4 sender: a *parity generation thread* slices the
//! refactored levels into fragments, solves the active optimization model
//! for the redundancy, and Reed–Solomon-encodes FTGs into a bounded
//! pipeline (backpressure); a *transmission thread* paces fragments onto
//! the wire at `r = min(r_ec, r_link)`, processes receiver feedback
//! (λ updates, lost-FTG lists) and drives passive retransmission.

use super::arena::FtgArena;
use super::packet::{
    encode_fragment_into, validate_fragment_size, FragmentHeader, Manifest, ManifestLevel, Packet,
};
use super::rate::{AdaptConfig, RateController, RttEstimator};
use crate::api::observer::{emit, EventSink};
use crate::api::{Contract, TransferEvent};
use crate::erasure::RsCode;
use crate::model::error_model::optimize_deadline_bitplane;
use crate::model::params::{LevelSchedule, NetParams, PlaneCut};
use crate::model::time_model::optimize_parity;
use crate::transport::channel::Datagram;
use crate::util::err::{Context, Result};
use crate::{anyhow, bail};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Sender configuration.
#[derive(Debug, Clone)]
pub struct SenderConfig {
    /// Network/coding parameters; `net.r` is the pacing rate `r_link`.
    pub net: NetParams,
    pub contract: Contract,
    /// Initial λ estimate for the first solve (losses/s).
    pub initial_lambda: f64,
    /// Abort the transfer after this much wall time.
    pub max_duration: Duration,
    /// Sub-level [`PlaneCut`]s per level (codec datasets; empty = whole-
    /// level granularity). Lets the Deadline contract shed the final
    /// level to a decodable bitplane prefix instead of dropping it.
    pub plane_cuts: Vec<Vec<PlaneCut>>,
    /// Congestion/burst adaptation knobs ([`AdaptConfig::fixed`] for the
    /// legacy fixed-rate behaviour).
    pub adapt: AdaptConfig,
}

/// What the sender did.
#[derive(Debug, Clone)]
pub struct SenderReport {
    pub fragments_sent: u64,
    pub data_fragments: u64,
    pub passes: u32,
    pub duration: f64,
    /// (fragment index, m) history — records adaptation (Alg. 1).
    pub m_history: Vec<(u64, usize)>,
    /// Per-level plan history (Alg. 2 re-solves).
    pub plan_history: Vec<Vec<usize>>,
    /// Measured parity-generation rate, fragments/s (`r_ec`).
    pub encode_rate: f64,
    /// λ updates received from the peer.
    pub lambda_updates: Vec<f64>,
    /// Pacing rate after each pass barrier (fragments/s) — records the
    /// controller's back-off/recovery trajectory.
    pub rate_history: Vec<f64>,
}

/// One encoded FTG traveling from the parity thread to the tx thread:
/// all k+m fragments in one strided arena (one allocation per group,
/// not k+m+2 — ISSUE 3).
struct EncodedFtg {
    level: u8,
    ftg: u32,
    k: u8,
    m: u8,
    arena: FtgArena,
}

/// Run a transfer as the sender.
#[deprecated(note = "use janus::api::Endpoint::send")]
pub fn run_sender(
    chan: &mut dyn Datagram,
    cfg: &SenderConfig,
    levels: &[Vec<u8>],
    eps: &[f64],
) -> Result<SenderReport> {
    transfer_sender(chan, cfg, levels, eps, None)
}

/// Single-stream sender engine. `levels` are the refactored level byte
/// buffers (largest-error-reduction first), `eps[i]` the error bound after
/// receiving levels `0..=i`. Public entry: [`crate::api::Endpoint::send`].
pub(crate) fn transfer_sender(
    chan: &mut dyn Datagram,
    cfg: &SenderConfig,
    levels: &[Vec<u8>],
    eps: &[f64],
    events: EventSink<'_>,
) -> Result<SenderReport> {
    assert_eq!(levels.len(), eps.len());
    let start = Instant::now();
    let n = cfg.net.n;
    let s = cfg.net.s;
    validate_fragment_size(s)?;
    let sched = LevelSchedule::new(levels.iter().map(|l| l.len() as u64).collect(), eps.to_vec())
        .with_cuts(cfg.plane_cuts.clone());

    // Contract-dependent level count and plan. The Deadline contract may
    // shed the final level to a decodable plane-prefix (codec datasets
    // carry `plane_cuts`), so each level also gets a byte limit and a
    // manifest ε: full levels keep theirs, a partial level advertises the
    // cut's measured ε and its truncated size.
    let mut limits: Vec<usize> = levels.iter().map(|l| l.len()).collect();
    let mut manifest_eps = eps.to_vec();
    let mut cut_flags = vec![false; levels.len()];
    let (send_levels, deadline) = match cfg.contract {
        Contract::Fidelity(bound) => {
            let l = sched.levels_for_error_bound(bound).ok_or_else(|| {
                anyhow!("error bound {bound} unachievable: ε_L = {}", eps[eps.len() - 1])
            })?;
            (l, None)
        }
        Contract::BestEffort => (levels.len(), None),
        Contract::Deadline(tau) => {
            let p = NetParams { lambda: cfg.initial_lambda, ..cfg.net };
            let plan = optimize_deadline_bitplane(&p, &sched, tau)
                .ok_or_else(|| anyhow!("deadline {tau}s infeasible for this schedule"))?;
            let mut m = plan.base.m.clone();
            let mut send = plan.base.levels;
            if let Some((li, cut)) = plan.partial {
                limits[li] = cut.bytes as usize;
                manifest_eps[li] = cut.eps;
                cut_flags[li] = true;
                m.push(0); // partial level ships unprotected (§5.2.3)
                send = li + 1;
            }
            (send, Some((tau, m)))
        }
    };
    // Per-level pass-0 parity advertised in the manifest. Deadline plans
    // fix it per level; the adaptive contracts start from the initial
    // Eq. 8 solve (the same one the parity thread seeds itself with).
    // This is a geometry *contract*: the parity thread freezes each
    // level's k at n − m0 and λ adaptation moves only the parity count,
    // so the receiver's `collect_lost` can stride never-seen groups
    // exactly instead of by the worst case.
    let manifest_m0: Vec<u8> = match &deadline {
        Some((_, m)) => m.iter().map(|&mi| mi as u8).collect(),
        None => {
            let p = NetParams { lambda: cfg.initial_lambda, ..cfg.net };
            let m = optimize_parity(&p, sched.total_bytes(send_levels).max(1)).m;
            vec![m as u8; send_levels]
        }
    };

    // Shared λ̂ (updated by tx thread from receiver feedback, read by the
    // parity thread when re-solving) — stored as bits of f64.
    let lambda_bits = Arc::new(AtomicU64::new(cfg.initial_lambda.to_bits()));
    let lambda_epoch = Arc::new(AtomicU64::new(0));

    // Handshake: manifest until ack.
    let manifest = Packet::Manifest(Manifest {
        n: n as u8,
        s: s as u32,
        streams: 1,
        levels: (0..send_levels)
            .map(|i| ManifestLevel {
                size: limits[i] as u64,
                eps: manifest_eps[i],
                m0: manifest_m0[i],
                cut: cut_flags[i],
            })
            .collect(),
        contract: u8::from(!cfg.contract.retransmits()),
    });
    let mut acked = false;
    for _ in 0..50 {
        chan.send(&manifest.encode());
        if let Some(buf) = chan.recv_timeout(Duration::from_millis(100)) {
            if matches!(Packet::decode(&buf), Ok(Packet::ManifestAck)) {
                acked = true;
                break;
            }
        }
    }
    if !acked {
        bail!("receiver did not acknowledge manifest");
    }

    let mut report = SenderReport {
        fragments_sent: 0,
        data_fragments: 0,
        passes: 0,
        duration: 0.0,
        m_history: Vec::new(),
        plan_history: Vec::new(),
        encode_rate: 0.0,
        lambda_updates: Vec::new(),
        rate_history: Vec::new(),
    };
    if let Some((_, plan)) = &deadline {
        report.plan_history.push(plan.clone());
    }

    // Parity pipeline: bounded to keep memory flat and give the paper's
    // backpressure between generation and transmission.
    let (ftg_tx, ftg_rx) = sync_channel::<EncodedFtg>(64);
    let enc_lambda = Arc::clone(&lambda_bits);
    let enc_epoch = Arc::clone(&lambda_epoch);
    let net = cfg.net;
    let contract = cfg.contract;
    let deadline_plan = deadline.clone();
    let enc_stats = Arc::new(AtomicU64::new(0)); // fragments encoded
    let enc_stats2 = Arc::clone(&enc_stats);
    let sched2 = sched.clone();
    let enc_m0 = manifest_m0.clone();

    // Emitted before the parity thread spawns so PassStarted is always
    // the first event of the transfer.
    emit(events, TransferEvent::PassStarted { pass: 0 });
    let result: Result<SenderReport> = std::thread::scope(|scope| {
        // === Parity generation thread ===
        let levels_ref = levels;
        let m_history = scope.spawn(move || -> Vec<(u64, usize)> {
            let mut history = Vec::new();
            let mut codes: HashMap<(usize, usize), RsCode> = HashMap::new();
            let mut seen_epoch = 0u64;
            let mut frag_counter = 0u64;
            let enc_start = Instant::now();

            // Current redundancy: Alg. 1 keeps a single m; Alg. 2 a plan.
            let mut current_m = if contract.retransmits() {
                let p = NetParams {
                    lambda: f64::from_bits(enc_lambda.load(Ordering::Relaxed)),
                    ..net
                };
                optimize_parity(&p, sched2.total_bytes(send_levels)).m
            } else {
                0
            };
            let plan = deadline_plan.as_ref().map(|(_, m)| m.clone());
            history.push((0, current_m));
            if contract.retransmits() {
                emit(events, TransferEvent::ParityAdapted { pass: 0, m: current_m });
            }

            'levels: for (li, level_bytes) in levels_ref.iter().enumerate().take(send_levels) {
                // Deadline shedding may cap the level at a plane-cut
                // byte prefix; everything else sends the full buffer.
                let limit = limits[li].min(level_bytes.len());
                let mut offset = 0usize;
                let mut ftg_id = 0u32;
                let mut remaining = limit;
                while remaining > 0 {
                    // Adapt on fresh λ (Alg. 1 path; Alg. 2 re-solve of the
                    // remaining levels happens in the tx thread via plan
                    // updates — kept simple: deadline plan is static per
                    // level here, re-solving is exercised in the sim).
                    let epoch = enc_epoch.load(Ordering::Acquire);
                    if epoch != seen_epoch {
                        seen_epoch = epoch;
                        if contract.retransmits() {
                            let lam = f64::from_bits(enc_lambda.load(Ordering::Relaxed));
                            let p = NetParams { lambda: lam, ..net };
                            let left = remaining as u64
                                + sched2.sizes[li + 1..send_levels].iter().sum::<u64>();
                            let m_new = optimize_parity(&p, left.max(1)).m;
                            if m_new != current_m {
                                current_m = m_new;
                                history.push((frag_counter, m_new));
                                emit(events, TransferEvent::ParityAdapted { pass: 0, m: m_new });
                            }
                        }
                    }
                    // Deadline plans fix m per level; otherwise use the
                    // λ̂-adapted value.
                    let m = match &plan {
                        Some(p) => p[li],
                        None => current_m,
                    };
                    // Geometry is frozen at the manifest's m0: k never
                    // follows the adapted m (the old `k = n − m` made a
                    // mid-pass λ update silently re-shape group
                    // boundaries, so the receiver could not enumerate
                    // never-seen groups and whole-pass loss cost one
                    // extra feedback round per group). Groups may carry
                    // k + m ≠ n slots; the header's (k, m) stays
                    // authoritative for the receiver's arenas.
                    let k = n
                        .saturating_sub(enc_m0[li] as usize)
                        .max(1)
                        .min(remaining.div_ceil(s).max(1));
                    let code = codes
                        .entry((k, m))
                        .or_insert_with(|| RsCode::new(k, m).expect("valid k,m"));
                    // Slice k data fragments straight into one strided
                    // arena (fresh arena → slots pre-zeroed, so the tail
                    // padding is already there) and encode parity in
                    // place.
                    let mut arena = FtgArena::new(k as u8, m as u8, s);
                    for i in 0..k {
                        let lo = offset.min(limit);
                        let hi = (offset + s).min(limit);
                        arena.slot_mut(i)[..hi - lo].copy_from_slice(&level_bytes[lo..hi]);
                        offset += s;
                        remaining = remaining.saturating_sub(s);
                    }
                    arena.encode_parity(&*code).expect("encode");
                    frag_counter += arena.slots() as u64;
                    enc_stats2.store(
                        (frag_counter as f64 / enc_start.elapsed().as_secs_f64().max(1e-9))
                            as u64,
                        Ordering::Relaxed,
                    );
                    let encoded =
                        EncodedFtg { level: li as u8, ftg: ftg_id, k: k as u8, m: m as u8, arena };
                    if ftg_tx.send(encoded).is_err() {
                        break 'levels; // tx thread gone (abort)
                    }
                    ftg_id += 1;
                }
            }
            drop(ftg_tx);
            history
        });

        // === Transmission thread (this thread) ===
        let tx_result = transmit_loop(
            chan,
            cfg,
            &ftg_rx,
            &lambda_bits,
            &lambda_epoch,
            deadline.as_ref().map(|(tau, _)| *tau),
            start,
            &mut report,
            events,
        );
        // Unblock the parity thread if the tx loop exited early (error or
        // deadline): dropping the receiver makes its send() fail fast;
        // otherwise join would deadlock on a full pipeline.
        drop(ftg_rx);
        let history = m_history.join().map_err(|_| anyhow!("parity thread panicked"))?;
        report.m_history = history;
        report.encode_rate = enc_stats.load(Ordering::Relaxed) as f64;
        tx_result?;
        report.duration = start.elapsed().as_secs_f64();
        Ok(report.clone())
    });
    result.context("sender failed")
}

/// Pace fragments, handle feedback, run retransmission passes.
#[allow(clippy::too_many_arguments)]
fn transmit_loop(
    chan: &mut dyn Datagram,
    cfg: &SenderConfig,
    ftg_rx: &Receiver<EncodedFtg>,
    lambda_bits: &AtomicU64,
    lambda_epoch: &AtomicU64,
    deadline: Option<f64>,
    start: Instant,
    report: &mut SenderReport,
    events: EventSink<'_>,
) -> Result<()> {
    // Pacing: the controller starts at the configured `r` and moves
    // only on pass-barrier verdicts (congestion back-off / cubic
    // recovery). `AdaptConfig::fixed()` reproduces the legacy 1/r pace.
    let mut controller = RateController::new(cfg.net.r, cfg.adapt);
    let mut pace = Duration::from_secs_f64(1.0 / controller.rate());
    // Barrier retry cadence: cold RTO equals the legacy fixed 200 ms
    // retry window, then tightens to the measured feedback RTT.
    let mut rtt = RttEstimator::new(0.02, 0.2);
    let mut next_send = Instant::now();
    let mut seq = 0u64;
    let mut pass_groups = 0u64;
    let mut out = Vec::with_capacity(cfg.net.s + 64);
    // Retained FTGs for retransmission (Alg. 1 only).
    let retain = cfg.contract.retransmits();
    let mut buf_store: HashMap<(u8, u32), EncodedFtg> = HashMap::new();

    let poll_feedback = |chan: &mut dyn Datagram, report: &mut SenderReport| {
        while let Some(buf) = chan.try_recv() {
            if let Ok(Packet::LambdaUpdate { lambda }) = Packet::decode(&buf) {
                report.lambda_updates.push(lambda);
                lambda_bits.store(lambda.to_bits(), Ordering::Relaxed);
                lambda_epoch.fetch_add(1, Ordering::Release);
                emit(events, TransferEvent::LambdaUpdated { lambda });
            }
        }
    };

    // === Initial pass ===
    loop {
        if start.elapsed() > cfg.max_duration {
            bail!("sender exceeded max duration");
        }
        let ftg = match ftg_rx.recv_timeout(Duration::from_millis(200)) {
            Ok(f) => f,
            Err(RecvTimeoutError::Disconnected) => break,
            Err(RecvTimeoutError::Timeout) => continue,
        };
        for idx in 0..ftg.arena.slots() {
            let hdr = FragmentHeader {
                level: ftg.level,
                stream: 0,
                ftg: ftg.ftg,
                index: idx as u8,
                k: ftg.k,
                m: ftg.m,
                seq,
                pass: 0,
            };
            seq += 1;
            encode_fragment_into(&hdr, ftg.arena.slot(idx), &mut out);
            // Pace to r_link (hybrid sleep+spin: plain sleep overshoots
            // by the timer granularity and starves the nominal rate).
            pace_until(next_send);
            next_send = Instant::now().max(next_send) + pace;
            chan.send(&out);
            report.fragments_sent += 1;
            if idx < ftg.k as usize {
                report.data_fragments += 1;
            }
            if seq % 64 == 0 {
                poll_feedback(chan, report);
            }
        }
        pass_groups += 1;
        if retain {
            buf_store.insert((ftg.level, ftg.ftg), ftg);
        }
        // Deadline contract: hard stop at τ.
        if let Some(tau) = deadline {
            if start.elapsed().as_secs_f64() >= tau {
                break;
            }
        }
    }

    // === End-of-pass + retransmission rounds (Alg. 1) ===
    let mut pass = 0u32;
    emit(
        events,
        TransferEvent::StreamFinished { stream: 0, pass: 0, fragments: report.fragments_sent },
    );
    loop {
        // Notify end of pass; await the lost list (re-notify on timeout).
        // The retry window is the RTT estimator's RTO, fed by the
        // latency of each successful EndOfPass → LostList exchange.
        let mut lost: Option<(u32, Vec<(u8, u32)>)> = None;
        for _ in 0..100 {
            let eop_sent = Instant::now();
            chan.send(&Packet::EndOfPass { pass }.encode());
            let deadline_wait = eop_sent + Duration::from_secs_f64(rtt.rto());
            while Instant::now() < deadline_wait {
                match chan.recv_timeout(Duration::from_millis(50)) {
                    Some(buf) => match Packet::decode(&buf) {
                        Ok(Packet::LostList { pass: p, total, ftgs }) if p == pass => {
                            rtt.observe(eop_sent.elapsed().as_secs_f64());
                            lost = Some((total, ftgs));
                            break;
                        }
                        Ok(Packet::Done) => return Ok(()),
                        Ok(Packet::LambdaUpdate { lambda }) => {
                            report.lambda_updates.push(lambda);
                            lambda_bits.store(lambda.to_bits(), Ordering::Relaxed);
                            lambda_epoch.fetch_add(1, Ordering::Release);
                            emit(events, TransferEvent::LambdaUpdated { lambda });
                        }
                        _ => {}
                    },
                    None => break,
                }
            }
            if lost.is_some() {
                break;
            }
            if start.elapsed() > cfg.max_duration {
                bail!("sender timed out waiting for lost list");
            }
        }
        let (lost_total, lost) = match lost {
            Some(l) => l,
            None => {
                if !cfg.contract.retransmits() {
                    // No retransmission contract: peer may simply be done.
                    return Ok(());
                }
                bail!("no response to EndOfPass");
            }
        };
        if lost.is_empty() || !retain {
            return Ok(());
        }
        // Pass-barrier rate decision. The single-stream receiver reports
        // group-granular loss only, so the group-failure fraction stands
        // in for the fragment loss fraction and runs are unobserved
        // (burst_len = 1 ⇒ the controller relies on its rate-response
        // probe to discriminate congestion from channel loss).
        let loss_frac = (lost_total as f64 / pass_groups.max(1) as f64).min(1.0);
        controller.on_pass(start.elapsed().as_secs_f64(), loss_frac, 1.0);
        if (controller.rate() - cfg.net.r).abs() > f64::EPSILON * cfg.net.r {
            emit(
                events,
                TransferEvent::RateAdapted {
                    pass,
                    rate: controller.rate(),
                    backoff: controller.rate() < controller.r_max(),
                },
            );
        }
        report.rate_history.push(controller.rate());
        pace = Duration::from_secs_f64(1.0 / controller.rate());
        // Retransmit the lost FTGs.
        pass += 1;
        pass_groups = lost.len() as u64;
        report.passes = pass;
        emit(events, TransferEvent::PassStarted { pass });
        let pass_start_fragments = report.fragments_sent;
        for key in &lost {
            if let Some(ftg) = buf_store.get(key) {
                for idx in 0..ftg.arena.slots() {
                    let hdr = FragmentHeader {
                        level: ftg.level,
                        stream: 0,
                        ftg: ftg.ftg,
                        index: idx as u8,
                        k: ftg.k,
                        m: ftg.m,
                        seq,
                        pass,
                    };
                    seq += 1;
                    encode_fragment_into(&hdr, ftg.arena.slot(idx), &mut out);
                    pace_until(next_send);
                    next_send = Instant::now().max(next_send) + pace;
                    chan.send(&out);
                    report.fragments_sent += 1;
                }
            }
        }
        emit(
            events,
            TransferEvent::StreamFinished {
                stream: 0,
                pass,
                fragments: report.fragments_sent - pass_start_fragments,
            },
        );
        if start.elapsed() > cfg.max_duration {
            bail!("sender exceeded max duration during retransmission");
        }
    }
}

/// Sleep-then-spin until `deadline`: coarse sleep to within 200 µs, then
/// spin for precision — keeps the achieved wire rate at the nominal `r`.
/// Shared with the multi-stream pool workers.
#[inline]
pub(crate) fn pace_until(deadline: Instant) {
    let now = Instant::now();
    if deadline <= now {
        return;
    }
    let gap = deadline - now;
    if gap > Duration::from_micros(250) {
        std::thread::sleep(gap - Duration::from_micros(200));
    }
    while Instant::now() < deadline {
        std::hint::spin_loop();
    }
}
