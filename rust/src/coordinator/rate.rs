//! Congestion-aware rate control for the transfer engines.
//!
//! JANUS's fixed `pace = 1/r` treats every loss as channel noise to be
//! out-coded with parity. On a shared path that is exactly wrong:
//! congestion loss must be answered by *sending slower*, not by coding
//! harder (which adds load and makes the collapse worse). This module
//! supplies the discrimination machinery the pass barrier needs:
//!
//! * [`RttEstimator`] — SRTT/RTTVAR/RTO in the RFC 6298 shape, fed by
//!   the wall-clock latency of the pass-barrier feedback exchange. It
//!   drives only the *retry cadence* of the (idempotent) barrier
//!   control exchange — never rate decisions — so engine traces stay a
//!   pure function of (config, dataset, channel seeds).
//! * [`RateController`] — a CUBIC-style pacer in the rate domain,
//!   driven by **virtual** pass time: multiplicative decrease on
//!   confirmed congestion, cubic recovery toward the pre-loss rate on
//!   clean passes, full restore when a probe proves the loss is channel
//!   noise.
//! * [`AdaptConfig`] — the knobs, with [`AdaptConfig::fixed`]
//!   reproducing the legacy fixed-rate/i.i.d. behaviour (the baseline
//!   the adaptive path is benchmarked against).
//!
//! Congestion vs channel loss is settled by a deterministic
//! rate-response probe. A policer of capacity `c` drops the fraction
//! `1 − c/rate` regardless of coding; random or burst channel loss
//! drops a fraction independent of the send rate. So on a suspect pass
//! (lossy, but not burst-shaped) the controller backs off one pass and
//! compares the observed loss against both predictions:
//!
//! ```text
//! congestion prediction: max(0, 1 − capacity_est / rate_new)
//!                        capacity_est = rate_old · (1 − loss_old)
//! channel prediction:    loss_old   (rate-independent)
//! ```
//!
//! whichever is closer wins. Burst-shaped loss (mean run length ≥
//! [`AdaptConfig::burst_threshold`]) skips the probe entirely: bursts
//! at sustained rate are the classic channel-fade signature and are
//! answered with parity sized by the burst-aware Eq. 8
//! ([`crate::model::optimize_parity_bursty`]).

/// Knobs of the adaptive layer shared by both engines.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptConfig {
    /// Pace passes with the CUBIC controller (false = fixed `1/r`).
    pub rate_control: bool,
    /// Feed measured burst length into the Eq. 8 / Eq. 12 re-solves
    /// (false = i.i.d. λ̂, the pre-adaptive behaviour).
    pub burst_aware: bool,
    /// Multiplicative decrease factor on congestion (CUBIC β).
    pub beta: f64,
    /// Cubic growth coefficient, as a fraction of the configured rate
    /// per cubic-second (dimensionless; scales with `r`).
    pub cubic_c: f64,
    /// Mean loss-run length at or above which a lossy pass is
    /// classified as channel burst loss (code harder, sustain rate).
    pub burst_threshold: f64,
    /// Pass loss fraction at or below which the pass counts as clean.
    pub loss_threshold: f64,
    /// Passes to wait after a channel verdict before probing again.
    pub probe_holdoff: u32,
    /// Rate floor, as a fraction of the configured per-stream rate.
    pub min_rate_frac: f64,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        AdaptConfig {
            rate_control: true,
            burst_aware: true,
            beta: 0.7,
            cubic_c: 0.4,
            burst_threshold: 3.0,
            loss_threshold: 0.02,
            probe_holdoff: 2,
            min_rate_frac: 0.25,
        }
    }
}

impl AdaptConfig {
    /// Legacy behaviour: fixed pacing at the configured rate and the
    /// i.i.d. per-pass λ̂ — the ablation baseline.
    pub fn fixed() -> Self {
        AdaptConfig { rate_control: false, burst_aware: false, ..AdaptConfig::default() }
    }

    /// Engine-side sanity gate (the typed builder validates earlier).
    pub fn validate(&self) -> crate::util::err::Result<()> {
        if !(0.0 < self.beta && self.beta < 1.0) {
            crate::bail!("adapt.beta must be in (0, 1), got {}", self.beta);
        }
        if !(self.cubic_c > 0.0 && self.cubic_c.is_finite()) {
            crate::bail!("adapt.cubic_c must be positive, got {}", self.cubic_c);
        }
        if !(self.burst_threshold >= 1.0) {
            crate::bail!("adapt.burst_threshold must be ≥ 1, got {}", self.burst_threshold);
        }
        if !(0.0..1.0).contains(&self.loss_threshold) {
            crate::bail!("adapt.loss_threshold must be in [0, 1), got {}", self.loss_threshold);
        }
        if !(0.0 < self.min_rate_frac && self.min_rate_frac <= 1.0) {
            crate::bail!("adapt.min_rate_frac must be in (0, 1], got {}", self.min_rate_frac);
        }
        Ok(())
    }
}

/// SRTT/RTTVAR/RTO estimator (RFC 6298 shape: α = 1/8, β = 1/4).
///
/// Fed with wall-clock samples of the pass-barrier feedback exchange
/// (EndOfPass sent → PassStats received); [`RttEstimator::rto`] sets
/// the retry timeout of that idempotent exchange, replacing the fixed
/// 200 ms retry the engines used before.
#[derive(Debug, Clone)]
pub struct RttEstimator {
    srtt: Option<f64>,
    rttvar: f64,
    min_rto: f64,
    max_rto: f64,
}

const RTT_ALPHA: f64 = 0.125;
const RTT_BETA: f64 = 0.25;

impl RttEstimator {
    /// `min_rto`/`max_rto` clamp the retry timeout (seconds).
    pub fn new(min_rto: f64, max_rto: f64) -> Self {
        assert!(0.0 < min_rto && min_rto <= max_rto);
        RttEstimator { srtt: None, rttvar: 0.0, min_rto, max_rto }
    }

    /// Record one RTT sample (seconds, non-negative).
    pub fn observe(&mut self, rtt: f64) {
        if !rtt.is_finite() || rtt < 0.0 {
            return;
        }
        match self.srtt {
            None => {
                self.srtt = Some(rtt);
                self.rttvar = rtt / 2.0;
            }
            Some(srtt) => {
                self.rttvar = (1.0 - RTT_BETA) * self.rttvar + RTT_BETA * (srtt - rtt).abs();
                self.srtt = Some((1.0 - RTT_ALPHA) * srtt + RTT_ALPHA * rtt);
            }
        }
    }

    /// Smoothed RTT, if warmed up.
    pub fn srtt(&self) -> Option<f64> {
        self.srtt
    }

    /// Retransmission timeout: `srtt + 4·rttvar`, clamped; `max_rto`
    /// before the first sample (a cold barrier must not spin).
    pub fn rto(&self) -> f64 {
        match self.srtt {
            None => self.max_rto,
            Some(srtt) => (srtt + 4.0 * self.rttvar).clamp(self.min_rto, self.max_rto),
        }
    }
}

/// How the controller judged one pass barrier.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PassVerdict {
    /// Loss at or below the clean threshold; rate grows cubically.
    Clean,
    /// Burst-shaped channel loss: sustain rate, code harder with the
    /// measured mean burst length.
    Burst { burst_len: f64 },
    /// Lossy but shape-ambiguous: rate backed off for one probe pass.
    Probing,
    /// Probe settled on congestion: stay backed off (CUBIC regime).
    Congestion { residual_loss: f64 },
    /// Probe settled on channel loss: rate restored, parity handles it.
    ChannelLoss,
}

/// Outstanding rate-response probe.
#[derive(Debug, Clone, Copy)]
struct Probe {
    /// Loss fraction of the pass that triggered the probe.
    pre_loss: f64,
    /// Rate the trigger pass ran at.
    r_old: f64,
}

/// CUBIC-style pacer in the rate domain (fragments/s per stream),
/// clocked by **virtual** pass time so decisions are deterministic.
#[derive(Debug, Clone)]
pub struct RateController {
    cfg: AdaptConfig,
    /// Configured (ceiling) per-stream rate.
    r_max: f64,
    /// Current per-stream pacing rate.
    rate: f64,
    /// Rate at the last multiplicative decrease (CUBIC `W_max`).
    w_max: f64,
    /// Virtual time of the last decrease (CUBIC epoch start).
    epoch: f64,
    probe: Option<Probe>,
    holdoff: u32,
    /// Path capacity implied by the last congestion verdict
    /// (fragments/s per stream); `None` when no congestion is in
    /// evidence. Consumed by the Deadline re-planner, which must not
    /// price residual work at a rate the path has been shown to drop.
    capacity: Option<f64>,
}

impl RateController {
    pub fn new(r_max: f64, cfg: AdaptConfig) -> Self {
        assert!(r_max > 0.0 && r_max.is_finite());
        RateController {
            cfg,
            r_max,
            rate: r_max,
            w_max: r_max,
            epoch: 0.0,
            probe: None,
            holdoff: 0,
            capacity: None,
        }
    }

    /// Current per-stream pacing rate (fragments/s).
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Capacity implied by the last congestion verdict (fragments/s per
    /// stream), or `None` while the path shows no congestion. Cleared
    /// when a probe settles on channel loss, on a burst verdict, and
    /// once clean passes carry the rate past the estimate (the policer
    /// is gone or was never that tight).
    pub fn capacity_estimate(&self) -> Option<f64> {
        self.capacity
    }

    /// Configured ceiling rate.
    pub fn r_max(&self) -> f64 {
        self.r_max
    }

    fn floor(&self) -> f64 {
        self.r_max * self.cfg.min_rate_frac
    }

    /// CUBIC window as a function of time since the last decrease:
    /// `W(t) = C·(t − K)³ + W_max`, `K = ∛(W_max·(1−β)/C)`, with
    /// `C = cubic_c · r_max` so the knob is scale-free.
    fn cubic_at(&self, now: f64) -> f64 {
        let c = self.cfg.cubic_c * self.r_max;
        let k = (self.w_max * (1.0 - self.cfg.beta) / c).cbrt();
        let t = (now - self.epoch).max(0.0);
        c * (t - k).powi(3) + self.w_max
    }

    fn decrease(&mut self, now: f64) {
        self.w_max = self.rate;
        self.rate = (self.rate * self.cfg.beta).max(self.floor());
        self.epoch = now;
    }

    /// Feed one pass-barrier observation and update the rate the next
    /// pass will be paced at. `now` is virtual seconds elapsed,
    /// `loss_frac` the pass loss fraction, `burst_len` the mean length
    /// of the receiver's observed loss runs (≥ 1 when any loss).
    pub fn on_pass(&mut self, now: f64, loss_frac: f64, burst_len: f64) -> PassVerdict {
        if !self.cfg.rate_control {
            return if loss_frac <= self.cfg.loss_threshold {
                PassVerdict::Clean
            } else {
                PassVerdict::Burst { burst_len }
            };
        }
        if let Some(p) = self.probe.take() {
            // Rate response observed: attribute the trigger pass.
            let capacity_est = p.r_old * (1.0 - p.pre_loss);
            let congestion_pred = (1.0 - capacity_est / self.rate).max(0.0);
            let channel_pred = p.pre_loss;
            let is_congestion = (loss_frac - congestion_pred).abs()
                <= (loss_frac - channel_pred).abs();
            if is_congestion {
                // Stay backed off; decrease again while loss persists.
                if loss_frac > self.cfg.loss_threshold {
                    self.decrease(now);
                }
                self.capacity = Some(capacity_est);
                let residual =
                    (1.0 - capacity_est.min(self.rate) / self.rate).max(0.0);
                return PassVerdict::Congestion { residual_loss: residual };
            }
            // Channel loss: the back-off bought nothing — restore.
            self.rate = self.r_max;
            self.holdoff = self.cfg.probe_holdoff;
            self.capacity = None;
            return PassVerdict::ChannelLoss;
        }
        if loss_frac <= self.cfg.loss_threshold {
            // Clean pass: cubic growth toward (and past) w_max.
            if self.rate < self.r_max {
                self.rate = self.cubic_at(now).clamp(self.rate, self.r_max);
            }
            if self.capacity.map_or(false, |cap| self.rate > cap) {
                // Running clean above the estimate falsifies it.
                self.capacity = None;
            }
            self.holdoff = self.holdoff.saturating_sub(1);
            return PassVerdict::Clean;
        }
        if self.cfg.burst_aware && burst_len >= self.cfg.burst_threshold {
            // Burst-shaped channel loss: never back off, code harder.
            self.rate = self.r_max;
            self.capacity = None;
            return PassVerdict::Burst { burst_len };
        }
        if self.holdoff > 0 {
            self.holdoff -= 1;
            return PassVerdict::ChannelLoss;
        }
        // Ambiguous loss: probe with one backed-off pass.
        self.probe = Some(Probe { pre_loss: loss_frac, r_old: self.rate });
        self.rate = (self.rate * self.cfg.beta).max(self.floor());
        self.epoch = now;
        PassVerdict::Probing
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtt_first_sample_initializes_rfc6298() {
        let mut e = RttEstimator::new(0.05, 2.0);
        assert_eq!(e.rto(), 2.0, "cold estimator retries at max_rto");
        e.observe(0.1);
        assert!((e.srtt().unwrap() - 0.1).abs() < 1e-12);
        // rto = srtt + 4·(srtt/2) = 3·srtt
        assert!((e.rto() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn rtt_converges_and_clamps() {
        let mut e = RttEstimator::new(0.05, 2.0);
        for _ in 0..200 {
            e.observe(0.01);
        }
        assert!((e.srtt().unwrap() - 0.01).abs() < 1e-6);
        assert_eq!(e.rto(), 0.05, "steady low RTT clamps to min_rto");
        e.observe(f64::NAN); // ignored
        assert_eq!(e.rto(), 0.05);
    }

    #[test]
    fn clean_passes_keep_the_configured_rate() {
        let mut c = RateController::new(1000.0, AdaptConfig::default());
        for pass in 0..10 {
            let v = c.on_pass(pass as f64 * 0.1, 0.0, 1.0);
            assert_eq!(v, PassVerdict::Clean);
            assert_eq!(c.rate(), 1000.0);
        }
    }

    #[test]
    fn policer_loss_confirms_congestion_and_converges() {
        // Deterministic policer of capacity 500 frag/s: observed loss
        // at rate R is max(0, 1 − 500/R).
        let cap = 500.0;
        let mut c = RateController::new(1000.0, AdaptConfig::default());
        let loss_at = |r: f64| (1.0 - cap / r).max(0.0);
        // Pass 0 at 1000 → 50% loss, runs of length 1 → probe.
        let v = c.on_pass(0.1, loss_at(1000.0), 1.0);
        assert_eq!(v, PassVerdict::Probing);
        assert!((c.rate() - 700.0).abs() < 1e-9);
        // Probe pass at 700 → 28.6% loss ⇒ congestion, decrease again.
        let v = c.on_pass(0.2, loss_at(700.0), 1.0);
        assert!(matches!(v, PassVerdict::Congestion { .. }), "{v:?}");
        assert!((c.rate() - 490.0).abs() < 1e-9, "rate {}", c.rate());
        // Below capacity: clean passes grow cubically but stay ≤ r_max.
        let mut t = 0.3;
        for _ in 0..20 {
            let v = c.on_pass(t, loss_at(c.rate()), 1.0);
            t += 0.1;
            if c.rate() <= cap {
                assert_eq!(v, PassVerdict::Clean);
            }
            assert!(c.rate() <= 1000.0);
        }
        // The controller hovers near capacity, not back at r_max.
        assert!(c.rate() < 800.0, "rate {} should hug capacity", c.rate());
    }

    #[test]
    fn congestion_verdict_exposes_capacity_estimate() {
        let cap = 500.0;
        let mut c = RateController::new(1000.0, AdaptConfig::default());
        assert_eq!(c.capacity_estimate(), None, "no congestion seen yet");
        let loss_at = |r: f64| (1.0 - cap / r).max(0.0);
        c.on_pass(0.1, loss_at(1000.0), 1.0); // probe
        assert_eq!(c.capacity_estimate(), None, "probe pending, no verdict");
        let v = c.on_pass(0.2, loss_at(700.0), 1.0);
        assert!(matches!(v, PassVerdict::Congestion { .. }), "{v:?}");
        // capacity_est = r_old · (1 − pre_loss) = 1000 · 0.5 = 500.
        let est = c.capacity_estimate().expect("congestion fixes an estimate");
        assert!((est - cap).abs() < 1e-9, "estimate {est}");
        // Clean passes below the estimate keep it; growth past it
        // falsifies it.
        let mut t = 0.3;
        while c.rate() <= est {
            assert_eq!(c.capacity_estimate(), Some(est));
            c.on_pass(t, 0.0, 1.0);
            t += 5.0;
        }
        assert_eq!(c.capacity_estimate(), None, "clean above estimate clears it");
    }

    #[test]
    fn channel_verdict_clears_capacity_estimate() {
        let mut c = RateController::new(1000.0, AdaptConfig::default());
        let loss_at = |r: f64| (1.0 - 500.0 / r).max(0.0);
        c.on_pass(0.1, loss_at(1000.0), 1.0);
        c.on_pass(0.2, loss_at(700.0), 1.0);
        assert!(c.capacity_estimate().is_some());
        // A later probe that resolves to channel loss wipes the stale
        // congestion picture. First grow back over the threshold so a
        // fresh probe can trigger, then feed rate-independent loss.
        let mut t = 10.0;
        loop {
            match c.on_pass(t, 0.2, 1.2) {
                PassVerdict::Probing => {}
                PassVerdict::ChannelLoss => break,
                v => panic!("unexpected verdict {v:?}"),
            }
            t += 5.0;
        }
        assert_eq!(c.capacity_estimate(), None);
        assert_eq!(c.rate(), 1000.0);
    }

    #[test]
    fn bernoulli_loss_restores_rate_after_one_probe() {
        // 20% rate-independent loss: the probe changes nothing ⇒
        // channel verdict, rate restored, probing held off.
        let mut c = RateController::new(1000.0, AdaptConfig::default());
        assert_eq!(c.on_pass(0.1, 0.2, 1.2), PassVerdict::Probing);
        assert!((c.rate() - 700.0).abs() < 1e-9);
        assert_eq!(c.on_pass(0.2, 0.2, 1.2), PassVerdict::ChannelLoss);
        assert_eq!(c.rate(), 1000.0, "channel loss must not cost rate");
        // Holdoff: the next lossy passes do not probe again.
        assert_eq!(c.on_pass(0.3, 0.2, 1.2), PassVerdict::ChannelLoss);
        assert_eq!(c.rate(), 1000.0);
    }

    #[test]
    fn burst_loss_sustains_rate_without_probing() {
        let mut c = RateController::new(1000.0, AdaptConfig::default());
        let v = c.on_pass(0.1, 0.2, 8.0);
        assert_eq!(v, PassVerdict::Burst { burst_len: 8.0 });
        assert_eq!(c.rate(), 1000.0);
    }

    #[test]
    fn fixed_config_never_moves_the_rate() {
        let mut c = RateController::new(1000.0, AdaptConfig::fixed());
        for (i, loss) in [0.5, 0.3, 0.0, 0.9].iter().enumerate() {
            c.on_pass(i as f64, *loss, 1.0);
            assert_eq!(c.rate(), 1000.0);
        }
    }

    #[test]
    fn rate_floor_holds_under_sustained_congestion() {
        let cfg = AdaptConfig { min_rate_frac: 0.25, ..AdaptConfig::default() };
        let mut c = RateController::new(1000.0, cfg);
        for i in 0..40 {
            c.on_pass(i as f64 * 0.1, 0.9, 1.0);
            assert!(c.rate() >= 250.0 - 1e-9, "rate {} under floor", c.rate());
        }
    }

    #[test]
    fn adapt_config_validation() {
        assert!(AdaptConfig::default().validate().is_ok());
        assert!(AdaptConfig::fixed().validate().is_ok());
        assert!(AdaptConfig { beta: 1.0, ..AdaptConfig::default() }.validate().is_err());
        assert!(AdaptConfig { cubic_c: 0.0, ..AdaptConfig::default() }.validate().is_err());
        assert!(AdaptConfig { burst_threshold: 0.5, ..AdaptConfig::default() }
            .validate()
            .is_err());
        assert!(AdaptConfig { loss_threshold: 1.0, ..AdaptConfig::default() }.validate().is_err());
        assert!(AdaptConfig { min_rate_frac: 0.0, ..AdaptConfig::default() }.validate().is_err());
    }
}
