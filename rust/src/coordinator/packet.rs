//! Wire format for Janus fragments and control messages.
//!
//! The paper's prototype (§5.3.1) uses Protobuf to carry erasure-coding
//! metadata — level, FTG id, redundancy m — alongside each fragment. We
//! use a hand-rolled fixed layout (little-endian) with a CRC32 trailer:
//! no proto toolchain in the offline environment, and a fixed layout
//! keeps the per-packet encode/decode cost off the hot path's heap.

use crc32fast::Hasher;

/// Maximum datagram we ever emit (fragment header + 4 KiB payload fits
/// comfortably; control messages are small).
pub const MAX_DATAGRAM: usize = 9 * 1024;

/// A parsed Janus packet.
#[derive(Debug, Clone, PartialEq)]
pub enum Packet {
    /// One erasure-coded fragment of a fault-tolerant group.
    Fragment(FragmentHeader, Vec<u8>),
    /// Receiver → sender: freshly measured packet-loss rate (λ̂, /s).
    LambdaUpdate { lambda: f64 },
    /// Sender → receiver: pass `pass` finished (0 = initial transmission).
    EndOfPass { pass: u32 },
    /// Receiver → sender: FTGs with unrecoverable losses in this pass.
    LostList { ftgs: Vec<(u8, u32)> },
    /// Receiver → sender: transfer complete.
    Done,
    /// Sender → receiver: transfer manifest (must precede fragments).
    Manifest(Manifest),
    /// Receiver → sender: manifest acknowledged, start sending.
    ManifestAck,
}

/// Fragment metadata (the paper's per-packet erasure-coding metadata).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FragmentHeader {
    /// Refactoring level this fragment belongs to (0-based).
    pub level: u8,
    /// FTG index within the level.
    pub ftg: u32,
    /// Fragment index within the FTG: `0..k` data, `k..k+m` parity.
    pub index: u8,
    /// Data fragments in this FTG.
    pub k: u8,
    /// Parity fragments in this FTG (the redundancy metadata of §4.2).
    pub m: u8,
    /// Global wire sequence number (loss detection at the receiver).
    pub seq: u64,
    /// Retransmission pass that produced this copy.
    pub pass: u32,
}

/// Transfer manifest: level schedule + coding geometry.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Fragments per FTG (n = k + m is constant; k varies per FTG).
    pub n: u8,
    /// Fragment payload size in bytes.
    pub s: u32,
    /// Per-level (byte size, ε) pairs, in transmission order.
    pub levels: Vec<(u64, f64)>,
    /// Contract: 0 = guaranteed error bound (Alg. 1, retransmission on),
    /// 1 = guaranteed time (Alg. 2, no retransmission).
    pub contract: u8,
}

const KIND_FRAGMENT: u8 = 1;
const KIND_LAMBDA: u8 = 2;
const KIND_END: u8 = 3;
const KIND_LOST: u8 = 4;
const KIND_DONE: u8 = 5;
const KIND_MANIFEST: u8 = 6;
const KIND_MANIFEST_ACK: u8 = 7;

fn crc(buf: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(buf);
    h.finalize()
}

/// Serialize a fragment without constructing a [`Packet`] (the sender hot
/// path: avoids cloning the 4 KiB payload into the enum).
pub fn encode_fragment_into(h: &FragmentHeader, payload: &[u8], out: &mut Vec<u8>) {
    out.clear();
    out.push(KIND_FRAGMENT);
    out.push(h.level);
    out.extend_from_slice(&h.ftg.to_le_bytes());
    out.push(h.index);
    out.push(h.k);
    out.push(h.m);
    out.extend_from_slice(&h.seq.to_le_bytes());
    out.extend_from_slice(&h.pass.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    let c = crc(out);
    out.extend_from_slice(&c.to_le_bytes());
}

/// Packet (de)serialization error.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum WireError {
    #[error("datagram too short ({0} bytes)")]
    Truncated(usize),
    #[error("bad checksum")]
    BadChecksum,
    #[error("unknown packet kind {0}")]
    UnknownKind(u8),
}

impl Packet {
    /// Serialize into a fresh buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        self.encode_into(&mut out);
        out
    }

    /// Serialize, reusing `out` (cleared first). Appends a CRC32 trailer.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        match self {
            Packet::Fragment(h, payload) => {
                out.push(KIND_FRAGMENT);
                out.push(h.level);
                out.extend_from_slice(&h.ftg.to_le_bytes());
                out.push(h.index);
                out.push(h.k);
                out.push(h.m);
                out.extend_from_slice(&h.seq.to_le_bytes());
                out.extend_from_slice(&h.pass.to_le_bytes());
                out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                out.extend_from_slice(payload);
            }
            Packet::LambdaUpdate { lambda } => {
                out.push(KIND_LAMBDA);
                out.extend_from_slice(&lambda.to_le_bytes());
            }
            Packet::EndOfPass { pass } => {
                out.push(KIND_END);
                out.extend_from_slice(&pass.to_le_bytes());
            }
            Packet::LostList { ftgs } => {
                out.push(KIND_LOST);
                out.extend_from_slice(&(ftgs.len() as u32).to_le_bytes());
                for &(level, ftg) in ftgs {
                    out.push(level);
                    out.extend_from_slice(&ftg.to_le_bytes());
                }
            }
            Packet::Done => out.push(KIND_DONE),
            Packet::Manifest(m) => {
                out.push(KIND_MANIFEST);
                out.push(m.n);
                out.extend_from_slice(&m.s.to_le_bytes());
                out.push(m.contract);
                out.extend_from_slice(&(m.levels.len() as u32).to_le_bytes());
                for &(size, eps) in &m.levels {
                    out.extend_from_slice(&size.to_le_bytes());
                    out.extend_from_slice(&eps.to_le_bytes());
                }
            }
            Packet::ManifestAck => out.push(KIND_MANIFEST_ACK),
        }
        let c = crc(out);
        out.extend_from_slice(&c.to_le_bytes());
    }

    /// Parse a datagram (checks the CRC32 trailer).
    pub fn decode(buf: &[u8]) -> Result<Packet, WireError> {
        if buf.len() < 5 {
            return Err(WireError::Truncated(buf.len()));
        }
        let (body, trailer) = buf.split_at(buf.len() - 4);
        let want = u32::from_le_bytes(trailer.try_into().unwrap());
        if crc(body) != want {
            return Err(WireError::BadChecksum);
        }
        let kind = body[0];
        let rest = &body[1..];
        let need = |n: usize| {
            if rest.len() < n {
                Err(WireError::Truncated(buf.len()))
            } else {
                Ok(())
            }
        };
        match kind {
            KIND_FRAGMENT => {
                need(1 + 4 + 1 + 1 + 1 + 8 + 4 + 4)?;
                let level = rest[0];
                let ftg = u32::from_le_bytes(rest[1..5].try_into().unwrap());
                let index = rest[5];
                let k = rest[6];
                let m = rest[7];
                let seq = u64::from_le_bytes(rest[8..16].try_into().unwrap());
                let pass = u32::from_le_bytes(rest[16..20].try_into().unwrap());
                let len = u32::from_le_bytes(rest[20..24].try_into().unwrap()) as usize;
                if rest.len() < 24 + len {
                    return Err(WireError::Truncated(buf.len()));
                }
                Ok(Packet::Fragment(
                    FragmentHeader { level, ftg, index, k, m, seq, pass },
                    rest[24..24 + len].to_vec(),
                ))
            }
            KIND_LAMBDA => {
                need(8)?;
                Ok(Packet::LambdaUpdate {
                    lambda: f64::from_le_bytes(rest[..8].try_into().unwrap()),
                })
            }
            KIND_END => {
                need(4)?;
                Ok(Packet::EndOfPass {
                    pass: u32::from_le_bytes(rest[..4].try_into().unwrap()),
                })
            }
            KIND_LOST => {
                need(4)?;
                let count = u32::from_le_bytes(rest[..4].try_into().unwrap()) as usize;
                need(4 + count * 5)?;
                let mut ftgs = Vec::with_capacity(count);
                for i in 0..count {
                    let off = 4 + i * 5;
                    ftgs.push((
                        rest[off],
                        u32::from_le_bytes(rest[off + 1..off + 5].try_into().unwrap()),
                    ));
                }
                Ok(Packet::LostList { ftgs })
            }
            KIND_DONE => Ok(Packet::Done),
            KIND_MANIFEST => {
                need(1 + 4 + 1 + 4)?;
                let n = rest[0];
                let s = u32::from_le_bytes(rest[1..5].try_into().unwrap());
                let contract = rest[5];
                let count = u32::from_le_bytes(rest[6..10].try_into().unwrap()) as usize;
                need(10 + count * 16)?;
                let mut levels = Vec::with_capacity(count);
                for i in 0..count {
                    let off = 10 + i * 16;
                    levels.push((
                        u64::from_le_bytes(rest[off..off + 8].try_into().unwrap()),
                        f64::from_le_bytes(rest[off + 8..off + 16].try_into().unwrap()),
                    ));
                }
                Ok(Packet::Manifest(Manifest { n, s, levels, contract }))
            }
            KIND_MANIFEST_ACK => Ok(Packet::ManifestAck),
            k => Err(WireError::UnknownKind(k)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(p: Packet) {
        let buf = p.encode();
        assert!(buf.len() <= MAX_DATAGRAM);
        let got = Packet::decode(&buf).unwrap();
        assert_eq!(got, p);
    }

    #[test]
    fn fragment_roundtrip() {
        roundtrip(Packet::Fragment(
            FragmentHeader { level: 2, ftg: 12345, index: 31, k: 24, m: 8, seq: 987654321, pass: 3 },
            vec![0xAB; 4096],
        ));
    }

    #[test]
    fn empty_payload_fragment() {
        roundtrip(Packet::Fragment(
            FragmentHeader { level: 0, ftg: 0, index: 0, k: 1, m: 0, seq: 0, pass: 0 },
            vec![],
        ));
    }

    #[test]
    fn control_roundtrips() {
        roundtrip(Packet::LambdaUpdate { lambda: 383.25 });
        roundtrip(Packet::EndOfPass { pass: 7 });
        roundtrip(Packet::LostList { ftgs: vec![(0, 1), (3, 99999)] });
        roundtrip(Packet::LostList { ftgs: vec![] });
        roundtrip(Packet::Done);
        roundtrip(Packet::ManifestAck);
        roundtrip(Packet::Manifest(Manifest {
            n: 32,
            s: 4096,
            levels: vec![(668 << 20, 0.004), (2867 << 20, 0.0005)],
            contract: 1,
        }));
    }

    #[test]
    fn corruption_detected() {
        let mut buf = Packet::LambdaUpdate { lambda: 1.0 }.encode();
        buf[3] ^= 0x40;
        assert_eq!(Packet::decode(&buf), Err(WireError::BadChecksum));
    }

    #[test]
    fn truncation_detected() {
        let buf = Packet::Done.encode();
        assert!(matches!(
            Packet::decode(&buf[..2]),
            Err(WireError::Truncated(_) | WireError::BadChecksum)
        ));
        assert!(matches!(Packet::decode(&[]), Err(WireError::Truncated(0))));
    }

    #[test]
    fn unknown_kind_rejected() {
        let mut buf = vec![0xEEu8];
        let c = {
            let mut h = Hasher::new();
            h.update(&buf);
            h.finalize()
        };
        buf.extend_from_slice(&c.to_le_bytes());
        assert_eq!(Packet::decode(&buf), Err(WireError::UnknownKind(0xEE)));
    }

    #[test]
    fn encode_into_reuses_buffer() {
        let mut buf = Vec::new();
        Packet::Done.encode_into(&mut buf);
        let len1 = buf.len();
        Packet::LambdaUpdate { lambda: 2.0 }.encode_into(&mut buf);
        assert_ne!(buf.len(), len1);
        assert_eq!(Packet::decode(&buf).unwrap(), Packet::LambdaUpdate { lambda: 2.0 });
    }
}
