//! Wire format for Janus fragments and control messages.
//!
//! The paper's prototype (§5.3.1) uses Protobuf to carry erasure-coding
//! metadata — level, FTG id, redundancy m — alongside each fragment. We
//! use a hand-rolled fixed layout (little-endian) with a CRC32 trailer:
//! no proto toolchain in the offline environment, and a fixed layout
//! keeps the per-packet encode/decode cost off the hot path's heap.
//!
//! Multi-stream extension: every fragment carries a `stream` id so a
//! [`crate::coordinator::pool::TransferPool`] receiver can demultiplex N
//! concurrent sender workers, and the control plane gains per-stream
//! end-of-pass markers ([`Packet::StreamEnd`]) plus aggregate pass loss
//! statistics ([`Packet::PassStats`]) feeding the shared λ̂ estimator.

use crate::util::crc32::Hasher;

/// Maximum datagram we ever emit (fragment header + 4 KiB payload fits
/// comfortably; control messages are small).
pub const MAX_DATAGRAM: usize = 9 * 1024;

/// Largest fragment payload that still fits one [`MAX_DATAGRAM`]
/// datagram (kind byte + fragment header + payload + CRC32 trailer).
/// [`crate::api::TransferSpec`] validation rejects larger `s`, since
/// channels truncate at [`MAX_DATAGRAM`] like a UDP socket would.
pub const MAX_FRAGMENT_PAYLOAD: usize = MAX_DATAGRAM - FRAGMENT_HEADER - 5;

/// The one engine-side gate for fragment payload sizes: channels
/// truncate at [`MAX_DATAGRAM`], so an oversized `s` would corrupt
/// every fragment on the wire — fail loudly instead. (The typed
/// [`crate::api::TransferSpec`] builder rejects this earlier on the
/// public path; deprecated direct entry points land here.)
pub fn validate_fragment_size(s: usize) -> crate::util::err::Result<()> {
    if s > MAX_FRAGMENT_PAYLOAD {
        crate::bail!(
            "fragment size {s} exceeds the {MAX_FRAGMENT_PAYLOAD}-byte datagram payload limit"
        );
    }
    Ok(())
}

/// Largest lost-FTG count one [`Packet::LostList`] may carry: senders of
/// the list truncate to this so the datagram always fits [`MAX_DATAGRAM`]
/// (kind + pass + total + count + 5 bytes/entry + CRC). The remainder is
/// reported on the next pass — passes iterate until the list drains —
/// and the `total` field keeps the truncated tail visible to deadline
/// budget accounting meanwhile.
pub const MAX_LOST_PER_MSG: usize = 1500;

/// A parsed Janus packet.
#[derive(Debug, Clone, PartialEq)]
pub enum Packet {
    /// One erasure-coded fragment of a fault-tolerant group.
    Fragment(FragmentHeader, Vec<u8>),
    /// Receiver → sender: freshly measured packet-loss rate (λ̂, /s).
    LambdaUpdate { lambda: f64 },
    /// Sender → receiver: pass `pass` finished (0 = initial transmission).
    EndOfPass { pass: u32 },
    /// Receiver → sender: FTGs with unrecoverable losses after `pass`
    /// (the tag lets retried end-of-pass exchanges discard stale lists).
    /// `total` is the true count of unrecoverable FTGs at the barrier:
    /// when it exceeds `ftgs.len()`, the list was truncated to
    /// [`MAX_LOST_PER_MSG`] and the sender must price the un-reported
    /// tail into its deadline budget even though the entries arrive on
    /// later passes.
    LostList { pass: u32, total: u32, ftgs: Vec<(u8, u32)> },
    /// Receiver → sender: transfer complete.
    Done,
    /// Sender → receiver: transfer manifest (must precede fragments).
    Manifest(Manifest),
    /// Receiver → sender: manifest acknowledged, start sending.
    ManifestAck,
    /// Sender → receiver, per data stream: stream `stream` has finished
    /// transmitting pass `pass` after sending `sent` fragments in it.
    StreamEnd { stream: u8, pass: u32, sent: u64 },
    /// Receiver → sender: of the `expected` fragments announced for
    /// `pass`, `received` survived the wire (λ̂ input at the sender).
    /// `runs` counts the distinct loss runs (maximal gaps in per-stream
    /// sequence numbers) and `burst_lost` the losses that fell in runs of
    /// length ≥ 2 — the shape inputs of the two-state burst estimator.
    PassStats { pass: u32, expected: u64, received: u64, runs: u32, burst_lost: u64 },
    /// Sender → receiver (pooled Deadline): a pass barrier shed level
    /// `level` — its advertised prefix shrinks to `bytes` (0 = the level
    /// is abandoned entirely) with measured ε `eps`. Idempotent: re-sent
    /// ahead of every later `EndOfPass` so a lossy control path
    /// converges on the same manifest state.
    LevelShed { level: u8, bytes: u64, eps: f64 },
    /// Sender → receiver (fountain mode): one rateless symbol of group
    /// `header.group`. Symbols with `esi < k` are systematic source
    /// fragments; `esi ≥ k` are seeded LT combinations. Rides the data
    /// path (loss-injected like fragments), never the control path.
    RepairSymbol(RepairHeader, Vec<u8>),
    /// Receiver → sender (fountain mode): compact cumulative group ack —
    /// every global group id `< upto` has decoded, and bit `i` of
    /// `bitmap` marks group `upto + i` decoded too. Replaces the
    /// EndOfPass/LostList barrier exchange; idempotent and monotone, so
    /// a duplicated or reordered ack never un-retires a group.
    GroupAck { upto: u32, bitmap: u64 },
}

/// Fragment metadata (the paper's per-packet erasure-coding metadata).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FragmentHeader {
    /// Refactoring level this fragment belongs to (0-based).
    pub level: u8,
    /// Sender stream that paced this fragment (0 for single-stream
    /// sessions; the pool demultiplexes on this).
    pub stream: u8,
    /// FTG index within the level.
    pub ftg: u32,
    /// Fragment index within the FTG: `0..k` data, `k..k+m` parity.
    pub index: u8,
    /// Data fragments in this FTG.
    pub k: u8,
    /// Parity fragments in this FTG (the redundancy metadata of §4.2).
    pub m: u8,
    /// Per-stream wire sequence number (loss detection at the receiver).
    pub seq: u64,
    /// Retransmission pass that produced this copy.
    pub pass: u32,
}

/// Rateless-symbol metadata (fountain mode's counterpart of
/// [`FragmentHeader`]). Groups are addressed by a flat global id — both
/// endpoints enumerate the manifest's levels in order and stride each
/// into `k`-fragment groups, so the id needs no (level, ftg) pair on the
/// wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RepairHeader {
    /// Global group id (manifest enumeration order).
    pub group: u32,
    /// Encoding symbol id: `< k` systematic source, `≥ k` LT repair.
    pub esi: u32,
    /// Transfer-wide seed the symbol's neighbor set derives from.
    pub seed: u64,
    /// Wire sequence number (loss detection / λ̂ windows at the receiver).
    pub seq: u64,
}

/// One level entry of the transfer manifest.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ManifestLevel {
    /// Advertised byte size (a plane-cut prefix when `cut` is set).
    pub size: u64,
    /// Relative L∞ error after receiving levels up to this one.
    pub eps: f64,
    /// Pass-0 parity the sender planned this level's FTG geometry with:
    /// every group except the level tail slices `k = n − m0` data
    /// fragments, so a receiver can recompute the exact group strides
    /// for FTGs it never saw (whole-level first-pass loss) instead of
    /// guessing the worst case `k = n`.
    pub m0: u8,
    /// The advertised size is a decodable plane-cut prefix of a larger
    /// level (Deadline shedding at bitplane granularity).
    pub cut: bool,
}

/// Transfer manifest: level schedule + coding geometry.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Fragments per FTG (n = k + m is constant; k varies per FTG).
    pub n: u8,
    /// Fragment payload size in bytes.
    pub s: u32,
    /// Concurrent sender streams (1 for plain sessions).
    pub streams: u8,
    /// Per-level entries, in transmission order.
    pub levels: Vec<ManifestLevel>,
    /// Low nibble: 0 = guaranteed error bound (Alg. 1, retransmission
    /// on), 1 = guaranteed time (Alg. 2 / pooled pass-barrier τ
    /// accounting). Bit [`CONTRACT_FOUNTAIN`]: the transfer streams
    /// rateless symbols instead of RS passes. RS manifests never set the
    /// flag, keeping legacy encodings byte-identical.
    pub contract: u8,
}

/// Bit of the manifest `contract` byte marking a fountain-mode transfer.
pub const CONTRACT_FOUNTAIN: u8 = 0x10;

impl Manifest {
    /// Does this manifest announce a rateless (fountain) transfer?
    pub fn is_fountain(&self) -> bool {
        self.contract & CONTRACT_FOUNTAIN != 0
    }

    /// The contract id with mode flags masked off.
    pub fn contract_mode(&self) -> u8 {
        self.contract & !CONTRACT_FOUNTAIN
    }
}

const KIND_FRAGMENT: u8 = 1;
const KIND_LAMBDA: u8 = 2;
const KIND_END: u8 = 3;
const KIND_LOST: u8 = 4;
const KIND_DONE: u8 = 5;
const KIND_MANIFEST: u8 = 6;
const KIND_MANIFEST_ACK: u8 = 7;
const KIND_STREAM_END: u8 = 8;
const KIND_PASS_STATS: u8 = 9;
const KIND_LEVEL_SHED: u8 = 10;
const KIND_TRANSFER_TAG: u8 = 11;
const KIND_REPAIR: u8 = 12;
const KIND_GROUP_ACK: u8 = 13;

/// Bytes per manifest level entry on the wire: size + ε + m0 + cut flag.
const MANIFEST_LEVEL_BYTES: usize = 8 + 8 + 1 + 1;

/// Fragment wire header length after the kind byte.
const FRAGMENT_HEADER: usize = 1 + 1 + 4 + 1 + 1 + 1 + 8 + 4 + 4;

/// Repair-symbol wire header length after the kind byte:
/// group + esi + seed + seq + payload length.
const REPAIR_HEADER: usize = 4 + 4 + 8 + 8 + 4;

fn crc(buf: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(buf);
    h.finalize()
}

/// Cheap peek: is this (unvalidated) datagram a data fragment? Loss
/// injectors use it to drop only the data path, like the paper's WAN
/// substitute — control packets model a reliable side channel. Fountain
/// repair symbols are the data path of rateless transfers, so they count
/// too. Sees through a transfer-tag envelope so the testkit's loss and
/// congestion channels gate `janus serve` traffic the same way they gate
/// legacy single-transfer traffic.
pub fn is_fragment(buf: &[u8]) -> bool {
    match buf.first() {
        Some(&KIND_FRAGMENT) | Some(&KIND_REPAIR) => true,
        Some(&KIND_TRANSFER_TAG) => {
            matches!(buf.get(TAG_BYTES), Some(&KIND_FRAGMENT) | Some(&KIND_REPAIR))
        }
        _ => false,
    }
}

/// Bytes the transfer-tag envelope prepends to an inner datagram: kind
/// byte + little-endian `u32` transfer id. Tagged senders must keep
/// `s ≤ MAX_FRAGMENT_PAYLOAD − TAG_BYTES` so a max-size fragment still
/// fits one [`MAX_DATAGRAM`] (the serve daemon validates this at
/// registration).
pub const TAG_BYTES: usize = 5;

/// Wrap a complete inner datagram (its CRC trailer included) in a
/// transfer-tag envelope: `[kind=11][u32 id LE][inner…]`. The envelope
/// carries no checksum of its own — the inner CRC already covers the
/// payload, and a corrupted id merely misroutes to a transfer whose
/// machine rejects the inner packet.
pub fn encode_tagged(id: u32, inner: &[u8], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(TAG_BYTES + inner.len());
    out.push(KIND_TRANSFER_TAG);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(inner);
}

/// Peel a transfer-tag envelope, returning `(id, inner datagram)`.
/// `None` when the buffer is not tagged (legacy untagged traffic) or too
/// short to carry an id — the caller decides whether untagged datagrams
/// are dropped (daemon sockets) or passed through (legacy engines).
pub fn peel_tag(buf: &[u8]) -> Option<(u32, &[u8])> {
    if buf.first() != Some(&KIND_TRANSFER_TAG) || buf.len() < TAG_BYTES {
        return None;
    }
    let id = u32::from_le_bytes(buf[1..TAG_BYTES].try_into().unwrap());
    Some((id, &buf[TAG_BYTES..]))
}

/// Validate the length and CRC32 trailer, returning the body (kind byte
/// + fields).
fn checked_body(buf: &[u8]) -> Result<&[u8], WireError> {
    if buf.len() < 5 {
        return Err(WireError::Truncated(buf.len()));
    }
    let (body, trailer) = buf.split_at(buf.len() - 4);
    let want = u32::from_le_bytes(trailer.try_into().unwrap());
    if crc(body) != want {
        return Err(WireError::BadChecksum);
    }
    Ok(body)
}

/// Parse a fragment body (everything after the kind byte), borrowing the
/// payload. `total` is the datagram length, for error reporting.
fn parse_fragment(rest: &[u8], total: usize) -> Result<(FragmentHeader, &[u8]), WireError> {
    if rest.len() < FRAGMENT_HEADER {
        return Err(WireError::Truncated(total));
    }
    let level = rest[0];
    let stream = rest[1];
    let ftg = u32::from_le_bytes(rest[2..6].try_into().unwrap());
    let index = rest[6];
    let k = rest[7];
    let m = rest[8];
    let seq = u64::from_le_bytes(rest[9..17].try_into().unwrap());
    let pass = u32::from_le_bytes(rest[17..21].try_into().unwrap());
    let len = u32::from_le_bytes(rest[21..25].try_into().unwrap()) as usize;
    if rest.len() < FRAGMENT_HEADER + len {
        return Err(WireError::Truncated(total));
    }
    Ok((
        FragmentHeader { level, stream, ftg, index, k, m, seq, pass },
        &rest[FRAGMENT_HEADER..FRAGMENT_HEADER + len],
    ))
}

/// Parse a repair-symbol body (everything after the kind byte),
/// borrowing the payload. `total` is the datagram length, for errors.
fn parse_repair(rest: &[u8], total: usize) -> Result<(RepairHeader, &[u8]), WireError> {
    if rest.len() < REPAIR_HEADER {
        return Err(WireError::Truncated(total));
    }
    let group = u32::from_le_bytes(rest[..4].try_into().unwrap());
    let esi = u32::from_le_bytes(rest[4..8].try_into().unwrap());
    let seed = u64::from_le_bytes(rest[8..16].try_into().unwrap());
    let seq = u64::from_le_bytes(rest[16..24].try_into().unwrap());
    let len = u32::from_le_bytes(rest[24..28].try_into().unwrap()) as usize;
    if rest.len() < REPAIR_HEADER + len {
        return Err(WireError::Truncated(total));
    }
    Ok((RepairHeader { group, esi, seed, seq }, &rest[REPAIR_HEADER..REPAIR_HEADER + len]))
}

/// Borrowed view of one fragment: header parsed, payload still sitting
/// in the receive buffer — the receiver copies it exactly once, into its
/// [`crate::coordinator::arena::FtgArena`] slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FragmentView<'a> {
    pub header: FragmentHeader,
    pub payload: &'a [u8],
}

/// Borrowed view of one rateless symbol (fountain mode's hot path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RepairView<'a> {
    pub header: RepairHeader,
    pub payload: &'a [u8],
}

/// Zero-copy decode of a datagram: fragments and repair symbols borrow
/// their payload from the input buffer; control packets (small, off the
/// hot path) decode to the owned [`Packet`].
#[derive(Debug, PartialEq)]
pub enum PacketView<'a> {
    Fragment(FragmentView<'a>),
    Repair(RepairView<'a>),
    Control(Packet),
}

impl<'a> PacketView<'a> {
    /// Parse a datagram (checks the CRC32 trailer) without copying
    /// fragment payloads.
    pub fn decode(buf: &'a [u8]) -> Result<PacketView<'a>, WireError> {
        let body = checked_body(buf)?;
        if body[0] == KIND_FRAGMENT {
            let (header, payload) = parse_fragment(&body[1..], buf.len())?;
            Ok(PacketView::Fragment(FragmentView { header, payload }))
        } else if body[0] == KIND_REPAIR {
            let (header, payload) = parse_repair(&body[1..], buf.len())?;
            Ok(PacketView::Repair(RepairView { header, payload }))
        } else {
            Ok(PacketView::Control(Packet::decode_body(body, buf.len())?))
        }
    }
}

/// Serialize a fragment without constructing a [`Packet`] (the sender hot
/// path: avoids cloning the 4 KiB payload into the enum).
pub fn encode_fragment_into(h: &FragmentHeader, payload: &[u8], out: &mut Vec<u8>) {
    out.clear();
    out.push(KIND_FRAGMENT);
    out.push(h.level);
    out.push(h.stream);
    out.extend_from_slice(&h.ftg.to_le_bytes());
    out.push(h.index);
    out.push(h.k);
    out.push(h.m);
    out.extend_from_slice(&h.seq.to_le_bytes());
    out.extend_from_slice(&h.pass.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    let c = crc(out);
    out.extend_from_slice(&c.to_le_bytes());
}

/// Serialize a repair symbol without constructing a [`Packet`] (the
/// fountain sender hot path: avoids cloning the payload into the enum).
pub fn encode_repair_into(h: &RepairHeader, payload: &[u8], out: &mut Vec<u8>) {
    out.clear();
    out.push(KIND_REPAIR);
    out.extend_from_slice(&h.group.to_le_bytes());
    out.extend_from_slice(&h.esi.to_le_bytes());
    out.extend_from_slice(&h.seed.to_le_bytes());
    out.extend_from_slice(&h.seq.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    let c = crc(out);
    out.extend_from_slice(&c.to_le_bytes());
}

/// Packet (de)serialization error.
#[derive(Debug, PartialEq, Eq)]
pub enum WireError {
    Truncated(usize),
    BadChecksum,
    UnknownKind(u8),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated(n) => write!(f, "datagram too short ({n} bytes)"),
            WireError::BadChecksum => write!(f, "bad checksum"),
            WireError::UnknownKind(k) => write!(f, "unknown packet kind {k}"),
        }
    }
}

impl std::error::Error for WireError {}

impl Packet {
    /// Serialize into a fresh buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        self.encode_into(&mut out);
        out
    }

    /// Serialize, reusing `out` (cleared first). Appends a CRC32 trailer.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        match self {
            Packet::Fragment(h, payload) => {
                out.push(KIND_FRAGMENT);
                out.push(h.level);
                out.push(h.stream);
                out.extend_from_slice(&h.ftg.to_le_bytes());
                out.push(h.index);
                out.push(h.k);
                out.push(h.m);
                out.extend_from_slice(&h.seq.to_le_bytes());
                out.extend_from_slice(&h.pass.to_le_bytes());
                out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                out.extend_from_slice(payload);
            }
            Packet::LambdaUpdate { lambda } => {
                out.push(KIND_LAMBDA);
                out.extend_from_slice(&lambda.to_le_bytes());
            }
            Packet::EndOfPass { pass } => {
                out.push(KIND_END);
                out.extend_from_slice(&pass.to_le_bytes());
            }
            Packet::LostList { pass, total, ftgs } => {
                out.push(KIND_LOST);
                out.extend_from_slice(&pass.to_le_bytes());
                out.extend_from_slice(&total.to_le_bytes());
                out.extend_from_slice(&(ftgs.len() as u32).to_le_bytes());
                for &(level, ftg) in ftgs {
                    out.push(level);
                    out.extend_from_slice(&ftg.to_le_bytes());
                }
            }
            Packet::Done => out.push(KIND_DONE),
            Packet::Manifest(m) => {
                out.push(KIND_MANIFEST);
                out.push(m.n);
                out.extend_from_slice(&m.s.to_le_bytes());
                out.push(m.contract);
                out.push(m.streams);
                out.extend_from_slice(&(m.levels.len() as u32).to_le_bytes());
                for level in &m.levels {
                    out.extend_from_slice(&level.size.to_le_bytes());
                    out.extend_from_slice(&level.eps.to_le_bytes());
                    out.push(level.m0);
                    out.push(level.cut as u8);
                }
            }
            Packet::ManifestAck => out.push(KIND_MANIFEST_ACK),
            Packet::StreamEnd { stream, pass, sent } => {
                out.push(KIND_STREAM_END);
                out.push(*stream);
                out.extend_from_slice(&pass.to_le_bytes());
                out.extend_from_slice(&sent.to_le_bytes());
            }
            Packet::PassStats { pass, expected, received, runs, burst_lost } => {
                out.push(KIND_PASS_STATS);
                out.extend_from_slice(&pass.to_le_bytes());
                out.extend_from_slice(&expected.to_le_bytes());
                out.extend_from_slice(&received.to_le_bytes());
                out.extend_from_slice(&runs.to_le_bytes());
                out.extend_from_slice(&burst_lost.to_le_bytes());
            }
            Packet::LevelShed { level, bytes, eps } => {
                out.push(KIND_LEVEL_SHED);
                out.push(*level);
                out.extend_from_slice(&bytes.to_le_bytes());
                out.extend_from_slice(&eps.to_le_bytes());
            }
            Packet::RepairSymbol(h, payload) => {
                out.push(KIND_REPAIR);
                out.extend_from_slice(&h.group.to_le_bytes());
                out.extend_from_slice(&h.esi.to_le_bytes());
                out.extend_from_slice(&h.seed.to_le_bytes());
                out.extend_from_slice(&h.seq.to_le_bytes());
                out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                out.extend_from_slice(payload);
            }
            Packet::GroupAck { upto, bitmap } => {
                out.push(KIND_GROUP_ACK);
                out.extend_from_slice(&upto.to_le_bytes());
                out.extend_from_slice(&bitmap.to_le_bytes());
            }
        }
        let c = crc(out);
        out.extend_from_slice(&c.to_le_bytes());
    }

    /// Parse a datagram (checks the CRC32 trailer).
    pub fn decode(buf: &[u8]) -> Result<Packet, WireError> {
        let body = checked_body(buf)?;
        Self::decode_body(body, buf.len())
    }

    /// Parse a CRC-validated body. `total` is the datagram length, for
    /// error reporting.
    fn decode_body(body: &[u8], total: usize) -> Result<Packet, WireError> {
        let kind = body[0];
        let rest = &body[1..];
        let need = |n: usize| {
            if rest.len() < n {
                Err(WireError::Truncated(total))
            } else {
                Ok(())
            }
        };
        match kind {
            KIND_FRAGMENT => {
                let (header, payload) = parse_fragment(rest, total)?;
                Ok(Packet::Fragment(header, payload.to_vec()))
            }
            KIND_LAMBDA => {
                need(8)?;
                Ok(Packet::LambdaUpdate {
                    lambda: f64::from_le_bytes(rest[..8].try_into().unwrap()),
                })
            }
            KIND_END => {
                need(4)?;
                Ok(Packet::EndOfPass {
                    pass: u32::from_le_bytes(rest[..4].try_into().unwrap()),
                })
            }
            KIND_LOST => {
                need(12)?;
                let pass = u32::from_le_bytes(rest[..4].try_into().unwrap());
                let total = u32::from_le_bytes(rest[4..8].try_into().unwrap());
                let count = u32::from_le_bytes(rest[8..12].try_into().unwrap()) as usize;
                need(12 + count * 5)?;
                let mut ftgs = Vec::with_capacity(count);
                for i in 0..count {
                    let off = 12 + i * 5;
                    ftgs.push((
                        rest[off],
                        u32::from_le_bytes(rest[off + 1..off + 5].try_into().unwrap()),
                    ));
                }
                Ok(Packet::LostList { pass, total, ftgs })
            }
            KIND_DONE => Ok(Packet::Done),
            KIND_MANIFEST => {
                need(1 + 4 + 1 + 1 + 4)?;
                let n = rest[0];
                let s = u32::from_le_bytes(rest[1..5].try_into().unwrap());
                let contract = rest[5];
                let streams = rest[6];
                let count = u32::from_le_bytes(rest[7..11].try_into().unwrap()) as usize;
                need(11 + count.saturating_mul(MANIFEST_LEVEL_BYTES))?;
                let mut levels = Vec::with_capacity(count);
                for i in 0..count {
                    let off = 11 + i * MANIFEST_LEVEL_BYTES;
                    levels.push(ManifestLevel {
                        size: u64::from_le_bytes(rest[off..off + 8].try_into().unwrap()),
                        eps: f64::from_le_bytes(rest[off + 8..off + 16].try_into().unwrap()),
                        m0: rest[off + 16],
                        cut: rest[off + 17] != 0,
                    });
                }
                Ok(Packet::Manifest(Manifest { n, s, streams, levels, contract }))
            }
            KIND_MANIFEST_ACK => Ok(Packet::ManifestAck),
            KIND_STREAM_END => {
                need(1 + 4 + 8)?;
                Ok(Packet::StreamEnd {
                    stream: rest[0],
                    pass: u32::from_le_bytes(rest[1..5].try_into().unwrap()),
                    sent: u64::from_le_bytes(rest[5..13].try_into().unwrap()),
                })
            }
            KIND_PASS_STATS => {
                need(4 + 8 + 8 + 4 + 8)?;
                Ok(Packet::PassStats {
                    pass: u32::from_le_bytes(rest[..4].try_into().unwrap()),
                    expected: u64::from_le_bytes(rest[4..12].try_into().unwrap()),
                    received: u64::from_le_bytes(rest[12..20].try_into().unwrap()),
                    runs: u32::from_le_bytes(rest[20..24].try_into().unwrap()),
                    burst_lost: u64::from_le_bytes(rest[24..32].try_into().unwrap()),
                })
            }
            KIND_LEVEL_SHED => {
                need(1 + 8 + 8)?;
                Ok(Packet::LevelShed {
                    level: rest[0],
                    bytes: u64::from_le_bytes(rest[1..9].try_into().unwrap()),
                    eps: f64::from_le_bytes(rest[9..17].try_into().unwrap()),
                })
            }
            KIND_REPAIR => {
                let (header, payload) = parse_repair(rest, total)?;
                Ok(Packet::RepairSymbol(header, payload.to_vec()))
            }
            KIND_GROUP_ACK => {
                need(4 + 8)?;
                Ok(Packet::GroupAck {
                    upto: u32::from_le_bytes(rest[..4].try_into().unwrap()),
                    bitmap: u64::from_le_bytes(rest[4..12].try_into().unwrap()),
                })
            }
            k => Err(WireError::UnknownKind(k)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(p: Packet) {
        let buf = p.encode();
        assert!(buf.len() <= MAX_DATAGRAM);
        let got = Packet::decode(&buf).unwrap();
        assert_eq!(got, p);
    }

    #[test]
    fn fragment_roundtrip() {
        roundtrip(Packet::Fragment(
            FragmentHeader {
                level: 2,
                stream: 5,
                ftg: 12345,
                index: 31,
                k: 24,
                m: 8,
                seq: 987654321,
                pass: 3,
            },
            vec![0xAB; 4096],
        ));
    }

    #[test]
    fn empty_payload_fragment() {
        roundtrip(Packet::Fragment(
            FragmentHeader { level: 0, stream: 0, ftg: 0, index: 0, k: 1, m: 0, seq: 0, pass: 0 },
            vec![],
        ));
    }

    #[test]
    fn control_roundtrips() {
        roundtrip(Packet::LambdaUpdate { lambda: 383.25 });
        roundtrip(Packet::EndOfPass { pass: 7 });
        roundtrip(Packet::LostList { pass: 2, total: 2, ftgs: vec![(0, 1), (3, 99999)] });
        roundtrip(Packet::LostList { pass: 0, total: 0, ftgs: vec![] });
        // A maximally-sized (truncated) lost list must fit one datagram.
        roundtrip(Packet::LostList {
            pass: 9,
            total: 10 * MAX_LOST_PER_MSG as u32,
            ftgs: (0..MAX_LOST_PER_MSG).map(|i| (3u8, i as u32)).collect(),
        });
        roundtrip(Packet::Done);
        roundtrip(Packet::ManifestAck);
        roundtrip(Packet::Manifest(Manifest {
            n: 32,
            s: 4096,
            streams: 4,
            levels: vec![
                ManifestLevel { size: 668 << 20, eps: 0.004, m0: 5, cut: false },
                ManifestLevel { size: 2867 << 20, eps: 0.0005, m0: 0, cut: true },
            ],
            contract: 1,
        }));
        roundtrip(Packet::StreamEnd { stream: 3, pass: 2, sent: 123_456 });
        roundtrip(Packet::PassStats {
            pass: 1,
            expected: 50_000,
            received: 49_500,
            runs: 125,
            burst_lost: 320,
        });
        roundtrip(Packet::LevelShed { level: 3, bytes: 40 * 1024, eps: 0.0042 });
        roundtrip(Packet::LevelShed { level: 0, bytes: 0, eps: 1.0 });
    }

    #[test]
    fn repair_and_group_ack_roundtrip() {
        roundtrip(Packet::RepairSymbol(
            RepairHeader { group: 123_456, esi: 7, seed: 0xFEED_FACE_CAFE_BEEF, seq: 99 },
            vec![0x5D; 4096],
        ));
        roundtrip(Packet::RepairSymbol(
            RepairHeader { group: 0, esi: 0, seed: 0, seq: 0 },
            vec![],
        ));
        roundtrip(Packet::GroupAck { upto: 0, bitmap: 0 });
        roundtrip(Packet::GroupAck { upto: u32::MAX, bitmap: u64::MAX });
    }

    #[test]
    fn repair_fast_path_matches_enum_encoding() {
        let h = RepairHeader { group: 9, esi: 40, seed: 0x1234_5678, seq: 1_000_000 };
        let payload = vec![0xA7u8; 777];
        let mut fast = Vec::new();
        encode_repair_into(&h, &payload, &mut fast);
        assert_eq!(fast, Packet::RepairSymbol(h, payload.clone()).encode());
        // Repair symbols are the fountain data path: loss-injected like
        // fragments, directly and through a transfer-tag envelope.
        assert!(is_fragment(&fast));
        let mut tagged = Vec::new();
        encode_tagged(3, &fast, &mut tagged);
        assert!(is_fragment(&tagged));
        // And the borrowing view decode matches the owned decode.
        match PacketView::decode(&fast).unwrap() {
            PacketView::Repair(view) => {
                assert_eq!(view.header, h);
                assert_eq!(view.payload, &payload[..]);
                let base = fast.as_ptr() as usize;
                let p = view.payload.as_ptr() as usize;
                assert!(p >= base && p < base + fast.len());
            }
            other => panic!("expected repair view, got {other:?}"),
        }
    }

    #[test]
    fn group_ack_is_control_not_data() {
        let buf = Packet::GroupAck { upto: 5, bitmap: 0b101 }.encode();
        assert!(!is_fragment(&buf), "acks ride the reliable control path");
    }

    #[test]
    fn fountain_flag_masks_out_of_contract() {
        let mut m = Manifest { n: 32, s: 1024, streams: 1, levels: vec![], contract: 1 };
        assert!(!m.is_fountain());
        assert_eq!(m.contract_mode(), 1);
        m.contract |= CONTRACT_FOUNTAIN;
        assert!(m.is_fountain());
        assert_eq!(m.contract_mode(), 1, "mode bits survive the flag");
    }

    #[test]
    fn corruption_detected() {
        let mut buf = Packet::LambdaUpdate { lambda: 1.0 }.encode();
        buf[3] ^= 0x40;
        assert_eq!(Packet::decode(&buf), Err(WireError::BadChecksum));
    }

    #[test]
    fn truncation_detected() {
        let buf = Packet::Done.encode();
        assert!(matches!(
            Packet::decode(&buf[..2]),
            Err(WireError::Truncated(_) | WireError::BadChecksum)
        ));
        assert!(matches!(Packet::decode(&[]), Err(WireError::Truncated(0))));
    }

    #[test]
    fn unknown_kind_rejected() {
        let mut buf = vec![0xEEu8];
        let c = {
            let mut h = Hasher::new();
            h.update(&buf);
            h.finalize()
        };
        buf.extend_from_slice(&c.to_le_bytes());
        assert_eq!(Packet::decode(&buf), Err(WireError::UnknownKind(0xEE)));
    }

    #[test]
    fn encode_into_reuses_buffer() {
        let mut buf = Vec::new();
        Packet::Done.encode_into(&mut buf);
        let len1 = buf.len();
        Packet::LambdaUpdate { lambda: 2.0 }.encode_into(&mut buf);
        assert_ne!(buf.len(), len1);
        assert_eq!(Packet::decode(&buf).unwrap(), Packet::LambdaUpdate { lambda: 2.0 });
    }

    #[test]
    fn view_decode_borrows_fragment_payload() {
        let h = FragmentHeader {
            level: 3,
            stream: 1,
            ftg: 77,
            index: 9,
            k: 24,
            m: 8,
            seq: 42,
            pass: 2,
        };
        let payload = vec![0xC3u8; 2048];
        let buf = Packet::Fragment(h, payload.clone()).encode();
        match PacketView::decode(&buf).unwrap() {
            PacketView::Fragment(view) => {
                assert_eq!(view.header, h);
                assert_eq!(view.payload, &payload[..]);
                // Borrowed straight from the datagram, no copy.
                let base = buf.as_ptr() as usize;
                let p = view.payload.as_ptr() as usize;
                assert!(p >= base && p < base + buf.len());
            }
            other => panic!("expected fragment view, got {other:?}"),
        }
    }

    #[test]
    fn view_decode_matches_owned_decode() {
        let frames = vec![
            Packet::Fragment(
                FragmentHeader {
                    level: 0,
                    stream: 0,
                    ftg: 1,
                    index: 2,
                    k: 4,
                    m: 2,
                    seq: 5,
                    pass: 0,
                },
                vec![7u8; 100],
            ),
            Packet::LambdaUpdate { lambda: 1.5 },
            Packet::Done,
            Packet::LostList { pass: 1, total: 1, ftgs: vec![(0, 3)] },
            Packet::StreamEnd { stream: 2, pass: 0, sent: 10 },
        ];
        for p in frames {
            let buf = p.encode();
            match (PacketView::decode(&buf).unwrap(), Packet::decode(&buf).unwrap()) {
                (PacketView::Fragment(view), Packet::Fragment(h, payload)) => {
                    assert_eq!(view.header, h);
                    assert_eq!(view.payload, &payload[..]);
                }
                (PacketView::Control(c), owned) => assert_eq!(c, owned),
                (view, owned) => panic!("mismatch: {view:?} vs {owned:?}"),
            }
        }
    }

    #[test]
    fn view_decode_rejects_malformed_input() {
        assert_eq!(PacketView::decode(&[]), Err(WireError::Truncated(0)));
        let mut buf = Packet::Fragment(
            FragmentHeader { level: 0, stream: 0, ftg: 0, index: 0, k: 1, m: 0, seq: 0, pass: 0 },
            vec![1, 2, 3],
        )
        .encode();
        buf[7] ^= 0xFF;
        assert_eq!(PacketView::decode(&buf), Err(WireError::BadChecksum));
    }

    #[test]
    fn fragment_fast_path_matches_enum_encoding() {
        let h = FragmentHeader {
            level: 1,
            stream: 2,
            ftg: 42,
            index: 7,
            k: 28,
            m: 4,
            seq: 1_000_000,
            pass: 1,
        };
        let payload = vec![0x5Au8; 777];
        let mut fast = Vec::new();
        encode_fragment_into(&h, &payload, &mut fast);
        assert_eq!(fast, Packet::Fragment(h, payload).encode());
        assert!(is_fragment(&fast));
        assert!(!is_fragment(&Packet::Done.encode()));
        assert!(!is_fragment(&[]));
    }

    #[test]
    fn transfer_tag_roundtrip() {
        let inner = Packet::EndOfPass { pass: 3 }.encode();
        let mut tagged = Vec::new();
        encode_tagged(0xDEAD_BEEF, &inner, &mut tagged);
        assert_eq!(tagged.len(), inner.len() + TAG_BYTES);
        let (id, peeled) = peel_tag(&tagged).expect("tagged datagram must peel");
        assert_eq!(id, 0xDEAD_BEEF);
        assert_eq!(peeled, &inner[..]);
        assert_eq!(Packet::decode(peeled).unwrap(), Packet::EndOfPass { pass: 3 });
        // encode_tagged clears its output buffer like the other encoders.
        encode_tagged(7, &inner, &mut tagged);
        assert_eq!(peel_tag(&tagged).unwrap().0, 7);
    }

    #[test]
    fn peel_tag_rejects_untagged_and_truncated() {
        assert_eq!(peel_tag(&Packet::Done.encode()), None);
        assert_eq!(peel_tag(&[]), None);
        assert_eq!(peel_tag(&[KIND_TRANSFER_TAG, 1, 2]), None);
        // Exactly TAG_BYTES peels to an empty inner datagram (which any
        // decoder then rejects as truncated).
        let bare = [KIND_TRANSFER_TAG, 9, 0, 0, 0];
        assert_eq!(peel_tag(&bare), Some((9, &[][..])));
    }

    #[test]
    fn is_fragment_sees_through_transfer_tag() {
        let h =
            FragmentHeader { level: 0, stream: 0, ftg: 0, index: 0, k: 1, m: 0, seq: 0, pass: 0 };
        let mut frag = Vec::new();
        encode_fragment_into(&h, &[1, 2, 3], &mut frag);
        let mut tagged = Vec::new();
        encode_tagged(42, &frag, &mut tagged);
        assert!(is_fragment(&tagged));
        encode_tagged(42, &Packet::Done.encode(), &mut tagged);
        assert!(!is_fragment(&tagged));
        assert!(!is_fragment(&[KIND_TRANSFER_TAG]));
    }

    #[test]
    fn max_tagged_fragment_fits_one_datagram() {
        let h =
            FragmentHeader { level: 0, stream: 0, ftg: 0, index: 0, k: 1, m: 0, seq: 0, pass: 0 };
        let payload = vec![0u8; MAX_FRAGMENT_PAYLOAD - TAG_BYTES];
        let mut frag = Vec::new();
        encode_fragment_into(&h, &payload, &mut frag);
        let mut tagged = Vec::new();
        encode_tagged(u32::MAX, &frag, &mut tagged);
        assert!(tagged.len() <= MAX_DATAGRAM, "tagged max fragment must fit MAX_DATAGRAM");
    }
}
