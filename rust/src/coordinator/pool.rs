//! Multi-stream parallel transfer engine — [`TransferPool`].
//!
//! The Petascale-DTN lesson (PAPERS.md) is that single-stream transfers
//! cannot saturate a fat WAN pipe: real facility-to-facility deployments
//! reach line rate only with many concurrent streams. This module shards
//! a dataset's fault-tolerant groups across `N` sender workers, each with
//! its own paced [`Datagram`] endpoint and its own Reed–Solomon encoder
//! (worker-pool parity generation), while a receiver demultiplexes
//! fragments by the wire-format's stream id and reassembles one shared
//! group table.
//!
//! ## Adaptation: one λ̂ for all streams
//!
//! All streams traverse the same WAN, so there is one loss process and
//! one estimate. The pool measures λ̂ at **pass barriers**: each worker
//! announces how many fragments it sent ([`Packet::StreamEnd`]); the
//! receiver answers the end-of-pass exchange with aggregate
//! expected/received counts ([`Packet::PassStats`]); the sender converts
//! the surviving fraction into λ̂ = loss_fraction · (N·r) and re-solves
//! Eq. 8 ([`optimize_parity`]) for the retransmission pass's parity.
//! Because adaptation happens only at barriers and every per-stream send
//! order is fixed at planning time, the complete transfer trace is a
//! deterministic function of (config, dataset, channel seeds) — asserted
//! by `rust/tests/pool_e2e.rs` and exploited by `testkit`.
//!
//! ## Retransmission without retention
//!
//! Workers re-encode lost FTGs from the source level buffers instead of
//! retaining every encoded fragment (the single-stream sender's
//! approach): parity rows of the systematic generator are nested in m
//! (row `k+p` is identical for every parity count), so a retransmission
//! pass may *raise* m for the lost groups and the receiver can combine
//! parity fragments from different passes in one decode.
//!
//! ## Transport assumptions (current limitation)
//!
//! Data-path fragments may be dropped arbitrarily, but the end-of-pass
//! barrier assumes `StreamEnd` markers and control replies eventually get
//! through: markers are sent in triplicate but never re-announced, so a
//! transport that can swallow all copies (raw UDP under receive-buffer
//! overflow) can wedge a pass until `max_duration` aborts it. In-process
//! channels and the testkit (which drops only fragment datagrams, the
//! convention the loopback experiments already follow) satisfy the
//! assumption; a marker re-announcement round is future work for the
//! real-UDP pool deployment.

use super::arena::FtgArena;
use super::packet::{
    encode_fragment_into, FragmentHeader, Manifest, Packet, PacketView, MAX_DATAGRAM,
    MAX_LOST_PER_MSG,
};
use super::receiver::ReceiverConfig;
use super::sender::pace_until;
use crate::api::observer::{emit, EventSink};
use crate::api::TransferEvent;
use crate::erasure::RsCode;
use crate::model::params::{LevelSchedule, NetParams};
use crate::model::time_model::optimize_parity;
use crate::transport::channel::{Datagram, FrameQueue};
use crate::transport::frame::FramePool;
use crate::util::err::Result;
use crate::{anyhow, bail};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Configuration for a multi-stream pool transfer (guaranteed-error-bound
/// contract, the paper's Alg. 1 generalized to N streams).
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Network/coding parameters; `net.r` is the **per-stream** pacing
    /// rate, so the aggregate nominal rate is `streams · net.r`.
    pub net: NetParams,
    /// Concurrent sender workers (≥ 1; 1 degenerates to a single-stream
    /// engine with the pool protocol).
    pub streams: usize,
    /// Deliver every level needed for this relative L∞ bound.
    pub error_bound: f64,
    /// Initial λ estimate feeding the first Eq. 8 solve (losses/s over
    /// the aggregate link).
    pub initial_lambda: f64,
    /// Abort the transfer after this much wall time.
    pub max_duration: Duration,
}

impl PoolConfig {
    fn validate(&self) -> Result<()> {
        if self.streams < 1 || self.streams > 255 {
            bail!("pool streams must be in 1..=255, got {}", self.streams);
        }
        if self.net.n < 2 || self.net.n > 128 {
            bail!("pool n must be in 2..=128, got {}", self.net.n);
        }
        if self.net.s == 0 {
            bail!("fragment size must be positive");
        }
        super::packet::validate_fragment_size(self.net.s)?;
        Ok(())
    }

    /// Aggregate network parameters (what the Eq. 8 solver sees).
    fn aggregate_net(&self, lambda: f64) -> NetParams {
        NetParams { lambda, r: self.net.r * self.streams as f64, ..self.net }
    }
}

/// One sender pass, as recorded in the deterministic transfer trace.
#[derive(Debug, Clone, PartialEq)]
pub struct PassRecord {
    /// Pass number (0 = initial transmission).
    pub pass: u32,
    /// Parity fragments per FTG used for groups encoded this pass.
    pub m: usize,
    /// FTGs transmitted this pass.
    pub ftgs: u64,
    /// Fragments put on the wire this pass, summed over streams.
    pub fragments: u64,
    /// Per-stream fragment counts (length = streams).
    pub per_stream: Vec<u64>,
    /// λ̂ computed from this pass's receiver statistics.
    pub lambda_hat: f64,
    /// FTGs the receiver reported unrecoverable after this pass.
    pub lost_ftgs: u64,
}

/// Sender-side outcome of a pool transfer.
#[derive(Debug, Clone)]
pub struct PoolSenderReport {
    pub fragments_sent: u64,
    pub data_fragments: u64,
    /// Retransmission passes (0 = everything recovered first pass).
    pub passes: u32,
    pub duration: f64,
    /// Per-pass records; identical across runs with identical seeds.
    pub trace: Vec<PassRecord>,
    /// λ̂ after each pass (same values as in `trace`, flat for plotting).
    pub lambda_history: Vec<f64>,
}

/// One receiver pass, as recorded in the deterministic transfer trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecvPassRecord {
    pub pass: u32,
    /// Fragments the sender announced for this pass.
    pub expected: u64,
    /// Fragments that survived the wire.
    pub received: u64,
    /// FTGs still undecodable when the pass closed.
    pub lost_ftgs: u64,
}

/// Receiver-side outcome of a pool transfer.
#[derive(Debug, Clone)]
pub struct PoolReceiverReport {
    /// Recovered level buffers (exact original bytes).
    pub levels: Vec<Option<Vec<u8>>>,
    /// Leading fully-recovered levels.
    pub levels_recovered: usize,
    /// ε of the recovered prefix (1.0 when nothing usable).
    pub achieved_eps: f64,
    pub fragments_received: u64,
    /// FTGs that needed Reed–Solomon recovery (vs. arriving complete).
    pub groups_recovered: u64,
    pub duration: f64,
    /// Per-pass records; identical across runs with identical seeds.
    pub trace: Vec<RecvPassRecord>,
}

/// One planned fault-tolerant group: `k` data fragments sliced from a
/// level buffer at `offset`. Parity count is chosen per pass.
#[derive(Debug, Clone, Copy)]
struct FtgJob {
    level: u8,
    ftg: u32,
    offset: usize,
    k: usize,
}

/// Multi-stream parallel transfer engine (see module docs).
#[derive(Debug, Clone)]
pub struct TransferPool {
    cfg: PoolConfig,
}

impl TransferPool {
    pub fn new(cfg: PoolConfig) -> Result<TransferPool> {
        cfg.validate()?;
        Ok(TransferPool { cfg })
    }

    pub fn config(&self) -> &PoolConfig {
        &self.cfg
    }

    /// Run the sender side.
    #[deprecated(note = "use janus::api::Endpoint::send")]
    pub fn run_sender<C, D>(
        &self,
        control: &mut C,
        data: &mut [D],
        levels: &[Vec<u8>],
        eps: &[f64],
    ) -> Result<PoolSenderReport>
    where
        C: Datagram,
        D: Datagram,
    {
        self.pooled_sender(control, data, levels, eps, None)
    }

    /// Pooled sender engine. `control` carries the handshake and pass
    /// exchanges; `data[w]` is stream `w`'s paced endpoint
    /// (`data.len()` must equal `cfg.streams`). Public entry:
    /// [`crate::api::Endpoint::send`].
    pub(crate) fn pooled_sender<C, D>(
        &self,
        control: &mut C,
        data: &mut [D],
        levels: &[Vec<u8>],
        eps: &[f64],
        events: EventSink<'_>,
    ) -> Result<PoolSenderReport>
    where
        C: Datagram,
        D: Datagram,
    {
        let cfg = &self.cfg;
        assert_eq!(levels.len(), eps.len());
        if data.len() != cfg.streams {
            bail!("pool wants {} data channels, got {}", cfg.streams, data.len());
        }
        let start = Instant::now();
        let n = cfg.net.n;
        let s = cfg.net.s;
        let sched =
            LevelSchedule::new(levels.iter().map(|l| l.len() as u64).collect(), eps.to_vec());
        let send_levels = sched.levels_for_error_bound(cfg.error_bound).ok_or_else(|| {
            anyhow!("error bound {} unachievable: ε_L = {}", cfg.error_bound, eps[eps.len() - 1])
        })?;
        let total_bytes = sched.total_bytes(send_levels);

        // === Handshake ===
        let manifest = Packet::Manifest(Manifest {
            n: n as u8,
            s: s as u32,
            streams: cfg.streams as u8,
            levels: (0..send_levels).map(|i| (levels[i].len() as u64, eps[i])).collect(),
            contract: 0,
        });
        let mut acked = false;
        for _ in 0..50 {
            control.send(&manifest.encode());
            if let Some(buf) = control.recv_timeout(Duration::from_millis(100)) {
                if matches!(Packet::decode(&buf), Ok(Packet::ManifestAck)) {
                    acked = true;
                    break;
                }
            }
        }
        if !acked {
            bail!("pool receiver did not acknowledge manifest");
        }

        // === Pass-0 plan: fixed m per pass keeps the trace deterministic;
        // λ̂ feedback adapts the *next* pass (Eq. 8 re-solve). ===
        let mut lambda_hat = cfg.initial_lambda;
        let mut m = optimize_parity(&cfg.aggregate_net(lambda_hat), total_bytes.max(1)).m;

        let mut jobs: Vec<FtgJob> = Vec::new();
        for (li, level) in levels.iter().enumerate().take(send_levels) {
            let mut offset = 0usize;
            let mut ftg = 0u32;
            while offset < level.len() {
                let remaining = level.len() - offset;
                let k = (n - m).min(remaining.div_ceil(s)).max(1);
                jobs.push(FtgJob { level: li as u8, ftg, offset, k });
                offset += k * s;
                ftg += 1;
            }
        }
        let data_fragments: u64 = jobs.iter().map(|j| j.k as u64).sum();

        let mut report = PoolSenderReport {
            fragments_sent: 0,
            data_fragments,
            passes: 0,
            duration: 0.0,
            trace: Vec::new(),
            lambda_history: Vec::new(),
        };

        // Per-stream wire sequence numbers, monotone across passes.
        let mut seqs = vec![0u64; cfg.streams];
        // Jobs (indices) to transmit this pass; pass 0 sends everything.
        let mut todo: Vec<usize> = (0..jobs.len()).collect();
        let mut pass = 0u32;

        loop {
            if start.elapsed() > cfg.max_duration {
                bail!("pool sender exceeded max duration");
            }
            emit(events, TransferEvent::PassStarted { pass });
            emit(events, TransferEvent::ParityAdapted { pass, m });
            // Deterministic shard: round-robin over the pass's job list.
            let shards: Vec<Vec<usize>> = (0..cfg.streams)
                .map(|w| todo.iter().copied().skip(w).step_by(cfg.streams).collect())
                .collect();

            // === Fan out: one worker per stream, own channel + encoder ===
            let pace = Duration::from_secs_f64(1.0 / cfg.net.r);
            let net = cfg.net;
            let jobs_ref = &jobs;
            let sent_counts: Vec<u64> = std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(cfg.streams);
                for (w, chan) in data.iter_mut().enumerate() {
                    let shard = &shards[w];
                    let seq0 = seqs[w];
                    handles.push(scope.spawn(move || {
                        send_shard(
                            chan, w as u8, pass, m, shard, jobs_ref, levels, &net, pace, seq0,
                            events,
                        )
                    }));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("pool worker panicked"))
                    .collect()
            });
            let per_stream = sent_counts; // moved, not cloned (ISSUE 3)
            let pass_sent: u64 = per_stream.iter().sum();
            for (w, &c) in per_stream.iter().enumerate() {
                seqs[w] += c;
            }
            report.fragments_sent += pass_sent;

            // === Barrier: end-of-pass exchange on the control channel ===
            let mut stats: Option<(u64, u64)> = None;
            let mut lost: Option<Vec<(u8, u32)>> = None;
            let mut finished = false;
            'exchange: for _ in 0..200 {
                control.send(&Packet::EndOfPass { pass }.encode());
                let wait_until = Instant::now() + Duration::from_millis(200);
                while Instant::now() < wait_until {
                    let buf = match control.recv_timeout(Duration::from_millis(50)) {
                        Some(b) => b,
                        None => break,
                    };
                    match Packet::decode(&buf) {
                        Ok(Packet::PassStats { pass: p, expected, received }) if p == pass => {
                            stats = Some((expected, received));
                        }
                        Ok(Packet::LostList { pass: p, ftgs }) if p == pass => {
                            lost = Some(ftgs);
                        }
                        Ok(Packet::Done) => {
                            finished = true;
                        }
                        _ => {}
                    }
                    if stats.is_some() && lost.is_some() {
                        break 'exchange;
                    }
                }
                if start.elapsed() > cfg.max_duration {
                    bail!("pool sender timed out awaiting pass {pass} feedback");
                }
            }
            let (expected, received) = stats.ok_or_else(|| {
                anyhow!("no PassStats for pass {pass} (receiver gone?)")
            })?;
            let lost = lost.ok_or_else(|| anyhow!("no LostList for pass {pass}"))?;

            // === Shared λ̂ update + Eq. 8 re-solve for the next pass ===
            let loss_frac = if expected == 0 {
                0.0
            } else {
                (1.0 - received as f64 / expected as f64).clamp(0.0, 1.0)
            };
            lambda_hat = loss_frac * cfg.net.r * cfg.streams as f64;
            report.lambda_history.push(lambda_hat);
            emit(events, TransferEvent::LambdaUpdated { lambda: lambda_hat });
            report.trace.push(PassRecord {
                pass,
                m,
                ftgs: todo.len() as u64,
                fragments: pass_sent,
                per_stream,
                lambda_hat,
                lost_ftgs: lost.len() as u64,
            });

            if finished || lost.is_empty() {
                break;
            }

            // Map the lost (level, ftg) ids back to job indices.
            let index: HashMap<(u8, u32), usize> = jobs
                .iter()
                .enumerate()
                .map(|(i, j)| ((j.level, j.ftg), i))
                .collect();
            let mut next: Vec<usize> = Vec::with_capacity(lost.len());
            for key in &lost {
                match index.get(key) {
                    Some(&i) => next.push(i),
                    None => bail!("receiver reported unknown FTG {key:?}"),
                }
            }
            let lost_bytes: u64 = next.iter().map(|&i| jobs[i].k as u64 * s as u64).sum();
            m = optimize_parity(&cfg.aggregate_net(lambda_hat), lost_bytes.max(1)).m;
            todo = next;
            pass += 1;
            report.passes = pass;
            if pass > 10_000 {
                bail!("pool retransmission did not converge");
            }
        }

        report.duration = start.elapsed().as_secs_f64();
        Ok(report)
    }

    /// Run the receiver side.
    #[deprecated(note = "use janus::api::Endpoint::receive")]
    pub fn run_receiver<C, D>(
        control: &mut C,
        data: Vec<D>,
        rcfg: &ReceiverConfig,
    ) -> Result<PoolReceiverReport>
    where
        C: Datagram,
        D: Datagram + Send,
    {
        Self::pooled_receiver(control, data, rcfg, None)
    }

    /// Pooled receiver engine: demultiplex `data` endpoints by stream id
    /// into one shared reassembly table, answer pass barriers with
    /// aggregate loss statistics, and reconstruct the levels on `Done`.
    /// Public entry: [`crate::api::Endpoint::receive`].
    pub(crate) fn pooled_receiver<C, D>(
        control: &mut C,
        data: Vec<D>,
        rcfg: &ReceiverConfig,
        events: EventSink<'_>,
    ) -> Result<PoolReceiverReport>
    where
        C: Datagram,
        D: Datagram + Send,
    {
        let start = Instant::now();

        // === Handshake ===
        let manifest: Manifest = loop {
            if start.elapsed() > rcfg.max_duration {
                bail!("pool receiver: no manifest");
            }
            match control.recv_timeout(rcfg.idle_timeout) {
                Some(buf) => match Packet::decode(&buf) {
                    Ok(Packet::Manifest(m)) => {
                        control.send(&Packet::ManifestAck.encode());
                        break m;
                    }
                    _ => continue,
                },
                None => bail!("pool receiver: timed out waiting for manifest"),
            }
        };
        let streams = manifest.streams as usize;
        if data.len() != streams {
            bail!("manifest announces {streams} streams, receiver has {}", data.len());
        }
        let s = manifest.s as usize;
        super::packet::validate_fragment_size(s)?;
        let num_levels = manifest.levels.len();

        let mut report = PoolReceiverReport {
            levels: vec![None; num_levels],
            levels_recovered: 0,
            achieved_eps: 1.0,
            fragments_received: 0,
            groups_recovered: 0,
            duration: 0.0,
            trace: Vec::new(),
        };

        let mut groups: HashMap<(u8, u32), FtgArena> = HashMap::new();
        // Per-pass statistics: announced (per stream) and received counts.
        let mut announced: HashMap<u32, HashMap<u8, u64>> = HashMap::new();
        let mut received_in_pass: HashMap<u32, u64> = HashMap::new();
        // Cached reply to the last finalized pass, pre-encoded once:
        // duplicate EndOfPass retries must get byte-identical answers
        // even after later fragments arrive, and resending reuses the
        // same wire bytes instead of re-cloning the lost list
        // (pass, stats datagram, lost-list datagram, lost-list empty).
        let mut last_reply: Option<(u32, Vec<u8>, Vec<u8>, bool)> = None;
        // An EndOfPass that arrived before every stream's marker did —
        // finalized the moment the last marker drains from the fan-in.
        let mut pending_end: Option<u32> = None;

        // === Demux fan-in: one reader thread per data endpoint ===
        // Readers receive into pooled frames (recycled on drop) and hand
        // them over on a condvar FrameQueue, so the steady-state fan-in
        // allocates nothing per datagram (mpsc would allocate a block
        // per batch of messages).
        let frames = FramePool::new();
        let shutdown = AtomicBool::new(false);
        let fan = FrameQueue::new();
        let done = std::thread::scope(|scope| -> Result<()> {
            for mut chan in data {
                let stop = &shutdown;
                let pool = Arc::clone(&frames);
                let q = Arc::clone(&fan);
                scope.spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let mut frame = pool.lease();
                        match chan.recv_into(frame.buf_mut(), Duration::from_millis(50)) {
                            Some(n) => {
                                frame.set_len(n);
                                q.push(frame);
                            }
                            None => {} // timeout: frame drops back into the pool
                        }
                    }
                });
            }

            // Answer an end-of-pass barrier whose stream markers have all
            // arrived. Returns true when the transfer is complete.
            // Idempotent: a duplicate EndOfPass resends the cached reply;
            // passes older than the cache are ignored.
            let finalize = |pass: u32,
                                control: &mut C,
                                groups: &HashMap<(u8, u32), FtgArena>,
                                announced: &HashMap<u32, HashMap<u8, u64>>,
                                received_in_pass: &HashMap<u32, u64>,
                                last_reply: &mut Option<(u32, Vec<u8>, Vec<u8>, bool)>,
                                report: &mut PoolReceiverReport|
             -> bool {
                if let Some((p, stats_buf, lost_buf, lost_empty)) = last_reply.as_ref() {
                    if pass < *p {
                        return false; // stale retry of an older pass
                    }
                    if pass == *p {
                        // Resend the pre-encoded reply bytes verbatim.
                        control.send(stats_buf);
                        control.send(lost_buf);
                        if *lost_empty {
                            control.send(&Packet::Done.encode());
                            return true;
                        }
                        return false;
                    }
                }
                let expected: u64 = announced[&pass].values().sum();
                let received = *received_in_pass.get(&pass).unwrap_or(&0);
                let lost = collect_lost(&manifest, groups, s);
                report.trace.push(RecvPassRecord {
                    pass,
                    expected,
                    received,
                    lost_ftgs: lost.len() as u64,
                });
                // Cap the wire list to one datagram; the tail is simply
                // re-reported on the next pass (nonempty ⇒ capped
                // nonempty, so the Done decision is unaffected). Encoded
                // once per pass — retries reuse the bytes.
                let wire: Vec<(u8, u32)> =
                    lost.iter().take(MAX_LOST_PER_MSG).copied().collect();
                let lost_empty = lost.is_empty();
                let stats_buf = Packet::PassStats { pass, expected, received }.encode();
                let lost_buf = Packet::LostList { pass, ftgs: wire }.encode();
                control.send(&stats_buf);
                control.send(&lost_buf);
                *last_reply = Some((pass, stats_buf, lost_buf, lost_empty));
                if lost_empty {
                    control.send(&Packet::Done.encode());
                    return true;
                }
                false
            };

            let marker_complete = |announced: &HashMap<u32, HashMap<u8, u64>>, pass: u32| {
                announced.get(&pass).map_or(false, |e| e.len() >= streams)
            };

            let mut last_packet = Instant::now();
            let mut ctl_buf = vec![0u8; MAX_DATAGRAM];
            let result = 'pump: loop {
                if start.elapsed() > rcfg.max_duration {
                    break Err(anyhow!("pool receiver exceeded max duration"));
                }
                if last_packet.elapsed() > rcfg.idle_timeout {
                    break Err(anyhow!("pool receiver: sender went silent"));
                }
                // Control plane (cheap nonblocking poll): note the barrier
                // request; it is answered only once every stream's marker
                // has drained from the fan-in, because per-channel FIFO
                // then guarantees all surviving fragments of the pass are
                // already in `groups`.
                while let Some(n) = control.try_recv_into(&mut ctl_buf) {
                    last_packet = Instant::now();
                    if let Ok(Packet::EndOfPass { pass }) = Packet::decode(&ctl_buf[..n]) {
                        pending_end = Some(pass);
                    }
                }
                if let Some(pass) = pending_end {
                    if marker_complete(&announced, pass) {
                        pending_end = None;
                        if finalize(
                            pass,
                            control,
                            &groups,
                            &announced,
                            &received_in_pass,
                            &mut last_reply,
                            &mut report,
                        ) {
                            break 'pump Ok(());
                        }
                    }
                }
                // Data plane: fragments + stream-end markers. Frames are
                // decoded in place (borrowing view) and recycled on drop.
                match fan.pop_timeout(Duration::from_millis(2)) {
                    Some(frame) => {
                        last_packet = Instant::now();
                        match PacketView::decode(&frame) {
                            Ok(PacketView::Fragment(view)) => {
                                let h = view.header;
                                report.fragments_received += 1;
                                *received_in_pass.entry(h.pass).or_insert(0) += 1;
                                let g = groups
                                    .entry((h.level, h.ftg))
                                    .or_insert_with(|| FtgArena::new(h.k, h.m, s));
                                g.insert(h.index as usize, view.payload);
                            }
                            Ok(PacketView::Control(Packet::StreamEnd {
                                stream,
                                pass,
                                sent,
                            })) => {
                                announced.entry(pass).or_default().insert(stream, sent);
                            }
                            _ => {}
                        }
                    }
                    None => {} // poll timeout
                }
            };
            shutdown.store(true, Ordering::Relaxed);
            result
        });
        shutdown.store(true, Ordering::Relaxed);
        done?;

        // === Reconstruct levels (shared group table) ===
        reconstruct_levels(&manifest, &groups, s, &mut report, events)?;
        report.duration = start.elapsed().as_secs_f64();
        Ok(report)
    }

    /// Convenience harness: run a full pool transfer in threads.
    #[deprecated(note = "use janus::api::run_pair")]
    #[allow(clippy::type_complexity)]
    pub fn run_session<C, DS, DR>(
        &self,
        sender_control: &mut C,
        sender_data: Vec<DS>,
        receiver_control: &mut C,
        receiver_data: Vec<DR>,
        rcfg: &ReceiverConfig,
        levels: &[Vec<u8>],
        eps: &[f64],
    ) -> Result<(PoolSenderReport, PoolReceiverReport)>
    where
        C: Datagram,
        DS: Datagram,
        DR: Datagram + Send,
    {
        self.pooled_session(sender_control, sender_data, receiver_control, receiver_data, rcfg, levels, eps)
    }

    /// Session engine: run a full pool transfer across connected channel
    /// sets in threads and collect both reports.
    #[allow(clippy::type_complexity)]
    pub(crate) fn pooled_session<C, DS, DR>(
        &self,
        sender_control: &mut C,
        mut sender_data: Vec<DS>,
        receiver_control: &mut C,
        receiver_data: Vec<DR>,
        rcfg: &ReceiverConfig,
        levels: &[Vec<u8>],
        eps: &[f64],
    ) -> Result<(PoolSenderReport, PoolReceiverReport)>
    where
        C: Datagram,
        DS: Datagram,
        DR: Datagram + Send,
    {
        std::thread::scope(|scope| {
            let recv = scope
                .spawn(move || Self::pooled_receiver(receiver_control, receiver_data, rcfg, None));
            let send_report =
                self.pooled_sender(sender_control, &mut sender_data, levels, eps, None)?;
            let recv_report = recv
                .join()
                .map_err(|_| anyhow!("pool receiver thread panicked"))??;
            Ok((send_report, recv_report))
        })
    }
}

/// Worker body: RS-encode and pace this stream's share of the pass.
/// Returns the number of fragments sent.
#[allow(clippy::too_many_arguments)]
fn send_shard<D: Datagram>(
    chan: &mut D,
    stream: u8,
    pass: u32,
    m: usize,
    shard: &[usize],
    jobs: &[FtgJob],
    levels: &[Vec<u8>],
    net: &NetParams,
    pace: Duration,
    seq0: u64,
    events: EventSink<'_>,
) -> u64 {
    let s = net.s;
    let mut codes: HashMap<(usize, usize), RsCode> = HashMap::new();
    let mut out = Vec::with_capacity(s + 64);
    // One strided arena reused across the shard's FTGs: the worker's
    // steady state allocates nothing per group (the buffer only regrows
    // when (k+m)·s grows).
    let mut arena = FtgArena::new(0, 0, s);
    let mut seq = seq0;
    let mut next_send = Instant::now();
    for &ji in shard {
        let job = jobs[ji];
        let level_bytes = &levels[job.level as usize];
        // Parity never shrinks a group below its planned k.
        let m_eff = m.min(255usize.saturating_sub(job.k));
        // Slice k data fragments into the arena (zero-padding tails —
        // the arena is reused, so stale bytes must be overwritten).
        arena.reset(job.k as u8, m_eff as u8, s);
        for i in 0..job.k {
            let lo = (job.offset + i * s).min(level_bytes.len());
            let hi = (job.offset + (i + 1) * s).min(level_bytes.len());
            let slot = arena.slot_mut(i);
            slot[..hi - lo].copy_from_slice(&level_bytes[lo..hi]);
            slot[hi - lo..].fill(0);
        }
        let code = codes
            .entry((job.k, m_eff))
            .or_insert_with(|| RsCode::new(job.k, m_eff).expect("valid k,m"));
        arena.encode_parity(code).expect("encode");
        for idx in 0..arena.slots() {
            let hdr = FragmentHeader {
                level: job.level,
                stream,
                ftg: job.ftg,
                index: idx as u8,
                k: job.k as u8,
                m: m_eff as u8,
                seq,
                pass,
            };
            seq += 1;
            encode_fragment_into(&hdr, arena.slot(idx), &mut out);
            pace_until(next_send);
            next_send = Instant::now().max(next_send) + pace;
            chan.send(&out);
        }
    }
    let sent = seq - seq0;
    // Announce this stream's pass total on the data path (FIFO after the
    // fragments); duplicated for robustness on real lossy transports.
    let end = Packet::StreamEnd { stream, pass, sent }.encode();
    for _ in 0..3 {
        chan.send(&end);
    }
    emit(events, TransferEvent::StreamFinished { stream, pass, fragments: sent });
    sent
}

/// FTGs (per manifest byte accounting) that cannot currently be decoded.
/// (Reassembly state lives in [`FtgArena`]s — one strided allocation per
/// group with a presence bitmap, growing when later passes raise m.)
fn collect_lost(
    manifest: &Manifest,
    groups: &HashMap<(u8, u32), FtgArena>,
    s: usize,
) -> Vec<(u8, u32)> {
    let n = manifest.n as usize;
    let mut lost = Vec::new();
    for (li, &(size, _)) in manifest.levels.iter().enumerate() {
        let mut covered = 0u64;
        let mut ftg = 0u32;
        while covered < size {
            match groups.get(&(li as u8, ftg)) {
                Some(g) => {
                    if !g.decodable() {
                        lost.push((li as u8, ftg));
                    }
                    covered += g.k() as u64 * s as u64;
                }
                None => {
                    // Never seen: unrecoverable by definition; stride by
                    // the worst case since its true k is unknown.
                    lost.push((li as u8, ftg));
                    covered += n as u64 * s as u64;
                }
            }
            ftg += 1;
        }
    }
    lost
}

/// Rebuild the exact level bytes from the shared group table.
fn reconstruct_levels(
    manifest: &Manifest,
    groups: &HashMap<(u8, u32), FtgArena>,
    s: usize,
    report: &mut PoolReceiverReport,
    events: EventSink<'_>,
) -> Result<()> {
    let mut codes: HashMap<(u8, u8), RsCode> = HashMap::new();
    for (li, &(size, _eps)) in manifest.levels.iter().enumerate() {
        let mut out = Vec::with_capacity(size as usize);
        let mut ok = true;
        let mut ftg = 0u32;
        while (out.len() as u64) < size {
            match groups.get(&(li as u8, ftg)) {
                Some(g) if g.data_complete() => {
                    for i in 0..g.k() as usize {
                        out.extend_from_slice(g.slot(i));
                    }
                }
                Some(g) if g.decodable() => {
                    // Reed–Solomon recovery over whatever mix of passes'
                    // fragments arrived (parity rows nest in m), decoded
                    // straight into the level buffer with the
                    // survivor-pattern matrix cache.
                    let k = g.k();
                    let m_seen = (g.slots() - k as usize) as u8;
                    let code = codes.entry((k, m_seen)).or_insert_with(|| {
                        RsCode::new(k as usize, m_seen as usize).expect("valid k,m")
                    });
                    let shards: Vec<(usize, &[u8])> = g.iter_present().collect();
                    let start_len = out.len();
                    out.resize(start_len + k as usize * s, 0);
                    match code.reconstruct_into(&shards, &mut out[start_len..]) {
                        Ok(()) => {
                            report.groups_recovered += 1;
                            emit(
                                events,
                                TransferEvent::GroupRecovered { level: li as u8, ftg },
                            );
                        }
                        Err(_) => {
                            out.truncate(start_len);
                            ok = false;
                            break;
                        }
                    }
                }
                _ => {
                    ok = false;
                    break;
                }
            }
            ftg += 1;
        }
        if ok {
            out.truncate(size as usize);
            report.levels[li] = Some(out);
        }
    }
    let mut prefix = 0;
    for l in &report.levels {
        if l.is_some() {
            prefix += 1;
        } else {
            break;
        }
    }
    report.levels_recovered = prefix;
    report.achieved_eps = if prefix == 0 { 1.0 } else { manifest.levels[prefix - 1].1 };
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::channel::{mem_pair, MemChannel};
    use crate::util::Pcg64;

    fn pool_channels(streams: usize) -> (MemChannel, Vec<MemChannel>, MemChannel, Vec<MemChannel>) {
        let (sc, rc) = mem_pair();
        let mut sd = Vec::new();
        let mut rd = Vec::new();
        for _ in 0..streams {
            let (a, b) = mem_pair();
            sd.push(a);
            rd.push(b);
        }
        (sc, sd, rc, rd)
    }

    fn test_levels(seed: u64) -> (Vec<Vec<u8>>, Vec<f64>) {
        let mut rng = Pcg64::seeded(seed);
        let sizes = [50_000usize, 200_000, 400_000];
        let eps = vec![0.004, 0.0005, 0.0000001];
        (
            sizes
                .iter()
                .map(|&sz| {
                    let mut v = vec![0u8; sz];
                    rng.fill_bytes(&mut v);
                    v
                })
                .collect(),
            eps,
        )
    }

    fn cfg(streams: usize) -> PoolConfig {
        PoolConfig {
            net: NetParams { t: 0.0005, r: 200_000.0, lambda: 0.0, n: 32, s: 1024 },
            streams,
            error_bound: 1e-7,
            initial_lambda: 0.0,
            max_duration: Duration::from_secs(60),
        }
    }

    fn rcfg() -> ReceiverConfig {
        ReceiverConfig {
            t_w: 0.25,
            idle_timeout: Duration::from_secs(5),
            max_duration: Duration::from_secs(60),
        }
    }

    #[test]
    fn lossless_pool_delivers_exact_bytes_four_streams() {
        let (levels, eps) = test_levels(1);
        let pool = TransferPool::new(cfg(4)).unwrap();
        let (mut sc, sd, mut rc, rd) = pool_channels(4);
        let (s_rep, r_rep) = pool
            .pooled_session(&mut sc, sd, &mut rc, rd, &rcfg(), &levels, &eps)
            .unwrap();
        assert_eq!(r_rep.levels_recovered, 3);
        for (got, want) in r_rep.levels.iter().zip(&levels) {
            assert_eq!(got.as_ref().unwrap(), want);
        }
        assert_eq!(s_rep.passes, 0);
        assert_eq!(s_rep.trace.len(), 1);
        assert_eq!(s_rep.trace[0].lambda_hat, 0.0);
        assert_eq!(s_rep.trace[0].per_stream.len(), 4);
        // Every stream carried a share of the load.
        assert!(s_rep.trace[0].per_stream.iter().all(|&c| c > 0));
        assert_eq!(
            s_rep.trace[0].per_stream.iter().sum::<u64>(),
            s_rep.fragments_sent
        );
    }

    #[test]
    fn single_stream_pool_degenerates_cleanly() {
        let (levels, eps) = test_levels(2);
        let pool = TransferPool::new(cfg(1)).unwrap();
        let (mut sc, sd, mut rc, rd) = pool_channels(1);
        let (_s, r) = pool
            .pooled_session(&mut sc, sd, &mut rc, rd, &rcfg(), &levels, &eps)
            .unwrap();
        assert_eq!(r.levels_recovered, 3);
        for (got, want) in r.levels.iter().zip(&levels) {
            assert_eq!(got.as_ref().unwrap(), want);
        }
    }

    #[test]
    fn error_bound_limits_transmitted_levels() {
        let (levels, eps) = test_levels(3);
        let mut c = cfg(2);
        c.error_bound = 0.004; // level 1 suffices
        let pool = TransferPool::new(c).unwrap();
        let (mut sc, sd, mut rc, rd) = pool_channels(2);
        let (_s, r) = pool
            .pooled_session(&mut sc, sd, &mut rc, rd, &rcfg(), &levels, &eps)
            .unwrap();
        assert_eq!(r.levels.len(), 1, "only level 1 in manifest");
        assert_eq!(r.levels[0].as_ref().unwrap(), &levels[0]);
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        let mut c = cfg(0);
        assert!(TransferPool::new(c.clone()).is_err());
        c.streams = 4;
        c.net.n = 1;
        assert!(TransferPool::new(c.clone()).is_err());
        c.net.n = 32;
        assert!(TransferPool::new(c).is_ok());
    }

    #[test]
    fn mismatched_channel_count_is_an_error() {
        let (levels, eps) = test_levels(4);
        let pool = TransferPool::new(cfg(3)).unwrap();
        let (mut sc, mut sd, _rc, _rd) = pool_channels(2); // too few
        let err = pool
            .pooled_sender(&mut sc, &mut sd, &levels, &eps, None)
            .unwrap_err();
        assert!(format!("{err}").contains("data channels"), "{err}");
    }
}
