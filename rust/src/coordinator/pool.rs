//! Multi-stream parallel transfer engine — [`TransferPool`].
//!
//! The Petascale-DTN lesson (PAPERS.md) is that single-stream transfers
//! cannot saturate a fat WAN pipe: real facility-to-facility deployments
//! reach line rate only with many concurrent streams. This module shards
//! a dataset's fault-tolerant groups across `N` sender workers, each with
//! its own paced [`Datagram`] endpoint and its own Reed–Solomon encoder
//! (worker-pool parity generation), while a receiver demultiplexes
//! fragments by the wire-format's stream id and reassembles one shared
//! group table.
//!
//! ## Adaptation: one λ̂ for all streams
//!
//! All streams traverse the same WAN, so there is one loss process and
//! one estimate. The pool measures λ̂ at **pass barriers**: each worker
//! announces how many fragments it sent ([`Packet::StreamEnd`]); the
//! receiver answers the end-of-pass exchange with aggregate
//! expected/received counts ([`Packet::PassStats`]); the sender converts
//! the surviving fraction into λ̂ = loss_fraction · (N·r) and re-solves
//! Eq. 8 ([`optimize_parity`]) for the retransmission pass's parity.
//! Because adaptation happens only at barriers and every per-stream send
//! order is fixed at planning time, the complete transfer trace is a
//! deterministic function of (config, dataset, channel seeds) — asserted
//! by `rust/tests/pool_e2e.rs` and exploited by `testkit`.
//!
//! ## Retransmission without retention
//!
//! Workers re-encode lost FTGs from the source level buffers instead of
//! retaining every encoded fragment (the single-stream sender's
//! approach): parity rows of the systematic generator are nested in m
//! (row `k+p` is identical for every parity count), so a retransmission
//! pass may *raise* m for the lost groups and the receiver can combine
//! parity fragments from different passes in one decode.
//!
//! ## Transport assumptions (current limitation)
//!
//! Data-path fragments may be dropped arbitrarily, but the end-of-pass
//! barrier assumes `StreamEnd` markers and control replies eventually get
//! through: markers are sent in triplicate but never re-announced, so a
//! transport that can swallow all copies (raw UDP under receive-buffer
//! overflow) can wedge a pass until `max_duration` aborts it. In-process
//! channels and the testkit (which drops only fragment datagrams, the
//! convention the loopback experiments already follow) satisfy the
//! assumption; a marker re-announcement round is future work for the
//! real-UDP pool deployment.

use super::arena::FtgArena;
use super::packet::{
    encode_fragment_into, FragmentHeader, Manifest, ManifestLevel, Packet, PacketView,
    MAX_DATAGRAM, MAX_LOST_PER_MSG,
};
use super::estimate::{PassObservation, TwoStateEstimator};
use super::rate::{AdaptConfig, PassVerdict, RateController, RttEstimator};
use super::receiver::ReceiverConfig;
use super::sender::pace_until;
use crate::api::observer::{emit, EventSink};
use crate::api::{Contract, TransferEvent};
use crate::erasure::{CodingPool, RsCode};
use crate::model::error_model::{
    optimize_deadline_bitplane, BitplaneDeadlinePlan, ResidualSchedule,
};
use crate::model::params::{LevelSchedule, NetParams, PlaneCut};
use crate::model::time_model::{optimize_parity, optimize_parity_bursty, parity_floor_bursty};
use crate::transport::channel::{Datagram, FrameQueue};
use crate::transport::frame::FramePool;
use crate::util::err::Result;
use crate::{anyhow, bail};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Configuration for a multi-stream pool transfer: the paper's Alg. 1
/// generalized to N streams, plus pass-barrier τ accounting for the
/// Deadline contract (Alg. 2 with bounded retransmission).
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Network/coding parameters; `net.r` is the **per-stream** pacing
    /// rate, so the aggregate nominal rate is `streams · net.r`.
    pub net: NetParams,
    /// Concurrent sender workers (≥ 1; 1 degenerates to a single-stream
    /// engine with the pool protocol).
    pub streams: usize,
    /// What the transfer guarantees: `Fidelity`/`BestEffort` retransmit
    /// until every needed level is recovered; `Deadline(τ)` debits a
    /// virtual τ budget at each pass barrier and sheds work that no
    /// longer fits ([`DeadlineOutcome`]).
    pub contract: Contract,
    /// Initial λ estimate feeding the first Eq. 8 / Eq. 12 solve
    /// (losses/s over the aggregate link).
    pub initial_lambda: f64,
    /// Abort the transfer after this much wall time.
    pub max_duration: Duration,
    /// Sub-level [`PlaneCut`]s per level (codec datasets; empty = whole-
    /// level shed granularity). Lets a Deadline transfer keep a decodable
    /// bitplane prefix of a level it cannot afford in full.
    pub plane_cuts: Vec<Vec<PlaneCut>>,
    /// Congestion/burst adaptation knobs ([`AdaptConfig::fixed`] for the
    /// legacy fixed-rate, i.i.d.-λ̂ behaviour).
    pub adapt: AdaptConfig,
}

impl PoolConfig {
    fn validate(&self) -> Result<()> {
        if self.streams < 1 || self.streams > 255 {
            bail!("pool streams must be in 1..=255, got {}", self.streams);
        }
        if self.net.n < 2 || self.net.n > 128 {
            bail!("pool n must be in 2..=128, got {}", self.net.n);
        }
        if self.net.s == 0 {
            bail!("fragment size must be positive");
        }
        super::packet::validate_fragment_size(self.net.s)?;
        match self.contract {
            Contract::Deadline(tau) => {
                if !tau.is_finite() || tau <= 0.0 {
                    bail!("pool deadline must be positive and finite, got {tau}");
                }
            }
            Contract::Fidelity(bound) => {
                if bound.is_nan() || bound <= 0.0 || bound >= 1.0 {
                    bail!("pool fidelity bound must be in (0, 1), got {bound}");
                }
            }
            Contract::BestEffort => {}
        }
        self.adapt.validate()?;
        Ok(())
    }

    /// Aggregate network parameters (what the Eq. 8 / Eq. 12 solvers see).
    fn aggregate_net(&self, lambda: f64) -> NetParams {
        NetParams { lambda, r: self.net.r * self.streams as f64, ..self.net }
    }
}

/// One shed decision taken at a pass barrier: level `level`'s advertised
/// prefix shrank to `kept_bytes` (0 = the level was abandoned entirely)
/// because the residual τ budget could not afford its retransmission.
/// `eps` is the relative L∞ error the transfer prefix achieves after the
/// shed (the cut's measured ε for a partial shed; the preceding usable
/// prefix's ε for a full shed).
#[derive(Debug, Clone, PartialEq)]
pub struct ShedDecision {
    pub level: u8,
    pub kept_bytes: u64,
    pub eps: f64,
}

/// Sender-side account of a pooled Deadline transfer: how the virtual τ
/// budget was spent and what the final advertisement promises.
#[derive(Debug, Clone, PartialEq)]
pub struct DeadlineOutcome {
    /// The contracted deadline τ, seconds.
    pub tau: f64,
    /// Virtual seconds consumed: per pass, Eq. 9's aggregate air time
    /// (fragments sent over `N·r`) plus one-way latency — a pure
    /// function of the deterministic fragment counts, never of
    /// wall-clock jitter, and priced exactly like the Eq. 12 solves.
    pub virtual_elapsed: f64,
    /// `virtual_elapsed ≤ τ` at completion, within the plan's
    /// group-count rounding (Eq. 12 prices fractional groups; the wire
    /// sends whole ones — at most one data fragment plus the pass-0
    /// parity per level of deterministic slack).
    pub met: bool,
    /// ε the initial Eq. 12 bitplane plan promised.
    pub planned_eps: f64,
    /// ε of the final advertisement after all pass-barrier sheds (what
    /// the receiver certifies when the transfer completes).
    pub advertised_eps: f64,
}

/// One sender pass, as recorded in the deterministic transfer trace.
#[derive(Debug, Clone, PartialEq)]
pub struct PassRecord {
    /// Pass number (0 = initial transmission).
    pub pass: u32,
    /// Parity fragments per FTG used for groups encoded this pass (the
    /// maximum per-level parity when a Deadline plan differentiates).
    pub m: usize,
    /// FTGs transmitted this pass.
    pub ftgs: u64,
    /// Fragments put on the wire this pass, summed over streams.
    pub fragments: u64,
    /// Per-stream fragment counts (length = streams).
    pub per_stream: Vec<u64>,
    /// λ̂ computed from this pass's receiver statistics.
    pub lambda_hat: f64,
    /// Per-stream pacing rate the pass was sent at (fragments/s).
    pub rate: f64,
    /// Smoothed mean loss-run length b̂ after this pass's barrier.
    pub burst: f64,
    /// FTGs the receiver reported unrecoverable after this pass.
    pub lost_ftgs: u64,
    /// Shed decisions taken at this pass's barrier (Deadline only; part
    /// of the determinism contract asserted by `pool_e2e`).
    pub shed: Vec<ShedDecision>,
}

/// Sender-side outcome of a pool transfer.
#[derive(Debug, Clone)]
pub struct PoolSenderReport {
    pub fragments_sent: u64,
    pub data_fragments: u64,
    /// Retransmission passes (0 = everything recovered first pass).
    pub passes: u32,
    pub duration: f64,
    /// Per-pass records; identical across runs with identical seeds.
    pub trace: Vec<PassRecord>,
    /// λ̂ after each pass (same values as in `trace`, flat for plotting).
    pub lambda_history: Vec<f64>,
    /// Per-stream pacing rate after each pass barrier (the controller's
    /// back-off/recovery trajectory; constant under a fixed config).
    pub rate_history: Vec<f64>,
    /// τ accounting for Deadline transfers (`None` otherwise).
    pub deadline: Option<DeadlineOutcome>,
}

/// One receiver pass, as recorded in the deterministic transfer trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecvPassRecord {
    pub pass: u32,
    /// Fragments the sender announced for this pass.
    pub expected: u64,
    /// Fragments that survived the wire.
    pub received: u64,
    /// FTGs still undecodable when the pass closed.
    pub lost_ftgs: u64,
}

/// Receiver-side outcome of a pool transfer.
#[derive(Debug, Clone)]
pub struct PoolReceiverReport {
    /// Recovered level buffers (exact original bytes).
    pub levels: Vec<Option<Vec<u8>>>,
    /// Leading fully-recovered levels.
    pub levels_recovered: usize,
    /// ε of the recovered prefix (1.0 when nothing usable).
    pub achieved_eps: f64,
    pub fragments_received: u64,
    /// FTGs that needed Reed–Solomon recovery (vs. arriving complete).
    pub groups_recovered: u64,
    pub duration: f64,
    /// Per-pass records; identical across runs with identical seeds.
    pub trace: Vec<RecvPassRecord>,
}

/// One planned fault-tolerant group: `k` data fragments sliced from a
/// level buffer at `offset`. `k` is fixed at pass 0 (the manifest's
/// per-level `m0` lets the receiver recompute it); the parity count `m`
/// is re-chosen per pass (parity rows nest, so later passes may raise
/// it and the receiver combines fragments across passes).
#[derive(Debug, Clone, Copy)]
struct FtgJob {
    level: u8,
    ftg: u32,
    offset: usize,
    k: usize,
    m: u8,
}

/// Pass-barrier τ accounting state for a pooled Deadline transfer.
#[derive(Debug)]
struct DeadlineState {
    tau: f64,
    planned_eps: f64,
    /// Virtual seconds consumed so far (see [`DeadlineOutcome`]).
    virtual_elapsed: f64,
    /// Advertised per-level byte limits, shrunk by sheds (0 = abandoned).
    limits: Vec<u64>,
    /// Advertised per-level ε (a shed cut's measured ε after a partial).
    adv_eps: Vec<f64>,
    abandoned: Vec<bool>,
    /// Levels advertised as a plane-cut prefix. A cut level is the
    /// *last* usable rung: later rungs cannot refine the reconstruction
    /// without its shed bitplanes, so the ε accounting must stop there
    /// even when later levels happen to be fully delivered.
    cut: Vec<bool>,
    /// Encoded [`Packet::LevelShed`] advertisements, re-sent ahead of
    /// every `EndOfPass` so a lossy control path converges.
    shed_pkts: Vec<Vec<u8>>,
}

impl DeadlineState {
    fn new(
        tau: f64,
        planned_eps: f64,
        limits: Vec<u64>,
        adv_eps: Vec<f64>,
        cut: Vec<bool>,
    ) -> DeadlineState {
        let n = limits.len();
        DeadlineState {
            tau,
            planned_eps,
            virtual_elapsed: 0.0,
            limits,
            adv_eps,
            abandoned: vec![false; n],
            cut,
            shed_pkts: Vec::new(),
        }
    }

    /// ε of the advertised usable prefix: the last non-abandoned level's
    /// advertised ε (1.0 when even level 0 was abandoned). The prefix
    /// ends at the first plane-cut level — its missing bitplanes gate
    /// every later rung (mirrored by the receiver's prefix walk).
    fn advertised_eps(&self) -> f64 {
        let mut eps = 1.0;
        for ((gone, level_eps), is_cut) in
            self.abandoned.iter().zip(&self.adv_eps).zip(&self.cut)
        {
            if *gone {
                break;
            }
            eps = *level_eps;
            if *is_cut {
                break;
            }
        }
        eps
    }

    /// Re-solve the deadline plan against the residual budget for the
    /// pending retransmission set `next` (job indices into `jobs`), at
    /// the barrier's λ̂ (priced into `net`, whose `r` is the *actual*
    /// aggregate rate the next pass will be paced at). `burst` is the
    /// smoothed mean loss-run length b̂ (1.0 = i.i.d.); `unreported` the
    /// lost FTGs beyond the wire list's cap, charged as worst-case
    /// groups the budget must still cover in later passes. Mutates the
    /// kept jobs' per-pass parity, drops shed jobs from `next` (marking
    /// them dead in `alive`), queues [`Packet::LevelShed`]
    /// advertisements, and returns the decisions for the pass trace.
    /// Deterministic: every input is a pure function of (config,
    /// dataset, channel seeds).
    #[allow(clippy::too_many_arguments)]
    fn replan(
        &mut self,
        cfg: &PoolConfig,
        net: &NetParams,
        jobs: &mut [FtgJob],
        alive: &mut [bool],
        next: &mut Vec<usize>,
        burst: f64,
        unreported: u64,
    ) -> Vec<ShedDecision> {
        let s = cfg.net.s as u64;
        // Reserve the closing barrier pass (one latency for the empty
        // pass that converges the Done exchange after a shed) and the
        // air time of the lost FTGs the receiver could not fit in the
        // capped wire list — they resurface in later lost lists and
        // cost at most n fragments each. The old reserve instead kept
        // one whole group of ceil-rounding slack: with the exact
        // per-group pricing of [`ResidualSchedule::transmission_time`]
        // there is no fractional-group rounding left to absorb.
        let budget = self.tau
            - self.virtual_elapsed
            - cfg.net.t
            - unreported as f64 * cfg.net.n as f64 / net.r;

        // Pending retransmission set grouped by level, in level order.
        let mut by_level: BTreeMap<u8, Vec<usize>> = BTreeMap::new();
        for &i in next.iter() {
            by_level.entry(jobs[i].level).or_default().push(i);
        }
        if by_level.is_empty() {
            return Vec::new();
        }
        let order: Vec<u8> = by_level.keys().copied().collect();
        let sizes: Vec<u64> = order
            .iter()
            .map(|l| by_level[l].iter().map(|&i| jobs[i].k as u64 * s).sum())
            .collect();
        let res_eps: Vec<f64> = order.iter().map(|&l| self.adv_eps[l as usize]).collect();

        // Remap each level's plane cuts into residual (pending-byte)
        // space: a cut at original offset C keeps the pending jobs with
        // `offset < C`, so its residual cost is their byte mass. Cuts
        // already outside the current advertisement, or collapsing to an
        // empty/full pending set, are dropped; equal kept-masses keep the
        // largest original cut (same retransmission cost, tighter ε).
        let mut res_cuts: Vec<Vec<(PlaneCut, PlaneCut)>> = Vec::with_capacity(order.len());
        for (oi, &l) in order.iter().enumerate() {
            let li = l as usize;
            let prev_eps = if oi == 0 { 1.0 } else { res_eps[oi - 1] };
            let pending = &by_level[&l];
            let mut list: Vec<(PlaneCut, PlaneCut)> = Vec::new();
            for cut in cfg.plane_cuts.get(li).map(|v| v.as_slice()).unwrap_or(&[]) {
                if cut.bytes >= self.limits[li] || cut.eps >= prev_eps || cut.eps <= res_eps[oi]
                {
                    continue;
                }
                let kept: u64 = pending
                    .iter()
                    .filter(|&&i| (jobs[i].offset as u64) < cut.bytes)
                    .map(|&i| jobs[i].k as u64 * s)
                    .sum();
                if kept == 0 || kept >= sizes[oi] {
                    continue;
                }
                let residual = PlaneCut { bytes: kept, eps: cut.eps };
                match list.last_mut() {
                    Some(last) if last.0.bytes == kept => *last = (residual, *cut),
                    _ => list.push((residual, *cut)),
                }
            }
            res_cuts.push(list);
        }

        let mut rsched = LevelSchedule::new(sizes, res_eps);
        if res_cuts.iter().any(|c| !c.is_empty()) {
            let remapped = res_cuts.iter().map(|c| c.iter().map(|p| p.0).collect()).collect();
            rsched = rsched.with_cuts(remapped);
        }
        // Exact residual pricing: the pending groups' data geometry is
        // frozen, so the re-plan charges Σ ceil(bytes_j/s) + G_j·m_j
        // fragments per level — not the fractional Eq. 9 re-derivation,
        // which overcharged ceil slack at the old m0 and undercharged
        // plans that lowered parity.
        let group_counts: Vec<u64> =
            order.iter().map(|l| by_level[l].len() as u64).collect();
        let residual = ResidualSchedule::new(rsched, group_counts);
        let plan = BitplaneDeadlinePlan::replan_residual_exact(net, &residual, budget, burst);
        let (kept_levels, base_m, partial) = match plan {
            Some(p) => (p.base.levels, p.base.m, p.partial),
            None => (0, Vec::new(), None),
        };

        let mut decisions = Vec::new();
        let mut keep: Vec<usize> = Vec::new();
        for (oi, &l) in order.iter().enumerate() {
            let li = l as usize;
            if oi < kept_levels {
                let m_new = base_m[oi].min(255) as u8;
                for &i in &by_level[&l] {
                    jobs[i].m = m_new;
                    keep.push(i);
                }
            } else if partial.as_ref().map_or(false, |(pi, _)| *pi == oi) {
                // Keep the plane-cut prefix of the first excluded level
                // (sent unprotected, matching the §5.2.3 optima).
                let rcut = partial.as_ref().unwrap().1;
                let orig = res_cuts[oi]
                    .iter()
                    .find(|(rc, _)| *rc == rcut)
                    .map(|(_, o)| *o)
                    .expect("residual cut originates from the remap");
                for &i in &by_level[&l] {
                    if (jobs[i].offset as u64) < orig.bytes {
                        jobs[i].m = 0;
                        keep.push(i);
                    } else {
                        alive[i] = false;
                    }
                }
                self.limits[li] = orig.bytes;
                self.adv_eps[li] = orig.eps;
                self.cut[li] = true;
                self.shed_pkts.push(
                    Packet::LevelShed { level: l, bytes: orig.bytes, eps: orig.eps }.encode(),
                );
                decisions.push(ShedDecision { level: l, kept_bytes: orig.bytes, eps: orig.eps });
            } else {
                // The residual budget cannot afford this level at all.
                for &i in &by_level[&l] {
                    alive[i] = false;
                }
                self.abandoned[li] = true;
                self.limits[li] = 0;
                let eps_after = self.advertised_eps();
                self.shed_pkts
                    .push(Packet::LevelShed { level: l, bytes: 0, eps: eps_after }.encode());
                decisions.push(ShedDecision { level: l, kept_bytes: 0, eps: eps_after });
            }
        }
        *next = keep;
        decisions
    }
}

/// Multi-stream parallel transfer engine (see module docs).
#[derive(Debug, Clone)]
pub struct TransferPool {
    cfg: PoolConfig,
}

impl TransferPool {
    pub fn new(cfg: PoolConfig) -> Result<TransferPool> {
        cfg.validate()?;
        Ok(TransferPool { cfg })
    }

    pub fn config(&self) -> &PoolConfig {
        &self.cfg
    }

    /// Run the sender side.
    #[deprecated(note = "use janus::api::Endpoint::send")]
    pub fn run_sender<C, D>(
        &self,
        control: &mut C,
        data: &mut [D],
        levels: &[Vec<u8>],
        eps: &[f64],
    ) -> Result<PoolSenderReport>
    where
        C: Datagram,
        D: Datagram,
    {
        self.pooled_sender(control, data, levels, eps, None)
    }

    /// Pooled sender engine. `control` carries the handshake and pass
    /// exchanges; `data[w]` is stream `w`'s paced endpoint
    /// (`data.len()` must equal `cfg.streams`). Public entry:
    /// [`crate::api::Endpoint::send`].
    pub(crate) fn pooled_sender<C, D>(
        &self,
        control: &mut C,
        data: &mut [D],
        levels: &[Vec<u8>],
        eps: &[f64],
        events: EventSink<'_>,
    ) -> Result<PoolSenderReport>
    where
        C: Datagram,
        D: Datagram,
    {
        let cfg = &self.cfg;
        if levels.len() != eps.len() {
            bail!("pool sender: {} levels but {} epsilons", levels.len(), eps.len());
        }
        if levels.is_empty() {
            bail!("pool sender: dataset has no levels");
        }
        if !cfg.plane_cuts.is_empty() && cfg.plane_cuts.len() != levels.len() {
            bail!(
                "pool sender: {} plane-cut lists for {} levels",
                cfg.plane_cuts.len(),
                levels.len()
            );
        }
        if data.len() != cfg.streams {
            bail!("pool wants {} data channels, got {}", cfg.streams, data.len());
        }
        let start = Instant::now();
        let n = cfg.net.n;
        let s = cfg.net.s;
        let mut sched =
            LevelSchedule::new(levels.iter().map(|l| l.len() as u64).collect(), eps.to_vec());
        if !cfg.plane_cuts.is_empty() {
            sched = sched.with_cuts(cfg.plane_cuts.clone());
        }

        // === Pass-0 plan ===
        // Contract-dependent: how many levels go out, each level's byte
        // limit (a Deadline plan may cap the last at a plane-cut prefix),
        // the advertised ε, and the per-level pass-0 parity m0 — which
        // the manifest carries so the receiver can recompute the exact
        // FTG geometry of groups it never saw.
        let lambda_hat0 = cfg.initial_lambda;
        let mut limits: Vec<usize> = levels.iter().map(|l| l.len()).collect();
        let mut adv_eps: Vec<f64> = eps.to_vec();
        let mut cut_flag: Vec<bool> = vec![false; levels.len()];
        let (send_levels, m0, mut deadline) = match cfg.contract {
            Contract::Fidelity(bound) => {
                let l = sched.levels_for_error_bound(bound).ok_or_else(|| {
                    anyhow!("error bound {bound} unachievable: ε_L = {}", eps[eps.len() - 1])
                })?;
                let m =
                    optimize_parity(&cfg.aggregate_net(lambda_hat0), sched.total_bytes(l).max(1))
                        .m;
                (l, vec![m; l], None)
            }
            Contract::BestEffort => {
                let l = levels.len();
                let m =
                    optimize_parity(&cfg.aggregate_net(lambda_hat0), sched.total_bytes(l).max(1))
                        .m;
                (l, vec![m; l], None)
            }
            Contract::Deadline(tau) => {
                let plan = optimize_deadline_bitplane(&cfg.aggregate_net(lambda_hat0), &sched, tau)
                    .ok_or_else(|| anyhow!("deadline {tau}s infeasible for this schedule"))?;
                let mut m = plan.base.m.clone();
                let mut send = plan.base.levels;
                if let Some((li, cut)) = plan.partial {
                    limits[li] = cut.bytes as usize;
                    adv_eps[li] = cut.eps;
                    cut_flag[li] = true;
                    m.push(0); // the partial level ships unprotected (§5.2.3)
                    send = li + 1;
                }
                let planned_eps = plan.planned_eps(&sched);
                let state = DeadlineState::new(
                    tau,
                    planned_eps,
                    (0..send).map(|i| limits[i].min(levels[i].len()) as u64).collect(),
                    adv_eps[..send].to_vec(),
                    cut_flag[..send].to_vec(),
                );
                (send, m, Some(state))
            }
        };

        // === Handshake ===
        let manifest = Packet::Manifest(Manifest {
            n: n as u8,
            s: s as u32,
            streams: cfg.streams as u8,
            levels: (0..send_levels)
                .map(|i| ManifestLevel {
                    size: limits[i].min(levels[i].len()) as u64,
                    eps: adv_eps[i],
                    m0: m0[i] as u8,
                    cut: cut_flag[i],
                })
                .collect(),
            contract: u8::from(!cfg.contract.retransmits()),
        });
        let mut acked = false;
        for _ in 0..50 {
            control.send(&manifest.encode());
            if let Some(buf) = control.recv_timeout(Duration::from_millis(100)) {
                if matches!(Packet::decode(&buf), Ok(Packet::ManifestAck)) {
                    acked = true;
                    break;
                }
            }
        }
        if !acked {
            bail!("pool receiver did not acknowledge manifest");
        }

        // Fixed per-pass parity keeps the trace deterministic; λ̂
        // feedback adapts the *next* pass (Eq. 8 / Eq. 12 re-solve).
        let mut lambda_hat = lambda_hat0;
        // Adaptive layer, clocked by the same virtual pass time as the
        // deadline debit so every decision is deterministic. The RTT
        // estimator drives only the barrier retry cadence (cold RTO =
        // the legacy 200 ms retry window); the controller moves the
        // per-stream pace between passes; the two-state estimator
        // splits the loss into burst/residual and prices λ̂ at the
        // *actual* pass rate instead of the nominal one.
        let mut controller = RateController::new(cfg.net.r, cfg.adapt);
        let mut estimator = TwoStateEstimator::new(0.5);
        let mut rtt = RttEstimator::new(0.02, 0.2);
        let mut virtual_now = 0.0f64;

        // Shared coding pool: parity compute parallelism beyond the
        // stream count. Output is byte-identical for any worker count
        // (erasure::par determinism contract), so the thread budget is
        // pure tuning — clamped to keep streams + coding threads modest.
        let coding = CodingPool::new(
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(4),
        );

        let mut jobs: Vec<FtgJob> = Vec::new();
        for (li, level) in levels.iter().enumerate().take(send_levels) {
            let limit = limits[li].min(level.len());
            let mut offset = 0usize;
            let mut ftg = 0u32;
            while offset < limit {
                let remaining = limit - offset;
                let k = (n - m0[li]).min(remaining.div_ceil(s)).max(1);
                jobs.push(FtgJob { level: li as u8, ftg, offset, k, m: m0[li] as u8 });
                offset += k * s;
                ftg += 1;
            }
        }
        let data_fragments: u64 = jobs.iter().map(|j| j.k as u64).sum();
        // Jobs shed at a barrier stay dead even if a stale lost list
        // mentions them again.
        let mut alive = vec![true; jobs.len()];

        let mut report = PoolSenderReport {
            fragments_sent: 0,
            data_fragments,
            passes: 0,
            duration: 0.0,
            trace: Vec::new(),
            lambda_history: Vec::new(),
            rate_history: Vec::new(),
            deadline: None,
        };

        // Per-stream wire sequence numbers, monotone across passes.
        let mut seqs = vec![0u64; cfg.streams];
        // Jobs (indices) to transmit this pass; pass 0 sends everything.
        let mut todo: Vec<usize> = (0..jobs.len()).collect();
        let mut pass = 0u32;

        loop {
            if start.elapsed() > cfg.max_duration {
                bail!("pool sender exceeded max duration");
            }
            // The pass's representative parity: uniform for retransmitting
            // contracts, the per-level maximum under a Deadline plan.
            let pass_m: usize = todo.iter().map(|&i| jobs[i].m as usize).max().unwrap_or(0);
            emit(events, TransferEvent::PassStarted { pass });
            emit(events, TransferEvent::ParityAdapted { pass, m: pass_m });
            // Deterministic shard: round-robin over the pass's job list.
            let shards: Vec<Vec<usize>> = (0..cfg.streams)
                .map(|w| todo.iter().copied().skip(w).step_by(cfg.streams).collect())
                .collect();

            // === Fan out: one worker per stream, own channel + encoder ===
            // Paced at the controller's current per-stream rate (the
            // configured `r` until a barrier verdict moves it).
            let pace_rate = controller.rate();
            let pace = Duration::from_secs_f64(1.0 / pace_rate);
            let net = cfg.net;
            let jobs_ref = &jobs;
            let coding_ref = &coding;
            let sent_counts: Vec<u64> = std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(cfg.streams);
                for (w, chan) in data.iter_mut().enumerate() {
                    let shard = &shards[w];
                    let seq0 = seqs[w];
                    handles.push(scope.spawn(move || {
                        send_shard(
                            chan, w as u8, pass, shard, jobs_ref, levels, &net, pace, seq0,
                            coding_ref, events,
                        )
                    }));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("pool worker panicked"))
                    .collect()
            });
            let per_stream = sent_counts; // moved, not cloned (ISSUE 3)
            let pass_sent: u64 = per_stream.iter().sum();
            for (w, &c) in per_stream.iter().enumerate() {
                seqs[w] += c;
            }
            report.fragments_sent += pass_sent;

            // === Barrier: end-of-pass exchange on the control channel ===
            let mut stats: Option<(u64, u64, u32, u64)> = None;
            let mut lost: Option<(u32, Vec<(u8, u32)>)> = None;
            let mut finished = false;
            'exchange: for _ in 0..200 {
                // Re-advertise pending sheds ahead of the barrier: the
                // receiver must price lost FTGs against the *current*
                // manifest, and LevelShed datagrams are idempotent.
                if let Some(dl) = &deadline {
                    for pkt in &dl.shed_pkts {
                        control.send(pkt);
                    }
                }
                let eop_sent = Instant::now();
                control.send(&Packet::EndOfPass { pass }.encode());
                // Retry cadence from the RTT estimator: the idempotent
                // exchange re-sends after one RTO instead of a fixed
                // 200 ms (which the cold estimator reproduces).
                let wait_until = eop_sent + Duration::from_secs_f64(rtt.rto());
                while Instant::now() < wait_until {
                    let buf = match control.recv_timeout(Duration::from_millis(50)) {
                        Some(b) => b,
                        None => break,
                    };
                    match Packet::decode(&buf) {
                        Ok(Packet::PassStats { pass: p, expected, received, runs, burst_lost })
                            if p == pass =>
                        {
                            rtt.observe(eop_sent.elapsed().as_secs_f64());
                            stats = Some((expected, received, runs, burst_lost));
                        }
                        Ok(Packet::LostList { pass: p, total, ftgs }) if p == pass => {
                            lost = Some((total, ftgs));
                        }
                        Ok(Packet::Done) => {
                            finished = true;
                        }
                        _ => {}
                    }
                    if (stats.is_some() && lost.is_some()) || finished {
                        // Done is terminal: the receiver certified
                        // completion and may already be gone — never spin
                        // the retry budget waiting for dropped stats.
                        break 'exchange;
                    }
                }
                if start.elapsed() > cfg.max_duration {
                    bail!("pool sender timed out awaiting pass {pass} feedback");
                }
            }
            let (expected, received, runs, burst_lost, lost_total, lost) =
                if finished && (stats.is_none() || lost.is_none()) {
                    // A completed transfer whose PassStats/LostList
                    // datagrams were dropped: synthesize the final trace
                    // record instead of aborting on "no PassStats".
                    let (e, r, ru, bl) = stats.unwrap_or((0, 0, 0, 0));
                    (e, r, ru, bl, 0u32, Vec::new())
                } else {
                    let (e, r, ru, bl) = stats
                        .ok_or_else(|| anyhow!("no PassStats for pass {pass} (receiver gone?)"))?;
                    let (t, l) = lost.ok_or_else(|| anyhow!("no LostList for pass {pass}"))?;
                    (e, r, ru, bl, t, l)
                };

            // === Virtual-clock debit: Eq. 9 for the pass — aggregate
            // air time over the rate the pass was *actually* paced at,
            // plus one-way latency. Deterministic (a pure function of
            // the fragment counts and the controller's virtual-time
            // decisions, unlike wall time) and priced like the Eq. 12
            // solves that planned the pass. ===
            let pass_rate_agg = pace_rate * cfg.streams as f64;
            let pass_secs = cfg.net.t + pass_sent as f64 / pass_rate_agg;
            virtual_now += pass_secs;
            if let Some(dl) = deadline.as_mut() {
                dl.virtual_elapsed += pass_secs;
            }

            // === Shared λ̂ update (kept when no fresh statistics came).
            // The loss fraction is priced at the pass's actual aggregate
            // rate: the old `loss_frac · N·r_nominal` overestimated λ̂
            // whenever the pacer had backed off, double-counting the
            // very loss the back-off was answering. ===
            let obs = PassObservation {
                elapsed: pass_secs,
                offered: expected,
                received,
                runs,
                burst_lost,
                rate: pass_rate_agg,
            };
            let loss_frac = obs.loss_frac();
            if !finished || expected > 0 {
                estimator.observe_pass(&obs);
                lambda_hat = loss_frac * pass_rate_agg;
            }

            // === Pass verdict: congestion backs the rate off, burst-
            // shaped channel loss sustains it and codes harder. ===
            let verdict = controller.on_pass(virtual_now, loss_frac, obs.burst_len());
            if let PassVerdict::Congestion { residual_loss } = verdict {
                // Loss the next (backed-off) pass still expects from the
                // policer — the channel-noise part the parity must cover.
                lambda_hat = residual_loss * controller.rate() * cfg.streams as f64;
            }
            let burst = if cfg.adapt.burst_aware { estimator.burst_len() } else { 1.0 };
            report.lambda_history.push(lambda_hat);
            report.rate_history.push(controller.rate());
            emit(events, TransferEvent::LambdaUpdated { lambda: lambda_hat });
            // Emitted before the next pass fans out, so an observer
            // driving a live channel (the congestion testkit) applies
            // the new rate deterministically at the pass boundary.
            emit(
                events,
                TransferEvent::RateAdapted {
                    pass,
                    rate: controller.rate(),
                    backoff: controller.rate() < controller.r_max(),
                },
            );

            // === Next pass: map lost ids to jobs, re-solve, shed ===
            // Solvers see λ̂ *and* the rate the next pass will actually
            // run at (λ·n/r is the regime selector — pricing λ̂ at the
            // actual rate but r at nominal would skew every solve).
            let solver_net =
                NetParams { lambda: lambda_hat, r: controller.rate() * cfg.streams as f64, ..cfg.net };
            let mut shed: Vec<ShedDecision> = Vec::new();
            let mut next: Vec<usize> = Vec::new();
            if !finished && !lost.is_empty() {
                let index: HashMap<(u8, u32), usize> = jobs
                    .iter()
                    .enumerate()
                    .map(|(i, j)| ((j.level, j.ftg), i))
                    .collect();
                for key in &lost {
                    match index.get(key) {
                        Some(&i) => {
                            if alive[i] {
                                next.push(i);
                            }
                        }
                        None => bail!("receiver reported unknown FTG {key:?}"),
                    }
                }
                let unreported = lost_total.saturating_sub(lost.len() as u32) as u64;
                if let Some(dl) = deadline.as_mut() {
                    // Pass-barrier τ accounting: price the pending set
                    // under the fresh λ̂ against the residual budget and
                    // shed what no longer fits (exact-geometry Eq. 12
                    // re-solve, burst-aware under a burst verdict).
                    // Under a congestion verdict the pacing rate is not
                    // the delivery rate: a policer of capacity c drops
                    // everything above c no matter how fast we send, so
                    // the τ budget must price residual air time at
                    // min(rate, ĉ) or the re-plan keeps levels the path
                    // cannot actually carry and the deadline is missed.
                    let cap_net = match controller.capacity_estimate() {
                        Some(cap) => NetParams {
                            r: solver_net.r.min(cap * cfg.streams as f64),
                            ..solver_net
                        },
                        None => solver_net,
                    };
                    shed = dl.replan(cfg, &cap_net, &mut jobs, &mut alive, &mut next, burst, unreported);
                } else {
                    let lost_bytes: u64 =
                        next.iter().map(|&i| jobs[i].k as u64 * s as u64).sum();
                    // Under a burst verdict Eq. 8's optimum sits at the
                    // start of a survivability plateau (see
                    // `parity_floor_bursty`): clamp the solve so the
                    // per-pass group-failure residual is contracted and
                    // the lost list drains geometrically.
                    let m_new = if matches!(verdict, PassVerdict::Burst { .. }) && burst > 1.0 {
                        optimize_parity_bursty(&solver_net, lost_bytes.max(1), burst)
                            .m
                            .max(parity_floor_bursty(&solver_net, burst, 0.05))
                    } else {
                        optimize_parity(&solver_net, lost_bytes.max(1)).m
                    };
                    for &i in &next {
                        jobs[i].m = m_new as u8;
                    }
                }
            }
            report.trace.push(PassRecord {
                pass,
                m: pass_m,
                ftgs: todo.len() as u64,
                fragments: pass_sent,
                per_stream,
                lambda_hat,
                rate: pace_rate,
                burst: estimator.burst_len(),
                lost_ftgs: lost.len() as u64,
                shed: shed.clone(),
            });
            for d in &shed {
                emit(
                    events,
                    TransferEvent::LevelShed {
                        pass,
                        level: d.level,
                        kept_bytes: d.kept_bytes,
                        eps: d.eps,
                    },
                );
            }

            if finished || lost.is_empty() {
                break;
            }
            todo = next;
            pass += 1;
            report.passes = pass;
            if pass > 10_000 {
                bail!("pool retransmission did not converge");
            }
        }

        if let Some(dl) = &deadline {
            // Eq. 12 prices *fractional* group counts; the wire sends
            // whole groups, so a plan that exactly saturates τ can land
            // the virtual clock up to one data fragment plus m0 parity
            // fragments per level above the fractional cost. Allow that
            // deterministic rounding before calling τ missed (the
            // replans' retransmission passes carry their own reserve).
            let rounding = (send_levels + m0.iter().sum::<usize>() + 2) as f64
                / (cfg.net.r * cfg.streams as f64);
            report.deadline = Some(DeadlineOutcome {
                tau: dl.tau,
                virtual_elapsed: dl.virtual_elapsed,
                met: dl.virtual_elapsed <= dl.tau + rounding,
                planned_eps: dl.planned_eps,
                advertised_eps: dl.advertised_eps(),
            });
        }
        report.duration = start.elapsed().as_secs_f64();
        Ok(report)
    }

    /// Run the receiver side.
    #[deprecated(note = "use janus::api::Endpoint::receive")]
    pub fn run_receiver<C, D>(
        control: &mut C,
        data: Vec<D>,
        rcfg: &ReceiverConfig,
    ) -> Result<PoolReceiverReport>
    where
        C: Datagram,
        D: Datagram + Send,
    {
        Self::pooled_receiver(control, data, rcfg, None)
    }

    /// Pooled receiver engine: demultiplex `data` endpoints by stream id
    /// into one shared reassembly table, answer pass barriers with
    /// aggregate loss statistics, and reconstruct the levels on `Done`.
    /// Public entry: [`crate::api::Endpoint::receive`].
    pub(crate) fn pooled_receiver<C, D>(
        control: &mut C,
        data: Vec<D>,
        rcfg: &ReceiverConfig,
        events: EventSink<'_>,
    ) -> Result<PoolReceiverReport>
    where
        C: Datagram,
        D: Datagram + Send,
    {
        let start = Instant::now();

        // === Handshake ===
        // Mutable: Deadline senders shrink level advertisements mid-
        // transfer via [`Packet::LevelShed`].
        let mut manifest: Manifest = loop {
            if start.elapsed() > rcfg.max_duration {
                bail!("pool receiver: no manifest");
            }
            match control.recv_timeout(rcfg.idle_timeout) {
                Some(buf) => match Packet::decode(&buf) {
                    Ok(Packet::Manifest(m)) => {
                        control.send(&Packet::ManifestAck.encode());
                        break m;
                    }
                    _ => continue,
                },
                None => bail!("pool receiver: timed out waiting for manifest"),
            }
        };
        let streams = manifest.streams as usize;
        if data.len() != streams {
            bail!("manifest announces {streams} streams, receiver has {}", data.len());
        }
        let s = manifest.s as usize;
        super::packet::validate_fragment_size(s)?;
        if manifest.n < 2 {
            bail!("manifest group size n={} is malformed", manifest.n);
        }
        for (li, entry) in manifest.levels.iter().enumerate() {
            if entry.m0 >= manifest.n {
                bail!("manifest level {li} claims m0={} >= n={}", entry.m0, manifest.n);
            }
        }
        let num_levels = manifest.levels.len();
        // Levels the sender abandoned at a pass barrier (never usable,
        // as opposed to shrunk to a plane-cut prefix).
        let mut abandoned = vec![false; num_levels];

        let mut report = PoolReceiverReport {
            levels: vec![None; num_levels],
            levels_recovered: 0,
            achieved_eps: 1.0,
            fragments_received: 0,
            groups_recovered: 0,
            duration: 0.0,
            trace: Vec::new(),
        };

        let mut groups: HashMap<(u8, u32), FtgArena> = HashMap::new();
        // Per-pass statistics: announced (per stream) and received counts.
        let mut announced: HashMap<u32, HashMap<u8, u64>> = HashMap::new();
        let mut received_in_pass: HashMap<u32, u64> = HashMap::new();
        // Loss-run accounting for the burst estimator: per-stream wire
        // sequences are monotone across passes, so a fragment arriving
        // with seq above the stream's expectation is one contiguous loss
        // run (length = gap). Runs of length ≥ 2 also accumulate into
        // `burst_lost` so the sender can split λ̂ into burst/residual
        // components. Tail losses (fragments after a stream's last
        // arrival) are charged at the pass barrier from the announced
        // counts.
        let mut next_seq: HashMap<u8, u64> = HashMap::new();
        let mut cum_announced: HashMap<u8, u64> = HashMap::new();
        let mut pass_runs: HashMap<u32, u32> = HashMap::new();
        let mut pass_burst_lost: HashMap<u32, u64> = HashMap::new();
        // Cached reply to the last finalized pass, pre-encoded once:
        // duplicate EndOfPass retries must get byte-identical answers
        // even after later fragments arrive, and resending reuses the
        // same wire bytes instead of re-cloning the lost list
        // (pass, stats datagram, lost-list datagram, lost-list empty).
        let mut last_reply: Option<(u32, Vec<u8>, Vec<u8>, bool)> = None;
        // An EndOfPass that arrived before every stream's marker did —
        // finalized the moment the last marker drains from the fan-in.
        let mut pending_end: Option<u32> = None;

        // === Demux fan-in: one reader thread per data endpoint ===
        // Readers receive into pooled frames (recycled on drop) and hand
        // them over on a condvar FrameQueue, so the steady-state fan-in
        // allocates nothing per datagram (mpsc would allocate a block
        // per batch of messages).
        let frames = FramePool::new();
        let shutdown = AtomicBool::new(false);
        let fan = FrameQueue::new();
        let done = std::thread::scope(|scope| -> Result<()> {
            for mut chan in data {
                let stop = &shutdown;
                let pool = Arc::clone(&frames);
                let q = Arc::clone(&fan);
                scope.spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let mut frame = pool.lease();
                        match chan.recv_into(frame.buf_mut(), Duration::from_millis(50)) {
                            Some(n) => {
                                frame.set_len(n);
                                q.push(frame);
                            }
                            None => {} // timeout: frame drops back into the pool
                        }
                    }
                });
            }

            // Answer an end-of-pass barrier whose stream markers have all
            // arrived. Returns true when the transfer is complete.
            // Idempotent: a duplicate EndOfPass resends the cached reply;
            // passes older than the cache are ignored. The manifest is a
            // parameter (not a capture) because LevelShed advertisements
            // mutate it between barriers.
            #[allow(clippy::too_many_arguments)]
            let finalize = |pass: u32,
                                control: &mut C,
                                manifest: &Manifest,
                                groups: &HashMap<(u8, u32), FtgArena>,
                                announced: &HashMap<u32, HashMap<u8, u64>>,
                                received_in_pass: &HashMap<u32, u64>,
                                pass_runs: &HashMap<u32, u32>,
                                pass_burst_lost: &HashMap<u32, u64>,
                                last_reply: &mut Option<(u32, Vec<u8>, Vec<u8>, bool)>,
                                report: &mut PoolReceiverReport|
             -> bool {
                if let Some((p, stats_buf, lost_buf, lost_empty)) = last_reply.as_ref() {
                    if pass < *p {
                        return false; // stale retry of an older pass
                    }
                    if pass == *p {
                        // Resend the pre-encoded reply bytes verbatim.
                        control.send(stats_buf);
                        control.send(lost_buf);
                        if *lost_empty {
                            control.send(&Packet::Done.encode());
                            return true;
                        }
                        return false;
                    }
                }
                let expected: u64 = announced[&pass].values().sum();
                let received = *received_in_pass.get(&pass).unwrap_or(&0);
                let lost = collect_lost(manifest, groups, s);
                report.trace.push(RecvPassRecord {
                    pass,
                    expected,
                    received,
                    lost_ftgs: lost.len() as u64,
                });
                // Cap the wire list to one datagram; the tail is simply
                // re-reported on the next pass (nonempty ⇒ capped
                // nonempty, so the Done decision is unaffected). `total`
                // carries the true count so the sender can price the
                // unreported tail when re-planning. Encoded once per
                // pass — retries reuse the bytes.
                let total = lost.len() as u32;
                let wire: Vec<(u8, u32)> =
                    lost.iter().take(MAX_LOST_PER_MSG).copied().collect();
                let lost_empty = lost.is_empty();
                let stats_buf = Packet::PassStats {
                    pass,
                    expected,
                    received,
                    runs: *pass_runs.get(&pass).unwrap_or(&0),
                    burst_lost: *pass_burst_lost.get(&pass).unwrap_or(&0),
                }
                .encode();
                let lost_buf = Packet::LostList { pass, total, ftgs: wire }.encode();
                control.send(&stats_buf);
                control.send(&lost_buf);
                *last_reply = Some((pass, stats_buf, lost_buf, lost_empty));
                if lost_empty {
                    control.send(&Packet::Done.encode());
                    return true;
                }
                false
            };

            let marker_complete = |announced: &HashMap<u32, HashMap<u8, u64>>, pass: u32| {
                announced.get(&pass).map_or(false, |e| e.len() >= streams)
            };

            let mut last_packet = Instant::now();
            let mut ctl_buf = vec![0u8; MAX_DATAGRAM];
            let result = 'pump: loop {
                if start.elapsed() > rcfg.max_duration {
                    break Err(anyhow!("pool receiver exceeded max duration"));
                }
                if last_packet.elapsed() > rcfg.idle_timeout {
                    break Err(anyhow!("pool receiver: sender went silent"));
                }
                // Control plane (cheap nonblocking poll): note the barrier
                // request; it is answered only once every stream's marker
                // has drained from the fan-in, because per-channel FIFO
                // then guarantees all surviving fragments of the pass are
                // already in `groups`. Shed advertisements precede the
                // barrier they apply to (control is FIFO), so a barrier
                // is always priced against the current manifest.
                while let Some(n) = control.try_recv_into(&mut ctl_buf) {
                    last_packet = Instant::now();
                    match Packet::decode(&ctl_buf[..n]) {
                        Ok(Packet::EndOfPass { pass }) => {
                            pending_end = Some(pass);
                        }
                        Ok(Packet::LevelShed { level, bytes, eps }) => {
                            let li = level as usize;
                            if li < manifest.levels.len() {
                                let entry = &mut manifest.levels[li];
                                if bytes == 0 {
                                    entry.size = 0;
                                    abandoned[li] = true;
                                } else if bytes < entry.size {
                                    entry.size = bytes;
                                    entry.eps = eps;
                                    entry.cut = true;
                                }
                            }
                        }
                        _ => {}
                    }
                }
                if let Some(pass) = pending_end {
                    if marker_complete(&announced, pass) {
                        pending_end = None;
                        // Tail-loss accounting, once per pass (retries hit
                        // the cached reply): any announced fragments past a
                        // stream's highest arrival are one trailing loss
                        // run. Map order is irrelevant — per-stream
                        // contributions commute into the pass totals.
                        if last_reply.as_ref().map_or(true, |(p, ..)| pass > *p) {
                            if let Some(per_stream) = announced.get(&pass) {
                                for (st, &sent) in per_stream {
                                    let cum = cum_announced.entry(*st).or_insert(0);
                                    *cum += sent;
                                    let seen = next_seq.get(st).copied().unwrap_or(0);
                                    if *cum > seen {
                                        let gap = *cum - seen;
                                        *pass_runs.entry(pass).or_insert(0) += 1;
                                        if gap >= 2 {
                                            *pass_burst_lost.entry(pass).or_insert(0) += gap;
                                        }
                                        next_seq.insert(*st, *cum);
                                    }
                                }
                            }
                        }
                        if finalize(
                            pass,
                            control,
                            &manifest,
                            &groups,
                            &announced,
                            &received_in_pass,
                            &pass_runs,
                            &pass_burst_lost,
                            &mut last_reply,
                            &mut report,
                        ) {
                            break 'pump Ok(());
                        }
                    }
                }
                // Data plane: fragments + stream-end markers. Frames are
                // decoded in place (borrowing view) and recycled on drop.
                match fan.pop_timeout(Duration::from_millis(2)) {
                    Some(frame) => {
                        last_packet = Instant::now();
                        match PacketView::decode(&frame) {
                            Ok(PacketView::Fragment(view)) => {
                                let h = view.header;
                                report.fragments_received += 1;
                                *received_in_pass.entry(h.pass).or_insert(0) += 1;
                                // Loss-run detection on the stream's
                                // monotone wire sequence. Reordering
                                // within a channel cannot happen (FIFO
                                // transports), so a gap is a genuine
                                // contiguous drop; the run is charged to
                                // the pass whose fragment exposed it.
                                let exp = next_seq.get(&h.stream).copied().unwrap_or(0);
                                if h.seq > exp {
                                    let gap = h.seq - exp;
                                    *pass_runs.entry(h.pass).or_insert(0) += 1;
                                    if gap >= 2 {
                                        *pass_burst_lost.entry(h.pass).or_insert(0) += gap;
                                    }
                                }
                                if h.seq >= exp {
                                    next_seq.insert(h.stream, h.seq + 1);
                                }
                                let g = groups
                                    .entry((h.level, h.ftg))
                                    .or_insert_with(|| FtgArena::new(h.k, h.m, s));
                                g.insert(h.index as usize, view.payload);
                            }
                            Ok(PacketView::Control(Packet::StreamEnd {
                                stream,
                                pass,
                                sent,
                            })) => {
                                announced.entry(pass).or_default().insert(stream, sent);
                            }
                            _ => {}
                        }
                    }
                    None => {} // poll timeout
                }
            };
            shutdown.store(true, Ordering::Relaxed);
            result
        });
        shutdown.store(true, Ordering::Relaxed);
        done?;

        // === Reconstruct levels (shared group table) ===
        reconstruct_levels(&manifest, &groups, s, &abandoned, &mut report, events)?;
        report.duration = start.elapsed().as_secs_f64();
        Ok(report)
    }

    /// Convenience harness: run a full pool transfer in threads.
    #[deprecated(note = "use janus::api::run_pair")]
    #[allow(clippy::type_complexity)]
    pub fn run_session<C, DS, DR>(
        &self,
        sender_control: &mut C,
        sender_data: Vec<DS>,
        receiver_control: &mut C,
        receiver_data: Vec<DR>,
        rcfg: &ReceiverConfig,
        levels: &[Vec<u8>],
        eps: &[f64],
    ) -> Result<(PoolSenderReport, PoolReceiverReport)>
    where
        C: Datagram,
        DS: Datagram,
        DR: Datagram + Send,
    {
        self.pooled_session(sender_control, sender_data, receiver_control, receiver_data, rcfg, levels, eps)
    }

    /// Session engine: run a full pool transfer across connected channel
    /// sets in threads and collect both reports.
    #[allow(clippy::type_complexity)]
    pub(crate) fn pooled_session<C, DS, DR>(
        &self,
        sender_control: &mut C,
        mut sender_data: Vec<DS>,
        receiver_control: &mut C,
        receiver_data: Vec<DR>,
        rcfg: &ReceiverConfig,
        levels: &[Vec<u8>],
        eps: &[f64],
    ) -> Result<(PoolSenderReport, PoolReceiverReport)>
    where
        C: Datagram,
        DS: Datagram,
        DR: Datagram + Send,
    {
        std::thread::scope(|scope| {
            let recv = scope
                .spawn(move || Self::pooled_receiver(receiver_control, receiver_data, rcfg, None));
            let send_report =
                self.pooled_sender(sender_control, &mut sender_data, levels, eps, None)?;
            let recv_report = recv
                .join()
                .map_err(|_| anyhow!("pool receiver thread panicked"))??;
            Ok((send_report, recv_report))
        })
    }
}

/// Groups a worker encodes ahead of pacing them out: deep enough to
/// amortize the coding-pool handoff, shallow enough that the look-ahead
/// working set (`ENC_BATCH · (k+m) · s` bytes) stays cache-friendly.
const ENC_BATCH: usize = 4;

/// Worker body: RS-encode and pace this stream's share of the pass.
/// Parity is per-job (`FtgJob::m`), set by the pass's plan. Runs of
/// same-geometry jobs are encoded as one [`RsCode::encode_batch`] on the
/// shared coding pool, then paced out strictly in job order — wire
/// bytes and sequence numbers are identical to the old
/// one-group-at-a-time loop. Returns the number of fragments sent.
#[allow(clippy::too_many_arguments)]
fn send_shard<D: Datagram>(
    chan: &mut D,
    stream: u8,
    pass: u32,
    shard: &[usize],
    jobs: &[FtgJob],
    levels: &[Vec<u8>],
    net: &NetParams,
    pace: Duration,
    seq0: u64,
    coding: &CodingPool,
    events: EventSink<'_>,
) -> u64 {
    let s = net.s;
    let mut codes: HashMap<(usize, usize), RsCode> = HashMap::new();
    let mut out = Vec::with_capacity(s + 64);
    // A ring of strided arenas reused across the shard's FTGs: the
    // worker's steady state allocates nothing per group (buffers only
    // regrow when (k+m)·s grows).
    let mut arenas: Vec<FtgArena> = (0..ENC_BATCH).map(|_| FtgArena::new(0, 0, s)).collect();
    let mut seq = seq0;
    let mut next_send = Instant::now();
    let mut i = 0usize;
    while i < shard.len() {
        let job0 = jobs[shard[i]];
        // The fragment index is a u8: parity never pushes k + m past 255.
        let m_eff = (job0.m as usize).min(255usize.saturating_sub(job0.k));
        // Extend the batch across consecutive jobs sharing (k, m_eff):
        // one RsCode, one pool dispatch.
        let mut batch = 1usize;
        while batch < ENC_BATCH && i + batch < shard.len() {
            let next = jobs[shard[i + batch]];
            let next_m = (next.m as usize).min(255usize.saturating_sub(next.k));
            if next.k != job0.k || next_m != m_eff {
                break;
            }
            batch += 1;
        }
        for (b, arena) in arenas.iter_mut().enumerate().take(batch) {
            let job = jobs[shard[i + b]];
            arena.reset(job.k as u8, m_eff as u8, s);
            arena.fill_data(&levels[job.level as usize], job.offset);
        }
        let code = codes
            .entry((job0.k, m_eff))
            .or_insert_with(|| RsCode::new(job0.k, m_eff).expect("valid k,m"));
        code.encode_batch(coding, &mut arenas[..batch]).expect("encode");
        for (b, arena) in arenas.iter().enumerate().take(batch) {
            let job = jobs[shard[i + b]];
            for idx in 0..arena.slots() {
                let hdr = FragmentHeader {
                    level: job.level,
                    stream,
                    ftg: job.ftg,
                    index: idx as u8,
                    k: job.k as u8,
                    m: m_eff as u8,
                    seq,
                    pass,
                };
                seq += 1;
                encode_fragment_into(&hdr, arena.slot(idx), &mut out);
                pace_until(next_send);
                next_send = Instant::now().max(next_send) + pace;
                chan.send(&out);
            }
        }
        i += batch;
    }
    let sent = seq - seq0;
    // Announce this stream's pass total on the data path (FIFO after the
    // fragments); duplicated for robustness on real lossy transports.
    let end = Packet::StreamEnd { stream, pass, sent }.encode();
    for _ in 0..3 {
        chan.send(&end);
    }
    emit(events, TransferEvent::StreamFinished { stream, pass, fragments: sent });
    sent
}

/// FTGs (per manifest byte accounting) that cannot currently be decoded.
/// (Reassembly state lives in [`FtgArena`]s — one strided allocation per
/// group with a presence bitmap, growing when later passes raise m.)
///
/// Never-seen FTGs are strided by the *pass-0 planner geometry*: the
/// manifest carries each level's pass-0 parity `m0`, so every group but
/// the level tail covers exactly `(n − m0)·s` bytes. (The old worst-case
/// `n·s` stride under-enumerated whole-level first-pass loss — the
/// receiver then wasted retransmission passes re-discovering the tail
/// as earlier groups arrived.)
fn collect_lost(
    manifest: &Manifest,
    groups: &HashMap<(u8, u32), FtgArena>,
    s: usize,
) -> Vec<(u8, u32)> {
    let n = manifest.n as usize;
    let mut lost = Vec::new();
    for (li, entry) in manifest.levels.iter().enumerate() {
        let size = entry.size; // shrinks when the sender sheds a level
        let k0 = n.saturating_sub(entry.m0 as usize).max(1) as u64;
        let mut covered = 0u64;
        let mut ftg = 0u32;
        while covered < size {
            match groups.get(&(li as u8, ftg)) {
                Some(g) => {
                    if !g.decodable() {
                        lost.push((li as u8, ftg));
                    }
                    covered += g.k() as u64 * s as u64;
                }
                None => {
                    // Never seen: unrecoverable by definition; recompute
                    // the planner's k for this group.
                    lost.push((li as u8, ftg));
                    let remaining = size - covered;
                    let k = k0.min(remaining.div_ceil(s as u64)).max(1);
                    covered += k * s as u64;
                }
            }
            ftg += 1;
        }
    }
    lost
}

/// Decode-worker budget for the pooled receiver's batched RS recovery:
/// `JANUS_POOL_DECODE_WORKERS` overrides (0 = caller-drains, still
/// correct), else the same modest clamp the sender's encode pool uses.
fn decode_workers() -> usize {
    match std::env::var("JANUS_POOL_DECODE_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        Some(w) => w.min(64),
        None => std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(4),
    }
}

/// One RS-decodable group queued for the batched recovery phase of
/// [`reconstruct_levels`], remembering where its `k·s` bytes land in
/// the level buffer.
struct DecodeJobItem<'a> {
    level: usize,
    ftg: u32,
    start: usize,
    k: u8,
    m_seen: u8,
    arena: &'a FtgArena,
    buf: Vec<u8>,
    ok: bool,
}

/// Rebuild the exact level bytes from the shared group table. Levels the
/// sender abandoned (`abandoned[li]`) stay `None`; levels shed to a
/// plane-cut prefix reconstruct up to their (shrunken) advertised size.
///
/// Recovery is batched: a first sequential walk copies complete groups
/// and queues every decodable one, then same-geometry runs fan across a
/// [`CodingPool`] via [`RsCode::reconstruct_batch`]. Delivered bytes,
/// `GroupRecovered` event order and `groups_recovered` are byte-for-byte
/// identical to the old one-group-at-a-time loop for any worker count
/// (the erasure::par determinism contract; asserted by
/// `tests/pool_e2e.rs`).
fn reconstruct_levels(
    manifest: &Manifest,
    groups: &HashMap<(u8, u32), FtgArena>,
    s: usize,
    abandoned: &[bool],
    report: &mut PoolReceiverReport,
    events: EventSink<'_>,
) -> Result<()> {
    let num_levels = manifest.levels.len();
    // === Phase 1: sequential layout walk ===
    // Complete groups are copied straight into the level buffer;
    // decodable groups reserve their range (zero-filled) and join the
    // batch. A missing/undecodable group ends the level's walk exactly
    // where the sequential loop stopped.
    let mut outs: Vec<Option<Vec<u8>>> = (0..num_levels).map(|_| None).collect();
    let mut walk_ok = vec![false; num_levels];
    let mut pending: Vec<DecodeJobItem<'_>> = Vec::new();
    for (li, entry) in manifest.levels.iter().enumerate() {
        if abandoned[li] {
            continue; // stays None: no usable prefix of this level
        }
        let size = entry.size;
        let mut out = Vec::with_capacity(size as usize);
        let mut ok = true;
        let mut ftg = 0u32;
        while (out.len() as u64) < size {
            match groups.get(&(li as u8, ftg)) {
                Some(g) if g.data_complete() => {
                    for i in 0..g.k() as usize {
                        out.extend_from_slice(g.slot(i));
                    }
                }
                Some(g) if g.decodable() => {
                    let k = g.k();
                    let m_seen = (g.slots() - k as usize) as u8;
                    pending.push(DecodeJobItem {
                        level: li,
                        ftg,
                        start: out.len(),
                        k,
                        m_seen,
                        arena: g,
                        buf: vec![0u8; k as usize * s],
                        ok: false,
                    });
                    out.resize(out.len() + k as usize * s, 0);
                }
                _ => {
                    ok = false;
                    break;
                }
            }
            ftg += 1;
        }
        walk_ok[li] = ok;
        outs[li] = Some(out);
    }

    // === Phase 2: batched Reed–Solomon recovery ===
    // Stable-sort by geometry so each `(k, m_seen)` run shares one
    // survivor-pattern matrix cache family and one batch submission.
    if !pending.is_empty() {
        let pool = CodingPool::new(decode_workers());
        let mut codes: HashMap<(u8, u8), RsCode> = HashMap::new();
        pending.sort_by_key(|it| (it.k, it.m_seen));
        let mut rest: &mut [DecodeJobItem<'_>] = &mut pending;
        while !rest.is_empty() {
            let geom = (rest[0].k, rest[0].m_seen);
            let len = rest.iter().take_while(|it| (it.k, it.m_seen) == geom).count();
            let (run, tail) = rest.split_at_mut(len);
            rest = tail;
            let code = codes.entry(geom).or_insert_with(|| {
                RsCode::new(geom.0 as usize, geom.1 as usize).expect("valid k,m")
            });
            let mut items: Vec<(&FtgArena, &mut [u8])> =
                run.iter_mut().map(|it| (it.arena, it.buf.as_mut_slice())).collect();
            let results = code.reconstruct_batch(&pool, &mut items);
            drop(items);
            for (it, res) in run.iter_mut().zip(results) {
                it.ok = res.is_ok();
            }
        }
        pending.sort_by_key(|it| (it.level, it.ftg)); // restore walk order
    }

    // === Phase 3: sequential stitch ===
    // Events and `groups_recovered` replay the old loop exactly: within
    // a level, decoded groups are announced in ftg order up to the first
    // failure; a failed decode (like a failed walk) leaves the level
    // `None`.
    let mut idx = 0usize;
    for li in 0..num_levels {
        let Some(mut out) = outs[li].take() else { continue };
        let mut failed = false;
        while idx < pending.len() && pending[idx].level == li {
            let it = &pending[idx];
            idx += 1;
            if failed {
                continue;
            }
            if it.ok {
                out[it.start..it.start + it.buf.len()].copy_from_slice(&it.buf);
                report.groups_recovered += 1;
                emit(events, TransferEvent::GroupRecovered { level: li as u8, ftg: it.ftg });
            } else {
                failed = true;
            }
        }
        if walk_ok[li] && !failed {
            out.truncate(manifest.levels[li].size as usize);
            report.levels[li] = Some(out);
        }
    }
    // Usable prefix: leading recovered levels, ending at the first
    // plane-cut level — a cut level's missing bitplanes gate every
    // later rung, so a fully-delivered level *behind* a cut must not
    // inflate the certified ε (the sender's advertised_eps mirrors
    // this walk).
    let mut prefix = 0;
    for (li, l) in report.levels.iter().enumerate() {
        if l.is_none() {
            break;
        }
        prefix += 1;
        if manifest.levels[li].cut {
            break;
        }
    }
    report.levels_recovered = prefix;
    report.achieved_eps = if prefix == 0 { 1.0 } else { manifest.levels[prefix - 1].eps };
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::channel::{mem_pair, MemChannel};
    use crate::util::Pcg64;

    fn pool_channels(streams: usize) -> (MemChannel, Vec<MemChannel>, MemChannel, Vec<MemChannel>) {
        let (sc, rc) = mem_pair();
        let mut sd = Vec::new();
        let mut rd = Vec::new();
        for _ in 0..streams {
            let (a, b) = mem_pair();
            sd.push(a);
            rd.push(b);
        }
        (sc, sd, rc, rd)
    }

    fn test_levels(seed: u64) -> (Vec<Vec<u8>>, Vec<f64>) {
        let mut rng = Pcg64::seeded(seed);
        let sizes = [50_000usize, 200_000, 400_000];
        let eps = vec![0.004, 0.0005, 0.0000001];
        (
            sizes
                .iter()
                .map(|&sz| {
                    let mut v = vec![0u8; sz];
                    rng.fill_bytes(&mut v);
                    v
                })
                .collect(),
            eps,
        )
    }

    fn cfg(streams: usize) -> PoolConfig {
        PoolConfig {
            net: NetParams { t: 0.0005, r: 200_000.0, lambda: 0.0, n: 32, s: 1024 },
            streams,
            contract: Contract::Fidelity(1e-7),
            initial_lambda: 0.0,
            max_duration: Duration::from_secs(60),
            plane_cuts: Vec::new(),
            adapt: AdaptConfig::fixed(),
        }
    }

    /// Drops everything `drop_if` matches on the way out; delivery and
    /// receive paths are untouched. `fn` pointers keep every filter the
    /// same type, so sender and receiver control channels stay one `C`.
    struct SendFilter<C: Datagram> {
        inner: C,
        drop_if: fn(&[u8]) -> bool,
    }

    impl<C: Datagram> Datagram for SendFilter<C> {
        fn send(&mut self, buf: &[u8]) {
            if !(self.drop_if)(buf) {
                self.inner.send(buf);
            }
        }
        fn recv_into(&mut self, buf: &mut [u8], timeout: Duration) -> Option<usize> {
            self.inner.recv_into(buf, timeout)
        }
        fn try_recv_into(&mut self, buf: &mut [u8]) -> Option<usize> {
            self.inner.try_recv_into(buf)
        }
    }

    fn keep_all(_: &[u8]) -> bool {
        false
    }

    fn drop_pass_stats(buf: &[u8]) -> bool {
        matches!(Packet::decode(buf), Ok(Packet::PassStats { .. }))
    }

    fn drop_pass0_fragments(buf: &[u8]) -> bool {
        matches!(PacketView::decode(buf), Ok(PacketView::Fragment(v)) if v.header.pass == 0)
    }

    fn rcfg() -> ReceiverConfig {
        ReceiverConfig {
            t_w: 0.25,
            idle_timeout: Duration::from_secs(5),
            max_duration: Duration::from_secs(60),
        }
    }

    #[test]
    fn lossless_pool_delivers_exact_bytes_four_streams() {
        let (levels, eps) = test_levels(1);
        let pool = TransferPool::new(cfg(4)).unwrap();
        let (mut sc, sd, mut rc, rd) = pool_channels(4);
        let (s_rep, r_rep) = pool
            .pooled_session(&mut sc, sd, &mut rc, rd, &rcfg(), &levels, &eps)
            .unwrap();
        assert_eq!(r_rep.levels_recovered, 3);
        for (got, want) in r_rep.levels.iter().zip(&levels) {
            assert_eq!(got.as_ref().unwrap(), want);
        }
        assert_eq!(s_rep.passes, 0);
        assert_eq!(s_rep.trace.len(), 1);
        assert_eq!(s_rep.trace[0].lambda_hat, 0.0);
        assert_eq!(s_rep.trace[0].per_stream.len(), 4);
        // Every stream carried a share of the load.
        assert!(s_rep.trace[0].per_stream.iter().all(|&c| c > 0));
        assert_eq!(
            s_rep.trace[0].per_stream.iter().sum::<u64>(),
            s_rep.fragments_sent
        );
    }

    #[test]
    fn single_stream_pool_degenerates_cleanly() {
        let (levels, eps) = test_levels(2);
        let pool = TransferPool::new(cfg(1)).unwrap();
        let (mut sc, sd, mut rc, rd) = pool_channels(1);
        let (_s, r) = pool
            .pooled_session(&mut sc, sd, &mut rc, rd, &rcfg(), &levels, &eps)
            .unwrap();
        assert_eq!(r.levels_recovered, 3);
        for (got, want) in r.levels.iter().zip(&levels) {
            assert_eq!(got.as_ref().unwrap(), want);
        }
    }

    #[test]
    fn error_bound_limits_transmitted_levels() {
        let (levels, eps) = test_levels(3);
        let mut c = cfg(2);
        c.contract = Contract::Fidelity(0.004); // level 1 suffices
        let pool = TransferPool::new(c).unwrap();
        let (mut sc, sd, mut rc, rd) = pool_channels(2);
        let (_s, r) = pool
            .pooled_session(&mut sc, sd, &mut rc, rd, &rcfg(), &levels, &eps)
            .unwrap();
        assert_eq!(r.levels.len(), 1, "only level 1 in manifest");
        assert_eq!(r.levels[0].as_ref().unwrap(), &levels[0]);
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        let mut c = cfg(0);
        assert!(TransferPool::new(c.clone()).is_err());
        c.streams = 4;
        c.net.n = 1;
        assert!(TransferPool::new(c.clone()).is_err());
        c.net.n = 32;
        assert!(TransferPool::new(c).is_ok());
    }

    #[test]
    fn mismatched_channel_count_is_an_error() {
        let (levels, eps) = test_levels(4);
        let pool = TransferPool::new(cfg(3)).unwrap();
        let (mut sc, mut sd, _rc, _rd) = pool_channels(2); // too few
        let err = pool
            .pooled_sender(&mut sc, &mut sd, &levels, &eps, None)
            .unwrap_err();
        assert!(format!("{err}").contains("data channels"), "{err}");
    }

    #[test]
    fn empty_level_set_is_an_error_not_a_panic() {
        // Regression: `eps[eps.len() - 1]` used to panic on an empty
        // level set before the error message could even be built.
        let pool = TransferPool::new(cfg(2)).unwrap();
        let (mut sc, mut sd, _rc, _rd) = pool_channels(2);
        let err = pool
            .pooled_sender(&mut sc, &mut sd, &[], &[], None)
            .unwrap_err();
        assert!(format!("{err}").contains("no levels"), "{err}");
        // Mismatched lengths are equally a typed error, not an assert.
        let err = pool
            .pooled_sender(&mut sc, &mut sd, &[vec![0u8; 8]], &[], None)
            .unwrap_err();
        assert!(format!("{err}").contains("epsilons"), "{err}");
    }

    #[test]
    fn whole_level_first_pass_loss_enumerates_every_ftg() {
        // Regression for the `collect_lost` stride: never-seen FTGs used
        // to be strided by the worst case n·s while the sender plans
        // k = n − m0, so a 100%-loss first pass under-enumerated the
        // lost list and wasted passes re-discovering the tail. With the
        // manifest-carried m0 the very first lost list names every
        // planned FTG and one retransmission pass finishes the job.
        let (levels, eps) = test_levels(6);
        let mut c = cfg(2);
        // Honest-but-lossy λ₀ so the pass-0 plan buys parity (k < n).
        c.initial_lambda = 0.2 * c.net.r * 2.0;
        let pool = TransferPool::new(c).unwrap();
        let (mut sc, sd_raw, mut rc, rd) = pool_channels(2);
        let sd: Vec<SendFilter<MemChannel>> = sd_raw
            .into_iter()
            .map(|inner| SendFilter { inner, drop_if: drop_pass0_fragments })
            .collect();
        let (s_rep, r_rep) = pool
            .pooled_session(&mut sc, sd, &mut rc, rd, &rcfg(), &levels, &eps)
            .unwrap();
        assert!(s_rep.trace[0].m >= 1, "regression needs k < n geometry");
        assert_eq!(
            s_rep.trace[0].lost_ftgs, s_rep.trace[0].ftgs,
            "100% pass-0 loss: the first lost list must enumerate every planned FTG"
        );
        assert_eq!(s_rep.passes, 1, "exact enumeration ⇒ one retransmission pass");
        for (got, want) in r_rep.levels.iter().zip(&levels) {
            assert_eq!(got.as_ref().unwrap(), want);
        }
    }

    #[test]
    fn done_is_terminal_even_when_pass_stats_is_dropped() {
        // Regression: a completed transfer whose PassStats datagram was
        // dropped used to spin the full retry budget and then abort with
        // "no PassStats" — after the receiver had already certified
        // completion with Done.
        let (levels, eps) = test_levels(8);
        let pool = TransferPool::new(cfg(2)).unwrap();
        let (sc_raw, sd, rc_raw, rd) = pool_channels(2);
        let mut sc = SendFilter { inner: sc_raw, drop_if: keep_all };
        let mut rc = SendFilter { inner: rc_raw, drop_if: drop_pass_stats };
        let (s_rep, r_rep) = pool
            .pooled_session(&mut sc, sd, &mut rc, rd, &rcfg(), &levels, &eps)
            .unwrap();
        assert_eq!(r_rep.levels_recovered, 3);
        for (got, want) in r_rep.levels.iter().zip(&levels) {
            assert_eq!(got.as_ref().unwrap(), want);
        }
        assert_eq!(s_rep.passes, 0);
        assert_eq!(s_rep.trace.len(), 1, "synthesized final record");
        assert_eq!(s_rep.trace[0].lost_ftgs, 0);
        assert_eq!(s_rep.trace[0].lambda_hat, 0.0, "no fresh stats: λ̂ keeps its prior");
    }

    #[test]
    fn pooled_deadline_generous_tau_delivers_everything() {
        let mut c = cfg(4);
        c.contract = Contract::Deadline(60.0);
        let pool = TransferPool::new(c).unwrap();
        let (levels, eps) = test_levels(9);
        let (mut sc, sd, mut rc, rd) = pool_channels(4);
        let (s_rep, r_rep) = pool
            .pooled_session(&mut sc, sd, &mut rc, rd, &rcfg(), &levels, &eps)
            .unwrap();
        assert_eq!(r_rep.levels_recovered, 3);
        for (got, want) in r_rep.levels.iter().zip(&levels) {
            assert_eq!(got.as_ref().unwrap(), want);
        }
        let dl = s_rep.deadline.as_ref().expect("deadline outcome");
        assert!(dl.met, "generous τ must be met: {dl:?}");
        assert!(dl.virtual_elapsed <= dl.tau);
        assert!((dl.advertised_eps - eps[2]).abs() < 1e-15, "nothing shed");
        assert!(s_rep.trace.iter().all(|p| p.shed.is_empty()));
        assert!((r_rep.achieved_eps - dl.advertised_eps).abs() < 1e-15);
    }

    #[test]
    fn pooled_deadline_exhausted_budget_sheds_pending_levels() {
        // Pass 0 goes out unprotected under a lying λ₀ = 0; every pass-0
        // fragment dies; the barrier then shows the (tight) τ cannot fit
        // retransmitting everything, so the tail levels are shed
        // deterministically and the transfer still completes.
        let (levels, eps) = test_levels(10);
        let mut c = cfg(2);
        // τ ≈ 2 × the unprotected pass-0 air time: after the total pass-0
        // loss the residual budget can afford retransmitting a level
        // prefix, nowhere near the whole dataset.
        let frags: f64 = levels.iter().map(|l| l.len().div_ceil(1024) as f64).sum();
        let tau = 2.0 * (0.0005 + frags / (2.0 * 200_000.0));
        c.contract = Contract::Deadline(tau);
        let pool = TransferPool::new(c).unwrap();
        let (mut sc, sd_raw, mut rc, rd) = pool_channels(2);
        let sd: Vec<SendFilter<MemChannel>> = sd_raw
            .into_iter()
            .map(|inner| SendFilter { inner, drop_if: drop_pass0_fragments })
            .collect();
        let (s_rep, r_rep) = pool
            .pooled_session(&mut sc, sd, &mut rc, rd, &rcfg(), &levels, &eps)
            .unwrap();
        let dl = s_rep.deadline.as_ref().expect("deadline outcome");
        let shed: Vec<&ShedDecision> = s_rep.trace.iter().flat_map(|p| &p.shed).collect();
        assert!(!shed.is_empty(), "tight τ after total loss must shed: {dl:?}");
        assert!(dl.met, "shedding must keep the virtual clock inside τ: {dl:?}");
        // The receiver certifies exactly what the sender advertised.
        assert!(
            (r_rep.achieved_eps - dl.advertised_eps).abs() < 1e-15,
            "receiver ε {} vs advertised {}",
            r_rep.achieved_eps,
            dl.advertised_eps
        );
        // Raw datasets have no plane cuts ⇒ every shed abandons a whole
        // level, so the usable prefix genuinely shrank.
        assert!(r_rep.levels_recovered < 3, "something must have been shed");
        // Abandoned levels stay None; recovered prefix is byte-exact.
        for li in 0..r_rep.levels_recovered {
            assert_eq!(r_rep.levels[li].as_ref().unwrap(), &levels[li]);
        }
    }

    #[test]
    fn usable_prefix_stops_at_a_cut_level_even_when_later_levels_arrived() {
        // Certification soundness: a mid-transfer plane-cut shed of
        // level 1 removes bitplanes that every later rung depends on.
        // A fully-delivered level 2 behind that cut must not inflate the
        // certified ε — the prefix (and thus achieved_eps) stops at the
        // cut on both sides.
        let s = 4usize;
        let mut groups: HashMap<(u8, u32), FtgArena> = HashMap::new();
        for li in 0u8..3 {
            let mut g = FtgArena::new(1, 0, s);
            g.insert(0, &[li; 4]);
            groups.insert((li, 0), g);
        }
        let manifest = Manifest {
            n: 32,
            s: s as u32,
            streams: 1,
            contract: 1,
            levels: vec![
                ManifestLevel { size: 4, eps: 0.01, m0: 0, cut: false },
                ManifestLevel { size: 4, eps: 0.004, m0: 0, cut: true }, // shed to a cut
                ManifestLevel { size: 4, eps: 0.0001, m0: 0, cut: false },
            ],
        };
        let mut report = PoolReceiverReport {
            levels: vec![None; 3],
            levels_recovered: 0,
            achieved_eps: 1.0,
            fragments_received: 0,
            groups_recovered: 0,
            duration: 0.0,
            trace: Vec::new(),
        };
        reconstruct_levels(&manifest, &groups, s, &[false; 3], &mut report, None).unwrap();
        assert!(report.levels.iter().all(|l| l.is_some()), "all bytes arrived");
        assert_eq!(report.levels_recovered, 2, "prefix ends at the cut level");
        assert!(
            (report.achieved_eps - 0.004).abs() < 1e-15,
            "certify the cut ε, not the later rung's: {}",
            report.achieved_eps
        );

        // The sender's advertisement walks identically.
        let mut dl = DeadlineState::new(
            10.0,
            0.0001,
            vec![4, 4, 4],
            vec![0.01, 0.004, 0.0001],
            vec![false, false, false],
        );
        dl.cut[1] = true;
        dl.adv_eps[1] = 0.004;
        assert!((dl.advertised_eps() - 0.004).abs() < 1e-15);
        // An abandoned level 0 trumps everything.
        dl.abandoned[0] = true;
        assert!((dl.advertised_eps() - 1.0).abs() < 1e-15);
    }
}
