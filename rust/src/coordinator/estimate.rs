//! Loss-rate estimation for the transfer engines.
//!
//! The paper's receiver estimates λ by counting losses in a window
//! `T_W` (§4). This module hosts that estimator family — promoted out
//! of the simulator so the *engines* share it — plus the two-state
//! burst/residual estimator the pass barrier feeds: raw per-pass loss
//! fractions cannot distinguish 20% i.i.d. loss from 20% loss arriving
//! in bursts of eight, yet Eq. 8 sizes parity very differently for the
//! two (a burst eats `b` consecutive fragments of one FTG, so `m`
//! parity only survives `⌊m/b⌋` events).
//!
//! [`tracking_rmse`](crate::sim::estimator::tracking_rmse) (in
//! `sim::estimator`, which re-exports everything here) scores these
//! estimators against HMM ground truth.

/// Online λ estimator fed with per-window loss counts or raw events.
pub trait LambdaEstimator {
    /// Record that `lost` fragments were detected missing at `time`.
    fn record_losses(&mut self, time: f64, lost: u64);
    /// Current estimate (losses/second), if warmed up.
    fn estimate(&self) -> Option<f64>;
    fn name(&self) -> &'static str;
}

/// The paper's estimator: losses per fixed window `T_W`.
#[derive(Debug, Clone)]
pub struct WindowEstimator {
    t_w: f64,
    window_start: f64,
    window_losses: u64,
    last: Option<f64>,
}

impl WindowEstimator {
    pub fn new(t_w: f64) -> Self {
        assert!(t_w > 0.0);
        WindowEstimator { t_w, window_start: 0.0, window_losses: 0, last: None }
    }
}

impl LambdaEstimator for WindowEstimator {
    fn record_losses(&mut self, time: f64, lost: u64) {
        if time - self.window_start >= self.t_w {
            let elapsed = time - self.window_start;
            self.last = Some(self.window_losses as f64 / elapsed);
            self.window_start = time;
            self.window_losses = 0;
        }
        self.window_losses += lost;
    }
    fn estimate(&self) -> Option<f64> {
        self.last
    }
    fn name(&self) -> &'static str {
        "window"
    }
}

/// Exponentially-weighted moving average over sub-windows: smoother than
/// the raw window estimate, faster to react than enlarging `T_W`.
#[derive(Debug, Clone)]
pub struct EwmaEstimator {
    sub_window: f64,
    alpha: f64,
    window_start: f64,
    window_losses: u64,
    value: Option<f64>,
}

impl EwmaEstimator {
    pub fn new(sub_window: f64, alpha: f64) -> Self {
        assert!(sub_window > 0.0 && (0.0..=1.0).contains(&alpha));
        EwmaEstimator { sub_window, alpha, window_start: 0.0, window_losses: 0, value: None }
    }
}

impl LambdaEstimator for EwmaEstimator {
    fn record_losses(&mut self, time: f64, lost: u64) {
        if time - self.window_start >= self.sub_window {
            let elapsed = time - self.window_start;
            let sample = self.window_losses as f64 / elapsed;
            self.value = Some(match self.value {
                Some(v) => self.alpha * sample + (1.0 - self.alpha) * v,
                None => sample,
            });
            self.window_start = time;
            self.window_losses = 0;
        }
        self.window_losses += lost;
    }
    fn estimate(&self) -> Option<f64> {
        self.value
    }
    fn name(&self) -> &'static str {
        "ewma"
    }
}

/// One pass-barrier observation, as reported by the pooled receiver.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PassObservation {
    /// Virtual seconds the pass occupied on the wire.
    pub elapsed: f64,
    /// Fragments offered to the wire during the pass.
    pub offered: u64,
    /// Fragments that survived.
    pub received: u64,
    /// Distinct loss runs (maximal gaps of consecutive per-stream
    /// sequence numbers) the receiver observed; 0 when lossless.
    pub runs: u32,
    /// Losses that fell in runs of length ≥ 2.
    pub burst_lost: u64,
    /// Aggregate rate (fragments/s, all streams) the pass was paced at.
    pub rate: f64,
}

impl PassObservation {
    /// Lost fragments in the pass.
    pub fn lost(&self) -> u64 {
        self.offered.saturating_sub(self.received)
    }

    /// Pass loss fraction in [0, 1].
    pub fn loss_frac(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        (1.0 - self.received as f64 / self.offered as f64).clamp(0.0, 1.0)
    }

    /// Mean loss-run length (≥ 1 whenever anything was lost).
    pub fn burst_len(&self) -> f64 {
        let lost = self.lost();
        if lost == 0 || self.runs == 0 {
            return if lost == 0 { 0.0 } else { 1.0 };
        }
        (lost as f64 / self.runs as f64).max(1.0)
    }
}

/// Two-state burst/residual λ estimator: decomposes the per-pass loss
/// observation into a total rate λ̂ (losses/s at the *actual* pass
/// rate — the pre-adaptive code priced loss fractions at the nominal
/// configured rate, overestimating λ̂ whenever the pacer had backed
/// off), a mean burst length b̂, and the burst/residual split. EWMA
/// smoothing across barriers; the first observation seeds the state
/// directly so pass-0 estimates are the raw measurement (the
/// determinism contract existing traces assert).
#[derive(Debug, Clone)]
pub struct TwoStateEstimator {
    alpha: f64,
    lambda_total: Option<f64>,
    lambda_burst: f64,
    burst_len: f64,
}

impl TwoStateEstimator {
    /// `alpha` weights the newest barrier observation (1.0 = no memory).
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha) && alpha > 0.0);
        TwoStateEstimator { alpha, lambda_total: None, lambda_burst: 0.0, burst_len: 1.0 }
    }

    fn blend(&self, old: f64, new: f64) -> f64 {
        self.alpha * new + (1.0 - self.alpha) * old
    }

    /// Fold in one pass-barrier observation.
    pub fn observe_pass(&mut self, obs: &PassObservation) {
        let lam = obs.loss_frac() * obs.rate;
        let lost = obs.lost();
        let burst_frac = if lost == 0 { 0.0 } else { obs.burst_lost as f64 / lost as f64 };
        let lam_burst = lam * burst_frac;
        let b = obs.burst_len().max(1.0);
        match self.lambda_total {
            None => {
                self.lambda_total = Some(lam);
                self.lambda_burst = lam_burst;
                self.burst_len = b;
            }
            Some(prev) => {
                self.lambda_total = Some(self.blend(prev, lam));
                self.lambda_burst = self.blend(self.lambda_burst, lam_burst);
                // Burst length only means something when losses exist;
                // a lossless pass must not drag b̂ toward zero.
                if lost > 0 {
                    self.burst_len = self.blend(self.burst_len, b).max(1.0);
                }
            }
        }
    }

    /// Smoothed total loss rate λ̂ (losses/s), if warmed up.
    pub fn lambda_total(&self) -> Option<f64> {
        self.lambda_total
    }

    /// Smoothed burst-state loss rate (losses arriving in runs ≥ 2).
    pub fn lambda_burst(&self) -> f64 {
        self.lambda_burst
    }

    /// Residual (isolated-loss) rate: λ̂ − λ̂_burst.
    pub fn lambda_residual(&self) -> f64 {
        self.lambda_total.unwrap_or(0.0) - self.lambda_burst
    }

    /// Smoothed mean burst length b̂ ≥ 1.
    pub fn burst_len(&self) -> f64 {
        self.burst_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(offered: u64, received: u64, runs: u32, burst_lost: u64, rate: f64) -> PassObservation {
        PassObservation { elapsed: 1.0, offered, received, runs, burst_lost, rate }
    }

    #[test]
    fn first_observation_is_raw() {
        let mut e = TwoStateEstimator::new(0.5);
        assert!(e.lambda_total().is_none());
        // 20% loss at 1000 frag/s aggregate ⇒ λ̂ = 200.
        e.observe_pass(&obs(1000, 800, 200, 0, 1000.0));
        assert!((e.lambda_total().unwrap() - 200.0).abs() < 1e-9);
        assert!((e.burst_len() - 1.0).abs() < 1e-9, "200 runs of 1");
        assert_eq!(e.lambda_burst(), 0.0);
        assert!((e.lambda_residual() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn lambda_prices_loss_at_the_actual_rate() {
        // Same 20% fraction at half the pace ⇒ half the λ̂ — the bug the
        // nominal-rate estimate had.
        let mut full = TwoStateEstimator::new(1.0);
        let mut half = TwoStateEstimator::new(1.0);
        full.observe_pass(&obs(1000, 800, 200, 0, 1000.0));
        half.observe_pass(&obs(1000, 800, 200, 0, 500.0));
        assert!((full.lambda_total().unwrap() - 2.0 * half.lambda_total().unwrap()).abs() < 1e-9);
    }

    #[test]
    fn burst_split_tracks_run_shape() {
        let mut e = TwoStateEstimator::new(1.0);
        // 160 of 200 losses in runs ≥ 2, 25 runs ⇒ b̂ = 8.
        e.observe_pass(&obs(1000, 800, 25, 160, 1000.0));
        assert!((e.burst_len() - 8.0).abs() < 1e-9);
        assert!((e.lambda_burst() - 160.0).abs() < 1e-9);
        assert!((e.lambda_residual() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_smooths_and_lossless_passes_keep_burst_len() {
        let mut e = TwoStateEstimator::new(0.5);
        e.observe_pass(&obs(1000, 800, 25, 160, 1000.0)); // b̂ = 8
        e.observe_pass(&obs(1000, 1000, 0, 0, 1000.0)); // lossless
        assert!((e.lambda_total().unwrap() - 100.0).abs() < 1e-9, "EWMA halves");
        assert!((e.burst_len() - 8.0).abs() < 1e-9, "b̂ untouched by lossless pass");
        e.observe_pass(&obs(1000, 900, 100, 0, 1000.0)); // b = 1
        assert!((e.burst_len() - 4.5).abs() < 1e-9);
    }

    #[test]
    fn zero_offered_pass_is_a_no_op_observation() {
        let mut e = TwoStateEstimator::new(0.5);
        e.observe_pass(&obs(0, 0, 0, 0, 1000.0));
        assert_eq!(e.lambda_total(), Some(0.0));
        assert_eq!(e.burst_len(), 1.0);
    }

    #[test]
    fn observation_helpers() {
        let o = obs(100, 90, 5, 6, 1000.0);
        assert_eq!(o.lost(), 10);
        assert!((o.loss_frac() - 0.1).abs() < 1e-12);
        assert!((o.burst_len() - 2.0).abs() < 1e-12);
        let clean = obs(100, 100, 0, 0, 1000.0);
        assert_eq!(clean.burst_len(), 0.0);
        // Malformed (received > offered) clamps instead of exploding.
        let weird = obs(100, 200, 0, 0, 1000.0);
        assert_eq!(weird.loss_frac(), 0.0);
    }
}
