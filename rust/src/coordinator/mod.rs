//! The Janus coordinator — the paper's system contribution over real
//! transports (§4, §5.3): adaptive sender/receiver protocol engines,
//! wire format, and session harness.
//!
//! * [`packet`] — fragment + control wire format (Protobuf substitute).
//! * [`sender`] — Alg. 1/Alg. 2 sender: parity-generation thread feeding a
//!   paced transmission thread, λ-adaptive redundancy, passive
//!   retransmission.
//! * [`receiver`] — FTG reassembly, Reed–Solomon recovery, λ measurement
//!   window, lost-FTG feedback.
//! * [`session`] — run a sender/receiver pair over connected channels.
//! * [`pool`] — multi-stream parallel transfer engine ([`pool::TransferPool`]):
//!   N sender workers with per-stream paced endpoints and worker-pool RS
//!   encoding, a demultiplexing receiver, and one shared λ̂ estimator.

pub mod packet;
pub mod pool;
pub mod receiver;
pub mod sender;
pub mod session;

pub use packet::{FragmentHeader, Manifest, Packet, WireError};
pub use pool::{
    PassRecord, PoolConfig, PoolReceiverReport, PoolSenderReport, RecvPassRecord, TransferPool,
};
pub use receiver::{run_receiver, ReceiverConfig, ReceiverReport};
pub use sender::{run_sender, Contract, SenderConfig, SenderReport};
pub use session::run_session;
