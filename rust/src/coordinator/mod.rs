//! The Janus coordinator — the paper's system contribution over real
//! transports (§4, §5.3): the adaptive sender/receiver protocol engines,
//! the wire format, and the multi-stream transfer pool.
//!
//! These are the **engines**, not the public surface: user code runs
//! transfers through the [`crate::api`] facade
//! ([`crate::api::Endpoint::send`] / [`crate::api::Endpoint::receive`] /
//! [`crate::api::run_pair`]), which validates a
//! [`crate::api::TransferSpec`], opens channels via a
//! [`crate::api::Transport`], routes to the right engine, and delivers
//! typed [`crate::api::TransferEvent`]s. The free functions this module
//! still exports (`run_sender`, `run_receiver`, `run_session`,
//! `TransferPool::run_*`) are `#[deprecated]` one-line shims kept for
//! source compatibility.
//!
//! * [`arena`] — strided per-FTG fragment arenas with presence bitmaps
//!   (one allocation per group; the engines' reassembly tables and the
//!   parity pipeline's unit of transfer).
//! * [`packet`] — fragment + control wire format (Protobuf substitute),
//!   including the borrowing [`packet::PacketView`] hot-path decode.
//! * [`estimate`] — λ̂ estimator family (window, EWMA, and the two-state
//!   burst/residual estimator the pass barrier feeds).
//! * [`rate`] — SRTT/RTTVAR barrier timing and the CUBIC-style
//!   congestion-aware pacer shared by the engines.
//! * [`sender`] — Alg. 1/Alg. 2 sender engine: a parity-generation thread
//!   feeding a paced transmission thread, λ-adaptive redundancy, passive
//!   retransmission.
//! * [`receiver`] — FTG reassembly, Reed–Solomon recovery, λ measurement
//!   window, lost-FTG feedback.
//! * [`session`] — deprecated single-pair harness (see
//!   [`crate::api::run_pair`]).
//! * [`pool`] — multi-stream parallel transfer engine
//!   ([`pool::TransferPool`]): N sender workers with per-stream paced
//!   endpoints and worker-pool RS encoding, a demultiplexing receiver,
//!   and one shared λ̂ estimator.

pub mod arena;
pub mod estimate;
pub mod packet;
pub mod pool;
pub mod rate;
pub mod receiver;
pub mod sender;
pub mod session;

pub use crate::api::Contract;
pub use arena::FtgArena;
pub use estimate::{
    EwmaEstimator, LambdaEstimator, PassObservation, TwoStateEstimator, WindowEstimator,
};
pub use packet::{
    FragmentHeader, FragmentView, Manifest, ManifestLevel, Packet, PacketView, RepairHeader,
    RepairView, WireError,
};
pub use pool::{
    DeadlineOutcome, PassRecord, PoolConfig, PoolReceiverReport, PoolSenderReport,
    RecvPassRecord, ShedDecision, TransferPool,
};
pub use rate::{AdaptConfig, PassVerdict, RateController, RttEstimator};
#[allow(deprecated)]
pub use receiver::run_receiver;
pub use receiver::{ReceiverConfig, ReceiverReport};
#[allow(deprecated)]
pub use sender::run_sender;
pub use sender::{SenderConfig, SenderReport};
#[allow(deprecated)]
pub use session::run_session;
