//! `janus` — CLI for the Janus adaptive data-transmission system.
//!
//! Every transfer-running subcommand goes through the `janus::api`
//! facade (spec → endpoint → transport); the model/simulation
//! subcommands call the `model`/`sim` layers directly.
//!
//! Subcommands:
//!   optimize   Solve the paper's optimization models (Eq. 8 / Eq. 12).
//!   simulate   Run a simulated transfer (TCP / static UDP+EC / adaptive).
//!   send       Run a real-UDP sender against a peer address.
//!   recv       Run a real-UDP receiver.
//!   ec-rate    Measure Reed–Solomon parity-generation throughput (r_ec).
//!   e2e        End-to-end demo: refactor → transfer → reconstruct.
//!   pool       Multi-stream transfer demo over lossy in-memory
//!              channels (deterministic; see coordinator::pool).
//!   codec      Progressive-codec demo: GRF volume → ε-ladder encode →
//!              lossy facade transfer → progressive decode, reporting
//!              the achieved (measured) error bound.
//!   serve      Multi-tenant daemon demo: many concurrent transfers
//!              multiplexed over one shared lossy socket pair on a
//!              single event loop (serve::Daemon, virtual clock).
//!   lint       Run the in-tree static-analysis catalog over the
//!              workspace sources (DESIGN.md §13); exits non-zero on
//!              any violation.
//!
//! `janus <subcommand> --help` prints generated help; unknown options
//! are rejected with the valid list (typos used to be silently ignored).

use janus::api::{
    run_pair, ChannelTransport, Contract, Dataset, Endpoint, TransferSpec, UdpTransport,
};
use janus::config::{Args, CommandSpec, OptSpec};
use janus::erasure::sweep_ec_rates;
use janus::model::{optimize_deadline_paper, optimize_parity, LevelSchedule, NetParams};
use janus::sim::{
    run_guaranteed_error, run_guaranteed_time, run_tcp, BernoulliLoss, DeadlinePolicy, HmmLoss,
    ParityPolicy, StaticLoss,
};
use janus::transport::UdpChannel;
use std::time::Duration;

const COMMANDS: &[CommandSpec] = &[
    CommandSpec {
        name: "optimize",
        summary: "solve the paper's optimization models (Eq. 8 / Eq. 12)",
        positional: &[],
        opts: &[
            OptSpec { name: "lambda", value: Some("l/s"), help: "packet-loss rate" },
            OptSpec { name: "mode", value: Some("error-bound|deadline"), help: "which model to solve" },
            OptSpec { name: "tau", value: Some("s"), help: "deadline for --mode deadline" },
            OptSpec { name: "scale", value: Some("f"), help: "shrink the Nyx schedule by this factor" },
        ],
    },
    CommandSpec {
        name: "simulate",
        summary: "run a simulated transfer over a synthetic loss process",
        positional: &[],
        opts: &[
            OptSpec { name: "protocol", value: Some("tcp|static|adaptive|deadline"), help: "protocol under test" },
            OptSpec { name: "lambda", value: Some("l/s|hmm"), help: "loss rate, or 'hmm' for the 3-state model" },
            OptSpec { name: "m", value: Some("parity"), help: "static parity count (--protocol static)" },
            OptSpec { name: "tau", value: Some("s"), help: "deadline (--protocol deadline)" },
            OptSpec { name: "scale", value: Some("f"), help: "shrink the Nyx schedule by this factor" },
            OptSpec { name: "seed", value: Some("n"), help: "loss-process seed" },
        ],
    },
    CommandSpec {
        name: "ec-rate",
        summary: "measure Reed–Solomon parity-generation throughput (r_ec)",
        positional: &[],
        opts: &[
            OptSpec { name: "n", value: Some("frags"), help: "fragments per group" },
            OptSpec { name: "max-m", value: Some("m"), help: "largest parity count to sweep" },
            OptSpec { name: "secs", value: Some("s"), help: "measurement time per point" },
        ],
    },
    CommandSpec {
        name: "send",
        summary: "send a synthetic refactored dataset to a real-UDP peer",
        positional: &[],
        opts: &[
            OptSpec { name: "peer", value: Some("addr:port"), help: "receiver address (required)" },
            OptSpec { name: "bind", value: Some("addr:port"), help: "local bind address" },
            OptSpec { name: "deadline", value: Some("s"), help: "use a Deadline contract instead of Fidelity" },
            OptSpec { name: "rate", value: Some("pkt/s"), help: "pacing rate" },
            OptSpec { name: "lambda", value: Some("l/s"), help: "initial loss estimate" },
            OptSpec { name: "dim", value: Some("d"), help: "synthetic volume dimension" },
            OptSpec { name: "seed", value: Some("n"), help: "synthetic volume seed" },
            OptSpec { name: "max-secs", value: Some("s"), help: "abort after this long" },
        ],
    },
    CommandSpec {
        name: "recv",
        summary: "receive a transfer on a real-UDP socket",
        positional: &[],
        opts: &[
            OptSpec { name: "bind", value: Some("addr:port"), help: "listen address (required)" },
            OptSpec { name: "t-w", value: Some("s"), help: "lambda measurement window" },
            OptSpec { name: "idle-secs", value: Some("s"), help: "give up after this much silence" },
            OptSpec { name: "max-secs", value: Some("s"), help: "abort after this long" },
        ],
    },
    CommandSpec {
        name: "e2e",
        summary: "end-to-end demo: refactor, simulated transfer, reconstruct",
        positional: &[],
        opts: &[
            OptSpec { name: "dim", value: Some("d"), help: "synthetic volume dimension" },
            OptSpec { name: "lambda", value: Some("l/s"), help: "loss rate" },
            OptSpec { name: "seed", value: Some("n"), help: "synthetic volume seed" },
        ],
    },
    CommandSpec {
        name: "pool",
        summary: "multi-stream transfer demo over deterministic lossy channels",
        positional: &[],
        opts: &[
            OptSpec { name: "streams", value: Some("n"), help: "concurrent streams (1..=255)" },
            OptSpec { name: "loss", value: Some("frac"), help: "injected fragment-loss fraction" },
            OptSpec { name: "mb", value: Some("MB"), help: "dataset size" },
            OptSpec { name: "rate", value: Some("frag/s"), help: "per-stream pacing rate" },
            OptSpec { name: "seed", value: Some("n"), help: "loss-trace seed" },
        ],
    },
    CommandSpec {
        name: "codec",
        summary: "progressive codec demo: volume → ε rungs → lossy transfer → decode",
        positional: &[],
        opts: &[
            OptSpec { name: "dim", value: Some("d"), help: "synthetic volume dimension" },
            OptSpec { name: "seed", value: Some("n"), help: "volume + loss-trace seed" },
            OptSpec { name: "levels", value: Some("L"), help: "lifting levels" },
            OptSpec { name: "eps", value: Some("e1,e2,…"), help: "requested ε ladder (decreasing)" },
            OptSpec { name: "planes", value: Some("p"), help: "mantissa plane budget (1..=30)" },
            OptSpec { name: "loss", value: Some("frac"), help: "injected fragment-loss fraction" },
            OptSpec { name: "streams", value: Some("n"), help: "concurrent streams" },
            OptSpec { name: "rate", value: Some("frag/s"), help: "per-stream pacing rate" },
            OptSpec { name: "deadline", value: Some("s"), help: "use a Deadline contract" },
        ],
    },
    CommandSpec {
        name: "serve",
        summary: "multi-tenant daemon demo: concurrent transfers on one event loop",
        positional: &[],
        opts: &[
            OptSpec { name: "transfers", value: Some("n"), help: "concurrent transfers" },
            OptSpec { name: "kb", value: Some("KB"), help: "dataset size per transfer" },
            OptSpec { name: "loss", value: Some("frac"), help: "injected fragment-loss fraction" },
            OptSpec { name: "rate", value: Some("frag/s"), help: "per-transfer pacing rate" },
            OptSpec { name: "tenants", value: Some("n"), help: "tenants sharing the daemon" },
            OptSpec { name: "budget-kb", value: Some("KB"), help: "per-tenant in-flight budget (0 = unlimited)" },
            OptSpec { name: "seed", value: Some("n"), help: "loss-trace + payload seed" },
        ],
    },
    CommandSpec {
        name: "lint",
        summary: "run the in-tree static-analysis rule catalog (DESIGN.md §13)",
        positional: &[],
        opts: &[OptSpec {
            name: "root",
            value: Some("dir"),
            help: "workspace root to lint (default: auto-detected)",
        }],
    },
];

fn global_usage() -> String {
    let mut out = String::from("usage: janus <subcommand> [--options]\n\nsubcommands:\n");
    for c in COMMANDS {
        out.push_str(&format!("  {:<10} {}\n", c.name, c.summary));
    }
    out.push_str("\n`janus <subcommand> --help` lists that subcommand's options.\n");
    out
}

fn main() {
    let args = Args::from_env();
    let cmd = match args.command.as_deref() {
        Some(c) => c,
        None => {
            if args.flag("help") {
                print!("{}", global_usage());
                return;
            }
            eprint!("{}", global_usage());
            std::process::exit(2);
        }
    };
    let spec = match COMMANDS.iter().find(|s| s.name == cmd) {
        Some(s) => s,
        None => {
            eprintln!("janus: unknown subcommand `{cmd}`\n");
            eprint!("{}", global_usage());
            std::process::exit(2);
        }
    };
    if args.flag("help") {
        print!("{}", spec.help_text());
        return;
    }
    if let Err(e) = spec.validate(&args) {
        eprintln!("{e}");
        std::process::exit(2);
    }
    match cmd {
        "optimize" => cmd_optimize(&args),
        "simulate" => cmd_simulate(&args),
        "ec-rate" => cmd_ec_rate(&args),
        "send" => cmd_send(&args),
        "recv" => cmd_recv(&args),
        "e2e" => cmd_e2e(&args),
        "pool" => cmd_pool(&args),
        "codec" => cmd_codec(&args),
        "serve" => cmd_serve(&args),
        "lint" => cmd_lint(&args),
        _ => unreachable!("spec lookup covers every command"),
    }
}

fn sched_scaled(args: &Args) -> LevelSchedule {
    let scale = args.get_u64("scale", 1);
    if scale <= 1 {
        LevelSchedule::paper_nyx()
    } else {
        LevelSchedule::paper_nyx_scaled(scale)
    }
}

fn cmd_optimize(args: &Args) {
    let lambda = args.get_f64("lambda", 19.0);
    let p = NetParams::paper_default(lambda);
    let sched = sched_scaled(args);
    match args.get_or("mode", "error-bound") {
        "error-bound" => {
            let bytes = sched.total_bytes(sched.num_levels());
            let opt = optimize_parity(&p, bytes);
            println!(
                "Eq.8: λ={lambda}/s → m={} (p_unrec={:.3e}) E[T_total]={:.2}s",
                opt.m, opt.p_unrecoverable, opt.expected_time
            );
        }
        "deadline" => {
            let tau = args.get_f64("tau", 400.0);
            match optimize_deadline_paper(&p, &sched, tau) {
                Some(o) => println!(
                    "Eq.12: λ={lambda}/s τ={tau}s → l={} m={:?} E[ε]={:.3e} time={:.2}s",
                    o.levels, o.m, o.expected_error, o.time
                ),
                None => println!("Eq.12: τ={tau}s infeasible"),
            }
        }
        other => {
            eprintln!("unknown --mode {other}");
            std::process::exit(2);
        }
    }
}

fn cmd_simulate(args: &Args) {
    let seed = args.get_u64("seed", 1);
    let sched = sched_scaled(args);
    let lambda_arg = args.get_or("lambda", "19");
    let lambda_num: f64 = lambda_arg.parse().unwrap_or(383.0);
    let p = NetParams::paper_default(lambda_num);
    let ttl = 1.0 / p.r;
    let levels = sched.num_levels();
    let protocol = args.get_or("protocol", "adaptive");

    let make_loss = |seed: u64| -> Box<dyn janus::sim::LossProcess> {
        if lambda_arg == "hmm" {
            Box::new(HmmLoss::paper_default_with_ttl(seed, ttl))
        } else {
            Box::new(StaticLoss::with_ttl(lambda_num, seed, ttl))
        }
    };

    match protocol {
        "tcp" => {
            let frac = p.lambda / p.r;
            let mut loss = BernoulliLoss::new(frac, seed);
            let res = run_tcp(&mut loss, &p, sched.total_bytes(levels));
            println!(
                "TCP: {:.2}s sent={} lost={} retrans={} timeouts={}",
                res.total_time,
                res.packets_sent,
                res.packets_lost,
                res.retransmissions,
                res.timeouts
            );
        }
        "static" => {
            let m = args.get_usize("m", 0);
            let mut loss = make_loss(seed);
            let res =
                run_guaranteed_error(loss.as_mut(), &p, &sched, levels, &ParityPolicy::Static(m));
            println!(
                "UDP+EC m={m}: {:.2}s rounds={} sent={} lost={} retransFTG={}",
                res.total_time,
                res.rounds,
                res.fragments_sent,
                res.fragments_lost,
                res.ftgs_retransmitted
            );
        }
        "adaptive" => {
            let mut loss = make_loss(seed);
            let policy = ParityPolicy::Adaptive { t_w: 3.0, initial_lambda: p.lambda };
            let res = run_guaranteed_error(loss.as_mut(), &p, &sched, levels, &policy);
            println!(
                "Adaptive (Alg.1): {:.2}s rounds={} sent={} lost={} m-changes={:?}",
                res.total_time, res.rounds, res.fragments_sent, res.fragments_lost, res.m_changes
            );
        }
        "deadline" => {
            let tau = args.get_f64("tau", 400.0);
            let mut loss = make_loss(seed);
            let policy = DeadlinePolicy::Adaptive { t_w: 3.0, initial_lambda: p.lambda };
            match run_guaranteed_time(loss.as_mut(), &p, &sched, tau, &policy) {
                Some(res) => println!(
                    "Deadline (Alg.2) τ={tau}: {:.2}s levels={}/{} ε={:.1e} plans={}",
                    res.total_time,
                    res.levels_recovered,
                    res.levels_sent,
                    res.achieved_eps,
                    res.plan_changes.len()
                ),
                None => println!("Deadline τ={tau}: infeasible"),
            }
        }
        other => {
            eprintln!("unknown --protocol {other}");
            std::process::exit(2);
        }
    }
}

fn cmd_ec_rate(args: &Args) {
    let n = args.get_usize("n", 32);
    let max_m = args.get_usize("max-m", 16);
    let secs = args.get_f64("secs", 0.3);
    println!("r_ec sweep: n={n}, 4096-B fragments (paper §5.2.2)");
    println!("{:>4} {:>16} {:>14}", "m", "fragments/s", "MB/s data");
    for rate in sweep_ec_rates(n, max_m, 4096, secs) {
        println!(
            "{:>4} {:>16.0} {:>14.1}",
            rate.m,
            rate.fragments_per_sec,
            rate.data_bytes_per_sec / 1e6
        );
    }
}

fn cmd_send(args: &Args) {
    let peer = args.get("peer").unwrap_or_else(|| {
        eprintln!("send: --peer <addr:port> required");
        std::process::exit(2);
    });
    let bind = args.get_or("bind", "0.0.0.0:0");
    let rate = args.get_f64("rate", 19_144.0);
    let dim = args.get_usize("dim", 64);
    let seed = args.get_u64("seed", 1);
    // Synthetic refactored payload (native mirror; the PJRT artifacts are
    // exercised by the e2e example).
    let vol = janus::refactor::generate(dim, &janus::refactor::GrfConfig::default(), seed);
    let levels = janus::refactor::decompose(&vol, 4);
    let bytes = janus::refactor::levels_to_bytes(&levels);
    let eps = measured_eps(&vol, &levels);
    let contract = match args.get("deadline") {
        Some(tau) => Contract::Deadline(tau.parse().expect("--deadline seconds")),
        None => Contract::Fidelity(eps[3]),
    };
    let dataset = Dataset::new(bytes, eps).expect("synthetic dataset is well-formed");
    let spec = TransferSpec::builder()
        .contract(contract)
        .net(NetParams { r: rate, ..NetParams::paper_default(args.get_f64("lambda", 19.0)) })
        .initial_lambda(args.get_f64("lambda", 19.0))
        .max_duration(Duration::from_secs(args.get_u64("max-secs", 600)))
        .build()
        .expect("send spec");
    let mut transport = UdpTransport::new(bind, peer).expect("resolve addresses");
    let rep = Endpoint::new(spec)
        .send(&mut transport, &dataset, None)
        .expect("send");
    println!(
        "sent {} fragments ({} data) in {:.2}s, {} retransmission passes",
        rep.fragments_sent, rep.data_fragments, rep.duration, rep.passes
    );
}

fn cmd_recv(args: &Args) {
    let bind = args.get("bind").unwrap_or_else(|| {
        eprintln!("recv: --bind <addr:port> required");
        std::process::exit(2);
    });
    let sock = std::net::UdpSocket::bind(bind).expect("bind");
    // Learn the peer from the first datagram, then connect.
    let mut buf = [0u8; 9216];
    let (_, peer) = sock.peek_from(&mut buf).expect("first datagram");
    sock.connect(peer).expect("connect");
    let chan = UdpChannel::from_socket(sock);
    let spec = TransferSpec::builder()
        .lambda_window(args.get_f64("t-w", 3.0))
        .idle_timeout(Duration::from_secs(args.get_u64("idle-secs", 15)))
        .max_duration(Duration::from_secs(args.get_u64("max-secs", 600)))
        .build()
        .expect("recv spec");
    let mut transport = ChannelTransport::new(chan);
    let rep = Endpoint::new(spec)
        .receive(&mut transport, None)
        .expect("recv");
    println!(
        "received {} fragments; levels {}/{} recovered (ε ≤ {:.1e}) in {:.2}s; RS-recovered groups: {}",
        rep.fragments_received,
        rep.levels_recovered,
        rep.levels.len(),
        rep.achieved_eps,
        rep.duration,
        rep.groups_recovered
    );
}

fn cmd_e2e(args: &Args) {
    // Compact version of examples/nyx_workflow.rs; see that example for
    // the fully instrumented (PJRT-artifact) run.
    let dim = args.get_usize("dim", 64);
    let seed = args.get_u64("seed", 1);
    let lambda = args.get_f64("lambda", 383.0);
    let vol = janus::refactor::generate(dim, &janus::refactor::GrfConfig::default(), seed);
    let levels = janus::refactor::decompose(&vol, 4);
    let eps = measured_eps(&vol, &levels);
    let sizes: Vec<u64> = levels.iter().map(|l| (l.len() * 4) as u64).collect();
    println!("volume {dim}³, levels {sizes:?} bytes, ε {eps:?}");
    let sched = LevelSchedule::new(sizes, eps.clone());
    let p = NetParams::paper_default(lambda);
    let mut loss = StaticLoss::with_ttl(lambda, seed, 1.0 / p.r);
    let res = run_guaranteed_error(
        &mut loss,
        &p,
        &sched,
        4,
        &ParityPolicy::Adaptive { t_w: 3.0, initial_lambda: lambda },
    );
    println!(
        "adaptive transfer: {:.3}s (sim), rounds={} lost={}",
        res.total_time, res.rounds, res.fragments_lost
    );
}

fn cmd_pool(args: &Args) {
    use janus::testkit::{loss_transport_pair, LossTrace};

    let streams = args.get_usize_in("streams", 4, 1, 255);
    let loss = args.get_f64("loss", 0.02);
    let mb = args.get_usize("mb", 8);
    let seed = args.get_u64("seed", 1);
    let rate = args.get_f64("rate", 100_000.0);

    // Synthetic levels with the Nyx ε ladder shape.
    let mut rng = janus::util::Pcg64::seeded(seed);
    let total = mb * 1024 * 1024;
    let sizes = [total / 10, total * 3 / 10, total * 6 / 10];
    let eps = vec![0.004, 0.0005, 0.0000001];
    let levels: Vec<Vec<u8>> = sizes
        .iter()
        .map(|&sz| {
            let mut v = vec![0u8; sz.max(1)];
            rng.fill_bytes(&mut v);
            v
        })
        .collect();
    let dataset = Dataset::new(levels, eps).expect("synthetic dataset is well-formed");

    let spec = TransferSpec::builder()
        .contract(Contract::Fidelity(1e-7))
        .streams(streams)
        .net(NetParams { t: 0.0005, r: rate, lambda: 0.0, n: 32, s: 4096 })
        .initial_lambda(loss * rate * streams as f64)
        .lambda_window(0.25)
        .idle_timeout(Duration::from_secs(10))
        .max_duration(Duration::from_secs(600))
        .build()
        .expect("pool spec");
    let (st, rt) =
        loss_transport_pair(streams, |w| LossTrace::seeded(loss, seed ^ (w as u64 + 1)));
    let start = std::time::Instant::now();
    let report = run_pair(&spec, st, rt, &dataset, None, None).expect("pool transfer");
    let wall = start.elapsed().as_secs_f64();
    let bytes = dataset.total_bytes() as f64;
    for (got, want) in report.received.levels.iter().zip(&dataset.levels) {
        assert_eq!(got.as_ref().unwrap(), want, "delivery must be byte-exact");
    }
    println!(
        "pool: {streams} streams × {rate:.0} frag/s, {:.1} MB at {:.1}% loss",
        bytes / 1e6,
        loss * 100.0
    );
    println!(
        "  sender: {} fragments ({} data) in {} pass(es), λ̂ history {:?}",
        report.sent.fragments_sent,
        report.sent.data_fragments,
        report.sent.passes + 1,
        report
            .sent
            .lambda_history
            .iter()
            .map(|l| format!("{l:.0}"))
            .collect::<Vec<_>>()
    );
    println!(
        "  receiver: {} fragments, {} RS-recovered groups, {} levels byte-exact",
        report.received.fragments_received,
        report.received.groups_recovered,
        report.received.levels_recovered
    );
    println!(
        "  throughput: {:.1} MB/s aggregate ({wall:.2}s wall)",
        bytes / 1e6 / wall
    );
}

fn cmd_codec(args: &Args) {
    use janus::api::{EventLog, TransferEvent};
    use janus::codec::{encode, CodecConfig};
    use janus::testkit::{loss_transport_pair, LossTrace};

    let dim = args.get_usize("dim", 32);
    let seed = args.get_u64("seed", 1);
    let levels = args.get_usize("levels", 3);
    let planes = args.get_usize_in("planes", 24, 1, 30) as u8;
    let loss = args.get_f64("loss", 0.05);
    let streams = args.get_usize_in("streams", 1, 1, 255);
    let rate = args.get_f64("rate", 100_000.0);
    let ladder: Vec<f64> = args
        .get_or("eps", "4e-3,5e-4,5e-5")
        .split(',')
        .map(|s| s.trim().parse().unwrap_or_else(|_| {
            eprintln!("codec: bad --eps entry `{s}`");
            std::process::exit(2);
        }))
        .collect();

    // 1. Synthetic scientific volume + progressive encode.
    let vol = janus::refactor::generate(dim, &janus::refactor::GrfConfig::default(), seed);
    let cfg = CodecConfig { levels, ladder: ladder.clone(), max_planes: planes };
    let enc = encode(&vol, &cfg).unwrap_or_else(|e| {
        eprintln!("codec: {e}");
        std::process::exit(2);
    });
    println!(
        "codec: {dim}³ volume ({} B raw) → {} rungs, {} B container ({:.1}% of raw)",
        enc.raw_bytes(),
        enc.rungs.len(),
        enc.total_bytes(),
        100.0 * enc.total_bytes() as f64 / enc.raw_bytes() as f64
    );
    println!(
        "{:>5} {:>10} {:>12} {:>12} {:>14} {:>6}",
        "rung", "bytes", "ε requested", "ε measured", "planes/level", "cuts"
    );
    for r in 0..enc.rungs.len() {
        println!(
            "{:>5} {:>10} {:>12.3e} {:>12.3e} {:>14} {:>6}",
            r + 1,
            enc.rungs[r].len(),
            ladder[r],
            enc.eps[r],
            format!("{:?}", enc.planes[r]),
            enc.cuts[r].len()
        );
    }

    // 2. Transfer through the facade over a deterministic lossy wire.
    let contract = match args.get("deadline") {
        Some(tau) => Contract::Deadline(tau.parse().expect("--deadline seconds")),
        None => Contract::Fidelity(*enc.eps.last().expect("non-empty ladder")),
    };
    let dataset = Dataset::from_encoded(enc);
    let spec = TransferSpec::builder()
        .contract(contract)
        .streams(streams)
        .net(janus::model::NetParams { t: 0.0005, r: rate, lambda: 0.0, n: 32, s: 4096 })
        .initial_lambda(loss * rate * streams as f64)
        .lambda_window(0.25)
        .max_duration(Duration::from_secs(600))
        .build()
        .expect("codec spec");
    let (st, rt) =
        loss_transport_pair(spec.streams(), |w| LossTrace::seeded(loss, seed ^ (w as u64 + 0x51)));
    let mut log = EventLog::new();
    let report = run_pair(&spec, st, rt, &dataset, None, Some(&mut log)).expect("codec transfer");
    if let Some(dl) = report.sent.deadline() {
        println!(
            "deadline: τ = {:.4}s, virtual clock {:.4}s ({}), advertised ε ≤ {:.3e}",
            dl.tau,
            dl.virtual_elapsed,
            if dl.met { "met" } else { "MISSED" },
            dl.advertised_eps
        );
    }

    // 3. Progressive decode: the facade already replayed the prefix.
    for e in log.filtered(|e| matches!(e, TransferEvent::LevelDecoded { .. })) {
        if let TransferEvent::LevelDecoded { level, achieved_eps } = e {
            println!("  LevelDecoded: rung {} → ε ≤ {achieved_eps:.3e}", level + 1);
        }
    }
    let codec = match report.received.codec.as_ref() {
        Some(c) => c,
        None => {
            println!("transfer delivered no decodable rung (deadline too tight?)");
            return;
        }
    };
    let out = report
        .received
        .decode_volume()
        .expect("codec stream")
        .expect("delivered prefix decodes");
    let true_err = vol.linf_rel_error(&out.volume);
    println!(
        "transfer: {} fragments, {} RS-recovered groups, {} pass(es); \
         {} / {} rungs decoded, planes {:?}",
        report.sent.fragments_sent,
        report.received.groups_recovered,
        report.sent.passes + 1,
        codec.rungs_decoded,
        dataset.levels.len(),
        codec.planes_used
    );
    println!(
        "achieved: reported ε ≤ {:.3e}, measured ε = {:.3e} → {}",
        out.achieved_eps,
        true_err,
        if true_err <= out.achieved_eps + 1e-12 { "WITHIN BOUND ✓" } else { "VIOLATED ✗" }
    );
}

fn cmd_serve(args: &Args) {
    use janus::coordinator::receiver::ReceiverConfig;
    use janus::coordinator::sender::SenderConfig;
    use janus::serve::{AdmissionPolicy, Daemon, ServeConfig, TimeMode, TransferOutcome};
    use janus::testkit::{FragmentLossChannel, LossTrace};
    use janus::transport::mem_pair;

    let transfers = args.get_usize_in("transfers", 64, 1, 65_536);
    let kb = args.get_usize("kb", 64);
    let loss = args.get_f64("loss", 0.02);
    let rate = args.get_f64("rate", 200_000.0);
    let tenants_n = args.get_usize_in("tenants", 4, 1, transfers);
    let budget_kb = args.get_u64("budget-kb", 0);
    let seed = args.get_u64("seed", 1);

    let mut daemon =
        Daemon::new(ServeConfig { mode: TimeMode::Virtual, ..ServeConfig::default() });
    // One shared socket pair: every sender machine talks through `tx`,
    // every receiver machine through `rx`; fragments drop per the trace.
    let (a, b) = mem_pair();
    let trace = LossTrace::seeded(loss, seed);
    let tx = daemon.add_socket(Box::new(FragmentLossChannel::new(a, trace)));
    let rx = daemon.add_socket(Box::new(b));
    let budget = if budget_kb == 0 { u64::MAX } else { budget_kb * 1024 };
    let tenants: Vec<usize> = (0..tenants_n)
        .map(|i| daemon.add_tenant(&format!("tenant-{i}"), budget, AdmissionPolicy::Queue))
        .collect();

    let scfg = SenderConfig {
        net: NetParams { t: 0.0005, r: rate, lambda: 0.0, n: 32, s: 4096 },
        contract: Contract::Fidelity(1e-7),
        initial_lambda: loss * rate,
        max_duration: Duration::from_secs(600),
        plane_cuts: Vec::new(),
        adapt: janus::api::AdaptConfig::fixed(),
    };
    let rcfg = ReceiverConfig {
        t_w: 3.0,
        idle_timeout: Duration::from_secs(60),
        max_duration: Duration::from_secs(600),
    };
    let mut rng = janus::util::Pcg64::seeded(seed ^ 0xC0FFEE);
    let mut payloads = Vec::with_capacity(transfers);
    for t in 0..transfers {
        let mut level = vec![0u8; (kb * 1024).max(1)];
        rng.fill_bytes(&mut level);
        let id = t as u32;
        let tenant = tenants[t % tenants_n];
        daemon
            .register_sender(tenant, tx, id, scfg.clone(), vec![level.clone()], vec![1e-7])
            .expect("register sender");
        daemon
            .register_receiver(tenant, rx, id, rcfg.clone(), (kb * 1024) as u64)
            .expect("register receiver");
        payloads.push(level);
    }
    let queued = daemon.queued_transfers();

    let start = std::time::Instant::now();
    daemon.run_to_completion().expect("serve loop");
    let wall = start.elapsed().as_secs_f64();

    let finished = daemon.take_finished();
    let mut exact = 0usize;
    let mut failed = 0usize;
    let mut fragments = 0u64;
    for f in &finished {
        match &f.outcome {
            TransferOutcome::Received(rep) => {
                let want = &payloads[f.id as usize];
                let got = rep.levels[0].as_deref().unwrap_or(&[]);
                if got == want.as_slice() {
                    exact += 1;
                }
            }
            TransferOutcome::Sent(rep) => fragments += rep.fragments_sent,
            TransferOutcome::Failed(e) => {
                failed += 1;
                eprintln!("  transfer {} failed: {e}", f.id);
            }
        }
    }
    println!(
        "serve: {transfers} transfers × {kb} KB over one shared socket pair \
         ({tenants_n} tenants, {:.1}% loss, {queued} queued at start)",
        loss * 100.0
    );
    println!(
        "  {exact}/{transfers} byte-exact, {failed} failed, {fragments} fragments sent, \
         {} stray datagrams dropped",
        daemon.dropped_untagged() + daemon.dropped_unknown()
    );
    println!(
        "  {:.2}s wall for {:.1} MB aggregate ({:.1} MB/s through the event loop)",
        wall,
        (transfers * kb) as f64 / 1024.0,
        (transfers * kb) as f64 / 1024.0 / wall.max(1e-9)
    );
    if exact != transfers || failed != 0 {
        std::process::exit(1);
    }
}

fn measured_eps(vol: &janus::refactor::Volume, levels: &[Vec<f32>]) -> Vec<f64> {
    let refs: Vec<&[f32]> = levels.iter().map(|l| l.as_slice()).collect();
    let mut eps: Vec<f64> = (1..=levels.len())
        .map(|u| {
            let approx = janus::refactor::reconstruct(&refs, u, levels.len(), vol.d);
            vol.linf_rel_error(&approx).max(1e-12)
        })
        .collect();
    // Guard strict monotonicity for LevelSchedule.
    for i in 1..eps.len() {
        if eps[i] >= eps[i - 1] {
            eps[i] = eps[i - 1] * 0.999;
        }
    }
    eps
}

fn cmd_lint(args: &Args) {
    let root = match args.get("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => match janus::analysis::workspace_root() {
            Some(r) => r,
            None => {
                eprintln!(
                    "janus lint: cannot find the workspace root (looked for rust/src/lib.rs \
                     above the current directory); pass --root <dir>"
                );
                std::process::exit(2);
            }
        },
    };
    let violations = match janus::analysis::lint_root(&root) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("janus lint: failed to load {}: {e}", root.display());
            std::process::exit(2);
        }
    };
    if violations.is_empty() {
        println!("janus lint: clean ({} rules)", janus::analysis::rules::RULES.len());
        return;
    }
    for v in &violations {
        eprintln!("{v}");
    }
    eprintln!("janus lint: {} violation(s)", violations.len());
    std::process::exit(1);
}
