//! In-tree static analysis: the `janus lint` rule engine (DESIGN.md §13).
//!
//! JANUS's correctness story rests on contracts the compiler never
//! checks: the sans-IO engine promises "every clock is an explicit
//! `Instant` parameter", the datapath promises zero steady-state
//! allocation, the wire format promises pinned discriminants, the SIMD
//! kernels promise their `unsafe` is sound, and the workspace promises
//! zero external dependencies. This module turns those promises into
//! machine-checked rules: a comment/string-aware line scanner
//! ([`scan`]) feeds a catalog of project-specific rules ([`rules`]),
//! and `tests/lint_gate.rs` fails `cargo test` on any violation.
//!
//! The rules run over a [`SourceTree`] — an in-memory snapshot of the
//! workspace sources — so the gate test can also run them over
//! *mutated* copies: every rule is mutation-tested by seeding a
//! violation and asserting the rule goes red.
//!
//! Zero dependencies by design: no `syn`, no filesystem walker crate.
//! The scanner is a byte-wise state machine and the loader is a small
//! recursive `std::fs` walk.

pub mod rules;
pub mod scan;

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The checked-in per-file unsafe budget (rule `unsafe-audit`).
pub const DEFAULT_BUDGET: &str = include_str!("unsafe_budget.txt");

/// One rule violation: `path:line: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Rule name from [`rules::RULES`].
    pub rule: &'static str,
    /// Workspace-relative path with `/` separators.
    pub path: String,
    /// 1-based line, or 0 when the violation is file-level.
    pub line: usize,
    /// Human-readable description with the fix direction.
    pub message: String,
}

impl Violation {
    pub fn new(rule: &'static str, path: &str, line: usize, message: String) -> Self {
        Violation { rule, path: path.to_string(), line, message }
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// One source file: workspace-relative path (always `/`-separated) and
/// full text.
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// An in-memory snapshot of the workspace sources the rules care
/// about: every `.rs` file under `rust/src/` plus both Cargo.tomls.
/// Tests mutate copies via [`SourceTree::replace_file`]/
/// [`SourceTree::push_file`] to seed violations.
#[derive(Debug, Clone, Default)]
pub struct SourceTree {
    files: Vec<SourceFile>,
}

impl SourceTree {
    /// Load the tree from a workspace root (the directory holding the
    /// top-level `Cargo.toml` and `rust/`).
    pub fn load(root: &Path) -> io::Result<SourceTree> {
        let mut tree = SourceTree::default();
        let src = root.join("rust").join("src");
        if !src.is_dir() {
            return Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("{} is not a workspace root (no rust/src)", root.display()),
            ));
        }
        walk_rs(&src, Path::new("rust/src"), &mut tree.files)?;
        for rel in ["Cargo.toml", "rust/Cargo.toml"] {
            let text = fs::read_to_string(root.join(rel))?;
            tree.files.push(SourceFile { path: rel.to_string(), text });
        }
        tree.files.sort_by(|a, b| a.path.cmp(&b.path));
        Ok(tree)
    }

    /// All `.rs` files, in path order.
    pub fn rs_files(&self) -> impl Iterator<Item = &SourceFile> {
        self.files.iter().filter(|f| f.path.ends_with(".rs"))
    }

    /// Look up a file by workspace-relative path.
    pub fn file(&self, path: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.path == path)
    }

    /// Add a file (tests: seed a synthetic violating file).
    pub fn push_file(&mut self, path: &str, text: &str) {
        self.files.push(SourceFile { path: path.to_string(), text: text.to_string() });
    }

    /// Replace an existing file's text, returning whether it existed
    /// (tests: mutate a real file and assert the rule goes red).
    pub fn replace_file(&mut self, path: &str, text: &str) -> bool {
        match self.files.iter_mut().find(|f| f.path == path) {
            Some(f) => {
                f.text = text.to_string();
                true
            }
            None => false,
        }
    }
}

/// Recursive walk collecting `.rs` files with stable `/`-separated
/// relative paths, in sorted order for determinism across platforms.
fn walk_rs(dir: &Path, rel: &Path, out: &mut Vec<SourceFile>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let path = entry.path();
        let rel = rel.join(&*name);
        if path.is_dir() {
            walk_rs(&path, &rel, out)?;
        } else if name.ends_with(".rs") {
            let text = fs::read_to_string(&path)?;
            let rel = rel.to_string_lossy().replace('\\', "/");
            out.push(SourceFile { path: rel, text });
        }
    }
    Ok(())
}

/// Find the workspace root: prefer the compile-time manifest dir
/// (`rust/`, whose parent is the root), falling back to walking up
/// from the current directory looking for `rust/src/lib.rs`.
pub fn workspace_root() -> Option<PathBuf> {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    if let Some(parent) = manifest.parent() {
        if parent.join("rust/src/lib.rs").is_file() {
            return Some(parent.to_path_buf());
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust/src/lib.rs").is_file() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Load the tree at `root` and run the whole rule catalog against the
/// checked-in unsafe budget.
pub fn lint_root(root: &Path) -> io::Result<Vec<Violation>> {
    let tree = SourceTree::load(root)?;
    Ok(rules::run_all(&tree, DEFAULT_BUDGET))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_accessors() {
        let mut tree = SourceTree::default();
        tree.push_file("rust/src/a.rs", "fn a() {}\n");
        tree.push_file("Cargo.toml", "[workspace]\n");
        assert_eq!(tree.rs_files().count(), 1);
        assert!(tree.file("Cargo.toml").is_some());
        assert!(tree.replace_file("rust/src/a.rs", "fn b() {}\n"));
        assert!(!tree.replace_file("rust/src/missing.rs", ""));
        assert!(tree.file("rust/src/a.rs").unwrap().text.contains("fn b"));
    }

    #[test]
    fn workspace_root_finds_the_repo() {
        let root = workspace_root().expect("workspace root");
        assert!(root.join("rust/src/analysis/mod.rs").is_file());
    }

    #[test]
    fn real_tree_loads_and_lints_clean() {
        let root = workspace_root().expect("workspace root");
        let violations = lint_root(&root).expect("lint");
        for v in &violations {
            eprintln!("{v}");
        }
        assert!(violations.is_empty(), "{} violations on the real tree", violations.len());
    }
}
