//! The `janus lint` rule catalog (DESIGN.md §13). Each rule is a pure
//! function from a [`SourceTree`] to a list of [`Violation`]s, so the
//! gate test can run the same rules on both the real tree and mutated
//! in-memory copies (mutation tests: every rule must go red when its
//! invariant is seeded broken).

use super::scan::{self, Line};
use super::{SourceTree, Violation};
use std::collections::BTreeMap;

/// Rule names, in the order `run_all` executes them.
pub const RULES: &[&str] =
    &["sans-io-clock", "unsafe-audit", "datapath-no-alloc", "wire-pin", "no-deps"];

// ---------------------------------------------------------------------------
// Rule 1: sans-io-clock
// ---------------------------------------------------------------------------

/// Directories under the explicit-clock contract (DESIGN.md §10): the
/// machines take `Instant` parameters; only drivers may read the OS
/// clock.
const CLOCK_SCOPES: &[&str] = &["rust/src/engine/", "rust/src/serve/"];

/// Whole files allowed to touch the real clock: the blocking drivers,
/// whose entire job is pumping a sans-IO machine on real time.
const CLOCK_FILE_ALLOWLIST: &[&str] = &["rust/src/engine/driver.rs", "rust/src/serve/transport.rs"];

/// Banned tokens (matched on the comment/string-stripped shadow).
const CLOCK_TOKENS: &[&str] = &["Instant::now", "SystemTime::now", "thread::sleep"];

/// Inline waiver marker: on the flagged line or anywhere in the
/// contiguous comment block directly above it (waiver justifications
/// are encouraged to run long).
const CLOCK_WAIVER: &str = "lint: allow(sans-io-clock)";

/// Is the flagged line at `idx` covered by a waiver in the contiguous
/// `//` comment block directly above? Stops at the first code line, so
/// a waiver never leaks past the statement it annotates.
fn clock_waived_above(lines: &[Line], idx: usize) -> bool {
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let t = lines[i].raw.trim();
        if !t.starts_with("//") {
            return false;
        }
        if t.contains(CLOCK_WAIVER) {
            return true;
        }
    }
    false
}

/// No wall-clock reads inside the sans-IO scope, outside the allowlist.
pub fn sans_io_clock(tree: &SourceTree) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in tree.rs_files() {
        if !CLOCK_SCOPES.iter().any(|s| f.path.starts_with(s)) {
            continue;
        }
        if CLOCK_FILE_ALLOWLIST.contains(&f.path.as_str()) {
            continue;
        }
        let lines = scan::strip(&f.text);
        for (idx, line) in lines.iter().enumerate() {
            // Test modules sit at the bottom of each file; the real
            // clock is fair game there.
            if line.raw.contains("#[cfg(test)]") {
                break;
            }
            let Some(tok) = CLOCK_TOKENS.iter().find(|t| scan::has_token(&line.code, t)) else {
                continue;
            };
            let waived = line.raw.contains(CLOCK_WAIVER) || clock_waived_above(&lines, idx);
            if waived {
                continue;
            }
            out.push(Violation::new(
                "sans-io-clock",
                &f.path,
                idx + 1,
                format!(
                    "`{tok}` in sans-IO scope; pass `Instant` in, or waive with \
                     `// {CLOCK_WAIVER}: <reason>`"
                ),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 2: unsafe-audit
// ---------------------------------------------------------------------------

/// Path of the checked-in per-file unsafe budget.
const BUDGET_PATH: &str = "rust/src/analysis/unsafe_budget.txt";

/// Every `unsafe` token needs a `SAFETY:` justification on the same
/// line or in the contiguous comment/attribute block above, and the
/// per-file token counts must match the checked-in budget exactly
/// (both directions: new unsafe and stale budget entries fail).
pub fn unsafe_audit(tree: &SourceTree, budget: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    let mut pinned: BTreeMap<&str, usize> = BTreeMap::new();
    for (idx, line) in budget.lines().enumerate() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        match (it.next(), it.next().and_then(|n| n.parse().ok()), it.next()) {
            (Some(path), Some(count), None) => {
                pinned.insert(path, count);
            }
            _ => out.push(Violation::new(
                "unsafe-audit",
                BUDGET_PATH,
                idx + 1,
                format!("malformed budget line `{t}` (want `<path> <count>`)"),
            )),
        }
    }
    let mut seen: BTreeMap<String, usize> = BTreeMap::new();
    for f in tree.rs_files() {
        let lines = scan::strip(&f.text);
        let mut count = 0;
        for (idx, line) in lines.iter().enumerate() {
            let c = scan::count_token(&line.code, "unsafe");
            if c == 0 {
                continue;
            }
            count += c;
            if !has_safety_comment(&lines, idx) {
                out.push(Violation::new(
                    "unsafe-audit",
                    &f.path,
                    idx + 1,
                    "`unsafe` without a `// SAFETY:` comment immediately above".to_string(),
                ));
            }
        }
        if count > 0 {
            seen.insert(f.path.clone(), count);
        }
    }
    for (path, &want) in &pinned {
        let got = seen.get(*path).copied().unwrap_or(0);
        if got != want {
            out.push(Violation::new(
                "unsafe-audit",
                path,
                0,
                format!("unsafe budget mismatch: counted {got}, budget pins {want}"),
            ));
        }
    }
    for (path, &got) in &seen {
        if !pinned.contains_key(path.as_str()) {
            out.push(Violation::new(
                "unsafe-audit",
                path,
                0,
                format!("{got} unsafe token(s) but no entry in {BUDGET_PATH}"),
            ));
        }
    }
    out
}

/// `SAFETY:` (or a `# Safety` doc heading) on this raw line, or in the
/// contiguous run of comment/attribute lines directly above it.
fn has_safety_comment(lines: &[Line], idx: usize) -> bool {
    let justifies = |raw: &str| raw.contains("SAFETY:") || raw.contains("# Safety");
    if justifies(&lines[idx].raw) {
        return true;
    }
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let t = lines[i].raw.trim();
        if t.starts_with("//") || t.starts_with("#[") || t.starts_with("#![") {
            if justifies(t) {
                return true;
            }
        } else {
            return false;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Rule 3: datapath-no-alloc
// ---------------------------------------------------------------------------

/// Region markers: a line whose first token is the marker comment.
/// Matching on the line *prefix* (not `contains`) keeps prose that
/// merely mentions the markers — like this module — from opening
/// phantom regions.
const DATAPATH_OPEN: &str = "// lint: datapath";
const DATAPATH_CLOSE: &str = "// lint: end-datapath";

/// Allocation tokens banned inside marked regions. The counting
/// allocator (`tests/alloc_datapath.rs`) catches these dynamically on
/// the paths it drives; this rule catches them lexically everywhere.
const ALLOC_TOKENS: &[&str] = &["vec!", "Vec::new", ".to_vec()", ".clone()"];

/// No allocation tokens between `// lint: datapath` and
/// `// lint: end-datapath`; unbalanced markers are violations too.
pub fn datapath_no_alloc(tree: &SourceTree) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in tree.rs_files() {
        let lines = scan::strip(&f.text);
        let mut open: Option<usize> = None;
        for (idx, line) in lines.iter().enumerate() {
            let marker = line.raw.trim_start();
            if marker.starts_with(DATAPATH_CLOSE) {
                if open.is_none() {
                    out.push(Violation::new(
                        "datapath-no-alloc",
                        &f.path,
                        idx + 1,
                        "stray `lint: end-datapath` (no open region)".to_string(),
                    ));
                }
                open = None;
                continue;
            }
            if marker.starts_with(DATAPATH_OPEN) {
                if open.is_some() {
                    out.push(Violation::new(
                        "datapath-no-alloc",
                        &f.path,
                        idx + 1,
                        "nested `lint: datapath` (close the previous region first)".to_string(),
                    ));
                }
                open = Some(idx);
                continue;
            }
            if open.is_none() {
                continue;
            }
            for tok in ALLOC_TOKENS {
                if scan::has_token(&line.code, tok) {
                    out.push(Violation::new(
                        "datapath-no-alloc",
                        &f.path,
                        idx + 1,
                        format!("`{tok}` inside a `lint: datapath` region"),
                    ));
                }
            }
        }
        if let Some(start) = open {
            out.push(Violation::new(
                "datapath-no-alloc",
                &f.path,
                start + 1,
                "unclosed `lint: datapath` region".to_string(),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 4: wire-pin
// ---------------------------------------------------------------------------

/// The wire-format source of truth.
const PACKET_FILE: &str = "rust/src/coordinator/packet.rs";

/// Pinned `Packet` discriminants: the on-wire kind bytes. Renumbering
/// any of these breaks cross-version interop — a new variant gets a
/// new number appended here, existing numbers never move.
const PINNED_KINDS: &[(&str, u64)] = &[
    ("KIND_FRAGMENT", 1),
    ("KIND_LAMBDA", 2),
    ("KIND_END", 3),
    ("KIND_LOST", 4),
    ("KIND_DONE", 5),
    ("KIND_MANIFEST", 6),
    ("KIND_MANIFEST_ACK", 7),
    ("KIND_STREAM_END", 8),
    ("KIND_PASS_STATS", 9),
    ("KIND_LEVEL_SHED", 10),
    ("KIND_TRANSFER_TAG", 11),
    ("KIND_REPAIR", 12),
    ("KIND_GROUP_ACK", 13),
];

/// Other pinned wire constants from the same file.
const PINNED_CONSTS: &[(&str, u64)] = &[("CONTRACT_FOUNTAIN", 0x10), ("TAG_BYTES", 5)];

/// Cross-check packet.rs constants against the pinned tables: every
/// pinned name must exist with the pinned value, and every `KIND_*`
/// constant in the file must be pinned.
pub fn wire_pin(tree: &SourceTree) -> Vec<Violation> {
    let Some(f) = tree.file(PACKET_FILE) else {
        return vec![Violation::new("wire-pin", PACKET_FILE, 0, "file missing".to_string())];
    };
    let mut out = Vec::new();
    let mut found: BTreeMap<String, (usize, Option<u64>)> = BTreeMap::new();
    for (idx, line) in scan::strip(&f.text).iter().enumerate() {
        if let Some((name, value)) = parse_const_line(&line.code) {
            found.insert(name.to_string(), (idx + 1, value));
        }
    }
    for &(name, want) in PINNED_KINDS.iter().chain(PINNED_CONSTS) {
        match found.get(name) {
            None => out.push(Violation::new(
                "wire-pin",
                PACKET_FILE,
                0,
                format!("pinned constant `{name}` not found"),
            )),
            Some(&(line, None)) => out.push(Violation::new(
                "wire-pin",
                PACKET_FILE,
                line,
                format!("pinned constant `{name}` has a non-literal value"),
            )),
            Some(&(line, Some(got))) if got != want => out.push(Violation::new(
                "wire-pin",
                PACKET_FILE,
                line,
                format!("wire constant `{name}` = {got}, pinned table says {want}"),
            )),
            Some(_) => {}
        }
    }
    for (name, &(line, _)) in &found {
        let pinned = PINNED_KINDS.iter().any(|&(n, _)| n == name);
        if name.starts_with("KIND_") && !pinned {
            out.push(Violation::new(
                "wire-pin",
                PACKET_FILE,
                line,
                format!("new discriminant `{name}` is not in the pinned table (analysis/rules.rs)"),
            ));
        }
    }
    out
}

/// Parse `[pub [(crate)]] const NAME: TY = <int literal>;` from a
/// stripped code line. Returns `(name, None)` when the value is not a
/// plain integer literal.
fn parse_const_line(code: &str) -> Option<(&str, Option<u64>)> {
    let rest = code.trim_start();
    let rest = rest.strip_prefix("pub(crate) ").unwrap_or(rest);
    let rest = rest.strip_prefix("pub ").unwrap_or(rest);
    let rest = rest.strip_prefix("const ")?;
    let name = rest[..rest.find(':')?].trim();
    if name.is_empty() || !name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_') {
        return None;
    }
    let val = rest[rest.find('=')? + 1..].trim().trim_end_matches(';').trim();
    Some((name, parse_int_literal(val)))
}

/// Parse a decimal or `0x` integer literal, `_` separators allowed.
fn parse_int_literal(s: &str) -> Option<u64> {
    let s: String = s.chars().filter(|&c| c != '_').collect();
    if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

// ---------------------------------------------------------------------------
// Rule 5: no-deps
// ---------------------------------------------------------------------------

/// Both manifests stay dependency-free. The single sanctioned entry is
/// the pjrt-gated `xla` path dependency (normally commented out).
const MANIFESTS: &[&str] = &["Cargo.toml", "rust/Cargo.toml"];

/// Every `*dependencies*` section in both Cargo.tomls must be empty,
/// except an `xla` path entry (the pjrt escape hatch).
pub fn no_deps(tree: &SourceTree) -> Vec<Violation> {
    let mut out = Vec::new();
    for path in MANIFESTS {
        let Some(f) = tree.file(path) else {
            out.push(Violation::new("no-deps", path, 0, "manifest missing".to_string()));
            continue;
        };
        let mut section = String::new();
        for (idx, line) in f.text.lines().enumerate() {
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            if t.starts_with('[') {
                section = t.trim_matches(|c| c == '[' || c == ']').to_string();
                continue;
            }
            if !section.ends_with("dependencies") {
                continue;
            }
            if t.starts_with("xla") && t.contains("path") {
                continue;
            }
            out.push(Violation::new(
                "no-deps",
                path,
                idx + 1,
                format!("dependency `{t}` in [{section}]: the workspace is zero-dependency"),
            ));
        }
    }
    out
}

/// Run the whole catalog against `tree` with the given unsafe budget.
pub fn run_all(tree: &SourceTree, budget: &str) -> Vec<Violation> {
    let mut out = sans_io_clock(tree);
    out.extend(unsafe_audit(tree, budget));
    out.extend(datapath_no_alloc(tree));
    out.extend(wire_pin(tree));
    out.extend(no_deps(tree));
    out
}
