//! Comment/string-aware line scanner: the zero-dependency substitute
//! for a real Rust parser (`syn` is not in the offline crate set, and
//! the rules in [`crate::analysis::rules`] only need token-level facts).
//!
//! [`strip`] runs a byte-wise state machine over a source file and
//! returns, per line, the original text plus a "code only" shadow where
//! comments and string/char-literal contents are blanked to spaces.
//! Rules match tokens against the shadow (so a doc comment mentioning
//! `Instant::now()` never fires) and read markers/waivers from the raw
//! text (so `// SAFETY:` and `// lint:` comments stay visible).
//!
//! Handled lexical shapes: `//`-comments, nested `/* */` blocks,
//! `"…"`/`b"…"` strings with escapes, `r"…"`/`r#"…"#` raw strings
//! (any hash depth, `br` included), char literals (`'x'`, `'\n'`,
//! `'"'`), and lifetimes (`'a` is kept as code). Non-ASCII bytes
//! inside blanked regions become spaces, so the shadow stays valid
//! UTF-8 and line numbers always match the raw text.

/// One source line: raw text and its comment/string-stripped shadow.
#[derive(Debug, Clone)]
pub struct Line {
    pub raw: String,
    pub code: String,
}

/// Is `b` an identifier byte (the token-boundary alphabet)?
fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Detect a raw-string opener at `i` (pointing at `r`): `r"`, `r#"`,
/// `br"`, … — returns (hash count, index just past the opening quote).
fn raw_string_open(bytes: &[u8], i: usize) -> Option<(usize, usize)> {
    let prev_ok = |p: usize| p == 0 || !is_ident(bytes[p - 1]);
    let start_ok = if bytes[i] != b'r' {
        false
    } else if i >= 1 && bytes[i - 1] == b'b' {
        prev_ok(i - 1)
    } else {
        prev_ok(i)
    };
    if !start_ok {
        return None;
    }
    let mut j = i + 1;
    let mut hashes = 0;
    while j < bytes.len() && bytes[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j < bytes.len() && bytes[j] == b'"' {
        Some((hashes, j + 1))
    } else {
        None
    }
}

/// Strip comments and literal contents from `text`, preserving line
/// structure exactly (see module docs).
pub fn strip(text: &str) -> Vec<Line> {
    let bytes = text.as_bytes();
    let mut code = Vec::with_capacity(bytes.len());
    let mut i = 0;
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(usize),
    }
    let mut st = St::Code;
    while i < bytes.len() {
        let b = bytes[i];
        if b == b'\n' {
            code.push(b'\n');
            i += 1;
            if let St::LineComment = st {
                st = St::Code;
            }
            continue;
        }
        match st {
            St::Code => {
                if b == b'/' && bytes.get(i + 1) == Some(&b'/') {
                    st = St::LineComment;
                    code.extend_from_slice(b"  ");
                    i += 2;
                } else if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    st = St::BlockComment(1);
                    code.extend_from_slice(b"  ");
                    i += 2;
                } else if b == b'"' {
                    st = St::Str;
                    code.push(b' ');
                    i += 1;
                } else if b == b'r' {
                    if let Some((hashes, past_quote)) = raw_string_open(bytes, i) {
                        for _ in i..past_quote {
                            code.push(b' ');
                        }
                        st = St::RawStr(hashes);
                        i = past_quote;
                    } else {
                        code.push(b);
                        i += 1;
                    }
                } else if b == b'\'' {
                    if bytes.get(i + 1) == Some(&b'\\') {
                        // Escaped char literal: blank through the close.
                        let mut j = i + 2;
                        while j < bytes.len() {
                            if bytes[j] == b'\\' {
                                j += 2;
                            } else if bytes[j] == b'\'' {
                                j += 1;
                                break;
                            } else {
                                j += 1;
                            }
                        }
                        let j = j.min(bytes.len());
                        for _ in i..j {
                            code.push(b' ');
                        }
                        i = j;
                    } else if bytes.get(i + 2) == Some(&b'\'') {
                        // Simple one-byte char literal, `'"'` included.
                        code.extend_from_slice(b"   ");
                        i += 3;
                    } else {
                        // Lifetime: keep the tick as code.
                        code.push(b);
                        i += 1;
                    }
                } else {
                    code.push(b);
                    i += 1;
                }
            }
            St::LineComment => {
                code.push(b' ');
                i += 1;
            }
            St::BlockComment(depth) => {
                if b == b'*' && bytes.get(i + 1) == Some(&b'/') {
                    code.extend_from_slice(b"  ");
                    i += 2;
                    st = if depth == 1 {
                        St::Code
                    } else {
                        St::BlockComment(depth - 1)
                    };
                } else if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    code.extend_from_slice(b"  ");
                    i += 2;
                    st = St::BlockComment(depth + 1);
                } else {
                    code.push(b' ');
                    i += 1;
                }
            }
            St::Str => {
                if b == b'\\' {
                    code.push(b' ');
                    if i + 1 < bytes.len() && bytes[i + 1] != b'\n' {
                        code.push(b' ');
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if b == b'"' {
                    code.push(b' ');
                    i += 1;
                    st = St::Code;
                } else {
                    code.push(b' ');
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                let hashes_follow =
                    bytes[i + 1..].iter().take(hashes).filter(|&&h| h == b'#').count() == hashes;
                if b == b'"' && hashes_follow {
                    for _ in 0..=hashes {
                        code.push(b' ');
                    }
                    i += 1 + hashes;
                    st = St::Code;
                } else {
                    code.push(b' ');
                    i += 1;
                }
            }
        }
    }
    let code = String::from_utf8(code).expect("blanked shadow stays valid UTF-8");
    text.lines()
        .zip(code.lines().chain(std::iter::repeat("")))
        .map(|(raw, shadow)| Line { raw: raw.to_string(), code: shadow.to_string() })
        .collect()
}

/// Occurrences of `tok` in `code` at identifier boundaries: a match may
/// not be flanked by identifier bytes when the token itself starts/ends
/// with one (`unsafe` never matches inside `unsafe_code`; punctuated
/// tokens like `.to_vec()` need no boundary on the punctuation side).
pub fn count_token(code: &str, tok: &str) -> usize {
    let cb = code.as_bytes();
    let tb = tok.as_bytes();
    if tb.is_empty() || cb.len() < tb.len() {
        return 0;
    }
    let first_ident = is_ident(tb[0]);
    let last_ident = is_ident(tb[tb.len() - 1]);
    let mut n = 0;
    let mut from = 0;
    while let Some(pos) = code[from..].find(tok) {
        let s = from + pos;
        let e = s + tb.len();
        let left_ok = !first_ident || s == 0 || !is_ident(cb[s - 1]);
        let right_ok = !last_ident || e >= cb.len() || !is_ident(cb[e]);
        if left_ok && right_ok {
            n += 1;
        }
        from = s + 1;
    }
    n
}

/// Does `code` contain `tok` at identifier boundaries?
pub fn has_token(code: &str, tok: &str) -> bool {
    count_token(code, tok) > 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked() {
        let src = "let a = 1; // Instant::now()\nlet s = \"unsafe\"; /* vec! */ let b = 2;\n";
        let lines = strip(src);
        assert_eq!(lines.len(), 2);
        assert!(!has_token(&lines[0].code, "Instant::now"));
        assert!(lines[0].raw.contains("Instant::now"));
        assert!(!has_token(&lines[1].code, "unsafe"));
        assert!(!has_token(&lines[1].code, "vec!"));
        assert!(has_token(&lines[1].code, "b"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let src = "/* a /* b */ still comment\nunsafe */ let x = 1;\n";
        let lines = strip(src);
        assert!(!has_token(&lines[0].code, "still"));
        assert!(!has_token(&lines[1].code, "unsafe"));
        assert!(has_token(&lines[1].code, "x"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let src = "fn f<'a>(c: char) { if c == '\"' { } let _q: &'a str = \"x\"; }\n";
        let lines = strip(src);
        // The '"' char literal must not open a string (the code after
        // it survives).
        assert!(has_token(&lines[0].code, "str"));
        assert!(lines[0].code.contains("'a"));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let src = "let r = r#\"unsafe \" still\"#; let done = 1;\n";
        let lines = strip(src);
        assert!(!has_token(&lines[0].code, "unsafe"));
        assert!(has_token(&lines[0].code, "done"));
    }

    #[test]
    fn token_boundaries_respected() {
        assert_eq!(count_token("unsafe unsafe_code deny(unsafe_op)", "unsafe"), 1);
        assert_eq!(count_token("self.x.to_vec()", ".to_vec()"), 1);
        assert_eq!(count_token("Vec::with_capacity(4)", "Vec::new"), 0);
        assert_eq!(count_token("vec![0u8; 4] myvec!", "vec!"), 1);
        assert_eq!(count_token("std::thread::sleep(d)", "thread::sleep"), 1);
    }

    #[test]
    fn line_counts_always_match() {
        let src = "a\n\"multi\nline\nstring\"\nb";
        let lines = strip(src);
        assert_eq!(lines.len(), src.lines().count());
        assert!(has_token(lines.last().unwrap().code.as_str(), "b"));
    }
}
