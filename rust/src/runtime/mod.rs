//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the Rust hot path.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `compile` → `execute`). One [`Runtime`] owns the
//! PJRT client and a compile cache keyed by artifact name; an
//! [`Executable`] runs with `f32` buffers in/out. Python authored the
//! artifacts at build time (`make artifacts`); nothing here touches
//! Python.
//!
//! The `xla` crate is not part of the offline vendored set, so all PJRT
//! execution is gated behind the `pjrt` cargo feature. Without it the
//! manifest still parses (so callers can enumerate artifacts) but
//! `load`/`run_f32` return a descriptive error; the native mirror in
//! [`crate::refactor`] covers every code path the tests exercise.

#[cfg(feature = "pjrt")]
use crate::anyhow;
use crate::bail;
use crate::util::err::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded artifact collection (an `artifacts/` directory with the
/// `manifest.tsv` written by `python/compile/aot.py`).
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    dir: PathBuf,
    /// name → (file, input arity)
    manifest: HashMap<String, (String, usize)>,
    cache: HashMap<String, Executable>,
}

/// A compiled artifact ready to execute.
pub struct Executable {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

impl Runtime {
    /// Create a CPU PJRT client over an artifact directory.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let mut manifest = HashMap::new();
        for line in text.lines() {
            if line.starts_with('#') || line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() < 3 {
                bail!("malformed manifest line: {line:?}");
            }
            manifest.insert(
                cols[0].to_string(),
                (cols[1].to_string(), cols[2].parse::<usize>()?),
            );
        }
        #[cfg(feature = "pjrt")]
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime {
            #[cfg(feature = "pjrt")]
            client,
            dir,
            manifest,
            cache: HashMap::new(),
        })
    }

    /// Artifact names available in the manifest.
    pub fn names(&self) -> Vec<&str> {
        self.manifest.keys().map(|s| s.as_str()).collect()
    }

    /// Declared input arity of an artifact.
    pub fn arity(&self, name: &str) -> Option<usize> {
        self.manifest.get(name).map(|&(_, a)| a)
    }

    /// Load + compile an artifact (cached after the first call).
    #[cfg(feature = "pjrt")]
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let (file, _) = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            self.cache.insert(name.to_string(), Executable { exe });
        }
        Ok(&self.cache[name])
    }

    /// Without the `pjrt` feature compilation is unavailable.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.manifest.contains_key(name) {
            bail!("artifact {name:?} not in manifest");
        }
        let _ = &self.dir;
        let _ = &self.cache;
        bail!("PJRT runtime unavailable: build with `--features pjrt` (artifact {name:?})")
    }

    /// Convenience: load and run in one call.
    pub fn run_f32(&mut self, name: &str, inputs: &[F32Input<'_>]) -> Result<Vec<Vec<f32>>> {
        if let Some(arity) = self.arity(name) {
            if arity != inputs.len() {
                bail!("artifact {name} wants {arity} inputs, got {}", inputs.len());
            }
        }
        self.load(name)?;
        self.cache[name].run_f32(inputs)
    }
}

/// One f32 input buffer with an optional shape (1-D when `dims` is None).
pub struct F32Input<'a> {
    pub data: &'a [f32],
    pub dims: Option<&'a [usize]>,
}

impl<'a> F32Input<'a> {
    pub fn vec(data: &'a [f32]) -> Self {
        F32Input { data, dims: None }
    }
    pub fn shaped(data: &'a [f32], dims: &'a [usize]) -> Self {
        F32Input { data, dims: Some(dims) }
    }
}

impl Executable {
    /// Execute with f32 inputs; flatten every output buffer to `Vec<f32>`.
    ///
    /// Artifacts are lowered with `return_tuple=True`, so the single
    /// result literal is a tuple — decomposed here.
    #[cfg(feature = "pjrt")]
    pub fn run_f32(&self, inputs: &[F32Input<'_>]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for inp in inputs {
            let lit = xla::Literal::vec1(inp.data);
            let lit = match inp.dims {
                Some(dims) => {
                    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                    lit.reshape(&d).map_err(|e| anyhow!("reshape input: {e}"))?
                }
                None => lit,
            };
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        let tuple = out.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        let mut buffers = Vec::with_capacity(tuple.len());
        for lit in tuple {
            buffers.push(lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?);
        }
        Ok(buffers)
    }

    /// Without the `pjrt` feature execution is unavailable.
    #[cfg(not(feature = "pjrt"))]
    pub fn run_f32(&self, _inputs: &[F32Input<'_>]) -> Result<Vec<Vec<f32>>> {
        bail!("PJRT runtime unavailable: build with `--features pjrt`")
    }
}

/// Default artifact directory: `$JANUS_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("JANUS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
