//! Expected total transmission time with passive retransmission — Eq. 2 —
//! and the parity optimizer of Eq. 8 (guaranteed-error-bound contract).

use super::params::NetParams;
use super::prob::{p_unrecoverable, p_unrecoverable_bursty};

/// Number of FTGs needed to carry `total_bytes` of data with `m` parity
/// fragments per group (continuous, as in the model: `N = S / ((n−m)s)`).
pub fn num_ftgs(total_bytes: u64, p: &NetParams, m: usize) -> f64 {
    assert!(m < p.n);
    total_bytes as f64 / ((p.n - m) as f64 * p.s as f64)
}

/// Eq. 2 — expected total time to deliver `N` FTGs of `n` fragments at
/// rate `r` with per-FTG unrecoverable-loss probability `p_loss`,
/// including the expected geometric cascade of retransmission rounds.
pub fn expected_total_time(params: &NetParams, n_ftgs: f64, p_loss: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p_loss), "p={p_loss}");
    let t = params.t;
    let r = params.r;
    let n = params.n as f64;
    // Initial transmission: t + (nN − 1)/r.
    let mut total = t + (n * n_ftgs - 1.0) / r;
    if p_loss <= 0.0 || n_ftgs <= 0.0 {
        return total;
    }
    // Retransmission rounds: round i retransmits ~N·p^i FTGs and occurs
    // with probability 1 − (1−p)^{N·p^{i−1}}.
    let mut p_pow = 1.0; // p^{i−1}
    for _i in 1..=200 {
        let expected_groups_prev = n_ftgs * p_pow; // N·p^{i−1}
        let prob_round = 1.0 - (1.0 - p_loss).powf(expected_groups_prev);
        if prob_round < 1e-15 {
            break;
        }
        p_pow *= p_loss; // now p^i
        let round_time = t + (n * n_ftgs * p_pow - 1.0).max(0.0) / r;
        total += prob_round * round_time;
    }
    total
}

/// Result of the Eq. 8 search.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimeOpt {
    pub m: usize,
    pub expected_time: f64,
    pub p_unrecoverable: f64,
}

/// Eq. 8 — choose `m ∈ {0..n/2}` minimizing `E[T_total]` for transferring
/// `total_bytes` (the first `l` levels) under `params`.
///
/// `p` is computed with Eq. 7 when `λ·n/r > 1`, else Eq. 6 — dispatched
/// inside [`p_unrecoverable`].
pub fn optimize_parity(params: &NetParams, total_bytes: u64) -> TimeOpt {
    optimize_parity_bursty(params, total_bytes, 1.0)
}

/// Eq. 8 under burst-shaped loss: identical search, but the constraint
/// probability is [`p_unrecoverable_bursty`] with mean burst length
/// `burst` (the two-state estimator's b̂). At `burst ≤ 1` this *is*
/// [`optimize_parity`]. Burst-aware solves pick enough parity to survive
/// whole loss events, where the i.i.d. estimate under-provisions and
/// pays in extra retransmission passes.
pub fn optimize_parity_bursty(params: &NetParams, total_bytes: u64, burst: f64) -> TimeOpt {
    let max_m = params.n / 2;
    let mut best: Option<TimeOpt> = None;
    for m in 0..=max_m {
        let p_loss = p_unrecoverable_bursty(params, m, burst);
        let n_ftgs = num_ftgs(total_bytes, params, m);
        let t = expected_total_time(params, n_ftgs, p_loss);
        if best.map_or(true, |b| t < b.expected_time) {
            best = Some(TimeOpt { m, expected_time: t, p_unrecoverable: p_loss });
        }
    }
    best.expect("non-empty search space")
}

/// Smallest `m ∈ {0..n/2}` whose burst-aware unrecoverability at mean
/// burst length `burst` is at most `p_max` (falling back to `n/2` when
/// no m reaches the target).
///
/// Why a floor on top of [`optimize_parity_bursty`]: Eq. 2 prices
/// retransmission rounds as pure wire time, so under burst loss its
/// optimum sits at the *start* of a survivability plateau (`m = b`,
/// tolerating one event) and happily pays a long cascade of cheap
/// rounds. In the pass-barrier engines every round is a full barrier —
/// feedback RTT, re-solve, control exchange — which the continuous
/// cascade underprices. When the two-state estimator's burst verdict is
/// in force, the engines therefore clamp the Eq. 8 solve to this floor,
/// bounding the per-pass group-failure residual at `p_max` so the lost
/// list drains geometrically at a contracted rate instead of
/// plateau-limited ~`P(≥2 events)`.
pub fn parity_floor_bursty(params: &NetParams, burst: f64, p_max: f64) -> usize {
    assert!((0.0..1.0).contains(&p_max));
    let max_m = params.n / 2;
    for m in 0..=max_m {
        if p_unrecoverable_bursty(params, m, burst) <= p_max {
            return m;
        }
    }
    max_m
}

// ---------------------------------------------------------------------------
// Barrier-free fountain accounting (DESIGN.md §12).
//
// Eq. 2 prices repair as a geometric cascade of pass barriers: each round
// costs a feedback RTT plus the retransmitted wire time. The rateless
// backend has no rounds at all — the sender streams source symbols and
// then repair symbols until the compact group acks drain, so the only
// repair cost is the *expected overhead symbol count*, paid inline at
// line rate. These functions re-derive the τ budget for that shape.

/// Expected reception overhead `ε` of the LT decoder at group size `k`:
/// robust-soliton peeling completes w.h.p. once `k·(1+ε)` distinct
/// symbols arrive, with `ε ≈ (R + g)/k` where `R = c·ln(k/δ)·√k` is the
/// soliton spike mass (the classic `O(√k·ln(k/δ))` overhead) and `g` a
/// small constant margin for the Gaussian-elimination fallback clearing
/// the last rank deficiencies. Uses the decoder's shipped defaults
/// ([`crate::erasure::RobustSoliton::C`]/[`DELTA`](crate::erasure::RobustSoliton::DELTA)).
pub fn fountain_overhead(k: usize) -> f64 {
    assert!(k >= 1);
    if k == 1 {
        return 0.0; // degree-1 symbols only: first arrival decodes.
    }
    const GE_MARGIN: f64 = 2.0;
    let kf = k as f64;
    let c = crate::erasure::RobustSoliton::C;
    let delta = crate::erasure::RobustSoliton::DELTA;
    let r_spike = (c * (kf / delta).ln() * kf.sqrt()).max(1.0);
    (r_spike + GE_MARGIN) / kf
}

/// Expected symbols the fountain sender puts on the wire to deliver
/// `total_bytes`: `(S/s)·(1+ε)/(1−p_f)` — every group needs `k·(1+ε)`
/// *received* symbols and the channel erases each sent symbol with
/// probability `p_f` independently. Fountain groups carry `k = n` data
/// fragments (no planned parity slots).
pub fn fountain_symbols(total_bytes: u64, p: &NetParams, p_frag_loss: f64) -> f64 {
    assert!((0.0..1.0).contains(&p_frag_loss), "p={p_frag_loss}");
    let source = total_bytes as f64 / p.s as f64;
    source * (1.0 + fountain_overhead(p.n)) / (1.0 - p_frag_loss)
}

/// Per-fragment channel loss probability implied by the Table 1
/// parameters: `λ` losses/s over `r` fragments/s on the wire.
pub fn p_fragment_loss(p: &NetParams) -> f64 {
    (p.lambda / p.r).clamp(0.0, 0.999)
}

/// Barrier-free expected completion time: one propagation delay to open
/// the stream, the symbol train at rate `r`, and one more `t` for the
/// final [`GroupAck`](crate::coordinator::Packet::GroupAck) to land —
/// the *entire* feedback cost, replacing Eq. 2's per-round `t` cascade.
pub fn fountain_total_time(params: &NetParams, total_bytes: u64, p_frag_loss: f64) -> f64 {
    let symbols = fountain_symbols(total_bytes, params, p_frag_loss);
    2.0 * params.t + (symbols - 1.0).max(0.0) / params.r
}

/// Deadline prefix selection for the barrier-free mode: the largest
/// level count `l` whose fountain completion time fits `τ` (the Eq. 12
/// analogue — with no retransmission rounds to price, the search over
/// per-level parity collapses to a prefix scan).
pub fn fountain_feasible_levels(
    params: &NetParams,
    sched: &crate::model::LevelSchedule,
    tau: f64,
) -> usize {
    let p_f = p_fragment_loss(params);
    (1..=sched.num_levels())
        .rev()
        .find(|&l| fountain_total_time(params, sched.total_bytes(l), p_f) <= tau)
        .unwrap_or(0)
}

/// Expected time for every m (for Fig. 2's model curves).
pub fn expected_time_curve(params: &NetParams, total_bytes: u64, max_m: usize) -> Vec<TimeOpt> {
    (0..=max_m)
        .map(|m| {
            let p_loss = p_unrecoverable(params, m);
            let n_ftgs = num_ftgs(total_bytes, params, m);
            TimeOpt {
                m,
                expected_time: expected_total_time(params, n_ftgs, p_loss),
                p_unrecoverable: p_loss,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::LevelSchedule;

    #[test]
    fn no_loss_time_is_wire_time() {
        let p = NetParams::paper_default(0.0);
        let n_ftgs = 100.0;
        let t = expected_total_time(&p, n_ftgs, 0.0);
        let expect = p.t + (p.n as f64 * 100.0 - 1.0) / p.r;
        assert!((t - expect).abs() < 1e-12);
    }

    #[test]
    fn time_increases_with_loss_probability() {
        let p = NetParams::paper_default(383.0);
        let t1 = expected_total_time(&p, 1000.0, 0.001);
        let t2 = expected_total_time(&p, 1000.0, 0.01);
        let t3 = expected_total_time(&p, 1000.0, 0.2);
        assert!(t1 < t2 && t2 < t3);
    }

    #[test]
    fn converges_for_high_p() {
        let p = NetParams::paper_default(957.0);
        let t = expected_total_time(&p, 50_000.0, 0.9);
        assert!(t.is_finite());
        // Geometric cascade with p=0.9 is long but finite.
        assert!(t > 0.0);
    }

    #[test]
    fn num_ftgs_matches_formula() {
        let p = NetParams::paper_default(19.0);
        let s = LevelSchedule::paper_nyx();
        let bytes = s.total_bytes(4);
        // N = S/((n−m)·s)
        let n0 = num_ftgs(bytes, &p, 0);
        assert!((n0 - bytes as f64 / (32.0 * 4096.0)).abs() < 1e-9);
        let n16 = num_ftgs(bytes, &p, 16);
        assert!((n16 - 2.0 * n0).abs() / n0 < 1e-9);
    }

    #[test]
    fn low_loss_prefers_little_parity() {
        // Paper Fig. 2(a): at λ=19 the overhead of parity dominates; the
        // optimum sits at small m.
        let p = NetParams::paper_default(19.0);
        let bytes = LevelSchedule::paper_nyx().total_bytes(4);
        let opt = optimize_parity(&p, bytes);
        assert!(opt.m <= 3, "expected small m at low loss, got {}", opt.m);
    }

    #[test]
    fn high_loss_prefers_more_parity_than_low_loss() {
        let bytes = LevelSchedule::paper_nyx().total_bytes(4);
        let low = optimize_parity(&NetParams::paper_default(19.0), bytes);
        let high = optimize_parity(&NetParams::paper_default(957.0), bytes);
        assert!(
            high.m > low.m,
            "λ=957 chose m={} <= λ=19's m={}",
            high.m,
            low.m
        );
    }

    #[test]
    fn optimum_beats_endpoints() {
        // Paper Fig. 2(b)/(c): an interior optimal m exists at medium/high λ.
        let p = NetParams::paper_default(957.0);
        let bytes = LevelSchedule::paper_nyx().total_bytes(4);
        let curve = expected_time_curve(&p, bytes, 16);
        let opt = optimize_parity(&p, bytes);
        assert!(opt.expected_time <= curve[0].expected_time);
        assert!(opt.expected_time <= curve[16].expected_time);
        assert!(opt.m > 0 && opt.m < 16, "interior optimum expected, m={}", opt.m);
    }

    #[test]
    fn burst_plateaus_trap_the_iid_solve() {
        // Equal mean λ (20% of line rate, n = 32), burst length 8: the
        // i.i.d. Eq. 8 solve lands mid-plateau (8 ≤ m ≤ 15 all survive
        // exactly one event), so its believed failure rate is far below
        // the burst truth, and extra parity between b and 2b−1 bought it
        // nothing.
        let p = NetParams { lambda: 0.2 * 19_144.0, ..NetParams::paper_default(0.0) };
        let bytes = LevelSchedule::paper_nyx().total_bytes(4);
        let iid = optimize_parity(&p, bytes);
        assert!(
            (8..16).contains(&iid.m),
            "iid pick m={} expected mid-plateau",
            iid.m
        );
        let true_p = p_unrecoverable_bursty(&p, iid.m, 8.0);
        assert!(
            true_p > 1.5 * iid.p_unrecoverable,
            "iid believed p={}, truth under bursts is {true_p}",
            iid.p_unrecoverable
        );
        assert!((0.15..0.25).contains(&true_p), "plateau p={true_p}");
    }

    #[test]
    fn parity_floor_escapes_the_plateau() {
        // Same scenario: the 5%-residual floor demands m = 16 (two whole
        // events survivable, p ≈ 4.7%) — the clamp that turns the burst
        // verdict into fewer passes instead of a cheaper-looking cascade.
        let p = NetParams { lambda: 0.2 * 19_144.0, ..NetParams::paper_default(0.0) };
        let floor = parity_floor_bursty(&p, 8.0, 0.05);
        assert_eq!(floor, 16);
        assert!(p_unrecoverable_bursty(&p, floor, 8.0) <= 0.05);
        assert!(p_unrecoverable_bursty(&p, floor - 1, 8.0) > 0.05);
        // Unit burst degrades to the i.i.d. tail: the floor is modest.
        let iid_floor = parity_floor_bursty(&p, 1.0, 0.05);
        assert!(iid_floor < floor, "iid floor {iid_floor} !< burst floor {floor}");
        // Unreachable targets saturate at n/2 instead of panicking.
        assert_eq!(parity_floor_bursty(&p, 64.0, 1e-9), 16);
    }

    #[test]
    fn burst_aware_solve_at_unit_burst_is_iid() {
        let p = NetParams::paper_default(383.0);
        let bytes = LevelSchedule::paper_nyx().total_bytes(4);
        assert_eq!(optimize_parity_bursty(&p, bytes, 1.0), optimize_parity(&p, bytes));
    }

    #[test]
    fn fountain_overhead_shrinks_relatively_with_k() {
        assert_eq!(fountain_overhead(1), 0.0);
        // ε ~ O(ln k/√k): the *relative* overhead decays as groups grow.
        let e8 = fountain_overhead(8);
        let e32 = fountain_overhead(32);
        let e256 = fountain_overhead(256);
        assert!(e8 > e32 && e32 > e256, "{e8} {e32} {e256}");
        assert!(e256 > 0.0 && e8 < 2.0, "overhead out of range: {e8}..{e256}");
    }

    #[test]
    fn fountain_time_monotone_in_loss_and_size() {
        let p = NetParams::paper_default(383.0);
        let t0 = fountain_total_time(&p, 1 << 26, 0.0);
        let t5 = fountain_total_time(&p, 1 << 26, 0.05);
        let t20 = fountain_total_time(&p, 1 << 26, 0.20);
        assert!(t0 < t5 && t5 < t20);
        assert!(fountain_total_time(&p, 1 << 27, 0.05) > t5);
        // Lossless fountain pays only the soliton overhead over wire time.
        let wire = 2.0 * p.t + ((1u64 << 26) as f64 / p.s as f64 - 1.0) / p.r;
        assert!(t0 >= wire && t0 < wire * (1.0 + 2.0 * fountain_overhead(p.n)) + 1.0);
    }

    #[test]
    fn fountain_beats_barrier_cascade_at_high_rtt_loss() {
        // The headline claim of the barrier-free mode: at 5% fragment
        // loss on a high-latency path, streaming the expected overhead
        // inline beats Eq. 2's pass cascade (every round re-pays `t`).
        let mut p = NetParams::paper_default(0.0);
        p.t = 0.5; // 500 ms one-way: cross-facility WAN.
        p.lambda = 0.05 * p.r; // 5% fragment loss.
        let bytes = 1u64 << 26;
        let m = 2; // lightly provisioned RS: repair happens in passes.
        let p_loss = p_unrecoverable(&p, m);
        let rs_time = expected_total_time(&p, num_ftgs(bytes, &p, m), p_loss);
        let f_time = fountain_total_time(&p, bytes, p_fragment_loss(&p));
        assert!(
            f_time < rs_time,
            "fountain {f_time:.3}s !< RS cascade {rs_time:.3}s"
        );
    }

    #[test]
    fn fountain_feasible_levels_monotone_in_tau() {
        let p = NetParams::paper_default(383.0);
        let sched = LevelSchedule::paper_nyx();
        let p_f = p_fragment_loss(&p);
        let full = fountain_total_time(&p, sched.total_bytes(sched.num_levels()), p_f);
        assert_eq!(fountain_feasible_levels(&p, &sched, full * 1.01), sched.num_levels());
        let one = fountain_total_time(&p, sched.total_bytes(1), p_f);
        assert_eq!(fountain_feasible_levels(&p, &sched, one * 0.5), 0);
        let mut prev = 0;
        for i in 1..=8 {
            let l = fountain_feasible_levels(&p, &sched, full * i as f64 / 8.0);
            assert!(l >= prev, "feasible prefix not monotone in τ");
            prev = l;
        }
    }

    #[test]
    fn minimum_times_in_paper_ballpark() {
        // Paper §5.2.3: minimum total times ≈ 378.03 s (λ=19),
        // 401.11 s (λ=383), 429.75 s (λ=957). Our model should land in
        // the same ballpark (±10%).
        let bytes = LevelSchedule::paper_nyx().total_bytes(4);
        for (lambda, expect) in [(19.0, 378.03), (383.0, 401.11), (957.0, 429.75)] {
            let opt = optimize_parity(&NetParams::paper_default(lambda), bytes);
            let rel = (opt.expected_time - expect).abs() / expect;
            assert!(
                rel < 0.10,
                "λ={lambda}: model {:.2}s vs paper {expect}s (rel {rel:.3})",
                opt.expected_time
            );
        }
    }
}
