//! The paper's optimization models (§3):
//!
//! * [`params`] — Table 1 symbols, paper-default parameters, level schedule.
//! * [`prob`] — per-FTG unrecoverable-loss probability (Eq. 4–7).
//! * [`time_model`] — expected total time with passive retransmission
//!   (Eq. 2) and the guaranteed-error-bound parity optimizer (Eq. 8).
//! * [`error_model`] — deadline-constrained expected error (Eq. 9–11) and
//!   the guaranteed-time optimizer (Eq. 12).

pub mod error_model;
pub mod params;
pub mod prob;
pub mod time_model;

pub use error_model::{
    optimize_deadline_bitplane, optimize_deadline_paper,
    expected_error, expected_error_with, feasible_levels,
    optimize_deadline_coordinate, optimize_deadline_coordinate_with,
    optimize_deadline_exhaustive, optimize_deadline_exhaustive_with,
    transmission_time, BitplaneDeadlinePlan, DeadlineOpt, ErrorFormula, ResidualSchedule,
};
pub use params::{LevelSchedule, NetParams, PlaneCut};
pub use prob::{
    mean_losses_per_ftg, p_unrecoverable, p_unrecoverable_bursty, p_unrecoverable_table,
    p_unrecoverable_table_bursty,
};
pub use time_model::{
    expected_time_curve, expected_total_time, fountain_feasible_levels, fountain_overhead,
    fountain_symbols, fountain_total_time, num_ftgs, optimize_parity, optimize_parity_bursty,
    p_fragment_loss, parity_floor_bursty, TimeOpt,
};
