//! Probability that an FTG suffers unrecoverable loss — Equations 4–7.
//!
//! Two regimes (paper §3.2.1):
//! * **Low loss** (`λ·n/r ≤ 1`): losses during the FTG's air time follow a
//!   Poisson with mean `λ·T`, `T = t + (n−1)/r`; given `j` total losses
//!   among the `u = r·t + n − 1` fragments in flight, the number landing
//!   in one particular FTG is hypergeometric (Eq. 5); combining gives
//!   Eq. 6.
//! * **High loss** (`λ·n/r > 1`): losses per FTG are Poisson with mean
//!   `λ·n/r` directly (Eq. 7), which models the correlation the
//!   independent-FTG assumption misses.

use super::params::NetParams;
use crate::util::special::{hypergeometric_pmf, poisson_pmf, poisson_sf};

/// `u = r·t + n − 1`: fragments in flight during one FTG's air time (Eq. 3).
pub fn fragments_in_flight(p: &NetParams) -> u64 {
    (p.r * p.t).round() as u64 + p.n as u64 - 1
}

/// FTG air time `T = t + (n−1)/r`.
pub fn ftg_airtime(p: &NetParams) -> f64 {
    p.t + (p.n as f64 - 1.0) / p.r
}

/// Mean fragment losses per FTG, `λ·n/r` — the regime selector of Eq. 8.
pub fn mean_losses_per_ftg(p: &NetParams) -> f64 {
    p.lambda * p.n as f64 / p.r
}

/// Eq. 6 — low-loss-regime probability that an FTG with `m` parity
/// fragments is unrecoverable.
pub fn p_unrecoverable_low(p: &NetParams, m: usize) -> f64 {
    assert!(m < p.n, "parity must leave at least one data fragment");
    let n = p.n as u64;
    let u = fragments_in_flight(p);
    let mu = p.lambda * ftg_airtime(p);
    // Σ_{j=m+1}^{u} P(unrecoverable | v=j) · P(v=j)
    let mut total = 0.0;
    for j in (m as u64 + 1)..=u {
        let pv = poisson_pmf(j, mu);
        if pv < 1e-18 && j as f64 > mu {
            break; // Poisson tail is negligible from here on.
        }
        // Σ_{w=m+1}^{min(n, j)} hypergeom(u, n, j, w)
        let mut cond = 0.0;
        for w in (m as u64 + 1)..=n.min(j) {
            cond += hypergeometric_pmf(u, n, j, w);
        }
        total += cond * pv;
    }
    total.clamp(0.0, 1.0)
}

/// Eq. 7 — high-loss-regime probability: more than `m` Poisson(λ·n/r)
/// losses hit the FTG.
pub fn p_unrecoverable_high(p: &NetParams, m: usize) -> f64 {
    assert!(m < p.n);
    poisson_sf(m as u64, mean_losses_per_ftg(p))
}

/// Regime-dispatched probability (the constraint of Eq. 8).
pub fn p_unrecoverable(p: &NetParams, m: usize) -> f64 {
    if mean_losses_per_ftg(p) > 1.0 {
        p_unrecoverable_high(p, m)
    } else {
        p_unrecoverable_low(p, m)
    }
}

/// Precompute `p(m)` for m = 0..=max_m (solvers evaluate many m).
pub fn p_unrecoverable_table(p: &NetParams, max_m: usize) -> Vec<f64> {
    (0..=max_m).map(|m| p_unrecoverable(p, m)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(lambda: f64) -> NetParams {
        NetParams::paper_default(lambda)
    }

    #[test]
    fn in_flight_count_matches_paper_numbers() {
        // u = 19144·0.01 + 32 − 1 ≈ 222
        let u = fragments_in_flight(&params(19.0));
        assert_eq!(u, 222);
    }

    #[test]
    fn regime_selector_thresholds() {
        // λ·n/r: 19·32/19144 ≈ 0.032 (low), 957·32/19144 ≈ 1.6 (high)
        assert!(mean_losses_per_ftg(&params(19.0)) < 1.0);
        assert!(mean_losses_per_ftg(&params(957.0)) > 1.0);
    }

    #[test]
    fn p_decreases_with_more_parity() {
        for lambda in [19.0, 383.0, 957.0] {
            let p = params(lambda);
            let table = p_unrecoverable_table(&p, 16);
            for w in table.windows(2) {
                assert!(
                    w[1] <= w[0] + 1e-15,
                    "λ={lambda}: p must not increase with m: {table:?}"
                );
            }
        }
    }

    #[test]
    fn p_increases_with_loss_rate() {
        for m in [0, 2, 8] {
            let lo = p_unrecoverable(&params(19.0), m);
            let hi = p_unrecoverable(&params(957.0), m);
            assert!(hi > lo, "m={m}: p(957)={hi} <= p(19)={lo}");
        }
    }

    #[test]
    fn p_zero_lambda_is_zero() {
        let p = params(0.0);
        assert_eq!(p_unrecoverable_low(&p, 0), 0.0);
        assert_eq!(p_unrecoverable_high(&p, 0), 0.0);
    }

    #[test]
    fn p_bounded_in_unit_interval() {
        for lambda in [1.0, 19.0, 383.0, 957.0, 5000.0] {
            for m in 0..=16 {
                let v = p_unrecoverable(&params(lambda), m);
                assert!((0.0..=1.0).contains(&v), "λ={lambda} m={m} p={v}");
            }
        }
    }

    #[test]
    fn low_regime_m0_close_to_expected_fraction() {
        // With m=0, an FTG is unrecoverable iff ≥1 of its n fragments is
        // lost. E[losses in T] = λT, fraction hitting this FTG ≈ n/u, so
        // P ≈ 1 − exp(−λT·n/u) ≈ 1 − exp(−λn/r) for rt >> n.
        let p = params(19.0);
        let got = p_unrecoverable_low(&p, 0);
        let approx = 1.0 - (-mean_losses_per_ftg(&p)).exp();
        assert!(
            (got - approx).abs() / approx < 0.15,
            "got={got} approx={approx}"
        );
    }

    #[test]
    fn high_regime_matches_poisson_tail_identity() {
        let p = params(957.0);
        let mu = mean_losses_per_ftg(&p);
        // m=0: P(X>0) = 1 − e^{−mu}
        let got = p_unrecoverable_high(&p, 0);
        assert!((got - (1.0 - (-mu).exp())).abs() < 1e-12);
    }

    #[test]
    fn table_matches_pointwise() {
        let p = params(383.0);
        let table = p_unrecoverable_table(&p, 8);
        for (m, &v) in table.iter().enumerate() {
            assert_eq!(v, p_unrecoverable(&p, m));
        }
    }
}
