//! Probability that an FTG suffers unrecoverable loss — Equations 4–7.
//!
//! Two regimes (paper §3.2.1):
//! * **Low loss** (`λ·n/r ≤ 1`): losses during the FTG's air time follow a
//!   Poisson with mean `λ·T`, `T = t + (n−1)/r`; given `j` total losses
//!   among the `u = r·t + n − 1` fragments in flight, the number landing
//!   in one particular FTG is hypergeometric (Eq. 5); combining gives
//!   Eq. 6.
//! * **High loss** (`λ·n/r > 1`): losses per FTG are Poisson with mean
//!   `λ·n/r` directly (Eq. 7), which models the correlation the
//!   independent-FTG assumption misses.

use super::params::NetParams;
use crate::util::special::{hypergeometric_pmf, poisson_pmf, poisson_sf};

/// `u = r·t + n − 1`: fragments in flight during one FTG's air time (Eq. 3).
pub fn fragments_in_flight(p: &NetParams) -> u64 {
    (p.r * p.t).round() as u64 + p.n as u64 - 1
}

/// FTG air time `T = t + (n−1)/r`.
pub fn ftg_airtime(p: &NetParams) -> f64 {
    p.t + (p.n as f64 - 1.0) / p.r
}

/// Mean fragment losses per FTG, `λ·n/r` — the regime selector of Eq. 8.
pub fn mean_losses_per_ftg(p: &NetParams) -> f64 {
    p.lambda * p.n as f64 / p.r
}

/// Eq. 6 — low-loss-regime probability that an FTG with `m` parity
/// fragments is unrecoverable.
pub fn p_unrecoverable_low(p: &NetParams, m: usize) -> f64 {
    assert!(m < p.n, "parity must leave at least one data fragment");
    let n = p.n as u64;
    let u = fragments_in_flight(p);
    let mu = p.lambda * ftg_airtime(p);
    // Σ_{j=m+1}^{u} P(unrecoverable | v=j) · P(v=j)
    let mut total = 0.0;
    for j in (m as u64 + 1)..=u {
        let pv = poisson_pmf(j, mu);
        if pv < 1e-18 && j as f64 > mu {
            break; // Poisson tail is negligible from here on.
        }
        // Σ_{w=m+1}^{min(n, j)} hypergeom(u, n, j, w)
        let mut cond = 0.0;
        for w in (m as u64 + 1)..=n.min(j) {
            cond += hypergeometric_pmf(u, n, j, w);
        }
        total += cond * pv;
    }
    total.clamp(0.0, 1.0)
}

/// Eq. 7 — high-loss-regime probability: more than `m` Poisson(λ·n/r)
/// losses hit the FTG.
pub fn p_unrecoverable_high(p: &NetParams, m: usize) -> f64 {
    assert!(m < p.n);
    poisson_sf(m as u64, mean_losses_per_ftg(p))
}

/// Regime-dispatched probability (the constraint of Eq. 8).
pub fn p_unrecoverable(p: &NetParams, m: usize) -> f64 {
    if mean_losses_per_ftg(p) > 1.0 {
        p_unrecoverable_high(p, m)
    } else {
        p_unrecoverable_low(p, m)
    }
}

/// Precompute `p(m)` for m = 0..=max_m (solvers evaluate many m).
pub fn p_unrecoverable_table(p: &NetParams, max_m: usize) -> Vec<f64> {
    (0..=max_m).map(|m| p_unrecoverable(p, m)).collect()
}

/// Burst-aware unrecoverability: λ losses/s arriving in runs of mean
/// length `burst` fragments, instead of independently.
///
/// A stream transmits each FTG's fragments consecutively, so one loss
/// *event* (a burst) erases ~`burst` consecutive fragments of the same
/// group. Events therefore arrive at rate `λ/burst` and the group dies
/// when more than `⌊m/burst⌋` events land in its air window — i.e.
/// `P = poisson_sf(⌊m/b⌋, (λ/b)·n/r)`. Degrades to [`p_unrecoverable`]
/// at `burst ≤ 1` (i.i.d.).
///
/// This is the correction the i.i.d. estimate misses: at 20% loss in
/// bursts of 8 on n = 32, the i.i.d. model believes m = 12 is ample
/// (p ≈ 1%) while the true failure rate is ~19% — one event kills 8
/// fragments, so 12 parity only survives one event.
pub fn p_unrecoverable_bursty(p: &NetParams, m: usize, burst: f64) -> f64 {
    assert!(m < p.n);
    if !(burst > 1.0) {
        return p_unrecoverable(p, m);
    }
    let events = mean_losses_per_ftg(p) / burst;
    let survivable = (m as f64 / burst).floor() as u64;
    poisson_sf(survivable, events)
}

/// Precompute `p(m)` for m = 0..=max_m under burst-shaped loss.
pub fn p_unrecoverable_table_bursty(p: &NetParams, max_m: usize, burst: f64) -> Vec<f64> {
    (0..=max_m).map(|m| p_unrecoverable_bursty(p, m, burst)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(lambda: f64) -> NetParams {
        NetParams::paper_default(lambda)
    }

    #[test]
    fn in_flight_count_matches_paper_numbers() {
        // u = 19144·0.01 + 32 − 1 ≈ 222
        let u = fragments_in_flight(&params(19.0));
        assert_eq!(u, 222);
    }

    #[test]
    fn regime_selector_thresholds() {
        // λ·n/r: 19·32/19144 ≈ 0.032 (low), 957·32/19144 ≈ 1.6 (high)
        assert!(mean_losses_per_ftg(&params(19.0)) < 1.0);
        assert!(mean_losses_per_ftg(&params(957.0)) > 1.0);
    }

    #[test]
    fn p_decreases_with_more_parity() {
        for lambda in [19.0, 383.0, 957.0] {
            let p = params(lambda);
            let table = p_unrecoverable_table(&p, 16);
            for w in table.windows(2) {
                assert!(
                    w[1] <= w[0] + 1e-15,
                    "λ={lambda}: p must not increase with m: {table:?}"
                );
            }
        }
    }

    #[test]
    fn p_increases_with_loss_rate() {
        for m in [0, 2, 8] {
            let lo = p_unrecoverable(&params(19.0), m);
            let hi = p_unrecoverable(&params(957.0), m);
            assert!(hi > lo, "m={m}: p(957)={hi} <= p(19)={lo}");
        }
    }

    #[test]
    fn p_zero_lambda_is_zero() {
        let p = params(0.0);
        assert_eq!(p_unrecoverable_low(&p, 0), 0.0);
        assert_eq!(p_unrecoverable_high(&p, 0), 0.0);
    }

    #[test]
    fn p_bounded_in_unit_interval() {
        for lambda in [1.0, 19.0, 383.0, 957.0, 5000.0] {
            for m in 0..=16 {
                let v = p_unrecoverable(&params(lambda), m);
                assert!((0.0..=1.0).contains(&v), "λ={lambda} m={m} p={v}");
            }
        }
    }

    #[test]
    fn low_regime_m0_close_to_expected_fraction() {
        // With m=0, an FTG is unrecoverable iff ≥1 of its n fragments is
        // lost. E[losses in T] = λT, fraction hitting this FTG ≈ n/u, so
        // P ≈ 1 − exp(−λT·n/u) ≈ 1 − exp(−λn/r) for rt >> n.
        let p = params(19.0);
        let got = p_unrecoverable_low(&p, 0);
        let approx = 1.0 - (-mean_losses_per_ftg(&p)).exp();
        assert!(
            (got - approx).abs() / approx < 0.15,
            "got={got} approx={approx}"
        );
    }

    #[test]
    fn high_regime_matches_poisson_tail_identity() {
        let p = params(957.0);
        let mu = mean_losses_per_ftg(&p);
        // m=0: P(X>0) = 1 − e^{−mu}
        let got = p_unrecoverable_high(&p, 0);
        assert!((got - (1.0 - (-mu).exp())).abs() < 1e-12);
    }

    #[test]
    fn table_matches_pointwise() {
        let p = params(383.0);
        let table = p_unrecoverable_table(&p, 8);
        for (m, &v) in table.iter().enumerate() {
            assert_eq!(v, p_unrecoverable(&p, m));
        }
    }

    #[test]
    fn bursty_degrades_to_iid_at_unit_burst() {
        for lambda in [19.0, 383.0, 957.0] {
            let p = params(lambda);
            for m in [0, 4, 12] {
                assert_eq!(p_unrecoverable_bursty(&p, m, 1.0), p_unrecoverable(&p, m));
                assert_eq!(p_unrecoverable_bursty(&p, m, 0.5), p_unrecoverable(&p, m));
            }
        }
    }

    #[test]
    fn bursts_defeat_sub_burst_parity() {
        // 20% loss at r=19144, n=32 ⇒ λn/r = 6.4 mean losses/FTG. In
        // bursts of 8, m=12 survives only ⌊12/8⌋ = 1 event while events
        // arrive at mean 0.8/FTG ⇒ P(≥2 events) ≈ 19% — an order of
        // magnitude above the i.i.d. belief.
        let p = NetParams { lambda: 0.2 * 19_144.0, ..params(0.0) };
        let iid = p_unrecoverable(&p, 12);
        let bursty = p_unrecoverable_bursty(&p, 12, 8.0);
        assert!(bursty > 5.0 * iid, "bursty={bursty} iid={iid}");
        assert!((0.15..0.25).contains(&bursty), "bursty={bursty}");
    }

    #[test]
    fn bursty_table_monotone_and_matches_pointwise() {
        let p = NetParams { lambda: 0.2 * 19_144.0, ..params(0.0) };
        let table = p_unrecoverable_table_bursty(&p, 16, 8.0);
        for (m, &v) in table.iter().enumerate() {
            assert_eq!(v, p_unrecoverable_bursty(&p, m, 8.0));
            assert!((0.0..=1.0).contains(&v));
        }
        for w in table.windows(2) {
            assert!(w[1] <= w[0] + 1e-15, "p must not increase with m");
        }
    }
}
