//! Expected reconstruction error under a deadline — Eq. 9–12
//! (guaranteed-transmission-time contract).
//!
//! Note on Eq. 11: the paper's displayed middle sum runs to `l−1`, which
//! together with the first and last terms does not partition the event
//! space; the intended partition (level 1 fails → ε_0; levels 1..i−1
//! succeed, level i fails → ε_{i−1}, i = 2..l; all succeed → ε_l) is what
//! we implement — the branch probabilities then sum to exactly 1
//! (verified by `prob_partition_sums_to_one`). Likewise the constraint of
//! Eq. 12 uses the full Eq. 9 (`t + (n·ΣN_j − 1)/r ≤ τ`); the paper's
//! display drops the `n`.

use super::params::{LevelSchedule, NetParams, PlaneCut};
use super::prob::{p_unrecoverable_table, p_unrecoverable_table_bursty};

/// Per-level configuration chosen by the Eq. 12 solver.
#[derive(Debug, Clone, PartialEq)]
pub struct DeadlineOpt {
    /// Number of levels transmitted, `l`.
    pub levels: usize,
    /// Parity fragments per FTG for each transmitted level, `[m_1..m_l]`.
    pub m: Vec<usize>,
    /// Expected relative L∞ error of the reconstruction (Eq. 11).
    pub expected_error: f64,
    /// Transmission time of this configuration (Eq. 9).
    pub time: f64,
}

/// Eq. 9 — single-pass (no retransmission) transmission time for the
/// first `l` levels with per-level parity `m[0..l]`.
pub fn transmission_time(params: &NetParams, sched: &LevelSchedule, m: &[usize]) -> f64 {
    let n = params.n as f64;
    let groups: f64 = m
        .iter()
        .enumerate()
        .map(|(j, &mj)| sched.sizes[j] as f64 / ((params.n - mj) as f64 * params.s as f64))
        .sum();
    params.t + (n * groups - 1.0) / params.r
}

/// Eq. 10 — all level counts `l` whose *fastest* configuration (m_j = 0)
/// meets the deadline `τ`.
pub fn feasible_levels(params: &NetParams, sched: &LevelSchedule, tau: f64) -> Vec<usize> {
    (1..=sched.num_levels())
        .filter(|&l| {
            let m0 = vec![0usize; l];
            transmission_time(params, sched, &m0) <= tau
        })
        .collect()
}

/// Which variant of Eq. 11 to optimize.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorFormula {
    /// Complete event partition (branch probabilities sum to 1).
    Corrected,
    /// Eq. 11 exactly as printed in the paper: the middle sum stops at
    /// `l−1`, omitting the "levels 1..l−1 recovered but level l lost"
    /// branch. Under this objective transmitting an extra level can never
    /// hurt, which is why the paper's reported configurations always send
    /// all four levels and saturate the deadline with parity
    /// ([5,4,2,0] / [8,7,7,0] / [12,11,11,0] in §5.2.3). Kept for
    /// paper-faithful regeneration of Fig. 3/5; see the
    /// `ablation_models` bench for the comparison.
    AsPrinted,
}

/// Eq. 11 — expected relative L∞ error given per-level unrecoverable
/// probabilities `p[j]` and group counts `n_groups[j]`.
///
/// `eps_with_levels(i)` supplies ε_i with ε_0 = 1.
pub fn expected_error_with(
    sched: &LevelSchedule,
    p: &[f64],
    n_groups: &[f64],
    formula: ErrorFormula,
) -> f64 {
    let l = p.len();
    assert_eq!(n_groups.len(), l);
    // P(level j fully recovered) = (1−p_j)^{N_j}
    let level_ok: Vec<f64> = p
        .iter()
        .zip(n_groups)
        .map(|(&pj, &nj)| (1.0 - pj).powf(nj))
        .collect();
    let mut err = 0.0;
    let mut prefix_ok = 1.0; // Π_{j<i} (1−p_j)^{N_j}
    for i in 0..l {
        // Levels 0..i−1 recovered, level i not → error ε_i (ε_0 = 1 when
        // the very first level fails). The paper's printed sum omits the
        // final (i = l) failure branch.
        if formula == ErrorFormula::AsPrinted && i == l - 1 && l >= 2 {
            break;
        }
        err += prefix_ok * (1.0 - level_ok[i]) * sched.eps_with_levels(i);
        prefix_ok *= level_ok[i];
    }
    if formula == ErrorFormula::AsPrinted && l >= 2 {
        // Recompute the full prefix product for the last term.
        prefix_ok = level_ok.iter().product();
    }
    // All l levels recovered → ε_l.
    err + prefix_ok * sched.eps_with_levels(l)
}

/// [`expected_error_with`] using the corrected partition (default).
pub fn expected_error(sched: &LevelSchedule, p: &[f64], n_groups: &[f64]) -> f64 {
    expected_error_with(sched, p, n_groups, ErrorFormula::Corrected)
}

/// Internal: evaluate one `[m_1..m_l]` candidate.
fn evaluate(
    params: &NetParams,
    sched: &LevelSchedule,
    p_table: &[f64],
    m: &[usize],
    formula: ErrorFormula,
) -> (f64, f64) {
    let n_groups: Vec<f64> = m
        .iter()
        .enumerate()
        .map(|(j, &mj)| sched.sizes[j] as f64 / ((params.n - mj) as f64 * params.s as f64))
        .collect();
    let p: Vec<f64> = m.iter().map(|&mj| p_table[mj]).collect();
    (
        expected_error_with(sched, &p, &n_groups, formula),
        transmission_time(params, sched, m),
    )
}

/// [`optimize_deadline_exhaustive_with`] using the corrected Eq. 11.
pub fn optimize_deadline_exhaustive(
    params: &NetParams,
    sched: &LevelSchedule,
    tau: f64,
) -> Option<DeadlineOpt> {
    optimize_deadline_exhaustive_with(params, sched, tau, ErrorFormula::Corrected)
}

/// Eq. 12 solved exhaustively: for each feasible `l`, search every
/// `[m_1..m_l] ∈ {0..n/2}^l` satisfying the deadline and keep the
/// minimum expected error. Exact for the paper's L = 4, n = 32
/// (≤ 17⁴ ≈ 84 k evaluations per l).
pub fn optimize_deadline_exhaustive_with(
    params: &NetParams,
    sched: &LevelSchedule,
    tau: f64,
    formula: ErrorFormula,
) -> Option<DeadlineOpt> {
    let ls = feasible_levels(params, sched, tau);
    if ls.is_empty() {
        return None;
    }
    let max_m = params.n / 2;
    let p_table = p_unrecoverable_table(params, max_m);
    let mut best: Option<DeadlineOpt> = None;
    for &l in &ls {
        let mut m = vec![0usize; l];
        loop {
            let (err, time) = evaluate(params, sched, &p_table, &m, formula);
            if time <= tau && best.as_ref().map_or(true, |b| err < b.expected_error) {
                best = Some(DeadlineOpt { levels: l, m: m.clone(), expected_error: err, time });
            }
            // Odometer increment over {0..max_m}^l.
            let mut idx = 0;
            loop {
                if idx == l {
                    break;
                }
                m[idx] += 1;
                if m[idx] <= max_m {
                    break;
                }
                m[idx] = 0;
                idx += 1;
            }
            if idx == l {
                break;
            }
        }
    }
    best
}

/// Paper-faithful Eq. 12 solve (§5.2.3 configurations): transmit the
/// *maximum* feasible number of levels, then minimize the corrected
/// expected error over `[m_1..m_l]` within the deadline.
///
/// Rationale: comparing E[ε] across different `l` under the printed
/// Eq. 11 is degenerate (omitting the last level's failure branch rewards
/// sabotaging it), while under the corrected formula sending a hopeless
/// giant level ties instead of winning. The paper's reported optima
/// ([5,4,2,0] / [8,7,7,0] / [12,11,11,0], all saturating τ with l = 4)
/// are exactly what "max levels, then min error" produces.
pub fn optimize_deadline_paper(
    params: &NetParams,
    sched: &LevelSchedule,
    tau: f64,
) -> Option<DeadlineOpt> {
    let l = *feasible_levels(params, sched, tau).last()?;
    let max_m = params.n / 2;
    let p_table = p_unrecoverable_table(params, max_m);
    let mut best: Option<DeadlineOpt> = None;
    let mut m = vec![0usize; l];
    loop {
        let (err, time) = evaluate(params, sched, &p_table, &m, ErrorFormula::Corrected);
        if time <= tau && best.as_ref().map_or(true, |b| err < b.expected_error) {
            best = Some(DeadlineOpt { levels: l, m: m.clone(), expected_error: err, time });
        }
        let mut idx = 0;
        loop {
            if idx == l {
                break;
            }
            m[idx] += 1;
            if m[idx] <= max_m {
                break;
            }
            m[idx] = 0;
            idx += 1;
        }
        if idx == l {
            break;
        }
    }
    best
}

/// Alg. 2 extended to *bitplane* granularity: the whole-level Eq. 12
/// solve plus, when the schedule carries codec [`PlaneCut`]s, the
/// largest plane-prefix of the first excluded level that still fits the
/// leftover deadline budget.
#[derive(Debug, Clone, PartialEq)]
pub struct BitplaneDeadlinePlan {
    /// The whole-level optimum ([`optimize_deadline_paper`]).
    pub base: DeadlineOpt,
    /// `(level, cut)` when a plane-prefix of level `base.levels` fits
    /// in the remaining budget; the partial level ships with `m = 0`.
    pub partial: Option<(usize, PlaneCut)>,
}

impl BitplaneDeadlinePlan {
    /// ε of the full plan (the partial cut's measured ε when present).
    pub fn planned_eps(&self, sched: &LevelSchedule) -> f64 {
        match &self.partial {
            Some((_, cut)) => cut.eps,
            None => sched.eps_with_levels(self.base.levels),
        }
    }

    /// Re-solve Eq. 12 against a **residual** schedule and budget — the
    /// pass-barrier τ-accounting hook of the pooled Deadline engine. The
    /// caller prices its pending retransmission set as a schedule (one
    /// entry per level still missing data, `sizes` = pending bytes under
    /// the pass-0 geometry, plane cuts remapped into pending-byte space)
    /// and passes the deadline budget left after the virtual clock's
    /// debits. `None` means not even one pending level fits at `m = 0`:
    /// shed everything still pending. The returned plan's `base.levels`
    /// counts *residual* levels (a prefix of the residual schedule), and
    /// `partial` names a residual-space cut of the first excluded one.
    pub fn replan_residual(
        params: &NetParams,
        residual: &LevelSchedule,
        budget: f64,
    ) -> Option<BitplaneDeadlinePlan> {
        if budget.is_nan() || budget <= 0.0 {
            return None;
        }
        optimize_deadline_bitplane(params, residual, budget)
    }

    /// [`replan_residual`](Self::replan_residual) with exact per-group
    /// pricing and burst-aware loss: residual pass time comes from
    /// [`ResidualSchedule::transmission_time`] (`Σ D_j + G_j·m_j`
    /// fragments — the frozen pass-0 group geometry, not the fractional
    /// Eq. 9 re-derivation) and the constraint probabilities use mean
    /// burst length `burst` (1.0 = i.i.d.). The error partition weighs
    /// the *actual* pending group counts. Like the paper solve, it takes
    /// the maximum feasible residual-level prefix, minimizes corrected
    /// expected error over the parity odometer, then spends slack on the
    /// best plane cut of the first excluded level.
    pub fn replan_residual_exact(
        params: &NetParams,
        residual: &ResidualSchedule,
        budget: f64,
        burst: f64,
    ) -> Option<BitplaneDeadlinePlan> {
        if budget.is_nan() || budget <= 0.0 {
            return None;
        }
        let sched = &residual.sched;
        let l = (1..=sched.num_levels())
            .filter(|&l| residual.transmission_time(params, &vec![0; l]) <= budget)
            .last()?;
        let max_m = params.n / 2;
        let p_table = p_unrecoverable_table_bursty(params, max_m, burst);
        let n_groups: Vec<f64> = residual.groups[..l].iter().map(|&g| g as f64).collect();
        let mut best: Option<DeadlineOpt> = None;
        let mut m = vec![0usize; l];
        loop {
            let time = residual.transmission_time(params, &m);
            if time <= budget {
                let p: Vec<f64> = m.iter().map(|&mj| p_table[mj]).collect();
                let err = expected_error_with(sched, &p, &n_groups, ErrorFormula::Corrected);
                if best.as_ref().map_or(true, |b| err < b.expected_error) {
                    best = Some(DeadlineOpt { levels: l, m: m.clone(), expected_error: err, time });
                }
            }
            let mut idx = 0;
            loop {
                if idx == l {
                    break;
                }
                m[idx] += 1;
                if m[idx] <= max_m {
                    break;
                }
                m[idx] = 0;
                idx += 1;
            }
            if idx == l {
                break;
            }
        }
        let base = best?;
        let next = base.levels;
        let mut partial = None;
        if next < sched.num_levels() {
            let left = budget - base.time;
            if left > 0.0 {
                let frags = (left * params.r).floor();
                if frags >= 1.0 {
                    let budget_bytes = (frags as u64).saturating_mul(params.s as u64);
                    if let Some(cut) = sched.best_cut_within(next, budget_bytes) {
                        partial = Some((next, cut));
                    }
                }
            }
        }
        Some(BitplaneDeadlinePlan { base, partial })
    }
}

/// A pending retransmission set with its *frozen* group geometry: the
/// per-level byte sizes (and remapped plane cuts) of a residual
/// [`LevelSchedule`], plus the exact count of pending FTGs per level.
///
/// The continuous Eq. 9 model re-derives group counts from the candidate
/// parity — `sizes_j / ((n − m_j)·s)` — which is right when planning a
/// fresh transmission but wrong for a residual pass: the pending groups'
/// data geometry was fixed at pass 0, so a re-plan only changes the
/// *parity* appended to each existing group. Pricing residual passes
/// with the fractional formula both overcharges (whole-group ceil slack
/// at the old `m0`) and undercharges (a re-plan dropping parity below
/// `m0` does not shrink the group count), which skews every shed
/// decision downstream.
#[derive(Debug, Clone, PartialEq)]
pub struct ResidualSchedule {
    /// Pending bytes + ε ladder (+ remapped cuts) per residual level.
    pub sched: LevelSchedule,
    /// Pending FTGs per residual level (same length as `sched`).
    pub groups: Vec<u64>,
}

impl ResidualSchedule {
    pub fn new(sched: LevelSchedule, groups: Vec<u64>) -> ResidualSchedule {
        assert_eq!(groups.len(), sched.num_levels());
        ResidualSchedule { sched, groups }
    }

    /// Exact single-pass time for retransmitting the first `l = m.len()`
    /// residual levels with per-level parity `m`: every pending group
    /// resends its data fragments (`Σ ceil(bytes_j/s)` in total) plus
    /// `m_j` fresh parity fragments — `t + (Σ_j (D_j + G_j·m_j) − 1)/r`.
    pub fn transmission_time(&self, params: &NetParams, m: &[usize]) -> f64 {
        let s = params.s as f64;
        let frags: f64 = m
            .iter()
            .enumerate()
            .map(|(j, &mj)| {
                let data = (self.sched.sizes[j] as f64 / s).ceil();
                data + self.groups[j] as f64 * mj as f64
            })
            .sum();
        params.t + (frags - 1.0) / params.r
    }
}

/// Eq. 12 at bitplane granularity. Solves the paper's whole-level model
/// first, then spends the deadline slack on a decodable plane-prefix of
/// the next level (chosen from the schedule's [`PlaneCut`]s, sent with
/// `m = 0` — the Eq. 12 optima leave the final, largest level
/// unprotected anyway, see §5.2.3). Schedules without cuts degrade to
/// exactly [`optimize_deadline_paper`].
pub fn optimize_deadline_bitplane(
    params: &NetParams,
    sched: &LevelSchedule,
    tau: f64,
) -> Option<BitplaneDeadlinePlan> {
    let base = optimize_deadline_paper(params, sched, tau)?;
    let next = base.levels;
    let mut partial = None;
    if next < sched.num_levels() {
        let left = tau - base.time;
        if left > 0.0 {
            // With m = 0 every fragment is data: the slack buys
            // floor(left·r) fragments of s bytes each, and any byte
            // prefix B needs ceil(B/s) ≤ floor(left·r) fragments.
            let frags = (left * params.r).floor();
            if frags >= 1.0 {
                let budget_bytes = (frags as u64).saturating_mul(params.s as u64);
                if let Some(cut) = sched.best_cut_within(next, budget_bytes) {
                    partial = Some((next, cut));
                }
            }
        }
    }
    Some(BitplaneDeadlinePlan { base, partial })
}

/// [`optimize_deadline_coordinate_with`] using the corrected Eq. 11.
pub fn optimize_deadline_coordinate(
    params: &NetParams,
    sched: &LevelSchedule,
    tau: f64,
    restarts: usize,
) -> Option<DeadlineOpt> {
    optimize_deadline_coordinate_with(params, sched, tau, restarts, ErrorFormula::Corrected)
}

/// Eq. 12 solved by coordinate descent with restarts: scales to larger L
/// where the exhaustive odometer is infeasible. Returns the best local
/// optimum found.
pub fn optimize_deadline_coordinate_with(
    params: &NetParams,
    sched: &LevelSchedule,
    tau: f64,
    restarts: usize,
    formula: ErrorFormula,
) -> Option<DeadlineOpt> {
    let ls = feasible_levels(params, sched, tau);
    if ls.is_empty() {
        return None;
    }
    let max_m = params.n / 2;
    let p_table = p_unrecoverable_table(params, max_m);
    let mut best: Option<DeadlineOpt> = None;
    for &l in &ls {
        // Restart points: all-zero, all-max-feasible, and staircase starts.
        for restart in 0..restarts.max(1) {
            let mut m: Vec<usize> = match restart % 3 {
                0 => vec![0; l],
                1 => (0..l).map(|j| (max_m / (j + 1)).min(max_m)).collect(),
                _ => vec![max_m / 2; l],
            };
            // Make the start feasible by stripping parity from the back.
            let mut j = l;
            while transmission_time(params, sched, &m) > tau {
                if j == 0 {
                    m.fill(0);
                    break;
                }
                j -= 1;
                m[j] = 0;
            }
            if transmission_time(params, sched, &m) > tau {
                continue;
            }
            let (mut cur_err, _) = evaluate(params, sched, &p_table, &m, formula);
            loop {
                let mut improved = false;
                for coord in 0..l {
                    let orig = m[coord];
                    for cand in 0..=max_m {
                        if cand == orig {
                            continue;
                        }
                        m[coord] = cand;
                        let (err, time) = evaluate(params, sched, &p_table, &m, formula);
                        if time <= tau && err < cur_err - 1e-18 {
                            cur_err = err;
                            improved = true;
                        } else {
                            m[coord] = orig;
                        }
                        if m[coord] == cand {
                            break; // keep the improvement, rescan later
                        }
                    }
                }
                if !improved {
                    break;
                }
            }
            let (err, time) = evaluate(params, sched, &p_table, &m, formula);
            if time <= tau && best.as_ref().map_or(true, |b| err < b.expected_error) {
                best = Some(DeadlineOpt { levels: l, m, expected_error: err, time });
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(lambda: f64) -> (NetParams, LevelSchedule) {
        (NetParams::paper_default(lambda), LevelSchedule::paper_nyx())
    }

    #[test]
    fn transmission_time_monotone_in_parity() {
        let (p, s) = setup(19.0);
        let t0 = transmission_time(&p, &s, &[0, 0, 0, 0]);
        let t8 = transmission_time(&p, &s, &[8, 8, 8, 8]);
        let t16 = transmission_time(&p, &s, &[16, 16, 16, 16]);
        assert!(t0 < t8 && t8 < t16);
        // m=16 halves k => doubles groups => ~2x the m=0 time.
        assert!((t16 / t0 - 2.0).abs() < 0.01, "ratio {}", t16 / t0);
    }

    #[test]
    fn feasible_levels_shrink_with_tau() {
        let (p, s) = setup(19.0);
        let t_all = transmission_time(&p, &s, &[0, 0, 0, 0]);
        let all = feasible_levels(&p, &s, t_all + 1.0);
        assert_eq!(all, vec![1, 2, 3, 4]);
        let one = feasible_levels(&p, &s, transmission_time(&p, &s, &[0]) + 0.1);
        assert_eq!(one, vec![1]);
        let none = feasible_levels(&p, &s, 0.001);
        assert!(none.is_empty());
    }

    #[test]
    fn prob_partition_sums_to_one() {
        // Replace ε_i with 1 everywhere: expected "error" must then be
        // exactly 1 regardless of p — i.e. branch probabilities partition.
        let ones = LevelSchedule::new(
            vec![1 << 20, 2 << 20, 3 << 20],
            vec![0.3, 0.2, 0.1], // unused below
        );
        struct Fake;
        let p: [f64; 3] = [0.02, 0.05, 0.4];
        let n: [f64; 3] = [10.0, 20.0, 30.0];
        // expected_error with all eps forced to 1: recompute by formula.
        let level_ok: Vec<f64> = p.iter().zip(&n).map(|(&pj, &nj)| (1.0 - pj).powf(nj)).collect();
        let mut total_prob = 0.0;
        let mut prefix = 1.0;
        for i in 0..3 {
            total_prob += prefix * (1.0 - level_ok[i]);
            prefix *= level_ok[i];
        }
        total_prob += prefix;
        assert!((total_prob - 1.0).abs() < 1e-12);
        let _ = (ones, Fake);
    }

    #[test]
    fn expected_error_bounds() {
        let (p, s) = setup(383.0);
        let p_tab = p_unrecoverable_table(&p, 16);
        let m = [8usize, 7, 7, 0];
        let n_groups: Vec<f64> = m
            .iter()
            .enumerate()
            .map(|(j, &mj)| s.sizes[j] as f64 / ((32 - mj) as f64 * 4096.0))
            .collect();
        let probs: Vec<f64> = m.iter().map(|&mj| p_tab[mj]).collect();
        let err = expected_error(&s, &probs, &n_groups);
        // Expected error is a convex combination of ε_0..ε_4.
        assert!(err >= s.eps[3] && err <= 1.0, "err={err}");
    }

    #[test]
    fn more_parity_lowers_expected_error() {
        let (p, s) = setup(957.0);
        let p_tab = p_unrecoverable_table(&p, 16);
        let eval = |m: &[usize]| {
            let n_groups: Vec<f64> = m
                .iter()
                .enumerate()
                .map(|(j, &mj)| s.sizes[j] as f64 / ((32 - mj) as f64 * 4096.0))
                .collect();
            let probs: Vec<f64> = m.iter().map(|&mj| p_tab[mj]).collect();
            expected_error(&s, &probs, &n_groups)
        };
        assert!(eval(&[12, 11, 11, 0]) < eval(&[0, 0, 0, 0]));
    }

    #[test]
    fn infeasible_deadline_returns_none() {
        let (p, s) = setup(19.0);
        assert!(optimize_deadline_exhaustive(&p, &s, 0.001).is_none());
        assert!(optimize_deadline_coordinate(&p, &s, 0.001, 3).is_none());
    }

    #[test]
    fn solution_respects_deadline() {
        let (p, s) = setup(383.0);
        let tau = 401.11;
        let opt = optimize_deadline_exhaustive(&p, &s, tau).unwrap();
        assert!(opt.time <= tau, "time {} > τ {tau}", opt.time);
        assert_eq!(opt.m.len(), opt.levels);
        assert!(opt.m.iter().all(|&m| m <= 16));
    }

    #[test]
    fn paper_strategy_reproduces_fig3_configs() {
        // Paper §5.2.3 (Fig. 3 configs): [5,4,2,0] (λ=19), [8,7,7,0]
        // (λ=383), [12,11,11,0] (λ=957). The max-levels-then-min-error
        // solve reproduces λ=19 exactly and the same shape for the rest:
        // all 4 levels, monotone non-increasing parity, m_4 = 0,
        // saturating the deadline.
        let cases = [(19.0, 378.03), (383.0, 401.11), (957.0, 429.75)];
        for (lambda, tau) in cases {
            let (p, s) = setup(lambda);
            let opt = optimize_deadline_paper(&p, &s, tau).unwrap();
            assert_eq!(opt.levels, 4, "λ={lambda} should send all 4 levels");
            for w in opt.m[..3].windows(2) {
                assert!(w[0] >= w[1], "λ={lambda}: parity not monotone: {:?}", opt.m);
            }
            // Level 4 is huge; adding parity there costs the most time.
            assert_eq!(*opt.m.last().unwrap(), 0, "λ={lambda}: {:?}", opt.m);
            // Saturates the deadline (within one FTG's air time).
            assert!(opt.time > tau - 2.0, "λ={lambda}: {:.2} ≪ τ={tau}", opt.time);
        }
        // Exact match on the low-loss case.
        let (p, s) = setup(19.0);
        let opt = optimize_deadline_paper(&p, &s, 378.03).unwrap();
        assert_eq!(opt.m, vec![5, 4, 2, 0]);
    }

    #[test]
    fn printed_formula_hides_last_level_failure() {
        // The as-printed Eq. 11 rewards leaving the last level
        // unprotected (its failure branch is dropped), which is why
        // cross-l comparison must use the corrected partition.
        let (p, s) = setup(383.0);
        let printed =
            optimize_deadline_exhaustive_with(&p, &s, 401.11, ErrorFormula::AsPrinted).unwrap();
        let corrected =
            optimize_deadline_exhaustive_with(&p, &s, 401.11, ErrorFormula::Corrected).unwrap();
        assert!(printed.expected_error <= corrected.expected_error);
        // The printed optimum's *real* expected error is no better than
        // the corrected optimum's.
        let p_tab = p_unrecoverable_table(&p, 16);
        let n_groups: Vec<f64> = printed
            .m
            .iter()
            .enumerate()
            .map(|(j, &mj)| s.sizes[j] as f64 / ((32 - mj) as f64 * 4096.0))
            .collect();
        let probs: Vec<f64> = printed.m.iter().map(|&mj| p_tab[mj]).collect();
        let real_err = expected_error(&s, &probs, &n_groups);
        assert!(real_err >= corrected.expected_error - 1e-15);
    }

    #[test]
    fn corrected_formula_ties_printed_when_last_level_hopeless() {
        // With m_4 = 0 over N_4 ≈ 1.5e5 groups level 4 never survives, so
        // both formulas should agree the expected error is ≈ ε_3 for a
        // config protecting levels 1..3 well.
        let (p, s) = setup(19.0);
        let p_tab = p_unrecoverable_table(&p, 16);
        let m = [8usize, 8, 8, 0];
        let n_groups: Vec<f64> = m
            .iter()
            .enumerate()
            .map(|(j, &mj)| s.sizes[j] as f64 / ((32 - mj) as f64 * 4096.0))
            .collect();
        let probs: Vec<f64> = m.iter().map(|&mj| p_tab[mj]).collect();
        let corrected = expected_error_with(&s, &probs, &n_groups, ErrorFormula::Corrected);
        let printed = expected_error_with(&s, &probs, &n_groups, ErrorFormula::AsPrinted);
        assert!((corrected - s.eps[2]).abs() / s.eps[2] < 0.05, "corrected={corrected}");
        // The printed formula drops the level-4-failure branch entirely.
        assert!(printed < corrected, "printed={printed} corrected={corrected}");
    }

    #[test]
    fn bitplane_plan_degrades_to_whole_levels_without_cuts() {
        let (p, s) = setup(383.0);
        let tau = 401.11;
        let plan = optimize_deadline_bitplane(&p, &s, tau).unwrap();
        assert_eq!(plan.base, optimize_deadline_paper(&p, &s, tau).unwrap());
        assert!(plan.partial.is_none(), "no cuts ⇒ whole-level shedding");
        assert!((plan.planned_eps(&s) - s.eps_with_levels(plan.base.levels)).abs() < 1e-18);
    }

    #[test]
    fn bitplane_plan_spends_slack_on_a_plane_prefix() {
        let p = NetParams { t: 0.001, r: 1000.0, lambda: 0.0, n: 32, s: 1024 };
        // Level 2 is too big to finish by τ, but carries two cuts.
        let sched = LevelSchedule::new(vec![32 * 1024, 512 * 1024], vec![0.01, 0.0001])
            .with_cuts(vec![
                vec![],
                vec![
                    PlaneCut { bytes: 40 * 1024, eps: 0.004 },
                    PlaneCut { bytes: 200 * 1024, eps: 0.0009 },
                ],
            ]);
        // Level 1 alone: 32 groups of fragments → 32 KiB / 1 KiB = 32
        // fragments at m = 0 ⇒ ~0.033 s. Full level 2 needs 512 more
        // fragments (~0.512 s). Pick τ between: level 2 infeasible
        // whole, but its 40 KiB cut (40 fragments) fits the slack.
        let tau = 0.15;
        let plan = optimize_deadline_bitplane(&p, &sched, tau).unwrap();
        assert_eq!(plan.base.levels, 1, "whole level 2 cannot meet τ");
        let (level, cut) = plan.partial.expect("slack fits the 40 KiB cut");
        assert_eq!(level, 1);
        assert_eq!(cut.bytes, 40 * 1024);
        assert!((plan.planned_eps(&sched) - 0.004).abs() < 1e-15);
        // The next-larger cut must genuinely not fit: 200 KiB needs 200
        // fragments and the slack only buys ⌊left·r⌋ < 200.
        let left = tau - plan.base.time;
        assert!((left * p.r).floor() < 200.0, "slack buys {} fragments", left * p.r);

        // A tighter τ that cannot even fit the small cut sheds to
        // whole-level granularity.
        let tight = plan.base.time + 0.01;
        let tight_plan = optimize_deadline_bitplane(&p, &sched, tight).unwrap();
        assert!(tight_plan.partial.is_none(), "10 ms slack < 40 fragments");
    }

    #[test]
    fn replan_residual_degrades_gracefully_with_the_budget() {
        let p = NetParams { t: 0.001, r: 1000.0, lambda: 0.0, n: 32, s: 1024 };
        // A pending retransmission set: 32 KiB of level 1 and 128 KiB of
        // level 2 still missing, level 2 carrying one remapped cut.
        let residual = LevelSchedule::new(vec![32 * 1024, 128 * 1024], vec![0.01, 0.0001])
            .with_cuts(vec![
                vec![],
                vec![PlaneCut { bytes: 40 * 1024, eps: 0.004 }],
            ]);
        // Generous budget: everything pending fits.
        let all = BitplaneDeadlinePlan::replan_residual(&p, &residual, 10.0).unwrap();
        assert_eq!(all.base.levels, 2);
        assert!(all.partial.is_none());
        // Mid budget: level 1 plus the 40 KiB cut of level 2 (32 + 40
        // fragments ≈ 0.073 s at m = 0).
        let mid = BitplaneDeadlinePlan::replan_residual(&p, &residual, 0.085).unwrap();
        assert_eq!(mid.base.levels, 1);
        let (level, cut) = mid.partial.expect("slack fits the remapped cut");
        assert_eq!(level, 1);
        assert_eq!(cut.bytes, 40 * 1024);
        // Tiny budget: level 1 alone, cut unaffordable.
        let tight = BitplaneDeadlinePlan::replan_residual(&p, &residual, 0.04).unwrap();
        assert_eq!(tight.base.levels, 1);
        assert!(tight.partial.is_none());
        // No budget at all: shed everything pending.
        assert!(BitplaneDeadlinePlan::replan_residual(&p, &residual, 0.0).is_none());
        assert!(BitplaneDeadlinePlan::replan_residual(&p, &residual, -1.0).is_none());
    }

    #[test]
    fn residual_time_charges_exact_per_group_parity() {
        let p = NetParams { t: 0.001, r: 1000.0, lambda: 0.0, n: 32, s: 1024 };
        // 10 pending groups holding 300 fragments of data (some groups
        // are short tails — that's why G·k ≠ ceil(bytes/s) in general).
        let rs = ResidualSchedule::new(
            LevelSchedule::new(vec![300 * 1024, 64 * 1024], vec![0.01, 0.0001]),
            vec![10, 2],
        );
        // m = [4, 16]: 300 + 10·4 + 64 + 2·16 = 436 fragments.
        let t = rs.transmission_time(&p, &[4, 16]);
        assert!((t - (0.001 + 435.0 / 1000.0)).abs() < 1e-12, "t={t}");
        // m = 0 charges no parity at all — no whole-group ceil slack.
        let t0 = rs.transmission_time(&p, &[0, 0]);
        assert!((t0 - (0.001 + 363.0 / 1000.0)).abs() < 1e-12, "t0={t0}");
        // The fractional Eq. 9 model overcharges the same m = 0 plan:
        // 300·1024/(32·1024) = 9.375 "groups" × n = 300 data fragments
        // priced as if every group were full-width.
        let frac = transmission_time(&p, &rs.sched, &[0, 0]);
        assert!((frac - t0).abs() < 1e-9, "full-width levels agree: {frac} vs {t0}");
    }

    #[test]
    fn exact_replan_affords_more_than_fractional_when_parity_drops() {
        let p = NetParams { t: 0.001, r: 1000.0, lambda: 0.0, n: 32, s: 1024 };
        // Pending: 64 groups of level 1 (64 KiB) + 256 groups of level 2
        // (256 KiB), every group a single data fragment (heavy loss left
        // scattered single-fragment remnants).
        let rs = ResidualSchedule::new(
            LevelSchedule::new(vec![64 * 1024, 256 * 1024], vec![0.01, 0.0001]),
            vec![64, 256],
        );
        // Budget fits all 320 data fragments at m = 0 (0.321 s) but the
        // fractional model can also only afford m = 0 here, so compare
        // where it matters: a budget in between lets the exact model
        // finish both levels while the fractional one (same time at
        // m = 0 for full-width levels) agrees — the divergence shows up
        // once parity enters: exact prices m = 1 on level 1 as +64
        // fragments, fractional as a *group-count* change.
        let exact = BitplaneDeadlinePlan::replan_residual_exact(&p, &rs, 0.40, 1.0).unwrap();
        assert_eq!(exact.base.levels, 2);
        let exact_t = rs.transmission_time(&p, &exact.base.m);
        assert!(exact_t <= 0.40);
        // Lossless residual: no parity is worth buying.
        assert_eq!(exact.base.m, vec![0, 0]);

        // Under loss, the exact model buys parity per *group*.
        let lossy = NetParams { lambda: 100.0, ..p };
        let plan = BitplaneDeadlinePlan::replan_residual_exact(&lossy, &rs, 0.80, 1.0).unwrap();
        assert_eq!(plan.base.levels, 2);
        assert!(plan.base.m.iter().any(|&m| m > 0), "loss ⇒ parity: {:?}", plan.base.m);
        assert!(rs.transmission_time(&lossy, &plan.base.m) <= 0.80);
        // And the budget constraint really binds at the fragment level:
        // every extra level-2 parity unit costs 256 fragments = 0.256 s.
        let mut over = plan.base.m.clone();
        over[1] += 4;
        assert!(rs.transmission_time(&lossy, &over) > 0.80);
    }

    #[test]
    fn exact_replan_burst_awareness_buys_whole_event_parity() {
        // 20% loss in bursts of 8 at the pass rate: i.i.d. pricing is
        // content below the plateau; burst pricing must either clear a
        // whole extra event or spend nothing — never the dead zone where
        // extra parity can't survive one more event.
        let p = NetParams { t: 0.001, r: 19_144.0, lambda: 0.2 * 19_144.0, n: 32, s: 1024 };
        let rs = ResidualSchedule::new(
            LevelSchedule::new(vec![1024 * 1024, 4096 * 1024], vec![0.01, 0.0001]),
            vec![32, 128],
        );
        // 5120 data fragments cost ~0.268 s; 0.30 leaves ~600 fragments
        // of parity budget, so the solvers must actually choose.
        let budget = 0.30;
        let iid = BitplaneDeadlinePlan::replan_residual_exact(&p, &rs, budget, 1.0).unwrap();
        let bursty = BitplaneDeadlinePlan::replan_residual_exact(&p, &rs, budget, 8.0).unwrap();
        for &mj in &bursty.base.m {
            assert!(
                mj % 8 == 0 || mj == 16,
                "burst-aware m={mj} wastes parity inside a plateau: {:?}",
                bursty.base.m
            );
        }
        assert!(iid.base.time <= budget && bursty.base.time <= budget);
    }

    #[test]
    fn exact_replan_rejects_empty_budgets() {
        let p = NetParams { t: 0.001, r: 1000.0, lambda: 0.0, n: 32, s: 1024 };
        let rs = ResidualSchedule::new(
            LevelSchedule::new(vec![64 * 1024], vec![0.01]),
            vec![64],
        );
        assert!(BitplaneDeadlinePlan::replan_residual_exact(&p, &rs, 0.0, 1.0).is_none());
        assert!(BitplaneDeadlinePlan::replan_residual_exact(&p, &rs, f64::NAN, 1.0).is_none());
        assert!(BitplaneDeadlinePlan::replan_residual_exact(&p, &rs, 0.01, 1.0).is_none());
    }

    #[test]
    fn coordinate_descent_matches_exhaustive_closely() {
        let (p, s) = setup(383.0);
        let tau = 401.11;
        let ex = optimize_deadline_exhaustive(&p, &s, tau).unwrap();
        let cd = optimize_deadline_coordinate(&p, &s, tau, 3).unwrap();
        // CD is a heuristic; it must be within 5% of the exact optimum.
        assert!(
            cd.expected_error <= ex.expected_error * 1.05 + 1e-12,
            "cd={} ex={}",
            cd.expected_error,
            ex.expected_error
        );
    }
}
