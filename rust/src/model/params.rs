//! Model parameters — the symbols of the paper's Table 1.

/// Network and coding parameters shared by both optimization models.
#[derive(Debug, Clone, Copy)]
pub struct NetParams {
    /// `t`: one-way latency of a single fragment, seconds.
    pub t: f64,
    /// `r`: effective fragments transmitted per second,
    /// `min(r_ec, r_link)` (§4.1).
    pub r: f64,
    /// `λ`: packet-loss events per second.
    pub lambda: f64,
    /// `n`: fragments per fault-tolerant group (data + parity).
    pub n: usize,
    /// `s`: fragment payload size in bytes.
    pub s: usize,
}

impl NetParams {
    /// The paper's measured testbed parameters (§5.2.2): t = 0.01 s,
    /// r_link = 19 144 packets/s of 4 096 B, n = 32.
    pub fn paper_default(lambda: f64) -> Self {
        NetParams { t: 0.01, r: 19_144.0, lambda, n: 32, s: 4_096 }
    }

    /// Effective rate from generation and link rates.
    pub fn effective_rate(r_ec: f64, r_link: f64) -> f64 {
        r_ec.min(r_link)
    }

    /// The paper's three loss regimes (§5.2.2): λ = r·0.1% (low),
    /// r·2% (medium), r·5% (high) ⇒ 19, 383, 957 losses/s.
    pub fn paper_lambdas() -> [f64; 3] {
        [19.0, 383.0, 957.0]
    }
}

/// A sub-level shed point inside one transfer level: delivering the
/// level's first `bytes` bytes still decodes (the codec cuts only at
/// segment boundaries) and achieves the measured relative L∞ error
/// `eps`. Produced by `janus::codec` (one cut per interior bitplane
/// segment boundary); consumed by the Deadline solver so Alg. 2 can
/// shed at *bitplane* granularity instead of whole levels.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlaneCut {
    /// Decodable byte prefix of the level.
    pub bytes: u64,
    /// Measured ε when reconstruction stops at this prefix; strictly
    /// between the level's own ε and the previous level's.
    pub eps: f64,
}

/// Hierarchical level schedule from data refactoring (pMGARD-style).
///
/// `sizes[i]` is the byte size `S_{i+1}` of level i+1; `eps[i]` is the
/// relative L∞ error `ε_{i+1}` when reconstructing with levels 1..=i+1.
/// `ε_0 = 1` (nothing received) is implicit. `cuts[i]` optionally lists
/// the level's interior [`PlaneCut`]s (empty = whole-level granularity).
#[derive(Debug, Clone)]
pub struct LevelSchedule {
    pub sizes: Vec<u64>,
    pub eps: Vec<f64>,
    pub cuts: Vec<Vec<PlaneCut>>,
}

impl LevelSchedule {
    pub fn new(sizes: Vec<u64>, eps: Vec<f64>) -> Self {
        assert_eq!(sizes.len(), eps.len(), "one ε per level");
        assert!(
            eps.windows(2).all(|w| w[0] > w[1]),
            "ε must strictly decrease with more levels"
        );
        let cuts = vec![Vec::new(); sizes.len()];
        LevelSchedule { sizes, eps, cuts }
    }

    /// Attach sub-level plane cuts (one list per level, possibly empty).
    /// Each list must be strictly increasing in bytes, strictly
    /// decreasing in ε, inside the level's byte size, and strictly
    /// between the neighbouring whole-level ε values.
    pub fn with_cuts(mut self, cuts: Vec<Vec<PlaneCut>>) -> Self {
        if cuts.is_empty() {
            return self;
        }
        assert_eq!(cuts.len(), self.sizes.len(), "one cut list per level");
        for (li, list) in cuts.iter().enumerate() {
            let mut last_bytes = 0u64;
            let mut last_eps = self.eps_with_levels(li); // ε before this level
            for cut in list {
                assert!(
                    cut.bytes > last_bytes && cut.bytes < self.sizes[li],
                    "level {li}: cut bytes must be strictly inside the level"
                );
                assert!(
                    cut.eps < last_eps && cut.eps > self.eps[li],
                    "level {li}: cut ε must interpolate the level's ε range"
                );
                last_bytes = cut.bytes;
                last_eps = cut.eps;
            }
        }
        self.cuts = cuts;
        self
    }

    /// The largest plane cut of `level` whose prefix fits `budget_bytes`
    /// (None when the level has no cuts or none fit).
    pub fn best_cut_within(&self, level: usize, budget_bytes: u64) -> Option<PlaneCut> {
        self.cuts
            .get(level)?
            .iter()
            .rev()
            .find(|c| c.bytes <= budget_bytes)
            .copied()
    }

    /// The paper's Nyx schedule (§5.1): S = 668 MB, 2.67 GB, 5.42 GB,
    /// 17.99 GB; ε = 4e-3, 5e-4, 6e-5, 1e-7.
    pub fn paper_nyx() -> Self {
        LevelSchedule::new(
            vec![
                668 * 1024 * 1024,
                (2.67 * 1024.0 * 1024.0 * 1024.0) as u64,
                (5.42 * 1024.0 * 1024.0 * 1024.0) as u64,
                (17.99 * 1024.0 * 1024.0 * 1024.0) as u64,
            ],
            vec![0.004, 0.0005, 0.00006, 0.0000001],
        )
    }

    /// A proportionally-scaled schedule for fast tests/CI: same shape,
    /// `factor` times smaller.
    pub fn paper_nyx_scaled(factor: u64) -> Self {
        let full = Self::paper_nyx();
        LevelSchedule::new(
            full.sizes.iter().map(|&s| (s / factor).max(1)).collect(),
            full.eps,
        )
    }

    /// Number of levels `L`.
    pub fn num_levels(&self) -> usize {
        self.sizes.len()
    }

    /// ε after receiving the first `levels` levels (`ε_0 = 1`).
    pub fn eps_with_levels(&self, levels: usize) -> f64 {
        if levels == 0 {
            1.0
        } else {
            self.eps[levels.min(self.eps.len()) - 1]
        }
    }

    /// Smallest `l` with `ε_l ≤ bound` (Alg. 1 line 1). None if even all
    /// L levels cannot meet the bound.
    pub fn levels_for_error_bound(&self, bound: f64) -> Option<usize> {
        (1..=self.num_levels()).find(|&l| self.eps_with_levels(l) <= bound)
    }

    /// Total bytes of the first `l` levels.
    pub fn total_bytes(&self, l: usize) -> u64 {
        self.sizes[..l].iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_match_section_5() {
        let p = NetParams::paper_default(383.0);
        assert_eq!(p.n, 32);
        assert_eq!(p.s, 4096);
        assert!((p.t - 0.01).abs() < 1e-12);
        assert!((p.r - 19_144.0).abs() < 1e-9);
        let s = LevelSchedule::paper_nyx();
        assert_eq!(s.num_levels(), 4);
        assert_eq!(s.sizes[0], 668 * 1024 * 1024);
        assert!((s.eps[3] - 1e-7).abs() < 1e-15);
    }

    #[test]
    fn effective_rate_is_min() {
        assert_eq!(NetParams::effective_rate(319_531.0, 19_144.0), 19_144.0);
        assert_eq!(NetParams::effective_rate(10.0, 19_144.0), 10.0);
    }

    #[test]
    fn levels_for_error_bound_picks_smallest_l() {
        let s = LevelSchedule::paper_nyx();
        // ε: 0.004, 0.0005, 0.00006, 1e-7
        assert_eq!(s.levels_for_error_bound(0.5), Some(1));
        assert_eq!(s.levels_for_error_bound(0.004), Some(1));
        assert_eq!(s.levels_for_error_bound(0.003), Some(2));
        assert_eq!(s.levels_for_error_bound(0.00001), Some(4)); // paper §5.2.3
        assert_eq!(s.levels_for_error_bound(1e-9), None);
    }

    #[test]
    fn eps_with_levels_monotone() {
        let s = LevelSchedule::paper_nyx();
        assert_eq!(s.eps_with_levels(0), 1.0);
        for l in 1..4 {
            assert!(s.eps_with_levels(l) > s.eps_with_levels(l + 1));
        }
    }

    #[test]
    #[should_panic(expected = "strictly decrease")]
    fn non_monotone_eps_rejected() {
        LevelSchedule::new(vec![10, 10], vec![0.1, 0.1]);
    }

    #[test]
    fn plane_cuts_validate_and_select() {
        let s = LevelSchedule::new(vec![100, 1000], vec![0.01, 0.0001]).with_cuts(vec![
            vec![],
            vec![
                PlaneCut { bytes: 200, eps: 0.005 },
                PlaneCut { bytes: 600, eps: 0.0008 },
            ],
        ]);
        // Largest cut fitting the budget wins; too-small budgets yield none.
        assert_eq!(s.best_cut_within(1, 150), None);
        assert_eq!(s.best_cut_within(1, 250).unwrap().bytes, 200);
        assert_eq!(s.best_cut_within(1, 10_000).unwrap().bytes, 600);
        assert_eq!(s.best_cut_within(0, 1_000), None, "no cuts on level 0");
        assert_eq!(s.best_cut_within(5, 1_000), None, "out of range is None");
        // A cut-free schedule stays cut-free.
        let plain = LevelSchedule::paper_nyx();
        assert!(plain.cuts.iter().all(|c| c.is_empty()));
    }

    #[test]
    #[should_panic(expected = "strictly inside")]
    fn cut_beyond_level_size_rejected() {
        LevelSchedule::new(vec![100], vec![0.01])
            .with_cuts(vec![vec![PlaneCut { bytes: 100, eps: 0.05 }]]);
    }

    #[test]
    #[should_panic(expected = "interpolate")]
    fn cut_eps_outside_level_range_rejected() {
        // ε must sit strictly between ε_0 = 1 and the level's 0.01.
        LevelSchedule::new(vec![100], vec![0.01])
            .with_cuts(vec![vec![PlaneCut { bytes: 50, eps: 0.005 }]]);
    }

    #[test]
    fn scaled_schedule_preserves_shape() {
        let s = LevelSchedule::paper_nyx_scaled(1000);
        let f = LevelSchedule::paper_nyx();
        for i in 0..4 {
            let ratio = f.sizes[i] as f64 / s.sizes[i] as f64;
            assert!((ratio - 1000.0).abs() / 1000.0 < 0.01);
        }
    }
}
