//! Metrics and the bench harness.
//!
//! `criterion` is not in the offline vendored crate set (DESIGN.md §3),
//! so `rust/benches/*` are `harness = false` binaries built on
//! [`bench::BenchTable`]: named rows of repeated measurements with
//! median/MAD summaries, pretty-printed and mirrored as TSV under
//! `target/bench-results/` for EXPERIMENTS.md.

pub mod bench;

pub use bench::{BenchTable, Measurement};
