//! Minimal criterion-style bench harness (offline substitute).

use crate::util::stats;
use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

/// One measured quantity across repeats.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub label: String,
    pub values: Vec<f64>,
    pub unit: &'static str,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        stats::median(&self.values)
    }
    pub fn mad(&self) -> f64 {
        stats::mad(&self.values)
    }
}

/// A named results table for one experiment (one paper figure/table).
pub struct BenchTable {
    pub name: String,
    pub columns: Vec<&'static str>,
    rows: Vec<(String, Vec<String>)>,
    started: Instant,
}

impl BenchTable {
    pub fn new(name: &str, columns: Vec<&'static str>) -> Self {
        println!("\n=== {name} ===");
        BenchTable { name: name.to_string(), columns, rows: Vec::new(), started: Instant::now() }
    }

    /// Add a row (first column is the row label).
    pub fn row<S: Into<String>>(&mut self, label: S, cells: Vec<String>) {
        let label = label.into();
        let mut line = format!("{label:<26}");
        for c in &cells {
            line.push_str(&format!(" {c:>14}"));
        }
        println!("{line}");
        self.rows.push((label, cells));
    }

    /// Print the header line.
    pub fn header(&self) {
        let mut line = format!("{:<26}", self.columns.first().copied().unwrap_or(""));
        for c in self.columns.iter().skip(1) {
            line.push_str(&format!(" {c:>14}"));
        }
        println!("{line}");
    }

    /// Format a (median ± mad) cell.
    pub fn cell(values: &[f64]) -> String {
        if values.len() == 1 {
            format!("{:.2}", values[0])
        } else {
            format!("{:.2}±{:.2}", stats::median(values), stats::mad(values))
        }
    }

    /// Write the table as TSV under `target/bench-results/<name>.tsv`.
    pub fn save(&self) -> std::io::Result<PathBuf> {
        let dir = PathBuf::from("target/bench-results");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{}.tsv", self.name));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "# {} ({:.1}s)", self.name, self.started.elapsed().as_secs_f64())?;
        writeln!(f, "{}", self.columns.join("\t"))?;
        for (label, cells) in &self.rows {
            writeln!(f, "{label}\t{}", cells.join("\t"))?;
        }
        println!("[saved {}]", path.display());
        Ok(path)
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Environment-controlled scale divisor for long benches
/// (`JANUS_SCALE=1` reproduces the paper's full 26.75 GB workload).
pub fn bench_scale(default: u64) -> u64 {
    std::env::var("JANUS_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
        .max(1)
}

/// Number of repetitions (`JANUS_RUNS` override).
pub fn bench_runs(default: usize) -> usize {
    std::env::var("JANUS_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
        .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_formats() {
        assert_eq!(BenchTable::cell(&[2.0]), "2.00");
        let c = BenchTable::cell(&[1.0, 2.0, 3.0]);
        assert!(c.starts_with("2.00±"), "{c}");
    }

    #[test]
    fn table_saves_tsv() {
        let mut t = BenchTable::new("unit_test_table", vec!["m", "time"]);
        t.row("0", vec!["1.23".into()]);
        let path = t.save().unwrap();
        let content = std::fs::read_to_string(path).unwrap();
        assert!(content.contains("unit_test_table"));
        assert!(content.contains("1.23"));
    }

    #[test]
    fn scale_defaults() {
        assert_eq!(bench_scale(10), 10);
        assert_eq!(bench_runs(5), 5);
    }

    #[test]
    fn time_it_measures() {
        let (v, secs) = time_it(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
