//! Encoder + error planner: volume → rungs of CRC'd plane segments.
//!
//! The planner maps the requested relative-L∞ ε ladder to per-level
//! plane counts. Seeding uses the bitplane truncation bound — a level
//! decoded with `b` of its planes is off by at most `2^(e_max − b)` per
//! coefficient, amplified by at most `4×` per inverse 3-D lifting step —
//! then every rung is **verified by measurement** against the original
//! volume, bumping the worst-residual level until the measured ε meets
//! the request. The recorded ε of every rung (and of every interior
//! segment boundary, the [`PlaneCut`]s the Deadline contract sheds at)
//! is therefore a measured bound, not a model estimate.

use super::container::{SegmentHeader, StreamHeader};
use super::{CodecConfig, CodecError};
use crate::model::params::PlaneCut;
use crate::refactor::bitplane::BitplaneBlock;
use crate::refactor::lifting::{try_decompose, try_reconstruct, Volume};

/// Floor for recorded ε values (a `Dataset` ladder must stay in (0, 1]).
const EPS_FLOOR: f64 = 1e-12;

/// Within-rung segment emission order (see [`encode_ordered`]).
///
/// Segment order never changes what a *full* rung decodes to — the
/// decoder applies per-level plane windows independently — but it does
/// change what a rung **prefix** certifies: the Deadline contract sheds
/// at interior [`PlaneCut`] boundaries, so the ε reached per byte of
/// rung is the shed schedule's quality.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentOrder {
    /// Coarse-to-fine level order — the legacy emission.
    LevelOrder,
    /// Greedy marginal-ε: each next segment is the one whose planes cut
    /// the measured error the most. Falls back to [`SegmentOrder::LevelOrder`]
    /// unless the greedy order's certified-ε step function dominates at
    /// every byte budget, so reordering can never worsen a shed point.
    MarginalEps,
}

/// Certified ε at `budget` bytes into a rung whose segment boundaries
/// are `steps` (cumulative bytes, measured ε): the running minimum over
/// boundaries inside the budget, starting from the previous rung's ε —
/// exactly the semantics of the [`PlaneCut`] list the boundaries feed.
fn certified_at(steps: &[(u64, f64)], budget: u64, start: f64) -> f64 {
    let mut e = start;
    for &(bytes, eps) in steps {
        if bytes <= budget && eps < e {
            e = eps;
        }
    }
    e
}

/// Does emission order `a` certify an ε no worse than order `b` at
/// *every* byte budget? Both step functions only change at their
/// boundaries, so the union of boundary budgets is exhaustive.
fn order_dominates(a: &[(u64, f64)], b: &[(u64, f64)], start: f64) -> bool {
    a.iter()
        .chain(b)
        .all(|&(budget, _)| certified_at(a, budget, start) <= certified_at(b, budget, start) + 1e-15)
}

/// The serialized progressive container plus its measured metadata.
#[derive(Debug, Clone)]
pub struct Encoded {
    /// Volume dimension.
    pub d: usize,
    /// Lifting levels `L`.
    pub levels: usize,
    /// One byte buffer per ε rung — the transfer levels of a
    /// [`crate::api::Dataset`]. Rung 0 opens with the stream header.
    pub rungs: Vec<Vec<u8>>,
    /// Measured relative L∞ error after each rung; strictly decreasing,
    /// each at or below its requested ladder entry.
    pub eps: Vec<f64>,
    /// Plane counts per rung per level (`planes[r][l]`), cumulative.
    pub planes: Vec<Vec<u8>>,
    /// Interior segment boundaries per rung: byte offsets into the rung
    /// at which a prefix stays decodable, with the measured ε there —
    /// the bitplane-granularity shed points for the Deadline contract.
    pub cuts: Vec<Vec<PlaneCut>>,
}

impl Encoded {
    /// Total container bytes across all rungs.
    pub fn total_bytes(&self) -> u64 {
        self.rungs.iter().map(|r| r.len() as u64).sum()
    }

    /// Bytes of the raw f32 volume the container encodes.
    pub fn raw_bytes(&self) -> u64 {
        (self.d * self.d * self.d * 4) as u64
    }
}

struct LevelCtx {
    block: BitplaneBlock,
    max_abs: f32,
    /// Conservative L∞ amplification of this level's coefficient error
    /// through the inverse lifting chain (4× per 3-D step).
    amp: f64,
}

/// Encode `vol` against the config's ε ladder. Fails with a typed error
/// on unsupported shapes, degenerate volumes, or rungs the plane budget
/// cannot reach. Segments within each rung are scheduled by marginal ε
/// reduction ([`SegmentOrder::MarginalEps`]).
pub fn encode(vol: &Volume, cfg: &CodecConfig) -> Result<Encoded, CodecError> {
    encode_ordered(vol, cfg, SegmentOrder::MarginalEps)
}

/// [`encode`] with an explicit within-rung segment order.
pub fn encode_ordered(
    vol: &Volume,
    cfg: &CodecConfig,
    order: SegmentOrder,
) -> Result<Encoded, CodecError> {
    cfg.validate()?;
    if vol.data.iter().any(|v| !v.is_finite()) {
        return Err(CodecError::BadConfig("volume values must be finite"));
    }
    let den = vol.data.iter().fold(0f32, |a, &v| a.max(v.abs()));
    if den == 0.0 {
        return Err(CodecError::BadConfig(
            "an all-zero volume has no relative error scale",
        ));
    }
    let l = cfg.levels;
    let coeffs = try_decompose(vol, l)?;
    let ctxs: Vec<LevelCtx> = coeffs
        .iter()
        .enumerate()
        .map(|(i, c)| {
            let max_abs = c.iter().fold(0f32, |a, &v| a.max(v.abs()));
            // The coarse buffer passes through all L−1 inverse steps;
            // detail buffer i enters at step i and passes through the
            // remaining L−i.
            let steps = if i == 0 { l - 1 } else { l - i };
            LevelCtx {
                block: BitplaneBlock::encode(c, cfg.max_planes),
                max_abs,
                amp: 4f64.powi(steps as i32),
            }
        })
        .collect();

    // Measured relative L∞ error of a plane-count vector (0 = absent).
    let measure = |b: &[u8]| -> Result<f64, CodecError> {
        let bufs: Vec<Vec<f32>> = ctxs
            .iter()
            .zip(b)
            .map(|(ctx, &bi)| {
                if bi == 0 {
                    vec![0f32; ctx.block.len]
                } else {
                    ctx.block.decode_prefix(bi)
                }
            })
            .collect();
        let refs: Vec<&[f32]> = bufs.iter().map(|v| v.as_slice()).collect();
        let rec = try_reconstruct(&refs, l, l, vol.d)?;
        Ok(vol.linf_rel_error(&rec))
    };

    // Add one plane to the level with the largest residual error bound.
    let bump = |b: &mut [u8]| -> bool {
        let mut best = None;
        let mut best_residual = f64::NEG_INFINITY;
        for (i, ctx) in ctxs.iter().enumerate() {
            if ctx.max_abs == 0.0 || b[i] >= cfg.max_planes {
                continue;
            }
            let residual = ctx.amp * (2f64).powi(ctx.block.e_max - b[i] as i32);
            if residual > best_residual {
                best_residual = residual;
                best = Some(i);
            }
        }
        match best {
            Some(i) => {
                b[i] += 1;
                true
            }
            None => false,
        }
    };

    // Theory seed: planes so each level's amplified truncation error
    // stays under an equal share of the rung's absolute budget.
    let seed = |eps_req: f64, prev: &[u8]| -> Vec<u8> {
        let budget = eps_req * den as f64 / l as f64;
        ctxs.iter()
            .enumerate()
            .map(|(i, ctx)| {
                if ctx.max_abs == 0.0 {
                    return prev[i];
                }
                let need = ctx.block.e_max as f64 - (budget / ctx.amp).log2();
                (need.ceil().clamp(0.0, cfg.max_planes as f64) as u8).max(prev[i])
            })
            .collect()
    };

    let mut prev_b = vec![0u8; l];
    let mut prev_eps = 1.0f64;
    let mut rungs = Vec::with_capacity(cfg.ladder.len());
    let mut eps_rec = Vec::with_capacity(cfg.ladder.len());
    let mut planes_plan = Vec::with_capacity(cfg.ladder.len());
    let mut cuts_all = Vec::with_capacity(cfg.ladder.len());

    for (r, &eps_req) in cfg.ladder.iter().enumerate() {
        let mut b = seed(eps_req, &prev_b);
        if b == prev_b && !bump(&mut b) {
            return Err(CodecError::UnachievableEps { rung: r, requested: eps_req, best: prev_eps });
        }
        let mut measured = measure(&b)?;
        // Verify against the original; the rung must beat both its
        // request and the previous rung (the Dataset ladder is strict).
        while !(measured <= eps_req && measured < prev_eps) {
            if !bump(&mut b) {
                return Err(CodecError::UnachievableEps {
                    rung: r,
                    requested: eps_req,
                    best: measured,
                });
            }
            measured = measure(&b)?;
        }
        let measured = measured.max(EPS_FLOOR);
        if measured >= prev_eps {
            // Only reachable when an earlier rung already hit the floor.
            return Err(CodecError::UnachievableEps { rung: r, requested: eps_req, best: measured });
        }

        // Schedule the rung's segments (one per level that gained
        // planes). Level order measures each prefix as it goes; the
        // marginal-ε greedy additionally searches, at every step, for
        // the remaining segment whose planes cut the measured error the
        // most — and is only kept if its certified-ε step function
        // dominates level order at every byte budget.
        let new_levels: Vec<usize> = (0..l).filter(|&i| b[i] > prev_b[i]).collect();
        // Measured ε at each segment boundary of an emission order (the
        // last boundary is the rung's `measured`, shared).
        let boundary_eps = |seq: &[usize]| -> Result<Vec<f64>, CodecError> {
            let mut cur = prev_b.clone();
            let mut out = Vec::with_capacity(seq.len());
            for (si, &i) in seq.iter().enumerate() {
                cur[i] = b[i];
                out.push(if si + 1 == seq.len() {
                    measured
                } else {
                    measure(&cur)?.max(EPS_FLOOR)
                });
            }
            Ok(out)
        };
        // Serialized length of level `i`'s segment — order-independent
        // (`eps_after` is fixed-width), so a scratch write sizes it.
        let seg_len = |i: usize| -> u64 {
            let ctx = &ctxs[i];
            let hdr = SegmentHeader {
                level: i as u8,
                plane_lo: prev_b[i],
                plane_hi: b[i],
                planes_total: ctx.block.planes,
                e_max: ctx.block.e_max,
                coeff_count: ctx.block.len as u64,
                eps_after: 0.0,
            };
            let plane_refs: Vec<&[u8]> = ctx.block.plane_bits
                [prev_b[i] as usize..b[i] as usize]
                .iter()
                .map(|p| p.as_slice())
                .collect();
            let signs =
                if prev_b[i] == 0 { Some(ctx.block.signs.as_slice()) } else { None };
            let mut scratch = Vec::new();
            super::container::write_segment(&mut scratch, &hdr, signs, &plane_refs);
            scratch.len() as u64
        };
        let steps_of = |seq: &[usize], eps: &[f64]| -> Vec<(u64, f64)> {
            let mut acc = 0u64;
            seq.iter()
                .zip(eps)
                .map(|(&i, &e)| {
                    acc += seg_len(i);
                    (acc, e)
                })
                .collect()
        };
        let (emit, emit_eps) = match order {
            SegmentOrder::MarginalEps if new_levels.len() > 1 => {
                let mut remaining = new_levels.clone();
                let mut cur = prev_b.clone();
                let mut seq = Vec::with_capacity(new_levels.len());
                let mut seq_eps = Vec::with_capacity(new_levels.len());
                while !remaining.is_empty() {
                    if remaining.len() == 1 {
                        let i = remaining.pop().expect("non-empty");
                        cur[i] = b[i];
                        seq.push(i);
                        seq_eps.push(measured);
                        break;
                    }
                    // Ties break toward the lower level index (the
                    // `<` comparison), keeping the schedule
                    // deterministic.
                    let mut best = 0usize;
                    let mut best_eps = f64::INFINITY;
                    for (ci, &i) in remaining.iter().enumerate() {
                        let saved = cur[i];
                        cur[i] = b[i];
                        let e = measure(&cur)?.max(EPS_FLOOR);
                        cur[i] = saved;
                        if e < best_eps {
                            best_eps = e;
                            best = ci;
                        }
                    }
                    let i = remaining.remove(best);
                    cur[i] = b[i];
                    seq.push(i);
                    seq_eps.push(best_eps);
                }
                let lvl_eps = boundary_eps(&new_levels)?;
                let greedy_steps = steps_of(&seq, &seq_eps);
                let lvl_steps = steps_of(&new_levels, &lvl_eps);
                if order_dominates(&greedy_steps, &lvl_steps, prev_eps) {
                    (seq, seq_eps)
                } else {
                    (new_levels.clone(), lvl_eps)
                }
            }
            _ => {
                let eps = boundary_eps(&new_levels)?;
                (new_levels.clone(), eps)
            }
        };

        // Serialize the rung in the chosen order, each segment stamped
        // with the measured ε of the stream prefix ending at it.
        let mut bytes = Vec::new();
        if r == 0 {
            StreamHeader { d: vol.d, levels: l, ladder: cfg.ladder.clone() }
                .encode_into(&mut bytes);
        }
        let mut cuts = Vec::new();
        let mut last_boundary_eps = prev_eps;
        for (si, (&i, &eps_after)) in emit.iter().zip(&emit_eps).enumerate() {
            let last = si + 1 == emit.len();
            let ctx = &ctxs[i];
            let hdr = SegmentHeader {
                level: i as u8,
                plane_lo: prev_b[i],
                plane_hi: b[i],
                planes_total: ctx.block.planes,
                e_max: ctx.block.e_max,
                coeff_count: ctx.block.len as u64,
                eps_after,
            };
            let plane_refs: Vec<&[u8]> = ctx.block.plane_bits
                [prev_b[i] as usize..b[i] as usize]
                .iter()
                .map(|p| p.as_slice())
                .collect();
            let signs =
                if prev_b[i] == 0 { Some(ctx.block.signs.as_slice()) } else { None };
            super::container::write_segment(&mut bytes, &hdr, signs, &plane_refs);
            // An interior boundary is a usable shed point only if it
            // strictly improves on the previous boundary and is still
            // strictly worse than delivering the whole rung.
            if !last && eps_after < last_boundary_eps && eps_after > measured {
                cuts.push(PlaneCut { bytes: bytes.len() as u64, eps: eps_after });
                last_boundary_eps = eps_after;
            }
        }
        rungs.push(bytes);
        eps_rec.push(measured);
        planes_plan.push(b.clone());
        cuts_all.push(cuts);
        prev_b = b;
        prev_eps = measured;
    }

    Ok(Encoded { d: vol.d, levels: l, rungs, eps: eps_rec, planes: planes_plan, cuts: cuts_all })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::refactor::{generate, GrfConfig};

    #[test]
    fn recorded_ladder_meets_every_request() {
        let vol = generate(32, &GrfConfig::default(), 7);
        let cfg = CodecConfig { levels: 4, ladder: vec![4e-3, 5e-4, 8e-5], max_planes: 24 };
        let enc = encode(&vol, &cfg).unwrap();
        assert_eq!(enc.rungs.len(), 3);
        assert_eq!(enc.eps.len(), 3);
        for (rec, req) in enc.eps.iter().zip(&cfg.ladder) {
            assert!(rec <= req, "recorded {rec} exceeds requested {req}");
            assert!(*rec > 0.0);
        }
        assert!(enc.eps.windows(2).all(|w| w[0] > w[1]), "strict ladder: {:?}", enc.eps);
        // Plane counts are cumulative and never shrink.
        for r in 1..enc.planes.len() {
            for (a, b) in enc.planes[r - 1].iter().zip(&enc.planes[r]) {
                assert!(a <= b);
            }
        }
    }

    #[test]
    fn container_is_smaller_than_raw_f32() {
        let vol = generate(32, &GrfConfig::default(), 8);
        let cfg = CodecConfig::default();
        let enc = encode(&vol, &cfg).unwrap();
        assert!(
            enc.total_bytes() < enc.raw_bytes(),
            "{} vs raw {}",
            enc.total_bytes(),
            enc.raw_bytes()
        );
    }

    #[test]
    fn cuts_sit_strictly_inside_their_rung() {
        let vol = generate(32, &GrfConfig::default(), 9);
        let cfg = CodecConfig { levels: 4, ladder: vec![4e-3, 2e-4], max_planes: 24 };
        let enc = encode(&vol, &cfg).unwrap();
        for (r, cuts) in enc.cuts.iter().enumerate() {
            let rung_len = enc.rungs[r].len() as u64;
            let upper = if r == 0 { 1.0 } else { enc.eps[r - 1] };
            let mut last_bytes = 0u64;
            let mut last_eps = upper;
            for cut in cuts {
                assert!(cut.bytes > last_bytes && cut.bytes < rung_len);
                assert!(cut.eps < last_eps && cut.eps > enc.eps[r]);
                last_bytes = cut.bytes;
                last_eps = cut.eps;
            }
        }
    }

    #[test]
    fn degenerate_inputs_rejected() {
        let zero = Volume::zeros(16);
        assert!(matches!(
            encode(&zero, &CodecConfig::default()),
            Err(CodecError::BadConfig(_))
        ));
        let mut nan = generate(16, &GrfConfig::default(), 1);
        nan.data[0] = f32::NAN;
        assert!(matches!(
            encode(&nan, &CodecConfig::default()),
            Err(CodecError::BadConfig(_))
        ));
        // Odd dimension: typed shape error, not a panic.
        let odd = generate(16, &GrfConfig::default(), 2);
        let cfg = CodecConfig { levels: 6, ..CodecConfig::default() }; // 16 / 2^5 == 0
        assert!(matches!(encode(&odd, &cfg), Err(CodecError::Shape(_))));
    }

    #[test]
    fn unachievable_rung_is_a_typed_error() {
        let vol = generate(16, &GrfConfig::default(), 3);
        // One plane cannot reach 1e-9.
        let cfg = CodecConfig { levels: 2, ladder: vec![1e-9], max_planes: 1 };
        match encode(&vol, &cfg) {
            Err(CodecError::UnachievableEps { rung: 0, requested, best }) => {
                assert!((requested - 1e-9).abs() < 1e-24);
                assert!(best > 1e-9);
            }
            other => panic!("expected UnachievableEps, got {other:?}"),
        }
    }
}
