//! The codec container format — self-describing progressive segments.
//!
//! A codec stream is a sequence of *rungs* (the transfer levels of a
//! [`crate::api::Dataset`]); each rung is a sequence of *segments*, and
//! each segment carries one contiguous bitplane range of one lifting
//! level. Rung 0 additionally opens with a stream header. Every header
//! is self-describing (level, plane range, shared exponent, coefficient
//! count, recorded ε) and every payload is CRC32-protected, so a
//! receiver can decode any prefix of the stream without out-of-band
//! metadata — the progressive-precision property of PAPER.md §2.2.
//!
//! ```text
//! rung 0: [stream header][segment][segment]…
//! rung r: [segment][segment]…
//! segment: JSEG | level | plane_lo | plane_hi | planes_total |
//!          e_max (i32) | coeff_count (u64) | eps_after (f64) |
//!          payload_len (u32) | crc32(header ++ payload) |
//!          payload = [signs iff plane_lo == 0] ++ planes[lo..hi)
//! ```
//!
//! Both CRCs cover their header fields as well as the body: a bit flip
//! in `e_max`, `eps_after`, or the ε ladder would otherwise silently
//! corrupt the decode *certificate* (the recorded measured ε), which is
//! the one thing this container exists to protect.
//!
//! `eps_after` is the relative L∞ error **measured at encode time** when
//! reconstructing from everything up to and including this segment in
//! stream order — what lets a decoder *report* (not guess) the achieved
//! error bound of any delivered prefix.

use super::CodecError;
use crate::util::crc32::Hasher;

/// Magic opening rung 0 of every codec stream.
pub const STREAM_MAGIC: [u8; 4] = *b"JNSC";
/// Magic opening every segment.
pub const SEGMENT_MAGIC: [u8; 4] = *b"JSEG";
/// Container format version.
pub const VERSION: u8 = 1;
/// Stream header size before the per-rung ε ladder (includes the CRC).
pub const STREAM_HEADER_FIXED: usize = 16;
/// Serialized segment header size (payload follows).
pub const SEGMENT_HEADER_LEN: usize = 36;
/// Largest volume dimension a stream header may declare. Headers come
/// off the wire, so the decoder must not size allocations (or compute
/// `d³`) from an unbounded claim: 1024³ f32 (4 GiB, the paper's Nyx
/// snapshots are 512³) is the ceiling; anything above is rejected as
/// inconsistent before any geometry arithmetic runs.
pub const MAX_DIM: usize = 1024;

/// The stream-level metadata at the front of rung 0: geometry plus the
/// *requested* ε ladder (one entry per rung; the achieved ε of a prefix
/// comes from the segments' measured `eps_after`, not from here).
#[derive(Debug, Clone, PartialEq)]
pub struct StreamHeader {
    /// Volume dimension (the payload is a `(d, d, d)` f32 volume).
    pub d: usize,
    /// Lifting levels in the decomposition.
    pub levels: usize,
    /// Requested relative-L∞ ε per rung.
    pub ladder: Vec<f64>,
}

impl StreamHeader {
    pub fn encoded_len(&self) -> usize {
        STREAM_HEADER_FIXED + 8 * self.ladder.len()
    }

    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let start = out.len();
        out.extend_from_slice(&STREAM_MAGIC);
        out.push(VERSION);
        out.push(self.levels as u8);
        out.push(self.ladder.len() as u8);
        out.push(0); // reserved
        out.extend_from_slice(&(self.d as u32).to_le_bytes());
        let crc_at = out.len();
        out.extend_from_slice(&[0u8; 4]); // CRC patched below
        for &e in &self.ladder {
            out.extend_from_slice(&e.to_le_bytes());
        }
        let mut h = Hasher::new();
        h.update(&out[start..crc_at]);
        h.update(&out[crc_at + 4..]);
        let crc = h.finalize();
        out[crc_at..crc_at + 4].copy_from_slice(&crc.to_le_bytes());
    }

    /// Parse a stream header; returns the header and the bytes consumed.
    pub fn decode(bytes: &[u8]) -> Result<(StreamHeader, usize), CodecError> {
        if bytes.len() < STREAM_HEADER_FIXED {
            return Err(CodecError::Truncated);
        }
        if bytes[0..4] != STREAM_MAGIC {
            return Err(CodecError::BadMagic);
        }
        if bytes[4] != VERSION {
            return Err(CodecError::UnsupportedVersion(bytes[4]));
        }
        let levels = bytes[5] as usize;
        let rungs = bytes[6] as usize;
        let d = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes")) as usize;
        if levels == 0 || rungs == 0 || d == 0 {
            return Err(CodecError::Inconsistent("empty stream header".into()));
        }
        if d > MAX_DIM {
            return Err(CodecError::Inconsistent(format!(
                "declared dimension {d} exceeds the {MAX_DIM} ceiling"
            )));
        }
        let need = STREAM_HEADER_FIXED + 8 * rungs;
        if bytes.len() < need {
            return Err(CodecError::Truncated);
        }
        let crc_stored = u32::from_le_bytes(bytes[12..16].try_into().expect("4 bytes"));
        let mut h = Hasher::new();
        h.update(&bytes[..12]);
        h.update(&bytes[STREAM_HEADER_FIXED..need]);
        if h.finalize() != crc_stored {
            return Err(CodecError::CrcMismatch { level: 0, plane_lo: 0 });
        }
        let ladder = (0..rungs)
            .map(|i| {
                let off = STREAM_HEADER_FIXED + 8 * i;
                f64::from_le_bytes(bytes[off..off + 8].try_into().expect("8 bytes"))
            })
            .collect();
        Ok((StreamHeader { d, levels, ladder }, need))
    }
}

/// Metadata of one segment: a contiguous bitplane range of one level.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SegmentHeader {
    /// Lifting level this range belongs to (0 = coarsest approximation).
    pub level: u8,
    /// First plane of the range (0 = MSB plane; a range starting at 0
    /// also carries the level's sign bitmap).
    pub plane_lo: u8,
    /// One past the last plane of the range.
    pub plane_hi: u8,
    /// Total mantissa planes the level was quantized to (fixes the
    /// reconstruction scale `2^(e_max − planes_total)`).
    pub planes_total: u8,
    /// Shared binary exponent of the level's coefficients.
    pub e_max: i32,
    /// Coefficients in the level.
    pub coeff_count: u64,
    /// Measured relative L∞ error after applying the stream up to and
    /// including this segment.
    pub eps_after: f64,
}

impl SegmentHeader {
    /// Bytes per plane (and per sign bitmap): one bit per coefficient.
    pub fn stride(&self) -> usize {
        (self.coeff_count as usize).div_ceil(8)
    }

    /// Payload length implied by the header.
    pub fn payload_len(&self) -> usize {
        let signs = if self.plane_lo == 0 { self.stride() } else { 0 };
        signs + (self.plane_hi - self.plane_lo) as usize * self.stride()
    }

    fn validate(&self) -> Result<(), CodecError> {
        // `planes_total` sizes the decoder's zero-padding, so a wire
        // value beyond the encoder's hard ceiling is a memory-
        // amplification vector, not a precision claim.
        if self.planes_total == 0 || self.planes_total > super::MAX_PLANES {
            return Err(CodecError::Inconsistent(format!(
                "segment level {} declares {} total planes (max {})",
                self.level,
                self.planes_total,
                super::MAX_PLANES
            )));
        }
        if self.plane_lo >= self.plane_hi || self.plane_hi > self.planes_total {
            return Err(CodecError::Inconsistent(format!(
                "segment level {} has empty or out-of-range plane window [{}, {}) of {}",
                self.level, self.plane_lo, self.plane_hi, self.planes_total
            )));
        }
        if self.coeff_count == 0 || self.coeff_count > (MAX_DIM * MAX_DIM * MAX_DIM) as u64 {
            return Err(CodecError::Inconsistent(format!(
                "segment level {} carries an impossible coefficient count {}",
                self.level, self.coeff_count
            )));
        }
        Ok(())
    }
}

/// Serialize one segment (header + CRC + payload) onto `out`.
///
/// `signs` must be `Some` exactly when `hdr.plane_lo == 0`; `planes`
/// holds the `[plane_lo, plane_hi)` bitplane slices, each
/// `hdr.stride()` bytes.
pub fn write_segment(
    out: &mut Vec<u8>,
    hdr: &SegmentHeader,
    signs: Option<&[u8]>,
    planes: &[&[u8]],
) {
    debug_assert_eq!(signs.is_some(), hdr.plane_lo == 0);
    debug_assert_eq!(planes.len(), (hdr.plane_hi - hdr.plane_lo) as usize);
    let seg_start = out.len();
    out.extend_from_slice(&SEGMENT_MAGIC);
    out.push(hdr.level);
    out.push(hdr.plane_lo);
    out.push(hdr.plane_hi);
    out.push(hdr.planes_total);
    out.extend_from_slice(&hdr.e_max.to_le_bytes());
    out.extend_from_slice(&hdr.coeff_count.to_le_bytes());
    out.extend_from_slice(&hdr.eps_after.to_le_bytes());
    out.extend_from_slice(&(hdr.payload_len() as u32).to_le_bytes());
    let crc_at = out.len();
    out.extend_from_slice(&[0u8; 4]); // CRC patched below
    let payload_start = out.len();
    if let Some(s) = signs {
        debug_assert_eq!(s.len(), hdr.stride());
        out.extend_from_slice(s);
    }
    for p in planes {
        debug_assert_eq!(p.len(), hdr.stride());
        out.extend_from_slice(p);
    }
    // CRC over header fields AND payload (see the module docs).
    let mut h = Hasher::new();
    h.update(&out[seg_start..crc_at]);
    h.update(&out[payload_start..]);
    let crc = h.finalize();
    out[crc_at..crc_at + 4].copy_from_slice(&crc.to_le_bytes());
}

/// One parsed segment borrowing its payload from the input buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedSegment<'a> {
    pub header: SegmentHeader,
    /// Present iff the range starts at plane 0.
    pub signs: Option<&'a [u8]>,
    /// The `[plane_lo, plane_hi)` plane slices, MSB-first order.
    pub planes: Vec<&'a [u8]>,
}

/// Parse the segment starting at `bytes[0]`; returns the segment and the
/// bytes consumed. [`CodecError::Truncated`] means the buffer ends
/// mid-segment — tolerable at the end of a progressive prefix, fatal
/// anywhere else (the caller decides).
pub fn parse_segment(bytes: &[u8]) -> Result<(ParsedSegment<'_>, usize), CodecError> {
    // Magic before length: 4+ bytes of non-JSEG tail is corruption
    // (BadMagic), not a truncated segment — a genuine mid-segment cut
    // always leaves the magic intact.
    if bytes.len() >= 4 && bytes[0..4] != SEGMENT_MAGIC {
        return Err(CodecError::BadMagic);
    }
    if bytes.len() < SEGMENT_HEADER_LEN {
        return Err(CodecError::Truncated);
    }
    let header = SegmentHeader {
        level: bytes[4],
        plane_lo: bytes[5],
        plane_hi: bytes[6],
        planes_total: bytes[7],
        e_max: i32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes")),
        coeff_count: u64::from_le_bytes(bytes[12..20].try_into().expect("8 bytes")),
        eps_after: f64::from_le_bytes(bytes[20..28].try_into().expect("8 bytes")),
    };
    header.validate()?;
    let payload_len = u32::from_le_bytes(bytes[28..32].try_into().expect("4 bytes")) as usize;
    let crc_stored = u32::from_le_bytes(bytes[32..36].try_into().expect("4 bytes"));
    if payload_len != header.payload_len() {
        return Err(CodecError::Inconsistent(format!(
            "segment level {} declares {payload_len} payload bytes, geometry needs {}",
            header.level,
            header.payload_len()
        )));
    }
    let end = SEGMENT_HEADER_LEN + payload_len;
    if bytes.len() < end {
        return Err(CodecError::Truncated);
    }
    let payload = &bytes[SEGMENT_HEADER_LEN..end];
    let mut h = Hasher::new();
    h.update(&bytes[..SEGMENT_HEADER_LEN - 4]);
    h.update(payload);
    if h.finalize() != crc_stored {
        return Err(CodecError::CrcMismatch { level: header.level, plane_lo: header.plane_lo });
    }
    let stride = header.stride();
    let (signs, planes_bytes) = if header.plane_lo == 0 {
        (Some(&payload[..stride]), &payload[stride..])
    } else {
        (None, payload)
    };
    let planes = planes_bytes.chunks_exact(stride).collect();
    Ok((ParsedSegment { header, signs, planes }, end))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_header() -> SegmentHeader {
        SegmentHeader {
            level: 2,
            plane_lo: 0,
            plane_hi: 3,
            planes_total: 12,
            e_max: -4,
            coeff_count: 29, // stride 4 with a ragged tail
            eps_after: 3.25e-4,
        }
    }

    #[test]
    fn stream_header_roundtrip() {
        let h = StreamHeader { d: 64, levels: 4, ladder: vec![4e-3, 5e-4, 6e-5] };
        let mut buf = Vec::new();
        h.encode_into(&mut buf);
        // Trailing bytes (the first segment) must not confuse the parse.
        buf.extend_from_slice(b"JSEGxxxx");
        let (back, used) = StreamHeader::decode(&buf).unwrap();
        assert_eq!(back, h);
        assert_eq!(used, h.encoded_len());
    }

    #[test]
    fn stream_header_rejects_garbage() {
        assert_eq!(StreamHeader::decode(&[0u8; 4]).unwrap_err(), CodecError::Truncated);
        let mut buf = Vec::new();
        StreamHeader { d: 8, levels: 2, ladder: vec![0.1] }.encode_into(&mut buf);
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert_eq!(StreamHeader::decode(&bad).unwrap_err(), CodecError::BadMagic);
        let mut wrong_ver = buf.clone();
        wrong_ver[4] = 9;
        assert_eq!(
            StreamHeader::decode(&wrong_ver).unwrap_err(),
            CodecError::UnsupportedVersion(9)
        );
        // Ladder truncated away.
        assert_eq!(
            StreamHeader::decode(&buf[..STREAM_HEADER_FIXED + 3]).unwrap_err(),
            CodecError::Truncated
        );
    }

    #[test]
    fn absurd_wire_geometry_rejected_before_any_allocation() {
        // A crafted header claiming a u32-max dimension must be a typed
        // error, not a d³ overflow or a multi-GB allocation downstream.
        let mut buf = Vec::new();
        StreamHeader { d: 8, levels: 2, ladder: vec![0.1] }.encode_into(&mut buf);
        buf[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            StreamHeader::decode(&buf),
            Err(CodecError::Inconsistent(_))
        ));
        // Just over the ceiling fails; the ceiling itself parses.
        let mut over = Vec::new();
        StreamHeader { d: MAX_DIM + 1, levels: 2, ladder: vec![0.1] }.encode_into(&mut over);
        assert!(StreamHeader::decode(&over).is_err());
        let mut at = Vec::new();
        StreamHeader { d: MAX_DIM, levels: 2, ladder: vec![0.1] }.encode_into(&mut at);
        assert!(StreamHeader::decode(&at).is_ok());

        // Same for a segment claiming an impossible coefficient count.
        let mut hdr = sample_header();
        hdr.coeff_count = u64::MAX;
        let mut seg = Vec::new();
        seg.extend_from_slice(&SEGMENT_MAGIC);
        seg.push(hdr.level);
        seg.push(hdr.plane_lo);
        seg.push(hdr.plane_hi);
        seg.push(hdr.planes_total);
        seg.extend_from_slice(&hdr.e_max.to_le_bytes());
        seg.extend_from_slice(&hdr.coeff_count.to_le_bytes());
        seg.extend_from_slice(&hdr.eps_after.to_le_bytes());
        seg.extend_from_slice(&0u32.to_le_bytes());
        seg.extend_from_slice(&0u32.to_le_bytes());
        assert!(matches!(parse_segment(&seg), Err(CodecError::Inconsistent(_))));
    }

    #[test]
    fn segment_roundtrip_with_and_without_signs() {
        let hdr = sample_header();
        let stride = hdr.stride();
        let signs = vec![0xA5u8; stride];
        let planes: Vec<Vec<u8>> = (0..3).map(|i| vec![i as u8 + 1; stride]).collect();
        let plane_refs: Vec<&[u8]> = planes.iter().map(|p| p.as_slice()).collect();
        let mut buf = Vec::new();
        write_segment(&mut buf, &hdr, Some(&signs), &plane_refs);
        let (seg, used) = parse_segment(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(seg.header, hdr);
        assert_eq!(seg.signs.unwrap(), signs.as_slice());
        assert_eq!(seg.planes.len(), 3);
        for (got, want) in seg.planes.iter().zip(&planes) {
            assert_eq!(*got, want.as_slice());
        }

        // A continuation range (plane_lo > 0) has no sign bitmap.
        let cont = SegmentHeader { plane_lo: 3, plane_hi: 5, ..hdr };
        let cont_planes: Vec<Vec<u8>> = (0..2).map(|i| vec![0x10 + i as u8; stride]).collect();
        let cont_refs: Vec<&[u8]> = cont_planes.iter().map(|p| p.as_slice()).collect();
        let mut buf2 = Vec::new();
        write_segment(&mut buf2, &cont, None, &cont_refs);
        let (seg2, _) = parse_segment(&buf2).unwrap();
        assert!(seg2.signs.is_none());
        assert_eq!(seg2.planes.len(), 2);
    }

    #[test]
    fn segment_crc_catches_payload_corruption() {
        let hdr = sample_header();
        let stride = hdr.stride();
        let signs = vec![0u8; stride];
        let planes: Vec<Vec<u8>> = (0..3).map(|_| vec![7u8; stride]).collect();
        let refs: Vec<&[u8]> = planes.iter().map(|p| p.as_slice()).collect();
        let mut buf = Vec::new();
        write_segment(&mut buf, &hdr, Some(&signs), &refs);
        let last = buf.len() - 1;
        buf[last] ^= 0x40;
        assert_eq!(
            parse_segment(&buf).unwrap_err(),
            CodecError::CrcMismatch { level: 2, plane_lo: 0 }
        );
    }

    #[test]
    fn header_field_corruption_is_detected() {
        // A flip in a segment's eps_after (header bytes, not payload)
        // must fail the CRC — the recorded ε IS the certificate.
        let hdr = sample_header();
        let stride = hdr.stride();
        let signs = vec![0x11u8; stride];
        let planes: Vec<Vec<u8>> = (0..3).map(|_| vec![0x22u8; stride]).collect();
        let refs: Vec<&[u8]> = planes.iter().map(|p| p.as_slice()).collect();
        let mut buf = Vec::new();
        write_segment(&mut buf, &hdr, Some(&signs), &refs);
        buf[20] ^= 0x01; // inside eps_after
        assert!(matches!(parse_segment(&buf), Err(CodecError::CrcMismatch { .. })));

        // Same for the stream header's ε ladder.
        let mut sbuf = Vec::new();
        StreamHeader { d: 16, levels: 3, ladder: vec![0.1, 0.01] }.encode_into(&mut sbuf);
        let last = sbuf.len() - 1;
        sbuf[last] ^= 0x01;
        assert!(matches!(
            StreamHeader::decode(&sbuf),
            Err(CodecError::CrcMismatch { .. })
        ));
    }

    #[test]
    fn segment_truncation_is_typed() {
        let hdr = sample_header();
        let stride = hdr.stride();
        let signs = vec![0u8; stride];
        let planes: Vec<Vec<u8>> = (0..3).map(|_| vec![7u8; stride]).collect();
        let refs: Vec<&[u8]> = planes.iter().map(|p| p.as_slice()).collect();
        let mut buf = Vec::new();
        write_segment(&mut buf, &hdr, Some(&signs), &refs);
        for cut in [3usize, SEGMENT_HEADER_LEN - 1, SEGMENT_HEADER_LEN + 1, buf.len() - 1] {
            assert_eq!(
                parse_segment(&buf[..cut]).unwrap_err(),
                CodecError::Truncated,
                "cut at {cut}"
            );
        }
    }

    #[test]
    fn short_garbage_tail_is_bad_magic_not_truncation() {
        // 4..35 bytes of non-JSEG garbage must read as corruption; only
        // a genuine mid-segment cut (magic intact) is Truncated.
        assert_eq!(parse_segment(&[0xAAu8; 20]).unwrap_err(), CodecError::BadMagic);
        assert_eq!(parse_segment(b"JSE").unwrap_err(), CodecError::Truncated);
        let mut keeps_magic = vec![0u8; 20];
        keeps_magic[..4].copy_from_slice(&SEGMENT_MAGIC);
        assert_eq!(parse_segment(&keeps_magic).unwrap_err(), CodecError::Truncated);
    }

    #[test]
    fn wire_plane_budget_is_bounded() {
        // planes_total beyond the encoder ceiling is a decoder zero-pad
        // amplification vector: typed error, never an allocation.
        let mut hdr = sample_header();
        hdr.planes_total = 255;
        hdr.plane_hi = 3;
        let mut buf = Vec::new();
        buf.extend_from_slice(&SEGMENT_MAGIC);
        buf.push(hdr.level);
        buf.push(hdr.plane_lo);
        buf.push(hdr.plane_hi);
        buf.push(hdr.planes_total);
        buf.extend_from_slice(&hdr.e_max.to_le_bytes());
        buf.extend_from_slice(&hdr.coeff_count.to_le_bytes());
        buf.extend_from_slice(&hdr.eps_after.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        assert!(matches!(parse_segment(&buf), Err(CodecError::Inconsistent(_))));
    }

    #[test]
    fn segment_rejects_inconsistent_geometry() {
        let mut bad = sample_header();
        bad.plane_hi = bad.plane_lo; // empty window
        let mut buf = Vec::new();
        // Build manually: write_segment debug-asserts, so craft bytes.
        buf.extend_from_slice(&SEGMENT_MAGIC);
        buf.push(bad.level);
        buf.push(bad.plane_lo);
        buf.push(bad.plane_hi);
        buf.push(bad.planes_total);
        buf.extend_from_slice(&bad.e_max.to_le_bytes());
        buf.extend_from_slice(&bad.coeff_count.to_le_bytes());
        buf.extend_from_slice(&bad.eps_after.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        assert!(matches!(parse_segment(&buf), Err(CodecError::Inconsistent(_))));
    }
}
