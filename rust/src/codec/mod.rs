//! # `janus::codec` — the end-to-end error-bounded progressive codec
//!
//! The paper's headline claim (§2.2, abstract) is that a transfer can
//! *balance transmission time and accuracy* by combining erasure coding
//! with error-bounded lossy compression. This module closes the gap
//! between the raw refactoring primitives (`refactor::{lifting,
//! bitplane}`) and the transfer facade: it turns an f32 volume into a
//! progressive, self-describing byte stream whose prefixes decode at
//! known, *measured* error bounds — and back.
//!
//! Pipeline (one direction):
//!
//! ```text
//! Volume (d³ f32)
//!   │ refactor::try_decompose           (L lifting levels)
//!   ▼
//! coefficient buffers ──BitplaneBlock::encode──▶ sign + mantissa planes
//!   │
//!   │ planner: requested ε ladder → per-level plane counts via the
//!   │ 2^(e_max − b) bound, then verified by measurement (the encoder
//!   │ holds the original, so every recorded ε is measured, not modeled)
//!   ▼
//! rungs (one per ε rung) of CRC'd segments   ──▶ api::Dataset levels
//!   ▼                                             (→ FTGs → fragments)
//! Decoder::push_rung × delivered prefix ──▶ Volume + achieved ε
//! ```
//!
//! * [`encode`] / [`Encoded`] — build the container from a volume.
//!   Within each rung, segments are scheduled by greedy marginal-ε
//!   reduction ([`SegmentOrder::MarginalEps`], with a dominance gate
//!   that falls back to level order), so a byte budget cut mid-rung —
//!   the Deadline contract's plane-cut shed — certifies the smallest
//!   reachable ε. [`encode_ordered`] exposes the order explicitly.
//! * [`Decoder`] / [`DecodeOutput`] — progressive reconstruction from
//!   any rung/plane prefix, reporting the recorded achieved ε.
//! * [`container`] — the segment wire format.
//! * The facade integration lives in `api` ([`crate::api::Dataset::from_volume`],
//!   `TransferEvent::LevelDecoded`, `ReceiveSummary::decode_volume`).

pub mod container;
pub mod decoder;
pub mod encoder;

pub use container::{ParsedSegment, SegmentHeader, StreamHeader};
pub use decoder::{DecodeOutput, Decoder};
pub use encoder::{encode, encode_ordered, Encoded, SegmentOrder};

use crate::refactor::ShapeError;
use std::fmt;

/// How many mantissa planes [`crate::refactor::BitplaneBlock`] accepts.
pub const MAX_PLANES: u8 = 30;

/// Everything that can go wrong encoding or decoding a codec stream.
#[derive(Debug, Clone, PartialEq)]
pub enum CodecError {
    /// The volume shape cannot go through the lifting pipeline.
    Shape(ShapeError),
    /// Invalid [`CodecConfig`] (empty/non-decreasing ladder, bad planes).
    BadConfig(&'static str),
    /// The requested ε rung cannot be met even at full precision.
    UnachievableEps { rung: usize, requested: f64, best: f64 },
    /// Bytes do not start with the codec (or segment) magic.
    BadMagic,
    /// Container version this build does not understand.
    UnsupportedVersion(u8),
    /// Bytes end mid-header or mid-payload (acceptable only as the tail
    /// of a progressive prefix).
    Truncated,
    /// A segment's CRC32 does not match its payload.
    CrcMismatch { level: u8, plane_lo: u8 },
    /// Self-contradictory metadata (geometry, plane windows, lengths).
    Inconsistent(String),
    /// Rungs must be pushed to the decoder in stream order.
    OutOfOrder { expected: usize, got: usize },
    /// Decoder operation that needs the stream header before rung 0.
    MissingHeader,
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Shape(e) => write!(f, "codec: {e}"),
            CodecError::BadConfig(why) => write!(f, "codec: bad config: {why}"),
            CodecError::UnachievableEps { rung, requested, best } => write!(
                f,
                "codec: rung {rung} requests eps {requested:.3e} but full precision reaches only {best:.3e}"
            ),
            CodecError::BadMagic => write!(f, "codec: not a codec stream (bad magic)"),
            CodecError::UnsupportedVersion(v) => {
                write!(f, "codec: unsupported container version {v}")
            }
            CodecError::Truncated => write!(f, "codec: bytes end mid-segment"),
            CodecError::CrcMismatch { level, plane_lo } => write!(
                f,
                "codec: CRC mismatch in segment (level {level}, plane {plane_lo})"
            ),
            CodecError::Inconsistent(why) => write!(f, "codec: inconsistent container: {why}"),
            CodecError::OutOfOrder { expected, got } => {
                write!(f, "codec: rung {got} pushed, decoder expects rung {expected}")
            }
            CodecError::MissingHeader => write!(f, "codec: stream header (rung 0) not seen yet"),
        }
    }
}

impl std::error::Error for CodecError {}

impl From<ShapeError> for CodecError {
    fn from(e: ShapeError) -> CodecError {
        CodecError::Shape(e)
    }
}

/// Encoder parameters: lifting depth, the requested ε ladder (one rung
/// per entry, strictly decreasing), and the quantization plane budget.
#[derive(Debug, Clone)]
pub struct CodecConfig {
    /// Lifting levels `L` (the volume dimension must divide `2^(L−1)`).
    pub levels: usize,
    /// Requested relative-L∞ ε per rung, strictly decreasing, each in
    /// (0, 1). The encoder guarantees the *measured* ε of every rung is
    /// at or below its request (or fails with
    /// [`CodecError::UnachievableEps`]).
    pub ladder: Vec<f64>,
    /// Mantissa planes per level (1..=[`MAX_PLANES`]); the precision
    /// ceiling of the whole stream.
    pub max_planes: u8,
}

impl Default for CodecConfig {
    fn default() -> Self {
        CodecConfig { levels: 3, ladder: vec![4e-3, 5e-4, 5e-5], max_planes: 24 }
    }
}

impl CodecConfig {
    pub(crate) fn validate(&self) -> Result<(), CodecError> {
        if self.levels == 0 {
            return Err(CodecError::BadConfig("at least one lifting level required"));
        }
        if self.levels > 250 {
            return Err(CodecError::BadConfig("lifting levels must fit a u8"));
        }
        if self.max_planes == 0 || self.max_planes > MAX_PLANES {
            return Err(CodecError::BadConfig("max_planes must be 1..=30"));
        }
        if self.ladder.is_empty() || self.ladder.len() > 255 {
            return Err(CodecError::BadConfig("ladder needs 1..=255 rungs"));
        }
        if self
            .ladder
            .iter()
            .any(|&e| !e.is_finite() || e <= 0.0 || e >= 1.0)
            || self.ladder.windows(2).any(|w| w[0] <= w[1])
        {
            return Err(CodecError::BadConfig(
                "ladder must be strictly decreasing with every eps in (0, 1)",
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation() {
        assert!(CodecConfig::default().validate().is_ok());
        let bad = CodecConfig { levels: 0, ..CodecConfig::default() };
        assert!(matches!(bad.validate(), Err(CodecError::BadConfig(_))));
        let bad = CodecConfig { max_planes: 31, ..CodecConfig::default() };
        assert!(matches!(bad.validate(), Err(CodecError::BadConfig(_))));
        let bad = CodecConfig { ladder: vec![], ..CodecConfig::default() };
        assert!(matches!(bad.validate(), Err(CodecError::BadConfig(_))));
        let bad = CodecConfig { ladder: vec![1e-3, 1e-3], ..CodecConfig::default() };
        assert!(matches!(bad.validate(), Err(CodecError::BadConfig(_))));
        let bad = CodecConfig { ladder: vec![1.5], ..CodecConfig::default() };
        assert!(matches!(bad.validate(), Err(CodecError::BadConfig(_))));
    }

    #[test]
    fn shape_errors_convert() {
        let e: CodecError = ShapeError::ZeroLevels.into();
        assert!(matches!(e, CodecError::Shape(ShapeError::ZeroLevels)));
        assert!(format!("{e}").contains("lifting"));
    }
}
