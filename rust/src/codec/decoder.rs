//! Progressive decoder: rebuild a volume from any rung/plane prefix.
//!
//! Rungs are pushed in stream order ([`Decoder::push_rung`]); each push
//! applies the rung's CRC-valid segments to the per-level plane state
//! and advances the *recorded* achieved ε (the `eps_after` measured at
//! encode time). [`Decoder::reconstruct`] then inverts the bitplane and
//! lifting transforms over whatever has arrived: absent levels decode
//! as zeros (exactly the zero-filled details the lifting reconstruction
//! expects) and truncated plane budgets decode at reduced precision.

use super::container::{parse_segment, StreamHeader};
use super::CodecError;
use crate::refactor::bitplane::BitplaneBlock;
use crate::refactor::lifting::{level_coeff_counts, try_reconstruct, Volume};

/// What a reconstruction yields.
#[derive(Debug, Clone)]
pub struct DecodeOutput {
    /// The reconstructed `(d, d, d)` volume.
    pub volume: Volume,
    /// Recorded relative L∞ error of the applied prefix (measured at
    /// encode time; 1.0 when nothing has been applied).
    pub achieved_eps: f64,
    /// Fully applied rungs.
    pub rungs_applied: usize,
    /// Contiguous mantissa-plane prefix applied per lifting level.
    pub planes_used: Vec<u8>,
}

/// Per-level accumulation state.
#[derive(Debug, Clone)]
struct LevelState {
    e_max: i32,
    planes_total: u8,
    coeff_count: usize,
    /// Contiguous plane prefix applied so far (headers always tracked).
    applied: u8,
    /// Sign bitmap — empty in headers-only mode.
    signs: Vec<u8>,
    /// Applied planes, MSB-first; empty in headers-only mode.
    planes: Vec<Vec<u8>>,
}

/// Progressive codec-stream decoder. See the module docs for the
/// push/reconstruct protocol.
#[derive(Debug, Clone)]
pub struct Decoder {
    header: Option<StreamHeader>,
    counts: Vec<usize>,
    states: Vec<Option<LevelState>>,
    rungs_applied: usize,
    segments_applied: usize,
    achieved_eps: f64,
    /// When false, payload bytes are validated (CRC) but not stored —
    /// full metadata at zero copies, no reconstruction.
    collect: bool,
}

impl Default for Decoder {
    fn default() -> Decoder {
        Decoder::new()
    }
}

impl Decoder {
    pub fn new() -> Decoder {
        Decoder {
            header: None,
            counts: Vec::new(),
            states: Vec::new(),
            rungs_applied: 0,
            segments_applied: 0,
            achieved_eps: 1.0,
            collect: true,
        }
    }

    /// A decoder that runs every structural/CRC check and tracks the
    /// full metadata (achieved ε, plane counts, geometry) without
    /// copying any payload bytes. [`Decoder::reconstruct`] is
    /// unavailable in this mode; everything else behaves identically —
    /// a prefix this decoder accepts is exactly one a collecting
    /// decoder can reconstruct.
    pub fn headers_only() -> Decoder {
        Decoder { collect: false, ..Decoder::new() }
    }

    /// The stream header, once rung 0 has been pushed.
    pub fn header(&self) -> Option<&StreamHeader> {
        self.header.as_ref()
    }

    /// Recorded ε after the last applied segment (1.0 before any).
    pub fn achieved_eps(&self) -> f64 {
        if self.segments_applied == 0 { 1.0 } else { self.achieved_eps }
    }

    pub fn rungs_applied(&self) -> usize {
        self.rungs_applied
    }

    pub fn segments_applied(&self) -> usize {
        self.segments_applied
    }

    /// Contiguous plane prefix applied per level (empty before rung 0).
    pub fn planes_used(&self) -> Vec<u8> {
        self.states
            .iter()
            .map(|s| s.as_ref().map_or(0, |st| st.applied))
            .collect()
    }

    /// Apply the next rung in stream order (rung 0 must open with the
    /// stream header). Whole CRC-valid segments are applied; a
    /// *trailing* truncated segment is tolerated — that is the
    /// progressive prefix property — but corruption (bad magic, CRC or
    /// geometry mismatches) is an error. Returns the recorded ε after
    /// this rung's last applied segment.
    pub fn push_rung(&mut self, bytes: &[u8]) -> Result<f64, CodecError> {
        let mut off = 0usize;
        if self.header.is_none() {
            let (header, used) = StreamHeader::decode(bytes)?;
            self.counts = level_coeff_counts(header.d, header.levels)?;
            self.states = vec![None; header.levels];
            self.header = Some(header);
            off = used;
        } else if self.rungs_applied >= self.header.as_ref().expect("set").ladder.len() {
            return Err(CodecError::OutOfOrder {
                expected: self.header.as_ref().expect("set").ladder.len(),
                got: self.rungs_applied,
            });
        }
        while off < bytes.len() {
            match parse_segment(&bytes[off..]) {
                Ok((seg, used)) => {
                    self.apply_segment(&seg)?;
                    off += used;
                }
                // The tail of a shed (deadline) or truncated prefix.
                Err(CodecError::Truncated) => break,
                Err(e) => return Err(e),
            }
        }
        self.rungs_applied += 1;
        Ok(self.achieved_eps())
    }

    fn apply_segment(
        &mut self,
        seg: &super::container::ParsedSegment<'_>,
    ) -> Result<(), CodecError> {
        let h = &seg.header;
        let li = h.level as usize;
        let levels = self.states.len();
        if li >= levels {
            return Err(CodecError::Inconsistent(format!(
                "segment level {li} outside the stream's {levels} levels"
            )));
        }
        if h.coeff_count as usize != self.counts[li] {
            return Err(CodecError::Inconsistent(format!(
                "level {li} has {} coefficients, geometry needs {}",
                h.coeff_count, self.counts[li]
            )));
        }
        let collect = self.collect;
        match &mut self.states[li] {
            slot @ None => {
                if h.plane_lo != 0 {
                    return Err(CodecError::Inconsistent(format!(
                        "level {li} starts at plane {} (expected 0)",
                        h.plane_lo
                    )));
                }
                let signs = seg.signs.expect("plane_lo == 0 carries signs");
                *slot = Some(LevelState {
                    e_max: h.e_max,
                    planes_total: h.planes_total,
                    coeff_count: h.coeff_count as usize,
                    applied: h.plane_hi,
                    signs: if collect { signs.to_vec() } else { Vec::new() },
                    planes: if collect {
                        seg.planes.iter().map(|p| p.to_vec()).collect()
                    } else {
                        Vec::new()
                    },
                });
            }
            Some(state) => {
                if state.e_max != h.e_max || state.planes_total != h.planes_total {
                    return Err(CodecError::Inconsistent(format!(
                        "level {li} metadata changed mid-stream"
                    )));
                }
                if h.plane_lo != state.applied {
                    return Err(CodecError::Inconsistent(format!(
                        "level {li} plane window starts at {} but {} planes are applied",
                        h.plane_lo, state.applied
                    )));
                }
                state.applied = h.plane_hi;
                if collect {
                    state.planes.extend(seg.planes.iter().map(|p| p.to_vec()));
                }
            }
        }
        self.achieved_eps = h.eps_after;
        self.segments_applied += 1;
        Ok(())
    }

    /// Invert bitplanes + lifting over everything applied so far.
    /// Unavailable on a [`Decoder::headers_only`] decoder (the payloads
    /// were deliberately not kept).
    pub fn reconstruct(&self) -> Result<DecodeOutput, CodecError> {
        let header = self.header.as_ref().ok_or(CodecError::MissingHeader)?;
        if !self.collect {
            return Err(CodecError::Inconsistent(
                "headers-only decoder holds no payloads to reconstruct from".into(),
            ));
        }
        let bufs: Vec<Vec<f32>> = self
            .states
            .iter()
            .zip(&self.counts)
            .map(|(state, &count)| match state {
                Some(st) if !st.planes.is_empty() => {
                    let avail = st.planes.len() as u8;
                    let stride = st.coeff_count.div_ceil(8);
                    let mut plane_bits = st.planes.clone();
                    while plane_bits.len() < st.planes_total as usize {
                        plane_bits.push(vec![0u8; stride]);
                    }
                    let block = BitplaneBlock {
                        len: st.coeff_count,
                        e_max: st.e_max,
                        planes: st.planes_total,
                        signs: st.signs.clone(),
                        plane_bits,
                    };
                    block.decode_prefix(avail)
                }
                _ => vec![0f32; count],
            })
            .collect();
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let volume = try_reconstruct(&refs, header.levels, header.levels, header.d)?;
        Ok(DecodeOutput {
            volume,
            achieved_eps: self.achieved_eps(),
            rungs_applied: self.rungs_applied,
            planes_used: self.planes_used(),
        })
    }

    /// One-shot decode of a delivered rung prefix.
    pub fn decode(rungs: &[&[u8]]) -> Result<DecodeOutput, CodecError> {
        let mut dec = Decoder::new();
        for rung in rungs {
            dec.push_rung(rung)?;
        }
        dec.reconstruct()
    }
}

#[cfg(test)]
mod tests {
    use super::super::encoder::encode;
    use super::super::{CodecConfig, CodecError};
    use super::*;
    use crate::refactor::{generate, GrfConfig};

    fn encoded_fixture() -> (Volume, super::super::Encoded, CodecConfig) {
        let vol = generate(16, &GrfConfig::default(), 42);
        let cfg = CodecConfig { levels: 3, ladder: vec![8e-3, 8e-4, 2e-4], max_planes: 22 };
        let enc = encode(&vol, &cfg).unwrap();
        (vol, enc, cfg)
    }

    #[test]
    fn full_prefix_reaches_recorded_eps() {
        let (vol, enc, _) = encoded_fixture();
        let refs: Vec<&[u8]> = enc.rungs.iter().map(|r| r.as_slice()).collect();
        let out = Decoder::decode(&refs).unwrap();
        assert_eq!(out.rungs_applied, enc.rungs.len());
        let last = *enc.eps.last().unwrap();
        assert!((out.achieved_eps - last).abs() < 1e-15, "reported ε is the recorded one");
        // The reported ε is *measured*, so the true error matches it.
        let true_err = vol.linf_rel_error(&out.volume);
        assert!(true_err <= out.achieved_eps + 1e-12, "{true_err} vs {}", out.achieved_eps);
    }

    #[test]
    fn every_rung_prefix_decodes_at_its_recorded_eps() {
        let (vol, enc, _) = encoded_fixture();
        for used in 1..=enc.rungs.len() {
            let refs: Vec<&[u8]> = enc.rungs[..used].iter().map(|r| r.as_slice()).collect();
            let out = Decoder::decode(&refs).unwrap();
            assert_eq!(out.rungs_applied, used);
            assert!((out.achieved_eps - enc.eps[used - 1]).abs() < 1e-15);
            let true_err = vol.linf_rel_error(&out.volume);
            assert!(
                true_err <= out.achieved_eps + 1e-12,
                "prefix {used}: {true_err} > {}",
                out.achieved_eps
            );
        }
    }

    #[test]
    fn truncated_trailing_segment_is_a_progressive_prefix() {
        let (vol, enc, _) = encoded_fixture();
        let mut dec = Decoder::new();
        dec.push_rung(&enc.rungs[0]).unwrap();
        let full = Decoder::decode(&[&enc.rungs[0]]).unwrap();
        // Chop the second rung mid-payload: applied segments only.
        let cut = enc.rungs[1].len() - 5;
        let eps = dec.push_rung(&enc.rungs[1][..cut]).unwrap();
        assert!(eps <= enc.eps[0] + 1e-15, "partial rung cannot be worse than rung 1");
        let out = dec.reconstruct().unwrap();
        let true_err = vol.linf_rel_error(&out.volume);
        assert!(true_err <= out.achieved_eps + 1e-12);
        // And it is no worse than stopping at rung 1 entirely.
        assert!(out.achieved_eps <= full.achieved_eps + 1e-15);
    }

    #[test]
    fn corruption_is_detected_not_absorbed() {
        let (_, enc, _) = encoded_fixture();
        // Flip the last byte of rung 0: always inside the final
        // segment's CRC-protected payload.
        let mut bad = enc.rungs[0].clone();
        let idx = bad.len() - 1;
        bad[idx] ^= 0x10;
        let mut dec = Decoder::new();
        let err = dec.push_rung(&bad).unwrap_err();
        assert!(
            matches!(
                err,
                CodecError::CrcMismatch { .. }
                    | CodecError::Inconsistent(_)
                    | CodecError::BadMagic
            ),
            "got {err:?}"
        );
    }

    #[test]
    fn non_codec_bytes_rejected() {
        let mut dec = Decoder::new();
        assert_eq!(dec.push_rung(&[0u8; 64]).unwrap_err(), CodecError::BadMagic);
        assert_eq!(dec.push_rung(b"JN").unwrap_err(), CodecError::Truncated);
        assert_eq!(Decoder::new().reconstruct().unwrap_err(), CodecError::MissingHeader);
    }

    #[test]
    fn pushing_past_the_ladder_is_out_of_order() {
        let (_, enc, _) = encoded_fixture();
        let mut dec = Decoder::new();
        for r in &enc.rungs {
            dec.push_rung(r).unwrap();
        }
        assert!(matches!(
            dec.push_rung(&enc.rungs[0]).unwrap_err(),
            CodecError::OutOfOrder { .. }
        ));
    }

    #[test]
    fn headers_only_mode_tracks_identical_metadata_without_payloads() {
        let (_, enc, _) = encoded_fixture();
        let mut full = Decoder::new();
        let mut light = Decoder::headers_only();
        for rung in &enc.rungs {
            let a = full.push_rung(rung).unwrap();
            let b = light.push_rung(rung).unwrap();
            assert!((a - b).abs() < 1e-18, "identical recorded ε");
        }
        assert_eq!(full.planes_used(), light.planes_used());
        assert_eq!(full.segments_applied(), light.segments_applied());
        assert_eq!(full.rungs_applied(), light.rungs_applied());
        assert_eq!(full.header(), light.header());
        assert!(full.reconstruct().is_ok());
        assert!(
            matches!(light.reconstruct(), Err(CodecError::Inconsistent(_))),
            "headers-only cannot reconstruct"
        );
    }

    #[test]
    fn empty_decoder_state_reports_unit_eps() {
        let dec = Decoder::new();
        assert!((dec.achieved_eps() - 1.0).abs() < 1e-15);
        assert_eq!(dec.rungs_applied(), 0);
        assert!(dec.planes_used().is_empty());
    }
}
