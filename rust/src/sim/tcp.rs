//! TCP baseline — event-driven Reno-style simulation (paper §5.2.1).
//!
//! The paper's baseline: "parity fragment generation is disabled, and
//! acknowledgment and retransmission mechanisms are simulated", with the
//! duplicate-ACK threshold at 3 and the RTO tied to the transmission
//! latency. We model a standard Reno loop: slow start / congestion
//! avoidance, fast retransmit on 3 dup-ACKs, timeout with exponential
//! backoff, cumulative ACKs, link pacing at `r` fragments/s, one-way
//! latency `t` each direction (RTT = 2t). ACKs are assumed lossless (the
//! reverse path carries only tiny control packets).

use super::engine::{run, Scheduler, SimTime, World};
use super::loss::LossProcess;
use crate::model::params::NetParams;

/// Outcome of a simulated TCP transfer.
#[derive(Debug, Clone)]
pub struct TcpResult {
    /// Time until the last byte was acknowledged, seconds.
    pub total_time: f64,
    /// Packets put on the wire (including retransmissions).
    pub packets_sent: u64,
    /// Packets dropped by the loss process.
    pub packets_lost: u64,
    /// Retransmissions (fast + timeout).
    pub retransmissions: u64,
    /// Timeout events.
    pub timeouts: u64,
}

#[derive(Debug)]
enum Ev {
    /// Data packet arrives at the receiver (survived the wire).
    Arrive(u64),
    /// Cumulative ACK (next expected seq) arrives at the sender.
    Ack(u64),
    /// RTO check, armed for a particular epoch.
    Timeout(u64),
    /// Sender may transmit (window/pacing opened up).
    TrySend,
}

struct Tcp<'a> {
    loss: &'a mut dyn LossProcess,
    // Link.
    r: f64,
    t: f64,
    next_free_tx: f64,
    // Sender.
    total: u64,
    send_base: u64,
    next_seq: u64,
    cwnd: f64,
    ssthresh: f64,
    dup_acks: u32,
    rto: f64,
    rto_base: f64,
    timer_epoch: u64,
    timer_armed: bool,
    in_fast_recovery: bool,
    // Receiver.
    rcv_next: u64,
    received: Vec<u64>, // bitset
    // Stats.
    res: TcpResult,
    done_at: Option<f64>,
}

impl<'a> Tcp<'a> {
    fn bit_get(&self, seq: u64) -> bool {
        (self.received[(seq / 64) as usize] >> (seq % 64)) & 1 == 1
    }
    fn bit_set(&mut self, seq: u64) {
        self.received[(seq / 64) as usize] |= 1 << (seq % 64);
    }

    fn in_flight(&self) -> u64 {
        // After a go-back-N reset a later cumulative ACK can advance
        // send_base past next_seq (the receiver already held the data).
        self.next_seq.saturating_sub(self.send_base)
    }

    /// Transmit one packet (new or retransmission) respecting pacing.
    fn transmit(&mut self, now: SimTime, seq: u64, sched: &mut Scheduler<Ev>) {
        let depart = now.max(self.next_free_tx);
        self.next_free_tx = depart + 1.0 / self.r;
        self.res.packets_sent += 1;
        if self.loss.is_lost(depart) {
            self.res.packets_lost += 1;
            // Lost: no arrival event.
        } else {
            sched.schedule_at(depart + self.t, Ev::Arrive(seq));
        }
    }

    fn arm_timer(&mut self, now: SimTime, sched: &mut Scheduler<Ev>) {
        self.timer_epoch += 1;
        self.timer_armed = true;
        sched.schedule_at(now + self.rto, Ev::Timeout(self.timer_epoch));
    }

    /// Send as much new data as window + data allow.
    fn pump(&mut self, now: SimTime, sched: &mut Scheduler<Ev>) {
        let window = self.cwnd.floor().max(1.0) as u64;
        let mut sent_any = false;
        while self.next_seq < self.total && self.in_flight() < window {
            let seq = self.next_seq;
            self.next_seq += 1;
            self.transmit(now, seq, sched);
            sent_any = true;
        }
        if sent_any && !self.timer_armed {
            self.arm_timer(now, sched);
        }
        // If pacing throttled us below the window, poll again when the
        // link frees up.
        if self.next_seq < self.total && self.in_flight() < window {
            sched.schedule_at(self.next_free_tx.max(now + 1e-9), Ev::TrySend);
        }
    }
}

impl<'a> World for Tcp<'a> {
    type Event = Ev;

    fn handle(&mut self, now: SimTime, ev: Ev, sched: &mut Scheduler<Ev>) -> bool {
        match ev {
            Ev::Arrive(seq) => {
                if !self.bit_get(seq) {
                    self.bit_set(seq);
                    while self.rcv_next < self.total && self.bit_get(self.rcv_next) {
                        self.rcv_next += 1;
                    }
                }
                // Cumulative ACK back to the sender (lossless, latency t).
                sched.schedule_at(now + self.t, Ev::Ack(self.rcv_next));
                true
            }
            Ev::Ack(ack) => {
                if ack >= self.total {
                    // Everything delivered & acknowledged.
                    if self.done_at.is_none() {
                        self.done_at = Some(now);
                        self.res.total_time = now;
                    }
                    return false;
                }
                if ack > self.send_base {
                    // New data acknowledged.
                    self.send_base = ack;
                    self.next_seq = self.next_seq.max(ack);
                    self.dup_acks = 0;
                    if self.in_fast_recovery {
                        self.in_fast_recovery = false;
                        self.cwnd = self.ssthresh;
                    } else if self.cwnd < self.ssthresh {
                        self.cwnd += 1.0; // slow start
                    } else {
                        self.cwnd += 1.0 / self.cwnd; // congestion avoidance
                    }
                    self.rto = self.rto_base; // fresh progress resets backoff
                    self.arm_timer(now, sched);
                    self.pump(now, sched);
                } else if ack == self.send_base {
                    self.dup_acks += 1;
                    if self.dup_acks == 3 && !self.in_fast_recovery {
                        // Fast retransmit.
                        self.ssthresh = (self.cwnd / 2.0).max(2.0);
                        self.cwnd = self.ssthresh;
                        self.in_fast_recovery = true;
                        self.res.retransmissions += 1;
                        self.transmit(now, self.send_base, sched);
                        self.arm_timer(now, sched);
                    } else if self.in_fast_recovery {
                        self.cwnd += 1.0; // inflate per extra dup
                        self.pump(now, sched);
                    }
                }
                true
            }
            Ev::Timeout(epoch) => {
                if epoch != self.timer_epoch || self.send_base >= self.total {
                    return true; // stale timer
                }
                // RTO: back off, shrink to one segment, go-back-N restart.
                self.res.timeouts += 1;
                self.res.retransmissions += 1;
                self.ssthresh = (self.cwnd / 2.0).max(2.0);
                self.cwnd = 1.0;
                self.in_fast_recovery = false;
                self.dup_acks = 0;
                self.rto = (self.rto * 2.0).min(60.0);
                // Go-back-N: outstanding unacked data is resent as the
                // window re-opens.
                self.next_seq = self.send_base;
                self.transmit(now, self.send_base, sched);
                self.next_seq = self.send_base + 1;
                self.arm_timer(now, sched);
                true
            }
            Ev::TrySend => {
                self.pump(now, sched);
                true
            }
        }
    }
}

/// Standalone Reno congestion window — the AIMD core of the simulation
/// above (slow start, congestion avoidance, multiplicative decrease)
/// without the event loop, for components that model a *competing* TCP
/// flow packet-by-packet (the testkit's TCP-competitor channel feeds
/// ACK/loss signals in as its shared link admits or drops packets).
#[derive(Debug, Clone)]
pub struct RenoCwnd {
    cwnd: f64,
    ssthresh: f64,
}

impl RenoCwnd {
    /// Initial window of 2 segments, matching [`run_tcp`].
    pub fn new() -> RenoCwnd {
        RenoCwnd { cwnd: 2.0, ssthresh: f64::INFINITY }
    }

    /// One cumulative-ACK step: slow start below ssthresh, congestion
    /// avoidance above.
    pub fn on_ack(&mut self) {
        if self.cwnd < self.ssthresh {
            self.cwnd += 1.0;
        } else {
            self.cwnd += 1.0 / self.cwnd;
        }
    }

    /// One loss event (triple-dup-ACK equivalent): halve, floor at 2.
    pub fn on_loss(&mut self) {
        self.ssthresh = (self.cwnd / 2.0).max(2.0);
        self.cwnd = self.ssthresh;
    }

    /// Current window, segments.
    pub fn cwnd(&self) -> f64 {
        self.cwnd
    }

    /// Send rate implied by the window over `rtt` seconds (segments/s).
    pub fn rate(&self, rtt: f64) -> f64 {
        self.cwnd / rtt.max(1e-9)
    }
}

impl Default for RenoCwnd {
    fn default() -> Self {
        RenoCwnd::new()
    }
}

/// Simulate a TCP transfer of `total_bytes` over the link described by
/// `params` (rate `r`, one-way latency `t`, fragment size `s`).
///
/// `loss` should be a per-packet-fraction process (see
/// [`super::loss::BernoulliLoss`] / [`super::loss::FractionOfRate`]).
pub fn run_tcp(loss: &mut dyn LossProcess, params: &NetParams, total_bytes: u64) -> TcpResult {
    let total = total_bytes.div_ceil(params.s as u64).max(1);
    let rtt = 2.0 * params.t;
    // Paper: "retransmission timeout is set to twice the transmission
    // latency". With RTT = 2t that leaves zero slack, so we interpret it
    // as twice the round trip (2·RTT) — the smallest non-degenerate RTO.
    let rto = 2.0 * rtt;
    let mut world = Tcp {
        loss,
        r: params.r,
        t: params.t,
        next_free_tx: 0.0,
        total,
        send_base: 0,
        next_seq: 0,
        cwnd: 2.0,
        ssthresh: f64::INFINITY,
        dup_acks: 0,
        rto,
        rto_base: rto,
        timer_epoch: 0,
        timer_armed: false,
        in_fast_recovery: false,
        rcv_next: 0,
        received: vec![0u64; (total as usize).div_ceil(64)],
        res: TcpResult {
            total_time: 0.0,
            packets_sent: 0,
            packets_lost: 0,
            retransmissions: 0,
            timeouts: 0,
        },
        done_at: None,
    };
    let mut sched = Scheduler::new();
    sched.schedule_at(0.0, Ev::TrySend);
    // Generous cap: ~40 events per packet covers deep-loss regimes.
    let cap = 200_000 + total.saturating_mul(40);
    run(&mut world, &mut sched, cap);
    assert!(world.done_at.is_some(), "TCP transfer did not complete");
    world.res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::loss::{BernoulliLoss, NoLoss};

    fn params() -> NetParams {
        NetParams::paper_default(0.0)
    }

    #[test]
    fn lossless_tcp_approaches_link_rate() {
        let p = params();
        let bytes = 200u64 * 1024 * 1024; // 200 MB ⇒ 51200 packets
        let res = run_tcp(&mut NoLoss, &p, bytes);
        let wire = bytes.div_ceil(4096) as f64 / p.r;
        assert_eq!(res.packets_lost, 0);
        assert_eq!(res.retransmissions, 0);
        // Slow start ramp + ACK latency overhead, but within 2% of wire.
        assert!(
            res.total_time < wire * 1.02 + 1.0,
            "time {} ≫ wire {wire}",
            res.total_time
        );
    }

    #[test]
    fn all_packets_delivered_exactly_once_lossless() {
        let p = params();
        let res = run_tcp(&mut NoLoss, &p, 10 * 1024 * 1024);
        assert_eq!(res.packets_sent, 2560);
    }

    #[test]
    fn loss_degrades_tcp_sharply() {
        let p = params();
        let bytes = 50u64 * 1024 * 1024;
        let t_clean = run_tcp(&mut NoLoss, &p, bytes).total_time;
        let mut l1 = BernoulliLoss::new(0.001, 3);
        let t_low = run_tcp(&mut l1, &p, bytes).total_time;
        let mut l2 = BernoulliLoss::new(0.02, 4);
        let t_med = run_tcp(&mut l2, &p, bytes).total_time;
        let mut l3 = BernoulliLoss::new(0.05, 5);
        let t_high = run_tcp(&mut l3, &p, bytes).total_time;
        assert!(t_clean < t_low && t_low < t_med && t_med < t_high,
            "{t_clean} {t_low} {t_med} {t_high}");
        // The paper's qualitative claim: transmission time increases
        // *significantly* with loss.
        assert!(t_med > 3.0 * t_low, "2% vs 0.1%: {t_med} vs {t_low}");
    }

    #[test]
    fn retransmissions_and_timeouts_counted() {
        let p = params();
        let mut l = BernoulliLoss::new(0.05, 9);
        let res = run_tcp(&mut l, &p, 20 * 1024 * 1024);
        assert!(res.retransmissions > 0);
        assert!(res.packets_lost > 0);
        // Every lost data packet eventually got through.
        assert!(res.packets_sent >= 5120 + res.packets_lost);
    }

    #[test]
    fn deterministic_for_seed() {
        let p = params();
        let run1 = {
            let mut l = BernoulliLoss::new(0.02, 7);
            run_tcp(&mut l, &p, 10 * 1024 * 1024)
        };
        let run2 = {
            let mut l = BernoulliLoss::new(0.02, 7);
            run_tcp(&mut l, &p, 10 * 1024 * 1024)
        };
        assert!((run1.total_time - run2.total_time).abs() < 1e-9);
        assert_eq!(run1.packets_sent, run2.packets_sent);
    }

    #[test]
    fn reno_cwnd_aimd_dynamics() {
        let mut w = RenoCwnd::new();
        assert!((w.cwnd() - 2.0).abs() < 1e-12);
        // Slow start: +1 per ACK while below ssthresh.
        for _ in 0..8 {
            w.on_ack();
        }
        assert!((w.cwnd() - 10.0).abs() < 1e-12);
        // Loss halves the window and sets ssthresh there.
        w.on_loss();
        assert!((w.cwnd() - 5.0).abs() < 1e-12);
        // Now in congestion avoidance: sub-linear growth per ACK.
        w.on_ack();
        assert!((w.cwnd() - 5.2).abs() < 1e-12);
        // Floor at 2 segments no matter how many losses.
        for _ in 0..10 {
            w.on_loss();
        }
        assert!((w.cwnd() - 2.0).abs() < 1e-12);
        // rate() spreads the window over one RTT.
        assert!((w.rate(0.5) - 4.0).abs() < 1e-12);
    }
}
